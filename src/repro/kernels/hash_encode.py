"""Pallas TPU kernel: fused weighted LSH hash encode.

Computes level-1 bucket codes for a tile of points against all beta hash
functions in one pass:

    codes = floor( ((X o W) @ A) / w + b_frac ) + b_int        (int32)

i.e. a blocked (n, d) x (d, beta) matmul (MXU) whose epilogue fuses the
weight elementwise scaling (on the X tile as it is loaded), the bucket-width
division, the fractional-offset floor, and the exact integer offset b_int —
so codes never round-trip through HBM as floats.

Tiling: grid (n/BN, beta/BB, d/BD); the d axis is the contraction
("arbitrary" semantics), with an f32 VMEM accumulator scratch.  MXU-aligned
defaults BN=256, BB=128, BD=256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["hash_encode_pallas"]


def _kernel(x_ref, w_ref, a_ref, bint_ref, bfrac_ref, o_ref, acc_ref, *,
            inv_width: float, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...] * w_ref[...]  # (BN, BD) * (1, BD): fused weighting
    acc_ref[...] += jnp.dot(
        x, a_ref[...], preferred_element_type=jnp.float32
    )

    @pl.when(k == k_steps - 1)
    def _epilogue():
        u = acc_ref[...] * inv_width + bfrac_ref[...]  # (BN, BB) + (1, BB)
        o_ref[...] = jnp.floor(u).astype(jnp.int32) + bint_ref[...]


@functools.partial(
    jax.jit, static_argnames=("width", "bn", "bb", "bd", "interpret")
)
def hash_encode_pallas(
    points,  # (n, d) f32
    weight,  # (d,) f32
    proj,  # (d, beta) f32
    b_int,  # (beta,) int32
    b_frac,  # (beta,) f32
    width: float,
    bn: int = 256,
    bb: int = 128,
    bd: int = 256,
    interpret: bool = False,
):
    n, d = points.shape
    beta = proj.shape[1]
    bn = min(bn, n)
    bb = min(bb, beta)
    bd = min(bd, d)
    assert n % bn == 0 and beta % bb == 0 and d % bd == 0, (
        "caller (ops.py) must pad to block multiples"
    )
    k_steps = d // bd
    grid = (n // bn, beta // bb, k_steps)
    kernel = functools.partial(
        _kernel, inv_width=float(1.0 / width), k_steps=k_steps
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), lambda i, j, k: (i, k)),  # X
            pl.BlockSpec((1, bd), lambda i, j, k: (0, k)),  # weight row
            pl.BlockSpec((bd, bb), lambda i, j, k: (k, j)),  # A
            pl.BlockSpec((1, bb), lambda i, j, k: (0, j)),  # b_int row
            pl.BlockSpec((1, bb), lambda i, j, k: (0, j)),  # b_frac row
        ],
        out_specs=pl.BlockSpec((bn, bb), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((n, beta), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bn, bb), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(
        points.astype(jnp.float32),
        weight.astype(jnp.float32)[None, :],
        proj.astype(jnp.float32),
        b_int.astype(jnp.int32)[None, :],
        b_frac.astype(jnp.float32)[None, :],
    )
