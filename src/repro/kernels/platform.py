"""Platform detection and per-backend kernel dispatch.

One process serves one backend, so the backend query is answered once and
cached (``backend()``) instead of re-asking ``jax.default_backend()`` on
every op call — the seed-era ``ops.on_tpu()`` did exactly that re-query in
the middle of every kernel dispatch.  ``set_platform`` (bayespec style,
SNIPPETS.md snippet 1) pins the platform *before* the first JAX call and
installs the GPU latency-hiding XLA flags; it also resets the cache.

``resolve`` maps the single user-facing knob — ``use_pallas`` on
``IndexConfig`` / ``ServiceConfig`` / the launcher's ``--use-pallas`` —
onto the concrete query-pipeline path.  The dispatch table for the
``None`` ("auto") default:

  ============  ==========================  ===========================
  backend       query pipeline              kernel bodies
  ============  ==========================  ===========================
  tpu           fused (single block-scan    Pallas, compiled (Mosaic)
                launch per pass)
  gpu           fused                       XLA composite (Pallas once
                                            ``gpu_pallas_supported()``;
                                            the bodies are Mosaic/TPU
                                            today, so not yet) — plus
                                            the latency-hiding XLA flags
                                            from ``set_platform``
  cpu           fused                       XLA composite (one jit, no
                                            per-stage HBM round trips)
  ============  ==========================  ===========================

Explicit values: ``False`` keeps the seed-era unfused stage-by-stage path
(the parity oracle), ``True`` forces fused Pallas (compiled on TPU,
interpret elsewhere), ``"interpret"`` forces fused Pallas with the kernel
body executed in interpret mode — the same body, testable on every
backend.
"""

from __future__ import annotations

import dataclasses
import os

import jax

__all__ = [
    "KernelPath",
    "backend",
    "default_use_pallas",
    "describe",
    "gpu_pallas_supported",
    "on_tpu",
    "resolve",
    "set_platform",
]

# <https://jax.readthedocs.io/en/latest/gpu_performance_tips.html> — the
# latency-hiding scheduler + async collectives let state restores/prefetch
# uploads overlap query launches on GPU the way they already do on TPU.
_GPU_XLA_FLAGS = (
    "--xla_gpu_triton_gemm_any=True "
    "--xla_gpu_enable_latency_hiding_scheduler=true "
    "--xla_gpu_enable_highest_priority_async_stream=true"
)

_backend_cache: str | None = None


def backend() -> str:
    """The JAX default backend name ("cpu" / "gpu" / "tpu"), cached.

    The answer cannot change after the first JAX computation, so every op
    dispatch reads this cache instead of re-querying the JAX client
    registry (``ops.on_tpu()`` used to call ``jax.default_backend()`` per
    op call).  ``set_platform`` resets the cache.
    """
    global _backend_cache
    if _backend_cache is None:
        _backend_cache = jax.default_backend()
    return _backend_cache


def on_tpu() -> bool:
    """True when the cached backend is TPU."""
    return backend() == "tpu"


def set_platform(platform: str | None = None) -> None:
    """Pin the JAX platform ("cpu" / "gpu" / "tpu") before first use.

    Only takes effect ahead of the first JAX computation (JAX fixes its
    client then).  On GPU additionally installs the latency-hiding XLA
    flags (appended to any existing ``XLA_FLAGS``), mirroring the
    bayespec ``set_platform`` helper.  Resets the cached ``backend()``.
    """
    global _backend_cache
    if platform is not None:
        jax.config.update("jax_platform_name", platform)
        if platform == "gpu":
            existing = os.environ.get("XLA_FLAGS", "")
            if "--xla_gpu_enable_latency_hiding_scheduler" not in existing:
                os.environ["XLA_FLAGS"] = (
                    f"{existing} {_GPU_XLA_FLAGS}".strip()
                )
    _backend_cache = None


def gpu_pallas_supported() -> bool:
    """Whether the Pallas kernel bodies can compile for the GPU backend.

    The kernels in this package target Mosaic (TPU): they use
    ``pltpu.VMEM``/``pltpu.SMEM`` memory spaces and TPU compiler params,
    so the compiled path is TPU-only today.  This probe is the single
    place a Triton port would flip to widen the auto dispatch.
    """
    return False


def default_use_pallas() -> bool:
    """Whether ``use_pallas=None`` resolves to compiled Pallas kernels."""
    b = backend()
    return b == "tpu" or (b == "gpu" and gpu_pallas_supported())


@dataclasses.dataclass(frozen=True)
class KernelPath:
    """Resolved query-pipeline dispatch for one ``use_pallas`` value.

    ``fused``     — dispatch both block-scan passes through
                    ``ops.fused_query_block`` (histogram / masked-score
                    intermediates never round-trip through HBM between
                    stages); ``False`` is the seed-era unfused oracle.
    ``pallas``    — run the fused step as the Pallas kernel body
                    (``False``: the bit-exact fused XLA composite).
    ``interpret`` — execute the Pallas body in interpret mode (same
                    kernel code, runs on every backend).
    """

    fused: bool
    pallas: bool
    interpret: bool

    @property
    def label(self) -> str:
        """Short human name of the path ("fused-pallas", "unfused", ...)."""
        if not self.fused:
            return "unfused"
        if not self.pallas:
            return "fused-xla"
        return "fused-pallas-interpret" if self.interpret else "fused-pallas"


def resolve(use_pallas: bool | str | None) -> KernelPath:
    """Map a ``use_pallas`` config value onto a concrete ``KernelPath``.

    ``None`` ("auto") picks per backend from the module dispatch table;
    ``True``/``False``/``"interpret"`` force the path (``True`` degrades
    compiled -> interpret off-TPU so the same config runs everywhere).
    """
    if use_pallas is False:
        return KernelPath(fused=False, pallas=False, interpret=False)
    if use_pallas is None:
        return KernelPath(True, default_use_pallas(), False)
    if use_pallas is True:
        return KernelPath(True, True, not on_tpu())
    if use_pallas == "interpret":
        return KernelPath(True, True, True)
    raise ValueError(
        f"use_pallas must be None, True, False or 'interpret', "
        f"got {use_pallas!r}"
    )


def describe(use_pallas: bool | str | None) -> str:
    """One-line report of the resolved kernel path for the CLI."""
    path = resolve(use_pallas)
    if not path.fused:
        return f"unfused reference stages (XLA) on {backend()}"
    if not path.pallas:
        return f"fused query step, XLA composite, on {backend()}"
    mode = "interpret" if path.interpret else "compiled"
    return f"fused query step, Pallas {mode}, on {backend()}"
