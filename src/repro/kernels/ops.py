"""Public jit'd wrappers around the Pallas kernels.

Each op pads inputs to kernel block multiples, dispatches to the Pallas
kernel (interpret=True off-TPU so the same kernel body runs everywhere),
and masks the padding out of the result.  ``use_pallas=False`` routes to
the pure-jnp oracle in ref.py; ``use_pallas=None`` resolves per backend
through ``kernels.platform`` (compiled Pallas where supported, reference
elsewhere) and ``use_pallas="interpret"`` forces the Pallas body in
interpret mode — the same kernel code, executable on every backend.

``fused_query_block`` is the engine's fused per-block query step (pass-1
histograms or pass-2 stop-masked scores in one launch); its reference
route is the fused XLA composite in ref.py, which shares the unfused
engine's distance helpers and is therefore bit-exact with it.

p == 2 distance scoring in the *unfused* ops always uses the norms+matmul
expansion (MXU beats any elementwise kernel for the quadratic case); the
fused kernel runs the same expansion on the MXU inside the kernel body.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import platform, ref
from .freq_level import freq_level_pallas
from .fused_query import fused_query_hist_pallas, fused_query_scores_pallas
from .hash_encode import hash_encode_pallas
from .weighted_lp import weighted_lp_pallas

__all__ = [
    "hash_encode",
    "freq_level",
    "weighted_lp_dist",
    "fused_query_block",
    "on_tpu",
]

# Back-compat alias; the cached query lives in kernels.platform now.
on_tpu = platform.on_tpu


def _resolve_flags(use_pallas, interpret):
    """Normalize (use_pallas, interpret) through the cached backend."""
    if use_pallas == "interpret":
        return True, True
    if use_pallas is None:
        use_pallas = platform.default_use_pallas()
    if interpret is None:
        interpret = not platform.on_tpu()
    return use_pallas, interpret


def _pad_to(x, mult: int, axis: int, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def hash_encode(
    points,
    weight,
    proj,
    b_int,
    b_frac,
    width: float,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    bb: int = 128,
    bd: int = 256,
):
    """(n, beta) int32 level-1 bucket codes."""
    use_pallas, interpret = _resolve_flags(use_pallas, interpret)
    if not use_pallas:
        return ref.hash_encode_ref(points, proj, b_int, b_frac, weight, width)
    n, d = points.shape
    beta = proj.shape[1]
    pts = _pad_to(_pad_to(points, bn, 0), bd, 1)
    w = _pad_to(weight, bd, 0)
    a = _pad_to(_pad_to(proj, bd, 0), bb, 1)
    bi = _pad_to(b_int, bb, 0)
    bf = _pad_to(b_frac, bb, 0)
    out = hash_encode_pallas(
        pts, w, a, bi, bf, width, bn=bn, bb=bb, bd=bd, interpret=interpret
    )
    return out[:n, :beta]


def freq_level(
    codes_p,
    codes_q,
    mu,
    c: int,
    n_levels: int,
    beta_q=None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    unroll: bool = False,
):
    """(Q, n) int32 first-frequent-level matrix (n_levels+1 = never)."""
    use_pallas, interpret = _resolve_flags(use_pallas, interpret)
    q = codes_q.shape[0]
    mu = jnp.broadcast_to(jnp.asarray(mu, jnp.int32), (q,))
    if beta_q is None:
        beta_q = jnp.full((q,), codes_p.shape[1], jnp.int32)
    beta_q = jnp.broadcast_to(jnp.asarray(beta_q, jnp.int32), (q,))
    if not use_pallas:
        return ref.freq_level_ref(codes_p, codes_q, mu, c, n_levels, beta_q,
                                  unroll=unroll)
    n = codes_p.shape[0]
    cp = _pad_to(codes_p, bn, 0, value=jnp.iinfo(jnp.int32).max // 2)
    out = freq_level_pallas(
        cp, codes_q, mu, beta_q, c=c, n_levels=n_levels, bn=bn,
        interpret=interpret,
    )
    return out[:, :n]


def weighted_lp_dist(
    queries,
    points,
    weight,
    p: float,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    bd: int = 256,
):
    """(Q, n) f32 weighted l_p distances."""
    use_pallas, interpret = _resolve_flags(use_pallas, interpret)
    if abs(p - 2.0) < 1e-9 or not use_pallas:
        return ref.weighted_lp_ref(queries, points, weight, p)
    qn, d = queries.shape
    n = points.shape[0]
    q = _pad_to(queries, bd, 1)
    x = _pad_to(_pad_to(points, bn, 0), bd, 1)
    w = _pad_to(weight, bd, 0)
    out = weighted_lp_pallas(q, x, w, p=p, bn=bn, bd=bd, interpret=interpret)
    return out[:, :n]


def fused_query_block(
    codes_p,  # (B, beta) int32 — one scan block of point codes
    points,  # (B, d) — the matching vector block (any float dtype)
    codes_q,  # (Q, beta) int32 query bucket codes
    queries,  # (Q, d) query vectors
    q_weight,  # (Q, d) per-query weight vectors
    mu,  # (Q,) or scalar int32 collision thresholds
    r_min,  # (Q,) or scalar f32 radius bases (pass-1 good-level ceil)
    beta_q,  # (Q,) or scalar int32 per-member table counts; None = all
    *,
    boff,  # () int32 global row offset of this block
    n_valid,  # () int32 streaming live-row watermark (rows >= it are dead)
    c: int,
    n_levels: int,
    p: float,
    stop=None,  # None = pass-1 (histograms); (Q,) int32 = pass-2 (scores)
    use_pallas: bool | str | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    unroll: bool = False,
):
    """One fused query block step — the engine's per-scan-block launch.

    Pass 1 (``stop=None``) returns ``(hist_f, hist_g)`` per-level
    frequent/good histogram contributions, each ``(Q, n_levels + 2)``
    int32 (bins 0..n_levels+1; excluded rows — block padding and rows at
    or beyond ``n_valid`` — are dropped entirely).  Pass 2 (``stop``
    given) returns ``(Q, B)`` f32 distances with rows past the query's
    stop level (and excluded rows) masked to +inf, ready for a running
    top-k.

    The reference route is the fused XLA composite in ref.py, which
    reuses the unfused engine's distance helpers on identical shapes and
    is therefore bit-exact with the unfused scan.  The Pallas route runs
    the whole step as one kernel launch (see fused_query.py).
    """
    use_pallas, interpret = _resolve_flags(use_pallas, interpret)
    b, _ = codes_p.shape
    q = codes_q.shape[0]
    mu = jnp.broadcast_to(jnp.asarray(mu, jnp.int32), (q,))
    r_min = jnp.broadcast_to(jnp.asarray(r_min, jnp.float32), (q,))
    if beta_q is None:
        beta_q = jnp.full((q,), codes_p.shape[1], jnp.int32)
    beta_q = jnp.broadcast_to(jnp.asarray(beta_q, jnp.int32), (q,))
    if stop is not None:
        stop = jnp.broadcast_to(jnp.asarray(stop, jnp.int32), (q,))
    boff = jnp.asarray(boff, jnp.int32)
    n_valid = jnp.asarray(n_valid, jnp.int32)
    pts = points.astype(jnp.float32)
    qs = queries.astype(jnp.float32)
    w = q_weight.astype(jnp.float32)

    if not use_pallas:
        row_ok = (boff + jnp.arange(b, dtype=jnp.int32)) < n_valid
        if stop is None:
            hf, hg = ref.fused_query_hist_ref(
                codes_p, pts, codes_q, qs, w, mu, beta_q, r_min, row_ok,
                c=c, n_levels=n_levels, p=p, unroll=unroll,
            )
            return hf[:, : n_levels + 2], hg[:, : n_levels + 2]
        return ref.fused_query_scores_ref(
            codes_p, pts, codes_q, qs, w, mu, beta_q, stop, row_ok,
            c=c, n_levels=n_levels, p=p, unroll=unroll,
        )

    cp = _pad_to(codes_p, bn, 0, value=jnp.iinfo(jnp.int32).max // 2)
    xp = _pad_to(_pad_to(pts, bn, 0), 128, 1)
    qsp = _pad_to(qs, 128, 1)
    wp = _pad_to(w, 128, 1)
    if stop is None:
        hf, hg = fused_query_hist_pallas(
            cp, xp, codes_q, qsp, wp, mu, beta_q, r_min, boff, n_valid,
            c=c, n_levels=n_levels, p=p, n_rows=b, bn=bn,
            interpret=interpret,
        )
        return hf[:, : n_levels + 2], hg[:, : n_levels + 2]
    out = fused_query_scores_pallas(
        cp, xp, codes_q, qsp, wp, mu, beta_q, stop, boff, n_valid,
        c=c, n_levels=n_levels, p=p, n_rows=b, bn=bn, interpret=interpret,
    )
    return out[:, :b]
