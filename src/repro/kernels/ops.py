"""Public jit'd wrappers around the Pallas kernels.

Each op pads inputs to kernel block multiples, dispatches to the Pallas
kernel (interpret=True off-TPU so the same kernel body runs everywhere),
and masks the padding out of the result.  ``use_pallas=False`` routes to the
pure-jnp oracle in ref.py — the default on CPU hosts for speed (interpret
mode executes the kernel body per grid cell in Python); the sharded engine
flips it on TPU.

p == 2 distance scoring always uses the norms+matmul expansion (MXU beats
any elementwise kernel for the quadratic case); the Pallas path serves the
fractional/l_1 distances the paper targets.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref
from .freq_level import freq_level_pallas
from .hash_encode import hash_encode_pallas
from .weighted_lp import weighted_lp_pallas

__all__ = ["hash_encode", "freq_level", "weighted_lp_dist", "on_tpu"]


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_to(x, mult: int, axis: int, value=0):
    size = x.shape[axis]
    rem = (-size) % mult
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value)


def hash_encode(
    points,
    weight,
    proj,
    b_int,
    b_frac,
    width: float,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    bb: int = 128,
    bd: int = 256,
):
    """(n, beta) int32 level-1 bucket codes."""
    if use_pallas is None:
        use_pallas = on_tpu()
    if not use_pallas:
        return ref.hash_encode_ref(points, proj, b_int, b_frac, weight, width)
    if interpret is None:
        interpret = not on_tpu()
    n, d = points.shape
    beta = proj.shape[1]
    pts = _pad_to(_pad_to(points, bn, 0), bd, 1)
    w = _pad_to(weight, bd, 0)
    a = _pad_to(_pad_to(proj, bd, 0), bb, 1)
    bi = _pad_to(b_int, bb, 0)
    bf = _pad_to(b_frac, bb, 0)
    out = hash_encode_pallas(
        pts, w, a, bi, bf, width, bn=bn, bb=bb, bd=bd, interpret=interpret
    )
    return out[:n, :beta]


def freq_level(
    codes_p,
    codes_q,
    mu,
    c: int,
    n_levels: int,
    beta_q=None,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    unroll: bool = False,
):
    """(Q, n) int32 first-frequent-level matrix (n_levels+1 = never)."""
    if use_pallas is None:
        use_pallas = on_tpu()
    q = codes_q.shape[0]
    mu = jnp.broadcast_to(jnp.asarray(mu, jnp.int32), (q,))
    if beta_q is None:
        beta_q = jnp.full((q,), codes_p.shape[1], jnp.int32)
    beta_q = jnp.broadcast_to(jnp.asarray(beta_q, jnp.int32), (q,))
    if not use_pallas:
        return ref.freq_level_ref(codes_p, codes_q, mu, c, n_levels, beta_q,
                                  unroll=unroll)
    if interpret is None:
        interpret = not on_tpu()
    n = codes_p.shape[0]
    cp = _pad_to(codes_p, bn, 0, value=jnp.iinfo(jnp.int32).max // 2)
    out = freq_level_pallas(
        cp, codes_q, mu, beta_q, c=c, n_levels=n_levels, bn=bn,
        interpret=interpret,
    )
    return out[:, :n]


def weighted_lp_dist(
    queries,
    points,
    weight,
    p: float,
    use_pallas: bool | None = None,
    interpret: bool | None = None,
    bn: int = 256,
    bd: int = 256,
):
    """(Q, n) f32 weighted l_p distances."""
    if abs(p - 2.0) < 1e-9 or use_pallas is False or (
        use_pallas is None and not on_tpu()
    ):
        return ref.weighted_lp_ref(queries, points, weight, p)
    if interpret is None:
        interpret = not on_tpu()
    qn, d = queries.shape
    n = points.shape[0]
    q = _pad_to(queries, bd, 1)
    x = _pad_to(_pad_to(points, bn, 0), bd, 1)
    w = _pad_to(weight, bd, 0)
    out = weighted_lp_pallas(q, x, w, p=p, bn=bn, bd=bd, interpret=interpret)
    return out[:, :n]
