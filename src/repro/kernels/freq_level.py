"""Pallas TPU kernel: fused multi-level collision counting ("freq_level").

The TPU-native form of the C2LSH virtual-rehashing search (DESIGN.md Sec 2):
for a tile of points and one query it computes, in a single pass over the
(point, table) code matrix, the FIRST level j at which the point's collision
count reaches the query's threshold mu:

    out[q, o] = min { j : #{ i : floor(h_i(o)/c^j) == floor(h_i(q)/c^j) } >= mu }

(n_levels + 1 if never frequent).  The level loop runs entirely in VMEM on
int32 code tiles — each iteration is one integer floor-divide + compare +
lane reduction; the codes shrink monotonically so no reloads are needed.
This replaces the paper's sequential radius-doubling probes with one fused
sweep (all radii at once), which is the main beyond-paper optimization.

Grid: (Q, n/BN).  Query block (1, beta), point block (BN, beta), output
block (1, BN).  All tiles 2-D to stay Mosaic-friendly.  beta is kept whole
in VMEM: BN=256, beta<=1024 -> ~1.3 MB of int32 codes per step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["freq_level_pallas"]


def _floor_div(x, c: int):
    # lax integer div truncates toward zero; emulate floor for negatives.
    q = jax.lax.div(x, jnp.int32(c))
    r = jax.lax.rem(x, jnp.int32(c))
    return q - jnp.where((r != 0) & ((r < 0) != (c < 0)), 1, 0).astype(jnp.int32)


def _kernel(q_ref, p_ref, mu_ref, bq_ref, o_ref, *, c: int, n_levels: int):
    never = jnp.int32(n_levels + 1)
    a = p_ref[...].astype(jnp.int32)  # (BN, beta)
    b = q_ref[...].astype(jnp.int32)  # (1, beta)
    mu = mu_ref[0, 0]
    beta_q = bq_ref[0, 0]
    lane = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)  # (BN, beta)
    lane_ok = (lane < beta_q).astype(jnp.int32)
    out = jnp.full((1, a.shape[0]), never, jnp.int32)

    def body(j, carry):
        a, b, out = carry
        cnt = jnp.sum((a == b).astype(jnp.int32) * lane_ok, axis=1)[None, :]
        out = jnp.where((cnt >= mu) & (out == never), jnp.int32(j), out)
        return (_floor_div(a, c), _floor_div(b, c), out)

    _, _, out = jax.lax.fori_loop(
        0, n_levels + 1, body, (a, b, out), unroll=True
    )
    o_ref[...] = out


@functools.partial(
    jax.jit, static_argnames=("c", "n_levels", "bn", "interpret")
)
def freq_level_pallas(
    codes_p,  # (n, beta) int32
    codes_q,  # (Q, beta) int32
    mu,  # (Q,) int32 per-query collision threshold
    beta_q,  # (Q,) int32 per-query table count (WLSH beta_{W_i})
    c: int,
    n_levels: int,
    bn: int = 256,
    interpret: bool = False,
):
    n, beta = codes_p.shape
    q = codes_q.shape[0]
    bn = min(bn, n)
    assert n % bn == 0, "caller (ops.py) must pad points to block multiples"
    grid = (q, n // bn)
    kernel = functools.partial(_kernel, c=int(c), n_levels=int(n_levels))
    smem_spec = pl.BlockSpec(
        (1, 1), lambda iq, ip: (iq, 0), memory_space=pltpu.SMEM
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, beta), lambda iq, ip: (iq, 0)),
            pl.BlockSpec((bn, beta), lambda iq, ip: (ip, 0)),
            smem_spec,
            smem_spec,
        ],
        out_specs=pl.BlockSpec((1, bn), lambda iq, ip: (iq, ip)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.int32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
    )(
        codes_q.astype(jnp.int32),
        codes_p.astype(jnp.int32),
        jnp.asarray(mu, jnp.int32).reshape(-1, 1),
        jnp.asarray(beta_q, jnp.int32).reshape(-1, 1),
    )
