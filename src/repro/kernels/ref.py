"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them exactly (integer
outputs) or to float tolerance (distances).  Shapes:

  hash_encode_ref : (n, d) x (d, beta) -> (n, beta) int32 bucket codes
  freq_level_ref  : (n, beta) codes x (Q, beta) query codes -> (Q, n) int32
                    first level j (0..n_levels) at which the point is
                    *frequent* for the query (collision count >= mu at
                    level-c^j buckets); n_levels + 1 if never frequent.
  count_level_ref : collision counts at one fixed level (faithful C2LSH)
  weighted_lp_ref : (Q, d) x (n, d) -> (Q, n) distances under weight W
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = [
    "hash_encode_ref",
    "freq_level_ref",
    "count_level_ref",
    "weighted_lp_ref",
]


@functools.partial(jax.jit, static_argnames=())
def hash_encode_ref(points, proj, b_int, b_frac, weight, width):
    """floor((a . (W o x))/w + b_frac) + b_int, exact-int split of b*."""
    x = points.astype(jnp.float32) * weight.astype(jnp.float32)
    u = (x @ proj.astype(jnp.float32)) / width + b_frac
    return jnp.floor(u).astype(jnp.int32) + b_int.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("c", "n_levels", "unroll"))
def _freq_level_ref(codes_p, codes_q, mu, beta_q, c: int, n_levels: int,
                    unroll: bool = False):
    never = jnp.int32(n_levels + 1)
    out = jnp.full((codes_q.shape[0], codes_p.shape[0]), never, jnp.int32)
    a = codes_p.astype(jnp.int32)  # (n, beta)
    b = codes_q.astype(jnp.int32)  # (Q, beta)
    lane = jnp.arange(a.shape[1], dtype=jnp.int32)
    lane_ok = (lane[None, :] < beta_q[:, None]).astype(jnp.int32)  # (Q, beta)

    def body(j, carry):
        a, b, out = carry
        cnt = jnp.sum(
            (b[:, None, :] == a[None, :, :]).astype(jnp.int32)
            * lane_ok[:, None, :],
            axis=-1,
        )  # (Q, n)
        hit = (cnt >= mu[:, None]) & (out == never)
        out = jnp.where(hit, jnp.int32(j), out)
        return (jnp.floor_divide(a, c), jnp.floor_divide(b, c), out)

    carry = (a, b, out)
    if unroll:  # analysis: cost_analysis counts loop bodies once
        for j in range(n_levels + 1):
            carry = body(j, carry)
        return carry[2]
    a, b, out = jax.lax.fori_loop(0, n_levels + 1, body, carry)
    return out


def freq_level_ref(codes_p, codes_q, mu, c: int, n_levels: int, beta_q=None,
                   unroll: bool = False):
    """First frequent level per (query, point); fuses all C2LSH radii.

    ``mu`` may be a scalar or (Q,); ``beta_q`` optionally limits each query
    to its first beta_q hash tables (WLSH per-member beta_{W_i} semantics;
    default = all tables).
    """
    q = codes_q.shape[0]
    mu_arr = jnp.broadcast_to(jnp.asarray(mu, jnp.int32), (q,))
    if beta_q is None:
        beta_q = jnp.full((q,), codes_p.shape[1], jnp.int32)
    beta_arr = jnp.broadcast_to(jnp.asarray(beta_q, jnp.int32), (q,))
    return _freq_level_ref(codes_p, codes_q, mu_arr, beta_arr, int(c),
                           int(n_levels), unroll=unroll)


@functools.partial(jax.jit, static_argnames=("c", "level"))
def count_level_ref(codes_p, codes_q, c: int, level: int):
    """Collision counts at level c**level (paper-faithful single radius)."""
    l = c**level
    a = jnp.floor_divide(codes_p.astype(jnp.int32), l)
    b = jnp.floor_divide(codes_q.astype(jnp.int32), l)
    return jnp.sum((b[:, None, :] == a[None, :, :]).astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("p",))
def weighted_lp_ref(queries, points, weight, p: float):
    """(Q, n) weighted l_p distances, f32."""
    qw = queries.astype(jnp.float32) * weight
    pw = points.astype(jnp.float32) * weight
    if abs(p - 2.0) < 1e-9:
        qq = jnp.sum(qw * qw, axis=-1)
        pp = jnp.sum(pw * pw, axis=-1)
        cross = qw @ pw.T
        d2 = qq[:, None] + pp[None, :] - 2.0 * cross
        return jnp.sqrt(jnp.maximum(d2, 0.0))
    diff = jnp.abs(qw[:, None, :] - pw[None, :, :])
    if abs(p - 1.0) < 1e-9:
        return jnp.sum(diff, axis=-1)
    return jnp.sum(diff**p, axis=-1) ** (1.0 / p)
