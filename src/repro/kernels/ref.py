"""Pure-jnp oracles for every Pallas kernel in this package.

These define the semantics; kernels must match them exactly (integer
outputs) or to float tolerance (distances).  Shapes:

  hash_encode_ref : (n, d) x (d, beta) -> (n, beta) int32 bucket codes
  freq_level_ref  : (n, beta) codes x (Q, beta) query codes -> (Q, n) int32
                    first level j (0..n_levels) at which the point is
                    *frequent* for the query (collision count >= mu at
                    level-c^j buckets); n_levels + 1 if never frequent.
  count_level_ref : collision counts at one fixed level (faithful C2LSH)
  weighted_lp_ref : (Q, d) x (n, d) -> (Q, n) distances under weight W

The fused-query oracles (``fused_query_hist_ref`` / ``fused_query_scores_ref``)
define the semantics of one fused block step — first-frequent level, weighted
distance, good-level histogramming and stop-mask scoring in one composite.
They are also the *serving* fused path off-TPU: the engine's unfused scan uses
the exact same ``per_query_l2`` / ``per_query_lp`` helpers on the exact same
block shapes, so the fused XLA composite is bit-exact with the unfused oracle
by construction (same HLO subgraphs — f32 gemm results are only reproducible
at fixed shapes, which is why sharing these helpers matters).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

__all__ = [
    "hash_encode_ref",
    "freq_level_ref",
    "count_level_ref",
    "weighted_lp_ref",
    "log_c",
    "per_query_l2",
    "per_query_lp",
    "per_query_dist",
    "fused_query_hist_ref",
    "fused_query_scores_ref",
]


@functools.partial(jax.jit, static_argnames=())
def hash_encode_ref(points, proj, b_int, b_frac, weight, width):
    """floor((a . (W o x))/w + b_frac) + b_int, exact-int split of b*."""
    x = points.astype(jnp.float32) * weight.astype(jnp.float32)
    u = (x @ proj.astype(jnp.float32)) / width + b_frac
    return jnp.floor(u).astype(jnp.int32) + b_int.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("c", "n_levels", "unroll"))
def _freq_level_ref(codes_p, codes_q, mu, beta_q, c: int, n_levels: int,
                    unroll: bool = False):
    never = jnp.int32(n_levels + 1)
    out = jnp.full((codes_q.shape[0], codes_p.shape[0]), never, jnp.int32)
    a = codes_p.astype(jnp.int32)  # (n, beta)
    b = codes_q.astype(jnp.int32)  # (Q, beta)
    lane = jnp.arange(a.shape[1], dtype=jnp.int32)
    lane_ok = (lane[None, :] < beta_q[:, None]).astype(jnp.int32)  # (Q, beta)

    def body(j, carry):
        a, b, out = carry
        cnt = jnp.sum(
            (b[:, None, :] == a[None, :, :]).astype(jnp.int32)
            * lane_ok[:, None, :],
            axis=-1,
        )  # (Q, n)
        hit = (cnt >= mu[:, None]) & (out == never)
        out = jnp.where(hit, jnp.int32(j), out)
        return (jnp.floor_divide(a, c), jnp.floor_divide(b, c), out)

    carry = (a, b, out)
    if unroll:  # analysis: cost_analysis counts loop bodies once
        for j in range(n_levels + 1):
            carry = body(j, carry)
        return carry[2]
    a, b, out = jax.lax.fori_loop(0, n_levels + 1, body, carry)
    return out


def freq_level_ref(codes_p, codes_q, mu, c: int, n_levels: int, beta_q=None,
                   unroll: bool = False):
    """First frequent level per (query, point); fuses all C2LSH radii.

    ``mu`` may be a scalar or (Q,); ``beta_q`` optionally limits each query
    to its first beta_q hash tables (WLSH per-member beta_{W_i} semantics;
    default = all tables).
    """
    q = codes_q.shape[0]
    mu_arr = jnp.broadcast_to(jnp.asarray(mu, jnp.int32), (q,))
    if beta_q is None:
        beta_q = jnp.full((q,), codes_p.shape[1], jnp.int32)
    beta_arr = jnp.broadcast_to(jnp.asarray(beta_q, jnp.int32), (q,))
    return _freq_level_ref(codes_p, codes_q, mu_arr, beta_arr, int(c),
                           int(n_levels), unroll=unroll)


@functools.partial(jax.jit, static_argnames=("c", "level"))
def count_level_ref(codes_p, codes_q, c: int, level: int):
    """Collision counts at level c**level (paper-faithful single radius)."""
    l = c**level
    a = jnp.floor_divide(codes_p.astype(jnp.int32), l)
    b = jnp.floor_divide(codes_q.astype(jnp.int32), l)
    return jnp.sum((b[:, None, :] == a[None, :, :]).astype(jnp.int32), axis=-1)


@functools.partial(jax.jit, static_argnames=("p",))
def weighted_lp_ref(queries, points, weight, p: float):
    """(Q, n) weighted l_p distances, f32."""
    qw = queries.astype(jnp.float32) * weight
    pw = points.astype(jnp.float32) * weight
    if abs(p - 2.0) < 1e-9:
        qq = jnp.sum(qw * qw, axis=-1)
        pp = jnp.sum(pw * pw, axis=-1)
        cross = qw @ pw.T
        d2 = qq[:, None] + pp[None, :] - 2.0 * cross
        return jnp.sqrt(jnp.maximum(d2, 0.0))
    diff = jnp.abs(qw[:, None, :] - pw[None, :, :])
    if abs(p - 1.0) < 1e-9:
        return jnp.sum(diff, axis=-1)
    return jnp.sum(diff**p, axis=-1) ** (1.0 / p)


# --------------------------------------------------- fused query-step oracles


def log_c(x, c: int):
    """log base c, the virtual-rehashing level scale."""
    return jnp.log(x) / math.log(c)


def per_query_l2(q, w, pts):
    """(Q, B) weighted l2 with per-query weights, via two matmuls (MXU)."""
    w2 = w * w
    qw2 = jnp.sum(w2 * q * q, axis=-1)  # (Q,)
    cross = (w2 * q) @ pts.T  # (Q, B)
    onorm = w2 @ (pts * pts).T  # (Q, B)
    d2 = qw2[:, None] - 2.0 * cross + onorm
    return jnp.sqrt(jnp.maximum(d2, 0.0))


def per_query_lp(q, w, pts, p: float):
    """(Q, B) weighted l_p (p != 2) with per-query weights, elementwise."""
    diff = jnp.abs((q[:, None, :] - pts[None, :, :]) * w[:, None, :])
    if abs(p - 1.0) < 1e-9:
        return jnp.sum(diff, axis=-1)
    return jnp.sum(diff**p, axis=-1) ** (1.0 / p)


def per_query_dist(q, w, pts, p: float):
    """Per-query-weight distance dispatch shared by every engine path.

    The unfused scan and the fused XLA composite must call this very
    function on the same shapes — that is what makes them bit-exact (f32
    gemms are shape-sensitive in the last ulp).
    """
    if abs(p - 2.0) < 1e-9:
        return per_query_l2(q, w, pts)
    return per_query_lp(q, w, pts, p)


def _fused_lf(codes_b, codes_q, mu, beta_q, row_ok, c, n_levels, unroll):
    """(Q, B) first-frequent level with excluded rows forced to L + 2.

    Excluded rows (padding or rows at/after the streaming ``n_valid``
    watermark) get the sentinel ``n_levels + 2`` — past every histogram
    bin the stop logic reads (0..n_levels) and past every reachable stop
    level, so they vanish from both passes.  (The unfused engine parks
    dead rows at ``n_levels + 1`` instead; bins 0..n_levels and the final
    scores are identical either way.)
    """
    lf = freq_level_ref(codes_b, codes_q, mu, c, n_levels, beta_q,
                        unroll=unroll)
    return jnp.where(row_ok[None, :], lf, jnp.int32(n_levels + 2))


@functools.partial(
    jax.jit, static_argnames=("c", "n_levels", "p", "unroll")
)
def fused_query_hist_ref(codes_b, points_b, codes_q, queries, q_weight, mu,
                         beta_q, r_min, row_ok, c: int, n_levels: int,
                         p: float, unroll: bool = False):
    """Pass-1 fused block step: (hist_f, hist_g) contributions, (Q, L+3).

    One block of codes/points in, per-level frequent and good histogram
    contributions out — level computation, distance, good-level ceil and
    one-hot binning in a single composite.  Bin L+2 collects excluded
    rows and is sliced off by the caller.
    """
    L = n_levels
    lf = _fused_lf(codes_b, codes_q, mu, beta_q, row_ok, c, L, unroll)
    dist = per_query_dist(queries, q_weight, points_b, p)
    jg = jnp.ceil(
        jnp.maximum(log_c(jnp.maximum(dist, 1e-30), c)
                    - log_c(c * r_min, c)[:, None], 0.0)
    ).astype(jnp.int32)
    good = jnp.where(row_ok[None, :], jnp.maximum(lf, jg), jnp.int32(L + 2))
    levels = jnp.arange(L + 3, dtype=jnp.int32)
    hist_f = jnp.sum(
        (lf[:, :, None] == levels[None, None, :]).astype(jnp.int32), axis=1
    )
    hist_g = jnp.sum(
        (good[:, :, None] == levels[None, None, :]).astype(jnp.int32), axis=1
    )
    return hist_f, hist_g


@functools.partial(
    jax.jit, static_argnames=("c", "n_levels", "p", "unroll")
)
def fused_query_scores_ref(codes_b, points_b, codes_q, queries, q_weight, mu,
                           beta_q, stop, row_ok, c: int, n_levels: int,
                           p: float, unroll: bool = False):
    """Pass-2 fused block step: (Q, B) stop-masked weighted distances.

    Rows whose first-frequent level exceeds the query's stop level — and
    every excluded row — score +inf, ready for the engine's running
    top-k.  ``stop <= n_levels`` always, so the L+2 exclusion sentinel
    can never pass the mask.
    """
    lf = _fused_lf(codes_b, codes_q, mu, beta_q, row_ok, c, n_levels, unroll)
    dist = per_query_dist(queries, q_weight, points_b, p)
    return jnp.where(lf <= stop[:, None], dist, jnp.inf)
