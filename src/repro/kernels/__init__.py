"""Pallas TPU kernels for the WLSH hot spots the paper optimizes:

  hash_encode  — fused weighted projection + bucket quantization (MXU matmul
                 with floor/offset epilogue); the Preprocess hot loop.
  freq_level   — fused multi-level collision counting: the C2LSH virtual-
                 rehashing search collapsed into one VMEM-resident sweep
                 returning the first frequent level per (query, point).
  weighted_lp  — candidate scoring for fractional/l_1 distances (p == 2 is
                 routed to a norms+matmul expansion instead).

``ops`` exposes jit'd padded wrappers with a pure-jnp fallback; ``ref``
holds the oracles every kernel is tested against (interpret=True on CPU).
"""

from .ops import freq_level, hash_encode, on_tpu, weighted_lp_dist

__all__ = ["freq_level", "hash_encode", "on_tpu", "weighted_lp_dist"]
