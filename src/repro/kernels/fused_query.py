"""Pallas TPU kernel: fused WLSH query block step (levels + distances).

One launch per scan block replaces the seed pipeline's three (freq_level,
weighted_lp, histogram / mask) with the (q, block) intermediates held in
VMEM — the level matrix and the distance matrix never round-trip through
HBM between stages, which is the memory traffic the LSH scoring pass is
bound by.  Two modes, one per engine pass:

  pass 1 (hist):   codes + points tile -> first-frequent level, weighted
                   l_p distance, good-level ceil, and per-level one-hot
                   histogram contributions (frequent + good), with the
                   streaming ``n_valid`` dead-row mask folded in.
  pass 2 (scores): codes + points tile -> first-frequent level + weighted
                   l_p distances masked by the query's stop level, ready
                   for the engine's running top-k.

Grid: (Q, block/BN).  Query code row (1, beta) and point codes (BN, beta)
stay whole in the lane axis, as do the (1, d)/(BN, d) vector tiles; the
p = 2 distance runs the norms+matmul expansion on the MXU inside the
kernel (two (1, d) x (d, BN) contractions), p != 2 is a VPU reduction.
VMEM per grid step at BN=256, beta<=1024, d<=1024: ~1 MB codes + ~1 MB
vectors + ~128 KB histogram scratch.  Per-query scalars (mu, beta_q,
r_min / stop) ride in SMEM; the block's global row offset and the
streaming row watermark are (1, 1) SMEM scalars shared by every cell.

Histogram bins use a 128-lane-padded axis (``_nbins``); excluded rows
(block padding or rows at/after ``n_valid``) land in bin n_levels + 2,
which the ops wrapper slices off.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = (getattr(pltpu, "CompilerParams", None)
                   or pltpu.TPUCompilerParams)

__all__ = ["fused_query_hist_pallas", "fused_query_scores_pallas", "nbins"]


def nbins(n_levels: int) -> int:
    """Lane-padded histogram width covering bins 0..n_levels+2."""
    return 128 * math.ceil((n_levels + 3) / 128)


def _floor_div(x, c: int):
    # lax integer div truncates toward zero; emulate floor for negatives.
    q = jax.lax.div(x, jnp.int32(c))
    r = jax.lax.rem(x, jnp.int32(c))
    neg = (r != 0) & ((r < 0) != (c < 0))
    return q - jnp.where(neg, 1, 0).astype(jnp.int32)


def _lf_and_dist(cq_ref, cp_ref, qpt_ref, ppt_ref, w_ref, mu_ref, bq_ref,
                 *, c: int, n_levels: int, p: float):
    """(1, BN) first-frequent level + (1, BN) weighted l_p distance."""
    a = cp_ref[...].astype(jnp.int32)  # (BN, beta)
    b = cq_ref[...].astype(jnp.int32)  # (1, beta)
    mu = mu_ref[0, 0]
    beta_q = bq_ref[0, 0]
    lane = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
    lane_ok = (lane < beta_q).astype(jnp.int32)
    never = jnp.int32(n_levels + 1)
    out = jnp.full((1, a.shape[0]), never, jnp.int32)

    def body(j, carry):
        a, b, out = carry
        cnt = jnp.sum((a == b).astype(jnp.int32) * lane_ok, axis=1)[None, :]
        out = jnp.where((cnt >= mu) & (out == never), jnp.int32(j), out)
        return (_floor_div(a, c), _floor_div(b, c), out)

    _, _, lf = jax.lax.fori_loop(
        0, n_levels + 1, body, (a, b, out), unroll=True
    )

    x = ppt_ref[...]  # (BN, d)
    qv = qpt_ref[...]  # (1, d)
    w = w_ref[...]  # (1, d)
    if abs(p - 2.0) < 1e-9:
        w2 = w * w
        qw2 = jnp.sum(w2 * qv * qv)
        cross = jax.lax.dot_general(
            w2 * qv, x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (1, BN)
        onorm = jax.lax.dot_general(
            w2, x * x, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (1, BN)
        d2 = qw2 - 2.0 * cross + onorm
        dist = jnp.sqrt(jnp.maximum(d2, 0.0))
    else:
        diff = jnp.abs((qv - x) * w)  # (BN, d)
        if abs(p - 1.0) < 1e-9:
            dist = jnp.sum(diff, axis=1)[None, :]
        else:
            dist = (jnp.sum(diff**p, axis=1) ** (1.0 / p))[None, :]
    return lf, dist


def _row_ok(boff_ref, nvalid_ref, bn: int, n_rows: int):
    """(1, BN) live-row mask: inside the unpadded block AND below n_valid."""
    ip = pl.program_id(1)
    row = ip * bn + jax.lax.broadcasted_iota(jnp.int32, (1, bn), 1)
    return (row < n_rows) & ((boff_ref[0, 0] + row) < nvalid_ref[0, 0])


def _hist_kernel(cq_ref, cp_ref, qpt_ref, ppt_ref, w_ref, mu_ref, bq_ref,
                 rmin_ref, boff_ref, nvalid_ref, of_ref, og_ref,
                 accf_ref, accg_ref, *, c: int, n_levels: int, p: float,
                 n_rows: int, n_tiles: int, n_bins: int):
    ip = pl.program_id(1)

    @pl.when(ip == 0)
    def _init():
        accf_ref[...] = jnp.zeros_like(accf_ref)
        accg_ref[...] = jnp.zeros_like(accg_ref)

    lf, dist = _lf_and_dist(cq_ref, cp_ref, qpt_ref, ppt_ref, w_ref,
                            mu_ref, bq_ref, c=c, n_levels=n_levels, p=p)
    bn = lf.shape[1]
    ok = _row_ok(boff_ref, nvalid_ref, bn, n_rows)
    excl = jnp.int32(n_levels + 2)
    base = jnp.log(c * rmin_ref[0, 0]) / math.log(c)
    jg = jnp.ceil(
        jnp.maximum(jnp.log(jnp.maximum(dist, 1e-30)) / math.log(c) - base,
                    0.0)
    ).astype(jnp.int32)
    lf_x = jnp.where(ok, lf, excl)
    good = jnp.where(ok, jnp.maximum(lf, jg), excl)
    bins = jax.lax.broadcasted_iota(jnp.int32, (n_bins, bn), 0)
    accf_ref[...] += jnp.sum((bins == lf_x).astype(jnp.int32), axis=1)[None, :]
    accg_ref[...] += jnp.sum((bins == good).astype(jnp.int32), axis=1)[None, :]

    @pl.when(ip == n_tiles - 1)
    def _epilogue():
        of_ref[...] = accf_ref[...]
        og_ref[...] = accg_ref[...]


def _scores_kernel(cq_ref, cp_ref, qpt_ref, ppt_ref, w_ref, mu_ref, bq_ref,
                   stop_ref, boff_ref, nvalid_ref, o_ref, *, c: int,
                   n_levels: int, p: float, n_rows: int):
    lf, dist = _lf_and_dist(cq_ref, cp_ref, qpt_ref, ppt_ref, w_ref,
                            mu_ref, bq_ref, c=c, n_levels=n_levels, p=p)
    ok = _row_ok(boff_ref, nvalid_ref, lf.shape[1], n_rows)
    keep = ok & (lf <= stop_ref[0, 0])
    o_ref[...] = jnp.where(keep, dist, jnp.inf)


def _specs(beta: int, d: int, bn: int):
    """Common in_specs prefix: codes/vectors/weight tiles + SMEM scalars."""
    smem_q = pl.BlockSpec(
        (1, 1), lambda iq, ip: (iq, 0), memory_space=pltpu.SMEM
    )
    smem_g = pl.BlockSpec(
        (1, 1), lambda iq, ip: (0, 0), memory_space=pltpu.SMEM
    )
    tiles = [
        pl.BlockSpec((1, beta), lambda iq, ip: (iq, 0)),
        pl.BlockSpec((bn, beta), lambda iq, ip: (ip, 0)),
        pl.BlockSpec((1, d), lambda iq, ip: (iq, 0)),
        pl.BlockSpec((bn, d), lambda iq, ip: (ip, 0)),
        pl.BlockSpec((1, d), lambda iq, ip: (iq, 0)),  # per-query weight
    ]
    return tiles, smem_q, smem_g


def _as_col(v, dtype):
    return jnp.asarray(v, dtype).reshape(-1, 1)


@functools.partial(
    jax.jit,
    static_argnames=("c", "n_levels", "p", "bn", "n_rows", "interpret"),
)
def fused_query_hist_pallas(
    codes_p,  # (B_pad, beta) int32
    points,  # (B_pad, d) f32
    codes_q,  # (Q, beta) int32
    queries,  # (Q, d) f32
    q_weight,  # (Q, d) f32
    mu,  # (Q,) int32
    beta_q,  # (Q,) int32
    r_min,  # (Q,) f32
    boff,  # () int32 global row offset of this block
    n_valid,  # () int32 streaming live-row watermark
    c: int,
    n_levels: int,
    p: float,
    n_rows: int,  # live rows in the block before padding
    bn: int = 256,
    interpret: bool = False,
):
    """Pass-1 fused block step -> (hist_f, hist_g), each (Q, nbins)."""
    b_pad, beta = codes_p.shape
    q, d = queries.shape
    bn = min(bn, b_pad)
    assert b_pad % bn == 0, "caller (ops.py) must pad rows to block multiples"
    n_tiles = b_pad // bn
    n_bins = nbins(n_levels)
    kernel = functools.partial(
        _hist_kernel, c=int(c), n_levels=int(n_levels), p=float(p),
        n_rows=int(n_rows), n_tiles=n_tiles, n_bins=n_bins,
    )
    tiles, smem_q, smem_g = _specs(beta, d, bn)
    out_spec = pl.BlockSpec((1, n_bins), lambda iq, ip: (iq, 0))
    out_shape = jax.ShapeDtypeStruct((q, n_bins), jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(q, n_tiles),
        in_specs=tiles + [smem_q, smem_q, smem_q, smem_g, smem_g],
        out_specs=(out_spec, out_spec),
        out_shape=(out_shape, out_shape),
        scratch_shapes=[
            pltpu.VMEM((1, n_bins), jnp.int32),
            pltpu.VMEM((1, n_bins), jnp.int32),
        ],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")
        ),
    )(
        codes_q.astype(jnp.int32),
        codes_p.astype(jnp.int32),
        queries.astype(jnp.float32),
        points.astype(jnp.float32),
        q_weight.astype(jnp.float32),
        _as_col(mu, jnp.int32),
        _as_col(beta_q, jnp.int32),
        _as_col(r_min, jnp.float32),
        _as_col(boff, jnp.int32),
        _as_col(n_valid, jnp.int32),
    )


@functools.partial(
    jax.jit,
    static_argnames=("c", "n_levels", "p", "bn", "n_rows", "interpret"),
)
def fused_query_scores_pallas(
    codes_p,  # (B_pad, beta) int32
    points,  # (B_pad, d) f32
    codes_q,  # (Q, beta) int32
    queries,  # (Q, d) f32
    q_weight,  # (Q, d) f32
    mu,  # (Q,) int32
    beta_q,  # (Q,) int32
    stop,  # (Q,) int32 per-query stop level
    boff,  # () int32
    n_valid,  # () int32
    c: int,
    n_levels: int,
    p: float,
    n_rows: int,
    bn: int = 256,
    interpret: bool = False,
):
    """Pass-2 fused block step -> (Q, B_pad) stop-masked distances."""
    b_pad, beta = codes_p.shape
    q, d = queries.shape
    bn = min(bn, b_pad)
    assert b_pad % bn == 0, "caller (ops.py) must pad rows to block multiples"
    kernel = functools.partial(
        _scores_kernel, c=int(c), n_levels=int(n_levels), p=float(p),
        n_rows=int(n_rows),
    )
    tiles, smem_q, smem_g = _specs(beta, d, bn)
    return pl.pallas_call(
        kernel,
        grid=(q, b_pad // bn),
        in_specs=tiles + [smem_q, smem_q, smem_q, smem_g, smem_g],
        out_specs=pl.BlockSpec((1, bn), lambda iq, ip: (iq, ip)),
        out_shape=jax.ShapeDtypeStruct((q, b_pad), jnp.float32),
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
    )(
        codes_q.astype(jnp.int32),
        codes_p.astype(jnp.int32),
        queries.astype(jnp.float32),
        points.astype(jnp.float32),
        q_weight.astype(jnp.float32),
        _as_col(mu, jnp.int32),
        _as_col(beta_q, jnp.int32),
        _as_col(stop, jnp.int32),
        _as_col(boff, jnp.int32),
        _as_col(n_valid, jnp.int32),
    )
