"""Pallas TPU kernel: weighted l_p candidate scoring.

Computes the (Q, n) distance matrix D[q, o] = (sum_i |w_i (q_i - o_i)|^p)^(1/p)
for the candidate-verification stage of the WLSH search.

Two regimes:
  * p == 2 is NOT handled here — ops.py routes it to the norms+matmul
    expansion (MXU) which is strictly better than any elementwise kernel.
  * p != 2 (the paper's fractional/l_1 case) is a VPU reduction; this kernel
    tiles it as grid (Q, n/BN, d/BD) with an f32 VMEM accumulator, fusing
    the weighting, |.|^p, and the final ^(1/p) epilogue.

Blocks are 2-D: query row (1, BD) against point tile (BN, BD).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# jax >= 0.5 renamed TPUCompilerParams -> CompilerParams
_CompilerParams = getattr(pltpu, "CompilerParams", None) or pltpu.TPUCompilerParams

__all__ = ["weighted_lp_pallas"]


def _kernel(q_ref, x_ref, w_ref, o_ref, acc_ref, *, p: float, k_steps: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    diff = jnp.abs((x_ref[...] - q_ref[...]) * w_ref[...])  # (BN, BD)
    if abs(p - 1.0) < 1e-9:
        contrib = diff
    else:
        contrib = diff**p
    acc_ref[...] += jnp.sum(contrib, axis=1)[None, :]  # (1, BN)

    @pl.when(k == k_steps - 1)
    def _epilogue():
        acc = acc_ref[...]
        if abs(p - 1.0) < 1e-9:
            o_ref[...] = acc
        else:
            o_ref[...] = acc ** (1.0 / p)


@functools.partial(
    jax.jit, static_argnames=("p", "bn", "bd", "interpret")
)
def weighted_lp_pallas(
    queries,  # (Q, d) f32
    points,  # (n, d) f32
    weight,  # (d,) f32
    p: float,
    bn: int = 256,
    bd: int = 256,
    interpret: bool = False,
):
    qn, d = queries.shape
    n = points.shape[0]
    bn = min(bn, n)
    bd = min(bd, d)
    assert n % bn == 0 and d % bd == 0, (
        "caller (ops.py) must pad to block multiples"
    )
    k_steps = d // bd
    grid = (qn, n // bn, k_steps)
    kernel = functools.partial(_kernel, p=float(p), k_steps=k_steps)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bd), lambda iq, ip, k: (iq, k)),
            pl.BlockSpec((bn, bd), lambda iq, ip, k: (ip, k)),
            pl.BlockSpec((1, bd), lambda iq, ip, k: (0, k)),
        ],
        out_specs=pl.BlockSpec((1, bn), lambda iq, ip, k: (iq, ip)),
        out_shape=jax.ShapeDtypeStruct((qn, n), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, bn), jnp.float32)],
        interpret=interpret,
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
    )(
        queries.astype(jnp.float32),
        points.astype(jnp.float32),
        weight.astype(jnp.float32)[None, :],
    )
