"""Streaming delta-index orchestration: inserts, deletes, compaction.

``DeltaIndex`` is the mutable side of the serving stack.  The compiled
group states stay immutable between compactions; everything that moves
lives here, per table group:

  insert     ``insert(vector, weight_id)`` routes the row to
             ``plan.group_of[weight_id]`` (inserts are tenant-scoped: the
             row is indexed in — and visible to — its weight's table
             group), assigns the next global id past the corpus epoch,
             and appends to the group's open memtable.  Fresh rows are
             served immediately by exact scan, so recall on them is
             perfect before any index work happens.
  seal       at ``ServiceConfig.delta_seal_rows`` rows the memtable is
             re-hashed with the group's original family seeds
             (``builder.seal_segment``) into a ``SealedSegment``.
  compact    sealed segments splice into the group state's reserved row
             capacity (``builder.append_to_state``) under a short
             ``StateCache`` lease, then ``StateCache.replace`` installs
             the new state at a bumped version — invalidating exactly one
             group's cached bytes, never another group's state and never
             a compiled step.  The result is bit-exact with a fresh
             ``build_group_state`` over the union corpus.
  delete     ``delete(id)`` tombstones a global id (base or inserted);
             tombstoned ids are filtered out of every merged top-k.
             Tombstones survive ordinary compaction.
  purge      ``compact(purge=True)`` is the rebuild-style sweep: every
             group's state is rebuilt over its *surviving* corpus
             (tombstoned base rows and inserts dropped), reclaiming their
             ``n_valid`` row capacity, and the tombstone set is cleared —
             merges stop paying the filter.  The purged state is
             bit-exact with a fresh ``build_group_state`` over the
             survivors, and no compiled step is touched (capacity shapes
             never change; ``n_valid`` only shrinks).

Every query launched through ``Batcher.run_batch`` calls ``augment``:
state-row indices translate to global ids, the group's pending rows are
scanned exactly with the engine's own distance form, and
``batching.merge_topk`` folds the two candidate lists under the no-drop /
no-dup / tombstone invariants.  A group with nothing pending and no
tombstones passes through bit-exactly — the post-compaction parity
guarantee.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..index.builder import append_to_state, seal_segment
from ..index.streaming import DeltaSegment, SealedSegment, scan_topk
from .batching import merge_topk

__all__ = ["DeltaIndex", "DeltaStats"]


@dataclasses.dataclass
class DeltaStats:
    """Running streaming counters (whole-service, monotone)."""

    n_inserts: int = 0  # rows ever inserted
    n_deletes: int = 0  # tombstones ever placed
    n_seals: int = 0  # memtable -> sealed-segment transitions
    n_compactions: int = 0  # compaction transactions committed
    n_rows_compacted: int = 0  # rows absorbed into main states
    n_delta_scans: int = 0  # launches that also scanned pending rows
    n_purges: int = 0  # purge sweeps (tombstone-dropping union rebuilds)
    n_rows_purged: int = 0  # tombstoned rows dropped from main states


class _GroupDelta:
    """One group's mutable side: open memtable, sealed queue, append log."""

    def __init__(self, d: int):
        self.open = DeltaSegment(d)
        self.sealed: list[SealedSegment] = []
        # append log of compacted rows (host copies): row r >= plan.n of
        # the group state maps to compacted_ids[r - plan.n]; vectors and
        # sealed codes are retained so a discard-mode cold rebuild can
        # reproduce the union state bit-exactly
        self.compacted_ids = np.empty(0, np.int64)
        self.compacted_vecs: list[np.ndarray] = []
        self.compacted_codes: list[np.ndarray] = []

    @property
    def n_pending(self) -> int:
        """Rows inserted but not yet compacted (open + sealed)."""
        return len(self.open) + sum(len(s) for s in self.sealed)

    def pending_rows(self) -> tuple[np.ndarray, np.ndarray]:
        """(ids, vectors) of every uncompacted row, insertion order."""
        ids = [s.ids for s in self.sealed] + [self.open.ids]
        vecs = [s.vectors for s in self.sealed] + [self.open.vectors]
        return np.concatenate(ids), np.concatenate(vecs)


class DeltaIndex:
    """Per-group delta segments + tombstones over a ``Batcher``.

    Created lazily by ``Batcher.delta_index()`` on the first write; until
    then the serving fast path carries zero streaming overhead.  Single-
    threaded like the frontends that drive it: compaction runs inline
    (``compact``), opportunistically from the async frontend's idle poll,
    or automatically once a group holds
    ``ServiceConfig.auto_compact_segments`` sealed segments.
    """

    def __init__(self, batcher):
        self.batcher = batcher
        plan = batcher.plan
        self.base_n = int(plan.n)
        # global ids continue from the plan's corpus epoch, so a service
        # resumed from a compacted plan export never reuses an id
        self._next_id = int(plan.corpus_epoch or plan.n)
        self._groups = {
            gi: _GroupDelta(plan.d) for gi in range(plan.n_groups)
        }
        self.tombstones: set[int] = set()
        # surviving base-corpus rows after purges: global ids (== row
        # indices into batcher.points), insertion order.  None = every
        # base row is live (the pre-purge fast path).
        self._base_ids: np.ndarray | None = None
        self.stats = DeltaStats()

    @property
    def n_base_live(self) -> int:
        """Live (unpurged) base-corpus rows at the front of every state."""
        return self.base_n if self._base_ids is None else len(self._base_ids)

    def base_rows(self) -> np.ndarray | None:
        """Surviving base row indices for rebuilds (None = all rows).

        Shared by every group: tombstones are global, so a purged base
        row is gone from each group's state.  ``Batcher._build_state``
        threads this into ``build_group_state`` so discard-mode cold
        rebuilds after a purge cannot resurrect dropped rows.
        """
        return self._base_ids

    # -------------------------------------------------------------- writes

    def insert(self, vector, weight_id) -> int:
        """Insert one vector under ``weight_id``; returns its global id.

        The row lands in ``plan.group_of[weight_id]``'s open memtable and
        is queryable immediately (exact scan).  Reaching
        ``delta_seal_rows`` buffered rows seals the memtable; with
        ``auto_compact_segments`` set, enough sealed segments trigger an
        inline compaction.
        """
        gi = int(self.batcher.route(weight_id)[0])
        gd = self._groups[gi]
        pid = self._next_id
        gd.open.append(pid, np.asarray(vector, np.float32))
        self._next_id += 1
        self.stats.n_inserts += 1
        if len(gd.open) >= self.batcher.cfg.delta_seal_rows:
            self.seal(gi)
        return pid

    def delete(self, point_id: int) -> None:
        """Tombstone a global id (base corpus row or streamed insert).

        Tombstoned ids are filtered from every subsequent top-k merge;
        result slots they would have held backfill from the remaining
        candidates.  Raises on ids outside the corpus ever served.
        """
        pid = int(point_id)
        if not 0 <= pid < self._next_id:
            raise ValueError(
                f"delete of unknown id {pid} (corpus ids span "
                f"[0, {self._next_id}))"
            )
        self.tombstones.add(pid)
        self.stats.n_deletes += 1

    def seal(self, gi: int) -> None:
        """Seal group ``gi``'s open memtable into a hashed segment.

        Re-hashes the rows with the group's original family seeds at the
        padded table width; no compiled step is touched.  A no-op on an
        empty memtable.
        """
        gi = int(gi)
        gd = self._groups[gi]
        if not len(gd.open):
            return
        ids, vecs = gd.open.drain()
        cfg = self.batcher.group_config(gi)
        g = self.batcher.plan.groups[gi]
        if g.codes is not None:
            codes = seal_segment(cfg, g, vecs)
        else:  # device-encode plans hash through the (leased) state proj
            with self.batcher.state_cache.lease(gi) as state:
                codes = seal_segment(cfg, g, vecs, state=state)
        gd.sealed.append(SealedSegment(ids=ids, vectors=vecs, codes=codes))
        self.stats.n_seals += 1
        auto = self.batcher.cfg.auto_compact_segments
        if auto is not None and len(gd.sealed) >= auto:
            self._compact_group(gi)

    # ---------------------------------------------------------- compaction

    def compact(self, group: int | None = None, purge: bool = False) -> int:
        """Compact sealed segments into the main state(s); returns rows.

        ``group=None`` sweeps every group.  Open (unsealed) memtables are
        sealed first, so an explicit ``compact()`` is a full flush.

        ``purge=True`` upgrades the sweep to a tombstone purge: every
        group's state is rebuilt over its surviving corpus (pending rows
        absorbed, tombstoned rows dropped, ``n_valid`` capacity
        reclaimed) and the tombstone set is cleared.  Tombstones are
        global, so a purge is necessarily whole-service: combining it
        with a single ``group`` raises.
        """
        if purge:
            if group is not None:
                raise ValueError(
                    "purge rebuilds every group (tombstones are global); "
                    "drop the group argument"
                )
            return self._purge()
        gis = (
            [int(group)] if group is not None
            else list(range(self.batcher.plan.n_groups))
        )
        total = 0
        for gi in gis:
            self.seal(gi)
            total += self._compact_group(gi)
        return total

    def compact_sealed(self) -> int:
        """Compact only the already-sealed backlog (the background path).

        Open memtables are left to fill toward their seal threshold, and
        groups whose reserved capacity cannot take their backlog are
        skipped (they keep serving by exact scan) instead of raising —
        this is the safe form the async frontend's idle poll calls.
        """
        return sum(
            self._compact_group(gi, strict=False)
            for gi in range(self.batcher.plan.n_groups)
        )

    def _compact_group(self, gi: int, strict: bool = True) -> int:
        """One compaction transaction: splice sealed rows, bump version."""
        gd = self._groups[gi]
        if not gd.sealed:
            return 0
        cfg = self.batcher.group_config(gi)
        ids = np.concatenate([s.ids for s in gd.sealed])
        vecs = np.concatenate([s.vectors for s in gd.sealed])
        codes = np.concatenate([s.codes for s in gd.sealed])
        rows_now = self.n_base_live + len(gd.compacted_ids)
        if rows_now + len(ids) > cfg.n:
            if not strict:
                return 0
            raise ValueError(
                f"group {gi} compaction needs {rows_now + len(ids)} rows "
                f"but the state capacity is {cfg.n}; raise "
                f"ServiceConfig.delta_reserve_rows"
            )
        cache = self.batcher.state_cache
        with cache.lease(gi) as state:
            assert int(state.n_valid) == rows_now, "append log out of sync"
            new_state = append_to_state(
                state, codes, vecs, mesh=self.batcher.mesh
            )
        cache.replace(gi, new_state)  # versioned: only this group's bytes
        gd.compacted_ids = np.concatenate([gd.compacted_ids, ids])
        gd.compacted_vecs.append(vecs)
        gd.compacted_codes.append(codes)
        gd.sealed.clear()
        self.stats.n_compactions += 1
        self.stats.n_rows_compacted += len(ids)
        self.batcher.plan = self.batcher.plan.bumped(len(ids))
        return len(ids)

    def _purge(self) -> int:
        """Tombstone-purging rebuild of every group; returns rows absorbed.

        Full flush first (open memtables seal, like ``compact``), then
        each group's state is rebuilt from its surviving corpus: live
        base rows (shared across groups — tombstones are global) plus the
        group's compacted and sealed rows minus tombstoned ones, with
        their already-sealed codes reused.  ``StateCache.replace``
        installs each rebuilt state at a bumped version, ``n_valid``
        shrinks by the dropped rows (capacity reclaimed for future
        compactions), compiled steps are untouched (capacity shapes never
        change), and the result is bit-exact with a fresh
        ``build_group_state`` over the survivors.  Ends by clearing the
        tombstone set — merges stop paying the filter — and bumping the
        plan version, with ``corpus_epoch`` advanced to cover every id
        ever minted (a tombstoned pending row is dropped rather than
        absorbed, but its id is spent, so a resumed service must not
        re-mint it).

        The sweep is transactional *and* budget-respecting: capacity and
        pinning are validated for every group up front (the same
        explicit ``delta_reserve_rows`` error ordinary compaction
        raises), and the commit itself is pure host-side bookkeeping —
        log rewrites plus versioned ``StateCache.invalidate`` of the
        rebuilt groups, no device work at all.  Each invalidated group
        cold-builds lazily on its next acquire through the normal
        ``Batcher._build_state`` path (which threads the surviving base
        rows and the rewritten logs), so rebuilds page one at a time
        under the configured device budget instead of materializing
        every state at once.  Only groups that actually drop a row
        rebuild: with no base row dropped this sweep, a group whose
        rows all survive takes the ordinary (cheaper) append-compaction
        for its sealed backlog — or is left entirely untouched, cached
        state and all; with no tombstones at all the purge degrades to
        an ordinary full ``compact``.
        """
        if not self.tombstones:
            return self.compact()
        plan = self.batcher.plan
        cache = self.batcher.state_cache
        for gi in range(plan.n_groups):
            self.seal(gi)
        tomb = np.fromiter(
            self.tombstones, np.int64, count=len(self.tombstones)
        )
        base_ids = (
            self._base_ids if self._base_ids is not None
            else np.arange(self.base_n, dtype=np.int64)
        )
        base_keep = base_ids[~np.isin(base_ids, tomb)]
        base_changed = len(base_keep) < len(base_ids)

        # phase 1: gather survivors and validate every group, before any
        # state is touched — a raise here leaves the service unchanged
        survivors = {}
        rebuild = set()
        for gi in range(plan.n_groups):
            gd = self._groups[gi]
            n_comp = len(gd.compacted_ids)
            ids = np.concatenate(
                [gd.compacted_ids] + [s.ids for s in gd.sealed]
            )
            keep = ~np.isin(ids, tomb)
            surv_vecs = surv_codes = None
            if len(ids):
                vecs = np.concatenate(
                    gd.compacted_vecs + [s.vectors for s in gd.sealed]
                )
                codes = np.concatenate(
                    gd.compacted_codes + [s.codes for s in gd.sealed]
                )
                surv_vecs, surv_codes = vecs[keep], codes[keep]
            cfg = self.batcher.group_config(gi)
            if len(base_keep) + int(keep.sum()) > cfg.n:
                raise ValueError(
                    f"group {gi} purge needs "
                    f"{len(base_keep) + int(keep.sum())} rows but the "
                    f"state capacity is {cfg.n}; raise "
                    f"ServiceConfig.delta_reserve_rows"
                )
            if base_changed or not keep.all():
                rebuild.add(gi)
                if cache.pin_count(gi):
                    raise ValueError(
                        f"cannot purge while group {gi} is pinned "
                        f"(launch in flight)"
                    )
            survivors[gi] = (ids[keep], surv_vecs, surv_codes,
                             int(keep[n_comp:].sum()), int((~keep).sum()))

        # phase 2: commit — host-side log rewrites plus versioned
        # invalidations for rebuilt groups (their next acquire cold-builds
        # from the committed logs, one at a time under the paging budget);
        # untouched groups absorb their sealed backlog through the
        # ordinary append path (no-op with nothing sealed)
        absorbed = n_purged = 0
        for gi in range(plan.n_groups):
            if gi not in rebuild:
                absorbed += self._compact_group(gi)
                continue
            gd = self._groups[gi]
            surv_ids, surv_vecs, surv_codes, n_abs, n_drop = survivors[gi]
            cache.invalidate(gi)
            absorbed += n_abs
            n_purged += (len(base_ids) - len(base_keep)) + n_drop
            gd.compacted_ids = surv_ids
            gd.compacted_vecs = [surv_vecs] if len(surv_ids) else []
            gd.compacted_codes = [surv_codes] if len(surv_ids) else []
            gd.sealed.clear()
            self.stats.n_rows_compacted += n_abs
        if base_changed or self._base_ids is not None:
            self._base_ids = base_keep
        self.tombstones.clear()
        self.stats.n_compactions += 1
        self.stats.n_purges += 1
        self.stats.n_rows_purged += n_purged
        epoch = self.batcher.plan.corpus_epoch or self.base_n
        self.batcher.plan = self.batcher.plan.bumped(self._next_id - epoch)
        return absorbed

    def compacted_rows(
        self, gi: int
    ) -> tuple[np.ndarray | None, np.ndarray | None]:
        """(vectors, sealed codes) of rows already absorbed by group ``gi``.

        The cold-rebuild feed: ``Batcher._build_state`` appends these to
        the base corpus so a discard-mode eviction can never lose
        streamed rows.  ``(None, None)`` when nothing was compacted.
        """
        gd = self._groups[int(gi)]
        if not len(gd.compacted_ids):
            return None, None
        return (
            np.concatenate(gd.compacted_vecs),
            np.concatenate(gd.compacted_codes),
        )

    # --------------------------------------------------------------- reads

    def pending_rows(self, gi: int) -> int:
        """Uncompacted (open + sealed) rows buffered for group ``gi``."""
        return self._groups[int(gi)].n_pending

    def visible_rows(self, gi: int) -> tuple[np.ndarray, np.ndarray]:
        """(global ids, vectors) of every row group ``gi`` can return.

        The exact-oracle corpus for one group: live base rows, the
        group's compacted append log, and its uncompacted (open +
        sealed) rows, with tombstoned ids filtered out — precisely the
        candidate set a launch through ``augment`` can surface.  Used
        by the shadow recall estimator; read-only.
        """
        gd = self._groups[int(gi)]
        base_ids = (np.arange(self.base_n, dtype=np.int64)
                    if self._base_ids is None else self._base_ids)
        ids = [base_ids]
        vecs = [np.asarray(self.batcher.points)[base_ids]]
        if len(gd.compacted_ids):
            ids.append(gd.compacted_ids)
            vecs.append(np.concatenate(gd.compacted_vecs))
        if gd.n_pending:
            pids, pvecs = gd.pending_rows()
            ids.append(pids)
            vecs.append(pvecs)
        all_ids = np.concatenate(ids)
        all_vecs = np.concatenate(vecs)
        if self.tombstones:
            live = ~np.isin(all_ids, np.fromiter(
                self.tombstones, np.int64, count=len(self.tombstones)))
            all_ids, all_vecs = all_ids[live], all_vecs[live]
        return all_ids, all_vecs

    def augment(self, gi, queries, weight_ids, ids, dists):
        """Fold the group's delta state into one launch's indexed hits.

        Translates state rows to global ids (appended rows through the
        group's append log; post-purge base rows through the surviving-id
        map), scans the group's pending rows exactly under each query's
        own weight, and merges under the tombstone filter.  With nothing
        pending and no tombstones the indexed results pass through
        bit-exactly.
        """
        gi = int(gi)
        gd = self._groups[gi]
        nb = self.n_base_live
        translated = ids
        if len(gd.compacted_ids) or self._base_ids is not None:
            orig = np.asarray(ids, np.int64)
            t = orig.copy()
            hi = orig >= nb
            if hi.any():
                t[hi] = gd.compacted_ids[orig[hi] - nb]
            if self._base_ids is not None:
                lo = (orig >= 0) & (orig < nb)
                if lo.any():
                    t[lo] = self._base_ids[orig[lo]]
            translated = t
        if not gd.n_pending and not self.tombstones:
            if translated is ids:
                return ids, dists
            return translated.astype(np.int32), dists
        k = self.batcher.cfg.k
        plan = self.batcher.plan
        if gd.n_pending:
            d_ids, d_vecs = gd.pending_rows()
            q_w = plan.weights[
                np.asarray(weight_ids, np.int64)
            ].astype(np.float32)
            extra_ids, extra_d = scan_topk(
                queries, q_w, d_ids, d_vecs, plan.p, k
            )
            self.stats.n_delta_scans += 1
        else:
            nq = len(np.atleast_2d(queries))
            extra_ids = np.full((nq, 0), -1, np.int64)
            extra_d = np.full((nq, 0), np.inf, np.float32)
        return merge_topk(
            translated, dists, extra_ids, extra_d, k, drop=self.tombstones
        )

    def summary(self) -> dict:
        """Flat streaming report: counters, backlog, plan lineage."""
        plan = self.batcher.plan
        return dict(
            n_inserts=self.stats.n_inserts,
            n_deletes=self.stats.n_deletes,
            n_seals=self.stats.n_seals,
            n_compactions=self.stats.n_compactions,
            n_rows_compacted=self.stats.n_rows_compacted,
            n_delta_scans=self.stats.n_delta_scans,
            n_purges=self.stats.n_purges,
            n_rows_purged=self.stats.n_rows_purged,
            n_base_live=self.n_base_live,
            n_pending=sum(g.n_pending for g in self._groups.values()),
            n_sealed_segments=sum(
                len(g.sealed) for g in self._groups.values()
            ),
            n_tombstones=len(self.tombstones),
            plan_version=plan.version,
            corpus_epoch=plan.corpus_epoch,
        )
