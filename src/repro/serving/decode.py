"""Serving loop: batched autoregressive generation over the decode step.

The lowered artifact for the decode_* dry-run shapes is ``make_serve_step``
(one token against a full cache); generation here drives it host-side with
temperature / top-k sampling.  Prompt ingestion reuses the decode step
token-by-token (exact, cache-filling); production prefill lowers the
full-sequence forward (``Model.prefill``) instead.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["SamplerConfig", "make_serve_step", "generate"]


@dataclasses.dataclass(frozen=True)
class SamplerConfig:
    """Host-side sampling knobs for the generation loop."""

    temperature: float = 1.0
    top_k: int = 0  # 0 = full softmax
    seed: int = 0


def make_serve_step(model):
    """jit'd (params, cache, tokens (B,), position) -> (logits, cache)."""
    def step(params, cache, tokens, position):
        return model.decode_step(params, cache, tokens, position)

    return jax.jit(step, donate_argnums=(1,))


def _sample(logits, key, cfg: SamplerConfig):
    logits = logits.astype(jnp.float32)
    if cfg.temperature <= 0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    logits = logits / cfg.temperature
    if cfg.top_k:
        vals, idx = jax.lax.top_k(logits, cfg.top_k)
        draw = jax.random.categorical(key, vals, axis=-1)
        return jnp.take_along_axis(idx, draw[:, None], axis=1)[:, 0].astype(
            jnp.int32
        )
    return jax.random.categorical(key, logits, axis=-1).astype(jnp.int32)


def generate(
    model,
    params,
    prompts: np.ndarray,  # (B, P) int32 prompt tokens
    max_new_tokens: int,
    cache_len: int,
    sampler: SamplerConfig = SamplerConfig(),
):
    """Returns (B, max_new_tokens) sampled tokens.  CPU-friendly driver."""
    B, P = prompts.shape
    serve_step = make_serve_step(model)
    cache = model.init_cache(B, cache_len)
    key = jax.random.PRNGKey(sampler.seed)

    logits = None
    for pos in range(P):
        logits, cache = serve_step(
            params, cache, jnp.asarray(prompts[:, pos]), jnp.int32(pos)
        )
    out = np.empty((B, max_new_tokens), np.int32)
    tok = _sample(logits, key, sampler)
    for i in range(max_new_tokens):
        out[:, i] = np.asarray(tok)
        key, sub = jax.random.split(key)
        logits, cache = serve_step(params, cache, tok, jnp.int32(P + i))
        tok = _sample(logits, sub, sampler)
    return out
