"""Multi-tenant QoS: admission, weighted fairness, SLO-aware degradation.

The real-time driver (``serving.scheduler``) schedules *paging* — which
states to bring on device ahead of their launches — but treats every
request identically: one tenant's burst can starve another's deadlines,
and overload only manifests as ``Overloaded`` rejections or deadline
misses.  This module turns the stack into a traffic-shaping layer:

  ``QosClass``           one tenant class: fair-share ``weight``,
                         token-bucket admission (``rate``/``burst``),
                         per-class SLO deadline budget (``slo_ms``) and
                         whether the tenant may be *degraded* under
                         overload.
  ``TokenBucket``        deterministic admission control on the
                         service's injectable clock — ``submit`` raises
                         a typed ``RateLimited`` before enqueueing, so
                         a rejected caller has lost nothing.
  ``DeficitRoundRobin``  weighted-fair dequeue across per-tenant launch
                         queues: every round credits each backlogged
                         tenant ``quantum * weight``, and a launch
                         spends its modeled cost from that deficit.
                         Low-weight tenants accumulate credit across
                         rounds, so they drain slower but are never
                         starved.
  ``DegradeStep``        one rung of the pre-planned (c, k) relaxation
                         ladder — the paper's accuracy-for-efficiency
                         trade (bound relaxation, Eqs. 14-15) applied
                         at serve time.  Each rung's step is compiled
                         at warmup (``c``/``k`` are part of
                         ``IndexConfig.shape_signature()``), so
                         stepping a tenant down the ladder never
                         recompiles.
  ``QosScheduler``       ties it together: admits, orders launches
                         fairly under a per-tick capacity, watches for
                         sustained overload and steps *degradable*
                         tenants down the ladder (restoring strict
                         parameters once pressure clears), and keeps
                         per-tenant SLO statistics.

Everything here is pure host-side bookkeeping on the injectable clock —
no wall-clock reads, no device work — so every fairness and admission
property is deterministic and replayable (``tests/test_qos.py``).
"""

from __future__ import annotations

import dataclasses
import math

from ..obs import MetricsRegistry

__all__ = [
    "DEFAULT_TENANT",
    "DegradeStep",
    "DeficitRoundRobin",
    "QosClass",
    "QosScheduler",
    "RateLimited",
    "TenantStats",
    "TokenBucket",
]

DEFAULT_TENANT = "default"  # tenant label used when the caller passes none


class RateLimited(RuntimeError):
    """Admission control rejected a submit: the token bucket is empty.

    Raised by ``AsyncRetrievalService.submit`` *before* the request is
    enqueued (like ``Overloaded``, the caller holds no future and has
    lost nothing).  Carries the tenant and its configured rate/burst so
    callers can back off per class:

    * ``tenant`` — the rejected tenant's class name
    * ``rate`` — its admitted queries/second
    * ``burst`` — its bucket capacity in queries
    """

    def __init__(self, tenant: str, rate: float, burst: float):
        super().__init__(
            f"tenant {tenant!r} exceeded its admission rate "
            f"({rate}/s, burst {burst}); retry after backoff"
        )
        self.tenant = str(tenant)
        self.rate = float(rate)
        self.burst = float(burst)


@dataclasses.dataclass(frozen=True)
class QosClass:
    """One tenant class: priority weight, admission budget, SLO.

    * ``weight`` — deficit-round-robin fair share (relative; a weight-4
      tenant drains four launches for every one of a weight-1 tenant
      under contention, but the weight-1 tenant still drains).
    * ``rate``/``burst`` — token-bucket admission: at most ``rate``
      admitted queries/second sustained, ``burst`` in a spike.  ``rate
      = None`` disables admission control for the class.
    * ``slo_ms`` — per-class deadline budget: a submit without an
      explicit deadline gets ``now + slo_ms / 1e3``.  ``None`` falls
      back to the service's ``max_delay_ms``.
    * ``degradable`` — whether sustained overload may step this
      tenant's effective (c, k) down the scheduler's relaxation ladder.
      Strict-recall tenants keep ``False`` and are never degraded.
    """

    name: str
    weight: float = 1.0
    rate: float | None = None
    burst: float = 1.0
    slo_ms: float | None = None
    degradable: bool = False

    def __post_init__(self):
        if not self.name:
            raise ValueError("tenant class name must be non-empty")
        if not (self.weight > 0):
            raise ValueError(f"weight must be > 0, got {self.weight}")
        if self.rate is not None and not (self.rate > 0):
            raise ValueError(f"rate must be > 0 or None, got {self.rate}")
        if not (self.burst >= 1):
            raise ValueError(f"burst must be >= 1, got {self.burst}")
        if self.slo_ms is not None and not (self.slo_ms >= 0):
            raise ValueError(
                f"slo_ms must be >= 0 or None, got {self.slo_ms}"
            )


class TokenBucket:
    """Deterministic token bucket on an injectable clock.

    Refills continuously at ``rate`` tokens/second up to ``burst``; one
    admitted request spends one token.  All arithmetic runs on the
    caller-supplied ``now`` (the service clock), so admission decisions
    are exact and replayable on a ``ManualClock`` — conservation (number
    admitted over any window never exceeds ``burst + rate * window``) is
    property-tested, not hoped for.
    """

    def __init__(self, rate: float, burst: float = 1.0):
        if not (rate > 0):
            raise ValueError(f"rate must be > 0, got {rate}")
        if not (burst >= 1):
            raise ValueError(f"burst must be >= 1, got {burst}")
        self.rate = float(rate)
        self.burst = float(burst)
        self._tokens = float(burst)  # a fresh bucket starts full
        self._last: float | None = None

    def _refill(self, now: float) -> None:
        if self._last is None:
            self._last = now
        elif now > self._last:
            self._tokens = min(
                self.burst, self._tokens + (now - self._last) * self.rate
            )
            self._last = now

    def tokens_at(self, now: float) -> float:
        """Tokens available at clock time ``now`` (after refill)."""
        self._refill(now)
        return self._tokens

    def try_take(self, now: float, n: float = 1.0) -> bool:
        """Spend ``n`` tokens if available at ``now``; False = rejected."""
        self._refill(now)
        if self._tokens >= n:
            self._tokens -= n
            return True
        return False


@dataclasses.dataclass(frozen=True)
class DegradeStep:
    """One rung of the (c, k) relaxation ladder.

    ``c`` is the relaxed approximation ratio (integer ``>=`` the strict
    plan's ``c`` — virtual rehashing needs an integer base, and a larger
    ``c`` stops the level loop earlier at a quantified recall cost);
    ``k`` the relaxed result count (``<=`` the strict ``k``; missing
    tail slots are padded ``-1``/``inf`` so answer shapes never change);
    ``cost`` the rung's modeled relative launch cost (strict = 1.0) —
    what the fair queue charges a degraded launch, so degradation frees
    capacity for the backlog.  ``recall_bound`` is the *planned*
    recall-vs-strict floor for the rung (what serve_bench sweep 8
    validates the measured recall against).
    """

    c: int
    k: int
    cost: float = 1.0
    recall_bound: float = 0.0

    def __post_init__(self):
        if self.c < 2 or self.c != int(self.c):
            raise ValueError(
                f"degrade rung needs integer c >= 2, got {self.c}"
            )
        if self.k < 1:
            raise ValueError(f"degrade rung needs k >= 1, got {self.k}")
        if not (self.cost > 0):
            raise ValueError(f"rung cost must be > 0, got {self.cost}")
        if not (0.0 <= self.recall_bound <= 1.0):
            raise ValueError(
                f"recall_bound must be in [0, 1], got {self.recall_bound}"
            )


class DeficitRoundRobin:
    """Weighted-fair launch ordering across per-tenant queues.

    Classic deficit round robin: each *round* credits every backlogged
    tenant ``quantum * weight``; a tenant then launches while its
    deficit covers the next launch's cost.  Deficits persist across
    calls while a tenant stays backlogged and reset when its queue
    drains (the textbook rule that bounds per-round unfairness), so:

    * **no starvation** — a backlogged tenant's deficit grows every
      round and eventually covers any bounded launch cost;
    * **work conservation** — rounds continue while capacity and
      backlog remain, so capacity is never idle with work pending;
    * **weighted shares** — over a contended window tenants drain in
      proportion to their weights.
    """

    def __init__(self, quantum: float = 1.0):
        if not (quantum > 0):
            raise ValueError(f"quantum must be > 0, got {quantum}")
        self.quantum = float(quantum)
        self._deficit: dict[str, float] = {}

    def deficit_of(self, tenant: str) -> float:
        """Current carried deficit of ``tenant`` (0.0 when drained)."""
        return self._deficit.get(tenant, 0.0)

    def select(
        self,
        queues: dict[str, list],
        weight_of,
        cost_of,
        budget: float = math.inf,
    ) -> list:
        """Fair-order launches from per-tenant ``queues`` under ``budget``.

        ``queues`` maps tenant -> list of opaque launch items (urgency
        order, consumed front-first); ``weight_of(tenant)`` and
        ``cost_of(tenant)`` supply the fair-share weight and the
        per-launch cost.  Returns the selected items in service order;
        items not selected (budget exhausted) stay in ``queues`` —
        the caller sees exactly what was deferred.
        """
        order = sorted(queues, key=lambda t: (-weight_of(t), t))
        selected: list = []
        active = [t for t in order if queues[t]]
        while active:
            progress = False
            for t in list(active):
                if not queues[t]:
                    active.remove(t)
                    self._deficit[t] = 0.0
                    continue
                self._deficit[t] = (
                    self._deficit.get(t, 0.0)
                    + self.quantum * weight_of(t)
                )
                cost = cost_of(t)
                while queues[t] and self._deficit[t] >= cost and (
                    budget >= cost
                ):
                    selected.append(queues[t].pop(0))
                    self._deficit[t] -= cost
                    budget -= cost
                    progress = True
                if not queues[t]:
                    active.remove(t)
                    self._deficit[t] = 0.0
            if not progress:
                if all(budget < cost_of(t) for t in active):
                    break  # capacity exhausted: the rest is deferred
        return selected


class TenantStats:
    """Per-tenant running counters (one ``QosScheduler`` lifetime).

    A read-only view over the ``wlsh_tenant_*`` series of the
    scheduler's registry.  The registry is read through a callable
    because ``bind_metrics`` re-homes a standalone scheduler's counters
    onto the serving stack's registry — views handed out before the
    bind keep reading the live location.
    """

    # attribute -> registry counter (labeled {tenant=<name>})
    _COUNTERS = {
        "n_admitted": "wlsh_tenant_admitted_total",
        "n_rate_limited": "wlsh_tenant_rate_limited_total",
        "n_resolved": "wlsh_tenant_resolved_total",
        "n_slo_misses": "wlsh_tenant_slo_misses_total",
        "n_degraded": "wlsh_tenant_degraded_total",
        "wait_sum": "wlsh_tenant_wait_seconds_total",
    }

    def __init__(self, metrics_fn, tenant: str):
        """Bind the view: ``metrics_fn()`` returns the live registry."""
        self._metrics_fn = metrics_fn
        self._tenant = str(tenant)

    def __getattr__(self, name: str):
        """Read the registry counter backing attribute ``name``."""
        metric = type(self)._COUNTERS.get(name)
        if metric is None:
            raise AttributeError(name)
        v = self._metrics_fn().counter(metric).value(tenant=self._tenant)
        return float(v) if name == "wait_sum" else int(v)

    @property
    def slo_miss_rate(self) -> float:
        """Missed-SLO fraction of resolved queries (nan with none)."""
        if not self.n_resolved:
            return float("nan")
        return self.n_slo_misses / self.n_resolved

    @property
    def mean_wait_s(self) -> float:
        """Mean queued seconds per resolved query (nan with none)."""
        if not self.n_resolved:
            return float("nan")
        return self.wait_sum / self.n_resolved

    def summary(self) -> dict:
        """Flat dict of every counter plus the derived rates."""
        return dict(
            n_admitted=self.n_admitted,
            n_rate_limited=self.n_rate_limited,
            n_resolved=self.n_resolved,
            n_slo_misses=self.n_slo_misses,
            n_degraded=self.n_degraded,
            slo_miss_rate=self.slo_miss_rate,
            mean_wait_s=self.mean_wait_s,
        )


class QosScheduler:
    """Per-tenant admission, weighted fairness and (c, k) degradation.

    Attach one to an ``AsyncRetrievalService`` (``qos=`` constructor
    argument): ``submit`` consults ``admit``/``deadline_for``, ``poll``
    orders expired launches through ``plan_launches`` under
    ``capacity_per_tick``, and a ``ServiceDriver`` calls
    ``observe_tick`` once per tick so sustained overload steps every
    *degradable* tenant down the ladder and sustained clearance steps
    them back up.  Without a driver the service still admits and
    dequeues fairly — rungs simply stay strict.

    Parameters
    ----------
    classes:
        The tenant classes.  Unknown tenants raise ``KeyError`` at
        submit unless a class named ``DEFAULT_TENANT`` is included.
    ladder:
        The pre-planned ``DegradeStep`` relaxation rungs, mildest
        first.  Rung 0 (implicit) is the strict service config; rung
        ``r >= 1`` serves degradable tenants at ``ladder[r - 1]``.
        Empty = degradation disabled (fairness/admission still apply).
    capacity_per_tick:
        Launch-cost units one ``poll`` may spend (strict launch = 1.0).
        Expired launches past the budget stay pending — *that* deferral
        is the overload signal the degradation controller watches.
        ``None`` = unbounded (every expired launch fires, as undriven).
    quantum:
        Deficit-round-robin per-round credit multiplier.
    degrade_after / restore_after:
        Consecutive overloaded (resp. clear) ticks before stepping the
        ladder down (resp. up) — hysteresis, so one bursty tick cannot
        flap the rung.
    """

    def __init__(
        self,
        classes,
        *,
        ladder=(),
        capacity_per_tick: float | None = None,
        quantum: float = 1.0,
        degrade_after: int = 3,
        restore_after: int = 3,
    ):
        classes = tuple(classes)
        if not classes:
            raise ValueError("QosScheduler needs at least one QosClass")
        names = [c.name for c in classes]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate tenant class names: {names}")
        if capacity_per_tick is not None and not (capacity_per_tick > 0):
            raise ValueError(
                f"capacity_per_tick must be > 0 or None, got "
                f"{capacity_per_tick}"
            )
        if degrade_after < 1 or restore_after < 1:
            raise ValueError(
                "degrade_after and restore_after must be >= 1"
            )
        self.classes: dict[str, QosClass] = {c.name: c for c in classes}
        self.ladder = tuple(ladder)
        self.capacity_per_tick = capacity_per_tick
        self.degrade_after = int(degrade_after)
        self.restore_after = int(restore_after)
        self.drr = DeficitRoundRobin(quantum=quantum)
        self._buckets = {
            c.name: TokenBucket(c.rate, c.burst)
            for c in classes if c.rate is not None
        }
        self._rung: dict[str, int] = {c.name: 0 for c in classes}
        self._over_streak = 0
        self._clear_streak = 0
        self._pressure = False  # expired work deferred on the last poll
        self.n_degrade_steps = 0
        self.n_restore_steps = 0
        # standalone registry until an AsyncRetrievalService attaches
        # this scheduler and re-homes the counters (bind_metrics)
        self.metrics = MetricsRegistry()
        self.stats: dict[str, TenantStats] = {
            c.name: TenantStats(lambda: self.metrics, c.name)
            for c in classes
        }

    def bind_metrics(self, registry: MetricsRegistry) -> None:
        """Re-home the tenant counters onto the serving stack's registry.

        Called when an ``AsyncRetrievalService`` attaches this
        scheduler: the stack's stale ``wlsh_tenant_*`` series (a
        previously attached scheduler's) are reset, anything this
        scheduler counted standalone is merged in, and future
        increments land in ``registry`` — the ``TenantStats`` views
        follow automatically through their registry callable.
        """
        if registry is self.metrics:
            return
        registry.reset("wlsh_tenant_")
        registry.merge_from(self.metrics)
        self.metrics = registry

    # ------------------------------------------------------------- admission

    def qos_class(self, tenant: str) -> QosClass:
        """The tenant's ``QosClass`` (unknown tenants raise KeyError)."""
        return self.classes[tenant]

    def admit(self, tenant: str, now: float) -> None:
        """Admission-control one submit at clock time ``now``.

        Raises ``KeyError`` for an unregistered tenant and a typed
        ``RateLimited`` when the tenant's token bucket is empty; on
        return the request is admitted (and counted).
        """
        cls = self.qos_class(tenant)
        bucket = self._buckets.get(tenant)
        if bucket is not None and not bucket.try_take(now):
            self.metrics.counter(
                "wlsh_tenant_rate_limited_total",
                "submits rejected by admission control",
            ).inc(tenant=tenant)
            raise RateLimited(tenant, cls.rate, cls.burst)
        self.metrics.counter(
            "wlsh_tenant_admitted_total", "admitted submits"
        ).inc(tenant=tenant)

    def deadline_for(
        self, tenant: str, now: float, default_s: float
    ) -> float:
        """Deadline for a submit with no explicit deadline.

        The class SLO budget when set, else the service default.
        """
        cls = self.qos_class(tenant)
        budget = default_s if cls.slo_ms is None else cls.slo_ms / 1e3
        return now + budget

    # ------------------------------------------------------------ fair queue

    def rung_of(self, tenant: str) -> int:
        """Tenant's current ladder rung (0 = strict parameters)."""
        return self._rung.get(tenant, 0)

    def cost_of(self, tenant: str) -> float:
        """Modeled launch cost at the tenant's current rung."""
        rung = self.rung_of(tenant)
        return 1.0 if rung == 0 else self.ladder[rung - 1].cost

    def recall_bound_of(self, rung: int, strict_bound: float = 1.0
                        ) -> float:
        """The planned recall floor at ladder ``rung``.

        Rung 0 (strict parameters) carries ``strict_bound`` — the
        caller's reference for undegraded answers (the serving stack
        passes ``ServiceConfig.recall_floor``); rung ``r >= 1`` carries
        ``ladder[r - 1].recall_bound``.  The shadow recall estimator
        and the ``recall_below_bound`` alert compare observed recall
        against this value per rung.
        """
        if not 0 <= rung <= len(self.ladder):
            raise ValueError(
                f"rung must be in [0, {len(self.ladder)}], got {rung}"
            )
        if rung == 0:
            return float(strict_bound)
        return float(self.ladder[rung - 1].recall_bound)

    def plan_launches(self, expired, now: float) -> list:
        """Fair-order the tick's expired launches under the capacity.

        ``expired`` is a list of ``(deadline, group_id, tenant)`` whose
        oldest pending deadline has passed.  Returns the launches to
        perform this tick as ``(group_id, tenant)`` pairs in service
        order; anything left over is deferred to a later tick and
        recorded as overload pressure for ``observe_tick``.
        """
        queues: dict[str, list] = {}
        for deadline, gi, tenant in sorted(
            expired, key=lambda e: (e[0], e[1])
        ):
            queues.setdefault(tenant, []).append((gi, tenant))
        budget = (
            math.inf if self.capacity_per_tick is None
            else self.capacity_per_tick
        )
        selected = self.drr.select(
            queues,
            weight_of=lambda t: self.qos_class(t).weight,
            cost_of=self.cost_of,
            budget=budget,
        )
        self._pressure = any(q for q in queues.values())
        return selected

    def note_idle_tick(self) -> None:
        """Record a tick with nothing expired (clears overload pressure)."""
        self._pressure = False

    # ----------------------------------------------------------- degradation

    @property
    def overloaded(self) -> bool:
        """Whether the last tick deferred expired work past the capacity."""
        return self._pressure

    def observe_tick(self) -> None:
        """Advance the degradation controller by one driver tick.

        ``degrade_after`` consecutive pressured ticks step every
        degradable tenant one rung down the ladder; ``restore_after``
        consecutive clear ticks step one rung back up.  Each transition
        restarts its streak, so every further step requires another
        full sustained window (hysteresis in both directions).
        """
        if self._pressure:
            self._over_streak += 1
            self._clear_streak = 0
        else:
            self._clear_streak += 1
            self._over_streak = 0
        if not self.ladder:
            return
        if self._over_streak >= self.degrade_after:
            self._over_streak = 0
            stepped = False
            for name, cls in self.classes.items():
                if cls.degradable and self._rung[name] < len(self.ladder):
                    self._rung[name] += 1
                    stepped = True
            if stepped:
                self.n_degrade_steps += 1
        elif self._clear_streak >= self.restore_after:
            self._clear_streak = 0
            stepped = False
            for name in self.classes:
                if self._rung[name] > 0:
                    self._rung[name] -= 1
                    stepped = True
            if stepped:
                self.n_restore_steps += 1

    # ----------------------------------------------------------- accounting

    def on_resolved(
        self, tenant: str, wait_s: float, missed: bool, rung: int
    ) -> None:
        """Record one resolved query (called by the service per future)."""
        m = self.metrics
        m.counter("wlsh_tenant_resolved_total",
                  "resolved queries").inc(tenant=tenant)
        m.counter("wlsh_tenant_wait_seconds_total",
                  "queued seconds over resolved queries").inc(
            float(wait_s), tenant=tenant)
        if missed:
            m.counter("wlsh_tenant_slo_misses_total",
                      "resolved queries past their deadline").inc(
                tenant=tenant)
        if rung > 0:
            m.counter("wlsh_tenant_degraded_total",
                      "resolved queries answered at rung > 0").inc(
                tenant=tenant)

    def summary(self) -> dict:
        """Per-tenant summaries plus the controller's transition counts."""
        return dict(
            tenants={
                name: dict(
                    **st.summary(),
                    weight=self.classes[name].weight,
                    degradable=self.classes[name].degradable,
                    rung=self._rung[name],
                )
                for name, st in self.stats.items()
            },
            n_degrade_steps=self.n_degrade_steps,
            n_restore_steps=self.n_restore_steps,
            capacity_per_tick=self.capacity_per_tick,
            n_rungs=len(self.ladder),
        )
