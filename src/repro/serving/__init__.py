"""Serving substrate for the multi-group retrieval stack.

Sync + async weight-routed frontends over a shared batching core, group
states paged through a budgeted ``StateCache``, streaming inserts/deletes
through the ``DeltaIndex`` subsystem, a real-time ``ServiceDriver`` with
predictive prefetch and cost-aware eviction, multi-tenant QoS (admission
control, weighted-fair dequeue, SLO-aware (c, k) degradation), plus the
LM decode loop/samplers.
"""

from .async_service import (
    AsyncRetrievalService,
    ManualClock,
    Overloaded,
    QueryAnswer,
    QueryFuture,
    replay_open_loop,
)
from .batching import (
    Batcher,
    BatchPlan,
    coalesce,
    merge_topk,
    pad_take,
    run_plans,
)
from .decode import SamplerConfig, generate, make_serve_step
from .delta import DeltaIndex, DeltaStats
from .qos import (
    DEFAULT_TENANT,
    DeficitRoundRobin,
    DegradeStep,
    QosClass,
    QosScheduler,
    RateLimited,
    TenantStats,
    TokenBucket,
)
from .scheduler import (
    CostAwareEviction,
    DeadlinePrefetch,
    DriverStats,
    EvictionPolicy,
    LRUEviction,
    PrefetchPolicy,
    ServiceDriver,
    replay_with_driver,
)
from .state_cache import (
    CacheStats,
    EvictionCandidate,
    RestoreCostModel,
    StateCache,
)
from .retrieval import (
    GroupServeStats,
    RetrievalResult,
    RetrievalService,
    ServiceConfig,
)

__all__ = [
    "AsyncRetrievalService",
    "BatchPlan",
    "Batcher",
    "CacheStats",
    "CostAwareEviction",
    "DEFAULT_TENANT",
    "DeadlinePrefetch",
    "DeficitRoundRobin",
    "DegradeStep",
    "DeltaIndex",
    "DeltaStats",
    "DriverStats",
    "EvictionCandidate",
    "EvictionPolicy",
    "GroupServeStats",
    "LRUEviction",
    "ManualClock",
    "Overloaded",
    "PrefetchPolicy",
    "QosClass",
    "QosScheduler",
    "QueryAnswer",
    "QueryFuture",
    "RateLimited",
    "RestoreCostModel",
    "RetrievalResult",
    "RetrievalService",
    "SamplerConfig",
    "ServiceConfig",
    "ServiceDriver",
    "StateCache",
    "TenantStats",
    "TokenBucket",
    "coalesce",
    "generate",
    "make_serve_step",
    "merge_topk",
    "pad_take",
    "replay_open_loop",
    "replay_with_driver",
    "run_plans",
]
