"""Serving substrate for the multi-group retrieval stack.

Sync + async weight-routed frontends over a shared batching core, group
states paged through a budgeted ``StateCache``, plus the LM decode
loop/samplers.
"""

from .async_service import (
    AsyncRetrievalService,
    ManualClock,
    QueryAnswer,
    QueryFuture,
    replay_open_loop,
)
from .batching import Batcher, BatchPlan, coalesce, pad_take, run_plans
from .decode import SamplerConfig, generate, make_serve_step
from .state_cache import CacheStats, StateCache
from .retrieval import (
    GroupServeStats,
    RetrievalResult,
    RetrievalService,
    ServiceConfig,
)

__all__ = [
    "AsyncRetrievalService",
    "BatchPlan",
    "Batcher",
    "CacheStats",
    "GroupServeStats",
    "ManualClock",
    "QueryAnswer",
    "QueryFuture",
    "RetrievalResult",
    "RetrievalService",
    "SamplerConfig",
    "ServiceConfig",
    "StateCache",
    "coalesce",
    "generate",
    "make_serve_step",
    "pad_take",
    "replay_open_loop",
    "run_plans",
]
