"""Serving substrate: sync + async multi-group retrieval frontends over a
shared batching core, plus the decode loop/samplers."""

from .async_service import (
    AsyncRetrievalService,
    ManualClock,
    QueryAnswer,
    QueryFuture,
    replay_open_loop,
)
from .batching import Batcher, BatchPlan, coalesce, pad_take, run_plans
from .decode import SamplerConfig, generate, make_serve_step
from .retrieval import (
    GroupServeStats,
    RetrievalResult,
    RetrievalService,
    ServiceConfig,
)

__all__ = [
    "AsyncRetrievalService",
    "BatchPlan",
    "Batcher",
    "GroupServeStats",
    "ManualClock",
    "QueryAnswer",
    "QueryFuture",
    "RetrievalResult",
    "RetrievalService",
    "SamplerConfig",
    "ServiceConfig",
    "coalesce",
    "generate",
    "make_serve_step",
    "pad_take",
    "replay_open_loop",
    "run_plans",
]
