"""Serving substrate: decode loop + samplers (KV caches live in models/)."""

from .decode import SamplerConfig, generate, make_serve_step

__all__ = ["SamplerConfig", "generate", "make_serve_step"]
