"""Serving substrate: multi-group retrieval service + decode loop/samplers."""

from .decode import SamplerConfig, generate, make_serve_step
from .retrieval import (
    GroupServeStats,
    RetrievalResult,
    RetrievalService,
    ServiceConfig,
)

__all__ = [
    "GroupServeStats",
    "RetrievalResult",
    "RetrievalService",
    "SamplerConfig",
    "ServiceConfig",
    "generate",
    "make_serve_step",
]
