"""Real-time scheduler: deadline-driven driver, prefetch, cost-aware evict.

The deadline-aware frontend is inert on its own: ``AsyncRetrievalService``
only launches work inside ``submit``/``poll``/``drain``, and the
``StateCache`` pages group states with a pure-LRU policy that knows
nothing about what is *about to* launch or what a restore costs.  But the
pending buffers are a schedule — every request carries a deadline, and a
deadline is a launch time — so the serving stack can be driven
predictively instead of reactively.  This module is that driver layer:

  ``ServiceDriver``      owns the service in real time.  Step-driven
                         (``step()`` on the injectable clock /
                         ``ManualClock`` — the deterministic form every
                         test and trace replay uses) or thread-backed
                         (``start()``/``stop()`` for wall-clock
                         deployments).  Each tick reads the pending
                         schedule, issues prefetches, fires expired
                         deadlines through ``poll``, and spends idle
                         ticks on background work (sealed-segment
                         compaction — handed off from the undriven
                         ``poll`` path).
  ``PrefetchPolicy``     decides which group states to bring on device
                         ahead of their launches.  The default
                         ``DeadlinePrefetch`` reads per-group pending
                         depth + oldest deadline and prefetches groups
                         launching within a restore horizon (or with
                         buffers near the batch size), soonest deadline
                         first, protecting them from eviction.
  ``EvictionPolicy``     makes the ``StateCache`` victim choice
                         pluggable.  ``LRUEviction`` reproduces the
                         classic choice; the driver's default
                         ``CostAwareEviction`` scores staleness against
                         ``state_nbytes`` restore cost, so a cheap
                         state is sacrificed before an expensive one of
                         similar recency.

Everything here only *reorders* paging work — prefetch is the same
restore issued earlier, eviction policies only choose among states the
LRU policy could also have evicted — so answers stay bit-exact with the
undriven ``poll()`` loop, prefetch on or off, paged or not.
"""

from __future__ import annotations

import dataclasses
import math
import threading

from .async_service import (
    AsyncRetrievalService,
    ManualClock,
    QueryFuture,
    _replay,
)
from .state_cache import EvictionCandidate

__all__ = [
    "CostAwareEviction",
    "DeadlinePrefetch",
    "DriverStats",
    "EvictionPolicy",
    "LRUEviction",
    "PrefetchPolicy",
    "ServiceDriver",
    "replay_with_driver",
]


def _fmt_delta(v: float) -> str:
    """Format a tick-summary counter delta (integral values as ints)."""
    f = float(v)
    return str(int(f)) if f.is_integer() else f"{f:.6g}"


# ------------------------------------------------------------------ eviction


class EvictionPolicy:
    """Pluggable ``StateCache`` victim choice.

    A policy is called with a non-empty tuple of ``EvictionCandidate``
    (every unpinned, unprotected resident group — pinned and protected
    groups are never offered) and must return one candidate's
    ``group_id``.  Policies see monotone access ticks, never wall-clock,
    so the choice is deterministic and replayable.
    """

    def __call__(
        self, candidates: tuple[EvictionCandidate, ...]
    ) -> int:
        """Return the ``group_id`` of the candidate to evict."""
        raise NotImplementedError


class LRUEviction(EvictionPolicy):
    """The classic choice: evict the least-recently-used candidate."""

    def __call__(
        self, candidates: tuple[EvictionCandidate, ...]
    ) -> int:
        """Return the candidate with the smallest ``last_use`` tick."""
        return min(
            candidates, key=lambda c: (c.last_use, c.group_id)
        ).group_id


@dataclasses.dataclass(frozen=True)
class CostAwareEviction(EvictionPolicy):
    """Evict the stalest state *per byte of restore cost*.

    Pure LRU treats a 4 MiB state and a 400 MiB state as equally cheap
    to lose, but re-acquiring them is not equally cheap: restore cost is
    one host-to-device copy of ``state_nbytes``.  This policy scores
    every candidate as ``age / nbytes`` — age in monotone access ticks
    since last use — and evicts the maximum: a state must be
    proportionally staler to justify evicting proportionally more
    restore bytes.  With equal sizes it degrades exactly to LRU.  Ties
    break toward the staler candidate, then the smaller group id, so
    the ordering is total and deterministic.

    ``cost_exponent`` tempers the size term (``age / nbytes**e``):
    1.0 is the balanced default, 0.0 recovers pure LRU.
    """

    cost_exponent: float = 1.0

    def __call__(
        self, candidates: tuple[EvictionCandidate, ...]
    ) -> int:
        """Return the candidate maximizing staleness per restore byte."""
        now = max(c.last_use for c in candidates) + 1

        def key(c: EvictionCandidate):
            age = now - c.last_use
            cost = max(c.nbytes, 1) ** self.cost_exponent
            return (age / cost, -c.last_use, -c.group_id)

        return max(candidates, key=key).group_id


# ----------------------------------------------------------------- prefetch


class PrefetchPolicy:
    """Decides which group states to page in ahead of their launches."""

    def plan(
        self,
        pending: dict[int, tuple[int, float]],
        q_batch: int,
        now: float,
        cache=None,
    ) -> tuple[list[int], set[int]]:
        """Return ``(prefetch_order, protect_set)`` for this tick.

        ``pending`` maps group id to ``(depth, oldest_deadline)`` per
        ``AsyncRetrievalService.pending_depths``.  ``prefetch_order`` is
        the list of groups to ``StateCache.prefetch``, most urgent
        first; ``protect_set`` is shielded from eviction until the next
        tick (it must contain every group the order asks to prefetch,
        or a later prefetch could evict an earlier one).  ``cache``
        optionally passes the shared ``StateCache`` so a policy can read
        learned restore-cost estimates (``restore_eta``); policies must
        accept ``cache=None`` and fall back to static knobs.
        """
        raise NotImplementedError


@dataclasses.dataclass(frozen=True)
class DeadlinePrefetch(PrefetchPolicy):
    """Prefetch groups that are scheduled to launch soon.

    A group is *imminent* when its oldest pending deadline falls within
    ``horizon_s`` of now (the restore horizon: the upload must start at
    least one restore-time before the launch), or when its buffer has
    filled past ``depth_fraction`` of ``q_batch`` (a full buffer
    launches immediately on the next submit, deadline notwithstanding).
    Imminent groups are prefetched soonest-deadline-first and protected
    from eviction for the tick, so a prefetch can never evict a state
    that is itself about to launch.

    Groups whose deadline has *already expired* are protected but not
    prefetched: their launch happens this very tick, so a restore issued
    now would serialize into the launch's critical path anyway — letting
    the launch fault it in keeps the hit/overlap counters honest (a
    same-tick restore must count as a miss, not an overlap).

    When the driver passes the shared ``StateCache``, the horizon is
    *learned* per group: the cache's ``RestoreCostModel`` (EWMA bytes/s
    over observed restore timings) predicts that group's restore time,
    and the effective horizon is ``max(horizon_s, eta_margin * eta)`` —
    a big state whose restore takes longer than the static knob is
    prefetched proportionally earlier, while ``horizon_s`` stays a
    deterministic floor so behaviour without timing data (and every
    virtual-time replay) is unchanged.
    """

    horizon_s: float = 0.050
    depth_fraction: float = 0.5
    eta_margin: float = 1.5  # prefetch this many predicted-restores early

    def plan(
        self,
        pending: dict[int, tuple[int, float]],
        q_batch: int,
        now: float,
        cache=None,
    ) -> tuple[list[int], set[int]]:
        """Imminent groups, soonest oldest-deadline first."""
        fill = max(1, math.ceil(self.depth_fraction * q_batch))
        due, coming = [], []
        for gi, (depth, deadline) in pending.items():
            horizon = self.horizon_s
            if cache is not None:
                horizon = max(
                    horizon, self.eta_margin * cache.restore_eta(gi)
                )
            if deadline <= now:  # launching this tick: protect only
                due.append(gi)
            elif deadline - now <= horizon or depth >= fill:
                coming.append((deadline, gi))
        order = [gi for _, gi in sorted(coming)]
        return order, set(order) | set(due)


# ------------------------------------------------------------------- driver


class DriverStats:
    """Running driver counters (one ``ServiceDriver`` lifetime).

    A *deadline miss* is counted when a group's oldest pending deadline
    has expired while its state is off-device — the restore (or cold
    build) then serializes into that launch's critical path.  Misses are
    accounted before the tick's prefetches run, so a prefetch issued in
    the same tick as the launch does not hide the miss.

    A read-only view over the stack's ``obs.MetricsRegistry``
    (``wlsh_driver_*`` counters); attaching a fresh driver resets the
    prefix, so one view spans one driver lifetime.
    """

    # attribute -> the (unlabeled) registry counter behind it
    _COUNTERS = {
        "n_ticks": "wlsh_driver_ticks_total",
        "n_launches": "wlsh_driver_launches_total",
        "n_deadlines_due": "wlsh_driver_deadlines_due_total",
        "n_deadline_misses": "wlsh_driver_deadline_misses_total",
        "n_prefetches_issued": "wlsh_driver_prefetches_issued_total",
        "n_idle_compactions": "wlsh_driver_idle_compactions_total",
    }

    def __init__(self, metrics):
        """Bind the view to ``metrics`` (the service stack's registry)."""
        self._metrics = metrics

    def __getattr__(self, name: str) -> int:
        """Read the registry counter backing attribute ``name``."""
        metric = type(self)._COUNTERS.get(name)
        if metric is None:
            raise AttributeError(name)
        return int(self._metrics.counter(metric).total())

    @property
    def deadline_miss_rate(self) -> float:
        """Missed fraction of expired deadlines (nan with none due)."""
        if not self.n_deadlines_due:
            return float("nan")
        return self.n_deadline_misses / self.n_deadlines_due

    def summary(self) -> dict:
        """Flat dict of every counter plus the derived miss rate."""
        return dict(
            n_ticks=self.n_ticks,
            n_launches=self.n_launches,
            n_deadlines_due=self.n_deadlines_due,
            n_deadline_misses=self.n_deadline_misses,
            n_prefetches_issued=self.n_prefetches_issued,
            n_idle_compactions=self.n_idle_compactions,
            deadline_miss_rate=self.deadline_miss_rate,
        )


class ServiceDriver:
    """Deadline-driven real-time driver over an ``AsyncRetrievalService``.

    One ``step()`` is a scheduler tick:

    1. read the pending schedule (``pending_depths``);
    2. account deadline misses (expired deadline, state off-device);
    3. run the prefetch policy — protect imminent groups from eviction
       and issue ``StateCache.prefetch`` for the non-resident ones, so
       their host-to-device uploads overlap the launches below;
    4. ``poll()`` — launch every group whose oldest deadline expired;
    5. on an idle tick (nothing launched), run one slice of background
       work (sealed-segment compaction via
       ``AsyncRetrievalService.idle_work``).

    Step-driven use (tests, trace replay) calls ``step`` explicitly on
    the service's injectable clock — fully deterministic, no wall-clock
    sleeps anywhere.  Wall-clock use calls ``start()``: a daemon thread
    sleeps until the next pending deadline (or ``tick_s`` when idle),
    waking early on ``submit``.  In thread mode, go through the
    driver's passthroughs — ``submit``/``drain`` and the streaming
    ``insert``/``delete``/``compact`` — which serialize against the
    driver thread (its idle ticks rewrite the same delta structures);
    the step-driven form has no second thread and needs no locking.

    Constructing the driver takes ownership of the service's idle-time
    work (undriven ``poll`` stops compacting) and installs ``eviction``
    on the shared ``StateCache`` (pass None to keep the cache's current
    policy); ``detach()`` reverses both.
    """

    def __init__(
        self,
        service: AsyncRetrievalService,
        *,
        prefetch: PrefetchPolicy | None = DeadlinePrefetch(),
        eviction: EvictionPolicy | None = CostAwareEviction(),
        tick_s: float = 0.005,
        health: "HealthMonitor | None" = None,
    ):
        if not (tick_s > 0):
            raise ValueError(f"tick_s must be > 0, got {tick_s}")
        if service.driver is not None:
            raise ValueError("service already has a driver attached")
        self.svc = service
        self.cache = service.batcher.state_cache
        self.prefetch = prefetch
        self.tick_s = float(tick_s)
        # driver counters live in the stack's unified registry; a fresh
        # driver over a reused service starts its lifetime at zero
        self.metrics = service.batcher.metrics
        self.metrics.reset("wlsh_driver_")
        self.stats = DriverStats(self.metrics)
        # SLO burn-rate alerting (obs.health.HealthMonitor): evaluated
        # once per tick after poll, surfaced in tick_summary.  None =
        # no alerting (zero overhead)
        self.health = health
        self._last_snap: dict | None = None  # tick_summary diff baseline
        self._prev_policy = self.cache.eviction_policy
        if eviction is not None:
            self.cache.eviction_policy = eviction
        service.driver = self
        self._lock = threading.RLock()
        self._wake = threading.Event()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # ------------------------------------------------------------- stepping

    def step(self, now: float | None = None) -> int:
        """One scheduler tick; returns the number of batches launched.

        The policy's imminent set is clamped to the cache budget before
        it is protected or prefetched (soonest deadline first), so
        scheduling can never turn over-budget residency into a steady
        state — the budget stays the budget, and anything past it simply
        faults in at launch time like an undriven service.
        """
        with self._lock:
            if now is None:
                now = self.svc.clock()
            m = self.metrics
            pending = self.svc.pending_depths()
            due = []
            for gi, (_, deadline) in pending.items():
                if deadline <= now:
                    due.append((deadline, gi))
                    m.counter("wlsh_driver_deadlines_due_total",
                              "group-deadlines found expired").inc()
                    if not self.cache.is_resident(gi):
                        m.counter(
                            "wlsh_driver_deadline_misses_total",
                            "expired deadlines with state off-device",
                        ).inc()
            if self.prefetch is not None:
                order, shield = self.prefetch.plan(
                    pending, self.svc.batcher.cfg.q_batch, now,
                    cache=self.cache,
                )
                due_gis = [gi for _, gi in sorted(due)]
                kept = self._clamp_to_budget(
                    due_gis
                    + [gi for gi in order if gi not in set(due_gis)]
                )
                self.cache.protect(shield & kept)
                for gi in order:
                    if gi in kept and self.cache.prefetch(gi):
                        m.counter(
                            "wlsh_driver_prefetches_issued_total",
                            "prefetch calls that issued paging work",
                        ).inc()
            n = self.svc.poll(now)
            m.counter("wlsh_driver_launches_total",
                      "batches launched by driver ticks").inc(n)
            if self.svc.qos is not None:
                # close the tick for degradation hysteresis: sustained
                # deferral pressure steps degradable tenants down the
                # (c, k) ladder; sustained clear ticks step them back up
                self.svc.qos.observe_tick()
            if n == 0 and self.svc.idle_work():
                m.counter("wlsh_driver_idle_compactions_total",
                          "idle ticks that absorbed sealed rows").inc()
            m.counter("wlsh_driver_ticks_total",
                      "scheduler ticks").inc()
            # close the tick for SLO alerting: publish the queue depth
            # the gauge rules watch, then evaluate every alert rule on
            # this tick's counter movement
            if self.health is not None:
                m.gauge("wlsh_pending_queue_depth",
                        "requests queued across pending buffers").set(
                    self.svc.pending_count)
                self.health.observe(now)
            return n

    def _clamp_to_budget(self, priority: list[int]) -> set[int]:
        """Longest prefix of ``priority`` the cache budget can hold.

        ``priority`` is the imminent groups, most urgent first (due
        launches, then the prefetch order).  Without the clamp, a
        horizon wider than the deadline budget would protect every
        pending group and make over-budget residency the steady state;
        clamped, protection + prefetch together never claim more groups
        (or bytes) than the configured budget.
        """
        cap = self.cache.max_resident_groups
        budget = self.cache.device_budget_bytes
        if cap is None and budget is None:
            return set(priority)
        kept: set[int] = set()
        nbytes = 0
        for gi in priority:
            nb = self.cache.nbytes_of(gi)
            if cap is not None and len(kept) + 1 > cap:
                break
            if budget is not None and nbytes + nb > budget:
                break
            kept.add(gi)
            nbytes += nb
        return kept

    def tick_summary(self) -> str:
        """One-line counter movement since the previous summary call.

        Built from ``MetricsRegistry.diff`` against the snapshot the
        last call took — the driver's human-readable heartbeat (the
        launcher prints it after a driven replay).
        """
        diff = self.metrics.diff(self._last_snap)
        self._last_snap = self.metrics.snapshot()
        firing = ([a.rule for a in self.health.firing()]
                  if self.health is not None else [])
        suffix = (" | ALERTS: " + ",".join(firing)) if firing else ""
        if not diff:
            return "driver: idle (no counter movement)" + suffix
        parts = []
        for name in sorted(diff):
            total = sum(diff[name].values())
            short = name.removeprefix("wlsh_").removesuffix("_total")
            parts.append(f"{short}=+{_fmt_delta(total)}")
        return "driver: " + " ".join(parts) + suffix

    def submit(self, query, weight_id, deadline: float | None = None,
               tenant: str | None = None) -> QueryFuture:
        """Thread-safe ``AsyncRetrievalService.submit`` passthrough.

        Serializes against a running driver thread; a full buffer still
        launches inside the call, and the sleeping thread is woken so
        the new request's deadline is picked up immediately.
        """
        with self._lock:
            return self.svc.submit(query, weight_id, deadline,
                                   tenant=tenant)

    def drain(self) -> int:
        """Thread-safe ``AsyncRetrievalService.drain`` passthrough."""
        with self._lock:
            return self.svc.drain()

    def insert(self, vector, weight_id) -> int:
        """Thread-safe ``AsyncRetrievalService.insert`` passthrough.

        Streaming writes mutate the same per-group delta structures the
        driver thread's idle-tick compaction rewrites, so in thread mode
        they must go through the driver's lock like ``submit``.
        """
        with self._lock:
            return self.svc.insert(vector, weight_id)

    def delete(self, point_id: int) -> None:
        """Thread-safe ``AsyncRetrievalService.delete`` passthrough."""
        with self._lock:
            self.svc.delete(point_id)

    def compact(self, group: int | None = None, purge: bool = False) -> int:
        """Thread-safe ``AsyncRetrievalService.compact`` passthrough."""
        with self._lock:
            return self.svc.compact(group, purge=purge)

    def notify_submit(self) -> None:
        """Wake the driver thread early (called by the service's submit)."""
        self._wake.set()

    # ---------------------------------------------------------- thread mode

    @property
    def running(self) -> bool:
        """Whether the wall-clock driver thread is alive."""
        return self._thread is not None and self._thread.is_alive()

    def start(self) -> "ServiceDriver":
        """Launch the wall-clock driver thread (returns self).

        Requires a real (monotonic) clock: a ``ManualClock`` only moves
        when a test advances it, so a thread sleeping on it would spin
        on a frozen deadline — step-driven mode is the deterministic
        form, use ``step()`` there instead.
        """
        if isinstance(self.svc.clock, ManualClock):
            raise TypeError(
                "thread mode needs a real clock; drive a ManualClock "
                "service with step() instead"
            )
        if self.running:
            raise RuntimeError("driver thread already running")
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, name="wlsh-service-driver", daemon=True
        )
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the driver thread; ``drain`` flushes remaining requests.

        Idempotent, and safe to call with the thread never started (the
        drain still runs, so no submitted future is left unresolvable).
        """
        self._stop.set()
        self._wake.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if drain:
            self.drain()

    def detach(self) -> None:
        """Release the service, reversing everything the attach did.

        Stops the thread (without draining), hands idle-time work back
        to ``poll``, restores the cache's previous eviction policy, and
        clears this driver's eviction protection.
        """
        self.stop(drain=False)
        self.cache.protect(())
        self.cache.eviction_policy = self._prev_policy
        if self.svc.driver is self:
            self.svc.driver = None

    def _run(self) -> None:
        while not self._stop.is_set():
            self.step()
            with self._lock:
                nd = self.svc.next_deadline()
                now = self.svc.clock()
            wait = self.tick_s if nd is None else (
                min(max(nd - now, 0.0), self.tick_s)
            )
            if wait > 0:
                self._wake.wait(wait)
            self._wake.clear()


# -------------------------------------------------------------- trace replay


def replay_with_driver(driver: ServiceDriver, queries, weight_ids,
                       arrivals, tenants=None):
    """Open-loop trace replay stepped by a ``ServiceDriver`` (virtual time).

    The driver-owned parameterization of the same replay core behind
    ``async_service.replay_open_loop``: the same absolute arrival
    schedule on a ``ManualClock``, but every event — each arrival and
    each expiring deadline — is a ``driver.step()``, so prefetches are
    issued from the pending schedule between launches exactly as a
    wall-clock driver thread would issue them.  Stepping at arrivals
    launches nothing extra (no deadline has newly expired there), so
    results are bit-exact with the undriven ``poll()`` replay of the
    same trace.

    Returns ``(RetrievalResult, waits)`` in submission order, where
    ``waits[i]`` is the virtual seconds request ``i`` spent queued.
    """
    return _replay(driver.svc, queries, weight_ids, arrivals,
                   tick=driver.step, tick_at_arrivals=True,
                   tenants=tenants)
