"""Shared batching core for the retrieval frontends.

The paper's query procedure (Algorithm 2) answers each query inside its
weight's table group; everything a serving frontend does around that —
route, coalesce, pad, execute, merge — is frontend-independent.  This
module is that shared core, consumed by both the synchronous
``RetrievalService`` (all queries present up front) and the asynchronous
``AsyncRetrievalService`` (queries trickle in and batches launch on fill
or deadline):

  route     (query, weight_id) -> plan.group_of[weight_id]     Batcher.route
  coalesce  same-group submission indices -> q_batch chunks    coalesce()
  pad       ragged tails cycle the batch's real rows           pad_take()
  execute   one compiled step per *shape signature* (groups    Batcher.run_batch
            quantized onto beta/level buckets share a step
            through QueryStepCache)
  merge     real rows scattered back to submission order       run_plans()

``coalesce``/``pad_take``/``run_plans`` are pure (numpy in, numpy out) so
the batching invariants — no dropped, duplicated or reordered query, and
no padded row ever reaching a result — are property-tested against a fake
executor without touching a device.  ``Batcher`` owns the stateful side:
per-group device states paged through a budgeted ``StateCache`` (lazy
build, LRU eviction, host offload/restore — see ``state_cache``), the
compiled-step cache, host/device query encoding, and per-group serving
stats.  Every launch acquires its group's state through the cache and
pins it only for the duration of the launch, so deadline-driven partial
launches from the async frontend cannot thrash each other's states.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..core.serving_plan import ServingPlan
from ..distributed import group_sharding
from ..obs import MetricsRegistry, Profiler, RecallEstimator, Tracer
from ..index.builder import (
    build_group_state,
    offload_state,
    pad_cols,
    restore_state,
)
from ..index.config import IndexConfig, pad_beta, pad_levels
from ..index.engine import QueryStepCache, encode_queries
from .qos import DegradeStep
from .state_cache import StateCache

_NULL_SCOPE = contextlib.nullcontext()  # profiler-off dispatch scope

__all__ = [
    "BatchPlan",
    "Batcher",
    "GroupServeStats",
    "ServiceConfig",
    "coalesce",
    "merge_topk",
    "pad_take",
    "run_plans",
]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving-side knobs (plan parameters come from the ServingPlan)."""

    k: int = 10
    q_batch: int = 8  # compiled batch shape; ragged tails are padded
    block_n: int | None = None  # points per scan block; None = whole shard
    vec_dtype: str = "float32"
    use_pallas: bool | str | None = None  # kernel path (kernels.platform):
    # None/"auto" = per-backend fused default, True/"on" = fused Pallas
    # (interpret off-TPU), False/"off" = unfused oracle, "interpret" =
    # fused Pallas interpret mode; CLI strings are normalized below
    beta_buckets: tuple[int, ...] | None = None  # None = config.pad_beta
    level_step: int = 4  # level-loop bound rounding (config.pad_levels)
    budget_override: int | None = None  # None = k + ceil(gamma * n)
    host_encode: bool = True  # f64 query codes (exact vs planner); False =
    # device f32 encode (standalone engines without exported codes)
    max_delay_ms: float = 5.0  # async frontend: a partial batch launches
    # once its oldest request has waited this long (0 = launch on next poll)
    max_resident_groups: int | None = None  # StateCache: keep at most this
    # many group states on device (None = all groups stay resident)
    device_budget_bytes: int | None = None  # StateCache: keep resident
    # state bytes (IndexConfig.state_nbytes accounting) under this budget
    offload_evicted: bool = True  # evicted states keep a host copy (restore
    # = one upload); False discards them (re-acquire rebuilds from scratch)
    delta_seal_rows: int = 1024  # streaming: a group's open delta memtable
    # seals into a hashed segment at this row count
    delta_reserve_rows: int = 0  # row capacity reserved per group state for
    # compacted inserts; 0 = static index (inserts still serve from the
    # delta scan, but compaction has nowhere to append)
    auto_compact_segments: int | None = None  # compact a group once it
    # holds this many sealed segments (None = compaction only on explicit
    # compact() calls / the async frontend's idle poll)
    max_pending: int | None = None  # async backpressure: cap per-group
    # pending buffers; submit raises Overloaded instead of growing unbounded
    n_shards: int = 1  # shard every group's state rows across this many
    # devices on the serving mesh's "data" axis
    # (distributed.group_sharding.serving_mesh); per-shard passes merge
    # with exact collectives, so answers are bit-identical at any shard
    # count.  Ignored when an explicit mesh is passed to the Batcher
    obs: bool = False  # observability: per-query trace spans (obs.Tracer)
    # and profiling hooks (obs.Profiler) on the serving path.  Host-side
    # bookkeeping only — results are bit-exact on or off.  The metrics
    # registry (Batcher.metrics) always exists regardless: the stats
    # surfaces are views over it
    obs_trace_capacity: int = 4096  # tracer ring: retain at most this
    # many finished spans (older spans fall off; totals stay exact)
    degrade_ladder: tuple = ()  # pre-planned (c, k) relaxation rungs
    # (qos.DegradeStep, mildest first).  Rung 0 is this config's strict
    # (plan.c, k); rung r >= 1 serves at degrade_ladder[r - 1].  Every
    # rung's step is compiled at warmup (c/k are shape-signature keys),
    # so runtime degradation never recompiles; rung answers with k' < k
    # are padded -1/inf back to k so result shapes never change
    recall_sample_rate: float = 0.0  # shadow-exact recall telemetry:
    # sample this fraction of served queries (deterministic hash of the
    # span's query id — no wall randomness) into shadow jobs re-ranked
    # against the exact host oracle off the serving path.  > 0 implies
    # obs (spans carry the query identity); answers stay bit-exact
    recall_shadow_max: int = 1024  # shadow queue depth cap; offers
    # beyond it are dropped and counted, never buffered unbounded
    recall_shadow_slice: int = 8  # shadow jobs executed per idle tick
    # (ServiceDriver idle_work), so shadow re-ranking never competes
    # with deadline launches
    recall_floor: float = 0.0  # observed-recall reference bound for the
    # strict rung 0 (rungs >= 1 use degrade_ladder[r-1].recall_bound);
    # feeds the wlsh_recall_bound_margin gauge and the below-bound alert

    def __post_init__(self):
        # normalize the CLI spellings onto the IndexConfig values (frozen
        # dataclass, hence object.__setattr__)
        up = self.use_pallas
        if isinstance(up, str):
            up = {"auto": None, "on": True, "off": False}.get(
                up.lower(), up.lower()
            )
            object.__setattr__(self, "use_pallas", up)
        if up not in (None, True, False, "interpret"):
            raise ValueError(
                f"use_pallas must be one of auto/on/off/interpret (or "
                f"None/True/False), got {self.use_pallas!r}"
            )
        if self.k < 1:
            raise ValueError(f"k must be >= 1, got {self.k}")
        if self.q_batch < 1:
            raise ValueError(f"q_batch must be >= 1, got {self.q_batch}")
        if self.block_n is not None and self.block_n < 1:
            raise ValueError(
                f"block_n must be >= 1 or None, got {self.block_n}"
            )
        if self.level_step < 1:
            raise ValueError(f"level_step must be >= 1, got {self.level_step}")
        if self.budget_override is not None and self.budget_override < 1:
            raise ValueError(
                f"budget_override must be >= 1 or None, got "
                f"{self.budget_override}"
            )
        if self.beta_buckets is not None and (
            len(self.beta_buckets) == 0
            or any(b < 1 for b in self.beta_buckets)
        ):
            raise ValueError(
                f"beta_buckets must be a non-empty tuple of positive table "
                f"counts or None, got {self.beta_buckets!r}"
            )
        if not (self.max_delay_ms >= 0):  # also rejects NaN
            raise ValueError(
                f"max_delay_ms must be >= 0, got {self.max_delay_ms}"
            )
        if self.max_resident_groups is not None and (
            self.max_resident_groups < 1
        ):
            raise ValueError(
                f"max_resident_groups must be >= 1 or None, got "
                f"{self.max_resident_groups}"
            )
        if self.device_budget_bytes is not None and (
            self.device_budget_bytes < 1
        ):
            raise ValueError(
                f"device_budget_bytes must be >= 1 or None, got "
                f"{self.device_budget_bytes}"
            )
        if self.delta_seal_rows < 1:
            raise ValueError(
                f"delta_seal_rows must be >= 1, got {self.delta_seal_rows}"
            )
        if self.delta_reserve_rows < 0:
            raise ValueError(
                f"delta_reserve_rows must be >= 0, got "
                f"{self.delta_reserve_rows}"
            )
        if self.auto_compact_segments is not None and (
            self.auto_compact_segments < 1
        ):
            raise ValueError(
                f"auto_compact_segments must be >= 1 or None, got "
                f"{self.auto_compact_segments}"
            )
        if self.max_pending is not None and self.max_pending < 1:
            raise ValueError(
                f"max_pending must be >= 1 or None, got {self.max_pending}"
            )
        if self.n_shards < 1:
            raise ValueError(f"n_shards must be >= 1, got {self.n_shards}")
        if self.obs_trace_capacity < 1:
            raise ValueError(
                f"obs_trace_capacity must be >= 1, got "
                f"{self.obs_trace_capacity}"
            )
        for i, step in enumerate(self.degrade_ladder):
            if not isinstance(step, DegradeStep):
                raise ValueError(
                    f"degrade_ladder[{i}] must be a qos.DegradeStep, got "
                    f"{step!r}"
                )
            if step.k > self.k:
                raise ValueError(
                    f"degrade_ladder[{i}].k={step.k} exceeds the strict "
                    f"k={self.k} (relaxation must not widen results)"
                )
        if not (0.0 <= self.recall_sample_rate <= 1.0):  # also rejects NaN
            raise ValueError(
                f"recall_sample_rate must be in [0, 1], got "
                f"{self.recall_sample_rate}"
            )
        if self.recall_shadow_max < 1:
            raise ValueError(
                f"recall_shadow_max must be >= 1, got "
                f"{self.recall_shadow_max}"
            )
        if self.recall_shadow_slice < 1:
            raise ValueError(
                f"recall_shadow_slice must be >= 1, got "
                f"{self.recall_shadow_slice}"
            )
        if not (0.0 <= self.recall_floor <= 1.0):
            raise ValueError(
                f"recall_floor must be in [0, 1], got {self.recall_floor}"
            )
        if self.recall_sample_rate > 0 and not self.obs:
            # shadow sampling keys on the tracer's query ids; force the
            # obs layer on (bit-exact either way) rather than silently
            # sampling nothing
            object.__setattr__(self, "obs", True)
        try:
            jnp.dtype(self.vec_dtype)
        except TypeError:
            raise ValueError(f"vec_dtype {self.vec_dtype!r} is not a dtype")


# --------------------------------------------------------------- pure helpers


@dataclasses.dataclass(frozen=True)
class BatchPlan:
    """One compiled-step launch: up to q_batch same-group submission rows."""

    group_id: int
    rows: np.ndarray  # global submission indices, submission order


def pad_take(n_real: int, q_batch: int) -> np.ndarray:
    """Gather indices padding ``n_real`` rows to a full ``q_batch``.

    Padding cycles the real rows (a real query repeated is still a valid
    query for the compiled step); callers slice outputs back to
    ``[:n_real]`` so padded rows never reach a result.
    """
    if not 1 <= n_real <= q_batch:
        raise ValueError(
            f"n_real must be in [1, q_batch={q_batch}], got {n_real}"
        )
    return np.arange(q_batch) % n_real


def coalesce(group_ids: np.ndarray, q_batch: int) -> list[BatchPlan]:
    """Stable-partition submission indices by group and chunk into batches.

    Within each group the submission order is preserved; every index lands
    in exactly one plan and every plan holds 1..q_batch rows of one group.
    """
    if q_batch < 1:
        raise ValueError(f"q_batch must be >= 1, got {q_batch}")
    group_ids = np.atleast_1d(np.asarray(group_ids))
    plans: list[BatchPlan] = []
    for gi in np.unique(group_ids):
        sel = np.where(group_ids == gi)[0]  # ascending = submission order
        for lo in range(0, len(sel), q_batch):
            plans.append(BatchPlan(int(gi), sel[lo : lo + q_batch]))
    return plans


def run_plans(plans, queries, weight_ids, run_batch, k, spans=None):
    """Execute every BatchPlan and merge outputs back to submission order.

    ``run_batch(group_id, queries, weight_ids)`` must return per-row
    ``(ids, dists, stop_levels, n_checked)`` for exactly the real rows it
    was handed (padding is its private business).  Shared by the sync
    frontend and the batching property tests, which pass a fake executor.

    ``spans`` (optional) is one ``obs.TraceSpan`` per submission row;
    each launch is handed its rows' spans via a ``spans=`` keyword so
    the executor can stamp launch-side stages.  Fake executors without
    the keyword keep working — the argument is only forwarded when
    spans are present.
    """
    nq = len(queries)
    out_ids = np.full((nq, k), -1, np.int32)
    out_d = np.full((nq, k), np.inf, np.float32)
    out_stop = np.zeros(nq, np.int32)
    out_chk = np.zeros(nq, np.int32)
    for bp in plans:
        kw = {}
        if spans is not None:
            kw["spans"] = [spans[i] for i in bp.rows]
        ids, d, stop, chk = run_batch(
            bp.group_id, queries[bp.rows], weight_ids[bp.rows], **kw
        )
        out_ids[bp.rows] = ids
        out_d[bp.rows] = d
        out_stop[bp.rows] = stop
        out_chk[bp.rows] = chk
    return out_ids, out_d, out_stop, out_chk


def merge_topk(ids, dists, extra_ids, extra_dists, k, drop=None):
    """Merge indexed hits with delta-scan hits into per-row top-k.

    ``ids``/``dists`` are the compiled index path's per-row candidates
    (sorted ascending, -1/inf = missing); ``extra_ids``/``extra_dists``
    the exact delta-scan hits (same conventions, disjoint ids — delta rows
    are by construction not yet in the index).  ``drop`` is the tombstone
    id set: dropped ids never appear, their slots backfilled from the
    remaining candidates.  Pure numpy, shared with the batching property
    tests; invariants:

    * output sorted ascending by distance, missing slots -1/inf at the end
    * no candidate duplicated or invented; tombstoned ids filtered
    * distance ties prefer the indexed operand (then lower slot), so with
      no delta hits and no tombstones the indexed rows pass through
      bit-exactly — the post-compaction parity guarantee
    """
    ids = np.atleast_2d(np.asarray(ids)).astype(np.int64)
    dists = np.atleast_2d(np.asarray(dists, np.float32))
    extra_ids = np.atleast_2d(np.asarray(extra_ids)).astype(np.int64)
    extra_dists = np.atleast_2d(np.asarray(extra_dists, np.float32))
    cand_ids = np.concatenate([ids, extra_ids], axis=1)
    cand_d = np.concatenate([dists, extra_dists], axis=1)
    invalid = cand_ids < 0
    if drop:
        tomb = np.fromiter(drop, np.int64, count=len(drop))
        invalid |= np.isin(cand_ids, tomb)
    cand_d = np.where(invalid, np.float32(np.inf), cand_d)
    cand_ids = np.where(invalid, np.int64(-1), cand_ids)
    order = np.argsort(cand_d, axis=1, kind="stable")[:, :k]
    out_ids = np.take_along_axis(cand_ids, order, axis=1)
    out_d = np.take_along_axis(cand_d, order, axis=1)
    out_ids = np.where(np.isinf(out_d), np.int64(-1), out_ids)
    return out_ids.astype(np.int32), out_d.astype(np.float32)


# ---------------------------------------------------------------------- stats


class GroupServeStats:
    """Per-group serving counters (reset with ``Batcher.reset_stats``).

    Since the observability PR this is a *read-only view* over the
    stack's ``obs.MetricsRegistry`` — ``Batcher.run_batch`` and the
    ``StateCache`` increment the registry counters directly, and each
    attribute here reads the value labeled with this view's group.  One
    source of truth; running sums, not samples, so a long-lived service
    never grows state with traffic.
    """

    # attribute -> registry counter (all labeled {group=<gi>})
    _COUNTERS = {
        "n_queries": "wlsh_group_queries_total",
        "n_batches": "wlsh_group_batches_total",
        "n_padded": "wlsh_group_padded_rows_total",
        "stop_level_sum": "wlsh_group_stop_levels_total",
        "n_checked_sum": "wlsh_group_checked_total",
        # state-paging counters, shared with CacheStats (same series)
        "n_state_hits": "wlsh_state_hits_total",
        "n_state_builds": "wlsh_state_builds_total",
        "n_state_restores": "wlsh_state_restores_total",
        "n_state_evictions": "wlsh_state_evictions_total",
        "n_state_invalidations": "wlsh_state_invalidations_total",
        "n_state_prefetches": "wlsh_state_prefetches_total",
        "n_state_prefetch_wasted": "wlsh_state_prefetch_wasted_total",
        "n_state_restore_overlapped":
            "wlsh_state_restore_overlapped_total",
    }

    def __init__(self, metrics: MetricsRegistry, group_id: int):
        """View over ``metrics`` restricted to ``group_id``'s series."""
        self._metrics = metrics
        self._group_id = int(group_id)

    def __getattr__(self, name: str) -> int:
        """Read the registry counter backing attribute ``name``."""
        metric = self._COUNTERS.get(name)
        if metric is None:
            raise AttributeError(name)
        return int(
            self._metrics.counter(metric).value(group=self._group_id)
        )

    @property
    def occupancy(self) -> float:
        """Real-row fraction of the launched (padded) batch rows."""
        filled = self.n_queries + self.n_padded
        return self.n_queries / filled if filled else 0.0

    def summary(self) -> dict:
        """Flat per-group report consumed by the launcher and benchmarks."""
        nq = self.n_queries
        return dict(
            n_queries=nq,
            n_batches=self.n_batches,
            occupancy=self.occupancy,
            mean_stop_level=self.stop_level_sum / nq if nq else float("nan"),
            mean_n_checked=self.n_checked_sum / nq if nq else float("nan"),
            n_state_hits=self.n_state_hits,
            n_state_builds=self.n_state_builds,
            n_state_restores=self.n_state_restores,
            n_state_evictions=self.n_state_evictions,
            n_state_invalidations=self.n_state_invalidations,
            n_state_prefetches=self.n_state_prefetches,
            n_state_prefetch_wasted=self.n_state_prefetch_wasted,
            n_state_restore_overlapped=self.n_state_restore_overlapped,
        )


# --------------------------------------------------------------------- core


class Batcher:
    """Stateful batching core shared by the sync and async frontends.

    States and compiled steps are built lazily per group (call ``warmup``
    to front-load); ``step_cache.n_compiled`` counts distinct compiled
    shape signatures, which stays far below the group count on real plans
    — and stays pinned no matter which frontend drives the traffic.

    Group states live in a budgeted ``StateCache``: under
    ``cfg.max_resident_groups`` / ``cfg.device_budget_bytes`` the
    least-recently-used groups are evicted (host-offloaded by default)
    and transparently restored on their next launch, bit-exactly.

    Every operational counter lands in one ``obs.MetricsRegistry``
    (``self.metrics``, shared with the state cache, driver and QoS
    layers); ``stats``/``cache_summary`` are views over it.  With
    ``cfg.obs`` enabled the batcher additionally opens per-query
    ``obs.TraceSpan``s (``self.tracer``) and attributes compiles and
    dispatch time per shape signature (``self.profiler``) — host-side
    only, results stay bit-exact.  ``self.clock`` is the injectable
    time source for span stamps; the async frontend re-binds it to its
    own clock so ``ManualClock`` replays trace deterministically.
    """

    def __init__(
        self,
        plan: ServingPlan,
        points: np.ndarray,
        mesh=None,
        cfg: ServiceConfig | None = None,
    ):
        if cfg is None:
            cfg = ServiceConfig()
        points = np.ascontiguousarray(points, dtype=np.float32)
        if points.shape != (plan.n, plan.d):
            raise ValueError(
                f"points shape {points.shape} != plan ({plan.n}, {plan.d})"
            )
        self.plan = plan
        self.points = points
        # cfg.n_shards sizes the serving mesh (group states shard their
        # rows across it); an explicit mesh wins, e.g. a training mesh
        # reused for serving
        self.mesh = mesh if mesh is not None else (
            group_sharding.serving_mesh(cfg.n_shards)
        )
        self.cfg = cfg
        for i, step in enumerate(cfg.degrade_ladder):
            if step.c < plan.c:
                raise ValueError(
                    f"degrade_ladder[{i}].c={step.c} is below the strict "
                    f"plan c={plan.c} (relaxation must not tighten the "
                    f"approximation ratio)"
                )
        self.clock = time.monotonic  # injectable; async frontend re-binds
        self.metrics = MetricsRegistry()
        self.tracer = (Tracer(cfg.obs_trace_capacity, metrics=self.metrics)
                       if cfg.obs else None)
        self.profiler = Profiler() if cfg.obs else None
        # shadow-exact recall telemetry (obs.recall): sampled served
        # queries are re-ranked against the exact host oracle off the
        # serving path.  None when sampling is off — zero overhead.
        self.recall = (RecallEstimator(self)
                       if cfg.recall_sample_rate > 0 else None)
        self._cache_events: list[str] | None = None  # span attribution
        self.step_cache = QueryStepCache()
        if self.profiler is not None:
            self.step_cache.on_compile = (
                lambda c: self.profiler.record_compile(
                    str(c.shape_signature())
                )
            )
        self._group_cfgs: dict[tuple[int, int], IndexConfig] = {}
        self._delta = None  # lazy DeltaIndex, created on first write
        # Paging moves sharded states per shard (each chunk device_put
        # straight to its device, no all-rows host concatenation); the
        # single-device variants keep the seed behavior on a 1-chip mesh.
        if self.mesh.size > 1:
            offload = group_sharding.offload_state_sharded
            restore = (
                lambda gi, host:
                group_sharding.restore_state_sharded(self.mesh, host)
            )
        else:
            offload = offload_state
            restore = lambda gi, host: restore_state(self.mesh, host)
        self.state_cache = StateCache(
            build=self._build_state,
            nbytes_of=lambda gi: self.group_config(gi).state_nbytes,
            max_resident_groups=cfg.max_resident_groups,
            device_budget_bytes=cfg.device_budget_bytes,
            offload=offload if cfg.offload_evicted else None,
            restore=restore if cfg.offload_evicted else None,
            on_event=self._note_cache_event,
            metrics=self.metrics,
        )
        self.stats: dict[int, GroupServeStats] = {
            gi: GroupServeStats(self.metrics, gi)
            for gi in range(plan.n_groups)
        }

    # ------------------------------------------------------------- per group

    def row_capacity(self) -> int:
        """Row capacity of every group state (base corpus + delta reserve).

        ``ServiceConfig.delta_reserve_rows`` preallocates headroom that
        streaming compaction appends into without changing any compiled
        shape; the capacity is rounded up to a mesh-size multiple so the
        row sharding stays even.  All groups share one capacity, which
        preserves the shape-bucket compiled-step sharing.
        """
        cap = self.plan.n + self.cfg.delta_reserve_rows
        return cap + (-cap) % self.mesh.size

    def _block_n(self) -> int:
        n_loc = self.row_capacity() // self.mesh.size
        want = self.cfg.block_n if self.cfg.block_n is not None else n_loc
        block = max(1, min(want, n_loc))
        while n_loc % block:
            block -= 1
        return block

    @property
    def n_rungs(self) -> int:
        """Ladder depth: valid rungs are ``0`` (strict) .. ``n_rungs``."""
        return len(self.cfg.degrade_ladder)

    def rung_params(self, rung: int) -> tuple[int, int]:
        """Effective ``(c, k)`` at ladder ``rung`` (0 = strict)."""
        if not 0 <= rung <= self.n_rungs:
            raise ValueError(
                f"rung must be in [0, {self.n_rungs}], got {rung}"
            )
        if rung == 0:
            return int(self.plan.c), int(self.cfg.k)
        step = self.cfg.degrade_ladder[rung - 1]
        return int(step.c), int(step.k)

    def recall_bound_of(self, rung: int) -> float:
        """The observed-recall reference bound at ladder ``rung``.

        Rung 0 (strict) answers carry ``ServiceConfig.recall_floor``;
        rung ``r >= 1`` answers carry the planned
        ``degrade_ladder[r - 1].recall_bound``.  The shadow recall
        estimator publishes ``wlsh_recall_bound_margin`` (observed −
        bound) against this value.
        """
        if not 0 <= rung <= self.n_rungs:
            raise ValueError(
                f"rung must be in [0, {self.n_rungs}], got {rung}"
            )
        if rung == 0:
            return float(self.cfg.recall_floor)
        return float(self.cfg.degrade_ladder[rung - 1].recall_bound)

    def group_config(self, gi: int, rung: int = 0) -> IndexConfig:
        """Padded IndexConfig for group ``gi`` (the jit-cache key).

        ``rung`` selects a degradation rung of the pre-planned (c, k)
        relaxation ladder (``ServiceConfig.degrade_ladder``); rung 0 is
        the strict config.  Rung configs differ only in the scalar
        ``c``/``k`` (and the derived budget) — state shapes are
        identical, so every rung serves from the *same* cached group
        state, and each rung's step is a distinct pre-compiled shape
        signature.
        """
        key = (gi, rung)
        cfg = self._group_cfgs.get(key)
        if cfg is None:
            g = self.plan.groups[gi]
            c_eff, k_eff = self.rung_params(rung)
            cfg = IndexConfig(
                n=self.row_capacity(),
                d=self.plan.d,
                beta=pad_beta(g.beta_group, self.cfg.beta_buckets),
                q_batch=self.cfg.q_batch,
                k=k_eff,
                c=c_eff,
                n_levels=pad_levels(g.n_levels_max, self.cfg.level_step),
                p=self.plan.p,
                block_n=self._block_n(),
                gamma_n=self.plan.gamma_n,
                budget_override=self.cfg.budget_override,
                vec_dtype=self.cfg.vec_dtype,
                use_pallas=self.cfg.use_pallas,
                delta_seal_rows=self.cfg.delta_seal_rows,
                n_shards=self.mesh.size,
                shard_axis=self.mesh.axis_names[0],
            )
            self._group_cfgs[key] = cfg
        return cfg

    def _build_state(self, gi: int):
        """Cold-path StateCache builder: materialize group ``gi`` on device.

        A group that has absorbed delta compactions rebuilds over its
        union corpus (base points + compacted rows, sealed codes reused),
        so paging in discard mode can never silently drop streamed rows.
        After a tombstone purge the surviving base rows are threaded
        through too, so a rebuild can never resurrect purged rows.
        """
        extra_points = extra_codes = base_rows = None
        if self._delta is not None:
            extra_points, extra_codes = self._delta.compacted_rows(gi)
            base_rows = self._delta.base_rows()
        return build_group_state(
            self.mesh, self.group_config(gi), self.points,
            self.plan.groups[gi],
            extra_points=extra_points, extra_codes=extra_codes,
            base_rows=base_rows,
        )

    def _note_cache_event(self, gi: int, kind: str) -> None:
        """Record a StateCache event for trace-span stage attribution.

        Counters live in the shared metrics registry (the StateCache
        increments them itself — no mirroring); this hook only captures
        which paging events happened inside the current launch's
        ``lease`` so its spans can mark their prefetch/restore stage.
        """
        events = self._cache_events
        if events is not None:
            events.append(kind)

    def warmup(self, groups=None) -> None:
        """Build states and compile steps ahead of traffic.

        Every ladder rung's step is compiled here too (rung ``c``/``k``
        are shape-signature keys), so runtime QoS degradation only ever
        *switches* among pre-compiled steps — the step-cache counter is
        pinned across overload.

        Under a residency budget (default offload mode) the
        earliest-built states are evicted to host as later ones land,
        leaving the tail resident and the rest warm for restore — first
        traffic to any group then pays one upload, never a rebuild.  In
        discard mode (``offload_evicted=False``) evicted builds would be
        pure waste, so only the budget-fitting tail is prebuilt; the
        rest build on first traffic.
        """
        gids = [
            int(gi) for gi in
            (groups if groups is not None else range(self.plan.n_groups))
        ]
        for gi in gids:
            for rung in range(self.n_rungs + 1):
                self.step_cache.get(self.mesh, self.group_config(gi, rung))
        if not self.cfg.offload_evicted:
            gids = self._budget_fitting_tail(gids)
        for gi in gids:
            with self.state_cache.lease(gi):
                pass

    def _budget_fitting_tail(self, gids: list[int]) -> list[int]:
        """Longest suffix of ``gids`` that fits the residency budget."""
        cap = self.cfg.max_resident_groups
        budget = self.cfg.device_budget_bytes
        keep: list[int] = []
        nbytes = 0
        for gi in reversed(gids):
            nb = self.group_config(gi).state_nbytes
            if cap is not None and len(keep) + 1 > cap:
                break
            if budget is not None and nbytes + nb > budget:
                break
            keep.append(gi)
            nbytes += nb
        return list(reversed(keep))

    def reset_stats(self) -> None:
        """Zero every per-group counter and the aggregate cache counters.

        Counters and latency histograms under the serving prefixes reset
        in the registry (the view objects in ``stats`` are unchanged);
        gauges — current state like resident bytes — are preserved.
        """
        self.metrics.reset("wlsh_group_")
        self.metrics.reset("wlsh_query_")
        self.state_cache.reset_stats()

    def stats_summary(self) -> dict[int, dict]:
        """Per-group summaries for groups that served at least one batch."""
        return {gi: s.summary() for gi, s in self.stats.items()
                if s.n_batches}

    def cache_summary(self) -> dict:
        """Aggregate state-paging report (counters + current residency).

        ``resident_bytes`` and ``budget_utilization`` ride in from
        ``CacheStats.summary()``.
        """
        return dict(
            **self.state_cache.stats.summary(),
            n_resident=self.state_cache.n_resident,
            n_groups=self.plan.n_groups,
            max_resident_groups=self.cfg.max_resident_groups,
            device_budget_bytes=self.cfg.device_budget_bytes,
        )

    def mean_occupancy(self) -> float:
        """Unweighted mean batch occupancy over groups that served traffic."""
        occs = [s.occupancy for s in self.stats.values() if s.n_batches]
        return float(np.mean(occs)) if occs else float("nan")

    # ------------------------------------------------------------- streaming

    @property
    def delta(self):
        """The streaming ``DeltaIndex``, or None before the first write."""
        return self._delta

    def delta_index(self):
        """Create on first use (and return) the streaming ``DeltaIndex``."""
        if self._delta is None:
            from .delta import DeltaIndex  # deferred: delta imports batching

            self._delta = DeltaIndex(self)
        return self._delta

    def insert(self, vector, weight_id) -> int:
        """Insert one vector into ``weight_id``'s group; returns its id."""
        return self.delta_index().insert(vector, weight_id)

    def delete(self, point_id: int) -> None:
        """Tombstone ``point_id``: it never appears in results again."""
        self.delta_index().delete(point_id)

    def compact(self, group: int | None = None, purge: bool = False) -> int:
        """Compact sealed delta segments into the main group state(s).

        Returns the number of rows absorbed (0 with nothing sealed or no
        streaming writes yet).  ``purge=True`` upgrades the sweep to a
        tombstone purge (see ``DeltaIndex.compact``): states rebuild over
        their surviving corpus, ``n_valid`` capacity is reclaimed, and
        the tombstone set is cleared.
        """
        if self._delta is None:
            return 0
        return self._delta.compact(group, purge=purge)

    def delta_summary(self) -> dict:
        """Aggregate streaming counters (empty dict before any write)."""
        return self._delta.summary() if self._delta is not None else {}

    # --------------------------------------------------------------- serving

    def route(self, weight_ids) -> np.ndarray:
        """(Q,) serving group per weight_id, validated against the plan."""
        weight_ids = np.atleast_1d(np.asarray(weight_ids, np.int64))
        if len(weight_ids) and (
            weight_ids.min() < 0 or weight_ids.max() >= self.plan.n_weights
        ):
            raise ValueError("weight_id out of range for the serving plan")
        return self.plan.group_of[weight_ids].astype(np.int32)

    def _encode(self, gi: int, cfg: IndexConfig, state, queries,
                take: np.ndarray) -> np.ndarray:
        """(q_batch, beta) codes for real ``queries`` padded via ``take``.

        Query and data codes must come from the same encoding: host f64
        only pairs with plan-shipped host codes; a device-built (f32)
        state needs device-encoded queries, or floor-boundary jitter
        mixes the two encodings and a query can miss its own point.
        Encoding is row-independent, so the host path encodes each real
        row once and gathers (no pad-duplicate work), while the device
        path encodes the padded batch to keep a fixed compiled shape.
        """
        g = self.plan.groups[gi]
        if self.cfg.host_encode and g.codes is not None:
            return pad_cols(g.encode_host(queries), cfg.beta)[take]
        return np.asarray(encode_queries(state, queries[take]))

    def run_batch(self, gi: int, queries, weight_ids, rung: int = 0,
                  spans=None):
        """One compiled-step launch for 1..q_batch same-group requests.

        Pads ragged input by cycling the real rows, encodes the padded
        batch (row-independent, so padding cannot perturb real rows), and
        returns ``(ids, dists, stop_levels, n_checked)`` sliced back to the
        real rows.  Both frontends answer every query through this method,
        which is what makes them bit-exact on identical traffic.

        ``rung`` serves the batch at a degradation rung of the (c, k)
        relaxation ladder: the same group state, a pre-compiled relaxed
        step, and answers padded ``-1``/``inf`` back to the strict ``k``
        so result shapes never change.  Rung 0 is the strict path and
        is bit-identical to the pre-QoS behavior.

        The group's state is leased from the ``StateCache`` around the
        launch: pinned (unevictable) while the compiled step runs, then
        released, so a budgeted cache can page any group between launches
        but never under one.

        ``spans`` is the frontend's per-row ``obs.TraceSpan`` list (one
        per real row, submission order): paging/launch/merge stages are
        stamped on them here.  With tracing on and no spans passed (a
        direct ``run_batch`` caller), spans are opened *and* resolved
        locally so every query still yields exactly one span.
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        weight_ids = np.atleast_1d(np.asarray(weight_ids, np.int64))
        cfg = self.group_config(gi, rung)
        step = self.step_cache.get(self.mesh, cfg)
        real = len(queries)
        take = pad_take(real, cfg.q_batch)
        g = self.plan.groups[gi]
        qtake = queries[take]
        wtake = weight_ids[take]
        slots = self.plan.member_slot[wtake]
        tr = self.tracer
        own_spans = tr is not None and spans is None
        if own_spans:
            t_sub = self.clock()
            spans = []
            for wid in weight_ids:
                s = tr.begin(weight_id=int(wid), group_id=int(gi))
                s.mark("submit", t_sub)
                s.mark("route", t_sub)
                s.mark("queue", t_sub)
                spans.append(s)
        if tr is not None:
            self._cache_events = []
        with self.state_cache.lease(gi) as state:
            if tr is not None and spans:
                # attribute this launch's paging work: a consumed
                # prefetch marks "prefetch", a blocking restore/build
                # marks "restore" (a plain hit marks neither)
                t_acq = self.clock()
                kinds = set(self._cache_events or ())
                for s in spans:
                    if "restore_overlapped" in kinds:
                        s.mark("prefetch", t_acq)
                    if kinds & {"restore", "build"}:
                        s.mark("restore", t_acq)
            codes = self._encode(
                gi, cfg, state, queries, take
            ).astype(np.int32)
            if tr is not None and spans:
                t_launch = self.clock()
                for s in spans:
                    s.mark("launch", t_launch)
            dispatch_scope = (
                self.profiler.dispatch(str(cfg.shape_signature()))
                if self.profiler is not None else _NULL_SCOPE
            )
            with dispatch_scope:
                d_b, i_b, stop_b, chk_b = step(
                    state,
                    jnp.asarray(qtake),
                    jnp.asarray(codes),
                    jnp.asarray(
                        self.plan.weights[wtake].astype(np.float32)
                    ),
                    jnp.asarray(g.mu_members[slots].astype(np.int32)),
                    jnp.asarray(g.r_min_members[slots].astype(np.float32)),
                    jnp.asarray(g.beta_members[slots].astype(np.int32)),
                    jnp.asarray(
                        g.n_levels_members[slots].astype(np.int32)
                    ),
                )
                # materialize before releasing the lease: the state must
                # stay resident until the device has finished reading it
                ids = np.asarray(i_b)[:real]
                dists = np.asarray(d_b)[:real]
                stop = np.asarray(stop_b)[:real]
                chk = np.asarray(chk_b)[:real]
        if cfg.k < self.cfg.k:
            # degraded rung: pad the short top-k back to the strict width
            # (missing-slot conventions, so downstream merge/augment and
            # every result consumer see one uniform shape)
            pad_ids = np.full((real, self.cfg.k), -1, ids.dtype)
            pad_d = np.full((real, self.cfg.k), np.inf, dists.dtype)
            pad_ids[:, : cfg.k] = ids
            pad_d[:, : cfg.k] = dists
            ids, dists = pad_ids, pad_d
        if self._delta is not None:
            # translate appended state rows to global ids, merge the exact
            # delta-scan hits, filter tombstones (no-op passthrough for a
            # group with nothing pending — the parity guarantee)
            ids, dists = self._delta.augment(
                gi, queries, weight_ids, ids, dists
            )
        m = self.metrics
        m.counter("wlsh_group_batches_total",
                  "compiled-step launches").inc(group=gi)
        m.counter("wlsh_group_queries_total",
                  "real rows served").inc(real, group=gi)
        m.counter("wlsh_group_padded_rows_total",
                  "padding rows across ragged batches").inc(
            cfg.q_batch - real, group=gi)
        m.counter("wlsh_group_stop_levels_total",
                  "summed histogram stop levels").inc(
            int(np.sum(stop)), group=gi)
        m.counter("wlsh_group_checked_total",
                  "summed candidates verified (n_checked)").inc(
            int(np.sum(chk)), group=gi)
        if tr is not None and spans:
            self._cache_events = None
            t_merge = self.clock()
            budget = int(cfg.budget)
            for i, s in enumerate(spans):
                s.mark("merge", t_merge)
                s.group_id = int(gi)
                s.rung = int(rung)
                s.n_shards = int(self.mesh.size)
                s.stop_level = int(stop[i])
                s.n_checked = int(chk[i])
                s.budget = budget
                s.budget_capped = bool(int(chk[i]) >= budget)
                if own_spans:
                    s.mark("resolve", t_merge)
                    tr.finish(s)
            if self.recall is not None:
                # shadow-sample by deterministic hash of the span's query
                # id: enqueue only (host copies) — the answer arrays are
                # returned untouched, so sampling is bit-invisible
                for i, s in enumerate(spans):
                    self.recall.offer(
                        s, queries[i], int(weight_ids[i]), int(gi),
                        int(rung), ids[i]
                    )
        return ids, dists, stop, chk
