"""Deadline-aware asynchronous frontend over the shared batching core.

The synchronous ``RetrievalService`` only fills a compiled batch when a
full ``q_batch`` of same-group traffic arrives in one call — under
open-loop streaming traffic (each request submitted alone as it arrives)
every launch pads ``q_batch - 1`` dead rows and occupancy collapses to
``1/q_batch`` (serve_bench sweep 2).  This module trades a bounded wait
for occupancy:

  submit    each (query, weight_id[, deadline]) enters its group's
            pending buffer and gets a ``QueryFuture``
  fill      a buffer reaching q_batch launches immediately
  deadline  ``poll()`` launches any group whose oldest pending request
            has expired (default budget ``ServiceConfig.max_delay_ms``)
  drain     flushes everything regardless of deadline (shutdown / end of
            trace)

Launches go through ``Batcher.run_batch`` — the same padding, encoding
and compiled-step path as the sync frontend — so the two are bit-exact
on identical traffic, and ``QueryStepCache`` compiles nothing new when
an async frontend is layered over a warmed sync service.  Futures
resolve in submission order within each launch.

The clock is injectable: real deployments use ``time.monotonic`` (the
default), while tests and open-loop trace replay (``replay_open_loop``)
drive a deterministic ``ManualClock`` so deadline behaviour is exact and
repeatable.
"""

from __future__ import annotations

import collections
import dataclasses
import time

import numpy as np

from .batching import Batcher
from .qos import DEFAULT_TENANT, QosScheduler
from .retrieval import RetrievalResult, RetrievalService

__all__ = [
    "AsyncRetrievalService",
    "ManualClock",
    "Overloaded",
    "QueryAnswer",
    "QueryFuture",
    "replay_open_loop",
]


class Overloaded(RuntimeError):
    """Backpressure: a group's pending buffer is at ``max_pending``.

    Raised by ``AsyncRetrievalService.submit`` *before* the request is
    enqueued (the caller holds no future and has lost nothing).  Carries
    the observed depth so callers can shed load or back off:

    * ``group_id`` — the group whose buffer is full
    * ``depth`` — its pending depth at rejection time
    * ``max_pending`` — the configured ``ServiceConfig.max_pending`` cap
    """

    def __init__(self, group_id: int, depth: int, max_pending: int):
        super().__init__(
            f"group {group_id} pending buffer is full "
            f"({depth}/{max_pending}); poll() or drain() frees it"
        )
        self.group_id = int(group_id)
        self.depth = int(depth)
        self.max_pending = int(max_pending)


class ManualClock:
    """Deterministic monotonic clock for tests and trace replay."""

    def __init__(self, t: float = 0.0):
        self.t = float(t)

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> float:
        """Move the clock forward by ``dt`` seconds (dt < 0 raises)."""
        if dt < 0:
            raise ValueError(f"clock must not run backwards (dt={dt})")
        self.t += dt
        return self.t

    def advance_to(self, t: float) -> float:
        """Jump the clock to absolute time ``t`` (going backwards raises)."""
        if t < self.t:
            raise ValueError(f"clock must not run backwards ({t} < {self.t})")
        self.t = float(t)
        return self.t


@dataclasses.dataclass(frozen=True)
class QueryAnswer:
    """One query's answer (the async counterpart of a RetrievalResult row)."""

    ids: np.ndarray  # (k,) int32, -1 = missing
    dists: np.ndarray  # (k,) f32, +inf = missing
    group_id: int
    stop_level: int
    n_checked: int


class QueryFuture:
    """Handle for one submitted query, resolved when its batch launches."""

    __slots__ = ("_answer", "_done", "t_resolved")

    def __init__(self):
        self._answer = None
        self._done = False
        self.t_resolved: float | None = None  # clock time of the launch

    def done(self) -> bool:
        """Whether the query's batch has launched and the answer is set."""
        return self._done

    def result(self) -> QueryAnswer:
        """The resolved ``QueryAnswer`` (raises while still pending)."""
        if not self._done:
            raise RuntimeError(
                "query still pending — its batch has not launched yet "
                "(advance the clock past the deadline and poll(), or drain())"
            )
        return self._answer

    def _resolve(self, answer: QueryAnswer, now: float) -> None:
        self._answer = answer
        self._done = True
        self.t_resolved = now


@dataclasses.dataclass(eq=False)  # identity semantics: requests may repeat
class _Pending:
    query: np.ndarray
    weight_id: int
    deadline: float
    t_submit: float
    future: QueryFuture
    tenant: str = DEFAULT_TENANT
    span: object = None  # obs.TraceSpan when tracing is enabled


class AsyncRetrievalService:
    """Deadline-aware streaming frontend: fill-or-deadline batch launches.

    Wraps an existing ``RetrievalService`` (or its ``Batcher``) so group
    states, serving stats and the compiled-step cache are shared across
    frontends.  ``max_delay_ms`` overrides ``ServiceConfig.max_delay_ms``
    as the default per-request deadline budget; an explicit ``deadline``
    (absolute clock time) on ``submit`` overrides both.

    Single-threaded by design: launches happen inside ``submit`` (batch
    full), ``poll`` (deadline expired) and ``drain``.  A real-time caller
    polls on its event loop at ``next_deadline()``; trace replay drives a
    ``ManualClock`` through the same code path.

    Every launch leases its group's state from the shared ``StateCache``
    (pinned only while the compiled step runs), so under a residency
    budget a burst of deadline-driven partial launches pages states
    between launches — never under one — and answers stay bit-exact.
    """

    def __init__(
        self,
        service: RetrievalService | Batcher,
        max_delay_ms: float | None = None,
        clock=time.monotonic,
        compact_on_idle: bool = True,
        qos: QosScheduler | None = None,
    ):
        self.batcher = (
            service.batcher if isinstance(service, RetrievalService)
            else service
        )
        if max_delay_ms is None:
            max_delay_ms = self.batcher.cfg.max_delay_ms
        if not (max_delay_ms >= 0):  # also rejects NaN
            raise ValueError(f"max_delay_ms must be >= 0, got {max_delay_ms}")
        self.max_delay_ms = float(max_delay_ms)
        self.clock = clock
        # the batcher stamps launch-side trace-span stages on the same
        # clock, so ManualClock replays produce deterministic traces
        self.batcher.clock = clock
        # multi-tenant QoS: admission control + per-class SLO deadlines
        # on submit, weighted-fair capacity-bounded dequeue on poll, and
        # (driver-stepped) (c, k) degradation under sustained overload.
        # None = single-tenant service, bit-identical to the pre-QoS path
        self.qos = qos
        if qos is not None:
            # fold the scheduler's standalone counters into the serving
            # stack's unified registry: one source of truth per stack
            qos.bind_metrics(self.batcher.metrics)
        # background compaction: an idle poll (nothing expired to launch)
        # absorbs the streaming delta's *sealed* backlog into the main
        # group states, capacity permitting — the single-threaded analog
        # of a background compaction thread
        self.compact_on_idle = bool(compact_on_idle)
        # a scheduler.ServiceDriver that has taken ownership of idle-time
        # work (background compaction) and wants submit wake-ups; None =
        # undriven (poll() keeps compacting on idle ticks itself)
        self.driver = None
        # pending buffers keyed (group_id, tenant): one tenant's queries
        # never share a launch with another's, so a degraded tenant's
        # relaxed step cannot touch a strict tenant's answers.  The
        # default tenant keeps the pre-QoS one-buffer-per-group layout
        self._pending: dict[
            tuple[int, str], collections.deque[_Pending]
        ] = collections.defaultdict(collections.deque)
        # launch-cause counters (visible to tests and serve_bench)
        self.n_launched_full = 0
        self.n_launched_deadline = 0
        self.n_launched_drain = 0

    # ------------------------------------------------------------- inspection

    @property
    def pending_count(self) -> int:
        """Total queued requests across every group's pending buffer."""
        return sum(len(q) for q in self._pending.values())

    def next_deadline(self) -> float | None:
        """Earliest pending deadline across groups (None = nothing pending)."""
        deadlines = [
            min(r.deadline for r in q)
            for q in self._pending.values() if q
        ]
        return min(deadlines) if deadlines else None

    def pending_depths(self) -> dict[int, tuple[int, float]]:
        """Per-group ``(depth, oldest_deadline)`` over non-empty buffers.

        The scheduler's view of the pending schedule: a deadline is a
        launch time, so the prefetch policy reads this to decide which
        group states to bring on device ahead of their launches.
        Per-tenant buffers aggregate to their group here — prefetch
        cares which *state* is about to launch, not for whom.
        """
        out: dict[int, tuple[int, float]] = {}
        for (gi, _tenant), q in self._pending.items():
            if not q:
                continue
            oldest = min(r.deadline for r in q)
            depth, prev = out.get(gi, (0, oldest))
            out[gi] = (depth + len(q), min(prev, oldest))
        return out

    def pending_tenant_depths(self) -> dict[tuple[int, str],
                                            tuple[int, float]]:
        """Per-``(group, tenant)`` ``(depth, oldest_deadline)`` snapshot.

        The fair queue's view: what ``QosScheduler.plan_launches``
        orders by deadline and serves by deficit round robin.
        """
        return {
            key: (len(q), min(r.deadline for r in q))
            for key, q in self._pending.items() if q
        }

    # ---------------------------------------------------------------- serving

    def submit(self, query, weight_id, deadline: float | None = None,
               tenant: str | None = None) -> QueryFuture:
        """Enqueue one request; launches its group's batch if now full.

        ``tenant`` names the submitting tenant class.  With a
        ``QosScheduler`` attached, the tenant must be registered
        (``KeyError`` otherwise), the submit is admission-controlled
        (typed ``RateLimited`` *before* enqueueing when the class's
        token bucket is empty), and a missing explicit ``deadline``
        takes the class's SLO budget instead of ``max_delay_ms``.
        Backpressure (``Overloaded``) is checked against the group's
        total pending depth across tenants, before any token is spent —
        a rejected caller never consumes admission budget.
        """
        now = self.clock()
        if tenant is None:
            tenant = DEFAULT_TENANT
        query = np.asarray(query, np.float32).reshape(-1)
        if query.shape != (self.batcher.plan.d,):
            raise ValueError(
                f"query must be a single ({self.batcher.plan.d},) vector, "
                f"got shape {query.shape}"
            )
        gi = int(self.batcher.route(weight_id)[0])
        max_pending = self.batcher.cfg.max_pending
        if max_pending is not None:
            depth = sum(
                len(q) for (g, _t), q in self._pending.items() if g == gi
            )
            if depth >= max_pending:
                # reject before enqueueing: the caller holds no future,
                # the buffer stays bounded, poll()/drain() frees capacity
                raise Overloaded(gi, depth, max_pending)
        if self.qos is not None:
            # admission last among the reject paths: a raise after the
            # token was spent would leak admission budget
            self.qos.admit(tenant, now)
        if deadline is None:
            if self.qos is not None:
                deadline = self.qos.deadline_for(
                    tenant, now, self.max_delay_ms / 1e3
                )
            else:
                deadline = now + self.max_delay_ms / 1e3
        elif not np.isfinite(deadline):
            # a NaN/inf deadline would never compare expired in poll() and
            # would poison next_deadline() for every event-loop driver
            raise ValueError(f"deadline must be finite, got {deadline}")
        tr = self.batcher.tracer
        span = None
        if tr is not None:
            # past every reject path: an Overloaded / RateLimited /
            # invalid submit never opens a span, so exactly one span
            # exists per accepted query
            span = tr.begin(weight_id=int(weight_id), group_id=gi,
                            tenant=str(tenant))
            t_routed = self.clock()
            span.mark("submit", now)
            span.mark("route", t_routed)
            if self.qos is not None:
                span.mark("admit", t_routed)
            span.mark("queue", t_routed)
        fut = QueryFuture()
        pend = _Pending(query, int(weight_id), float(deadline), now, fut,
                        str(tenant), span)
        q = self._pending[(gi, str(tenant))]
        q.append(pend)
        # with QoS attached, a full buffer launches at the next poll tick
        # instead of inside submit: *every* launch then flows through the
        # weighted-fair queue under the capacity, so no tenant can buy
        # extra capacity by bursting a buffer full
        if len(q) >= self.batcher.cfg.q_batch and self.qos is None:
            try:
                self._launch((gi, str(tenant)), "full")
            except Exception:
                # submit is atomic too: the caller never receives ``fut`` on
                # a raise, so withdraw their request (it is the newest, put
                # back last by the launch rollback) — a retry re-submits it,
                # while earlier requests stay queued with live futures
                if q and q[-1] is pend:
                    q.pop()
                raise
        if self.driver is not None:
            self.driver.notify_submit()  # wake a sleeping driver thread
        return fut

    def poll(self, now: float | None = None) -> int:
        """Launch every group whose oldest pending deadline has expired.

        Returns the number of batches launched.  An idle poll (nothing
        launched) additionally compacts the streaming delta's sealed
        backlog when ``compact_on_idle`` is set — background compaction
        rides the event loop's quiet ticks, never delaying a launch.
        With a ``scheduler.ServiceDriver`` attached, idle-time work is
        the driver's (its ticks call ``idle_work`` themselves), so an
        undriven ``poll`` no longer compacts.

        With a ``QosScheduler`` attached, launchable buffers (oldest
        deadline expired *or* filled to ``q_batch`` — submit defers full
        launches to the tick under QoS) instead go through
        ``QosScheduler.plan_launches``: deadline-ordered, served
        weighted-fair by deficit round robin under the scheduler's
        per-tick capacity.  Deferred launchable buffers register
        overload pressure; a tick with nothing launchable registers a
        clear tick, so the degradation hysteresis sees both.
        """
        if now is None:
            now = self.clock()
        n = 0
        if self.qos is None:
            for key in list(self._pending):
                q = self._pending[key]
                if q and min(r.deadline for r in q) <= now:
                    self._launch(key, "deadline")
                    n += 1
        else:
            qb = self.batcher.cfg.q_batch
            launchable = [
                (min(r.deadline for r in q), key[0], key[1])
                for key, q in self._pending.items()
                if q and (min(r.deadline for r in q) <= now
                          or len(q) >= qb)
            ]
            if launchable:
                for gi, tenant in self.qos.plan_launches(launchable, now):
                    key = (gi, tenant)
                    cause = (
                        "full" if len(self._pending[key]) >= qb
                        else "deadline"
                    )
                    self._launch(key, cause)
                    n += 1
            else:
                self.qos.note_idle_tick()
        if n == 0 and self.driver is None:
            self.idle_work()
        return n

    def idle_work(self) -> int:
        """One slice of idle-time background work, returning rows compacted.

        Compacts the streaming delta's *sealed* backlog when
        ``compact_on_idle`` is set, returning the rows absorbed.  Called
        by an undriven idle ``poll()``, or by the ``ServiceDriver``'s
        idle ticks once one owns the service.  A tick with nothing to
        compact instead executes one bounded slice of the shadow recall
        queue (``ServiceConfig.recall_shadow_slice`` oracle re-ranks) —
        quality telemetry rides the quiet ticks, never a launch.
        """
        n = 0
        if self.compact_on_idle and self.batcher.delta is not None:
            n = self.batcher.delta.compact_sealed()
        recall = self.batcher.recall
        if n == 0 and recall is not None and recall.backlog:
            recall.run(max_jobs=recall.slice)
        return n

    # ------------------------------------------------------------- streaming

    def insert(self, vector, weight_id) -> int:
        """Insert one vector into ``weight_id``'s group (applied at once).

        Writes are synchronous even on the async frontend: the row is in
        its group's delta memtable — and visible to queries — when this
        returns.  Returns the assigned global point id.
        """
        return self.batcher.insert(vector, weight_id)

    def delete(self, point_id: int) -> None:
        """Tombstone a global point id; it never appears in results again."""
        self.batcher.delete(point_id)

    def compact(self, group: int | None = None, purge: bool = False) -> int:
        """Flush and compact delta segments (see ``Batcher.compact``).

        ``purge=True`` runs the tombstone-purging rebuild.
        """
        return self.batcher.compact(group, purge=purge)

    def drain(self) -> int:
        """Flush all pending buffers regardless of deadline."""
        n = 0
        for key in list(self._pending):
            while self._pending[key]:
                self._launch(key, "drain")
                n += 1
        return n

    def _launch(self, key: tuple[int, str], cause: str) -> None:
        gi, tenant = key
        q = self._pending[key]
        qb = self.batcher.cfg.q_batch
        batch = [q.popleft() for _ in range(min(qb, len(q)))]
        # the tenant's current degradation rung picks which pre-compiled
        # (c, k) step serves this launch; rung 0 (and qos=None) is the
        # strict configured parameters
        rung = self.qos.rung_of(tenant) if self.qos is not None else 0
        tr = self.batcher.tracer
        try:
            ids, dists, stop, chk = self.batcher.run_batch(
                gi,
                np.stack([r.query for r in batch]),
                np.array([r.weight_id for r in batch], np.int64),
                rung=rung,
                spans=(
                    [r.span for r in batch] if tr is not None else None
                ),
            )
        except Exception:
            # atomic launch: put the batch back (original order, ahead of
            # anything newer) so a caller that retries after a device error
            # has lost nothing and no future is stranded unresolvable
            q.extendleft(reversed(batch))
            raise
        if cause == "full":
            self.n_launched_full += 1
        elif cause == "deadline":
            self.n_launched_deadline += 1
        else:
            self.n_launched_drain += 1
        now = self.clock()
        wait_h = self.batcher.metrics.histogram(
            "wlsh_query_wait_seconds",
            "submit-to-resolve wait on the service clock",
        )
        for i, r in enumerate(batch):  # submission order within the launch
            r.future._resolve(QueryAnswer(
                ids=ids[i], dists=dists[i], group_id=gi,
                stop_level=int(stop[i]), n_checked=int(chk[i]),
            ), now)
            wait_h.observe(now - r.t_submit)
            if r.span is not None:
                r.span.cause = cause
                r.span.mark("resolve", now)
                tr.finish(r.span)
            if self.qos is not None:
                self.qos.on_resolved(
                    r.tenant, now - r.t_submit, now > r.deadline, rung
                )


def _replay(svc: AsyncRetrievalService, queries, weight_ids, arrivals,
            tick, tick_at_arrivals: bool = False, tenants=None):
    """Shared open-loop replay core (``replay_open_loop`` and the
    scheduler's ``replay_with_driver`` parameterize only the tick).

    ``tick`` fires expired deadlines (``poll`` undriven,
    ``ServiceDriver.step`` driven); ``tick_at_arrivals`` additionally
    ticks at every arrival instant — those ticks never launch anything
    (no deadline has newly expired there), they only give a driver's
    prefetch policy its lead time, so both parameterizations stay
    bit-exact on the same trace by construction.  ``tenants`` optionally
    names the submitting tenant per request (multi-tenant QoS traces);
    admission rejections (``RateLimited``) propagate to the caller.
    """
    if not isinstance(svc.clock, ManualClock):
        raise TypeError("open-loop replay requires a ManualClock service")
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    weight_ids = np.atleast_1d(np.asarray(weight_ids, np.int64))
    arrivals = np.atleast_1d(np.asarray(arrivals, np.float64))
    nq = len(queries)
    if not (len(weight_ids) == len(arrivals) == nq):
        raise ValueError("queries / weight_ids / arrivals length mismatch")
    if tenants is not None and len(tenants) != nq:
        raise ValueError("tenants length must match queries")
    if np.any(np.diff(arrivals) < 0):
        raise ValueError("arrivals must be non-decreasing")
    k = svc.batcher.cfg.k
    if nq == 0:  # degenerate trace: agree with the sync frontend
        return RetrievalResult(
            ids=np.empty((0, k), np.int32),
            dists=np.empty((0, k), np.float32),
            group_ids=np.empty(0, np.int32),
            stop_levels=np.empty(0, np.int32),
            n_checked=np.empty(0, np.int32),
        ), np.empty(0)

    def fire(nd: float) -> None:
        # a QoS capacity can defer expired work, so nd may already be in
        # the past — hold time still and tick again (each tick grants a
        # fresh fair-queue budget).  A tick that then launches nothing is
        # a permanent stall (capacity below the cheapest launch cost):
        # fail loudly instead of spinning forever
        svc.clock.advance_to(max(nd, svc.clock()))
        before = svc.pending_count
        tick()
        if svc.pending_count == before and svc.next_deadline() == nd:
            raise RuntimeError(
                "replay stalled: an expired launch never fires — is "
                "qos capacity_per_tick below the cheapest launch cost?"
            )

    futs: list[QueryFuture] = []
    for i in range(nq):
        while True:  # fire deadlines that expire before this arrival
            nd = svc.next_deadline()
            if nd is None or nd > arrivals[i]:
                break
            fire(nd)
        svc.clock.advance_to(arrivals[i])
        if tick_at_arrivals:
            tick()
        tenant = None if tenants is None else tenants[i]
        futs.append(svc.submit(queries[i], weight_ids[i], tenant=tenant))
    while svc.pending_count:  # run out the tail
        fire(svc.next_deadline())

    answers = [f.result() for f in futs]
    t_resolved = np.array([f.t_resolved for f in futs])
    res = RetrievalResult(
        ids=np.stack([a.ids for a in answers]).astype(np.int32),
        dists=np.stack([a.dists for a in answers]).astype(np.float32),
        group_ids=np.array([a.group_id for a in answers], np.int32),
        stop_levels=np.array([a.stop_level for a in answers], np.int32),
        n_checked=np.array([a.n_checked for a in answers], np.int32),
    )
    assert res.ids.shape == (nq, k)
    return res, t_resolved - arrivals


def replay_open_loop(svc: AsyncRetrievalService, queries, weight_ids,
                     arrivals, tenants=None):
    """Open-loop trace replay on a ManualClock (virtual time).

    ``arrivals`` are absolute non-decreasing virtual times, one per query;
    each request is submitted alone at its arrival (the open-loop regime
    serve_bench sweep 2 penalizes), with the clock jumping to every
    deadline that expires between arrivals.  Device compute is off-clock:
    waits measure pure batching delay, which is what the deadline knob
    trades against occupancy.

    Returns ``(RetrievalResult, waits)`` in submission order, where
    ``waits[i]`` is the virtual seconds request ``i`` spent queued before
    its batch launched.
    """
    return _replay(svc, queries, weight_ids, arrivals, tick=svc.poll,
                   tenants=tenants)
