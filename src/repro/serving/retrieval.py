"""Multi-group WLSH retrieval service (the paper's Search, multi-tenant).

The host planner partitions the weight vector set S into table groups
(Algorithm 1); every incoming query carries a ``weight_id`` naming its
distance function, and must be answered in *that* weight's group
(Algorithm 2).  Serving splits into a shared core and two frontends:

  * ``batching.Batcher`` — the frontend-independent core: route each
    (query, weight_id) to ``plan.group_of[weight_id]``, pad ragged
    batches by cycling real rows, launch one compiled query step per
    *shape signature* (groups quantized onto beta/level buckets share a
    step through ``QueryStepCache``), and keep per-group serving stats.
  * ``RetrievalService`` (this module) — the synchronous frontend: all
    queries of a call are present up front, so they are coalesced into
    maximal same-group batches and answered in submission order.
  * ``async_service.AsyncRetrievalService`` — the asynchronous frontend:
    individual submissions accumulate in per-group pending buffers and a
    batch launches when it fills *or* the oldest request's deadline
    (``ServiceConfig.max_delay_ms``) expires.

Both frontends answer every query through ``Batcher.run_batch``, so they
are bit-exact with each other — and with ``WLSHIndex.search_dense`` —
on identical traffic.  Query bucket codes are computed host-side in
float64 against the exported family when the plan ships host codes.
Per-group serving stats (batch occupancy, stop-level / n_checked
distributions) feed the serving benchmarks.
"""

from __future__ import annotations

import dataclasses

import numpy as np

from ..core.serving_plan import ServingPlan
from .batching import (
    Batcher,
    GroupServeStats,
    ServiceConfig,
    coalesce,
    run_plans,
)

__all__ = [
    "GroupServeStats",
    "RetrievalResult",
    "RetrievalService",
    "ServiceConfig",
]


@dataclasses.dataclass
class RetrievalResult:
    """Per-query answers, in submission order."""

    ids: np.ndarray  # (Q, k) int32, -1 = missing
    dists: np.ndarray  # (Q, k) f32, +inf = missing
    group_ids: np.ndarray  # (Q,) int32 serving group per query
    stop_levels: np.ndarray  # (Q,) int32
    n_checked: np.ndarray  # (Q,) int32


class RetrievalService:
    """Synchronous weight-routed frontend over the shared ``Batcher`` core.

    States and compiled steps are built lazily per group (call ``warmup``
    to front-load); ``step_cache.n_compiled`` counts distinct compiled
    shape signatures, which stays far below the group count on real plans.
    Under ``ServiceConfig.max_resident_groups`` / ``device_budget_bytes``
    the per-group device states are additionally paged by a ``StateCache``
    (LRU eviction + host offload), bit-exactly.  Pass the service (or its
    ``batcher``) to ``AsyncRetrievalService`` to serve streaming traffic
    over the same states, stats and step cache.
    """

    def __init__(
        self,
        plan: ServingPlan,
        points: np.ndarray,
        mesh=None,
        cfg: ServiceConfig = ServiceConfig(),
    ):
        self.batcher = Batcher(plan, points, mesh=mesh, cfg=cfg)

    # ------------------------------------------------- shared-core delegation

    @property
    def plan(self) -> ServingPlan:
        """The ServingPlan this service answers under."""
        return self.batcher.plan

    @property
    def points(self) -> np.ndarray:
        """The (n, d) host corpus the group states are built from."""
        return self.batcher.points

    @property
    def mesh(self):
        """The device mesh group states and compiled steps live on."""
        return self.batcher.mesh

    @property
    def cfg(self) -> ServiceConfig:
        """Serving-side configuration (shared with the batching core)."""
        return self.batcher.cfg

    @property
    def step_cache(self):
        """Compiled-step cache, shared across groups and frontends."""
        return self.batcher.step_cache

    @property
    def state_cache(self):
        """Budgeted per-group device-state cache (see ``StateCache``)."""
        return self.batcher.state_cache

    @property
    def stats(self) -> dict[int, GroupServeStats]:
        """Per-group serving counters, keyed by group id."""
        return self.batcher.stats

    def group_config(self, gi: int):
        """Padded IndexConfig for group ``gi`` (the jit-cache key)."""
        return self.batcher.group_config(gi)

    def warmup(self, groups=None) -> None:
        """Build states and compile steps ahead of traffic."""
        self.batcher.warmup(groups)

    def reset_stats(self) -> None:
        """Zero the per-group serving counters and cache counters."""
        self.batcher.reset_stats()

    def stats_summary(self) -> dict[int, dict]:
        """Per-group summaries for groups that served at least one batch."""
        return self.batcher.stats_summary()

    def cache_summary(self) -> dict:
        """Aggregate state-paging report (counters + current residency)."""
        return self.batcher.cache_summary()

    def mean_occupancy(self) -> float:
        """Unweighted mean batch occupancy over groups that served traffic."""
        return self.batcher.mean_occupancy()

    # ------------------------------------------------------------- streaming

    def insert(self, vector, weight_id) -> int:
        """Insert one vector into ``weight_id``'s table group.

        Returns the assigned global point id.  The row is queryable
        immediately (exact delta scan) and is absorbed into the group's
        compiled state by a later compaction.  Requires
        ``ServiceConfig.delta_reserve_rows`` capacity for that compaction
        to have somewhere to append.
        """
        return self.batcher.insert(vector, weight_id)

    def delete(self, point_id: int) -> None:
        """Tombstone a global point id; it never appears in results again."""
        self.batcher.delete(point_id)

    def compact(self, group: int | None = None, purge: bool = False) -> int:
        """Flush and compact delta segments into the main group state(s).

        Returns the number of rows absorbed.  Only the compacted groups'
        cached states are invalidated (at a bumped version); compiled
        query steps are untouched.  ``purge=True`` additionally drops
        every tombstoned row from the rebuilt states, reclaims their
        ``n_valid`` capacity and clears the tombstone set.
        """
        return self.batcher.compact(group, purge=purge)

    def delta_summary(self) -> dict:
        """Streaming counters (inserts/seals/compactions/tombstones)."""
        return self.batcher.delta_summary()

    # --------------------------------------------------------------- serving

    def query(self, queries: np.ndarray, weight_ids) -> RetrievalResult:
        """Answer a mixed batch of (query, weight_id) requests.

        Queries are grouped by serving group, coalesced into q_batch-sized
        sub-batches, and results are returned in submission order.
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        weight_ids = np.atleast_1d(np.asarray(weight_ids, np.int64))
        if len(weight_ids) != len(queries):
            raise ValueError("queries and weight_ids length mismatch")
        gids = self.batcher.route(weight_ids)
        tr = self.batcher.tracer
        spans = None
        if tr is not None:
            # one span per submitted query; the whole call is one
            # synchronous submit/route/queue instant on the clock
            t_sub = self.batcher.clock()
            spans = []
            for wid, gi in zip(weight_ids, gids):
                s = tr.begin(weight_id=int(wid), group_id=int(gi))
                s.mark("submit", t_sub)
                s.mark("route", t_sub)
                s.mark("queue", t_sub)
                spans.append(s)
        out_ids, out_d, out_stop, out_chk = run_plans(
            coalesce(gids, self.cfg.q_batch),
            queries,
            weight_ids,
            self.batcher.run_batch,
            self.cfg.k,
            spans=spans,
        )
        if tr is not None:
            t_res = self.batcher.clock()
            for s in spans:
                s.mark("resolve", t_res)
                tr.finish(s)
        return RetrievalResult(
            ids=out_ids,
            dists=out_d,
            group_ids=gids,
            stop_levels=out_stop,
            n_checked=out_chk,
        )
