"""Multi-group WLSH retrieval service (the paper's Search, multi-tenant).

The host planner partitions the weight vector set S into table groups
(Algorithm 1); every incoming query carries a ``weight_id`` naming its
distance function, and must be answered in *that* weight's group
(Algorithm 2).  This module is the serving layer between the two:

  route     each (query, weight_id) -> plan.group_of[weight_id]
  coalesce  same-group queries into fixed-shape batches (the sharded step
            already supports per-query mu / r_min / beta_q / levels_q, so
            queries under different member weights share a batch)
  pad       ragged tail batches by repeating a real row, masked on output
  execute   one compiled query step per *shape signature*, not per group:
            group shapes quantize onto beta/level buckets (config.pad_beta
            / pad_levels) and equal IndexConfigs share a step through
            QueryStepCache
  merge     per-query results back into submission order

Query bucket codes are computed host-side in float64 against the exported
family — bit-exact with the planner's table codes when the plan ships them —
so the service's candidate sets match `WLSHIndex.search_dense` per query.
Per-group serving stats (batch occupancy, stop-level / n_checked
distributions) feed the serving benchmarks.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..core.serving_plan import ServingPlan
from ..index.builder import build_group_state, pad_cols
from ..index.config import IndexConfig, pad_beta, pad_levels
from ..index.engine import QueryStepCache, encode_queries

__all__ = [
    "GroupServeStats",
    "RetrievalResult",
    "RetrievalService",
    "ServiceConfig",
]


@dataclasses.dataclass(frozen=True)
class ServiceConfig:
    """Serving-side knobs (plan parameters come from the ServingPlan)."""

    k: int = 10
    q_batch: int = 8  # compiled batch shape; ragged tails are padded
    block_n: int | None = None  # points per scan block; None = whole shard
    vec_dtype: str = "float32"
    use_pallas: bool | None = None  # None = auto (TPU only)
    beta_buckets: tuple[int, ...] | None = None  # None = config.pad_beta
    level_step: int = 4  # level-loop bound rounding (config.pad_levels)
    budget_override: int | None = None  # None = k + ceil(gamma * n)
    host_encode: bool = True  # f64 query codes (exact vs planner); False =
    # device f32 encode (standalone engines without exported codes)


@dataclasses.dataclass
class GroupServeStats:
    """Per-group serving counters (reset with RetrievalService.reset_stats).

    Running sums, not samples: a long-lived service must not grow state
    with traffic.
    """

    n_queries: int = 0
    n_batches: int = 0
    n_padded: int = 0  # padded rows across ragged batches
    stop_level_sum: int = 0
    n_checked_sum: int = 0

    @property
    def occupancy(self) -> float:
        filled = self.n_queries + self.n_padded
        return self.n_queries / filled if filled else 0.0

    def summary(self) -> dict:
        nq = self.n_queries
        return dict(
            n_queries=nq,
            n_batches=self.n_batches,
            occupancy=self.occupancy,
            mean_stop_level=self.stop_level_sum / nq if nq else float("nan"),
            mean_n_checked=self.n_checked_sum / nq if nq else float("nan"),
        )


@dataclasses.dataclass
class RetrievalResult:
    """Per-query answers, in submission order."""

    ids: np.ndarray  # (Q, k) int32, -1 = missing
    dists: np.ndarray  # (Q, k) f32, +inf = missing
    group_ids: np.ndarray  # (Q,) int32 serving group per query
    stop_levels: np.ndarray  # (Q,) int32
    n_checked: np.ndarray  # (Q,) int32


class RetrievalService:
    """Weight-routed serving front end over the sharded group engine.

    States and compiled steps are built lazily per group (call ``warmup``
    to front-load); ``step_cache.n_compiled`` counts distinct compiled
    shape signatures, which stays far below the group count on real plans.
    """

    def __init__(
        self,
        plan: ServingPlan,
        points: np.ndarray,
        mesh=None,
        cfg: ServiceConfig = ServiceConfig(),
    ):
        points = np.ascontiguousarray(points, dtype=np.float32)
        if points.shape != (plan.n, plan.d):
            raise ValueError(
                f"points shape {points.shape} != plan ({plan.n}, {plan.d})"
            )
        self.plan = plan
        self.points = points
        self.mesh = mesh if mesh is not None else jax.make_mesh(
            (1, 1), ("data", "model")
        )
        self.cfg = cfg
        self.step_cache = QueryStepCache()
        self._group_cfgs: dict[int, IndexConfig] = {}
        self._states: dict[int, object] = {}
        self.stats: dict[int, GroupServeStats] = {
            gi: GroupServeStats() for gi in range(plan.n_groups)
        }

    # ------------------------------------------------------------- per group

    def _block_n(self) -> int:
        n_loc = self.plan.n // self.mesh.size
        want = self.cfg.block_n if self.cfg.block_n is not None else n_loc
        block = max(1, min(want, n_loc))
        while n_loc % block:
            block -= 1
        return block

    def group_config(self, gi: int) -> IndexConfig:
        """Padded IndexConfig for group ``gi`` (the jit-cache key)."""
        cfg = self._group_cfgs.get(gi)
        if cfg is None:
            g = self.plan.groups[gi]
            cfg = IndexConfig(
                n=self.plan.n,
                d=self.plan.d,
                beta=pad_beta(g.beta_group, self.cfg.beta_buckets),
                q_batch=self.cfg.q_batch,
                k=self.cfg.k,
                c=self.plan.c,
                n_levels=pad_levels(g.n_levels_max, self.cfg.level_step),
                p=self.plan.p,
                block_n=self._block_n(),
                gamma_n=self.plan.gamma_n,
                budget_override=self.cfg.budget_override,
                vec_dtype=self.cfg.vec_dtype,
                use_pallas=self.cfg.use_pallas,
            )
            self._group_cfgs[gi] = cfg
        return cfg

    def _group(self, gi: int):
        cfg = self.group_config(gi)
        state = self._states.get(gi)
        if state is None:
            state = build_group_state(
                self.mesh, cfg, self.points, self.plan.groups[gi]
            )
            self._states[gi] = state
        return cfg, state, self.step_cache.get(self.mesh, cfg)

    def warmup(self, groups=None) -> None:
        """Build states and compile steps ahead of traffic."""
        for gi in groups if groups is not None else range(self.plan.n_groups):
            self._group(int(gi))

    def reset_stats(self) -> None:
        for gi in self.stats:
            self.stats[gi] = GroupServeStats()

    def stats_summary(self) -> dict[int, dict]:
        return {gi: s.summary() for gi, s in self.stats.items()
                if s.n_batches}

    # --------------------------------------------------------------- serving

    def _encode(self, gi: int, cfg: IndexConfig, state, queries) -> np.ndarray:
        g = self.plan.groups[gi]
        # Query and data codes must come from the same encoding: host f64
        # only pairs with plan-shipped host codes; a device-built (f32)
        # state needs device-encoded queries, or floor-boundary jitter
        # mixes the two encodings and a query can miss its own point.
        if self.cfg.host_encode and g.codes is not None:
            return pad_cols(g.encode_host(queries), cfg.beta)
        return np.asarray(encode_queries(state, queries))

    def query(self, queries: np.ndarray, weight_ids) -> RetrievalResult:
        """Answer a mixed batch of (query, weight_id) requests.

        Queries are grouped by serving group, coalesced into q_batch-sized
        sub-batches, and results are returned in submission order.
        """
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        weight_ids = np.atleast_1d(np.asarray(weight_ids, np.int64))
        nq = len(queries)
        if len(weight_ids) != nq:
            raise ValueError("queries and weight_ids length mismatch")
        if nq and (weight_ids.min() < 0 or weight_ids.max() >= self.plan.n_weights):
            raise ValueError("weight_id out of range for the serving plan")
        k, qb = self.cfg.k, self.cfg.q_batch

        out_ids = np.full((nq, k), -1, np.int32)
        out_d = np.full((nq, k), np.inf, np.float32)
        out_stop = np.zeros(nq, np.int32)
        out_chk = np.zeros(nq, np.int32)
        gids = self.plan.group_of[weight_ids].astype(np.int32)

        for gi in np.unique(gids):
            gi = int(gi)
            sel = np.where(gids == gi)[0]  # submission order within group
            cfg, state, step = self._group(gi)
            g = self.plan.groups[gi]
            slots = self.plan.member_slot[weight_ids[sel]]
            mus = g.mu_members[slots].astype(np.int32)
            betas = g.beta_members[slots].astype(np.int32)
            rmins = g.r_min_members[slots].astype(np.float32)
            levels = g.n_levels_members[slots].astype(np.int32)
            qsel = queries[sel]
            codes = self._encode(gi, cfg, state, qsel).astype(np.int32)
            wsel = self.plan.weights[weight_ids[sel]].astype(np.float32)
            st = self.stats[gi]

            for lo in range(0, len(sel), qb):
                hi = min(lo + qb, len(sel))
                real = hi - lo
                # pad ragged tails by cycling the batch's real rows; padded
                # outputs are sliced away below
                take = lo + (np.arange(qb) % real)
                d_b, i_b, stop_b, chk_b = step(
                    state,
                    jnp.asarray(qsel[take]),
                    jnp.asarray(codes[take]),
                    jnp.asarray(wsel[take]),
                    jnp.asarray(mus[take]),
                    jnp.asarray(rmins[take]),
                    jnp.asarray(betas[take]),
                    jnp.asarray(levels[take]),
                )
                rows = sel[lo:hi]
                out_d[rows] = np.asarray(d_b)[:real]
                out_ids[rows] = np.asarray(i_b)[:real]
                out_stop[rows] = np.asarray(stop_b)[:real]
                out_chk[rows] = np.asarray(chk_b)[:real]
                st.n_batches += 1
                st.n_queries += real
                st.n_padded += qb - real
                st.stop_level_sum += int(np.sum(np.asarray(stop_b)[:real]))
                st.n_checked_sum += int(np.sum(np.asarray(chk_b)[:real]))

        return RetrievalResult(
            ids=out_ids,
            dists=out_d,
            group_ids=gids,
            stop_levels=out_stop,
            n_checked=out_chk,
        )
