"""Group-state memory manager: lazy build, LRU eviction, host offload.

WLSH's planner (Algorithm 1) deliberately produces *many* table groups to
cover the weight set, and each group's device state — codes ``(n, beta)``
plus vectors ``(n, d)`` — dominates the serving footprint.  Keeping every
``build_group_state`` result resident forever caps scale at
``device_bytes / state_nbytes`` groups, far below a production plan.  The
``StateCache`` bounds residency under an explicit budget instead:

  build     a group's state is built on first acquire (cold miss)
  evict     before a miss materializes a new state, *unpinned*,
            *unprotected* groups are evicted until the incoming state
            fits ``max_resident_groups`` / ``device_budget_bytes`` (its
            size is known up front, so the budget holds at peak
            residency); with an ``offload`` hook the evicted state is
            pulled to host memory first, otherwise it is discarded.  The
            victim is least-recently-used by default; an
            ``eviction_policy`` hook (see ``serving.scheduler``) makes
            the choice pluggable — the cost-aware default there scores
            recency against ``state_nbytes`` restore cost
  restore   re-acquiring an offloaded group uploads the host copy (warm
            miss: one host-to-device copy, bit-identical bytes, no
            re-encode and no recompile)
  prefetch  ``prefetch(gi)`` starts the restore (or build) *ahead* of
            the acquire that will need it — the scheduler issues it from
            the pending-deadline schedule, so the host-to-device upload
            (asynchronous under JAX) overlaps in-flight launches instead
            of serializing into a launch's critical path.  A prefetched
            state consumed by a later acquire counts a hit (and
            ``n_restore_overlapped`` when the prefetch restored); one
            evicted or invalidated before any acquire counts
            ``n_prefetch_wasted``
  protect   ``protect(gis)`` marks groups scheduled to launch within
            their restore horizon: they are never chosen as eviction
            victims (the budget goes soft instead, like pinning), so a
            prefetch can never evict a state that is about to launch
  pin       an acquired state is pinned until ``release`` — a launch in
            flight can never lose its state to a concurrent acquire, and
            deadline-driven partial launches cannot thrash each other
  version   keys are versioned: streaming compaction replaces or
            invalidates exactly one group's cached bytes (``replace`` /
            ``invalidate`` bump that group's version and drop its device
            and host copies) while every other group's state — and every
            compiled step — survives untouched

Misses are fault-tolerant: a raising restore/build executor is retried a
bounded number of times (``restore_retries``, with optional doubling
backoff) before the error propagates, the host copy survives a failed
restore, and a failing *prefetch* is contained entirely — counted
``n_prefetch_wasted``, never raising into the scheduler tick.  Observed
miss timings feed a ``RestoreCostModel`` (EWMA bytes/s) that prices
``restore_eta(gi)`` for the scheduler's learned prefetch horizon.

Byte accounting comes from ``IndexConfig.state_nbytes`` (the *padded*
shapes actually materialized), so budgets are enforceable before any state
is built.  Counters (hits / builds / restores / evictions) are recorded
directly in the serving stack's unified ``MetricsRegistry`` as
``wlsh_state_*`` series labeled by group — ``CacheStats`` (and the
per-group ``Batcher.stats`` views) read the same series, so nothing is
mirrored.  Compiled query steps
are deliberately *not* managed here: ``QueryStepCache`` keys on shape
signatures, so evicting a group's state never forces a recompile.

The cache is single-threaded like the frontends that drive it; the budget
is soft under pinning — if every resident state is pinned, an acquire may
temporarily exceed the budget rather than deadlock.
"""

from __future__ import annotations

import contextlib
import dataclasses
import time
from collections import OrderedDict
from typing import Callable

from ..obs import MetricsRegistry

__all__ = [
    "CacheStats",
    "EvictionCandidate",
    "RestoreCostModel",
    "StateCache",
]

# Cache event kind -> unified registry counter (labeled by group).
_EVENT_COUNTERS = {
    "hit": "wlsh_state_hits_total",
    "build": "wlsh_state_builds_total",
    "restore": "wlsh_state_restores_total",
    "evict": "wlsh_state_evictions_total",
    "invalidate": "wlsh_state_invalidations_total",
    "prefetch": "wlsh_state_prefetches_total",
    "prefetch_wasted": "wlsh_state_prefetch_wasted_total",
    "restore_overlapped": "wlsh_state_restore_overlapped_total",
}


class RestoreCostModel:
    """Learned host-to-device restore bandwidth (EWMA bytes/second).

    The scheduler's prefetch horizon used to be a hand-set knob
    (``DeadlinePrefetch.horizon_s``); this model learns the real figure
    from observed restore (and cold-build) timings instead.  Every
    ``StateCache`` miss feeds ``observe(nbytes, seconds)``; the
    exponentially-weighted moving average smooths transient latency
    spikes while tracking genuine bandwidth shifts.  ``eta(nbytes)``
    then prices a pending restore, and the prefetch policy widens its
    horizon to ``max(floor, margin * eta)`` — the hand-set horizon
    survives as a deterministic floor, so virtual-time replays (whose
    deadlines are not wall-clock commensurable) behave exactly as
    before, while a deployment whose restores are genuinely slow gets a
    proportionally earlier prefetch.
    """

    def __init__(
        self,
        alpha: float = 0.2,
        default_bytes_per_s: float = 4e9,
    ):
        if not (0 < alpha <= 1):
            raise ValueError(f"alpha must be in (0, 1], got {alpha}")
        if not (default_bytes_per_s > 0):
            raise ValueError(
                f"default_bytes_per_s must be > 0, got {default_bytes_per_s}"
            )
        self.alpha = float(alpha)
        self._bytes_per_s = float(default_bytes_per_s)
        self.n_observed = 0

    @property
    def bytes_per_s(self) -> float:
        """Current bandwidth estimate (the prior until first observed)."""
        return self._bytes_per_s

    def observe(self, nbytes: int, seconds: float) -> None:
        """Fold one observed transfer into the EWMA (bad samples skipped)."""
        if nbytes <= 0 or not (seconds > 0):
            return  # clock granularity can produce 0.0 — not a rate
        rate = nbytes / seconds
        if self.n_observed == 0:
            self._bytes_per_s = rate  # first sample replaces the prior
        else:
            self._bytes_per_s += self.alpha * (rate - self._bytes_per_s)
        self.n_observed += 1

    def eta(self, nbytes: int) -> float:
        """Predicted seconds to restore an ``nbytes`` state."""
        return max(nbytes, 0) / self._bytes_per_s


class CacheStats:
    """Cache counters as a read-only view over the unified registry.

    Every count lives in the serving stack's :class:`MetricsRegistry`
    (``wlsh_state_*`` counters labeled by group, plus the
    ``wlsh_state_resident_bytes`` gauge); this class is a thin summing
    view so callers keep the classic ``stats.n_hits`` spelling.  Reset
    with ``StateCache.reset_stats`` (residency and budget survive).
    """

    # attribute -> registry counter it sums over (all group labels)
    _COUNTERS = {
        "n_hits": "wlsh_state_hits_total",
        "n_builds": "wlsh_state_builds_total",
        "n_restores": "wlsh_state_restores_total",
        "n_evictions": "wlsh_state_evictions_total",
        "n_invalidations": "wlsh_state_invalidations_total",
        "n_prefetches": "wlsh_state_prefetches_total",
        "n_prefetch_wasted": "wlsh_state_prefetch_wasted_total",
        "n_restore_overlapped": "wlsh_state_restore_overlapped_total",
        "n_restore_retries": "wlsh_state_restore_retries_total",
    }

    def __init__(self, metrics: MetricsRegistry,
                 device_budget_bytes: int | None = None):
        """Bind the view to ``metrics`` (see ``StateCache.metrics``)."""
        self._metrics = metrics
        self.device_budget_bytes = device_budget_bytes

    def __getattr__(self, name: str) -> int:
        """Resolve ``n_*`` counter reads against the registry."""
        metric = type(self)._COUNTERS.get(name)
        if metric is None:
            raise AttributeError(name)
        return int(self._metrics.counter(metric).total())

    @property
    def resident_bytes(self) -> int:
        """Current accounted residency (gauge: survives reset_stats)."""
        return int(
            self._metrics.gauge("wlsh_state_resident_bytes").value()
        )

    @property
    def n_misses(self) -> int:
        """Acquires that had to build or restore."""
        return self.n_builds + self.n_restores

    @property
    def hit_rate(self) -> float:
        """Resident-hit fraction over all acquires (nan with no traffic).

        Prefetch-issued restores/builds count in the denominator — a
        prefetch that is never consumed must not look free.
        """
        total = self.n_hits + self.n_misses
        return self.n_hits / total if total else float("nan")

    @property
    def budget_utilization(self) -> float:
        """Resident bytes as a fraction of the byte budget.

        nan when the cache has no ``device_budget_bytes`` budget.
        """
        if not self.device_budget_bytes:
            return float("nan")
        return self.resident_bytes / self.device_budget_bytes

    def summary(self) -> dict:
        """Flat dict of every counter plus the derived rates/residency."""
        return dict(
            n_hits=self.n_hits,
            n_builds=self.n_builds,
            n_restores=self.n_restores,
            n_evictions=self.n_evictions,
            n_invalidations=self.n_invalidations,
            n_prefetches=self.n_prefetches,
            n_prefetch_wasted=self.n_prefetch_wasted,
            n_restore_overlapped=self.n_restore_overlapped,
            n_restore_retries=self.n_restore_retries,
            hit_rate=self.hit_rate,
            resident_bytes=self.resident_bytes,
            budget_utilization=self.budget_utilization,
        )


@dataclasses.dataclass(frozen=True)
class EvictionCandidate:
    """One evictable resident group, as seen by an eviction policy.

    ``last_use`` is a monotone access tick (smaller = staler); policies
    compare ticks, never wall-clock.  ``prefetched`` marks a state brought
    in by ``prefetch`` and not yet consumed by any acquire.
    """

    group_id: int
    last_use: int
    nbytes: int
    prefetched: bool = False


@dataclasses.dataclass
class _Entry:
    """One group's cache slot: at most one of state/host is populated."""

    state: object | None = None  # device-resident QueryState
    host: object | None = None  # offloaded host copy
    nbytes: int = 0
    pins: int = 0
    version: int = 0  # group version the stored bytes correspond to
    last_use: int = 0  # monotone access tick (acquire/prefetch/replace)
    prefetched: str | None = None  # "restore"/"build" while brought in by
    # prefetch and not yet consumed by an acquire


class StateCache:
    """LRU cache of per-group device states under a device-memory budget.

    Parameters
    ----------
    build:
        ``build(group_id) -> state`` — materialize a group's device state
        from scratch (cold path).
    nbytes_of:
        ``nbytes_of(group_id) -> int`` — the group's device footprint,
        derivable without building (``IndexConfig.state_nbytes``).
    max_resident_groups:
        Keep at most this many groups resident (None = unbounded).
    device_budget_bytes:
        Keep total resident bytes at or under this budget (None =
        unbounded).  Both limits may be set; eviction enforces both.
    offload:
        Optional ``offload(state) -> host_copy`` run at eviction; evicted
        groups restore from the copy instead of rebuilding.  None
        discards evicted states (rebuild on next acquire).
    restore:
        ``restore(group_id, host_copy) -> state`` — upload an offloaded
        copy.  Required when ``offload`` is set.
    on_event:
        Optional ``on_event(group_id, kind)`` observer with kind in
        ``{"hit", "build", "restore", "evict", "invalidate", "prefetch",
        "prefetch_wasted", "restore_overlapped"}`` — the hook ``Batcher``
        uses to attribute cache activity to in-flight trace spans (the
        counters themselves live in the shared registry, no mirroring).
    eviction_policy:
        Optional victim selector ``policy(candidates) -> group_id`` over
        a tuple of ``EvictionCandidate`` (every unpinned, unprotected
        resident group).  None keeps the classic least-recently-used
        choice; ``serving.scheduler.CostAwareEviction`` is the cost-aware
        default the real-time driver installs.
    restore_retries:
        Bounded retry budget for a failing restore or build: a raising
        executor is retried up to this many times per miss before the
        exception propagates (``acquire``) or the prefetch is written
        off as wasted (``prefetch``).  A transient device hiccup —
        exactly the regime paging exists for — therefore recovers
        instead of poisoning a lease.  0 disables retries.
    retry_backoff_s:
        Base backoff slept between retry attempts (doubling per
        attempt).  The default 0.0 retries immediately, keeping every
        test and virtual-time replay free of wall-clock sleeps.
    cost_model:
        The learned restore-bandwidth model fed by observed miss
        timings (``RestoreCostModel``); None installs a default one.
    metrics:
        The unified ``MetricsRegistry`` the cache's ``wlsh_state_*``
        counters and residency gauge live in — ``Batcher`` passes its
        own so every layer shares one registry; None creates a private
        one (standalone caches stay self-contained).
    timer:
        Injectable clock for restore/build timing (feeds the
        ``RestoreCostModel``); defaults to ``time.perf_counter``.
    sleep:
        Injectable retry-backoff sleep; defaults to ``time.sleep``.
    """

    def __init__(
        self,
        build: Callable[[int], object],
        nbytes_of: Callable[[int], int],
        *,
        max_resident_groups: int | None = None,
        device_budget_bytes: int | None = None,
        offload: Callable[[object], object] | None = None,
        restore: Callable[[int, object], object] | None = None,
        on_event: Callable[[int, str], None] | None = None,
        eviction_policy: Callable[[tuple], int] | None = None,
        restore_retries: int = 2,
        retry_backoff_s: float = 0.0,
        cost_model: RestoreCostModel | None = None,
        metrics: MetricsRegistry | None = None,
        timer: Callable[[], float] = time.perf_counter,
        sleep: Callable[[float], None] | None = None,
    ):
        if max_resident_groups is not None and max_resident_groups < 1:
            raise ValueError(
                f"max_resident_groups must be >= 1 or None, got "
                f"{max_resident_groups}"
            )
        if device_budget_bytes is not None and device_budget_bytes < 1:
            raise ValueError(
                f"device_budget_bytes must be >= 1 or None, got "
                f"{device_budget_bytes}"
            )
        if offload is not None and restore is None:
            raise ValueError("offload requires a restore callable")
        if restore_retries < 0:
            raise ValueError(
                f"restore_retries must be >= 0, got {restore_retries}"
            )
        if retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {retry_backoff_s}"
            )
        self.restore_retries = int(restore_retries)
        self.retry_backoff_s = float(retry_backoff_s)
        self._sleep = sleep if sleep is not None else time.sleep
        self._timer = timer
        self.cost_model = (
            cost_model if cost_model is not None else RestoreCostModel()
        )
        self._build = build
        self._nbytes_of = nbytes_of
        self.max_resident_groups = max_resident_groups
        self.device_budget_bytes = device_budget_bytes
        self._offload = offload
        self._restore = restore
        self._on_event = on_event or (lambda gi, kind: None)
        self.eviction_policy = eviction_policy
        # LRU order: first = least recently used.  Non-resident entries
        # (host copy only) live in _offloaded.
        self._resident: OrderedDict[int, _Entry] = OrderedDict()
        self._resident_nbytes = 0  # running sum over self._resident
        self._offloaded: dict[int, _Entry] = {}
        # versioned keys: cached bytes (device or host) are only valid for
        # the group's current version; invalidate/replace bump it so a
        # compacted group can never serve a pre-compaction copy
        self._versions: dict[int, int] = {}
        self._protected: frozenset[int] = frozenset()
        self._tick = 0  # monotone access counter for recency scoring
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.stats = CacheStats(
            self.metrics, device_budget_bytes=device_budget_bytes
        )

    def _event(self, gi: int, kind: str) -> None:
        """Count one cache event in the registry and notify the hook."""
        self.metrics.counter(
            _EVENT_COUNTERS[kind], "state-cache events by kind"
        ).inc(group=gi)
        self._on_event(gi, kind)

    # ------------------------------------------------------------- inspection

    @property
    def resident_bytes(self) -> int:
        """Total accounted bytes of the currently resident states."""
        return self._resident_nbytes

    @property
    def n_resident(self) -> int:
        """Number of groups currently resident on device."""
        return len(self._resident)

    def resident_group_ids(self) -> tuple[int, ...]:
        """Resident groups, least recently used first."""
        return tuple(self._resident)

    def is_resident(self, gi: int) -> bool:
        """Whether group ``gi`` is on device right now."""
        return gi in self._resident

    def pin_count(self, gi: int) -> int:
        """Outstanding acquires of group ``gi`` (0 = evictable)."""
        entry = self._resident.get(int(gi))
        return entry.pins if entry is not None else 0

    def nbytes_of(self, gi: int) -> int:
        """Accounted device footprint of group ``gi``'s state.

        The resident entry's priced size when the group is on device,
        otherwise the ``nbytes_of`` estimate — what eviction, budgets and
        the scheduler's imminent-set clamp all price with.
        """
        entry = self._resident.get(int(gi))
        return entry.nbytes if entry is not None else self._nbytes_of(gi)

    def restore_eta(self, gi: int) -> float:
        """Predicted seconds to page group ``gi`` in, from observed rates.

        ``RestoreCostModel`` bandwidth applied to the group's accounted
        bytes — what the scheduler's prefetch policy widens its horizon
        with (0.0 for an already-resident group: nothing to restore).
        """
        gi = int(gi)
        if gi in self._resident:
            return 0.0
        return self.cost_model.eta(self.nbytes_of(gi))

    def version_of(self, gi: int) -> int:
        """Current version of group ``gi`` (bumped by invalidate/replace)."""
        return self._versions.get(int(gi), 0)

    def protected_group_ids(self) -> frozenset[int]:
        """Groups currently shielded from eviction (see ``protect``)."""
        return self._protected

    def reset_stats(self) -> None:
        """Zero the counters (current residency/budget figures survive).

        Registry gauges survive ``reset`` by design, so the residency
        figure carries across while every ``wlsh_state_*`` counter
        starts over.
        """
        self.metrics.reset("wlsh_state_")

    def _add_bytes(self, delta: int) -> None:
        """Adjust the accounted residency (mirrored into the gauge)."""
        self._resident_nbytes += delta
        self.metrics.gauge(
            "wlsh_state_resident_bytes", "accounted resident state bytes"
        ).set(self._resident_nbytes)

    def _touch(self, entry: _Entry) -> None:
        """Stamp ``entry`` with the next monotone access tick."""
        self._tick += 1
        entry.last_use = self._tick

    # ---------------------------------------------------------------- serving

    def acquire(self, gi: int) -> object:
        """Return group ``gi``'s device state, pinned until ``release``.

        Resident: a hit (refreshes LRU position).  Offloaded: the host
        copy is uploaded (restore).  Unknown: built from scratch.  On
        either miss path, least-recently-used unpinned groups are evicted
        *before* the new state materializes (its size is known up front
        from ``nbytes_of``), so the budget holds at the moment of peak
        residency — never exceeded transiently by the incoming group.
        """
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is not None and entry.version == self.version_of(gi):
            self._resident.move_to_end(gi)
            self._touch(entry)
            entry.pins += 1
            self._event(gi, "hit")
            if entry.prefetched is not None:
                # the prefetch paid off: the upload happened before this
                # acquire needed it, off the launch's critical path
                if entry.prefetched == "restore":
                    self._event(gi, "restore_overlapped")
                entry.prefetched = None
            return entry.state
        entry, _ = self._materialize(gi)
        entry.pins += 1
        return entry.state

    def _materialize(self, gi: int) -> tuple[_Entry, str]:
        """Shared miss path of ``acquire`` and ``prefetch``.

        Evicts to fit, then restores the host copy or cold-builds, and
        installs the state resident (unpinned).
        """
        version = self.version_of(gi)
        if self._resident.get(gi) is not None:  # stale resident copy
            self.evict(gi)  # (defensive: invalidate/replace drop eagerly)
        entry = self._offloaded.get(gi)
        if entry is not None and entry.version != version:
            del self._offloaded[gi]
            entry = None
        nbytes = entry.nbytes if entry is not None else self._nbytes_of(gi)
        self._evict_to_fit(nbytes)
        if entry is not None:
            # restore before popping: if the upload raises (device OOM —
            # the regime paging exists for), the host copy survives and a
            # retry restores instead of silently cold-rebuilding
            host = entry.host
            entry.state = self._attempt(
                lambda: self._restore(gi, host), nbytes
            )
            del self._offloaded[gi]
            entry.host = None
            kind = "restore"
        else:
            entry = _Entry(
                state=self._attempt(lambda: self._build(gi), nbytes),
                nbytes=nbytes, version=version,
            )
            kind = "build"
        self._resident[gi] = entry  # newest LRU position
        self._touch(entry)
        self._add_bytes(entry.nbytes)
        self._event(gi, kind)
        entry.prefetched = None
        return entry, kind

    def _attempt(self, run: Callable[[], object], nbytes: int) -> object:
        """One restore/build with bounded retries and timing feedback.

        Retries a raising executor up to ``restore_retries`` times
        (optionally backing off, doubling per attempt) before letting
        the exception propagate — a transient failure recovers in place
        instead of poisoning the caller's lease.  Successful attempts
        feed their observed transfer time to the ``RestoreCostModel``.
        """
        for attempt in range(self.restore_retries + 1):
            t0 = self._timer()
            try:
                state = run()
            except Exception:
                if attempt >= self.restore_retries:
                    raise
                self.metrics.counter(
                    "wlsh_state_restore_retries_total",
                    "failed restore/build attempts that were retried",
                ).inc()
                backoff = self.retry_backoff_s * (2 ** attempt)
                if backoff > 0:
                    self._sleep(backoff)
                continue
            self.cost_model.observe(nbytes, self._timer() - t0)
            return state

    def release(self, gi: int) -> None:
        """Unpin one ``acquire`` of group ``gi`` (making it evictable)."""
        entry = self._resident.get(int(gi))
        if entry is None or entry.pins < 1:
            raise ValueError(f"release without matching acquire (group {gi})")
        entry.pins -= 1
        self._enforce_budget()

    @contextlib.contextmanager
    def lease(self, gi: int):
        """Context-managed acquire/release pair around one launch."""
        state = self.acquire(gi)
        try:
            yield state
        finally:
            self.release(gi)

    # ------------------------------------------------------------ prefetching

    def prefetch(self, gi: int) -> bool:
        """Start bringing group ``gi``'s state on device ahead of its launch.

        A no-op (returning False) when the state is already resident at
        its current version.  Otherwise the same evict-to-fit + restore /
        build path as a miss runs *now* — and since JAX host-to-device
        transfers are asynchronous, the upload overlaps whatever launches
        the caller runs next instead of blocking the acquire that will
        eventually need this state.  The state is installed resident but
        *unpinned*; a later ``acquire`` consumes it as a hit (counting
        ``n_restore_overlapped`` when the prefetch restored), while an
        eviction or invalidation before any acquire counts the work as
        ``n_prefetch_wasted``.  Returns True when work was issued.

        A prefetch whose restore/build *fails* (after the cache's
        bounded retries) is contained here: the work is written off as
        ``n_prefetch_wasted`` and False is returned, with no exception
        escaping — a speculative page-in must never take the scheduler
        tick down, and the eventual launch-time ``acquire`` still
        surfaces a persistent fault.  The host copy survives a failed
        restore (see ``_materialize``), so nothing is lost either way.
        """
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is not None and entry.version == self.version_of(gi):
            return False
        try:
            entry, kind = self._materialize(gi)
        except Exception:
            # speculative work only: swallow, count, let acquire retry
            self._event(gi, "prefetch")
            self._event(gi, "prefetch_wasted")
            return False
        entry.prefetched = kind
        self._event(gi, "prefetch")
        return True

    def protect(self, group_ids) -> None:
        """Shield ``group_ids`` from eviction until the next ``protect``.

        The scheduler's per-tick contract: groups scheduled to launch
        within their restore horizon are protected, so neither a prefetch
        nor a concurrent miss can evict a state that is about to be
        acquired.  Like pinning, protection makes the budget soft rather
        than deadlocking — each call *replaces* the previous set (pass an
        empty iterable to clear), so stale protection cannot accumulate.
        """
        self._protected = frozenset(int(g) for g in group_ids)

    # --------------------------------------------------------------- eviction

    def _over_budget(self, incoming_groups: int = 0,
                     incoming_bytes: int = 0) -> bool:
        if self.max_resident_groups is not None and (
            len(self._resident) + incoming_groups > self.max_resident_groups
        ):
            return True
        return self.device_budget_bytes is not None and (
            self.resident_bytes + incoming_bytes > self.device_budget_bytes
        )

    def _pick_victim(self) -> int | None:
        """Choose the next eviction victim, or None when nothing is evictable.

        Only unpinned, unprotected residents are candidates (LRU without
        a policy); None means soft budget, never a deadlock.
        """
        candidates = tuple(
            EvictionCandidate(
                group_id=gi, last_use=e.last_use, nbytes=e.nbytes,
                prefetched=e.prefetched is not None,
            )
            for gi, e in self._resident.items()
            if e.pins == 0 and gi not in self._protected
        )
        if not candidates:
            return None
        if self.eviction_policy is None:
            return candidates[0].group_id  # insertion order = LRU first
        victim = int(self.eviction_policy(candidates))
        if victim not in {c.group_id for c in candidates}:
            raise ValueError(
                f"eviction policy chose group {victim}, which is not an "
                f"evictable candidate"
            )
        return victim

    def _evict_lru_while(self, over) -> None:
        while over():
            victim = self._pick_victim()
            if victim is None:  # everything pinned/protected: soft budget
                return
            self.evict(victim)

    def _evict_to_fit(self, nbytes: int) -> None:
        """Make room for one incoming ``nbytes``-sized state up front."""
        self._evict_lru_while(lambda: self._over_budget(1, nbytes))

    def _enforce_budget(self) -> None:
        self._evict_lru_while(self._over_budget)

    def evict(self, gi: int) -> None:
        """Evict group ``gi`` from device (offloading first if configured)."""
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is None:
            return
        if entry.pins:
            raise ValueError(f"cannot evict pinned group {gi}")
        del self._resident[gi]
        self._add_bytes(-entry.nbytes)
        if self._offload is not None:
            entry.host = self._offload(entry.state)
            self._offloaded[gi] = entry
        entry.state = None  # drop the device reference either way
        self._mark_wasted_prefetch(gi, entry)
        self._event(gi, "evict")

    def _mark_wasted_prefetch(self, gi: int, entry: _Entry) -> None:
        """Count a prefetched state that left the device unconsumed."""
        if entry.prefetched is not None:
            entry.prefetched = None
            self._event(gi, "prefetch_wasted")

    def clear(self) -> None:
        """Drop every unpinned resident state (keeping host copies)."""
        for gi in [g for g, e in self._resident.items() if e.pins == 0]:
            self.evict(gi)

    # ------------------------------------------------------------ versioning

    def invalidate(self, gi: int) -> None:
        """Bump group ``gi``'s version and drop every cached copy of it.

        The compaction-driven invalidation path: the group's stored bytes
        (device state *and* host offload copy) no longer describe its
        corpus, so both are discarded and the next ``acquire`` cold-builds
        at the new version.  Only this group is touched — other groups'
        cached states and every compiled step survive.  Raises while the
        group is pinned (a launch in flight must never lose its state).
        """
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is not None:
            if entry.pins:
                raise ValueError(f"cannot invalidate pinned group {gi}")
            del self._resident[gi]
            self._add_bytes(-entry.nbytes)
            entry.state = None
            self._mark_wasted_prefetch(gi, entry)
        self._offloaded.pop(gi, None)
        self._versions[gi] = self.version_of(gi) + 1
        self._event(gi, "invalidate")

    def replace(self, gi: int, state: object, nbytes: int | None = None
                ) -> None:
        """Install ``state`` as group ``gi``'s new current version.

        The in-place compaction path: the caller has already produced the
        post-compaction state (``append_to_state`` on the leased old one),
        so instead of invalidate-then-rebuild the new state is installed
        directly at a bumped version — one version event, no cold build.
        Stale host copies are dropped; residency budgets are re-enforced
        against the (possibly re-priced) entry.  Raises while pinned.
        """
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is not None and entry.pins:
            raise ValueError(f"cannot replace pinned group {gi}")
        if entry is None:
            if nbytes is None:
                nbytes = self._nbytes_of(gi)
            self._evict_to_fit(nbytes)
            entry = _Entry(nbytes=nbytes)
            self._resident[gi] = entry
            self._add_bytes(nbytes)
        else:
            if nbytes is not None:
                self._add_bytes(nbytes - entry.nbytes)
                entry.nbytes = nbytes
            self._mark_wasted_prefetch(gi, entry)
        self._offloaded.pop(gi, None)
        self._versions[gi] = self.version_of(gi) + 1
        entry.version = self._versions[gi]
        entry.state = state
        entry.host = None
        self._resident.move_to_end(gi)
        self._touch(entry)
        self._event(gi, "invalidate")
        self._enforce_budget()
