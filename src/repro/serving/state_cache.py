"""Group-state memory manager: lazy build, LRU eviction, host offload.

WLSH's planner (Algorithm 1) deliberately produces *many* table groups to
cover the weight set, and each group's device state — codes ``(n, beta)``
plus vectors ``(n, d)`` — dominates the serving footprint.  Keeping every
``build_group_state`` result resident forever caps scale at
``device_bytes / state_nbytes`` groups, far below a production plan.  The
``StateCache`` bounds residency under an explicit budget instead:

  build     a group's state is built on first acquire (cold miss)
  evict     before a miss materializes a new state, least-recently-used
            *unpinned* groups are evicted until the incoming state fits
            ``max_resident_groups`` / ``device_budget_bytes`` (its size
            is known up front, so the budget holds at peak residency);
            with an ``offload`` hook the evicted state is pulled to host
            memory first, otherwise it is discarded
  restore   re-acquiring an offloaded group uploads the host copy (warm
            miss: one host-to-device copy, bit-identical bytes, no
            re-encode and no recompile)
  pin       an acquired state is pinned until ``release`` — a launch in
            flight can never lose its state to a concurrent acquire, and
            deadline-driven partial launches cannot thrash each other
  version   keys are versioned: streaming compaction replaces or
            invalidates exactly one group's cached bytes (``replace`` /
            ``invalidate`` bump that group's version and drop its device
            and host copies) while every other group's state — and every
            compiled step — survives untouched

Byte accounting comes from ``IndexConfig.state_nbytes`` (the *padded*
shapes actually materialized), so budgets are enforceable before any state
is built.  Counters (hits / builds / restores / evictions) feed
``Batcher.stats`` and the serve_bench paging sweep.  Compiled query steps
are deliberately *not* managed here: ``QueryStepCache`` keys on shape
signatures, so evicting a group's state never forces a recompile.

The cache is single-threaded like the frontends that drive it; the budget
is soft under pinning — if every resident state is pinned, an acquire may
temporarily exceed the budget rather than deadlock.
"""

from __future__ import annotations

import contextlib
import dataclasses
from collections import OrderedDict
from typing import Callable

__all__ = ["CacheStats", "StateCache"]


@dataclasses.dataclass
class CacheStats:
    """Running cache counters (reset with ``StateCache.reset_stats``)."""

    n_hits: int = 0  # acquire found the state resident
    n_builds: int = 0  # cold miss: state built from scratch
    n_restores: int = 0  # warm miss: host copy uploaded
    n_evictions: int = 0  # device evictions (offloaded or discarded)
    n_invalidations: int = 0  # version bumps (compaction replace/invalidate)

    @property
    def n_misses(self) -> int:
        """Acquires that had to build or restore."""
        return self.n_builds + self.n_restores

    @property
    def hit_rate(self) -> float:
        """Resident-hit fraction over all acquires (nan with no traffic)."""
        total = self.n_hits + self.n_misses
        return self.n_hits / total if total else float("nan")

    def summary(self) -> dict:
        """Flat dict of every counter plus the derived hit rate."""
        return dict(
            n_hits=self.n_hits,
            n_builds=self.n_builds,
            n_restores=self.n_restores,
            n_evictions=self.n_evictions,
            n_invalidations=self.n_invalidations,
            hit_rate=self.hit_rate,
        )


@dataclasses.dataclass
class _Entry:
    """One group's cache slot: at most one of state/host is populated."""

    state: object | None = None  # device-resident QueryState
    host: object | None = None  # offloaded host copy
    nbytes: int = 0
    pins: int = 0
    version: int = 0  # group version the stored bytes correspond to


class StateCache:
    """LRU cache of per-group device states under a device-memory budget.

    Parameters
    ----------
    build:
        ``build(group_id) -> state`` — materialize a group's device state
        from scratch (cold path).
    nbytes_of:
        ``nbytes_of(group_id) -> int`` — the group's device footprint,
        derivable without building (``IndexConfig.state_nbytes``).
    max_resident_groups:
        Keep at most this many groups resident (None = unbounded).
    device_budget_bytes:
        Keep total resident bytes at or under this budget (None =
        unbounded).  Both limits may be set; eviction enforces both.
    offload:
        Optional ``offload(state) -> host_copy`` run at eviction; evicted
        groups restore from the copy instead of rebuilding.  None
        discards evicted states (rebuild on next acquire).
    restore:
        ``restore(group_id, host_copy) -> state`` — upload an offloaded
        copy.  Required when ``offload`` is set.
    on_event:
        Optional ``on_event(group_id, kind)`` observer with kind in
        ``{"hit", "build", "restore", "evict"}`` — the hook ``Batcher``
        uses to mirror cache activity into its per-group serving stats.
    """

    def __init__(
        self,
        build: Callable[[int], object],
        nbytes_of: Callable[[int], int],
        *,
        max_resident_groups: int | None = None,
        device_budget_bytes: int | None = None,
        offload: Callable[[object], object] | None = None,
        restore: Callable[[int, object], object] | None = None,
        on_event: Callable[[int, str], None] | None = None,
    ):
        if max_resident_groups is not None and max_resident_groups < 1:
            raise ValueError(
                f"max_resident_groups must be >= 1 or None, got "
                f"{max_resident_groups}"
            )
        if device_budget_bytes is not None and device_budget_bytes < 1:
            raise ValueError(
                f"device_budget_bytes must be >= 1 or None, got "
                f"{device_budget_bytes}"
            )
        if offload is not None and restore is None:
            raise ValueError("offload requires a restore callable")
        self._build = build
        self._nbytes_of = nbytes_of
        self.max_resident_groups = max_resident_groups
        self.device_budget_bytes = device_budget_bytes
        self._offload = offload
        self._restore = restore
        self._on_event = on_event or (lambda gi, kind: None)
        # LRU order: first = least recently used.  Non-resident entries
        # (host copy only) live in _offloaded.
        self._resident: OrderedDict[int, _Entry] = OrderedDict()
        self._resident_nbytes = 0  # running sum over self._resident
        self._offloaded: dict[int, _Entry] = {}
        # versioned keys: cached bytes (device or host) are only valid for
        # the group's current version; invalidate/replace bump it so a
        # compacted group can never serve a pre-compaction copy
        self._versions: dict[int, int] = {}
        self.stats = CacheStats()

    # ------------------------------------------------------------- inspection

    @property
    def resident_bytes(self) -> int:
        """Total accounted bytes of the currently resident states."""
        return self._resident_nbytes

    @property
    def n_resident(self) -> int:
        """Number of groups currently resident on device."""
        return len(self._resident)

    def resident_group_ids(self) -> tuple[int, ...]:
        """Resident groups, least recently used first."""
        return tuple(self._resident)

    def is_resident(self, gi: int) -> bool:
        """Whether group ``gi`` is on device right now."""
        return gi in self._resident

    def pin_count(self, gi: int) -> int:
        """Outstanding acquires of group ``gi`` (0 = evictable)."""
        entry = self._resident.get(int(gi))
        return entry.pins if entry is not None else 0

    def version_of(self, gi: int) -> int:
        """Current version of group ``gi`` (bumped by invalidate/replace)."""
        return self._versions.get(int(gi), 0)

    def reset_stats(self) -> None:
        """Zero the hit/build/restore/eviction counters."""
        self.stats = CacheStats()

    # ---------------------------------------------------------------- serving

    def acquire(self, gi: int) -> object:
        """Return group ``gi``'s device state, pinned until ``release``.

        Resident: a hit (refreshes LRU position).  Offloaded: the host
        copy is uploaded (restore).  Unknown: built from scratch.  On
        either miss path, least-recently-used unpinned groups are evicted
        *before* the new state materializes (its size is known up front
        from ``nbytes_of``), so the budget holds at the moment of peak
        residency — never exceeded transiently by the incoming group.
        """
        gi = int(gi)
        version = self.version_of(gi)
        entry = self._resident.get(gi)
        if entry is not None and entry.version == version:
            self._resident.move_to_end(gi)
            entry.pins += 1
            self.stats.n_hits += 1
            self._on_event(gi, "hit")
            return entry.state
        if entry is not None:  # stale resident copy (defensive: invalidate
            self.evict(gi)  # and replace already drop these eagerly)
        entry = self._offloaded.get(gi)
        if entry is not None and entry.version != version:
            del self._offloaded[gi]
            entry = None
        nbytes = entry.nbytes if entry is not None else self._nbytes_of(gi)
        self._evict_to_fit(nbytes)
        if entry is not None:
            # restore before popping: if the upload raises (device OOM —
            # the regime paging exists for), the host copy survives and a
            # retry restores instead of silently cold-rebuilding
            entry.state = self._restore(gi, entry.host)
            del self._offloaded[gi]
            entry.host = None
            self.stats.n_restores += 1
            kind = "restore"
        else:
            entry = _Entry(
                state=self._build(gi), nbytes=nbytes, version=version
            )
            self.stats.n_builds += 1
            kind = "build"
        entry.pins += 1
        self._resident[gi] = entry  # newest LRU position
        self._resident_nbytes += entry.nbytes
        self._on_event(gi, kind)
        return entry.state

    def release(self, gi: int) -> None:
        """Unpin one ``acquire`` of group ``gi`` (making it evictable)."""
        entry = self._resident.get(int(gi))
        if entry is None or entry.pins < 1:
            raise ValueError(f"release without matching acquire (group {gi})")
        entry.pins -= 1
        self._enforce_budget()

    @contextlib.contextmanager
    def lease(self, gi: int):
        """Context-managed acquire/release pair around one launch."""
        state = self.acquire(gi)
        try:
            yield state
        finally:
            self.release(gi)

    # --------------------------------------------------------------- eviction

    def _over_budget(self, incoming_groups: int = 0,
                     incoming_bytes: int = 0) -> bool:
        if self.max_resident_groups is not None and (
            len(self._resident) + incoming_groups > self.max_resident_groups
        ):
            return True
        return self.device_budget_bytes is not None and (
            self.resident_bytes + incoming_bytes > self.device_budget_bytes
        )

    def _evict_lru_while(self, over) -> None:
        while over():
            victim = next(
                (gi for gi, e in self._resident.items() if e.pins == 0), None
            )
            if victim is None:  # everything pinned: soft budget, no deadlock
                return
            self.evict(victim)

    def _evict_to_fit(self, nbytes: int) -> None:
        """Make room for one incoming ``nbytes``-sized state up front."""
        self._evict_lru_while(lambda: self._over_budget(1, nbytes))

    def _enforce_budget(self) -> None:
        self._evict_lru_while(self._over_budget)

    def evict(self, gi: int) -> None:
        """Evict group ``gi`` from device (offloading first if configured)."""
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is None:
            return
        if entry.pins:
            raise ValueError(f"cannot evict pinned group {gi}")
        del self._resident[gi]
        self._resident_nbytes -= entry.nbytes
        if self._offload is not None:
            entry.host = self._offload(entry.state)
            self._offloaded[gi] = entry
        entry.state = None  # drop the device reference either way
        self.stats.n_evictions += 1
        self._on_event(gi, "evict")

    def clear(self) -> None:
        """Drop every unpinned resident state (keeping host copies)."""
        for gi in [g for g, e in self._resident.items() if e.pins == 0]:
            self.evict(gi)

    # ------------------------------------------------------------ versioning

    def invalidate(self, gi: int) -> None:
        """Bump group ``gi``'s version and drop every cached copy of it.

        The compaction-driven invalidation path: the group's stored bytes
        (device state *and* host offload copy) no longer describe its
        corpus, so both are discarded and the next ``acquire`` cold-builds
        at the new version.  Only this group is touched — other groups'
        cached states and every compiled step survive.  Raises while the
        group is pinned (a launch in flight must never lose its state).
        """
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is not None:
            if entry.pins:
                raise ValueError(f"cannot invalidate pinned group {gi}")
            del self._resident[gi]
            self._resident_nbytes -= entry.nbytes
            entry.state = None
        self._offloaded.pop(gi, None)
        self._versions[gi] = self.version_of(gi) + 1
        self.stats.n_invalidations += 1
        self._on_event(gi, "invalidate")

    def replace(self, gi: int, state: object, nbytes: int | None = None
                ) -> None:
        """Install ``state`` as group ``gi``'s new current version.

        The in-place compaction path: the caller has already produced the
        post-compaction state (``append_to_state`` on the leased old one),
        so instead of invalidate-then-rebuild the new state is installed
        directly at a bumped version — one version event, no cold build.
        Stale host copies are dropped; residency budgets are re-enforced
        against the (possibly re-priced) entry.  Raises while pinned.
        """
        gi = int(gi)
        entry = self._resident.get(gi)
        if entry is not None and entry.pins:
            raise ValueError(f"cannot replace pinned group {gi}")
        if entry is None:
            if nbytes is None:
                nbytes = self._nbytes_of(gi)
            self._evict_to_fit(nbytes)
            entry = _Entry(nbytes=nbytes)
            self._resident[gi] = entry
            self._resident_nbytes += nbytes
        elif nbytes is not None:
            self._resident_nbytes += nbytes - entry.nbytes
            entry.nbytes = nbytes
        self._offloaded.pop(gi, None)
        self._versions[gi] = self.version_of(gi) + 1
        entry.version = self._versions[gi]
        entry.state = state
        entry.host = None
        self._resident.move_to_end(gi)
        self.stats.n_invalidations += 1
        self._on_event(gi, "invalidate")
        self._enforce_budget()
