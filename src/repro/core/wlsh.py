"""WLSH index: Preprocess (Algorithm 1) + Search (Algorithm 2).

This module is the *paper-faithful* host implementation (numpy): hash tables
are per-function sorted code arrays; the search runs the C2LSH virtual-
rehashing level loop with incremental collision counting, so its work (and
the I/O metric we report) is proportional to the buckets actually probed —
exactly the quantity the paper's experiments measure.

The TPU-dense formulation (single-pass L_freq order statistic, Pallas
kernels, sharded execution) lives in ``repro.index`` / ``repro.kernels`` and
is cross-validated against this implementation in tests.

Glossary against the paper:
  * group            = S_i in the partition (one physical table group)
  * plan.betas/mus   = beta_{W_i}, mu_{W_i} from Eqs. 11-12
  * level j          = radius R = r_min^{W_i} * c^j, bucket = floor(h / c^j)
  * stop conditions  = (1) k (R,c)-WNNs found; (2) k + gamma*n candidates
                       checked at some radius
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .datagen import make_query_set  # noqa: F401  (re-export convenience)
from .distances import weighted_lp_np
from .families import LpFamilyParams, hash_codes_np, sample_lp_family
from .params import PlanConfig
from .partition import GroupPlan, PartitionResult, partition
from .serving_plan import GroupServingPlan, ServingPlan

__all__ = ["WLSHIndex", "SearchResult", "SearchStats", "BLOCK_BYTES"]

BLOCK_BYTES = 4096  # paper Sec. 5.1.3
_ENTRY_BYTES = 8  # (point id, code) per hash-table entry
_COORD_BYTES = 4


@dataclasses.dataclass
class SearchStats:
    stop_level: int
    n_checked: int  # candidates whose exact distance was computed
    n_collisions: int  # hash-table entries scanned (identify cost)
    io_blocks: float  # paper-style I/O: identify + check, in 4KB blocks
    found_k: bool


@dataclasses.dataclass
class SearchResult:
    ids: np.ndarray  # (k,) indices into the data set (-1 = missing)
    dists: np.ndarray  # (k,) distances under the query weight
    stats: SearchStats


@dataclasses.dataclass
class BuiltGroup:
    plan: GroupPlan
    fam: LpFamilyParams
    sorted_codes: np.ndarray  # (beta, n) int32, per-table ascending codes
    sorted_ids: np.ndarray  # (beta, n) int32, matching point ids
    codes: np.ndarray  # (n, beta) int32 raw codes (dense path / export)


class WLSHIndex:
    """Multi-weight (c, k)-WNN index over one data set.

    Parameters follow the paper: ``tau`` caps per-group tables, ``v/v_prime``
    enable bound relaxation (1/1 = strict Theorem 1), ``use_reduction``
    applies collision-threshold reduction at query time.
    """

    def __init__(
        self,
        data: np.ndarray,
        weights: np.ndarray,
        cfg: PlanConfig,
        tau: float,
        value_range: float = 10_000.0,
        v: int = 1,
        v_prime: int = 1,
        use_reduction: bool = True,
        seed: int = 0,
        materialize: bool = False,
    ):
        if abs(cfg.c - round(cfg.c)) > 1e-9 or cfg.c < 2:
            raise ValueError("virtual rehashing requires integer c >= 2")
        self.data = np.asarray(data, dtype=np.float32)
        self.weights = np.asarray(weights, dtype=np.float64)
        self.cfg = dataclasses.replace(cfg, n=len(self.data))
        self.tau = tau
        self.value_range = value_range
        self.v, self.v_prime = v, v_prime
        self.use_reduction = use_reduction
        self.seed = seed
        self.part: PartitionResult = partition(
            self.weights, self.cfg, value_range, tau, v=v, v_prime=v_prime
        )
        self._built: dict[int, BuiltGroup] = {}
        if materialize:
            for gi in range(len(self.part.groups)):
                self._group(gi)

    # ------------------------------------------------------------------ build

    @property
    def beta_total(self) -> int:
        return self.part.beta_total

    @property
    def n(self) -> int:
        return len(self.data)

    def _group(self, gi: int) -> BuiltGroup:
        if gi in self._built:
            return self._built[gi]
        plan = self.part.groups[gi]
        fam = sample_lp_family(
            d=self.data.shape[1],
            beta=plan.beta_group,
            p=self.cfg.p,
            width=plan.width,
            center_weight=self.weights[plan.center_id],
            ratio_cap=plan.ratio_cap,
            c=self.cfg.c,
            seed=self.seed + 7919 * gi,
        )
        codes = hash_codes_np(self.data, fam)  # (n, beta)
        order = np.argsort(codes, axis=0, kind="stable")  # (n, beta)
        sorted_codes = np.take_along_axis(codes, order, axis=0).T.copy()
        sorted_ids = order.T.astype(np.int32).copy()
        built = BuiltGroup(
            plan=plan,
            fam=fam,
            sorted_codes=sorted_codes,
            sorted_ids=sorted_ids,
            codes=codes,
        )
        self._built[gi] = built
        return built

    # ----------------------------------------------------------------- export

    def _effective_mus(self, plan: GroupPlan) -> np.ndarray:
        """Per-member integer collision thresholds (reduction applied)."""
        mus = plan.mus_reduced if self.use_reduction else plan.mus
        return np.maximum(1, np.ceil(mus - 1e-9)).astype(np.int32)

    def export_serving_plan(self, include_codes: bool = True) -> ServingPlan:
        """Flat, serializable description of every table group.

        This is the only core -> device handoff: the sharded engine and the
        retrieval service consume the plan, never `WLSHIndex` internals.
        ``include_codes`` ships the host-computed bucket codes so a device
        engine reproduces the host oracle's candidate sets exactly.
        """
        groups = []
        for gi in range(len(self.part.groups)):
            built = self._group(gi)
            plan = built.plan
            groups.append(
                GroupServingPlan(
                    group_id=gi,
                    center_id=int(plan.center_id),
                    beta_group=int(plan.beta_group),
                    width=float(built.fam.width),
                    levels_cap=int(built.fam.levels_cap),
                    member_ids=plan.member_ids.astype(np.int64),
                    beta_members=plan.betas.astype(np.int32),
                    mu_members=self._effective_mus(plan),
                    r_min_members=plan.r_min_members.astype(np.float64),
                    n_levels_members=plan.n_levels.astype(np.int32),
                    proj=built.fam.proj,
                    b_int=built.fam.b_int,
                    b_frac=built.fam.b_frac,
                    center_weight=built.fam.center_weight,
                    p=float(self.cfg.p),
                    codes=built.codes if include_codes else None,
                )
            )
        return ServingPlan(
            n=self.n,
            d=self.data.shape[1],
            p=float(self.cfg.p),
            c=int(round(self.cfg.c)),
            gamma_n=float(self.cfg.gamma_n),
            tau=float(self.part.tau),
            weights=self.weights.copy(),
            group_of=self.part.group_of.copy(),
            member_slot=self.part.member_slot.copy(),
            groups=tuple(groups),
            corpus_epoch=self.n,
        )

    # ----------------------------------------------------------------- search

    def _member_params(self, weight_id: int):
        gi = int(self.part.group_of[weight_id])
        built = self._group(gi)
        slot = int(self.part.member_slot[weight_id])
        plan = built.plan
        beta_i = int(plan.betas[slot])
        mu_i = int(self._effective_mus(plan)[slot])
        return built, slot, beta_i, mu_i

    @staticmethod
    def _c_eff(cfg_c: float, c: float | None) -> int:
        """Resolve an optional approximation-ratio override to int >= 2.

        Query-time ``c`` relaxation is the degradation ladder's oracle
        knob: the hash tables are c-independent (virtual rehashing only
        regroups buckets as ``code // c**j``), so a built index can be
        queried at any integer ratio >= the configured one without
        rebuilding — exactly what the serving ladder does via
        pre-compiled relaxed steps.
        """
        c_eff = cfg_c if c is None else c
        if c_eff != int(round(c_eff)) or int(round(c_eff)) < 2:
            raise ValueError(
                f"approximation ratio c must be an integer >= 2, got {c_eff}"
            )
        return int(round(c_eff))

    def search(
        self, q: np.ndarray, weight_id: int, k: int = 1,
        c: float | None = None,
    ) -> SearchResult:
        """(c, k)-WNN search under weight vector ``weight_id`` (Algorithm 2).

        Faithful C2LSH level loop with incremental collision counting over
        the group's first beta_{W_i} tables.  ``c`` optionally overrides
        the configured approximation ratio at query time (see ``_c_eff``).
        """
        built, slot, beta_i, mu_i = self._member_params(weight_id)
        plan = built.plan
        w_i = self.weights[weight_id]
        r_min = float(plan.r_min_members[slot])
        n_levels = int(plan.n_levels[slot])
        c = self._c_eff(self.cfg.c, c)
        n = self.n
        budget = k + int(math.ceil(self.cfg.gamma_n))  # == gamma * n, float-exact

        q = np.asarray(q, dtype=np.float32)
        q_codes = hash_codes_np(q[None, :], built.fam)[0][:beta_i]
        sc = built.sorted_codes[:beta_i]
        sids = built.sorted_ids[:beta_i]

        counts = np.zeros(n, dtype=np.int32)
        checked = np.zeros(n, dtype=bool)
        cand_ids: list[np.ndarray] = []
        cand_dists: list[np.ndarray] = []
        lo = np.empty(beta_i, dtype=np.int64)
        hi = np.empty(beta_i, dtype=np.int64)
        prev_lo = np.zeros(beta_i, dtype=np.int64)
        prev_hi = np.zeros(beta_i, dtype=np.int64)
        first = True
        n_collisions = 0
        n_checked = 0
        n_good = 0
        stop_level = n_levels
        found_k = False

        for j in range(n_levels + 1):
            l = c**j
            b_lo = (q_codes // l) * l  # level-j bucket = codes in [b_lo, b_lo+l)
            newly: list[np.ndarray] = []
            for t in range(beta_i):
                lo[t] = np.searchsorted(sc[t], b_lo[t], side="left")
                hi[t] = np.searchsorted(sc[t], b_lo[t] + l, side="left")
                if first:
                    seg = sids[t, lo[t] : hi[t]]
                    if seg.size:
                        newly.append(seg)
                else:
                    left = sids[t, lo[t] : prev_lo[t]]
                    right = sids[t, prev_hi[t] : hi[t]]
                    if left.size:
                        newly.append(left)
                    if right.size:
                        newly.append(right)
            first = False
            prev_lo[:] = lo
            prev_hi[:] = hi
            if newly:
                inc = np.concatenate(newly)
                n_collisions += inc.size
                np.add.at(counts, inc, 1)
            # identify frequent, not-yet-checked candidates
            freq = np.where((counts >= mu_i) & ~checked)[0]
            if freq.size:
                take = freq[: max(0, budget - n_checked)]
                if take.size:
                    d = weighted_lp_np(self.data[take], q, w_i, self.cfg.p)
                    checked[take] = True
                    n_checked += take.size
                    cand_ids.append(take)
                    cand_dists.append(d)
            R = r_min * (c**j)
            if cand_dists:
                all_d = np.concatenate(cand_dists)
                n_good = int(np.sum(all_d <= c * R))
            if n_good >= k or n_checked >= budget:
                stop_level = j
                found_k = n_good >= k
                break

        if cand_ids:
            ids = np.concatenate(cand_ids)
            dists = np.concatenate(cand_dists)
            top = np.argsort(dists, kind="stable")[:k]
            out_ids = np.full(k, -1, dtype=np.int64)
            out_d = np.full(k, np.inf)
            out_ids[: top.size] = ids[top]
            out_d[: top.size] = dists[top]
        else:
            out_ids = np.full(k, -1, dtype=np.int64)
            out_d = np.full(k, np.inf)

        blocks_identify = n_collisions / (BLOCK_BYTES / _ENTRY_BYTES)
        blocks_check = n_checked * max(
            1, math.ceil(self.data.shape[1] * _COORD_BYTES / BLOCK_BYTES)
        )
        stats = SearchStats(
            stop_level=stop_level,
            n_checked=n_checked,
            n_collisions=n_collisions,
            io_blocks=blocks_identify + blocks_check,
            found_k=found_k,
        )
        return SearchResult(ids=out_ids, dists=out_d, stats=stats)

    # ------------------------------------------------------------ dense oracle

    def search_dense(
        self, q: np.ndarray, weight_id: int, k: int = 1,
        c: float | None = None,
    ) -> SearchResult:
        """Single-pass dense search (the TPU formulation, numpy oracle).

        Computes jmin per (point, table), takes the mu-th order statistic to
        get L_freq, then applies the paper's stop conditions level-by-level
        analytically.  Must agree with ``search`` on the candidate *sets*;
        used to validate kernels and the sharded engine.  ``c`` optionally
        overrides the configured approximation ratio (see ``_c_eff``).
        """
        built, slot, beta_i, mu_i = self._member_params(weight_id)
        plan = built.plan
        w_i = self.weights[weight_id]
        r_min = float(plan.r_min_members[slot])
        n_levels = int(plan.n_levels[slot])
        c = self._c_eff(self.cfg.c, c)
        n = self.n
        budget = k + int(math.ceil(self.cfg.gamma_n))  # == gamma * n, float-exact

        q = np.asarray(q, dtype=np.float32)
        q_codes = hash_codes_np(q[None, :], built.fam)[0][:beta_i]
        codes = built.codes[:, :beta_i]

        jmin = np.full((n, beta_i), n_levels + 1, dtype=np.int16)
        a = codes.astype(np.int64).copy()
        b = q_codes.astype(np.int64).copy()
        for j in range(n_levels + 1):
            eq = (a == b[None, :]) & (jmin > n_levels)
            jmin[eq] = j
            a //= c
            b //= c
        if mu_i > beta_i:
            l_freq = np.full(n, n_levels + 1, dtype=np.int16)
        else:
            l_freq = np.partition(jmin, mu_i - 1, axis=1)[:, mu_i - 1]

        dists = weighted_lp_np(self.data, q, w_i, self.cfg.p)
        stop_level, n_checked, found_k = n_levels, 0, False
        for j in range(n_levels + 1):
            freq = l_freq <= j
            n_freq = int(np.sum(freq))
            n_chk = min(n_freq, budget)
            R = r_min * (c**j)
            n_good = int(np.sum(freq & (dists <= c * R)))
            if n_good >= k or n_chk >= budget:
                stop_level, n_checked, found_k = j, n_chk, n_good >= k
                break
            n_checked = n_chk
        freq = l_freq <= stop_level
        idx = np.where(freq)[0]
        top = idx[np.argsort(dists[idx], kind="stable")[:k]]
        out_ids = np.full(k, -1, dtype=np.int64)
        out_d = np.full(k, np.inf)
        out_ids[: top.size] = top
        out_d[: top.size] = dists[top]
        stats = SearchStats(
            stop_level=stop_level,
            n_checked=n_checked,
            n_collisions=int(np.sum(jmin <= stop_level)),
            io_blocks=float("nan"),
            found_k=found_k,
        )
        return SearchResult(ids=out_ids, dists=out_d, stats=stats)
