"""Weighted LSH families (paper Sec. 3.1) for the l_p distance.

The C2LSH-style family used by WLSH (Eq. 7):

    h_{a,b*,W}(x)   = floor((a . (W o x) + b*) / w)
    h^l_{a,b*,W}(x) = floor(h_{a,b*,W}(x) / l),   l in {c, c^2, ...}

``a`` has i.i.d. p-stable entries, ``w`` is the bucket width (set to
r_min^{W_center} in practice), and ``b*`` is uniform on [0, f*w] with
f = c^ceil(log_c r^S_max/min) so that virtual rehashing stays valid at all
levels (Lemma 1).

Numerical-exactness note (TPU adaptation): f*w can exceed float32's integer
resolution, which would corrupt bucket ids.  We therefore sample
``b*/w = b_int + b_frac`` with ``b_int`` an exact int32 uniform on [0, f) and
``b_frac`` uniform on [0, 1), and compute

    h = b_int + floor((a . (W o x)) / w + b_frac)

which equals floor((a.(W o x) + b*)/w) exactly (b_int is an integer shift of
bucket ids) while keeping every float intermediate small.  Level-l ids are
then exact integer divisions of int32 codes.

Hamming / angular weighted families (Appendix B) are provided for
completeness; the WLSH index itself targets l_p per the paper.
"""

from __future__ import annotations

import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np

from .pstable import sample_pstable_np

__all__ = ["LpFamilyParams", "sample_lp_family", "hash_codes_np", "hash_codes"]


@dataclasses.dataclass(frozen=True)
class LpFamilyParams:
    """beta sampled functions from H_{a,b*,W_center}."""

    proj: np.ndarray  # (d, beta) p-stable projection matrix
    b_int: np.ndarray  # (beta,) int32 exact part of b*/w
    b_frac: np.ndarray  # (beta,) float32 fractional part of b*/w
    width: float  # bucket width w
    p: float
    center_weight: np.ndarray  # (d,) W_center the tables were built for
    levels_cap: int  # f = c^ceil(log_c r^S_max/min)

    @property
    def beta(self) -> int:
        return self.proj.shape[1]

    @property
    def d(self) -> int:
        return self.proj.shape[0]


def sample_lp_family(
    d: int,
    beta: int,
    p: float,
    width: float,
    center_weight: np.ndarray,
    ratio_cap: float,
    c: float,
    seed: int = 0,
) -> LpFamilyParams:
    """Sample beta functions from H_{a,b*,W_center}.

    ``ratio_cap`` is r^{S_deg}_max/min — the largest r_max/r_min ratio over
    the weight vectors this table group must serve (Lemma 1 requires
    b* ~ U[0, c^ceil(log_c ratio_cap) * w]).
    """
    rng = np.random.default_rng(seed)
    f = int(
        round(c ** math.ceil(math.log(max(ratio_cap, 1.0 + 1e-9), c)))
    )
    f = max(f, 1)
    proj = sample_pstable_np(rng, p, (d, beta)).astype(np.float32)
    b_int = rng.integers(0, f, size=(beta,), dtype=np.int64).astype(np.int32)
    b_frac = rng.uniform(0.0, 1.0, size=(beta,)).astype(np.float32)
    return LpFamilyParams(
        proj=proj,
        b_int=b_int,
        b_frac=b_frac,
        width=float(width),
        p=p,
        center_weight=np.asarray(center_weight, dtype=np.float32),
        levels_cap=f,
    )


def hash_codes_np(points: np.ndarray, fam: LpFamilyParams) -> np.ndarray:
    """Level-1 bucket ids, (n, beta) int32 — numpy oracle."""
    x = np.asarray(points, dtype=np.float64) * fam.center_weight.astype(np.float64)
    u = x @ fam.proj.astype(np.float64) / fam.width + fam.b_frac.astype(np.float64)
    return (np.floor(u).astype(np.int64) + fam.b_int.astype(np.int64)).astype(
        np.int32
    )


def hash_codes(points, proj, b_int, b_frac, weight, width) -> jax.Array:
    """Level-1 bucket ids, (n, beta) int32 — JAX reference path.

    The Pallas kernel ``kernels/hash_encode.py`` fuses this; this function is
    the jnp fallback and the building block for the sharded index builder.
    """
    x = points * weight
    u = (x @ proj) / width + b_frac
    return jnp.floor(u).astype(jnp.int32) + b_int.astype(jnp.int32)


# ----------------------------------------------------------------------------
# Appendix B families (Hamming / angular) — host-side reference forms.
# ----------------------------------------------------------------------------


def sample_hamming_family(
    d: int, beta: int, weight: np.ndarray, seed: int = 0
) -> np.ndarray:
    """Indices k drawn with PMF w_k / sum(w); h(x) = w_k x_k (App. B)."""
    rng = np.random.default_rng(seed)
    w = np.asarray(weight, np.float64)
    return rng.choice(d, size=beta, p=w / w.sum())


def hamming_codes_np(points, ks, weight):
    return np.asarray(points)[:, ks] * np.asarray(weight)[ks]


def sample_angular_family(
    d: int, beta: int, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.standard_normal((d, beta))


def angular_codes_np(points, us, weight):
    """sign(u . (W o x)) in {0, 1}."""
    return (np.asarray(points) * np.asarray(weight) @ us >= 0).astype(np.int8)
