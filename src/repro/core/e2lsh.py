"""E2LSH (Indyk-Motwani / Datar et al.) baseline for the weighted l_p case.

Compound hash g = (h_1..h_m), L tables, hash tables re-created per radius
R in {r_min, c r_min, ...} (Sec. 2.3.1).  Parameterization:
m = ceil(log_{1/P2} n), L = ceil(n^rho), rho = ln(1/P1)/ln(1/P2).

Used in tests as a sanity baseline and by the benchmark suite to contrast
table counts; tables for one (weight, radius) pair at a time to bound memory.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .collision import collision_prob
from .distances import radius_bounds, weighted_lp_np
from .params import PlanConfig
from .pstable import sample_pstable_np

__all__ = ["E2LSH", "e2lsh_params"]


def e2lsh_params(n: int, w: float, c: float, p: float, R: float = 1.0):
    p1 = collision_prob(R, w, p)
    p2 = collision_prob(c * R, w, p)
    rho = math.log(1.0 / p1) / math.log(1.0 / p2)
    m = max(1, math.ceil(math.log(n) / math.log(1.0 / p2)))
    L = max(1, math.ceil(n**rho))
    return m, L, rho, p1, p2


@dataclasses.dataclass
class _RadiusTables:
    proj: np.ndarray  # (L, d, m)
    bias: np.ndarray  # (L, m)
    table: dict  # bucket tuple -> np.ndarray of ids  (per l in L: table[l])


class E2LSH:
    """Weighted E2LSH for a single weight vector (c-WNN baseline)."""

    def __init__(
        self,
        data: np.ndarray,
        weight: np.ndarray,
        cfg: PlanConfig,
        value_range: float = 10_000.0,
        width_mult: float = 4.0,
        max_tables: int = 64,
        seed: int = 0,
        t_factor: int = 3,
    ):
        self.data = np.asarray(data, np.float32)
        self.weight = np.asarray(weight, np.float64)
        self.cfg = dataclasses.replace(cfg, n=len(self.data))
        self.r_min, self.r_max = radius_bounds(self.weight, value_range, cfg.p)
        self.width = width_mult * self.r_min
        self.max_tables = max_tables
        self.seed = seed
        self.t_factor = t_factor  # check at most t*L candidates per radius
        self.n_levels = (
            math.ceil(math.log(self.r_max / self.r_min) / math.log(cfg.c)) + 1
        )
        self.m, self.L, self.rho, _, _ = e2lsh_params(
            len(self.data), self.width / self.r_min, cfg.c, cfg.p, R=1.0
        )
        self.L = min(self.L, max_tables)
        self._radius_tables: dict[int, _RadiusTables] = {}

    # Radius-j hashing uses width w * c^j (equivalent to rescaling R to 1).
    def _tables(self, j: int) -> _RadiusTables:
        if j in self._radius_tables:
            return self._radius_tables[j]
        rng = np.random.default_rng(self.seed + 104729 * j)
        d = self.data.shape[1]
        proj = sample_pstable_np(rng, self.cfg.p, (self.L, d, self.m)).astype(
            np.float32
        )
        w_j = self.width * (self.cfg.c**j)
        bias = rng.uniform(0, w_j, size=(self.L, self.m)).astype(np.float32)
        x = (self.data * self.weight).astype(np.float32)
        tables = []
        for l in range(self.L):
            codes = np.floor((x @ proj[l] + bias[l]) / w_j).astype(np.int64)
            tbl: dict = {}
            for i, key in enumerate(map(tuple, codes)):
                tbl.setdefault(key, []).append(i)
            tables.append({k: np.asarray(v) for k, v in tbl.items()})
        rt = _RadiusTables(proj=proj, bias=bias, table=tables)
        self._radius_tables[j] = rt
        return rt

    def query(self, q: np.ndarray, k: int = 1):
        q = np.asarray(q, np.float32)
        qw = q * self.weight
        seen: set[int] = set()
        results: list[tuple[float, int]] = []
        n_checked = 0
        for j in range(self.n_levels + 1):
            rt = self._tables(j)
            w_j = self.width * (self.cfg.c**j)
            R = self.r_min * (self.cfg.c**j)
            budget = self.t_factor * self.L
            got = 0
            for l in range(self.L):
                key = tuple(
                    np.floor((qw @ rt.proj[l] + rt.bias[l]) / w_j).astype(np.int64)
                )
                for i in rt.table[l].get(key, ()):  # type: ignore[index]
                    if i in seen:
                        continue
                    seen.add(int(i))
                    dist = float(
                        weighted_lp_np(self.data[i], q, self.weight, self.cfg.p)
                    )
                    n_checked += 1
                    got += 1
                    results.append((dist, int(i)))
                    if got >= budget:
                        break
                if got >= budget:
                    break
            good = [r for r in results if r[0] <= self.cfg.c * R]
            if len(good) >= k or got >= budget:
                break
        results.sort()
        ids = np.full(k, -1, dtype=np.int64)
        dists = np.full(k, np.inf)
        for i, (dist, pid) in enumerate(results[:k]):
            ids[i] = pid
            dists[i] = dist
        return ids, dists, n_checked
