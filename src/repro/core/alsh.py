"""SL-ALSH / S2-ALSH baselines (Lei et al., ICML'19) for the weighted l_2.

Two pieces, matching how the paper uses them:

1. **Space model** (Table 7 / Appendix A): rho_SL (Eq. 17) and rho_S2
   (Eq. 18) by numeric grid minimization; required tables L = n^rho.  Data
   are shifted/rescaled into [0, V]^d (V <= pi), so the radius R entering
   the formulas is R * V / value_range; eta_W = sqrt(d) * ||W/||W||_1||_2.

2. **Query path** (Table 8 / Figs. 8-9): the asymmetric reduction of
   weighted-l2 NN to MIPS via monomial augmentation

       P(o)    = [o^2, o, sqrt(1 - ||.||^2)] / scale      (data, W-independent)
       Q(q, W) = [-w^2, 2 w^2 * q, 0] (normalized)        (query, W-aware)

   so that Q.P is monotone in -D_W(q,o)^2.  SL-ALSH hashes the augmented
   sphere with the p-stable l_2 family (compound m, L tables); S2-ALSH uses
   sign random projections (SimHash) — consistent with the collision
   probabilities appearing in Eqs. 17-18.  Following the paper's protocol
   (Table 12), queries are answered under a *candidate budget* matched to
   WLSH's I/O, sweeping m and keeping the best ratio.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .collision import collision_prob_l2
from .distances import weighted_lp_np
from .params import PlanConfig

__all__ = ["rho_sl", "rho_s2", "alsh_tables", "ALSHIndex"]


def _eta(weights: np.ndarray, d: int) -> np.ndarray:
    w = np.asarray(weights, np.float64)
    w = w / np.sum(np.abs(w), axis=-1, keepdims=True)  # ||W||_1 = 1
    return math.sqrt(d) * np.linalg.norm(w, axis=-1)


def rho_sl(
    weights: np.ndarray,
    R: float,
    c: float,
    value_range: float = 10_000.0,
    w_grid=None,
    v_grid=None,
) -> float:
    """Eq. 17: min over (w, V) of max over W_i of ln P1 / ln P2."""
    d = weights.shape[1]
    eta = _eta(weights, d)
    w_grid = np.geomspace(0.25, 64.0, 25) if w_grid is None else w_grid
    v_grid = np.linspace(0.5, math.pi, 24) if v_grid is None else v_grid
    best = np.inf
    for V in v_grid:
        r = R * V / value_range
        if c * r - V**4 / 12.0 <= r:
            continue
        a1 = np.sqrt(np.maximum(2.0 * eta - 2.0 + r, 1e-12))
        a2 = np.sqrt(np.maximum(2.0 * eta - 2.0 + c * r - V**4 / 12.0, 1e-12))
        for w in w_grid:
            p1 = np.clip(collision_prob_l2(a1, w), 1e-12, 1 - 1e-12)
            p2 = np.clip(collision_prob_l2(a2, w), 1e-12, 1 - 1e-12)
            rho = float(np.max(np.log(p1) / np.log(p2)))
            if 0 < rho < best:
                best = rho
    return best


def rho_s2(
    weights: np.ndarray,
    R: float,
    c: float,
    value_range: float = 10_000.0,
    v_grid=None,
) -> float:
    """Eq. 18: min over V of max over W_i of ln P1 / ln P2 (SimHash form)."""
    d = weights.shape[1]
    eta = _eta(weights, d)
    v_grid = np.linspace(0.5, math.pi, 48) if v_grid is None else v_grid
    best = np.inf
    for V in v_grid:
        r = R * V / value_range
        if c * r - V**4 / 12.0 <= r:
            continue
        x1 = np.clip((1.0 - 0.5 * r) / eta, -1.0, 1.0)
        x2 = np.clip((1.0 - 0.5 * c * r + V**4 / 24.0) / eta, -1.0, 1.0)
        p1 = np.clip(1.0 - np.arccos(x1) / math.pi, 1e-12, 1 - 1e-12)
        p2 = np.clip(1.0 - np.arccos(x2) / math.pi, 1e-12, 1 - 1e-12)
        rho = float(np.max(np.log(p1) / np.log(p2)))
        if 0 < rho < best:
            best = rho
    return best


def alsh_tables(n: int, rho: float) -> int:
    """Required total number of hash tables, L = n^rho (Appendix A)."""
    return int(math.ceil(n**rho))


# --------------------------------------------------------------------------
# Query path: augmented MIPS reduction + (E2LSH | SimHash) on the sphere.
# --------------------------------------------------------------------------


def _augment_data(data: np.ndarray) -> tuple[np.ndarray, float]:
    """Appendix A preconditions: data rescaled into [0, V]^d (V <= pi) by the
    caller; monomial augmentation then stays O(1) per coordinate."""
    o = np.asarray(data, np.float64)
    P = np.concatenate([o**2, o], axis=1)
    scale = float(np.max(np.linalg.norm(P, axis=1))) or 1.0
    P = P / scale
    last = np.sqrt(np.maximum(1.0 - np.sum(P**2, axis=1), 0.0))
    return np.concatenate([P, last[:, None]], axis=1).astype(np.float32), scale


def _augment_query(q: np.ndarray, weight: np.ndarray) -> np.ndarray:
    w2 = np.asarray(weight, np.float64) ** 2
    Q = np.concatenate([-w2, 2.0 * w2 * np.asarray(q, np.float64), [0.0]])
    nrm = np.linalg.norm(Q) or 1.0
    return (Q / nrm).astype(np.float32)


@dataclasses.dataclass
class _Tables:
    proj: np.ndarray  # (L, D, m)
    bias: np.ndarray | None  # (L, m) for SL; None for S2
    codes: np.ndarray  # (L, n, m) per-table compound codes


class ALSHIndex:
    """SL-ALSH (variant='sl') or S2-ALSH (variant='s2') query engine.

    Candidate generation is a *dense multiprobe oracle*: points are ranked
    by total compound-code agreement with the query across all L tables
    (sum over tables of #matching hash dims), and the top ``budget`` are
    checked.  Any physical probing sequence with the same budget retrieves a
    subset of candidates no better-ordered than this, so the baselines'
    reported accuracy is an upper bound — the same only-favors-the-baseline
    stance the paper takes for their table counts (Sec. 5.2.2).
    """

    def __init__(
        self,
        data: np.ndarray,
        cfg: PlanConfig,
        variant: str = "sl",
        m: int = 12,
        L: int = 16,
        width: float = 1.0,
        seed: int = 0,
        value_range: float = 10_000.0,
        V: float = math.pi,
    ):
        assert variant in ("sl", "s2")
        self.data = np.asarray(data, np.float32)
        self.cfg = cfg
        self.variant = variant
        self.m, self.L, self.width = m, L, width
        # Appendix A: rescale data into [0, V]^d, V <= pi (ranking under any
        # W is invariant to the common rescale; weights are L1-normalized at
        # query time).
        self._rescale = V / float(value_range)
        self.aug, self._scale = _augment_data(self.data * self._rescale)
        rng = np.random.default_rng(seed)
        D = self.aug.shape[1]
        proj = rng.standard_normal((L, D, m)).astype(np.float32)
        bias = None
        if variant == "sl":
            bias = rng.uniform(0, width, size=(L, m)).astype(np.float32)
        codes = np.empty((L, len(self.data), m), np.int32)
        for l in range(L):
            u = self.aug @ proj[l]
            if variant == "sl":
                codes[l] = np.floor((u + bias[l]) / width).astype(np.int32)
            else:
                codes[l] = (u >= 0).astype(np.int32)
        self.tables = _Tables(proj=proj, bias=bias, codes=codes)

    def _query_codes(self, aq: np.ndarray) -> np.ndarray:
        """(L, m) compound code of the (augmented) query."""
        u = np.einsum("d,ldm->lm", aq, self.tables.proj)
        if self.variant == "sl":
            return np.floor((u + self.tables.bias) / self.width).astype(
                np.int32
            )
        return (u >= 0).astype(np.int32)

    def query(self, q: np.ndarray, weight: np.ndarray, k: int, budget: int):
        """Check up to ``budget`` candidates; return (ids, dists, n_checked)."""
        w1 = np.asarray(weight, np.float64)
        w1 = w1 / np.sum(np.abs(w1))  # ||W||_1 = 1 (Appendix A)
        aq = _augment_query(np.asarray(q, np.float64) * self._rescale, w1)
        qc = self._query_codes(aq)  # (L, m)
        # agreement score per point: sum over tables/dims of matching hashes
        score = np.einsum(
            "lnm->n", (self.tables.codes == qc[:, None, :]).astype(np.int32)
        )
        budget = min(budget, len(self.data))
        cand = np.argpartition(-score, budget - 1)[:budget]
        ids = np.full(k, -1, dtype=np.int64)
        dists = np.full(k, np.inf)
        d = weighted_lp_np(self.data[cand], q, weight, 2.0)
        top = np.argsort(d, kind="stable")[:k]
        ids[: top.size] = cand[top]
        dists[: top.size] = d[top]
        return ids, dists, len(cand)
