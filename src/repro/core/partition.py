"""Partitioning S into table-sharing groups (paper Sec. 4.2, Function
Partition) via maximal candidate subsets + greedy weighted set cover.

Pipeline:
  1. Pairwise plan: for every candidate center W_i, compute the derived
     beta_{W_k | center=i} for every target W_k (Eq. 11 with bucket width
     w = r_min^{W_i}); infeasible pairs (x_up >= y_down) get beta = inf.
  2. Candidate sets: for each center, sort targets by beta; every maximal
     prefix with weight = j-th smallest beta <= tau is a candidate set
     (condition (2) of Step 1 — only prefixes at distinct beta values).
  3. Greedy weighted set cover (Chvatal '79, O(ln|S|) approx): repeatedly
     pick the (center, prefix) minimizing weight / #newly-covered.
  4. Deduplicate into a disjoint partition; recompute per-group parameters.

The O(|S|^2 d) pairwise reduction is the planning hot spot; it runs through
a chunked jax.jit (derived.ratio_bounds).  Benchmarks default to CPU-scaled
sizes; paper-scale |S| = 5k remains tractable (~minutes on one core).
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .derived import derived_sensitivity, ratio_bounds
from .distances import radius_bounds
from .params import PlanConfig, beta_mu, threshold_reduction_factor

__all__ = ["GroupPlan", "PartitionResult", "pairwise_beta", "partition", "tau_min"]


@dataclasses.dataclass
class GroupPlan:
    center_id: int
    member_ids: np.ndarray  # indices into S, ascending beta
    betas: np.ndarray  # per-member beta_{W_i}
    mus: np.ndarray  # per-member collision threshold mu_{W_i}
    mus_reduced: np.ndarray  # after collision-threshold reduction
    beta_group: int  # max over members (tables to build)
    width: float  # bucket width w = r_min^{W_center}
    ratio_cap: float  # r^{S_i}_max/min (b* range, Lemma 1)
    n_levels: np.ndarray  # per-member ceil(log_c r_max/r_min) + 1
    r_min_members: np.ndarray  # per-member r_min^{W_i}


@dataclasses.dataclass
class PartitionResult:
    groups: list[GroupPlan]
    group_of: np.ndarray  # (|S|,) group index for every weight vector
    member_slot: np.ndarray  # (|S|,) position inside the group
    beta_total: int
    tau: float
    n_candidate_sets: int


def _per_weight_radii(weights: np.ndarray, value_range: float, p: float):
    r_min = np.empty(len(weights))
    r_max = np.empty(len(weights))
    for i, w in enumerate(weights):
        r_min[i], r_max[i] = radius_bounds(w, value_range, p)
    return r_min, r_max


def pairwise_beta(
    weights: np.ndarray,
    cfg: PlanConfig,
    value_range: float,
    v: int = 1,
    v_prime: int = 1,
    tau: float | None = None,
):
    """B[i, k] = beta_{W_k | center=i} (inf if infeasible or > tau).

    Also returns (r_min, r_max) per weight vector and the up-bounded radius
    X_UP[i, k] = (r_min^{W_k})^up used later for threshold reduction.
    """
    m = len(weights)
    r_min, r_max = _per_weight_radii(weights, value_range, cfg.p)
    B = np.empty((m, m))
    XUP = np.empty((m, m))
    for i in range(m):
        hi, lo = ratio_bounds(weights[i], weights, v=v, v_prime=v_prime)
        x = r_min
        y = cfg.c * r_min
        x_up, y_down, useful = derived_sensitivity(x, y, hi, lo)
        beta = np.full(m, np.inf)
        if useful.any():
            cap = int(tau) if tau is not None and np.isfinite(tau) else None
            b, _, _, _ = beta_mu(
                x_up[useful], y_down[useful], r_min[i], cfg, beta_cap=cap
            )
            beta[useful] = b
        B[i] = beta
        XUP[i] = x_up
    return B, XUP, r_min, r_max


def tau_min(B: np.ndarray) -> float:
    """max_i beta_{W_i | center=i}: each vector served by its own group."""
    return float(np.max(np.diag(B)))


def _greedy_wsc(B_sorted, order, tau: float):
    """Greedy weighted set cover over nested prefix candidates.

    B_sorted[i, j] = (j+1)-th smallest beta for center i (== prefix weight);
    order[i, j] = target index at that rank.  Returns list of
    (center, prefix_len) chosen sets, in selection order.
    """
    m = B_sorted.shape[0]
    uncovered = np.ones(m, dtype=bool)
    chosen: list[tuple[int, int]] = []
    valid = B_sorted <= tau  # (m, m) prefix admissible
    while uncovered.any():
        # newly-covered count per (center, prefix): cumsum of uncovered in
        # sorted order, zeroed where the prefix is inadmissible.
        unc_sorted = uncovered[order]  # (m, m)
        gain = np.cumsum(unc_sorted, axis=1)
        with np.errstate(divide="ignore", invalid="ignore"):
            eff = np.where(valid & (gain > 0), B_sorted / gain, np.inf)
        flat = np.argmin(eff)
        ci, pj = np.unravel_index(flat, eff.shape)
        if not np.isfinite(eff[ci, pj]):
            raise ValueError(
                "no admissible candidate set covers the remaining weight "
                "vectors; increase tau (>= tau_min)"
            )
        chosen.append((int(ci), int(pj) + 1))
        uncovered[order[ci, : pj + 1]] = False
    return chosen


def partition(
    weights: np.ndarray,
    cfg: PlanConfig,
    value_range: float,
    tau: float,
    v: int = 1,
    v_prime: int = 1,
) -> PartitionResult:
    """Function Partition() + Process(): disjoint groups minimizing beta_S."""
    m = len(weights)
    B, XUP, r_min, r_max = pairwise_beta(
        weights, cfg, value_range, v=v, v_prime=v_prime, tau=tau
    )
    tmin = tau_min(B)
    if tau < tmin:
        raise ValueError(f"tau={tau} < tau_min={tmin}; no feasible partition")

    order = np.argsort(B, axis=1, kind="stable")
    B_sorted = np.take_along_axis(B, order, axis=1)
    n_candidates = int(np.sum(B_sorted <= tau))
    chosen = _greedy_wsc(B_sorted, order, tau)

    # Deduplicate: assign each weight vector to the chosen set with the
    # smallest required beta for it (paper Step 3).
    group_of = np.full(m, -1, dtype=np.int64)
    best_beta = np.full(m, np.inf)
    for gi, (ci, pj) in enumerate(chosen):
        members = order[ci, :pj]
        betas = B[ci, members]
        better = betas < best_beta[members]
        sel = members[better]
        group_of[sel] = gi
        best_beta[sel] = betas[better]
    assert (group_of >= 0).all()

    groups: list[GroupPlan] = []
    member_slot = np.zeros(m, dtype=np.int64)
    kept = 0
    remap = {}
    for gi, (ci, _) in enumerate(chosen):
        members = np.where(group_of == gi)[0]
        if len(members) == 0:
            continue
        remap[gi] = kept
        kept += 1
        members = members[np.argsort(B[ci, members], kind="stable")]
        betas = B[ci, members]
        # Recompute mu on the exact member set (Eq. 12).
        hi, lo = ratio_bounds(weights[ci], weights[members], v=v, v_prime=v_prime)
        x_up, y_down, _ = derived_sensitivity(
            r_min[members], cfg.c * r_min[members], hi, lo
        )
        _, mus, _, _ = beta_mu(x_up, y_down, r_min[ci], cfg)
        xfac = threshold_reduction_factor(x_up, cfg.c, r_min[ci], cfg.p)
        n_levels = (
            np.ceil(
                np.log(np.maximum(r_max[members] / r_min[members], 1.0 + 1e-9))
                / math.log(cfg.c)
            ).astype(np.int64)
            + 1
        )
        ratio_cap = float(np.max(r_max[members] / r_min[members]))
        member_slot[members] = np.arange(len(members))
        groups.append(
            GroupPlan(
                center_id=int(ci),
                member_ids=members,
                betas=betas,
                mus=mus,
                mus_reduced=np.maximum(xfac * mus, 1.0),
                beta_group=int(np.max(betas)),
                width=float(r_min[ci]),
                ratio_cap=ratio_cap,
                n_levels=n_levels,
                r_min_members=r_min[members],
            )
        )
    group_of = np.array([remap[g] for g in group_of], dtype=np.int64)
    beta_total = int(sum(g.beta_group for g in groups))
    return PartitionResult(
        groups=groups,
        group_of=group_of,
        member_slot=member_slot,
        beta_total=beta_total,
        tau=tau,
        n_candidate_sets=n_candidates,
    )
