"""p-stable distributions: sampling, densities, and |X| PDFs.

The p-stable family underlies the l_p LSH functions (Datar et al., SoCG'04):
``h(x) = floor((a.x + b)/w)`` with entries of ``a`` drawn i.i.d. from the
symmetric p-stable distribution.  p=2 is the standard normal, p=1 is the
standard Cauchy; general p in (0,2) has no closed-form density and is
sampled with the Chambers-Mallows-Stuck (CMS) method and evaluated
numerically via the characteristic-function inversion

    f_p(x) = (1/pi) * int_0^inf cos(t x) exp(-t^p) dt.

Host-side evaluation uses numpy (these quantities feed index *planning*,
Eqs. 11-12, not the device hot path); sampling has a JAX version used when
generating projection matrices on device.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "sample_pstable",
    "sample_pstable_np",
    "pstable_pdf",
    "pstable_pdf_abs",
]


def _cms_transform(p: float, v, e, xp):
    """Chambers-Mallows-Stuck transform for symmetric p-stable.

    v ~ Uniform(-pi/2, pi/2), e ~ Exp(1).  Works for p in (0, 2]; p == 1
    reduces to tan(v) (Cauchy), p == 2 reduces to a scaled normal.
    """
    if abs(p - 1.0) < 1e-9:
        return xp.tan(v)
    if abs(p - 2.0) < 1e-9:
        # CMS at p=2 yields N(0, 2); rescale to the standard normal used by
        # the classical E2LSH family.
        s = xp.sin(2.0 * v) / xp.cos(v) ** (1.0 / 2.0) * (
            xp.cos(-v) / e
        ) ** ((1.0 - 2.0) / 2.0)
        return s / np.sqrt(2.0)
    s = (
        xp.sin(p * v)
        / xp.cos(v) ** (1.0 / p)
        * (xp.cos((1.0 - p) * v) / e) ** ((1.0 - p) / p)
    )
    return s


def sample_pstable(key: jax.Array, p: float, shape) -> jax.Array:
    """Draw i.i.d. symmetric p-stable samples (JAX)."""
    if abs(p - 2.0) < 1e-9:
        return jax.random.normal(key, shape)
    if abs(p - 1.0) < 1e-9:
        return jax.random.cauchy(key, shape)
    kv, ke = jax.random.split(key)
    v = jax.random.uniform(
        kv, shape, minval=-jnp.pi / 2 + 1e-7, maxval=jnp.pi / 2 - 1e-7
    )
    e = jax.random.exponential(ke, shape) + 1e-12
    return _cms_transform(p, v, e, jnp)


def sample_pstable_np(rng: np.random.Generator, p: float, shape) -> np.ndarray:
    """Draw i.i.d. symmetric p-stable samples (numpy, host-side)."""
    if abs(p - 2.0) < 1e-9:
        return rng.standard_normal(shape)
    if abs(p - 1.0) < 1e-9:
        return rng.standard_cauchy(shape)
    v = rng.uniform(-np.pi / 2 + 1e-12, np.pi / 2 - 1e-12, shape)
    e = rng.exponential(1.0, shape) + 1e-300
    return _cms_transform(p, v, e, np)


@functools.lru_cache(maxsize=64)
def _pdf_grid(p: float, umax: float, n_grid: int):
    """Tabulate f_p on [0, umax] via FFT characteristic-function inversion.

    f(x) = (1/pi) int_0^inf cos(tx) exp(-t^p) dt.  A plain quadrature
    aliases badly for small p (slow exp(-t^p) decay x fast cos(tx)
    oscillation); sampling t on the FFT-conjugate grid makes every
    oscillation exactly resolved: with t_j = j*dt, x_k = 2 pi k/(N dt),
    sum_j g_j cos(t_j x_k) = Re FFT(g)[k].
    """
    del n_grid  # grid density is set by the FFT length below
    # integrand support: cut where exp(-t^p) < 1e-12
    t_hi = (12.0 * np.log(10.0)) ** (1.0 / p)
    dt = np.pi / (1.05 * umax)  # x-range covers umax with margin
    n = int(2 ** np.ceil(np.log2(max(t_hi / dt, 4096.0))))
    t = np.arange(n) * dt
    g = np.exp(-(t**p))
    spec = np.fft.rfft(g)
    # trapezoid: half-weight the j=0 endpoint
    f = (np.real(spec) - 0.5 * g[0]) * dt / np.pi
    x = np.arange(len(f)) * (2.0 * np.pi / (n * dt))
    keep = x <= umax
    return x[keep], np.maximum(f[keep], 0.0)


def pstable_pdf(x, p: float, umax: float = 200.0, n_grid: int = 8192):
    """Density of the symmetric p-stable distribution (numpy, vectorized).

    Closed forms for p in {1, 2}; numeric inversion otherwise.  The numeric
    tail beyond ``umax`` is approximated by the exact asymptotic power law
    f_p(x) ~ p * sin(pi p / 2) * Gamma(p) / pi * x^{-(1+p)}.
    """
    x = np.abs(np.asarray(x, dtype=np.float64))
    if abs(p - 2.0) < 1e-9:
        return np.exp(-(x**2) / 2.0) / np.sqrt(2.0 * np.pi)
    if abs(p - 1.0) < 1e-9:
        return 1.0 / (np.pi * (1.0 + x**2))
    u, f = _pdf_grid(p, umax, n_grid)
    out = np.interp(x, u, f)
    try:  # pragma: no cover - scipy is available in this environment
        from scipy.special import gamma as _gamma

        tail = p * np.sin(np.pi * p / 2.0) * _gamma(p) / np.pi * np.where(
            x > 0, x, 1.0
        ) ** (-(1.0 + p))
        out = np.where(x > umax, tail, out)
    except Exception:
        pass
    return out


def pstable_pdf_abs(x, p: float):
    """PDF F_p of |X| for X symmetric p-stable (the paper's F_p)."""
    x = np.asarray(x, dtype=np.float64)
    return np.where(x >= 0, 2.0 * pstable_pdf(x, p), 0.0)
