"""Synthetic data / weight-vector-set / query-set generators (paper Sec 5.1.1).

* Data sets: integer points uniform in [0, value_range]^d  (Table 3).
* Weight vector sets: union of ``n_subset`` equal-size subsets.  [1, 10] is
  split into ``n_subrange`` equal-width subranges; each subset picks one
  subrange per dimension uniformly at random and then draws its vectors'
  coordinates uniformly inside the chosen subrange (Table 5).
* Query sets: Cartesian product of ``n_query_points`` points removed from
  the data set with ``n_query_weights`` weight vectors from S.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["make_dataset", "make_weight_set", "make_query_set", "QuerySet"]


def make_dataset(
    n: int, d: int, value_range: float = 10_000.0, seed: int = 0
) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, int(value_range) + 1, size=(n, d)).astype(np.float32)


def make_weight_set(
    size: int,
    d: int,
    n_subset: int = 200,
    n_subrange: int = 20,
    lo: float = 1.0,
    hi: float = 10.0,
    seed: int = 1,
) -> np.ndarray:
    """Weight vector set S per the paper's generator.

    ``n_subset == size`` and ``n_subrange == 1`` reduces to uniformly random
    weight vectors on [lo, hi]^d (used by Table 8 / Table 11).
    """
    if size % n_subset != 0:
        n_subset = max(1, min(n_subset, size))
    per = max(1, size // n_subset)
    rng = np.random.default_rng(seed)
    edges = np.linspace(lo, hi, n_subrange + 1)
    out = np.empty((n_subset * per, d), dtype=np.float64)
    for s in range(n_subset):
        sub = rng.integers(0, n_subrange, size=d)
        lo_d, hi_d = edges[sub], edges[sub + 1]
        out[s * per : (s + 1) * per] = rng.uniform(lo_d, hi_d, size=(per, d))
    return out[:size]


@dataclasses.dataclass
class QuerySet:
    points: np.ndarray  # (nq, d) query points (removed from data)
    weights: np.ndarray  # (nw, d) query weight vectors (subset of S)
    weight_ids: np.ndarray  # (nw,) indices into S
    data: np.ndarray  # data set with query points removed


def make_query_set(
    data: np.ndarray,
    weight_set: np.ndarray,
    n_query_points: int = 50,
    n_query_weights: int = 10,
    seed: int = 2,
) -> QuerySet:
    rng = np.random.default_rng(seed)
    qi = rng.choice(len(data), size=min(n_query_points, len(data)), replace=False)
    wi = rng.choice(
        len(weight_set), size=min(n_query_weights, len(weight_set)), replace=False
    )
    mask = np.ones(len(data), dtype=bool)
    mask[qi] = False
    return QuerySet(
        points=data[qi].copy(),
        weights=weight_set[wi].copy(),
        weight_ids=wi,
        data=data[mask],
    )
