"""C2LSH/WLSH parameter planning: beta / mu from Eqs. 4-5 and 11-12.

For a weight vector W_i served by tables centered at W_center:

    z    = sqrt(ln(2/gamma) / ln(1/eps))
    beta = ceil( ln(1/eps) / (2 (P(x_up) - P(y_down))^2) * (1+z)^2 )
    mu   = (z P(x_up) + P(y_down)) / (1+z) * beta

with x = r_min^{W_i}, y = c x, and x_up / y_down the derived-family bounds
(x_up = x, y_down = y when W_i == W_center, recovering C2LSH Eqs. 4-5).

``P`` is the collision probability at bucket width w (paper sets
w = r_min^{W_center}).  Collision-threshold reduction (Sec. 4.2.1) scales mu
by X = P((c^2 r)^up) / P((r)^up) < 1.

Defaults follow the paper: eps = 0.01, gamma = 100/n.
"""

from __future__ import annotations

import dataclasses
import math

import numpy as np

from .collision import collision_prob

__all__ = ["PlanConfig", "beta_mu", "threshold_reduction_factor", "z_value"]


@dataclasses.dataclass(frozen=True)
class PlanConfig:
    p: float = 2.0
    c: float = 3.0
    eps: float = 0.01
    gamma_n: float = 100.0  # gamma * n (paper: gamma = 100/n)
    n: int = 400_000

    @property
    def gamma(self) -> float:
        return self.gamma_n / self.n

    @property
    def z(self) -> float:
        return z_value(self.eps, self.gamma)


def z_value(eps: float, gamma: float) -> float:
    return math.sqrt(math.log(2.0 / gamma) / math.log(1.0 / eps))


def beta_mu(
    x_up,
    y_down,
    width,
    cfg: PlanConfig,
    beta_cap: int | None = None,
):
    """Vectorized Eqs. 11-12.

    Returns (beta, mu, p1, p2) arrays; entries where the derived family is
    useless (P(x_up) <= P(y_down)) get beta = inf.
    ``width`` may be scalar or per-entry (bucket width of the serving group).
    """
    x_up = np.atleast_1d(np.asarray(x_up, np.float64))
    y_down = np.atleast_1d(np.asarray(y_down, np.float64))
    width = np.broadcast_to(np.asarray(width, np.float64), x_up.shape)
    z = cfg.z
    p1 = np.empty_like(x_up)
    p2 = np.empty_like(x_up)
    # collision_prob is vectorized over r at fixed w; group by distinct widths
    for wv in np.unique(width):
        m = width == wv
        p1[m] = collision_prob(x_up[m], float(wv), cfg.p)
        p2[m] = collision_prob(y_down[m], float(wv), cfg.p)
    gap = p1 - p2
    ok = gap > 1e-12
    ln1e = math.log(1.0 / cfg.eps)
    beta = np.full(x_up.shape, np.inf)
    beta[ok] = np.ceil(ln1e / (2.0 * gap[ok] ** 2) * (1.0 + z) ** 2)
    if beta_cap is not None:
        beta = np.where(beta > beta_cap, np.inf, beta)
    mu = np.where(ok, (z * p1 + p2) / (1.0 + z) * beta, np.inf)
    return beta, mu, p1, p2


def threshold_reduction_factor(r_up, c: float, width, p: float):
    """X = P((c^2 r)^up) / P((r)^up) < 1 (Sec. 4.2.1).

    ``r_up`` is (r_min^{W_i})^up under the serving group's center; the c^2
    scaling commutes with the up-bound for l_p (Theorem 1(1) is linear in R).
    """
    r_up = np.asarray(r_up, np.float64)
    num = collision_prob(c * c * r_up, float(width), p)
    den = collision_prob(r_up, float(width), p)
    return np.clip(num / np.maximum(den, 1e-300), 0.0, 1.0)
