"""Weighted distance functions (Definition 4) for l_p, Hamming, angular.

JAX implementations (used on-device for candidate verification) plus numpy
mirrors for host-side exact ground truth in tests/benchmarks.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = [
    "weighted_lp",
    "weighted_lp_np",
    "weighted_hamming_np",
    "weighted_angular_np",
    "radius_bounds",
]


def weighted_lp(x, y, weight, p: float):
    """D_W(x, y) for the l_p distance; broadcasts over leading dims (JAX)."""
    diff = jnp.abs((x - y) * weight)
    if abs(p - 2.0) < 1e-9:
        return jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    if abs(p - 1.0) < 1e-9:
        return jnp.sum(diff, axis=-1)
    return jnp.sum(diff**p, axis=-1) ** (1.0 / p)


def weighted_lp_np(x, y, weight, p: float):
    diff = np.abs((np.asarray(x, np.float64) - np.asarray(y, np.float64)) * weight)
    if abs(p - 2.0) < 1e-9:
        return np.sqrt(np.sum(diff * diff, axis=-1))
    if abs(p - 1.0) < 1e-9:
        return np.sum(diff, axis=-1)
    return np.sum(diff**p, axis=-1) ** (1.0 / p)


def weighted_hamming_np(x, y, weight):
    """Weighted Hamming: sum of w_i over differing coordinates (App. B)."""
    return np.sum(np.asarray(weight) * (np.asarray(x) != np.asarray(y)), axis=-1)


def weighted_angular_np(x, y, weight):
    wx = np.asarray(x, np.float64) * weight
    wy = np.asarray(y, np.float64) * weight
    num = np.sum(wx * wy, axis=-1)
    den = np.linalg.norm(wx, axis=-1) * np.linalg.norm(wy, axis=-1)
    return np.arccos(np.clip(num / np.maximum(den, 1e-300), -1.0, 1.0))


def radius_bounds(weight, value_range: float, p: float, grid: float = 1.0):
    """(r_min^W, r_max^W): smallest/largest possible distances under W.

    The paper's data are integer-valued in [0, value_range] (Tables 3-4), so
    the smallest nonzero weighted l_p distance is ``min_i w_i * grid`` (two
    points differing by one grid step in the cheapest coordinate) and the
    largest is ``(sum_i (w_i * value_range)^p)^(1/p)``.
    """
    w = np.asarray(weight, dtype=np.float64)
    r_min = float(np.min(w)) * grid
    r_max = float(np.sum((w * value_range) ** p) ** (1.0 / p))
    return r_min, r_max
