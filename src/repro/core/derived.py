"""Derived weighted LSH families: Theorem 1 bounds + bound relaxation.

Given tables built for center weight W and a query weight W', the derived
family H_{W->W'} hashes identically but its sensitivity bounds shrink:

  l_p:  R^up = R * max_i(w_i / w'_i),   (cR)^down = cR * min_i(w_i / w'_i)

Bound relaxation (Eqs. 14-15) replaces max/min with the v-th largest /
v'-th smallest of T = {w_i / w'_i}; v = v' = 1 recovers Theorem 1.  The
derived family is *useful* iff x^up < y^down for x = r_min^{W'},
y = c r_min^{W'}.

All functions are vectorized over a batch of target weight vectors so the
partition step can evaluate O(|S|^2) pairs cheaply; the heavy ratio
reduction runs through jax.jit on CPU in chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["ratio_bounds", "derived_sensitivity", "angular_bounds"]


@functools.partial(jax.jit, static_argnames=("v", "v_prime"))
def _ratio_reduce(center: jax.Array, targets: jax.Array, v: int, v_prime: int):
    """(hi, lo) where hi = v-th largest, lo = v'-th smallest of w_i/w'_i."""
    t = center[None, :] / targets  # (m, d)
    if v == 1 and v_prime == 1:
        return jnp.max(t, axis=-1), jnp.min(t, axis=-1)
    hi = jax.lax.top_k(t, v)[0][:, -1]
    lo = -jax.lax.top_k(-t, v_prime)[0][:, -1]
    return hi, lo


def ratio_bounds(
    center: np.ndarray,
    targets: np.ndarray,
    v: int = 1,
    v_prime: int = 1,
    chunk: int = 4096,
) -> tuple[np.ndarray, np.ndarray]:
    """T^{(v)} and T^{(d+1-v')} per target weight vector (Eqs. 14-15)."""
    targets = np.atleast_2d(np.asarray(targets, np.float64))
    center = np.asarray(center, np.float64)
    his, los = [], []
    for i in range(0, len(targets), chunk):
        h, l = _ratio_reduce(
            jnp.asarray(center), jnp.asarray(targets[i : i + chunk]), v, v_prime
        )
        his.append(np.asarray(h))
        los.append(np.asarray(l))
    return np.concatenate(his), np.concatenate(los)


def derived_sensitivity(
    x: np.ndarray, y: np.ndarray, hi: np.ndarray, lo: np.ndarray
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(x_up, y_down, useful) for the derived family at radii (x, y=c x).

    x_up = x * hi, y_down = y * lo (Theorem 2); useful iff 0 < x_up < y_down.
    """
    x_up = np.asarray(x) * hi
    y_down = np.asarray(y) * lo
    useful = (x_up > 0) & (x_up < y_down)
    return x_up, y_down, useful


def angular_bounds(center, target, R: float, c: float):
    """Theorem 1(3) bounds for the angular distance (reference only)."""
    t2 = (np.asarray(center, np.float64) / np.asarray(target, np.float64)) ** 2
    M, N = float(np.max(t2)), float(np.min(t2))
    X = np.cos(R) + (N - M) / M
    Y = M * np.cos(c * R) / N + (M - N) / N
    r_up = np.arccos(max(-1.0, X))
    cr_down = np.arccos(min(1.0, Y))
    return r_up, cr_down
