"""C2LSH (Gan et al., SIGMOD'12) as the single-weight special case of WLSH.

WLSH with |S| = 1 degenerates exactly to C2LSH for the weighted distance
D_W (Sec. 2.3.2): x_up = x, y_down = y, one group, beta/mu from Eqs. 4-5.
Provided as a named class because the paper treats C2LSH as both substrate
and baseline.
"""

from __future__ import annotations

import numpy as np

from .params import PlanConfig
from .wlsh import WLSHIndex

__all__ = ["C2LSH"]


class C2LSH(WLSHIndex):
    def __init__(
        self,
        data: np.ndarray,
        cfg: PlanConfig,
        weight: np.ndarray | None = None,
        value_range: float = 10_000.0,
        use_reduction: bool = True,
        seed: int = 0,
        tau: float | None = None,
    ):
        d = np.asarray(data).shape[1]
        w = np.ones(d) if weight is None else np.asarray(weight, np.float64)
        super().__init__(
            data=data,
            weights=w[None, :],
            cfg=cfg,
            tau=float("inf") if tau is None else tau,
            value_range=value_range,
            v=1,
            v_prime=1,
            use_reduction=use_reduction,
            seed=seed,
            materialize=True,
        )

    def query(self, q: np.ndarray, k: int = 1):
        return self.search(q, weight_id=0, k=k)
