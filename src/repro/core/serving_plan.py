"""Serializable per-group serving plans: the core -> engine handoff.

`WLSHIndex` plans groups with host-side internals (`GroupPlan`,
`LpFamilyParams`, float64 math).  The device layers (``repro.index``,
``repro.serving``) must not reach into those; instead the planner exports a
``ServingPlan`` — a flat, numpy-only, npz-serializable description of every
table group:

  * routing:    ``group_of`` / ``member_slot`` (weight id -> group, slot)
  * per member: beta_{W_i}, effective integer mu_{W_i} (threshold reduction
                already applied), r_min^{W_i}, n_levels
  * per group:  the sampled family (raw projection + exact b* split) plus
                the *folded* form (center weight and bucket width folded
                into the projection) consumed by the sharded builder
  * optionally the host-computed bucket codes, so an engine can serve with
    bit-identical candidate sets to the host oracle (float32 re-encoding
    on device flips ~0.5% of codes at floor boundaries)

Everything downstream of this module treats the plan as the source of
truth; nothing imports `WLSHIndex` internals.
"""

from __future__ import annotations

import dataclasses
import json
import typing

import numpy as np

from .families import LpFamilyParams, hash_codes_np

__all__ = ["GroupServingPlan", "MemberParams", "ServingPlan"]


class MemberParams(typing.NamedTuple):
    """Resolved query-time parameters for one weight vector."""

    group: int
    slot: int
    beta: int  # beta_{W_i}: tables this member probes
    mu: int  # effective integer collision threshold
    r_min: float  # radius base r_min^{W_i}
    n_levels: int  # virtual-rehashing levels for this member


@dataclasses.dataclass(frozen=True)
class GroupServingPlan:
    """One table group, self-contained (family + per-member parameters)."""

    group_id: int
    center_id: int  # weight id of the group's center W_center
    beta_group: int  # tables materialized (max member beta)
    width: float  # bucket width w = r_min^{W_center}
    levels_cap: int  # f = c^ceil(log_c ratio_cap) (Lemma 1 b* range)
    member_ids: np.ndarray  # (m,) int64 weight ids, ascending beta
    beta_members: np.ndarray  # (m,) int32
    mu_members: np.ndarray  # (m,) int32 effective integer thresholds
    r_min_members: np.ndarray  # (m,) float64
    n_levels_members: np.ndarray  # (m,) int32
    proj: np.ndarray  # (d, beta_group) f32 raw p-stable projection
    b_int: np.ndarray  # (beta_group,) int32 exact part of b*/w
    b_frac: np.ndarray  # (beta_group,) f32 fractional part of b*/w
    center_weight: np.ndarray  # (d,) f32
    p: float
    codes: np.ndarray | None = None  # (n, beta_group) int32 host codes

    @property
    def n_members(self) -> int:
        """Number of weight vectors served by this group."""
        return len(self.member_ids)

    @property
    def n_levels_max(self) -> int:
        """Largest member level cap (the group's compiled loop bound)."""
        return int(np.max(self.n_levels_members))

    @property
    def d(self) -> int:
        """Dimensionality of the indexed points."""
        return self.proj.shape[0]

    def family(self) -> LpFamilyParams:
        """Reconstruct the sampled family (for host-exact re-encoding)."""
        return LpFamilyParams(
            proj=self.proj,
            b_int=self.b_int,
            b_frac=self.b_frac,
            width=self.width,
            p=self.p,
            center_weight=self.center_weight,
            levels_cap=self.levels_cap,
        )

    def folded(self) -> dict[str, np.ndarray]:
        """Center weight + width folded into the projection (device form).

        With the folded projection both data and queries hash at unit
        weight/width: codes = floor(x @ proj_folded + b_frac) + b_int.
        """
        proj = (
            self.proj.astype(np.float64)
            * self.center_weight[:, None].astype(np.float64)
            / self.width
        )
        return dict(
            proj=proj.astype(np.float32),
            b_int=self.b_int.astype(np.int32),
            b_frac=self.b_frac.astype(np.float32),
            width=np.float32(1.0),
        )

    def encode_host(self, points: np.ndarray) -> np.ndarray:
        """(n, beta_group) int32 bucket codes, host-exact (float64) path."""
        return hash_codes_np(np.atleast_2d(points), self.family())


@dataclasses.dataclass(frozen=True)
class ServingPlan:
    """Every group of a WLSH index, plus the weight -> group routing.

    ``version`` and ``corpus_epoch`` track the plan's streaming lineage:
    a freshly exported plan is version 0 at epoch ``n``; every compaction
    of delta segments into a group state bumps ``version`` and advances
    ``corpus_epoch`` to the total number of rows ever absorbed into the
    serving corpus (base rows plus compacted inserts).  The fields ride
    through the npz round-trip, so a persisted plan records how far its
    index has drifted from the base export.
    """

    n: int  # data-set size the plan was derived for
    d: int
    p: float
    c: int
    gamma_n: float  # gamma * n (query budget = k + ceil(gamma_n))
    tau: float
    weights: np.ndarray  # (|S|, d) float64 — the weight vector set S
    group_of: np.ndarray  # (|S|,) int64
    member_slot: np.ndarray  # (|S|,) int64
    groups: tuple[GroupServingPlan, ...]
    version: int = 0  # bumped once per delta compaction
    corpus_epoch: int = 0  # total rows absorbed (0 = base export, == n)

    @property
    def n_groups(self) -> int:
        """Number of table groups in the plan."""
        return len(self.groups)

    @property
    def n_weights(self) -> int:
        """Size of the weight vector set S the plan covers."""
        return len(self.group_of)

    @property
    def beta_total(self) -> int:
        """Total hash tables materialized across all groups."""
        return int(sum(g.beta_group for g in self.groups))

    def member_params(self, weight_id: int) -> MemberParams:
        """Resolve one weight id to its (group, slot) query parameters."""
        gi = int(self.group_of[weight_id])
        slot = int(self.member_slot[weight_id])
        g = self.groups[gi]
        return MemberParams(
            group=gi,
            slot=slot,
            beta=int(g.beta_members[slot]),
            mu=int(g.mu_members[slot]),
            r_min=float(g.r_min_members[slot]),
            n_levels=int(g.n_levels_members[slot]),
        )

    def bumped(self, n_absorbed: int) -> "ServingPlan":
        """Copy of the plan after one compaction of ``n_absorbed`` rows.

        ``version`` increments by one; ``corpus_epoch`` advances by the
        absorbed row count (from ``n`` when the plan was still at its
        base export).  The group parameters themselves are untouched —
        compaction re-hashes with the original family seeds.
        """
        base = self.corpus_epoch if self.corpus_epoch else self.n
        return dataclasses.replace(
            self,
            version=self.version + 1,
            corpus_epoch=base + int(n_absorbed),
        )

    # ------------------------------------------------------------- serialize

    _META_FIELDS = ("n", "d", "p", "c", "gamma_n", "tau", "version",
                    "corpus_epoch")
    _GROUP_SCALARS = (
        "group_id", "center_id", "beta_group", "width", "levels_cap", "p",
    )
    _GROUP_ARRAYS = (
        "member_ids", "beta_members", "mu_members", "r_min_members",
        "n_levels_members", "proj", "b_int", "b_frac", "center_weight",
    )

    def save_npz(self, path: str) -> None:
        """Write the plan to ``path`` as a flat compressed npz archive.

        Arrays are stored verbatim (dtypes preserved exactly — the
        round-trip regression test pins this, it is what makes a reloaded
        plan serve bit-identically); scalars travel in an embedded JSON
        blob.  Per-group host codes are included only when present.
        """
        meta = {f: getattr(self, f) for f in self._META_FIELDS}
        meta["n_groups"] = self.n_groups
        payload: dict[str, np.ndarray] = {
            "meta_json": np.frombuffer(
                json.dumps(meta).encode(), dtype=np.uint8
            ),
            "weights": self.weights,
            "group_of": self.group_of,
            "member_slot": self.member_slot,
        }
        for g in self.groups:
            pre = f"g{g.group_id}."
            for f in self._GROUP_SCALARS:
                payload[pre + f] = np.asarray(getattr(g, f))
            for f in self._GROUP_ARRAYS:
                payload[pre + f] = getattr(g, f)
            if g.codes is not None:
                payload[pre + "codes"] = g.codes
        np.savez_compressed(path, **payload)

    @classmethod
    def load_npz(cls, path: str) -> "ServingPlan":
        """Rebuild a ``ServingPlan`` saved by ``save_npz``, bit-exactly."""
        with np.load(path) as z:
            meta = json.loads(bytes(z["meta_json"].tobytes()).decode())
            groups = []
            for gi in range(int(meta.pop("n_groups"))):
                pre = f"g{gi}."
                kw = {f: z[pre + f].item() for f in cls._GROUP_SCALARS}
                kw.update({f: z[pre + f] for f in cls._GROUP_ARRAYS})
                if pre + "codes" in z.files:
                    kw["codes"] = z[pre + "codes"]
                groups.append(GroupServingPlan(**kw))
            return cls(
                n=int(meta["n"]),
                d=int(meta["d"]),
                p=float(meta["p"]),
                c=int(meta["c"]),
                gamma_n=float(meta["gamma_n"]),
                tau=float(meta["tau"]),
                weights=z["weights"],
                group_of=z["group_of"],
                member_slot=z["member_slot"],
                groups=tuple(groups),
                # absent in archives written before the streaming layer
                version=int(meta.get("version", 0)),
                corpus_epoch=int(meta.get("corpus_epoch", 0)),
            )
