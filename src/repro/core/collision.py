"""Collision probability functions P_{l_p}(r) for the p-stable LSH family.

P_{l_p}(r) = int_0^w (1/r) F_p(t/r) (1 - t/w) dt     (paper Sec. 2.2)

with F_p the PDF of |X| for symmetric p-stable X.  Closed forms exist for
p = 2 (Gaussian) and p = 1 (Cauchy) [Datar et al. '04]:

  p=2:  P(r) = 1 - 2 Phi(-w/r) - 2/(sqrt(2 pi) w/r) (1 - exp(-w^2/(2 r^2)))
  p=1:  P(r) = 2 arctan(w/r)/pi - 1/(pi w/r) ln(1 + (w/r)^2)

General p in (0,2) is evaluated with fixed quadrature over the numeric
p-stable density.  All functions are numpy (host-side planning math) and
vectorized over r.

Assumption 1 of the paper (P decreasing in r) holds for every family here;
``tests/test_collision.py`` checks it property-style.
"""

from __future__ import annotations

import numpy as np

from .pstable import pstable_pdf_abs

__all__ = ["collision_prob", "collision_prob_l2", "collision_prob_l1"]

_SQRT2PI = np.sqrt(2.0 * np.pi)


def _norm_cdf(x):
    from math import erf  # noqa: F401  (scalar fallback)

    try:
        from scipy.special import ndtr

        return ndtr(x)
    except Exception:  # pragma: no cover
        from numpy import vectorize

        return vectorize(lambda t: 0.5 * (1.0 + np.math.erf(t / np.sqrt(2.0))))(x)


def collision_prob_l2(r, w: float):
    """Closed-form P_{l_2}(r) for bucket width w."""
    r = np.asarray(r, dtype=np.float64)
    s = w / np.maximum(r, 1e-300)
    return (
        1.0
        - 2.0 * _norm_cdf(-s)
        - 2.0 / (_SQRT2PI * s) * (1.0 - np.exp(-(s**2) / 2.0))
    )


def collision_prob_l1(r, w: float):
    """Closed-form P_{l_1}(r) for bucket width w."""
    r = np.asarray(r, dtype=np.float64)
    s = w / np.maximum(r, 1e-300)
    return 2.0 * np.arctan(s) / np.pi - np.log1p(s**2) / (np.pi * s)


def _collision_prob_numeric(r, w: float, p: float, n_quad: int = 512):
    r = np.atleast_1d(np.asarray(r, dtype=np.float64))
    t = np.linspace(0.0, w, n_quad)
    # integrand(r, t) = (1/r) F_p(t/r) (1 - t/w)
    tr = t[None, :] / r[:, None]
    f = pstable_pdf_abs(tr, p)
    integ = f / r[:, None] * (1.0 - t[None, :] / w)
    out = np.trapezoid(integ, t, axis=1)
    return out


def collision_prob(r, w: float, p: float):
    """P_{l_p}(r): probability two points at l_p distance r collide.

    Vectorized over ``r``; scalar in ``w`` (bucket width) and ``p``.
    """
    if w <= 0:
        raise ValueError(f"bucket width must be positive, got {w}")
    if not (0.0 < p <= 2.0):
        raise ValueError(f"p must be in (0, 2], got {p}")
    scalar = np.isscalar(r) or np.ndim(r) == 0
    if abs(p - 2.0) < 1e-9:
        out = collision_prob_l2(r, w)
    elif abs(p - 1.0) < 1e-9:
        out = collision_prob_l1(r, w)
    else:
        out = _collision_prob_numeric(r, w, p)
    out = np.clip(out, 0.0, 1.0)
    return float(np.asarray(out).reshape(-1)[0]) if scalar else out
