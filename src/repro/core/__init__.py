"""WLSH core: the paper's contribution as a composable library.

Layers:
  * math substrate — p-stable sampling/densities, collision probabilities
  * LSH families — weighted (Sec. 3.1) and derived (Sec. 3.2, Theorem 1)
  * planning — Eqs. 11-12 parameters, bound relaxation, threshold reduction
  * partition — maximal candidate subsets + greedy weighted set cover
  * index — WLSHIndex (Preprocess/Search), C2LSH/E2LSH/SL-/S2-ALSH baselines
"""

from .alsh import ALSHIndex, alsh_tables, rho_s2, rho_sl
from .c2lsh import C2LSH
from .collision import collision_prob
from .datagen import make_dataset, make_query_set, make_weight_set
from .derived import derived_sensitivity, ratio_bounds
from .distances import radius_bounds, weighted_lp, weighted_lp_np
from .e2lsh import E2LSH
from .families import LpFamilyParams, hash_codes, hash_codes_np, sample_lp_family
from .params import PlanConfig, beta_mu, threshold_reduction_factor
from .partition import PartitionResult, pairwise_beta, partition, tau_min
from .pstable import pstable_pdf, pstable_pdf_abs, sample_pstable
from .serving_plan import GroupServingPlan, MemberParams, ServingPlan
from .wlsh import WLSHIndex

__all__ = [
    "ALSHIndex",
    "C2LSH",
    "E2LSH",
    "GroupServingPlan",
    "LpFamilyParams",
    "MemberParams",
    "PartitionResult",
    "PlanConfig",
    "ServingPlan",
    "WLSHIndex",
    "alsh_tables",
    "beta_mu",
    "collision_prob",
    "derived_sensitivity",
    "hash_codes",
    "hash_codes_np",
    "make_dataset",
    "make_query_set",
    "make_weight_set",
    "pairwise_beta",
    "partition",
    "pstable_pdf",
    "pstable_pdf_abs",
    "radius_bounds",
    "ratio_bounds",
    "rho_s2",
    "rho_sl",
    "sample_lp_family",
    "sample_pstable",
    "tau_min",
    "threshold_reduction_factor",
    "weighted_lp",
    "weighted_lp_np",
]
