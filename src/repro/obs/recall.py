"""Online recall telemetry: sampled shadow-exact re-ranking.

WLSH's contract is a *provable* recall/efficiency trade-off per
weighted l_p query, but latency and cost counters alone cannot say
whether delivered recall still meets the guarantee once degradation,
compaction, sharding and paging interact.  The
:class:`RecallEstimator` closes that loop:

* a **deterministic sampler** — :func:`should_sample` hashes the
  span's query id (splitmix64 finalizer, no wall randomness), so the
  same traffic yields the same sampled set across the sync, async and
  driver-stepped frontends and across reruns;
* a **shadow queue** — sampled queries are enqueued as
  :class:`ShadowJob`\\ s (host copies of the query, its weight and the
  served ids) at answer time; enqueueing is the only serving-path
  work, so sampling is bit-invisible to results;
* **off-path execution** — ``run()`` pops jobs and re-ranks each
  against the exact host oracle (``scan_topk`` over the group's full
  visible corpus: live base rows + compacted + pending, tombstones
  filtered).  The async frontend drains a small slice per
  ``idle_work()`` tick, so shadow work never competes with deadline
  launches;
* **registry results** — per-(tenant, rung, p, group) counters
  (``wlsh_recall_hits_total`` / ``wlsh_recall_relevant_total`` /
  ``wlsh_recall_samples_total``), the micro-averaged
  ``wlsh_recall_observed`` gauge, the ``wlsh_recall_bound_margin``
  gauge (observed − the rung's planned ``recall_bound``), and a
  per-sample recall histogram.  Each job also stamps its recall onto
  the originating ``TraceSpan``.

The estimate is **exactly** reproducible offline: recall is
micro-averaged (``sum(matched) / sum(relevant)`` over integer counts),
and the oracle is the same ``scan_topk`` float32 scan an offline
checker would run — so ``estimate()`` equals the offline oracle
computation on the same sampled set bit-for-bit.
"""

from __future__ import annotations

import threading
from collections import deque

import numpy as np

__all__ = ["RecallEstimator", "ShadowJob", "sample_hash", "should_sample"]

_MASK64 = (1 << 64) - 1

# per-sample recall distribution buckets (recall lives in [0, 1])
RECALL_BUCKETS: tuple[float, ...] = (
    0.1, 0.2, 0.3, 0.4, 0.5, 0.6, 0.7, 0.8, 0.9, 0.95, 1.0,
)


def sample_hash(query_id: int) -> int:
    """Deterministic 64-bit mix of a query id (splitmix64 finalizer).

    A pure function of the id: no seed, no clock, no process state —
    the sampling decision for query ``i`` is identical across
    frontends, replays and machines.
    """
    x = (int(query_id) + 0x9E3779B97F4A7C15) & _MASK64
    x = ((x ^ (x >> 30)) * 0xBF58476D1CE4E5B9) & _MASK64
    x = ((x ^ (x >> 27)) * 0x94D049BB133111EB) & _MASK64
    return x ^ (x >> 31)

def should_sample(query_id: int, rate: float) -> bool:
    """True when ``query_id`` falls in the sampled fraction ``rate``.

    Threshold test on :func:`sample_hash`, so the sampled set is
    monotone in ``rate``: every id sampled at rate r is also sampled
    at every r' >= r (useful when comparing sampling configurations).
    """
    if rate <= 0.0:
        return False
    if rate >= 1.0:
        return True
    return sample_hash(query_id) < int(rate * 2.0 ** 64)


class ShadowJob:
    """One sampled query queued for exact-oracle re-ranking."""

    __slots__ = ("query_id", "tenant", "rung", "group_id", "weight_id",
                 "query", "served_ids", "span")

    def __init__(self, span, query, weight_id, group_id, rung,
                 served_ids):
        """Capture host copies of everything the oracle pass needs."""
        self.span = span
        self.query_id = int(span.query_id)
        self.tenant = span.tenant
        self.rung = int(rung)
        self.group_id = int(group_id)
        self.weight_id = int(weight_id)
        self.query = np.array(query, np.float32, copy=True)
        self.served_ids = np.array(served_ids, np.int64, copy=True)


class RecallEstimator:
    """Sampled shadow-exact recall estimation over a ``Batcher``.

    Construction reads the batcher's ``ServiceConfig`` knobs
    (``recall_sample_rate`` / ``recall_shadow_max`` /
    ``recall_shadow_slice``) and registers its metrics on the
    batcher's registry.  ``offer`` runs on the serving path (enqueue
    only); ``run``/``drain`` execute the oracle passes off-path.
    Thread-safe like the registry: one lock guards the queue, so the
    thread-mode driver can drain while the submit thread offers.
    """

    def __init__(self, batcher):
        """Attach to ``batcher``; see the class docstring."""
        self.batcher = batcher
        cfg = batcher.cfg
        self.rate = float(cfg.recall_sample_rate)
        self.shadow_max = int(cfg.recall_shadow_max)
        self.slice = int(cfg.recall_shadow_slice)
        self._queue: deque[ShadowJob] = deque()
        self._lock = threading.Lock()
        # executed sampled query ids, bounded (determinism tests and
        # the --health report; not needed for the estimate itself)
        self._executed_ids: deque[int] = deque(maxlen=65536)
        m = batcher.metrics
        self._samples = m.counter(
            "wlsh_recall_samples_total",
            "shadow jobs executed (oracle re-ranks)")
        self._hits = m.counter(
            "wlsh_recall_hits_total",
            "served ids found in the exact oracle top-k")
        self._relevant = m.counter(
            "wlsh_recall_relevant_total",
            "exact oracle top-k slots (micro-average denominator)")
        self._offered = m.counter(
            "wlsh_recall_offers_total",
            "served queries that hashed into the sample")
        self._dropped = m.counter(
            "wlsh_recall_shadow_dropped_total",
            "sampled queries dropped on a full shadow queue")
        self._observed = m.gauge(
            "wlsh_recall_observed",
            "micro-averaged shadow-exact recall per series")
        self._margin = m.gauge(
            "wlsh_recall_bound_margin",
            "observed recall minus the rung's planned recall bound")
        self._depth = m.gauge(
            "wlsh_recall_shadow_depth", "shadow jobs queued")
        self._hist = m.histogram(
            "wlsh_recall_sample",
            "per-sample shadow-exact recall distribution",
            buckets=RECALL_BUCKETS)

    # ------------------------------------------------------- serving path

    def offer(self, span, query, weight_id, group_id, rung,
              served_ids) -> bool:
        """Sample-test one served query; enqueue a shadow job if it hits.

        Called by ``Batcher.run_batch`` per real row.  Never touches
        the answer arrays; a full queue drops the job (counted) rather
        than growing unbounded.  Returns True when enqueued.
        """
        if not should_sample(span.query_id, self.rate):
            return False
        labels = self._labels(span.tenant, rung, group_id)
        self._offered.inc(**labels)
        job = ShadowJob(span, query, weight_id, group_id, rung,
                        served_ids)
        with self._lock:
            if len(self._queue) >= self.shadow_max:
                self._dropped.inc(**labels)
                return False
            self._queue.append(job)
            self._depth.set(len(self._queue))
        return True

    @property
    def backlog(self) -> int:
        """Shadow jobs queued and not yet executed."""
        with self._lock:
            return len(self._queue)

    # ----------------------------------------------------------- off path

    def run(self, max_jobs: int | None = None) -> int:
        """Execute up to ``max_jobs`` queued shadow jobs (None = all).

        Host-only work (numpy scan over the group's visible corpus):
        safe to call from an idle tick without perturbing any launch.
        Returns the number of jobs executed.
        """
        done = 0
        while max_jobs is None or done < max_jobs:
            with self._lock:
                if not self._queue:
                    break
                job = self._queue.popleft()
                self._depth.set(len(self._queue))
            self._execute(job)
            done += 1
        return done

    def drain(self) -> int:
        """Execute every queued shadow job; returns the count."""
        return self.run(None)

    def _labels(self, tenant, rung, group_id) -> dict:
        """Canonical label set for one series."""
        return {"tenant": tenant or "default", "rung": str(int(rung)),
                "p": str(float(self.batcher.plan.p)),
                "group": str(int(group_id))}

    def oracle_topk(self, query, weight_id: int,
                    group_id: int) -> np.ndarray:
        """Exact host-oracle top-k ids for one query against one group.

        ``scan_topk`` (the engine's own exact-scan epilogue: float32
        coordinate-difference distances, stable composite-key
        selection) over the group's full visible corpus.  Without a
        delta index that corpus is the base plan; with one it is
        ``DeltaIndex.visible_rows`` (live base + compacted + pending,
        tombstones filtered).
        """
        # deferred: keep `import repro.obs` free of the jax-backed
        # index package until an oracle pass actually runs
        from ..index.streaming import scan_topk

        b = self.batcher
        delta = b.delta
        if delta is None:
            ids = np.arange(int(b.plan.n), dtype=np.int64)
            vecs = np.asarray(b.points)
        else:
            ids, vecs = delta.visible_rows(group_id)
        q_w = np.asarray(b.plan.weights)[int(weight_id)]
        oids, _ = scan_topk(
            np.asarray(query, np.float32)[None],
            np.asarray(q_w, np.float32)[None],
            ids, vecs, float(b.plan.p), int(b.cfg.k),
        )
        return oids[0]

    def _execute(self, job: ShadowJob) -> None:
        """Run one oracle pass and publish its recall."""
        exact = self.oracle_topk(job.query, job.weight_id, job.group_id)
        exact_set = {int(i) for i in exact if i >= 0}
        served_set = {int(i) for i in job.served_ids if i >= 0}
        relevant = len(exact_set)
        matched = len(served_set & exact_set)
        r = (matched / relevant) if relevant else 1.0
        labels = self._labels(job.tenant, job.rung, job.group_id)
        self._samples.inc(**labels)
        self._hits.inc(matched, **labels)
        self._relevant.inc(relevant, **labels)
        self._hist.observe(r, **labels)
        hits = self._hits.value(**labels)
        rel = self._relevant.value(**labels)
        observed = (hits / rel) if rel else 1.0
        self._observed.set(observed, **labels)
        self._margin.set(
            observed - self.batcher.recall_bound_of(job.rung), **labels)
        if job.span is not None:
            job.span.recall = r
        with self._lock:
            self._executed_ids.append(job.query_id)

    # ------------------------------------------------------------ reading

    def executed_ids(self) -> list[int]:
        """Query ids of executed shadow jobs, execution order (bounded)."""
        with self._lock:
            return list(self._executed_ids)

    def estimate(self, **match) -> float:
        """Micro-averaged observed recall over matching series.

        ``match`` filters by label (e.g. ``rung="1"``,
        ``tenant="gold"``); no filter aggregates everything.  Returns
        ``sum(hits) / sum(relevant)`` — two exact integer counts, so
        the value reproduces bit-for-bit offline — or NaN with no
        samples.
        """
        want = {k: str(v) for k, v in match.items()}

        def _fold(counter) -> float:
            tot = 0.0
            for key, v in counter.series().items():
                labels = dict(kv.split("=", 1)
                              for kv in key.split(",") if kv)
                if all(labels.get(k) == s for k, s in want.items()):
                    tot += v
            return tot

        rel = _fold(self._relevant)
        return (_fold(self._hits) / rel) if rel else float("nan")

    def summary(self) -> dict:
        """One JSON-safe dict: rates, backlog, per-rung estimates."""
        rungs = sorted({
            key.split("rung=", 1)[1].split(",", 1)[0]
            for key in self._relevant.series()
            if "rung=" in key
        })
        return {
            "sample_rate": self.rate,
            "backlog": self.backlog,
            "n_sampled": int(self._offered.total()),
            "n_executed": int(self._samples.total()),
            "n_dropped": int(self._dropped.total()),
            "observed": {
                r: self.estimate(rung=r) for r in rungs
            },
            "bound": {
                r: self.batcher.recall_bound_of(int(r)) for r in rungs
            },
        }
