"""Observability layer: metrics registry, per-query traces, profiling.

Zero-dependency (stdlib + optional ``jax.profiler``) building blocks
threaded through the serving stack:

* :mod:`repro.obs.metrics` — typed counters / gauges / fixed-bucket
  histograms in a thread-safe :class:`MetricsRegistry`; Prometheus-style
  text exposition, JSON snapshot, tick-to-tick diffs.  All four legacy
  stats surfaces (``Batcher.stats``, ``CacheStats``, ``DriverStats``,
  ``TenantStats``) are thin views over this registry.
* :mod:`repro.obs.trace` — per-query :class:`TraceSpan` lifecycle
  (``submit -> route -> admit -> queue -> prefetch/restore -> launch ->
  merge -> resolve``) on the injectable clock, ring-buffered by
  :class:`Tracer` with JSONL export.
* :mod:`repro.obs.profile` — scoped wrappers around ``jax.profiler``
  plus per-step compile-count and dispatch-time attribution keyed by
  ``IndexConfig.shape_signature()``.

Tracing and profiling are gated behind ``ServiceConfig.obs`` (off by
default, bit-exact on or off); the metrics registry always exists — the
stats surfaces need it — and never touches device values.
"""

from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .profile import Profiler
from .trace import STAGES, Tracer, TraceSpan

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "Profiler",
    "STAGES",
    "TraceSpan",
    "Tracer",
]
