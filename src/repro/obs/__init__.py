"""Observability layer: metrics, traces, profiling, quality telemetry.

Zero-dependency (stdlib + optional ``jax.profiler``) building blocks
threaded through the serving stack:

* :mod:`repro.obs.metrics` — typed counters / gauges / fixed-bucket
  histograms in a thread-safe :class:`MetricsRegistry`; Prometheus-style
  text exposition, JSON snapshot, tick-to-tick diffs.  All four legacy
  stats surfaces (``Batcher.stats``, ``CacheStats``, ``DriverStats``,
  ``TenantStats``) are thin views over this registry.
* :mod:`repro.obs.trace` — per-query :class:`TraceSpan` lifecycle
  (``submit -> route -> admit -> queue -> prefetch/restore -> launch ->
  merge -> resolve``) on the injectable clock, ring-buffered by
  :class:`Tracer` with JSONL export and exact drop accounting.
* :mod:`repro.obs.profile` — scoped wrappers around ``jax.profiler``
  plus per-step compile-count and dispatch-time attribution keyed by
  ``IndexConfig.shape_signature()``.
* :mod:`repro.obs.recall` — online quality telemetry: a deterministic
  hash sampler feeding shadow jobs that re-rank served answers against
  the exact host oracle off the serving path
  (:class:`RecallEstimator`).
* :mod:`repro.obs.health` — SLO burn-rate alerting: multi-window
  :class:`AlertRule` evaluation over registry diffs per driver tick,
  typed ring-retained :class:`Alert` events (:class:`HealthMonitor`).

Tracing and profiling are gated behind ``ServiceConfig.obs`` (off by
default, bit-exact on or off); the metrics registry always exists — the
stats surfaces need it — and never touches device values.  Recall
sampling (``ServiceConfig.recall_sample_rate``) implies ``obs`` and is
equally bit-invisible to answers.
"""

from .health import Alert, AlertRule, HealthMonitor, default_rules
from .metrics import Counter, Gauge, Histogram, MetricsRegistry
from .profile import Profiler
from .recall import RecallEstimator, ShadowJob, sample_hash, should_sample
from .trace import STAGES, Tracer, TraceSpan

__all__ = [
    "Alert",
    "AlertRule",
    "Counter",
    "Gauge",
    "HealthMonitor",
    "Histogram",
    "MetricsRegistry",
    "Profiler",
    "RecallEstimator",
    "STAGES",
    "ShadowJob",
    "TraceSpan",
    "Tracer",
    "default_rules",
    "sample_hash",
    "should_sample",
]
