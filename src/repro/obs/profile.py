"""Profiling hooks: ``jax.profiler`` wrappers + per-shape attribution.

The serving stack's compiled work is keyed by
``IndexConfig.shape_signature()`` — one executable per signature, one
signature per (shape bucket, rung, shard count, kernel path).  The
:class:`Profiler` attributes the two costs that matter to that key:

* **compile count** — how many distinct executables the step cache
  built (step-cache churn and rung switches become directly visible);
* **dispatch time** — wall seconds spent inside the compiled-step
  launch, per signature.

Both are host-side bookkeeping and never touch device values, so
enabling them is bit-exact.  When ``jax.profiler`` is importable the
dispatch scope additionally opens a ``TraceAnnotation`` region (so
launches are labeled in a captured device trace), ``start_trace`` /
``stop_trace`` bracket an on-demand profiler capture, and
``save_memory_snapshot`` writes a device-memory profile — all guarded:
a missing or stubbed ``jax.profiler`` degrades to timing-only.
"""

from __future__ import annotations

import contextlib
import threading
import time

__all__ = ["Profiler"]


def _jax_profiler():
    """``jax.profiler`` when importable, else None (timing-only mode)."""
    try:
        from jax import profiler
        return profiler
    except Exception:
        return None


class Profiler:
    """Per-``shape_signature`` compile/dispatch attribution + jax hooks."""

    def __init__(self, profile_dir: str | None = None,
                 timer=time.perf_counter):
        """Attribute compiles/dispatches; ``profile_dir`` enables capture.

        ``timer`` is injectable for deterministic tests; dispatch times
        are wall-clock by nature (they measure real device work).
        """
        self.profile_dir = profile_dir
        self._timer = timer
        self._lock = threading.Lock()
        self._compiles: dict[str, int] = {}
        self._dispatch_s: dict[str, float] = {}
        self._dispatch_n: dict[str, int] = {}
        self._tracing = False

    def record_compile(self, sig: str) -> None:
        """Count one step compilation under signature ``sig``."""
        with self._lock:
            self._compiles[sig] = self._compiles.get(sig, 0) + 1

    @contextlib.contextmanager
    def dispatch(self, sig: str):
        """Time one compiled-step launch, annotated in device traces."""
        prof = _jax_profiler()
        ctx = contextlib.nullcontext()
        if prof is not None:
            try:
                ctx = prof.TraceAnnotation(f"wlsh_query_step[{sig}]")
            except Exception:
                ctx = contextlib.nullcontext()
        t0 = self._timer()
        try:
            with ctx:
                yield
        finally:
            dt = self._timer() - t0
            with self._lock:
                self._dispatch_s[sig] = self._dispatch_s.get(sig, 0.0) + dt
                self._dispatch_n[sig] = self._dispatch_n.get(sig, 0) + 1

    def start_trace(self) -> bool:
        """Start a ``jax.profiler`` trace into ``profile_dir`` if possible."""
        prof = _jax_profiler()
        if prof is None or self.profile_dir is None or self._tracing:
            return False
        try:
            prof.start_trace(self.profile_dir)
        except Exception:
            return False
        self._tracing = True
        return True

    def stop_trace(self) -> bool:
        """Stop an in-flight ``jax.profiler`` trace, if one is running."""
        prof = _jax_profiler()
        if prof is None or not self._tracing:
            return False
        self._tracing = False
        try:
            prof.stop_trace()
        except Exception:
            return False
        return True

    def save_memory_snapshot(self, path: str) -> bool:
        """On-demand device-memory profile to ``path`` (best effort)."""
        prof = _jax_profiler()
        if prof is None:
            return False
        try:
            prof.save_device_memory_profile(path)
        except Exception:
            return False
        return True

    def summary(self) -> dict:
        """Compile counts and dispatch-time attribution per signature."""
        with self._lock:
            return {
                "n_compiles": sum(self._compiles.values()),
                "compiles": dict(self._compiles),
                "dispatch": {
                    sig: {
                        "count": self._dispatch_n[sig],
                        "total_s": self._dispatch_s[sig],
                        "mean_s": (self._dispatch_s[sig]
                                   / self._dispatch_n[sig]),
                    }
                    for sig in sorted(self._dispatch_n)
                },
            }
