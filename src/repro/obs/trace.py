"""Per-query trace spans: where did this query's milliseconds go?

One :class:`TraceSpan` per submitted query walks the canonical stage
lifecycle::

    submit -> route -> admit -> queue -> prefetch/restore -> launch
           -> merge -> resolve

Every timestamp comes from the serving stack's injectable clock, so a
``ManualClock`` replay produces deterministic traces.  Spans also carry
the WLSH-native cost counters the paper's query-efficiency accounting
is built on: ``n_checked`` (candidates verified), ``stop_level``
(histogram levels scanned), the candidate ``budget`` and whether the
histogram pass stopped on it (``budget_capped``), the degradation
``rung`` at launch, and the shard count.

The :class:`Tracer` retains finished spans in a fixed-capacity ring
(old spans fall off; ``n_started``/``n_finished`` keep exact totals and
``n_dropped`` counts ring evictions explicitly, mirrored into the
registry as ``wlsh_trace_dropped_total`` when a registry is bound) and
exports them as JSONL — a ``_meta`` header line with the exact totals,
then one span per line.
"""

from __future__ import annotations

import json
import math
import threading
from collections import deque

__all__ = ["STAGES", "TraceSpan", "Tracer"]

# Canonical stage order; "prefetch" and "restore" are alternatives on
# the same slot (a launch either consumed a prefetched state, faulted
# one in, or hit — a hit marks neither).
STAGES: tuple[str, ...] = (
    "submit", "route", "admit", "queue", "prefetch", "restore",
    "launch", "merge", "resolve",
)

_ATTRS = ("query_id", "tenant", "weight_id", "group_id", "rung",
          "n_shards", "cause", "stop_level", "n_checked", "budget",
          "budget_capped", "recall")


class TraceSpan:
    """One query's stage timestamps plus its WLSH cost counters."""

    __slots__ = _ATTRS + ("stages",)

    def __init__(self, query_id: int, weight_id: int = -1,
                 group_id: int = -1, tenant: str | None = None):
        """Open a span; stages are stamped later with :meth:`mark`."""
        self.query_id = query_id
        self.weight_id = weight_id
        self.group_id = group_id
        self.tenant = tenant
        self.rung = 0
        self.n_shards = 1
        self.cause = None        # launch cause: full | deadline | drain
        self.stop_level = -1     # histogram levels scanned at stop
        self.n_checked = -1      # candidates verified (cost model)
        self.budget = -1         # candidate budget k + ceil(gamma*n)
        self.budget_capped = False  # histogram pass stopped on budget?
        self.recall = -1.0       # shadow-exact recall; -1 = not sampled
        self.stages: dict[str, float] = {}

    def mark(self, stage: str, t: float) -> None:
        """Stamp ``stage`` at clock time ``t`` (re-marking overwrites)."""
        if stage not in STAGES:
            raise ValueError(f"unknown trace stage {stage!r} "
                             f"(expected one of {STAGES})")
        self.stages[stage] = float(t)

    @property
    def monotone(self) -> bool:
        """True when the stamped stages are non-decreasing in order."""
        last = -math.inf
        for stage in STAGES:
            if stage in self.stages:
                if self.stages[stage] < last:
                    return False
                last = self.stages[stage]
        return True

    @property
    def duration_s(self) -> float:
        """submit -> resolve wall (clock) time; NaN while incomplete."""
        try:
            return self.stages["resolve"] - self.stages["submit"]
        except KeyError:
            return math.nan

    def to_dict(self) -> dict:
        """JSON-safe dict form (the JSONL line payload)."""
        out = {a: getattr(self, a) for a in _ATTRS}
        out["stages"] = dict(self.stages)
        return out

    @classmethod
    def from_dict(cls, d: dict) -> TraceSpan:
        """Rebuild a span from :meth:`to_dict` output (JSONL import)."""
        span = cls(d["query_id"], d.get("weight_id", -1),
                   d.get("group_id", -1), d.get("tenant"))
        for a in _ATTRS[4:]:
            if a in d:
                setattr(span, a, d[a])
        for stage, t in d.get("stages", {}).items():
            span.mark(stage, t)
        return span


class Tracer:
    """Ring-buffered span store: begin/finish, retention, JSONL export."""

    def __init__(self, capacity: int = 4096, metrics=None):
        """Retain at most ``capacity`` finished spans (oldest dropped).

        When a :class:`~repro.obs.metrics.MetricsRegistry` is passed as
        ``metrics``, every ring eviction also increments the
        ``wlsh_trace_dropped_total`` counter there, so overflow is
        visible on the same surface as every other serving metric.
        """
        if capacity < 1:
            raise ValueError(f"tracer capacity must be >= 1, "
                             f"got {capacity}")
        self.capacity = capacity
        self._ring: deque[TraceSpan] = deque(maxlen=capacity)
        self._lock = threading.Lock()
        self._next_id = 0
        self.n_started = 0
        self.n_finished = 0
        self.n_dropped = 0
        self._dropped_ctr = (
            metrics.counter("wlsh_trace_dropped_total",
                            "finished spans evicted from the trace ring")
            if metrics is not None else None)

    def begin(self, weight_id: int = -1, group_id: int = -1,
              tenant: str | None = None) -> TraceSpan:
        """Open a new span with the next query id."""
        with self._lock:
            qid = self._next_id
            self._next_id += 1
            self.n_started += 1
        return TraceSpan(qid, weight_id, group_id, tenant)

    def finish(self, span: TraceSpan) -> None:
        """Retire a span into the retention ring.

        When the ring is full the oldest retained span is evicted and
        counted in ``n_dropped`` (and ``wlsh_trace_dropped_total`` when
        a registry is bound) — overflow is never silent.  The exact
        ledger ``n_started == len(spans()) + n_dropped + n_inflight``
        holds at all times.
        """
        with self._lock:
            if len(self._ring) == self.capacity:
                self.n_dropped += 1
                if self._dropped_ctr is not None:
                    self._dropped_ctr.inc()
            self._ring.append(span)
            self.n_finished += 1

    @property
    def n_inflight(self) -> int:
        """Spans begun but not yet finished."""
        with self._lock:
            return self.n_started - self.n_finished

    def spans(self) -> list[TraceSpan]:
        """Snapshot of the retained spans, oldest first."""
        with self._lock:
            return list(self._ring)

    def export_jsonl(self, path) -> int:
        """Write retained spans to ``path`` as JSONL; returns the count.

        The first line is a ``_meta`` header carrying the exact totals
        (``n_started``/``n_finished``/``n_dropped``/``n_inflight`` and
        the ring capacity), so an export taken after overflow still
        states how many spans it is missing.  ``load_jsonl`` skips it.
        """
        spans = self.spans()
        with self._lock:
            meta = {"n_started": self.n_started,
                    "n_finished": self.n_finished,
                    "n_dropped": self.n_dropped,
                    "n_inflight": self.n_started - self.n_finished,
                    "n_retained": len(spans),
                    "capacity": self.capacity}
        with open(path, "w") as fh:
            fh.write(json.dumps({"_meta": meta}) + "\n")
            for span in spans:
                fh.write(json.dumps(span.to_dict()) + "\n")
        return len(spans)

    @staticmethod
    def load_jsonl(path) -> list[TraceSpan]:
        """Read spans back from a JSONL export (round-trip tests, CLI).

        The ``_meta`` header line (when present) is skipped; use
        :meth:`load_jsonl_meta` to read it.
        """
        out = []
        with open(path) as fh:
            for line in fh:
                if not line.strip():
                    continue
                d = json.loads(line)
                if "_meta" in d:
                    continue
                out.append(TraceSpan.from_dict(d))
        return out

    @staticmethod
    def load_jsonl_meta(path) -> dict | None:
        """The ``_meta`` header of a JSONL export (None on old exports)."""
        with open(path) as fh:
            for line in fh:
                if line.strip():
                    d = json.loads(line)
                    return d.get("_meta")
        return None
