"""Unified metrics registry: typed counters, gauges and histograms.

One :class:`MetricsRegistry` per serving stack is the single source of
truth for every operational counter — the legacy per-layer stats
objects (``GroupServeStats``, ``CacheStats``, ``DriverStats``,
``TenantStats``) are property views over it.  Design constraints:

* **zero dependencies** — stdlib only, importable anywhere;
* **thread-safe** — one registry ``RLock`` guards every mutation, so
  the thread-mode ``ServiceDriver`` and the submitting thread can race
  freely;
* **bounded memory** — histograms are fixed-bucket: p50/p95/p99 come
  from cumulative bucket counts with linear interpolation, no samples
  are retained;
* **exportable** — Prometheus-style text exposition (``to_text``),
  JSON-safe ``snapshot()``, and counter ``diff()`` between two
  snapshots (the driver's tick summary line).

Naming convention (pinned by docs and tests): counters are
``wlsh_<layer>_<noun>_total``, gauges ``wlsh_<layer>_<noun>``, latency
histograms ``wlsh_<noun>_seconds``; label keys are lowercase
identifiers (``group``, ``tenant``, ``cause``, ``sig``).
"""

from __future__ import annotations

import json
import math
import threading

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry",
           "DEFAULT_LATENCY_BUCKETS"]

# Prometheus-ish latency ladder (seconds): 100 us .. 10 s, geometric-ish.
DEFAULT_LATENCY_BUCKETS: tuple[float, ...] = (
    1e-4, 2.5e-4, 5e-4, 1e-3, 2.5e-3, 5e-3, 1e-2, 2.5e-2, 5e-2,
    1e-1, 2.5e-1, 5e-1, 1.0, 2.5, 5.0, 10.0,
)


def _labelkey(labels: dict) -> str:
    """Canonical series key: ``"k=v,k2=v2"`` sorted by key, ``""`` bare."""
    return ",".join(f"{k}={labels[k]}" for k in sorted(labels))


def _fmt(v: float) -> str:
    """Exposition-format a value: integral floats print as ints."""
    f = float(v)
    return str(int(f)) if f.is_integer() and abs(f) < 1e15 else repr(f)


def _escape_label(value: str) -> str:
    """Escape a label value per the Prometheus exposition spec.

    Inside a quoted label value, backslash, double-quote and newline
    must be written as ``\\\\``, ``\\"`` and ``\\n`` respectively —
    everything else passes through verbatim.
    """
    return (str(value).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labpair(kv: str) -> str:
    """Render one ``k=v`` label-key fragment as ``k="escaped-v"``."""
    k, v = kv.split("=", 1)
    return f'{k}="{_escape_label(v)}"'


class Counter:
    """Monotone counter with optional labels (one series per label set)."""

    kind = "counter"

    def __init__(self, name: str, help: str, lock: threading.RLock):
        """Create the counter; use ``MetricsRegistry.counter`` instead."""
        self.name = name
        self.help = help
        self._lock = lock
        self._series: dict[str, float] = {}

    def inc(self, amount: float = 1, **labels) -> None:
        """Add ``amount`` (>= 0) to the series selected by ``labels``."""
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount

    def value(self, **labels) -> float:
        """Current value of one labeled series (0 if never incremented)."""
        with self._lock:
            return self._series.get(_labelkey(labels), 0)

    def total(self) -> float:
        """Sum over every labeled series."""
        with self._lock:
            return sum(self._series.values())

    def series(self) -> dict[str, float]:
        """Snapshot of ``{label_key: value}`` for every series."""
        with self._lock:
            return dict(self._series)

    def _reset(self) -> None:
        with self._lock:
            self._series.clear()


class Gauge(Counter):
    """Point-in-time value; supports ``set`` and signed ``add``.

    Gauges survive ``MetricsRegistry.reset`` — they describe current
    state (e.g. resident bytes), not accumulated work.
    """

    kind = "gauge"

    def inc(self, amount: float = 1, **labels) -> None:
        """Add ``amount`` (may be negative) to the labeled series."""
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0) + amount

    add = inc

    def set(self, value: float, **labels) -> None:
        """Overwrite the labeled series with ``value``."""
        with self._lock:
            self._series[_labelkey(labels)] = value

    def _reset(self) -> None:  # state, not work: keep across resets
        pass


class Histogram:
    """Fixed-bucket histogram: percentiles without retaining samples.

    Observations land in cumulative-count buckets bounded by
    ``buckets`` (upper bounds, ascending; an implicit +Inf bucket
    catches the tail).  ``percentile`` interpolates linearly inside the
    selected bucket, clamped to the observed min/max, so p50/p95/p99
    are exact to within one bucket's width at O(len(buckets)) memory.
    """

    kind = "histogram"

    def __init__(self, name: str, help: str, lock: threading.RLock,
                 buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS):
        """Create the histogram; use ``MetricsRegistry.histogram``."""
        if not buckets or list(buckets) != sorted(set(buckets)):
            raise ValueError(f"histogram {name} buckets must be a "
                             f"strictly ascending non-empty sequence")
        self.name = name
        self.help = help
        self.buckets = tuple(float(b) for b in buckets)
        self._lock = lock
        self._series: dict[str, dict] = {}

    def _cell(self, key: str) -> dict:
        cell = self._series.get(key)
        if cell is None:
            cell = self._series[key] = {
                "counts": [0] * (len(self.buckets) + 1),
                "sum": 0.0, "count": 0,
                "min": math.inf, "max": -math.inf,
            }
        return cell

    def observe(self, value: float, **labels) -> None:
        """Record one observation into the labeled series."""
        v = float(value)
        with self._lock:
            cell = self._cell(_labelkey(labels))
            i = 0
            while i < len(self.buckets) and v > self.buckets[i]:
                i += 1
            cell["counts"][i] += 1
            cell["sum"] += v
            cell["count"] += 1
            cell["min"] = min(cell["min"], v)
            cell["max"] = max(cell["max"], v)

    def count(self, **labels) -> int:
        """Number of observations in the labeled series."""
        with self._lock:
            cell = self._series.get(_labelkey(labels))
            return cell["count"] if cell else 0

    def sum(self, **labels) -> float:
        """Sum of observations in the labeled series."""
        with self._lock:
            cell = self._series.get(_labelkey(labels))
            return cell["sum"] if cell else 0.0

    def percentile(self, q: float, **labels) -> float:
        """The q-th percentile (q in [0, 100]) of the labeled series.

        Linear interpolation inside the bucket that crosses the target
        rank, clamped to the observed min/max (so the +Inf tail bucket
        and the first bucket stay finite and tight).  NaN when empty.
        """
        if not 0 <= q <= 100:
            raise ValueError(f"percentile q must be in [0, 100], got {q}")
        with self._lock:
            cell = self._series.get(_labelkey(labels))
            if cell is None or cell["count"] == 0:
                return math.nan
            rank = q / 100.0 * cell["count"]
            cum = 0
            for i, c in enumerate(cell["counts"]):
                if c and cum + c >= rank:
                    lo = self.buckets[i - 1] if i else cell["min"]
                    hi = (self.buckets[i] if i < len(self.buckets)
                          else cell["max"])
                    lo = max(lo, cell["min"])
                    hi = min(hi, cell["max"])
                    frac = max(0.0, (rank - cum)) / c
                    return lo + frac * max(0.0, hi - lo)
                cum += c
            return cell["max"]

    def series(self) -> dict[str, dict]:
        """Snapshot ``{label_key: {counts, sum, count, min, max}}``."""
        with self._lock:
            return {k: dict(v, counts=list(v["counts"]))
                    for k, v in self._series.items()}

    def _reset(self) -> None:
        with self._lock:
            self._series.clear()


class MetricsRegistry:
    """Get-or-create registry of named metrics; the one source of truth.

    ``counter``/``gauge``/``histogram`` return the existing metric when
    the name is already registered (raising on a type mismatch), so
    call sites never coordinate creation.  One ``RLock`` guards every
    metric, making the registry safe under the thread-mode driver.
    """

    def __init__(self):
        """Create an empty registry."""
        self._lock = threading.RLock()
        self._metrics: dict[str, Counter | Gauge | Histogram] = {}

    def _get(self, name: str, kind: type, **kwargs):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = self._metrics[name] = kind(name, lock=self._lock,
                                               **kwargs)
            elif not type(m) is kind:
                raise TypeError(f"metric {name!r} already registered as "
                                f"{m.kind}, not {kind.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        """Get or create the named counter."""
        return self._get(name, Counter, help=help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        """Get or create the named gauge."""
        return self._get(name, Gauge, help=help)

    def histogram(self, name: str, help: str = "",
                  buckets: tuple[float, ...] = DEFAULT_LATENCY_BUCKETS,
                  ) -> Histogram:
        """Get or create the named fixed-bucket histogram."""
        return self._get(name, Histogram, help=help, buckets=buckets)

    def metrics(self) -> dict[str, Counter | Gauge | Histogram]:
        """Snapshot of the registered metrics by name."""
        with self._lock:
            return dict(self._metrics)

    def reset(self, prefix: str = "") -> None:
        """Zero counters/histograms whose name starts with ``prefix``.

        Gauges are left untouched: they describe current state (e.g.
        resident bytes), which a stats reset must not fabricate.
        """
        with self._lock:
            for name, m in self._metrics.items():
                if name.startswith(prefix):
                    m._reset()

    def merge_from(self, other: MetricsRegistry) -> None:
        """Fold ``other``'s metrics into this registry (additive).

        Used when a standalone layer (e.g. a ``QosScheduler`` built
        before its service) re-binds onto the serving stack's registry:
        counter/gauge series add; histogram cells merge bucket-wise.
        """
        for name, m in other.metrics().items():
            if isinstance(m, Histogram):
                mine = self.histogram(name, m.help, m.buckets)
                with self._lock:
                    for key, cell in m.series().items():
                        tgt = mine._cell(key)
                        tgt["counts"] = [a + b for a, b in
                                         zip(tgt["counts"], cell["counts"])]
                        tgt["sum"] += cell["sum"]
                        tgt["count"] += cell["count"]
                        tgt["min"] = min(tgt["min"], cell["min"])
                        tgt["max"] = max(tgt["max"], cell["max"])
            else:
                mine = (self.gauge if isinstance(m, Gauge)
                        else self.counter)(name, m.help)
                for key, v in m.series().items():
                    labels = dict(kv.split("=", 1)
                                  for kv in key.split(",") if kv)
                    mine.inc(v, **labels)

    def snapshot(self) -> dict:
        """JSON-safe snapshot of every metric (series, buckets, help)."""
        out: dict = {}
        for name, m in sorted(self.metrics().items()):
            if isinstance(m, Histogram):
                series = {
                    k: {"count": c["count"], "sum": c["sum"],
                        "min": (None if c["count"] == 0 else c["min"]),
                        "max": (None if c["count"] == 0 else c["max"]),
                        "counts": list(c["counts"])}
                    for k, c in m.series().items()
                }
                out[name] = {"type": m.kind, "help": m.help,
                             "buckets": list(m.buckets), "series": series}
            else:
                out[name] = {"type": m.kind, "help": m.help,
                             "series": m.series()}
        return out

    def diff(self, prev: dict | None) -> dict:
        """Counter deltas since a previous ``snapshot()``.

        Returns ``{name: {label_key: delta}}`` with zero-delta series
        dropped — the driver's tick summary line is built from this.
        """
        prev = prev or {}
        out: dict = {}
        for name, entry in self.snapshot().items():
            if entry["type"] != "counter":
                continue
            before = prev.get(name, {}).get("series", {})
            deltas = {k: v - before.get(k, 0)
                      for k, v in entry["series"].items()
                      if v != before.get(k, 0)}
            if deltas:
                out[name] = deltas
        return out

    def to_text(self) -> str:
        """Prometheus-style text exposition of every metric."""
        lines: list[str] = []
        for name, m in sorted(self.metrics().items()):
            if m.help:
                lines.append(f"# HELP {name} {m.help}")
            lines.append(f"# TYPE {name} {m.kind}")
            if isinstance(m, Histogram):
                for key, cell in sorted(m.series().items()):
                    base = [kv for kv in key.split(",") if kv]
                    cum = 0
                    for ub, c in zip(
                            list(m.buckets) + [math.inf], cell["counts"]):
                        cum += c
                        le = "+Inf" if ub == math.inf else _fmt(ub)
                        lab = ",".join(
                            [_labpair(kv) for kv in base] + [f'le="{le}"'])
                        lines.append(f"{name}_bucket{{{lab}}} {cum}")
                    suffix = ("{" + ",".join(
                        _labpair(kv) for kv in base) + "}") if base else ""
                    lines.append(f"{name}_sum{suffix} "
                                 f"{_fmt(cell['sum'])}")
                    lines.append(f"{name}_count{suffix} {cell['count']}")
            else:
                for key, v in sorted(m.series().items()):
                    lab = ""
                    if key:
                        lab = "{" + ",".join(
                            _labpair(kv) for kv in key.split(",")) + "}"
                    lines.append(f"{name}{lab} {_fmt(v)}")
        return "\n".join(lines) + ("\n" if lines else "")

    def to_json(self) -> str:
        """The ``snapshot()`` dict serialized as JSON text."""
        return json.dumps(self.snapshot(), indent=2, sort_keys=True)
