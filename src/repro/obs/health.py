"""SLO burn-rate alerting over the metrics registry.

A :class:`HealthMonitor` is fed one ``observe()`` per driver tick.  It
keeps a bounded per-counter window of tick deltas (via
``MetricsRegistry.diff``) and evaluates :class:`AlertRule`\\ s against
it:

* ``burn_ratio`` rules implement classic **multi-window burn-rate**
  alerting: the rule fires only when the bad/total ratio exceeds the
  threshold over *both* a fast window (recent ticks — so a recovered
  incident clears promptly) and a slow window (so a momentary spike
  does not page).  Deadline-miss rate, tenant SLO-miss rate and
  prefetch-waste are ratios of two counters; a rule with no
  denominator burns against ticks (events per tick).
* ``gauge_below`` / ``gauge_above`` rules watch current state: the
  observed-recall margin dropping under zero, or the pending-queue
  depth saturating.  They fire after the condition holds for
  ``for_ticks`` consecutive observations (min over series for
  *below*, max for *above* — the worst series decides).

Alerts are edge-triggered typed :class:`Alert` events: one event when
a rule starts firing (counted in ``wlsh_alerts_fired_total``), a clear
mark when it stops (``wlsh_alerts_cleared_total``).  Events are
ring-retained and JSONL-exportable; the driver surfaces the
currently-firing set in its ``tick_summary()`` line and the launcher
exports them via ``--alerts-out``.

Stdlib-only and clock-free: windows are counted in ticks, and the
timestamps on events come from the caller's injectable clock — a
``ManualClock`` replay produces deterministic alert streams.
"""

from __future__ import annotations

import dataclasses
import json
import math
from collections import deque

__all__ = ["Alert", "AlertRule", "HealthMonitor", "default_rules"]

_KINDS = ("burn_ratio", "gauge_below", "gauge_above")


@dataclasses.dataclass(frozen=True)
class AlertRule:
    """One declarative SLO rule evaluated every tick.

    ``kind`` selects the evaluator (see the module docstring);
    ``burn_ratio`` rules read ``numerator``/``denominator`` counter
    deltas over ``fast_window``/``slow_window`` ticks, gauge rules
    compare the ``gauge``'s worst series against ``threshold`` for
    ``for_ticks`` consecutive observations.
    """

    name: str
    kind: str
    threshold: float
    numerator: str = ""
    denominator: str = ""  # "" = burn against ticks, not a counter
    fast_window: int = 12
    slow_window: int = 60
    min_events: int = 1  # denominator events needed before judging
    gauge: str = ""
    for_ticks: int = 2
    severity: str = "page"  # "page" | "warn"

    def __post_init__(self):
        """Validate the rule shape at construction."""
        if self.kind not in _KINDS:
            raise ValueError(f"alert rule {self.name!r}: kind must be "
                             f"one of {_KINDS}, got {self.kind!r}")
        if self.kind == "burn_ratio":
            if not self.numerator:
                raise ValueError(f"alert rule {self.name!r}: burn_ratio "
                                 f"needs a numerator counter")
            if not (1 <= self.fast_window <= self.slow_window):
                raise ValueError(
                    f"alert rule {self.name!r}: need 1 <= fast_window "
                    f"<= slow_window, got {self.fast_window} / "
                    f"{self.slow_window}")
            if self.min_events < 1:
                raise ValueError(f"alert rule {self.name!r}: min_events "
                                 f"must be >= 1, got {self.min_events}")
        else:
            if not self.gauge:
                raise ValueError(f"alert rule {self.name!r}: gauge "
                                 f"rules need a gauge name")
            if self.for_ticks < 1:
                raise ValueError(f"alert rule {self.name!r}: for_ticks "
                                 f"must be >= 1, got {self.for_ticks}")

    @property
    def counters(self) -> tuple[str, ...]:
        """Counter names this rule's windows must track."""
        if self.kind != "burn_ratio":
            return ()
        return tuple(n for n in (self.numerator, self.denominator) if n)


@dataclasses.dataclass(frozen=True)
class Alert:
    """One edge-triggered alert event (a rule started firing)."""

    rule: str
    kind: str
    severity: str
    t_fired: float
    tick: int
    value: float  # the violating value (slow-window ratio / gauge)
    value_fast: float  # fast-window ratio (NaN for gauge rules)
    threshold: float
    message: str

    def to_dict(self) -> dict:
        """JSON-safe dict form (the JSONL line payload)."""
        return dataclasses.asdict(self)


def _ratio(window, n: int, num: str, den: str, min_events: int):
    """Bad/total ratio over the last ``n`` ticks; None when unjudgeable."""
    ticks = list(window[num])[-n:]
    bad = sum(ticks)
    if den:
        total = sum(list(window[den])[-n:])
    else:
        total = float(len(ticks))
    if total < min_events:
        return None
    return bad / total


class HealthMonitor:
    """Tick-driven SLO evaluation: rules in, typed alert events out."""

    def __init__(self, metrics, rules, capacity: int = 256):
        """Watch ``metrics`` (a MetricsRegistry) under ``rules``.

        ``capacity`` bounds the retained alert-event ring; firing
        state and counters stay exact regardless.
        """
        self.metrics = metrics
        self.rules = tuple(rules)
        names = [r.name for r in self.rules]
        if len(set(names)) != len(names):
            raise ValueError(f"alert rule names must be unique: {names}")
        self.tick = 0
        self._prev_snap: dict | None = None
        slow = max((r.slow_window for r in self.rules
                    if r.kind == "burn_ratio"), default=1)
        tracked = {c for r in self.rules for c in r.counters}
        self._window = {c: deque(maxlen=slow) for c in tracked}
        self._streak = {r.name: 0 for r in self.rules}
        self._firing: dict[str, Alert] = {}
        self._ring: deque[Alert] = deque(maxlen=capacity)
        self._fired_ctr = metrics.counter(
            "wlsh_alerts_fired_total", "alert rule rising edges")
        self._cleared_ctr = metrics.counter(
            "wlsh_alerts_cleared_total", "alert rule falling edges")

    # ---------------------------------------------------------- evaluation

    def observe(self, now: float) -> list[Alert]:
        """Evaluate every rule against this tick; returns new alerts.

        Call once per driver tick with the injectable clock's time.
        Counter deltas since the previous call extend the burn
        windows; gauges are read at their current value.
        """
        snap = self.metrics.snapshot()
        diff = self.metrics.diff(self._prev_snap)
        self._prev_snap = snap
        self.tick += 1
        for name, dq in self._window.items():
            dq.append(sum(diff.get(name, {}).values()))
        fired: list[Alert] = []
        for rule in self.rules:
            alert = self._eval(rule, snap, now)
            was = rule.name in self._firing
            if alert is not None and not was:
                self._firing[rule.name] = alert
                self._ring.append(alert)
                self._fired_ctr.inc(rule=rule.name)
                fired.append(alert)
            elif alert is None and was:
                del self._firing[rule.name]
                self._cleared_ctr.inc(rule=rule.name)
        return fired

    def _eval(self, rule: AlertRule, snap: dict, now: float):
        """One rule against the current windows; Alert or None."""
        if rule.kind == "burn_ratio":
            fast = _ratio(self._window, rule.fast_window, rule.numerator,
                          rule.denominator, rule.min_events)
            slow = _ratio(self._window, rule.slow_window, rule.numerator,
                          rule.denominator, rule.min_events)
            if (fast is None or slow is None
                    or fast <= rule.threshold
                    or slow <= rule.threshold):
                return None
            return Alert(
                rule=rule.name, kind=rule.kind, severity=rule.severity,
                t_fired=float(now), tick=self.tick, value=slow,
                value_fast=fast, threshold=rule.threshold,
                message=(f"{rule.numerator} burn "
                         f"{fast:.3f}/{slow:.3f} (fast/slow) "
                         f"> {rule.threshold}"),
            )
        entry = snap.get(rule.gauge)
        series = (entry or {}).get("series", {})
        if not series:
            worst = None
        elif rule.kind == "gauge_below":
            worst = min(series.values())
        else:
            worst = max(series.values())
        bad = (worst is not None
               and (worst < rule.threshold
                    if rule.kind == "gauge_below"
                    else worst > rule.threshold))
        self._streak[rule.name] = (self._streak[rule.name] + 1
                                   if bad else 0)
        if self._streak[rule.name] < rule.for_ticks:
            return None
        op = "<" if rule.kind == "gauge_below" else ">"
        return Alert(
            rule=rule.name, kind=rule.kind, severity=rule.severity,
            t_fired=float(now), tick=self.tick, value=float(worst),
            value_fast=math.nan, threshold=rule.threshold,
            message=(f"{rule.gauge} {worst:.4g} {op} {rule.threshold} "
                     f"for {self._streak[rule.name]} ticks"),
        )

    # ------------------------------------------------------------- reading

    def firing(self) -> list[Alert]:
        """Currently-firing alerts, rule order."""
        return [self._firing[r.name] for r in self.rules
                if r.name in self._firing]

    def alerts(self) -> list[Alert]:
        """Retained alert events, oldest first (bounded ring)."""
        return list(self._ring)

    def export_jsonl(self, path) -> int:
        """Write retained alert events to ``path``; returns the count."""
        events = self.alerts()
        with open(path, "w") as fh:
            for a in events:
                fh.write(json.dumps(a.to_dict()) + "\n")
        return len(events)

    def summary(self) -> dict:
        """JSON-safe totals: per-rule fired/cleared/firing state."""
        fired = self._fired_ctr.series()
        cleared = self._cleared_ctr.series()
        return {
            "tick": self.tick,
            "firing": [a.rule for a in self.firing()],
            "rules": {
                r.name: {
                    "kind": r.kind,
                    "severity": r.severity,
                    "threshold": r.threshold,
                    "fired": int(fired.get(f"rule={r.name}", 0)),
                    "cleared": int(cleared.get(f"rule={r.name}", 0)),
                    "firing": r.name in self._firing,
                }
                for r in self.rules
            },
        }


def default_rules(max_pending: int | None = None) -> tuple[AlertRule, ...]:
    """The stock WLSH SLO rule set (driver metrics naming).

    Multi-window burns on deadline-miss rate, tenant SLO-miss rate and
    prefetch-waste, plus gauge rules on the observed-recall margin and
    the pending-queue depth (the latter only when ``max_pending`` gives
    a saturation point: the rule fires at 90% of the cap).
    """
    rules = [
        AlertRule(name="deadline_miss_burn", kind="burn_ratio",
                  numerator="wlsh_driver_deadline_misses_total",
                  denominator="wlsh_driver_deadlines_due_total",
                  threshold=0.25, fast_window=12, slow_window=60,
                  min_events=4, severity="page"),
        AlertRule(name="tenant_slo_burn", kind="burn_ratio",
                  numerator="wlsh_tenant_slo_misses_total",
                  denominator="wlsh_tenant_resolved_total",
                  threshold=0.25, fast_window=12, slow_window=60,
                  min_events=4, severity="page"),
        AlertRule(name="prefetch_waste_burn", kind="burn_ratio",
                  numerator="wlsh_state_prefetch_wasted_total",
                  denominator="wlsh_state_prefetches_total",
                  threshold=0.5, fast_window=20, slow_window=100,
                  min_events=4, severity="warn"),
        AlertRule(name="recall_below_bound", kind="gauge_below",
                  gauge="wlsh_recall_bound_margin", threshold=0.0,
                  for_ticks=2, severity="page"),
    ]
    if max_pending is not None:
        rules.append(
            AlertRule(name="queue_saturation", kind="gauge_above",
                      gauge="wlsh_pending_queue_depth",
                      threshold=0.9 * max_pending, for_ticks=3,
                      severity="warn"))
    return tuple(rules)
