"""Model factory + per-(arch, shape) input specs for lowering and smoke runs."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from .transformer import Model, RunFlags

__all__ = ["build_model", "input_specs", "make_batch"]


def build_model(cfg: ModelConfig, mesh=None, flags: RunFlags | None = None):
    if flags is None:
        flags = default_flags(cfg)
    return Model(cfg, mesh=mesh, flags=flags)


def _best_group(n: int) -> int:
    """Divisor of n closest to sqrt(n): balances boundary count (n/g)
    against live recompute window (g) under nested remat."""
    import math

    target = max(1, math.isqrt(n))
    divisors = [d for d in range(1, n + 1) if n % d == 0]
    return min(divisors, key=lambda d: abs(d - target))


def default_flags(cfg: ModelConfig) -> RunFlags:
    groups = 1
    n_scan = cfg.n_layers - cfg.first_dense_layers
    # nested remat for very wide stacks (llama3-405b, chameleon-34b): saved
    # layer boundaries at full width would blow HBM.
    if cfg.d_model >= 8192 and n_scan > 8:
        groups = _best_group(n_scan)
    return RunFlags(remat="full", layer_groups=groups)


def input_specs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this step kind.

    * train/prefill: token ids (or stub frontend embeddings for audio/vlm,
      per the assignment: the modality frontend provides precomputed
      frame/patch embeddings) + labels.
    * decode: one new token per sequence + scalar position; the KV/SSM cache
      is part of the step state, shaped for ``shape.seq_len``.
    """
    B, S = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        out = {}
        if cfg.input_mode == "embeddings":
            out["embeddings"] = jax.ShapeDtypeStruct(
                (B, S, cfg.d_model), jnp.dtype(cfg.dtype)
            )
        else:
            out["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        if shape.kind == "train":
            out["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        return out
    return {
        "tokens": jax.ShapeDtypeStruct((B,), jnp.int32),
        "position": jax.ShapeDtypeStruct((), jnp.int32),
    }


def make_batch(cfg: ModelConfig, shape: ShapeConfig, seed: int = 0) -> dict:
    """Materialized random batch matching input_specs (smoke tests)."""
    key = jax.random.PRNGKey(seed)
    specs = input_specs(cfg, shape)
    out = {}
    for name, s in specs.items():
        key, k = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            if s.shape == ():
                out[name] = jnp.asarray(shape.seq_len // 2, s.dtype)
            else:
                out[name] = jax.random.randint(k, s.shape, 0, cfg.vocab,
                                               dtype=s.dtype)
        else:
            out[name] = jax.random.normal(k, s.shape, jnp.float32).astype(
                s.dtype
            )
    return out
