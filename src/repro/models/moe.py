"""Mixture-of-Experts layer: top-k routing with sort-based dispatch.

Two implementations sharing the same routing math:

* ``_moe_block_global`` — single-device / mesh-free reference: one global
  argsort over all (token, k) assignments, GShard-free dispatch into an
  (E, C, d) buffer.  Used for CPU smoke tests and as the recorded baseline
  in EXPERIMENTS.md Sec. Perf (under pjit it replicates the dispatch
  buffers: ~400 GB/chip on olmoe train_4k — the measured pathology the EP
  path fixes).

* ``_moe_block_ep`` — the production path: ``shard_map`` over the mesh.
  Tokens stay local to their ("pod","data") shard, experts are sliced over
  "model" (EP).  Dispatch is pure local integer work: assignments are
  argsorted by expert id *per shard*, each shard keeps only the slots of
  its E/mp local experts, and the (e_loc*C, d) dispatch/combine buffers are
  built by scatter/gather of *int32 slot ids* (the (T*K, d) gather of the
  naive formulation never materializes).  The only communication is one
  psum of the (T_loc, d) combined output over the "model" axis per layer —
  the same wire cost a Megatron TP MLP pays.  Dropping is per-data-shard
  (capacity C = ceil(T_loc * top_k / E * capacity_factor)), the
  locality-aware choice real EP systems make.

Shared experts (DeepSeek/Moonlight style) are plain MLPs added to the
routed output.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from ..configs.base import ModelConfig
from ..distributed.sharding import shard, shard_map_nocheck
from .layers import mlp, mlp_defs
from .params import pdef

__all__ = ["moe_defs", "moe_block", "capacity"]


def capacity(n_tokens: int, cfg: ModelConfig) -> int:
    cap = math.ceil(n_tokens * cfg.top_k / cfg.n_experts * cfg.capacity_factor)
    return max(8, -(-cap // 8) * 8)  # round up to 8 for tiling


def moe_defs(cfg: ModelConfig):
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    out = {
        "router": pdef((d, e), ("fsdp", None), init="scaled"),
        "wg": pdef((e, d, ff), ("experts", "fsdp", None), init="scaled"),
        "wu": pdef((e, d, ff), ("experts", "fsdp", None), init="scaled"),
        "wd": pdef((e, ff, d), ("experts", None, "fsdp"), init="scaled"),
    }
    if cfg.n_shared_experts:
        out["shared"] = mlp_defs(cfg, ff=cfg.d_ff * cfg.n_shared_experts)
    return out


def _route(xt, router, cfg: ModelConfig):
    """Top-k routing: returns (sorted assignment arrays, capacity-free)."""
    T = xt.shape[0]
    K = cfg.top_k
    logits = (xt @ router).astype(jnp.float32)
    gate, sel = jax.lax.top_k(logits, K)  # (T, K)
    gate = jax.nn.softmax(gate, axis=-1)
    tok_ids = jnp.repeat(jnp.arange(T, dtype=jnp.int32), K)
    exp_ids = sel.reshape(-1).astype(jnp.int32)  # (T*K,)
    gates = gate.reshape(-1)
    order = jnp.argsort(exp_ids, stable=True)
    exp_sorted = exp_ids[order]
    tok_sorted = tok_ids[order]
    gate_sorted = gates[order]
    counts = jnp.bincount(exp_ids, length=cfg.n_experts)  # (E,)
    starts = jnp.cumsum(counts) - counts
    pos_in_e = jnp.arange(T * K, dtype=jnp.int32) - starts[exp_sorted]
    return exp_sorted, tok_sorted, gate_sorted, pos_in_e


# ---------------------------------------------------------------------------
# reference / mesh-free path
# ---------------------------------------------------------------------------


def _moe_block_global(params, x, cfg: ModelConfig, mesh):
    """x: (B, S, d) -> (B, S, d)."""
    B, S, d = x.shape
    dt = x.dtype
    T = B * S
    xt = x.reshape(T, d)
    E = cfg.n_experts
    C = capacity(T, cfg)

    exp_sorted, tok_sorted, gate_sorted, pos_in_e = _route(
        xt, params["router"].astype(dt), cfg
    )
    keep = pos_in_e < C
    slot = jnp.where(keep, exp_sorted * C + pos_in_e, E * C)  # E*C = dropped

    # --- dispatch via slot-id indirection (no (T*K, d) intermediate) -------
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
    tok_in_slot = (
        jnp.full((E * C + 1,), T, jnp.int32).at[slot].set(tok_sorted)[:-1]
    )
    buf = xt_pad[tok_in_slot].reshape(E, C, d)
    buf = shard(buf, mesh, "experts", None, None)

    # --- expert compute -----------------------------------------------------
    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, params["wg"].astype(dt))
    ) * jnp.einsum("ecd,edf->ecf", buf, params["wu"].astype(dt))
    h = shard(h, mesh, "experts", None, None)
    out_buf = jnp.einsum("ecf,efd->ecd", h, params["wd"].astype(dt))
    out_buf = shard(out_buf, mesh, "experts", None, None)

    # --- combine: scatter-add from slot-major -------------------------------
    gate_in_slot = (
        jnp.zeros((E * C + 1,), jnp.float32)
        .at[slot]
        .set(jnp.where(keep, gate_sorted, 0.0))[:-1]
    )
    flat = out_buf.reshape(E * C, d).astype(jnp.float32)
    y = (
        jnp.zeros((T + 1, d), jnp.float32)
        .at[tok_in_slot]
        .add(flat * gate_in_slot[:, None])[:-1]
    )
    wsum = (
        jnp.zeros((T + 1,), jnp.float32)
        .at[tok_in_slot]
        .add(gate_in_slot)[:-1]
    )
    y = y / jnp.maximum(wsum, 1e-9)[:, None]
    y = y.astype(dt).reshape(B, S, d)
    return shard(y, mesh, "batch", "seq", None)


# ---------------------------------------------------------------------------
# EP shard_map path (production)
# ---------------------------------------------------------------------------


def _ep_body(x_loc, router, wg, wu, wd, *, cfg: ModelConfig, e_loc: int,
             mp: str):
    Bl, Sl, d = x_loc.shape
    dt = x_loc.dtype
    T = Bl * Sl
    xt = x_loc.reshape(T, d)
    C = capacity(T, cfg)

    exp_sorted, tok_sorted, gate_sorted, pos_in_e = _route(
        xt, router.astype(dt), cfg
    )
    e0 = jax.lax.axis_index(mp).astype(jnp.int32) * e_loc
    local = (
        (exp_sorted >= e0) & (exp_sorted < e0 + e_loc) & (pos_in_e < C)
    )
    slot = jnp.where(local, (exp_sorted - e0) * C + pos_in_e, e_loc * C)

    # dispatch: slot-id indirection, only this shard's experts materialize
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, d), dt)], axis=0)
    tok_in_slot = (
        jnp.full((e_loc * C + 1,), T, jnp.int32).at[slot].set(tok_sorted)[:-1]
    )
    buf = xt_pad[tok_in_slot].reshape(e_loc, C, d)

    h = jax.nn.silu(
        jnp.einsum("ecd,edf->ecf", buf, wg.astype(dt))
    ) * jnp.einsum("ecd,edf->ecf", buf, wu.astype(dt))
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd.astype(dt))

    gate_in_slot = (
        jnp.zeros((e_loc * C + 1,), jnp.float32)
        .at[slot]
        .set(jnp.where(local, gate_sorted, 0.0))[:-1]
    )
    flat = out_buf.reshape(e_loc * C, d).astype(jnp.float32)
    y = (
        jnp.zeros((T + 1, d), jnp.float32)
        .at[tok_in_slot]
        .add(flat * gate_in_slot[:, None])[:-1]
    )
    wsum = (
        jnp.zeros((T + 1,), jnp.float32)
        .at[tok_in_slot]
        .add(gate_in_slot)[:-1]
    )
    # one collective per layer: combine expert slices over the model axis
    y = jax.lax.psum(y, mp)
    wsum = jax.lax.psum(wsum, mp)
    y = y / jnp.maximum(wsum, 1e-9)[:, None]
    return y.astype(dt).reshape(Bl, Sl, d)


def _moe_block_ep(params, x, cfg: ModelConfig, mesh):
    B, S, d = x.shape
    E = cfg.n_experts
    dp = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    mp = "model"
    dp_size = math.prod(mesh.shape[a] for a in dp)
    mp_size = mesh.shape[mp]
    if E % mp_size != 0:
        return _moe_block_global(params, x, cfg, mesh)
    e_loc = E // mp_size
    # tokens shard over the data axes when divisible; tiny decode batches
    # fall back to replicated routing (the expert compute stays sliced)
    tok_spec = P(dp, None, None) if B % dp_size == 0 else P(None, None, None)

    body = functools.partial(_ep_body, cfg=cfg, e_loc=e_loc, mp=mp)
    fn = shard_map_nocheck(
        body,
        mesh=mesh,
        in_specs=(
            tok_spec,
            P(None, None),  # router replicated inside the block
            P(mp, None, None),  # wg: experts sliced over "model"
            P(mp, None, None),  # wu
            P(mp, None, None),  # wd
        ),
        out_specs=tok_spec,
    )
    y = fn(x, params["router"], params["wg"], params["wu"], params["wd"])
    return shard(y, mesh, "batch", "seq", None)


def moe_block(params, x, cfg: ModelConfig, mesh):
    """x: (B, S, d) -> (B, S, d); EP shard_map on a mesh, reference off."""
    if mesh is None:
        y = _moe_block_global(params, x, cfg, mesh)
    else:
        y = _moe_block_ep(params, x, cfg, mesh)
    if cfg.n_shared_experts:
        y = y + mlp(params["shared"], x, mesh)
    return y
