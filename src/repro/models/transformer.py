"""Decoder stacks for all assigned families, built for pod-scale lowering:

  * scan-over-layers (compile time O(1) in depth) with nested remat groups
    (outer scan over L/G groups, inner scan over G layers, both
    checkpointed -> boundary memory L/G instead of L);
  * Megatron-style sequence-parallel residual stream: layer-boundary
    activations are sharded over the "model" axis ("act_seq" rule) and
    gathered inside the layer where heads/ff take over;
  * per-family blocks: dense (GQA/SWA + SwiGLU), MoE, Mamba2 (SSD),
    Zamba2-style hybrid (Mamba2 backbone + one *shared* attention+MLP block
    applied every k layers through a concat-projection, weights reused);
  * decode steps with functional KV/SSM caches (ring buffer for SWA).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from . import layers as L
from .moe import moe_block, moe_defs
from .params import pdef, stack_defs
from .ssm import mamba2_block, mamba2_decode_step, ssm_defs, ssm_state_shape

__all__ = ["Model", "RunFlags"]


@dataclasses.dataclass(frozen=True)
class RunFlags:
    remat: str = "full"  # none | full | dots
    layer_groups: int = 1  # nested-remat group count (1 = flat scan)
    causal_block_skip: bool = False  # perf iteration (EXPERIMENTS Sec Perf)
    seq_shard_boundary: bool = True  # Megatron-SP residual stream
    analysis_unroll: bool = False  # unroll every scan (layers, kv blocks,
    # CE chunks) so cost_analysis counts true work — XLA counts while-loop
    # bodies ONCE regardless of trip count.  Analysis lowering only
    # (launch/dryrun.py lowers shallow unrolled variants + extrapolates);
    # never used for execution.


def _policy(name: str):
    return {
        "none": None,
        "full": jax.checkpoint_policies.nothing_saveable,
        "dots": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
    }[name]


def _block_defs(cfg: ModelConfig):
    fam = cfg.family
    if fam in ("dense", "audio", "vlm"):
        return {
            "ln1": L.norm_defs(cfg),
            "attn": L.attn_defs(cfg),
            "ln2": L.norm_defs(cfg),
            "mlp": L.mlp_defs(cfg),
        }
    if fam == "moe":
        return {
            "ln1": L.norm_defs(cfg),
            "attn": L.attn_defs(cfg),
            "ln2": L.norm_defs(cfg),
            "moe": moe_defs(cfg),
        }
    if fam in ("ssm", "hybrid"):
        return {"ln1": L.norm_defs(cfg), "ssm": ssm_defs(cfg)}
    raise ValueError(fam)


def _kv_repeat(cfg: ModelConfig, mesh) -> int:
    """KV-cache head replication factor for decode TP.

    When n_kv_heads doesn't divide the "model" axis, the logical-axis rules
    fall back to replicating the cache over it — 16x the footprint at
    mesh (16,16).  Storing each kv head ``rep`` times (smallest rep with
    kvh*rep divisible by the axis, rep dividing the GQA group) costs rep x
    memory but shards the head dim, a net (axis/rep)x win.  MHA configs
    (G == 1, e.g. musicgen/minicpm) can't replicate — they fall back to
    sequence-sharded caches (launch/dryrun.py decode rules).
    """
    if mesh is None or "model" not in getattr(mesh, "axis_names", ()):
        return 1
    kvh = cfg.n_kv_heads
    if not kvh or cfg.family == "ssm":
        return 1
    mp = mesh.shape["model"]
    if kvh % mp == 0:
        return 1
    G = cfg.n_heads // kvh
    for rep in range(2, G + 1):
        if G % rep == 0 and (kvh * rep) % mp == 0:
            return rep
    return 1


def _shared_block_defs(cfg: ModelConfig):
    return {
        "proj": pdef((2 * cfg.d_model, cfg.d_model), ("fsdp", None),
                     init="scaled"),
        "ln1": L.norm_defs(cfg),
        "attn": L.attn_defs(cfg),
        "ln2": L.norm_defs(cfg),
        "mlp": L.mlp_defs(cfg),
    }


class Model:
    """build once per (config, mesh, flags); exposes defs + step functions."""

    def __init__(self, cfg: ModelConfig, mesh=None, flags: RunFlags = RunFlags()):
        self.cfg = cfg
        self.mesh = mesh
        self.flags = flags
        self.n_scan = cfg.n_layers - cfg.first_dense_layers
        g = flags.layer_groups
        if g > 1 and self.n_scan % g != 0:
            g = 1
        self.groups = g
        self.kv_rep = _kv_repeat(cfg, mesh)

    # ------------------------------------------------------------------ defs

    def defs(self):
        cfg = self.cfg
        out: dict[str, Any] = {"embed": L.embed_defs(cfg)}
        # blocks are always stacked (L, ...); nested-remat grouping reshapes
        # at trace time so the checkpoint layout is remat-independent.
        out["blocks"] = stack_defs(_block_defs(cfg), self.n_scan)
        if cfg.first_dense_layers:
            dense_cfg = dataclasses.replace(cfg, d_ff=cfg.dense_ff)
            out["first"] = stack_defs(
                {
                    "ln1": L.norm_defs(cfg),
                    "attn": L.attn_defs(cfg),
                    "ln2": L.norm_defs(cfg),
                    "mlp": L.mlp_defs(dense_cfg),
                },
                cfg.first_dense_layers,
            )
        if cfg.family == "hybrid":
            out["shared"] = _shared_block_defs(cfg)
        out["final_norm"] = L.norm_defs(cfg)
        return out

    # ------------------------------------------------------------ fwd blocks

    def _boundary(self, x):
        names = ("batch", "act_seq" if self.flags.seq_shard_boundary else "seq",
                 None)
        return shard(x, self.mesh, *names)

    def _dense_block(self, p, x, positions, ff_cfg=None):
        cfg = ff_cfg or self.cfg
        h = L.attention(
            p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg, self.mesh,
            positions, causal_block_skip=self.flags.causal_block_skip,
            unroll=self.flags.analysis_unroll,
        )
        x = x + h
        x = x + L.mlp(p["mlp"], L.apply_norm(p["ln2"], x, cfg), self.mesh)
        return self._boundary(x)

    def _moe_layer(self, p, x, positions):
        cfg = self.cfg
        h = L.attention(
            p["attn"], L.apply_norm(p["ln1"], x, cfg), cfg, self.mesh,
            positions, causal_block_skip=self.flags.causal_block_skip,
            unroll=self.flags.analysis_unroll,
        )
        x = x + h
        x = x + moe_block(p["moe"], L.apply_norm(p["ln2"], x, cfg), cfg,
                          self.mesh)
        return self._boundary(x)

    def _ssm_layer(self, p, x):
        cfg = self.cfg
        h, _ = mamba2_block(p["ssm"], L.apply_norm(p["ln1"], x, cfg), cfg,
                            self.mesh)
        return self._boundary(x + h)

    def _shared_block(self, p, x, x0, positions):
        cfg = self.cfg
        cat = jnp.concatenate([x, x0], axis=-1)
        h = (cat @ p["proj"].astype(x.dtype))
        h = self._dense_block(
            {"ln1": p["ln1"], "attn": p["attn"], "ln2": p["ln2"],
             "mlp": p["mlp"]},
            h, positions,
        )
        return self._boundary(x + h)

    # ------------------------------------------------------------- forward

    def hidden_states(self, params, batch):
        """Full-sequence forward -> final hidden states (B, S, d)."""
        cfg = self.cfg
        if cfg.input_mode == "embeddings":
            x = batch["embeddings"].astype(jnp.dtype(cfg.dtype))
            x = shard(x, self.mesh, "batch", "seq", None)
        else:
            x = L.embed(params["embed"], batch["tokens"], cfg, self.mesh)
        B, S, _ = x.shape
        positions = jnp.broadcast_to(jnp.arange(S), (B, S))
        x = self._boundary(x)
        x0 = x

        if cfg.first_dense_layers:
            dense_cfg = dataclasses.replace(cfg, d_ff=cfg.dense_ff)
            for i in range(cfg.first_dense_layers):
                p_i = jax.tree.map(lambda a: a[i], params["first"])
                x = self._dense_block(p_i, x, positions, ff_cfg=dense_cfg)

        fam = cfg.family
        every = cfg.shared_block_every

        def layer_fn(carry, p_layer):
            x, idx = carry
            if fam in ("dense", "audio", "vlm"):
                x = self._dense_block(p_layer, x, positions)
            elif fam == "moe":
                x = self._moe_layer(p_layer, x, positions)
            elif fam == "ssm":
                x = self._ssm_layer(p_layer, x)
            else:  # hybrid
                x = self._ssm_layer(p_layer, x)
                x = jax.lax.cond(
                    (idx + 1) % every == 0,
                    lambda x: self._shared_block(params["shared"], x, x0,
                                                 positions),
                    lambda x: x,
                    x,
                )
            return (x, idx + 1), None

        policy = _policy(self.flags.remat)
        if self.flags.remat != "none":
            layer_fn = jax.checkpoint(layer_fn, policy=policy,
                                      prevent_cse=False)

        if self.flags.analysis_unroll:
            # python loop (static): every layer's work appears in the HLO,
            # so cost_analysis counts it; shared blocks use static python
            # branching (exact 1-in-every counting, no lax.cond)
            def hybrid_shared(p_i, x):
                x = self._ssm_layer(p_i, x)
                return self._shared_block(params["shared"], x, x0, positions)

            def hybrid_plain(p_i, x):
                return self._ssm_layer(p_i, x)

            if self.flags.remat != "none":
                hybrid_shared = jax.checkpoint(hybrid_shared, policy=policy,
                                               prevent_cse=False)
                hybrid_plain = jax.checkpoint(hybrid_plain, policy=policy,
                                              prevent_cse=False)
            carry = (x, jnp.int32(0))
            for i in range(self.n_scan):
                p_i = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                if fam == "hybrid":
                    # static branching: no lax.cond (whose untaken branch
                    # cost_analysis would also count)
                    x, idx = carry
                    fn = hybrid_shared if (i + 1) % every == 0 else (
                        hybrid_plain)
                    carry = (fn(p_i, x), idx + 1)
                else:
                    carry, _ = layer_fn(carry, p_i)
            x = carry[0]
            return L.apply_norm(params["final_norm"], x, cfg)

        if self.groups > 1:
            g = self.groups
            grouped = jax.tree.map(
                lambda a: a.reshape(g, a.shape[0] // g, *a.shape[1:]),
                params["blocks"],
            )

            def group_fn(carry, p_group):
                carry, _ = jax.lax.scan(layer_fn, carry, p_group)
                return carry, None

            if self.flags.remat != "none":
                group_fn = jax.checkpoint(group_fn, policy=policy,
                                          prevent_cse=False)
            (x, _), _ = jax.lax.scan(group_fn, (x, jnp.int32(0)), grouped)
        else:
            (x, _), _ = jax.lax.scan(layer_fn, (x, jnp.int32(0)),
                                     params["blocks"])
        return L.apply_norm(params["final_norm"], x, cfg)

    def loss(self, params, batch):
        x = self.hidden_states(params, batch)
        return L.chunked_ce_loss(params["embed"], x, batch["labels"], self.cfg,
                                 self.mesh,
                                 unroll=self.flags.analysis_unroll)

    def prefill(self, params, batch):
        """Forward + final-position logits (the prefill_32k lowering)."""
        x = self.hidden_states(params, batch)
        W = L.unembed_matrix(params["embed"], self.cfg).astype(x.dtype)
        logits = x[:, -1, :] @ W
        return shard(logits, self.mesh, "batch", "vocab")

    # ------------------------------------------------------------- decode

    def cache_shapes(self, batch: int, cache_len: int):
        cfg = self.cfg
        fam = cfg.family
        dt = jnp.dtype(cfg.dtype)
        kdt = jnp.dtype(cfg.kv_dtype_)
        out = {}
        kvh, dh = cfg.n_kv_heads * self.kv_rep, cfg.head_dim_
        eff = min(cache_len, cfg.sliding_window) if cfg.sliding_window else (
            cache_len
        )
        if fam in ("dense", "audio", "vlm", "moe"):
            n_attn = cfg.n_layers
            out["k"] = jax.ShapeDtypeStruct((n_attn, batch, eff, kvh, dh), kdt)
            out["v"] = jax.ShapeDtypeStruct((n_attn, batch, eff, kvh, dh), kdt)
        if fam in ("ssm", "hybrid"):
            st = ssm_state_shape(cfg, batch)
            nl = self.n_scan
            out["ssm"] = jax.ShapeDtypeStruct((nl, *st["ssm"]), jnp.float32)
            out["conv"] = jax.ShapeDtypeStruct((nl, *st["conv"]), dt)
        if fam == "hybrid":
            n_inv = cfg.n_layers // cfg.shared_block_every
            out["k"] = jax.ShapeDtypeStruct((n_inv, batch, cache_len, kvh, dh),
                                            kdt)
            out["v"] = jax.ShapeDtypeStruct((n_inv, batch, cache_len, kvh, dh),
                                            kdt)
        return out

    def init_cache(self, batch: int, cache_len: int):
        return jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype),
            self.cache_shapes(batch, cache_len),
        )

    def _cache_slot(self, position):
        cfg = self.cfg
        if cfg.sliding_window:
            return position % cfg.sliding_window
        return position

    def decode_step(self, params, cache, tokens, position):
        """One-token decode: tokens (B,), position scalar -> (logits, cache).

        Attention families run a scan over stacked layers with the cache as
        carry (dynamic_update_slice per layer); hybrid unrolls (38 layers,
        7 shared-attn invocations with their own caches).
        """
        cfg = self.cfg
        fam = cfg.family
        dt = jnp.dtype(cfg.dtype)
        x = jnp.take(params["embed"]["tok"], tokens, axis=0).astype(dt)
        x = shard(x, self.mesh, "batch", None)
        x0 = x
        slot = self._cache_slot(position)

        if fam in ("dense", "audio", "vlm", "moe"):
            n_first = cfg.first_dense_layers
            if n_first:
                for i in range(n_first):
                    p_i = jax.tree.map(lambda a: a[i], params["first"])
                    x, cache = self._decode_attn_layer(
                        p_i, x, cache, i, position, slot,
                        mlp_fn="mlp",
                        ff_cfg=dataclasses.replace(cfg, d_ff=cfg.dense_ff),
                    )

            # The per-layer cache rides the scan as xs/ys, NOT as carry: a
            # stacked-cache carry is double-buffered by XLA (2x the cache in
            # temp — 16.8 GB/chip on llama3-405b decode_32k, measured),
            # while xs slices are read-once and ys can alias the donated
            # input buffer.  See EXPERIMENTS.md Sec Perf.
            def step(x, inp):
                p_layer, ck, cv = inp
                xn = L.apply_norm(p_layer["ln1"], x[:, None, :], cfg)[:, 0]
                y, k_new, v_new = L.decode_attention(
                    p_layer["attn"], xn, cfg, self.mesh, ck, cv, position
                )
                ck = jax.lax.dynamic_update_slice_in_dim(
                    ck, k_new[:, None].astype(ck.dtype), slot, axis=1
                )
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cv, v_new[:, None].astype(cv.dtype), slot, axis=1
                )
                x = x + y
                xn = L.apply_norm(p_layer["ln2"], x[:, None, :], cfg)[:, 0]
                if fam == "moe":
                    m = moe_block(
                        p_layer["moe"], xn[:, None, :], cfg, self.mesh
                    )[:, 0]
                else:
                    m = L.mlp(p_layer["mlp"], xn[:, None, :], self.mesh)[:, 0]
                return x + m, (ck, cv)

            nf = n_first
            if self.flags.analysis_unroll:
                ks, vs = [], []
                for i in range(self.n_scan):
                    p_i = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                    x, (ck, cv) = step(
                        x, (p_i, cache["k"][nf + i], cache["v"][nf + i])
                    )
                    ks.append(ck)
                    vs.append(cv)
                k_new, v_new = jnp.stack(ks), jnp.stack(vs)
            else:
                x, (k_new, v_new) = jax.lax.scan(
                    step, x,
                    (params["blocks"], cache["k"][nf:], cache["v"][nf:]),
                )
            if nf:
                k_new = jnp.concatenate([cache["k"][:nf], k_new])
                v_new = jnp.concatenate([cache["v"][:nf], v_new])
            cache = dict(cache, k=k_new, v=v_new)

        elif fam == "ssm":
            def step(carry, inp):
                x, li = carry
                p_layer, s_l, c_l = inp
                xn = L.apply_norm(p_layer["ln1"], x[:, None, :], cfg)[:, 0]
                y, new_state = mamba2_decode_step(
                    p_layer["ssm"], xn, cfg, self.mesh,
                    {"ssm": s_l, "conv": c_l},
                )
                return (x + y, li + 1), (new_state["ssm"], new_state["conv"])

            if self.flags.analysis_unroll:
                carry, ss, cc = (x, jnp.int32(0)), [], []
                for i in range(self.n_scan):
                    p_i = jax.tree.map(lambda a, i=i: a[i], params["blocks"])
                    carry, (s_n, c_n) = step(
                        carry, (p_i, cache["ssm"][i], cache["conv"][i])
                    )
                    ss.append(s_n)
                    cc.append(c_n)
                x = carry[0]
                new_ssm, new_conv = jnp.stack(ss), jnp.stack(cc)
            else:
                (x, _), (new_ssm, new_conv) = jax.lax.scan(
                    step, (x, jnp.int32(0)),
                    (params["blocks"], cache["ssm"], cache["conv"]),
                )
            cache = dict(cache, ssm=new_ssm, conv=new_conv)

        else:  # hybrid: unrolled
            every = cfg.shared_block_every
            new_ssm, new_conv = [], []
            k_all, v_all = cache["k"], cache["v"]
            inv = 0
            for i in range(self.n_scan):
                p_i = jax.tree.map(lambda a: a[i], params["blocks"])
                xn = L.apply_norm(p_i["ln1"], x[:, None, :], cfg)[:, 0]
                y, st = mamba2_decode_step(
                    p_i["ssm"], xn, cfg, self.mesh,
                    {"ssm": cache["ssm"][i], "conv": cache["conv"][i]},
                )
                x = x + y
                new_ssm.append(st["ssm"])
                new_conv.append(st["conv"])
                if (i + 1) % every == 0:
                    p_s = params["shared"]
                    cat = jnp.concatenate([x, x0], axis=-1)
                    h = cat @ p_s["proj"].astype(dt)
                    hn = L.apply_norm(p_s["ln1"], h[:, None, :], cfg)[:, 0]
                    y2, k_new, v_new = L.decode_attention(
                        p_s["attn"], hn, cfg, self.mesh,
                        k_all[inv], v_all[inv], position,
                    )
                    h = h + y2
                    hn = L.apply_norm(p_s["ln2"], h[:, None, :], cfg)[:, 0]
                    h = h + L.mlp(p_s["mlp"], hn[:, None, :], self.mesh)[:, 0]
                    k_all = k_all.at[inv, :, slot].set(
                        k_new.astype(k_all.dtype))
                    v_all = v_all.at[inv, :, slot].set(
                        v_new.astype(v_all.dtype))
                    x = x + h
                    inv += 1
            cache = dict(
                cache,
                ssm=jnp.stack(new_ssm),
                conv=jnp.stack(new_conv),
                k=k_all,
                v=v_all,
            )

        x = L.apply_norm(params["final_norm"], x[:, None, :], cfg)[:, 0]
        W = L.unembed_matrix(params["embed"], cfg).astype(dt)
        logits = x @ W
        return shard(logits, self.mesh, "batch", "vocab"), cache

    def _decode_attn_layer(self, p, x, cache, li, position, slot,
                           mlp_fn="mlp", ff_cfg=None):
        cfg = ff_cfg or self.cfg
        ck, cv = cache["k"][li], cache["v"][li]
        xn = L.apply_norm(p["ln1"], x[:, None, :], self.cfg)[:, 0]
        y, k_new, v_new = L.decode_attention(
            p["attn"], xn, self.cfg, self.mesh, ck, cv, position
        )
        x = x + y
        xn = L.apply_norm(p["ln2"], x[:, None, :], self.cfg)[:, 0]
        x = x + L.mlp(p[mlp_fn], xn[:, None, :], self.mesh)[:, 0]
        cache = dict(
            cache,
            k=cache["k"].at[li, :, slot].set(k_new.astype(cache["k"].dtype)),
            v=cache["v"].at[li, :, slot].set(v_new.astype(cache["v"].dtype)),
        )
        return x, cache
