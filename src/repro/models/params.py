"""Declarative parameter definitions.

A module describes its parameters once as ``ParamDef``s (shape + logical
dim names + init); from that single source we derive:

  * init_params(defs, key)      — materialized params (smoke tests/examples)
  * abstract_params(defs)       — ShapeDtypeStructs (dry-run, no allocation)
  * param_specs(defs, mesh)     — PartitionSpecs via the logical-axis rules

Stacked (scanned) layers prepend a ("layers", L) dim with ``stack_defs``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec

from ..distributed.sharding import spec as logical_spec

__all__ = [
    "ParamDef",
    "pdef",
    "stack_defs",
    "init_params",
    "abstract_params",
    "param_specs",
    "tree_bytes",
    "count_params",
]


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    names: tuple[str | None, ...]
    init: str = "normal"  # normal | zeros | ones | scaled (fan-in)
    scale: float = 0.02
    dtype: str = "float32"


def pdef(shape, names, init="normal", scale=0.02, dtype="float32") -> ParamDef:
    assert len(shape) == len(names), (shape, names)
    return ParamDef(tuple(shape), tuple(names), init, scale, dtype)


def stack_defs(defs, n_layers: int):
    return jax.tree.map(
        lambda d: ParamDef((n_layers, *d.shape), ("layers", *d.names),
                           d.init, d.scale, d.dtype),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def _init_one(d: ParamDef, key) -> jax.Array:
    dt = jnp.dtype(d.dtype)
    if d.init == "zeros":
        return jnp.zeros(d.shape, dt)
    if d.init == "ones":
        return jnp.ones(d.shape, dt)
    if d.init == "scaled":
        fan_in = d.shape[-2] if len(d.shape) >= 2 else d.shape[-1]
        return (jax.random.normal(key, d.shape) / math.sqrt(fan_in)).astype(dt)
    return (jax.random.normal(key, d.shape) * d.scale).astype(dt)


def init_params(defs, key):
    leaves, treedef = jax.tree.flatten(
        defs, is_leaf=lambda x: isinstance(x, ParamDef)
    )
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_one(d, k) for d, k in zip(leaves, keys)]
    )


def abstract_params(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def param_specs(defs, mesh: Mesh):
    return jax.tree.map(
        lambda d: logical_spec(mesh, d.names, d.shape),
        defs,
        is_leaf=lambda x: isinstance(x, ParamDef),
    )


def count_params(defs) -> int:
    return sum(
        math.prod(d.shape)
        for d in jax.tree.leaves(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    )


def tree_bytes(tree) -> int:
    return sum(
        x.size * x.dtype.itemsize if hasattr(x, "size") else 0
        for x in jax.tree.leaves(tree)
    )
