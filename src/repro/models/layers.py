"""Transformer building blocks: norms, rotary, GQA attention (blockwise
online-softmax for train/prefill, cache attention for decode), SwiGLU MLP,
embeddings, chunked cross-entropy.

All forwards take (params, x, cfg, mesh) and annotate activations with
logical-axis sharding constraints; weights follow Megatron column/row
splits over the "model" axis with FSDP over ("pod","data") (see
distributed/sharding.py).
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .params import pdef

NEG_INF = -1.0e30


# ----------------------------------------------------------------------------
# norms
# ----------------------------------------------------------------------------


def norm_defs(cfg: ModelConfig):
    if cfg.norm == "nonparametric_ln":
        return {}
    return {"scale": pdef((cfg.d_model,), (None,), init="ones")}


def apply_norm(params, x, cfg: ModelConfig, eps: float = 1e-6):
    """Statistics in f32, the (B,S,d)-sized products in x.dtype (the f32
    path would double every downstream activation collective)."""
    xf = x.astype(jnp.float32)
    if cfg.norm == "nonparametric_ln":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.var(xf, axis=-1, keepdims=True)
        inv = jax.lax.rsqrt(var + eps)
        return (x - mu.astype(x.dtype)) * inv.astype(x.dtype)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(ms + eps).astype(x.dtype)
    return x * inv * params["scale"].astype(x.dtype)


# ----------------------------------------------------------------------------
# rotary
# ----------------------------------------------------------------------------


def rope(x, positions, theta: float):
    """x: (..., S, H, dh); positions: (..., S).

    Angles in f32, the rotation itself in x.dtype: promoting the (B,S,H,dh)
    products to f32 doubles every downstream activation collective (the
    f32[B,S,d] all-gathers measured in EXPERIMENTS.md Sec Perf).
    """
    dh = x.shape[-1]
    half = dh // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(half, dtype=jnp.float32) / half
    )
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, half)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )


# ----------------------------------------------------------------------------
# attention
# ----------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig):
    d, h, kvh, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": pdef((d, h * dh), ("fsdp", "heads"), init="scaled"),
        "wk": pdef((d, kvh * dh), ("fsdp", "kv_heads"), init="scaled"),
        "wv": pdef((d, kvh * dh), ("fsdp", "kv_heads"), init="scaled"),
        "wo": pdef((h * dh, d), ("heads", "fsdp"), init="scaled"),
    }


def _qkv(params, x, cfg: ModelConfig, mesh, positions):
    B, S, _ = x.shape
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, S, h, dh)
    k = (x @ params["wk"].astype(dt)).reshape(B, S, kvh, dh)
    v = (x @ params["wv"].astype(dt)).reshape(B, S, kvh, dh)
    q = rope(q, positions, cfg.rope_theta)
    k = rope(k, positions, cfg.rope_theta)
    q = shard(q, mesh, "batch", "seq", "heads", None)
    k = shard(k, mesh, "batch", "seq", "kv_heads", None)
    v = shard(v, mesh, "batch", "seq", "kv_heads", None)
    return q, k, v


def blockwise_attention(
    q, k, v, cfg: ModelConfig, q_offset: int = 0,
    block_q: int = 512, block_kv: int = 512,
    causal_block_skip: bool = False,
    unroll: bool = False,
):
    """Online-softmax causal (optionally sliding-window) attention.

    q (B,S,H,dh), k/v (B,Sk,KVH,dh) -> (B,S,H,dh).  Memory O(S*block) —
    never materializes the (S, Sk) score matrix *in either direction*: the
    kv scan body is checkpointed, so the backward recomputes per-block
    scores from q/k/v instead of keeping the (nk, B, S, H, bk) stack the
    scan's autodiff would otherwise save (the flash-attention backward
    trade; the stack measured ~3 GB/chip on moonshot train_4k).
    ``causal_block_skip`` (perf iteration, EXPERIMENTS.md Sec Perf) skips
    fully-masked kv blocks instead of masking them.  ``unroll`` fully
    unrolls the kv scan — analysis-only (cost_analysis counts while-loop
    bodies once; launch/dryrun.py lowers unrolled shallow variants).
    """
    B, S, H, dh = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    bq = min(block_q, S)
    bk = min(block_kv, Sk)
    nq, nk = S // bq, Sk // bk
    scale = 1.0 / math.sqrt(dh)

    qb = q.reshape(B, nq, bq, KVH, G, dh)
    kb = k.reshape(B, nk, bk, KVH, dh)
    vb = v.reshape(B, nk, bk, KVH, dh)
    qpos = q_offset + jnp.arange(S).reshape(nq, bq)
    kpos = jnp.arange(Sk).reshape(nk, bk)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def kv_step(carry, j):
        m, l, acc = carry  # (B,nq,bq,KVH,G), same, (B,nq,bq,KVH,G,dh)
        kj = jnp.take(kb, j, axis=1)  # (B,bk,KVH,dh)
        vj = jnp.take(vb, j, axis=1)
        s = jnp.einsum(
            "bnqkgd,bpkd->bnqkgp", qb, kj,
            preferred_element_type=jnp.float32,
        ) * scale  # (B,nq,bq,KVH,G,bk)
        kp = jnp.take(kpos, j, axis=0)  # (bk,)
        mask = qpos[None, :, :, None, None, None] >= kp[None, None, None,
                                                        None, None, :]
        if cfg.sliding_window:
            mask &= (
                qpos[None, :, :, None, None, None]
                - kp[None, None, None, None, None, :]
            ) < cfg.sliding_window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bnqkgp,bpkd->bnqkgd", p.astype(vj.dtype), vj,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, nq, bq, KVH, G), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, nq, bq, KVH, G), jnp.float32)
    a0 = jnp.zeros((B, nq, bq, KVH, G, dh), jnp.float32)

    if causal_block_skip and q_offset == 0 and S == Sk:
        # process only kv blocks j <= i per q block: restructure as a scan
        # over diagonals is complex; instead unroll per q-block row.
        outs = []
        for i in range(nq):
            row_q = qb[:, i : i + 1]
            mi = m0[:, : 1]
            li = l0[:, : 1]
            ai = a0[:, : 1]
            hi = i + 1 if not cfg.sliding_window else max(
                0, i - cfg.sliding_window // bk
            )
            lo = 0 if not cfg.sliding_window else max(
                0, i - (cfg.sliding_window + bq) // bk
            )
            carry = (mi, li, ai)
            sub_q = qpos[i : i + 1]

            def kv_step_row(carry, j, row_q=row_q, sub_q=sub_q):
                m, l, acc = carry
                kj = jnp.take(kb, j, axis=1)
                vj = jnp.take(vb, j, axis=1)
                s = jnp.einsum(
                    "bnqkgd,bpkd->bnqkgp", row_q, kj,
                    preferred_element_type=jnp.float32,
                ) * scale
                kp = jnp.take(kpos, j, axis=0)
                mask = sub_q[None, :, :, None, None, None] >= kp[
                    None, None, None, None, None, :
                ]
                if cfg.sliding_window:
                    mask &= (
                        sub_q[None, :, :, None, None, None]
                        - kp[None, None, None, None, None, :]
                    ) < cfg.sliding_window
                s = jnp.where(mask, s, NEG_INF)
                m_new = jnp.maximum(m, jnp.max(s, axis=-1))
                p = jnp.exp(s - m_new[..., None])
                alpha = jnp.exp(m - m_new)
                l_new = l * alpha + jnp.sum(p, axis=-1)
                acc_new = acc * alpha[..., None] + jnp.einsum(
                    "bnqkgp,bpkd->bnqkgd", p.astype(vj.dtype), vj,
                    preferred_element_type=jnp.float32,
                )
                return (m_new, l_new, acc_new), None

            (mi, li, ai), _ = jax.lax.scan(
                kv_step_row, carry, jnp.arange(lo, i + 1)
            )
            outs.append(ai / jnp.maximum(li[..., None], 1e-30))
        out = jnp.concatenate(outs, axis=1)
    else:
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), jnp.arange(nk),
            unroll=nk if unroll else 1,
        )
        out = acc / jnp.maximum(l[..., None], 1e-30)

    return out.reshape(B, S, H, dh).astype(q.dtype)


def attention(params, x, cfg: ModelConfig, mesh, positions,
              causal_block_skip: bool = False, unroll: bool = False):
    B, S, _ = x.shape
    q, k, v = _qkv(params, x, cfg, mesh, positions)
    out = blockwise_attention(
        q, k, v, cfg, causal_block_skip=causal_block_skip, unroll=unroll
    )
    out = out.reshape(B, S, cfg.n_heads * cfg.head_dim_)
    y = out @ params["wo"].astype(x.dtype)
    return shard(y, mesh, "batch", "seq", None)


def decode_attention(params, x, cfg: ModelConfig, mesh, cache_k, cache_v,
                     position):
    """Single-token decode against a (B, S_cache, KVH_store, dh) cache.

    Returns (y, k_new, v_new) — cache update handled by the caller (ring
    buffer for SWA).  The (B,H,S_cache) score tensor is small for one token
    and shards over (batch|kv_seq, heads).

    KVH_store may be ``rep x n_kv_heads`` (rep = cache_k.shape[2] // kvh):
    when kv_heads < the "model" axis, the cache stores each kv head
    replicated rep times so the head dim shards (the vLLM/Megatron GQA-TP
    trick; a 2x-replicated cache sharded 16 ways beats an unsharded one
    8x over — see EXPERIMENTS.md Sec Perf, chameleon decode).  Query head
    i attends stored head i // (G/rep), which is exactly the layout the
    (B, KVH_store, G/rep, dh) reshape below produces.
    """
    B = x.shape[0]
    h, kvh, dh = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    kvh_store = cache_k.shape[2]
    rep = kvh_store // kvh
    dt = x.dtype
    q = (x @ params["wq"].astype(dt)).reshape(B, 1, h, dh)
    k = (x @ params["wk"].astype(dt)).reshape(B, 1, kvh, dh)
    v = (x @ params["wv"].astype(dt)).reshape(B, 1, kvh, dh)
    pos = jnp.broadcast_to(position, (B, 1))
    q = rope(q, pos, cfg.rope_theta)
    k = rope(k, pos, cfg.rope_theta)
    if rep > 1:
        k = jnp.repeat(k, rep, axis=2)
        v = jnp.repeat(v, rep, axis=2)
    G = h // kvh_store
    qg = q.reshape(B, kvh_store, G, dh)
    s = jnp.einsum(
        "bkgd,bskd->bkgs", qg, cache_k.astype(dt),
        preferred_element_type=jnp.float32,
    ) / math.sqrt(dh)
    Sc = cache_k.shape[1]
    kpos = jnp.arange(Sc)
    if cfg.sliding_window and Sc <= cfg.sliding_window:
        # ring buffer: all slots hold live positions once the window filled
        valid = (kpos[None, None, None, :] < position) | (
            position >= cfg.sliding_window
        )
    else:
        valid = kpos[None, None, None, :] < position
    s = jnp.where(valid, s, NEG_INF)
    # include the current token via the online-softmax merge
    s_self = jnp.einsum(
        "bkgd,bkd->bkg", qg, k[:, 0].astype(q.dtype),
        preferred_element_type=jnp.float32,
    )[..., None] / math.sqrt(dh)
    m = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), s_self)
    p = jnp.exp(s - m)
    p_self = jnp.exp(s_self - m)
    denom = jnp.sum(p, axis=-1, keepdims=True) + p_self
    ctx = jnp.einsum(
        "bkgs,bskd->bkgd", p.astype(dt), cache_v.astype(dt),
        preferred_element_type=jnp.float32,
    ) + p_self * v[:, 0][:, :, None, :]
    ctx = (ctx / denom).astype(dt)
    y = ctx.reshape(B, h * dh) @ params["wo"].astype(dt)
    return shard(y, mesh, "batch", None), k[:, 0], v[:, 0]


# ----------------------------------------------------------------------------
# MLP (SwiGLU)
# ----------------------------------------------------------------------------


def mlp_defs(cfg: ModelConfig, ff: int | None = None):
    d = cfg.d_model
    ff = ff or cfg.d_ff
    return {
        "wg": pdef((d, ff), ("fsdp", "ff"), init="scaled"),
        "wu": pdef((d, ff), ("fsdp", "ff"), init="scaled"),
        "wd": pdef((ff, d), ("ff", "fsdp"), init="scaled"),
    }


def mlp(params, x, mesh):
    dt = x.dtype
    h = jax.nn.silu(x @ params["wg"].astype(dt)) * (x @ params["wu"].astype(dt))
    h = shard(h, mesh, "batch", "seq", "ff")
    y = h @ params["wd"].astype(dt)
    return shard(y, mesh, "batch", "seq", None)


# ----------------------------------------------------------------------------
# embeddings + loss
# ----------------------------------------------------------------------------


def embed_defs(cfg: ModelConfig):
    out = {"tok": pdef((cfg.vocab, cfg.d_model), ("vocab", "fsdp"))}
    if not cfg.tie_embeddings:
        out["unembed"] = pdef(
            (cfg.d_model, cfg.vocab), ("fsdp", "vocab"), init="scaled"
        )
    return out


def embed(params, tokens, cfg: ModelConfig, mesh):
    x = jnp.take(params["tok"], tokens, axis=0).astype(jnp.dtype(cfg.dtype))
    return shard(x, mesh, "batch", "seq", None)


def unembed_matrix(params, cfg: ModelConfig):
    if cfg.tie_embeddings:
        return params["tok"].T
    return params["unembed"]


@functools.partial(jax.jit, static_argnames=())
def _ce_chunk(logits, labels):
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logp, labels[..., None], axis=-1)[..., 0]
    return -ll


def chunked_ce_loss(params, x, labels, cfg: ModelConfig, mesh,
                    chunk: int = 512, unroll: bool = False):
    """Cross-entropy with the (B,S,V) logits computed seq-chunk at a time.

    The scan body is rematerialized: without it, autodiff saves every
    chunk's logits for the backward pass — the full (B,S,V) f32 tensor the
    chunking exists to avoid (2.5 GB/chip on moonshot train_4k, measured;
    see EXPERIMENTS.md Sec Perf).  Recomputing logits in the backward costs
    one extra (B,S,D)x(D,V) matmul — the standard trade.
    """
    B, S, D = x.shape
    W = unembed_matrix(params, cfg).astype(x.dtype)
    chunk = min(chunk, S)
    nc = S // chunk
    xc = x.reshape(B, nc, chunk, D).swapaxes(0, 1)
    lc = labels.reshape(B, nc, chunk).swapaxes(0, 1)

    @functools.partial(jax.checkpoint,
                       policy=jax.checkpoint_policies.nothing_saveable)
    def body(carry, blk):
        xb, lb = blk
        logits = xb @ W  # (B, chunk, V)
        logits = shard(logits, mesh, "batch", "seq", "vocab")
        loss = _ce_chunk(logits, lb)
        return carry + jnp.sum(loss), None

    total, _ = jax.lax.scan(body, jnp.float32(0.0), (xc, lc),
                            unroll=nc if unroll else 1)
    return total / (B * S)
