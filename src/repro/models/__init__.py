"""Model zoo substrate: layers, MoE, Mamba2 SSD, decoder stacks, factory."""

from .model import build_model, default_flags, input_specs, make_batch
from .params import (abstract_params, count_params, init_params, param_specs,
                     pdef, stack_defs)
from .transformer import Model, RunFlags

__all__ = [
    "Model",
    "RunFlags",
    "abstract_params",
    "build_model",
    "count_params",
    "default_flags",
    "init_params",
    "input_specs",
    "make_batch",
    "param_specs",
    "pdef",
    "stack_defs",
]
