"""Mamba2 (SSD — state-space duality) layer: chunked train/prefill scan +
single-step recurrence for decode.

Per head h with state size N, head dim P, the SSM is

    s_t = exp(dt_t * A_h) * s_{t-1} + dt_t * B_t x_t^T        s in R^{N x P}
    y_t = C_t . s_t + D_h * x_t

The chunked algorithm (Dao & Gu '24) splits the sequence into chunks of Q:
an intra-chunk quadratic term (C B^T masked by the decay kernel L) plus an
inter-chunk recurrence on per-chunk states — both MXU-friendly einsums; the
inter-chunk scan carries only (H, N, P) states.  A causal depthwise conv
(kernel 4) precedes the SSM on the x/B/C paths, and a gated (silu z-branch)
RMSNorm follows it, as in the reference Mamba2 block.

``tests/test_ssm.py`` checks chunked == step-by-step recurrence.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..distributed.sharding import shard
from .params import pdef

__all__ = ["ssm_defs", "mamba2_block", "mamba2_decode_step", "ssm_state_shape"]


def _dims(cfg: ModelConfig):
    di = cfg.d_inner
    H = cfg.ssm_heads
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = 1  # single B/C group
    return di, H, P, N, G


def ssm_defs(cfg: ModelConfig):
    d = cfg.d_model
    di, H, P, N, G = _dims(cfg)
    conv_dim = di + 2 * G * N
    return {
        # in_proj packs [z (di), x (di), B (G*N), C (G*N), dt (H)]
        "in_proj": pdef((d, 2 * di + 2 * G * N + H), ("fsdp", "model"),
                        init="scaled"),
        "conv_w": pdef((cfg.conv_kernel, conv_dim), (None, "model")),
        "conv_b": pdef((conv_dim,), ("model",), init="zeros"),
        "A_log": pdef((H,), ("model",), init="ones"),
        "D": pdef((H,), ("model",), init="ones"),
        "dt_bias": pdef((H,), ("model",), init="zeros"),
        "norm_scale": pdef((di,), ("model",), init="ones"),
        "out_proj": pdef((di, d), ("model", "fsdp"), init="scaled"),
    }


def ssm_state_shape(cfg: ModelConfig, batch: int):
    di, H, P, N, G = _dims(cfg)
    return {
        "ssm": (batch, H, N, P),
        "conv": (batch, cfg.conv_kernel - 1, di + 2 * G * N),
    }


def _split_proj(cfg: ModelConfig, proj):
    di, H, P, N, G = _dims(cfg)
    z = proj[..., :di]
    xBC = proj[..., di : 2 * di + 2 * G * N]
    dt = proj[..., 2 * di + 2 * G * N :]
    return z, xBC, dt


def _causal_conv(xBC, w, b, carry=None):
    """Depthwise causal conv along seq.  xBC (B,S,Cd), w (K,Cd)."""
    K = w.shape[0]
    if carry is None:
        pad = jnp.zeros((xBC.shape[0], K - 1, xBC.shape[2]), xBC.dtype)
    else:
        pad = carry.astype(xBC.dtype)
    xp = jnp.concatenate([pad, xBC], axis=1)
    out = sum(
        xp[:, i : i + xBC.shape[1], :] * w[i][None, None, :] for i in range(K)
    )
    new_carry = xp[:, -(K - 1) :, :] if K > 1 else None
    return jax.nn.silu(out + b[None, None, :]), new_carry


def _gated_norm(y, z, scale, eps: float = 1e-6):
    y = y * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(y * y, axis=-1, keepdims=True)
    return y * jax.lax.rsqrt(ms + eps) * scale


def mamba2_block(params, x, cfg: ModelConfig, mesh, initial_state=None):
    """x: (B, S, d) -> (B, S, d); S must be a multiple of ssm_chunk."""
    B, S, d = x.shape
    di, H, P, N, G = _dims(cfg)
    Q = min(cfg.ssm_chunk, S)
    nc = S // Q
    dt_ = x.dtype

    proj = x @ params["in_proj"].astype(dt_)
    z, xBC, dtt = _split_proj(cfg, proj)
    xBC, _ = _causal_conv(xBC, params["conv_w"].astype(dt_),
                          params["conv_b"].astype(dt_))
    xs = xBC[..., :di].reshape(B, S, H, P)
    Bm = xBC[..., di : di + G * N].reshape(B, S, N).astype(jnp.float32)
    Cm = xBC[..., di + G * N :].reshape(B, S, N).astype(jnp.float32)
    dt = jax.nn.softplus(
        dtt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,) negative
    xs = shard(xs, mesh, "batch", "seq", "heads", None)

    # chunked SSD ------------------------------------------------------------
    xs_c = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    B_c = Bm.reshape(B, nc, Q, N)
    C_c = Cm.reshape(B, nc, Q, N)
    dt_c = dt.reshape(B, nc, Q, H)
    dA = dt_c * A[None, None, None, :]  # (B,nc,Q,H)
    cum = jnp.cumsum(dA, axis=2)  # inclusive cumsum within chunk
    # Stability clamp: decays below e^-20 are numerically zero, and the
    # clamp bounds exp(-cum) <= e^20 in the factorized intra-chunk term.
    cum = jnp.maximum(cum, -20.0)
    total = cum[:, :, -1:, :]  # (B,nc,1,H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j.  Factorized as
    # exp(cum_i) * exp(-cum_j) so the (Q, Q) term never carries the head
    # dim: y_intra[i] = exp(cum_i) * sum_j M[i,j] u[j] with
    # M = (C B^T) o causal, u[j] = exp(-cum_j) dt_j x_j.
    ii = jnp.arange(Q)
    causal = (ii[:, None] >= ii[None, :])[None, None, :, :]
    cb = jnp.einsum("bcin,bcjn->bcij", C_c, B_c)  # (B,nc,Q,Q)
    M = jnp.where(causal, cb, 0.0)
    u = jnp.exp(-cum)[..., None] * dt_c[..., None] * xs_c  # (B,nc,Q,H,P)
    y_intra = jnp.exp(cum)[..., None] * jnp.einsum(
        "bcij,bcjhp->bcihp", M, u
    )

    # per-chunk state contribution: sum_j exp(total - cum_j) dt_j B_j x_j^T
    decay_out = jnp.exp(total - cum)  # (B,nc,Q,H)
    s_local = jnp.einsum(
        "bcjh,bcjh,bcjn,bcjhp->bchnp", decay_out, dt_c, B_c, xs_c
    )  # (B,nc,H,N,P)

    # inter-chunk recurrence: s_c = exp(total_c) s_{c-1} + s_local_c
    g = jnp.exp(total[:, :, 0, :])  # (B,nc,H)

    def scan_fn(s_prev, inp):
        g_c, sl = inp  # (B,H), (B,H,N,P)
        s = g_c[:, :, None, None] * s_prev + sl
        return s, s_prev  # emit the state *entering* the chunk

    s0 = (
        jnp.zeros((B, H, N, P), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    s_last, s_in = jax.lax.scan(
        scan_fn,
        s0,
        (jnp.moveaxis(g, 1, 0), jnp.moveaxis(s_local, 1, 0)),
    )
    s_in = jnp.moveaxis(s_in, 0, 1)  # (B,nc,H,N,P) state entering each chunk

    # inter-chunk output: y_j += exp(cum_j) C_j . s_in
    y_inter = jnp.einsum(
        "bcjh,bcjn,bchnp->bcjhp", jnp.exp(cum), C_c, s_in
    )
    y = (y_intra + y_inter).reshape(B, S, H, P)
    y = y + params["D"].astype(jnp.float32)[None, None, :, None] * xs.astype(
        jnp.float32
    )
    y = _gated_norm(
        y.reshape(B, S, di), z, params["norm_scale"].astype(jnp.float32)
    )
    out = y.astype(dt_) @ params["out_proj"].astype(dt_)
    return shard(out, mesh, "batch", "seq", None), s_last


def mamba2_decode_step(params, x, cfg: ModelConfig, mesh, state):
    """x: (B, d) single token; state dict {ssm (B,H,N,P), conv (B,K-1,Cd)}."""
    B, d = x.shape
    di, H, P, N, G = _dims(cfg)
    dt_ = x.dtype
    proj = x @ params["in_proj"].astype(dt_)
    z, xBC, dtt = _split_proj(cfg, proj)
    xBC, new_conv = _causal_conv(
        xBC[:, None, :], params["conv_w"].astype(dt_),
        params["conv_b"].astype(dt_), carry=state["conv"],
    )
    xBC = xBC[:, 0]
    xs = xBC[..., :di].reshape(B, H, P).astype(jnp.float32)
    Bm = xBC[..., di : di + G * N].astype(jnp.float32)  # (B,N)
    Cm = xBC[..., di + G * N :].astype(jnp.float32)
    dt = jax.nn.softplus(
        dtt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32)
    )  # (B,H)
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    g = jnp.exp(dt * A[None, :])  # (B,H)
    s = state["ssm"].astype(jnp.float32)
    s_new = g[:, :, None, None] * s + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, Bm, xs
    )
    y = jnp.einsum("bn,bhnp->bhp", Cm, s_new)
    y = y + params["D"].astype(jnp.float32)[None, :, None] * xs
    y = _gated_norm(
        y.reshape(B, di), z, params["norm_scale"].astype(jnp.float32)
    )
    out = y.astype(dt_) @ params["out_proj"].astype(dt_)
    return (
        shard(out, mesh, "batch", None),
        {"ssm": s_new.astype(state["ssm"].dtype), "conv": new_conv},
    )
