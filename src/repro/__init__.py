"""repro: WLSH (weighted-LSH multi-weight ANN search) as a first-class
feature of a multi-pod JAX training/serving framework."""

__version__ = "0.1.0"
