"""MusicGen-medium [arXiv:2306.05284]: decoder-only over EnCodec tokens.

Backbone only per assignment: the EnCodec frontend is a stub —
input_specs() feeds precomputed frame embeddings (input_mode="embeddings").
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab=2048,
    input_mode="embeddings",
)
