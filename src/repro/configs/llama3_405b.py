"""Llama-3 405B [arXiv:2407.21783]: GQA kv=8, 128k vocab."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    n_layers=126,
    d_model=16_384,
    n_heads=128,
    n_kv_heads=8,
    d_ff=53_248,
    vocab=128_256,
    rope_theta=500_000.0,
    # decode_32k at global_batch=128 carries a 2.2 TB KV cache (with the
    # 2x GQA-TP head replication); f8 storage is what fits it on a single
    # 256-chip pod next to the 810 GB bf16 params (EXPERIMENTS.md Sec Perf)
    kv_dtype="float8_e4m3fn",
)
