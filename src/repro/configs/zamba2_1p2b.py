"""Zamba2-1.2B [arXiv:2411.15242]: Mamba2 backbone + shared attention block
(applied every 6 mamba layers, weights shared across applications)."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_block_every=6,
)
