"""OLMo-1B [arXiv:2402.00838]: non-parametric LayerNorm."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    n_layers=16,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=50_304,
    norm="nonparametric_ln",
    tie_embeddings=True,
)
