"""Mamba2-780m [arXiv:2405.21060]: SSD (state-space duality), attention-free."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
)
