"""h2o-danube3-4b [arXiv:2401.16818]: llama+mistral mix, sliding-window attn."""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    n_layers=24,
    d_model=3840,
    n_heads=32,
    n_kv_heads=8,
    d_ff=10_240,
    vocab=32_000,
    head_dim=120,
    sliding_window=4096,
)
