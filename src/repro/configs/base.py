"""Model/shape configuration dataclasses + the architecture registry.

One config module per assigned architecture lives next to this file; each
exports ``CONFIG``.  ``get_config(arch)`` resolves by name and
``reduced(cfg)`` derives the CPU-smoke variant (same family, tiny sizes).
"""

from __future__ import annotations

import dataclasses
import importlib

__all__ = ["ModelConfig", "ShapeConfig", "SHAPES", "ARCHS", "get_config",
           "reduced"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0  # 0 -> d_model // n_heads
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_dense_layers: int = 0
    dense_ff: int = 0  # d_ff of the leading dense layers (moonshot)
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_kernel: int = 4
    # --- hybrid (Zamba2) ---
    shared_block_every: int = 0  # shared attn+MLP block applied every k layers
    # --- attention ---
    sliding_window: int = 0  # 0 = full attention
    rope_theta: float = 10_000.0
    norm: str = "rmsnorm"  # rmsnorm | nonparametric_ln
    tie_embeddings: bool = False
    # --- modality frontend ---
    input_mode: str = "tokens"  # tokens | embeddings (audio/vlm stub)
    # --- numerics ---
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    kv_dtype: str = ""  # decode KV-cache storage dtype ("" = dtype);
    # "float8_e4m3fn" halves the cache (llama3-405b decode_32k only fits a
    # single pod with it — see EXPERIMENTS.md Sec Perf)

    @property
    def kv_dtype_(self) -> str:
        return self.kv_dtype or self.dtype

    @property
    def head_dim_(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def d_inner(self) -> int:  # mamba2 inner width
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def full_attention(self) -> bool:
        """True when long_500k decode would need a quadratic-size cache."""
        if self.family in ("ssm",):
            return False
        if self.family == "hybrid":
            return False  # constant SSM state + a few shared-attn caches
        return self.sliding_window == 0

    @property
    def attn_layers(self) -> int:
        if self.family == "ssm":
            return 0
        if self.family == "hybrid":
            return (self.n_layers + self.shared_block_every - 1) // max(
                self.shared_block_every, 1
            )
        return self.n_layers


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCHS = [
    "moonshot_v1_16b_a3b",
    "olmoe_1b_7b",
    "llama3_405b",
    "olmo_1b",
    "minicpm_2b",
    "h2o_danube_3_4b",
    "musicgen_medium",
    "chameleon_34b",
    "mamba2_780m",
    "zamba2_1p2b",
    "wlsh_index",  # the paper's technique as a dry-run "arch"
]

_ALIASES = {a.replace("_", "-"): a for a in ARCHS} | {
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "llama3-405b": "llama3_405b",
    "olmo-1b": "olmo_1b",
    "minicpm-2b": "minicpm_2b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "musicgen-medium": "musicgen_medium",
    "chameleon-34b": "chameleon_34b",
    "mamba2-780m": "mamba2_780m",
    "zamba2-1.2b": "zamba2_1p2b",
}


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def reduced(cfg: ModelConfig) -> ModelConfig:
    """CPU-smoke variant: same family/topology, tiny sizes."""
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        n_layers=max(2, min(4, cfg.n_layers)),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 4) if cfg.n_kv_heads else 0,
        d_ff=128,
        vocab=256,
        head_dim=16,
        n_experts=min(cfg.n_experts, 8),
        top_k=min(cfg.top_k, 2),
        n_shared_experts=min(cfg.n_shared_experts, 1),
        first_dense_layers=min(cfg.first_dense_layers, 1),
        dense_ff=128 if cfg.dense_ff else 0,
        ssm_state=min(cfg.ssm_state, 16),
        ssm_head_dim=32 if cfg.ssm_state else 64,
        ssm_chunk=16,
        shared_block_every=min(cfg.shared_block_every, 2),
        sliding_window=min(cfg.sliding_window, 32) if cfg.sliding_window else 0,
    )
