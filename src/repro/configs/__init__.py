"""Architecture registry: one module per assigned arch, plus shapes."""

from .base import ARCHS, SHAPES, ModelConfig, ShapeConfig, get_config, reduced

__all__ = ["ARCHS", "SHAPES", "ModelConfig", "ShapeConfig", "get_config",
           "reduced"]
