"""Chameleon-34B [arXiv:2405.09818]: early-fusion; VQ image tokens share the
65536 vocab.  Backbone only: the VQ tokenizer frontend is a stub —
input_specs() feeds precomputed patch embeddings (input_mode="embeddings").
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    n_layers=48,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22_016,
    vocab=65_536,
    input_mode="embeddings",
)
