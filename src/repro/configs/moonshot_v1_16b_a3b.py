"""moonshot-v1-16b-a3b (Moonlight-16B-A3B) [hf:moonshotai/Moonlight-16B-A3B].

DeepSeek-V3-style MoE: 64 routed experts top-6 + 2 shared experts,
expert d_ff 1408, first layer dense (d_ff 11264), GQA kv=16.
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=163_840,
    n_experts=64,
    top_k=6,
    n_shared_experts=2,
    first_dense_layers=1,
    dense_ff=11_264,
)
