"""The paper's own technique as a dry-run architecture: pod-scale WLSH
table group (1B points, SIFT-like d=128, beta=128).

beta=128 is a post-bound-relaxation table-group size (tau=500 caps groups;
relaxed Eq. 11 betas land in the tens-to-hundreds, Table 6).  The first-cut
config used beta=512 with q_batch=2048 -- both the (q, block, beta) scoring
working set (533 GB/chip measured at compile) and the Q*n*beta*L compare
work are infeasible at that point; see EXPERIMENTS.md Sec Perf for the
iteration.

Shapes map to index operations instead of LM steps:
  train_4k    -> build step (hash-encode 2^30 points)   [the Preprocess]
  prefill_32k -> query step, q_batch=64                 [the Search]
  decode_32k / long_500k -> skipped (no decode semantics for an index).
"""

from .base import ModelConfig

CONFIG = ModelConfig(
    name="wlsh-index",
    family="index",
    n_layers=0,
    d_model=128,  # point dimensionality
    n_heads=0,
    n_kv_heads=0,
    d_ff=128,  # beta (hash tables in the group)
    vocab=1 << 30,  # n points
)
