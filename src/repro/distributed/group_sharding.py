"""Sharded big-group serving: one group's rows across the device mesh.

A single table group's ``QueryState`` (codes ``(n, beta)`` + vectors
``(n, d)``) is the unit the serving stack pages, and until this layer it
had to fit one device.  This module makes the row dimension a first-class
mesh axis end to end:

  mesh        ``serving_mesh(n_shards)`` builds the serving mesh with
              ``n_shards`` devices on the "data" axis (a trailing
              size-1 "model" axis keeps the training meshes' two-axis
              layout).  Row placement always goes through the *strict*
              logical-name specs (``distributed.sharding.spec`` with
              ``strict=True``): a row capacity that does not divide the
              mesh is a hard error here, never a silent full replica
              per device.
  state       ``state_shardings`` gives the per-field placement of a
              resident group state — rows over every mesh axis,
              family/scalars replicated.  ``build_group_state_per_host``
              materializes that placement from per-host row ranges
              (``host_row_ranges``) so a huge corpus never exists as one
              host array; ``offload_state_sharded`` /
              ``restore_state_sharded`` page it per shard.
  query       inside the engine's ``shard_map`` both passes run on the
              local row slice through the ordinary kernel dispatch; the
              only cross-shard traffic is ``merge_histograms`` (a psum
              of the (Q, L+2) int level histograms — exact, ints) and
              ``merge_shard_topk`` (all-gather of the k per-shard
              survivors + global re-top-k).  Each shard re-ranks its
              survivors with the exact f32 diff-distance epilogue
              *before* the gather, and ties break by ascending global
              row id on every path, so the merged answer is bit-exact
              with the single-device engine.

``Batcher`` threads ``ServiceConfig.n_shards`` through here (mesh
construction, per-shard paging) and ``IndexConfig.n_shards`` /
``shard_axis`` keep the compiled-step cache key and the paging byte
accounting honest about the per-device slice.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from .sharding import named_sharding

__all__ = [
    "HostShardedState",
    "build_group_state_per_host",
    "host_row_ranges",
    "merge_histograms",
    "merge_shard_topk",
    "offload_state_sharded",
    "restore_state_sharded",
    "serving_mesh",
    "shard_row_offset",
    "state_shardings",
]


def serving_mesh(n_shards: int = 1, *, axis: str = "data") -> Mesh:
    """The serving mesh: ``n_shards`` devices on the row-sharding axis.

    Always a two-axis ``(axis, "model")`` mesh with the model axis at
    size 1, so the serving layer shares the training stack's mesh shape
    conventions and a ``(k, m)`` training mesh drops in unchanged.
    Raises with the ``XLA_FLAGS`` recipe when fewer than ``n_shards``
    devices are visible — on CPU a forced multi-device platform is one
    environment variable away.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    have = jax.device_count()
    if n_shards > have:
        raise ValueError(
            f"n_shards={n_shards} exceeds the {have} visible device(s); "
            f"for a forced multi-device CPU mesh set "
            f"XLA_FLAGS=--xla_force_host_platform_device_count={n_shards}"
        )
    if axis == "model":
        return jax.make_mesh((1, n_shards), ("data", "model"))
    if axis != "data":
        raise ValueError(f"shard axis must be 'data' or 'model', got {axis!r}")
    return jax.make_mesh((n_shards, 1), ("data", "model"))


def state_shardings(mesh: Mesh, cfg):
    """Strict per-field shardings of one group's resident ``QueryState``.

    Row-carrying fields (codes, points) shard over every mesh axis via
    the "rows" logical-name rule; the folded family and the scalars are
    replicated.  ``strict=True`` is the sharded-serving contract: a row
    capacity that does not divide the mesh raises instead of silently
    replicating the state onto every device (``Batcher.row_capacity``
    rounds capacities to a mesh-size multiple precisely so this never
    fires in the serving path).
    """
    from ..index.engine import QueryState  # deferred: engine imports us

    rows = functools.partial(named_sharding, mesh, ("rows", None),
                             strict=True)
    return QueryState(
        codes=rows(shape=(cfg.n, cfg.beta)),
        points=rows(shape=(cfg.n, cfg.d)),
        proj=named_sharding(mesh, (None, None)),
        b_int=named_sharding(mesh, (None,)),
        b_frac=named_sharding(mesh, (None,)),
        width=named_sharding(mesh, ()),
        n_valid=named_sharding(mesh, ()),
    )


# ------------------------------------------------------- in-shard collectives


def shard_row_offset(mesh_axes: tuple[str, ...],
                     axis_sizes: tuple[int, ...], n_loc: int):
    """Global row id of this shard's first local row (inside shard_map).

    Rows are laid out major-to-minor in mesh-axis order, so the offset is
    the shard's linearized mesh position times its slice length.  Every
    shard's local candidate indices are rebased by this before any
    cross-shard merge — which is what makes position-based tie-breaks
    equal ascending *global* row id, the same order the single-device
    scan produces.
    """
    off = jnp.int32(0)
    mul = 1
    for ax, size in reversed(tuple(zip(mesh_axes, axis_sizes))):
        off = off + jax.lax.axis_index(ax) * mul
        mul *= size
    return off * n_loc


def merge_histograms(hist_f, hist_g, mesh_axes: tuple[str, ...]):
    """Sum per-shard frequent/good level histograms across the mesh.

    The histograms are int32 counts, so the psum is exact — the merged
    stop condition is bit-identical to evaluating it over the unsharded
    corpus, regardless of shard count or reduction order.
    """
    return (jax.lax.psum(hist_f, mesh_axes),
            jax.lax.psum(hist_g, mesh_axes))


def merge_shard_topk(vals, idx, mesh_axes: tuple[str, ...], k: int):
    """Merge per-shard top-k survivors into the global top-k.

    All-gathers the ``(q, k)`` per-shard candidate distances and global
    row ids (bytes, not rows) and re-top-ks the ``(q, S*k)`` pool.  The
    gathered distances are the shards' exact f32 re-ranked values — no
    arithmetic happens on them here, only selection — so the merged
    ranking is bit-identical to a single device scoring the same rows,
    with distance ties resolved by gather position = ascending shard =
    ascending global row id.
    """
    gv = jax.lax.all_gather(vals, mesh_axes, tiled=False)  # (S, q, k)
    gi = jax.lax.all_gather(idx, mesh_axes, tiled=False)
    s, q = gv.shape[0], gv.shape[1]
    gv = jnp.moveaxis(gv, 0, 1).reshape(q, s * k)
    gi = jnp.moveaxis(gi, 0, 1).reshape(q, s * k)
    fvals, fpos = jax.lax.top_k(-gv, k)
    return -fvals, jnp.take_along_axis(gi, fpos, axis=1)


# ----------------------------------------------------------- per-host build


def host_row_ranges(capacity: int, n_shards: int) -> list[tuple[int, int]]:
    """Contiguous per-shard row ranges ``[(lo, hi), ...]`` over a capacity.

    The capacity must divide evenly (the same strict contract as
    ``state_shardings``); each range is one shard's slice of the padded
    row space, and a range's tail past the live row count is dead weight
    the build fills deterministically.
    """
    if n_shards < 1:
        raise ValueError(f"n_shards must be >= 1, got {n_shards}")
    if capacity % n_shards:
        raise ValueError(
            f"row capacity {capacity} does not divide {n_shards} shards; "
            f"round the capacity up first (Batcher.row_capacity does)"
        )
    n_loc = capacity // n_shards
    return [(s * n_loc, (s + 1) * n_loc) for s in range(n_shards)]


def _from_row_chunks(mesh: Mesh, chunks: list[np.ndarray],
                     sharding: NamedSharding, dtype) -> jax.Array:
    """Assemble a row-sharded device array from per-shard host chunks."""
    n_loc = chunks[0].shape[0]
    shape = (n_loc * len(chunks),) + chunks[0].shape[1:]
    arrs = []
    for dev, idx in sharding.addressable_devices_indices_map(shape).items():
        start = idx[0].start or 0
        arrs.append(
            jax.device_put(np.asarray(chunks[start // n_loc], dtype), dev)
        )
    return jax.make_array_from_single_device_arrays(shape, sharding, arrs)


def build_group_state_per_host(
    mesh: Mesh,
    cfg,
    gplan,
    points_loader,
    n_points: int,
):
    """Materialize a sharded ``QueryState`` from per-host row ranges.

    ``points_loader(lo, hi)`` returns the live corpus rows ``[lo, hi)``
    as ``(hi - lo, d)`` float32 — a memmap slice, a file-chunk read, a
    remote fetch — and is called once per shard range, so at no point
    does the full ``(n, d)`` corpus exist as one host array (the per-host
    peak is one shard's slice).  Host-shipped plan codes are row-sliced
    the same way; without them each padded chunk is encoded through the
    jitted f32 build step at the fixed ``(n_loc, d)`` per-device shape —
    the same local matmul the whole-corpus sharded build lowers to — so
    either path is bit-exact with ``build_group_state`` over the
    materialized corpus at the same capacity.
    """
    from ..index import builder  # deferred: builder imports engine

    if not 0 <= n_points <= cfg.n:
        raise ValueError(
            f"n_points={n_points} outside the row capacity [0, {cfg.n}]"
        )
    folded = gplan.folded()
    proj = builder.pad_cols(folded["proj"], cfg.beta)
    b_int = builder.pad_cols(folded["b_int"], cfg.beta)
    b_frac = builder.pad_cols(folded["b_frac"], cfg.beta)
    sh = state_shardings(mesh, cfg)
    vec_dt = jnp.dtype(cfg.vec_dtype)
    encode = None
    codes_chunks: list[np.ndarray] = []
    vec_chunks: list[np.ndarray] = []
    for lo, hi in host_row_ranges(cfg.n, mesh.size):
        n_loc = hi - lo
        m = max(0, min(hi, n_points) - lo)
        pts = np.zeros((n_loc, cfg.d), np.float32)
        if m:
            live = np.ascontiguousarray(
                points_loader(lo, lo + m), np.float32
            )
            if live.shape != (m, cfg.d):
                raise ValueError(
                    f"points_loader({lo}, {lo + m}) returned shape "
                    f"{live.shape}, expected ({m}, {cfg.d})"
                )
            pts[:m] = live
        if gplan.codes is not None:
            cods = np.full((n_loc, cfg.beta), builder._PAD_CODE, np.int32)
            if m:
                cods[:m] = builder.pad_cols(
                    gplan.codes[lo:lo + m], cfg.beta
                ).astype(np.int32)
            vecs = np.asarray(jnp.asarray(pts).astype(vec_dt))
        else:
            if encode is None:
                encode = jax.jit(functools.partial(
                    builder._build_fn, vec_dtype=vec_dt
                ))
            cods_d, vecs_d = encode(
                jnp.asarray(pts), jnp.asarray(proj),
                jnp.asarray(b_int), jnp.asarray(b_frac),
            )
            cods, vecs = np.asarray(cods_d), np.asarray(vecs_d)
        codes_chunks.append(cods)
        vec_chunks.append(vecs)

    from ..index.engine import QueryState

    return QueryState(
        codes=_from_row_chunks(mesh, codes_chunks, sh.codes, np.int32),
        points=_from_row_chunks(mesh, vec_chunks, sh.points,
                                np.dtype(vec_dt)),
        proj=jax.device_put(jnp.asarray(proj), sh.proj),
        b_int=jax.device_put(jnp.asarray(b_int), sh.b_int),
        b_frac=jax.device_put(jnp.asarray(b_frac), sh.b_frac),
        width=jax.device_put(jnp.asarray(1.0, jnp.float32), sh.width),
        n_valid=jax.device_put(jnp.asarray(n_points, jnp.int32),
                               sh.n_valid),
    )


# ------------------------------------------------------ per-shard paging


@dataclasses.dataclass
class HostShardedState:
    """Host copy of an evicted sharded group state, one chunk per shard.

    Row-carrying fields are lists of per-shard numpy chunks in global
    row order; the replicated family/scalars are plain arrays.  Keeping
    the shard structure means restore is one upload per shard straight
    to its device — never an all-rows host concatenation — and a
    multi-host deployment only ever holds its own shards.
    """

    codes: list[np.ndarray]
    points: list[np.ndarray]
    proj: np.ndarray
    b_int: np.ndarray
    b_frac: np.ndarray
    width: np.ndarray
    n_valid: np.ndarray


def _row_chunks(arr: jax.Array) -> list[np.ndarray]:
    """Per-shard host copies of a row-sharded array, replicas deduped."""
    by_start: dict[int, np.ndarray] = {}
    for s in arr.addressable_shards:
        start = s.index[0].start or 0
        if start not in by_start:
            by_start[start] = np.asarray(s.data)
    return [by_start[start] for start in sorted(by_start)]


def offload_state_sharded(state) -> HostShardedState:
    """Pull a sharded device state to host, shard by shard, bit-exactly.

    The device-to-host copy happens per addressable shard (replicas
    deduped), so the host footprint mirrors the device layout and the
    chunks carry the exact device bytes — a later
    ``restore_state_sharded`` round-trips them untouched.
    """
    return HostShardedState(
        codes=_row_chunks(state.codes),
        points=_row_chunks(state.points),
        proj=np.asarray(state.proj),
        b_int=np.asarray(state.b_int),
        b_frac=np.asarray(state.b_frac),
        width=np.asarray(state.width),
        n_valid=np.asarray(state.n_valid),
    )


def restore_state_sharded(mesh: Mesh, host: HostShardedState):
    """Upload an ``offload_state_sharded`` copy back onto the mesh.

    Each chunk is ``device_put`` straight to its shard's device and the
    global arrays assembled without any host-side concatenation; the
    restored state is bit-identical to the evicted one (same bytes, same
    placement), so paging a sharded group can never perturb answers.
    """
    from ..index.engine import QueryState

    rows = functools.partial(named_sharding, mesh, ("rows", None),
                             strict=True)
    n_codes = sum(c.shape[0] for c in host.codes)
    n_pts = sum(c.shape[0] for c in host.points)
    sh_codes = rows(shape=(n_codes, host.codes[0].shape[1]))
    sh_pts = rows(shape=(n_pts, host.points[0].shape[1]))
    return QueryState(
        codes=_from_row_chunks(mesh, host.codes, sh_codes,
                               host.codes[0].dtype),
        points=_from_row_chunks(mesh, host.points, sh_pts,
                                host.points[0].dtype),
        proj=jax.device_put(host.proj, named_sharding(mesh, (None, None))),
        b_int=jax.device_put(host.b_int, named_sharding(mesh, (None,))),
        b_frac=jax.device_put(host.b_frac, named_sharding(mesh, (None,))),
        width=jax.device_put(host.width, named_sharding(mesh, ())),
        n_valid=jax.device_put(host.n_valid, named_sharding(mesh, ())),
    )
