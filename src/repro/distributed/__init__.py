"""Distribution substrate: sharding rules, fault tolerance."""

from .sharding import named_sharding, shard, spec, with_rules

__all__ = ["named_sharding", "shard", "spec", "with_rules"]
