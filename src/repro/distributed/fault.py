"""Fault-tolerance substrate: preemption handling, straggler detection,
restart supervision, elastic re-sharding helpers.

On real pods these hook SIGTERM (maintenance events), per-host heartbeats
and the checkpoint manager; everything here is host-side and fully
exercisable on CPU (tests simulate stragglers and restarts).
"""

from __future__ import annotations

import dataclasses
import signal
import time
from collections import deque

__all__ = ["PreemptionHandler", "StragglerMonitor", "RestartSupervisor"]


class PreemptionHandler:
    """Converts SIGTERM/SIGINT into a checkpoint-and-exit flag.

    Usage:  handler = PreemptionHandler(); ... if handler.should_stop: save.
    """

    def __init__(self, signals=(signal.SIGTERM,)):
        self.should_stop = False
        self._prev = {}
        for s in signals:
            try:
                self._prev[s] = signal.signal(s, self._on_signal)
            except (ValueError, OSError):  # non-main thread / platform
                pass

    def _on_signal(self, signum, frame):
        self.should_stop = True

    def restore(self):
        for s, h in self._prev.items():
            signal.signal(s, h)


@dataclasses.dataclass
class StragglerReport:
    step: int
    duration: float
    median: float
    ratio: float


class StragglerMonitor:
    """Flags steps (or, fed per-host durations, hosts) slower than
    ``threshold`` x the rolling median.  At pod scale the mitigation is
    (1) log + alert, (2) exclude the host at the next elastic restart;
    both are driven off this signal.
    """

    def __init__(self, window: int = 50, threshold: float = 2.0):
        self.window = window
        self.threshold = threshold
        self.durations: deque[float] = deque(maxlen=window)
        self.flagged: list[StragglerReport] = []
        self._step = 0
        self._t0: float | None = None

    def start(self):
        self._t0 = time.monotonic()

    def stop(self) -> StragglerReport | None:
        assert self._t0 is not None, "start() not called"
        dur = time.monotonic() - self._t0
        self._t0 = None
        return self.record(dur)

    def record(self, duration: float) -> StragglerReport | None:
        self._step += 1
        report = None
        if len(self.durations) >= max(5, self.window // 5):
            med = sorted(self.durations)[len(self.durations) // 2]
            if med > 0 and duration > self.threshold * med:
                report = StragglerReport(
                    self._step, duration, med, duration / med
                )
                self.flagged.append(report)
        self.durations.append(duration)
        return report


class RestartSupervisor:
    """Run a (resumable) body with bounded automatic restarts.

    The body must accept ``resume_step`` and return normally on success;
    any exception triggers a reload-from-latest-checkpoint restart.  This
    is the single-process stand-in for the pod-level supervisor that
    re-schedules failed workers.
    """

    def __init__(self, max_restarts: int = 3, backoff_s: float = 0.0):
        self.max_restarts = max_restarts
        self.backoff_s = backoff_s
        self.restarts = 0
        self.failures: list[str] = []

    def run(self, body, resume_step_fn):
        while True:
            try:
                return body(resume_step_fn())
            except Exception as e:  # noqa: BLE001 — supervisor boundary
                self.restarts += 1
                self.failures.append(f"{type(e).__name__}: {e}")
                if self.restarts > self.max_restarts:
                    raise
                if self.backoff_s:
                    time.sleep(self.backoff_s)
