"""Logical-axis sharding rules (FSDP + TP + EP + SP) for the model zoo.

Every tensor dimension is tagged with a logical name; ``spec()`` maps names
to mesh axes with a divisibility fallback (a dimension that does not divide
by its mesh axes is replicated — e.g. musicgen's 24 heads on a 16-wide
model axis).  The fallback warns once per (name, shape) — a silently
replicated dimension multiplies the per-device footprint by the mesh size,
which for serving-state rows would turn an 8-way shard into 8 full
replicas; layers that cannot afford that (the group-sharding layer) pass
``strict=True`` to make non-divisibility an error instead.  Rules:

  batch    -> ("pod", "data")     data parallel
  fsdp     -> ("pod", "data")     parameter/optimizer sharding (ZeRO-3)
  model    -> ("model",)          tensor parallel (Megatron column/row)
  heads/kv_heads/ff/vocab/experts -> ("model",)
  rows     -> ("pod", "data", "model")  serving-state point rows (the WLSH
              group states shard rows over every mesh axis, see
              distributed.group_sharding)
  seq      -> ()                  (("pod","data") for seq-sharded KV caches)
  layers/None -> replicated

``with_rules`` overrides rules locally (e.g. long-context decode shards the
KV-cache sequence over the data axes because batch == 1).
"""

from __future__ import annotations

import contextlib
import warnings
from typing import Iterable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "spec",
    "shard",
    "shard_map_nocheck",
    "named_sharding",
    "with_rules",
    "axis_size",
]


def shard_map_nocheck(f, mesh: Mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions.

    jax >= 0.5 exports shard_map at top level (flag named check_vma);
    0.4.x ships it under jax.experimental with check_rep.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map  # jax 0.4.x

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )

_DEFAULT_RULES: dict[str | None, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "model": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "rows": ("pod", "data", "model"),
    "seq": (),
    "act_seq": ("model",),  # Megatron-SP residual stream between layers
    "kv_seq": (),
    "layers": (),
    None: (),
}

_rules_stack: list[dict] = [dict(_DEFAULT_RULES)]


def current_rules() -> dict:
    return _rules_stack[-1]


@contextlib.contextmanager
def with_rules(**overrides):
    new = dict(current_rules())
    for k, v in overrides.items():
        new[k] = tuple(v) if isinstance(v, (list, tuple)) else (v,)
    _rules_stack.append(new)
    try:
        yield
    finally:
        _rules_stack.pop()


def axis_size(mesh: Mesh, axes: Iterable[str]) -> int:
    s = 1
    for a in axes:
        if a in mesh.axis_names:
            s *= mesh.shape[a]
    return s


# (name, shape) pairs whose divisibility fallback already warned once —
# the fallback is deliberate for a handful of model-zoo dims (e.g. 24
# heads on a 16-wide model axis) and warning per call would be noise, but
# *silent* replication hides an N-fold footprint blowup from whoever
# sized the mesh.
_replication_warned: set[tuple] = set()


def spec(mesh: Mesh, names: tuple[str | None, ...],
         shape: tuple[int, ...] | None = None, *,
         strict: bool = False) -> P:
    """PartitionSpec from logical dim names, with divisibility fallback.

    A dimension whose size does not divide its mesh axes is replicated,
    with a once-per-(name, shape) ``UserWarning`` naming the footprint
    cost.  ``strict=True`` turns the fallback into a ``ValueError`` — the
    contract the group-sharding layer requests, where replicating the
    point rows would multiply the paging budget by the mesh size.
    """
    rules = current_rules()
    parts = []
    for i, name in enumerate(names):
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.axis_names)
        if not axes:
            parts.append(None)
            continue
        if shape is not None:
            size = axis_size(mesh, axes)
            if shape[i] % size != 0:
                if strict:
                    raise ValueError(
                        f"dim {i} ({name!r}) of shape {tuple(shape)} does "
                        f"not divide mesh axes {axes} (size {size}); "
                        f"strict sharding refuses to replicate — pad the "
                        f"dimension to a multiple of {size}"
                    )
                key = (name, tuple(shape))
                if key not in _replication_warned:
                    _replication_warned.add(key)
                    warnings.warn(
                        f"replicating dim {i} ({name!r}) of shape "
                        f"{tuple(shape)}: size {shape[i]} does not divide "
                        f"mesh axes {axes} (size {size}) — every device "
                        f"holds a full copy ({size}x the sharded "
                        f"footprint)",
                        UserWarning,
                        stacklevel=2,
                    )
                # replicate instead of uneven-sharding stacked/scanned dims
                parts.append(None)
                continue
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def named_sharding(mesh: Mesh, names, shape=None, *,
                   strict: bool = False) -> NamedSharding:
    return NamedSharding(mesh, spec(mesh, tuple(names), shape,
                                    strict=strict))


def shard(x, mesh: Mesh | None, *names):
    """with_sharding_constraint by logical names (no-op without mesh)."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(mesh, names, tuple(x.shape))
    )
