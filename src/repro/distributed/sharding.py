"""Logical-axis sharding rules (FSDP + TP + EP + SP) for the model zoo.

Every tensor dimension is tagged with a logical name; ``spec()`` maps names
to mesh axes with a divisibility fallback (a dimension that does not divide
by its mesh axes is replicated — e.g. musicgen's 24 heads on a 16-wide
model axis).  Rules:

  batch    -> ("pod", "data")     data parallel
  fsdp     -> ("pod", "data")     parameter/optimizer sharding (ZeRO-3)
  model    -> ("model",)          tensor parallel (Megatron column/row)
  heads/kv_heads/ff/vocab/experts -> ("model",)
  seq      -> ()                  (("pod","data") for seq-sharded KV caches)
  layers/None -> replicated

``with_rules`` overrides rules locally (e.g. long-context decode shards the
KV-cache sequence over the data axes because batch == 1).
"""

from __future__ import annotations

import contextlib
from typing import Iterable

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "spec",
    "shard",
    "shard_map_nocheck",
    "named_sharding",
    "with_rules",
    "axis_size",
]


def shard_map_nocheck(f, mesh: Mesh, in_specs, out_specs):
    """shard_map with replication checking off, across jax versions.

    jax >= 0.5 exports shard_map at top level (flag named check_vma);
    0.4.x ships it under jax.experimental with check_rep.
    """
    if hasattr(jax, "shard_map"):
        return jax.shard_map(
            f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
            check_vma=False,
        )
    from jax.experimental.shard_map import shard_map  # jax 0.4.x

    return shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )

_DEFAULT_RULES: dict[str | None, tuple[str, ...]] = {
    "batch": ("pod", "data"),
    "fsdp": ("pod", "data"),
    "model": ("model",),
    "heads": ("model",),
    "kv_heads": ("model",),
    "ff": ("model",),
    "vocab": ("model",),
    "experts": ("model",),
    "seq": (),
    "act_seq": ("model",),  # Megatron-SP residual stream between layers
    "kv_seq": (),
    "layers": (),
    None: (),
}

_rules_stack: list[dict] = [dict(_DEFAULT_RULES)]


def current_rules() -> dict:
    return _rules_stack[-1]


@contextlib.contextmanager
def with_rules(**overrides):
    new = dict(current_rules())
    for k, v in overrides.items():
        new[k] = tuple(v) if isinstance(v, (list, tuple)) else (v,)
    _rules_stack.append(new)
    try:
        yield
    finally:
        _rules_stack.pop()


def axis_size(mesh: Mesh, axes: Iterable[str]) -> int:
    s = 1
    for a in axes:
        if a in mesh.axis_names:
            s *= mesh.shape[a]
    return s


def spec(mesh: Mesh, names: tuple[str | None, ...],
         shape: tuple[int, ...] | None = None) -> P:
    """PartitionSpec from logical dim names, with divisibility fallback."""
    rules = current_rules()
    parts = []
    for i, name in enumerate(names):
        axes = tuple(a for a in rules.get(name, ()) if a in mesh.axis_names)
        if not axes:
            parts.append(None)
            continue
        if shape is not None:
            size = axis_size(mesh, axes)
            if shape[i] % size != 0:
                # replicate instead of uneven-sharding stacked/scanned dims
                parts.append(None)
                continue
        parts.append(axes if len(axes) > 1 else axes[0])
    return P(*parts)


def named_sharding(mesh: Mesh, names, shape=None) -> NamedSharding:
    return NamedSharding(mesh, spec(mesh, tuple(names), shape))


def shard(x, mesh: Mesh | None, *names):
    """with_sharding_constraint by logical names (no-op without mesh)."""
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, named_sharding(mesh, names, tuple(x.shape))
    )
