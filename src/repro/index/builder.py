"""Distributed WLSH table build (the paper's Preprocess on a mesh).

Points are sharded over the point axes; the hash encode is a plain sharded
matmul (rows x replicated projection), so the build is embarrassingly
parallel — XLA emits zero collectives for it.  The group's center weight
and bucket width are *folded* into the projection once so that serving
never touches them:

    proj_folded = diag(W_center) @ A / w
    codes       = floor(x @ proj_folded + b_frac) + b_int
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.families import LpFamilyParams
from ..core.serving_plan import GroupServingPlan
from ..kernels import ops
from .config import IndexConfig
from .engine import QueryState, _point_axes

__all__ = [
    "fold_center_weight",
    "make_build_step",
    "build_state",
    "build_group_state",
    "offload_state",
    "restore_state",
    "pad_cols",
    "build_input_specs",
]


def fold_center_weight(fam: LpFamilyParams) -> dict[str, np.ndarray]:
    """Fold center weight + width into the projection (host-side, once)."""
    proj = fam.proj.astype(np.float64) * fam.center_weight[:, None].astype(
        np.float64
    ) / fam.width
    return dict(
        proj=proj.astype(np.float32),
        b_int=fam.b_int.astype(np.int32),
        b_frac=fam.b_frac.astype(np.float32),
        width=np.float32(1.0),
    )


def _build_fn(points, proj, b_int, b_frac, vec_dtype):
    codes = ops.hash_encode(
        points.astype(jnp.float32),
        jnp.ones((points.shape[1],), jnp.float32),
        proj,
        b_int,
        b_frac,
        1.0,
        use_pallas=False,  # sharded matmul: XLA path; Pallas on TPU shards
    )
    return codes, points.astype(vec_dtype)


def make_build_step(mesh: Mesh, cfg: IndexConfig):
    """jit'd sharded build: (points, proj, b_int, b_frac) -> (codes, vectors)."""
    pa = _point_axes(mesh)
    rows = NamedSharding(mesh, P(pa, None))
    rep2 = NamedSharding(mesh, P(None, None))
    rep1 = NamedSharding(mesh, P(None))
    fn = functools.partial(_build_fn, vec_dtype=jnp.dtype(cfg.vec_dtype))
    return jax.jit(
        fn,
        in_shardings=(rows, rep2, rep1, rep1),
        out_shardings=(rows, rows),
    )


def build_input_specs(cfg: IndexConfig):
    return dict(
        points=jax.ShapeDtypeStruct((cfg.n, cfg.d), jnp.float32),
        proj=jax.ShapeDtypeStruct((cfg.d, cfg.beta), jnp.float32),
        b_int=jax.ShapeDtypeStruct((cfg.beta,), jnp.int32),
        b_frac=jax.ShapeDtypeStruct((cfg.beta,), jnp.float32),
    )


def build_state(
    mesh: Mesh, cfg: IndexConfig, points: np.ndarray, fam: LpFamilyParams
) -> QueryState:
    """Materialize a device-resident QueryState from host data (small/medium
    scale path used by examples/tests; production feeds per-host shards)."""
    folded = fold_center_weight(fam)
    step = make_build_step(mesh, cfg)
    codes, vecs = step(
        jnp.asarray(points, jnp.float32),
        jnp.asarray(folded["proj"]),
        jnp.asarray(folded["b_int"]),
        jnp.asarray(folded["b_frac"]),
    )
    rep2 = NamedSharding(mesh, P(None, None))
    rep1 = NamedSharding(mesh, P(None))
    return QueryState(
        codes=codes,
        points=vecs,
        proj=jax.device_put(jnp.asarray(folded["proj"]), rep2),
        b_int=jax.device_put(jnp.asarray(folded["b_int"]), rep1),
        b_frac=jax.device_put(jnp.asarray(folded["b_frac"]), rep1),
        width=jax.device_put(jnp.asarray(1.0, jnp.float32),
                             NamedSharding(mesh, P())),
    )


def _state_shardings(mesh: Mesh) -> QueryState:
    """Per-field shardings of a resident QueryState (rows over all axes)."""
    pa = _point_axes(mesh)
    return QueryState(
        codes=NamedSharding(mesh, P(pa, None)),
        points=NamedSharding(mesh, P(pa, None)),
        proj=NamedSharding(mesh, P(None, None)),
        b_int=NamedSharding(mesh, P(None)),
        b_frac=NamedSharding(mesh, P(None)),
        width=NamedSharding(mesh, P()),
    )


def offload_state(state: QueryState) -> QueryState:
    """Pull a device QueryState into host memory, bit-exactly.

    The host copy is a plain-numpy QueryState (codes keep int32, vectors
    keep ``vec_dtype`` — bfloat16 arrays come back as ml_dtypes numpy
    arrays), so a later ``restore_state`` round-trips the exact device
    bytes: candidate sets and answers are unchanged across an
    evict/restore cycle.  Dropping the returned value's device-side
    ancestor frees the group's device footprint.
    """
    return QueryState(
        **{
            f.name: np.asarray(getattr(state, f.name))
            for f in dataclasses.fields(QueryState)
        }
    )


def restore_state(mesh: Mesh, host: QueryState) -> QueryState:
    """Upload an ``offload_state`` host copy back onto the mesh.

    A pure ``device_put`` per field with the build-time shardings — no
    re-encode, no recompile — so restore cost is one host-to-device copy
    of ``IndexConfig.state_nbytes`` bytes and the restored state is
    bit-identical to the evicted one.
    """
    sh = _state_shardings(mesh)
    return QueryState(
        **{
            f.name: jax.device_put(getattr(host, f.name), getattr(sh, f.name))
            for f in dataclasses.fields(QueryState)
        }
    )


def pad_cols(x: np.ndarray, beta: int) -> np.ndarray:
    """Pad the trailing (table) axis to ``beta`` columns with zeros.

    Padded tables are dead weight only: every query masks lanes >= its
    beta_q in freq_level, and beta_q never exceeds the group's real beta.
    """
    have = x.shape[-1]
    if have == beta:
        return x
    if have > beta:
        raise ValueError(f"group beta {have} exceeds padded config beta {beta}")
    pad = [(0, 0)] * (x.ndim - 1) + [(0, beta - have)]
    return np.pad(x, pad)


def build_group_state(
    mesh: Mesh,
    cfg: IndexConfig,
    points: np.ndarray,
    gplan: GroupServingPlan,
) -> QueryState:
    """Materialize one table group's QueryState from its serving plan.

    ``cfg.beta`` may exceed the group's real table count (bucketed shape
    padding, config.pad_beta); family params are zero-padded to match.  When
    the plan ships host-computed codes they are placed directly (bit-exact
    candidate sets vs the host oracle); otherwise the codes are built on
    device through the sharded encode.
    """
    folded = gplan.folded()
    proj = pad_cols(folded["proj"], cfg.beta)
    b_int = pad_cols(folded["b_int"], cfg.beta)
    b_frac = pad_cols(folded["b_frac"], cfg.beta)
    pa = _point_axes(mesh)
    rows = NamedSharding(mesh, P(pa, None))
    rep2 = NamedSharding(mesh, P(None, None))
    rep1 = NamedSharding(mesh, P(None))

    if gplan.codes is not None:
        codes = jax.device_put(
            jnp.asarray(pad_cols(gplan.codes, cfg.beta), jnp.int32), rows
        )
        vecs = jax.device_put(
            jnp.asarray(points).astype(jnp.dtype(cfg.vec_dtype)), rows
        )
    else:
        step = make_build_step(mesh, cfg)
        codes, vecs = step(
            jnp.asarray(points, jnp.float32),
            jnp.asarray(proj),
            jnp.asarray(b_int),
            jnp.asarray(b_frac),
        )
    return QueryState(
        codes=codes,
        points=vecs,
        proj=jax.device_put(jnp.asarray(proj), rep2),
        b_int=jax.device_put(jnp.asarray(b_int), rep1),
        b_frac=jax.device_put(jnp.asarray(b_frac), rep1),
        width=jax.device_put(jnp.asarray(1.0, jnp.float32),
                             NamedSharding(mesh, P())),
    )
