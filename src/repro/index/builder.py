"""Distributed WLSH table build (the paper's Preprocess on a mesh).

Points are sharded over the point axes; the hash encode is a plain sharded
matmul (rows x replicated projection), so the build is embarrassingly
parallel — XLA emits zero collectives for it.  The group's center weight
and bucket width are *folded* into the projection once so that serving
never touches them:

    proj_folded = diag(W_center) @ A / w
    codes       = floor(x @ proj_folded + b_frac) + b_int
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..core.families import LpFamilyParams
from ..core.serving_plan import GroupServingPlan
from ..kernels import ops
from .config import IndexConfig
from .engine import QueryState, _point_axes, encode_queries

__all__ = [
    "append_to_state",
    "fold_center_weight",
    "make_build_step",
    "build_state",
    "build_group_state",
    "offload_state",
    "restore_state",
    "pad_cols",
    "build_input_specs",
    "seal_segment",
]

# Row-capacity padding fill for host-code builds: a fixed sentinel code
# (the same convention ops.py pads with) and zero vectors.  Dead rows are
# masked out of the query step by ``QueryState.n_valid``, so the fill only
# has to be deterministic — every build path over the same live rows must
# produce bit-identical states.
_PAD_CODE = np.iinfo(np.int32).max // 2


def fold_center_weight(fam: LpFamilyParams) -> dict[str, np.ndarray]:
    """Fold center weight + width into the projection (host-side, once)."""
    proj = fam.proj.astype(np.float64) * fam.center_weight[:, None].astype(
        np.float64
    ) / fam.width
    return dict(
        proj=proj.astype(np.float32),
        b_int=fam.b_int.astype(np.int32),
        b_frac=fam.b_frac.astype(np.float32),
        width=np.float32(1.0),
    )


def _build_fn(points, proj, b_int, b_frac, vec_dtype):
    codes = ops.hash_encode(
        points.astype(jnp.float32),
        jnp.ones((points.shape[1],), jnp.float32),
        proj,
        b_int,
        b_frac,
        1.0,
        use_pallas=False,  # sharded matmul: XLA path; Pallas on TPU shards
    )
    return codes, points.astype(vec_dtype)


def make_build_step(mesh: Mesh, cfg: IndexConfig):
    """jit'd sharded build: (points, proj, b_int, b_frac) -> (codes, vectors)."""
    pa = _point_axes(mesh)
    rows = NamedSharding(mesh, P(pa, None))
    rep2 = NamedSharding(mesh, P(None, None))
    rep1 = NamedSharding(mesh, P(None))
    fn = functools.partial(_build_fn, vec_dtype=jnp.dtype(cfg.vec_dtype))
    return jax.jit(
        fn,
        in_shardings=(rows, rep2, rep1, rep1),
        out_shardings=(rows, rows),
    )


def build_input_specs(cfg: IndexConfig):
    return dict(
        points=jax.ShapeDtypeStruct((cfg.n, cfg.d), jnp.float32),
        proj=jax.ShapeDtypeStruct((cfg.d, cfg.beta), jnp.float32),
        b_int=jax.ShapeDtypeStruct((cfg.beta,), jnp.int32),
        b_frac=jax.ShapeDtypeStruct((cfg.beta,), jnp.float32),
    )


def build_state(
    mesh: Mesh, cfg: IndexConfig, points: np.ndarray, fam: LpFamilyParams
) -> QueryState:
    """Materialize a device-resident QueryState from host data (small/medium
    scale path used by examples/tests; production feeds per-host shards)."""
    folded = fold_center_weight(fam)
    step = make_build_step(mesh, cfg)
    codes, vecs = step(
        jnp.asarray(points, jnp.float32),
        jnp.asarray(folded["proj"]),
        jnp.asarray(folded["b_int"]),
        jnp.asarray(folded["b_frac"]),
    )
    rep2 = NamedSharding(mesh, P(None, None))
    rep1 = NamedSharding(mesh, P(None))
    rep0 = NamedSharding(mesh, P())
    return QueryState(
        codes=codes,
        points=vecs,
        proj=jax.device_put(jnp.asarray(folded["proj"]), rep2),
        b_int=jax.device_put(jnp.asarray(folded["b_int"]), rep1),
        b_frac=jax.device_put(jnp.asarray(folded["b_frac"]), rep1),
        width=jax.device_put(jnp.asarray(1.0, jnp.float32), rep0),
        n_valid=jax.device_put(jnp.asarray(len(points), jnp.int32), rep0),
    )


def _state_shardings(mesh: Mesh) -> QueryState:
    """Per-field shardings of a resident QueryState (rows over all axes)."""
    pa = _point_axes(mesh)
    return QueryState(
        codes=NamedSharding(mesh, P(pa, None)),
        points=NamedSharding(mesh, P(pa, None)),
        proj=NamedSharding(mesh, P(None, None)),
        b_int=NamedSharding(mesh, P(None)),
        b_frac=NamedSharding(mesh, P(None)),
        width=NamedSharding(mesh, P()),
        n_valid=NamedSharding(mesh, P()),
    )


def offload_state(state: QueryState) -> QueryState:
    """Pull a device QueryState into host memory, bit-exactly.

    The host copy is a plain-numpy QueryState (codes keep int32, vectors
    keep ``vec_dtype`` — bfloat16 arrays come back as ml_dtypes numpy
    arrays), so a later ``restore_state`` round-trips the exact device
    bytes: candidate sets and answers are unchanged across an
    evict/restore cycle.  Dropping the returned value's device-side
    ancestor frees the group's device footprint.
    """
    return QueryState(
        **{
            f.name: np.asarray(getattr(state, f.name))
            for f in dataclasses.fields(QueryState)
        }
    )


def restore_state(mesh: Mesh, host: QueryState) -> QueryState:
    """Upload an ``offload_state`` host copy back onto the mesh.

    A pure ``device_put`` per field with the build-time shardings — no
    re-encode, no recompile — so restore cost is one host-to-device copy
    of ``IndexConfig.state_nbytes`` bytes and the restored state is
    bit-identical to the evicted one.
    """
    sh = _state_shardings(mesh)
    return QueryState(
        **{
            f.name: jax.device_put(getattr(host, f.name), getattr(sh, f.name))
            for f in dataclasses.fields(QueryState)
        }
    )


def pad_cols(x: np.ndarray, beta: int) -> np.ndarray:
    """Pad the trailing (table) axis to ``beta`` columns with zeros.

    Padded tables are dead weight only: every query masks lanes >= its
    beta_q in freq_level, and beta_q never exceeds the group's real beta.
    """
    have = x.shape[-1]
    if have == beta:
        return x
    if have > beta:
        raise ValueError(f"group beta {have} exceeds padded config beta {beta}")
    pad = [(0, 0)] * (x.ndim - 1) + [(0, beta - have)]
    return np.pad(x, pad)


def build_group_state(
    mesh: Mesh,
    cfg: IndexConfig,
    points: np.ndarray | None,
    gplan: GroupServingPlan,
    *,
    extra_points: np.ndarray | None = None,
    extra_codes: np.ndarray | None = None,
    base_rows: np.ndarray | None = None,
    points_loader=None,
    n_points: int | None = None,
) -> QueryState:
    """Materialize one table group's QueryState from its serving plan.

    ``cfg.beta`` may exceed the group's real table count (bucketed shape
    padding, config.pad_beta); family params are zero-padded to match.  When
    the plan ships host-computed codes they are placed directly (bit-exact
    candidate sets vs the host oracle); otherwise the codes are built on
    device through the sharded encode.

    Streaming extensions:

    * ``cfg.n`` is a row *capacity* and may exceed the live row count;
      excess rows are deterministic dead weight (sentinel codes / zero
      vectors on the host-code path, encoded zero vectors on the device
      path) masked out of every query by ``QueryState.n_valid``.
    * ``extra_points`` appends already-compacted streaming rows after the
      base corpus (the cold-rebuild path for a group that has absorbed
      delta segments); ``extra_codes`` carries their sealed hash codes on
      the host-code path (``seal_segment`` output, already at ``cfg.beta``
      columns).  The result is bit-exact with a state that reached the
      same rows through ``append_to_state``.
    * ``base_rows`` restricts the base corpus to those row indices (in
      that order) before the extra rows are appended — the tombstone-purge
      rebuild path: purged rows simply never enter the state, and the
      plan's host codes are row-sliced to match.  None keeps every row.
    * ``points_loader`` + ``n_points`` replace ``points`` (pass None)
      with per-host row ranges: ``points_loader(lo, hi)`` yields just the
      rows one shard needs, so a huge corpus never materializes on one
      host (``distributed.group_sharding.build_group_state_per_host``).
      Bit-exact with the materialized path at the same capacity; the
      streaming kwargs don't combine with it (delta compaction rebuilds
      from the materialized corpus).
    """
    if points_loader is not None:
        if points is not None:
            raise ValueError(
                "pass either points or points_loader, not both"
            )
        if n_points is None:
            raise ValueError("points_loader requires n_points")
        if (extra_points is not None or extra_codes is not None
                or base_rows is not None):
            raise ValueError(
                "points_loader does not combine with the streaming "
                "kwargs (extra_points/extra_codes/base_rows)"
            )
        from ..distributed.group_sharding import build_group_state_per_host

        return build_group_state_per_host(
            mesh, cfg, gplan, points_loader, n_points
        )
    folded = gplan.folded()
    proj = pad_cols(folded["proj"], cfg.beta)
    b_int = pad_cols(folded["b_int"], cfg.beta)
    b_frac = pad_cols(folded["b_frac"], cfg.beta)
    pa = _point_axes(mesh)
    rows = NamedSharding(mesh, P(pa, None))
    rep2 = NamedSharding(mesh, P(None, None))
    rep1 = NamedSharding(mesh, P(None))
    rep0 = NamedSharding(mesh, P())

    points = np.ascontiguousarray(points, dtype=np.float32)
    if base_rows is not None:
        base_rows = np.asarray(base_rows, np.int64)
        points = np.ascontiguousarray(points[base_rows])
    if extra_points is not None and len(extra_points):
        extra_points = np.ascontiguousarray(extra_points, dtype=np.float32)
        points = np.concatenate([points, extra_points], axis=0)
    n_rows = len(points)
    if n_rows > cfg.n:
        raise ValueError(
            f"{n_rows} live rows exceed the config row capacity {cfg.n}"
        )
    pad_rows = cfg.n - n_rows

    if gplan.codes is not None:
        base_codes = gplan.codes
        if base_rows is not None:
            base_codes = base_codes[base_rows]
        codes_np = pad_cols(base_codes, cfg.beta).astype(np.int32)
        if extra_codes is not None and len(extra_codes):
            if extra_codes.shape[1] != cfg.beta:
                raise ValueError(
                    f"extra_codes must be sealed at cfg.beta={cfg.beta} "
                    f"columns, got {extra_codes.shape[1]}"
                )
            codes_np = np.concatenate(
                [codes_np, extra_codes.astype(np.int32)], axis=0
            )
        if len(codes_np) != n_rows:
            raise ValueError(
                f"host codes cover {len(codes_np)} rows, expected {n_rows} "
                f"(pass extra_codes alongside extra_points)"
            )
        if pad_rows:
            codes_np = np.concatenate([
                codes_np,
                np.full((pad_rows, cfg.beta), _PAD_CODE, np.int32),
            ], axis=0)
            points = np.concatenate([
                points, np.zeros((pad_rows, cfg.d), np.float32)
            ], axis=0)
        codes = jax.device_put(jnp.asarray(codes_np, jnp.int32), rows)
        vecs = jax.device_put(
            jnp.asarray(points).astype(jnp.dtype(cfg.vec_dtype)), rows
        )
    else:
        if pad_rows:
            points = np.concatenate([
                points, np.zeros((pad_rows, cfg.d), np.float32)
            ], axis=0)
        step = make_build_step(mesh, cfg)
        codes, vecs = step(
            jnp.asarray(points, jnp.float32),
            jnp.asarray(proj),
            jnp.asarray(b_int),
            jnp.asarray(b_frac),
        )
    return QueryState(
        codes=codes,
        points=vecs,
        proj=jax.device_put(jnp.asarray(proj), rep2),
        b_int=jax.device_put(jnp.asarray(b_int), rep1),
        b_frac=jax.device_put(jnp.asarray(b_frac), rep1),
        width=jax.device_put(jnp.asarray(1.0, jnp.float32), rep0),
        n_valid=jax.device_put(jnp.asarray(n_rows, jnp.int32), rep0),
    )


def seal_segment(
    cfg: IndexConfig,
    gplan: GroupServingPlan,
    vectors: np.ndarray,
    state: QueryState | None = None,
) -> np.ndarray:
    """Hash a delta segment into ``(m, cfg.beta)`` int32 bucket codes.

    Re-hashes the segment's rows with the group's *original* family seeds,
    through the same encoding the group's data codes used: the host f64
    path when the plan ships host codes (bit-exact with a fresh host build
    over the union corpus), otherwise the device f32 path via the state's
    folded projection (``state`` required).  Sealed codes are what
    ``append_to_state`` later splices into the main group state — the
    hashing work of compaction happens here, at seal time.
    """
    vectors = np.ascontiguousarray(np.atleast_2d(vectors), np.float32)
    if gplan.codes is not None:
        return pad_cols(
            gplan.encode_host(vectors), cfg.beta
        ).astype(np.int32)
    if state is None:
        raise ValueError(
            "sealing without plan host codes requires the group's device "
            "state for the f32 encode"
        )
    return np.asarray(encode_queries(state, vectors), np.int32)


def append_to_state(
    state: QueryState,
    codes: np.ndarray,
    vectors: np.ndarray,
    mesh: Mesh | None = None,
) -> QueryState:
    """Splice sealed rows into a group state's reserved capacity.

    Writes ``m`` new rows at ``state.n_valid`` and returns a state with
    ``n_valid`` advanced — codes/vector buffers keep their compiled
    (capacity) shapes, so the compaction that calls this never triggers a
    query-step recompile.  The update is functional (the input state stays
    valid; the transient extra copy of one group is the compaction cost);
    with ``mesh`` the result is re-placed onto the build-time shardings.
    Bit-exact with ``build_group_state`` over the union corpus at the same
    capacity.
    """
    m = len(codes)
    if m != len(vectors):
        raise ValueError(f"codes/vectors row mismatch: {m} vs {len(vectors)}")
    off = int(state.n_valid)
    cap = state.codes.shape[0]
    if off + m > cap:
        raise ValueError(
            f"append of {m} rows at {off} exceeds row capacity {cap} "
            f"(raise ServiceConfig.delta_reserve_rows)"
        )
    codes_d = jnp.asarray(np.ascontiguousarray(codes, np.int32))
    vecs_d = jnp.asarray(
        np.ascontiguousarray(vectors, np.float32)
    ).astype(state.points.dtype)
    new_codes = jax.lax.dynamic_update_slice(state.codes, codes_d, (off, 0))
    new_points = jax.lax.dynamic_update_slice(state.points, vecs_d, (off, 0))
    n_valid = jnp.asarray(off + m, jnp.int32)
    if mesh is not None:
        sh = _state_shardings(mesh)
        new_codes = jax.device_put(new_codes, sh.codes)
        new_points = jax.device_put(new_points, sh.points)
        n_valid = jax.device_put(n_valid, sh.n_valid)
    return dataclasses.replace(
        state, codes=new_codes, points=new_points, n_valid=n_valid
    )
