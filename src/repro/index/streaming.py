"""Streaming-insert primitives: delta memtables, sealed segments, exact scan.

The WLSH group states are compiled at fixed shapes, so fresh inserts
cannot enter them row-by-row.  Instead each table group carries a small
mutable side-structure with an LSM-like lifecycle:

  open      an ``DeltaSegment`` memtable accumulates raw inserted vectors;
            queries scan it *exactly* (full weighted l_p distance, the
            same coordinate-difference form the engine's re-rank epilogue
            uses), so recall on unsealed points is perfect by construction
  sealed    at ``IndexConfig.delta_seal_rows`` rows the memtable freezes
            into a ``SealedSegment``: its rows re-hashed with the group's
            original family seeds (``builder.seal_segment``) into a hashed
            mini-state that still serves by exact scan but is ready to
            splice into the main state
  compacted ``builder.append_to_state`` moves sealed rows into the group
            state's reserved row capacity — after which they are served by
            the compiled index path, bit-exact with a fresh build over the
            union corpus

This module owns the host-side data structures and the exact-scan math;
the serving-layer orchestration (routing, tombstones, the compaction
transaction against the ``StateCache``) lives in ``repro.serving.delta``.
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DeltaSegment", "SealedSegment", "exact_weighted_lp", "scan_topk"]


class DeltaSegment:
    """Append-only open memtable of one group's unsealed inserts."""

    def __init__(self, d: int):
        self.d = int(d)
        self._ids: list[int] = []
        self._vecs: list[np.ndarray] = []
        self._stacked: np.ndarray | None = None  # cached ``vectors`` view

    def __len__(self) -> int:
        """Number of unsealed rows currently buffered."""
        return len(self._ids)

    def append(self, point_id: int, vector: np.ndarray) -> None:
        """Buffer one inserted vector under its assigned global id."""
        vector = np.ascontiguousarray(vector, np.float32).reshape(-1)
        if vector.shape != (self.d,):
            raise ValueError(
                f"insert must be a ({self.d},) vector, got {vector.shape}"
            )
        self._ids.append(int(point_id))
        self._vecs.append(vector)
        self._stacked = None  # invalidate the cached stack

    @property
    def ids(self) -> np.ndarray:
        """(m,) int64 global point ids of the buffered rows."""
        return np.asarray(self._ids, np.int64)

    @property
    def vectors(self) -> np.ndarray:
        """(m, d) float32 buffered rows, in insertion order.

        The stacked array is cached between writes: every query routed to
        a group scans its pending rows, so re-stacking per read would put
        an O(m*d) host copy on the query hot path.  The cache is
        invalidated by ``append``/``drain`` and returned read-only (it is
        shared across reads — callers copy before mutating, which the
        exact-scan path never does).
        """
        if self._stacked is None:
            if self._vecs:
                stacked = np.stack(self._vecs).astype(np.float32)
            else:
                stacked = np.empty((0, self.d), np.float32)
            stacked.flags.writeable = False
            self._stacked = stacked
        return self._stacked

    def drain(self) -> tuple[np.ndarray, np.ndarray]:
        """Freeze and clear the memtable, returning ``(ids, vectors)``."""
        ids, vecs = self.ids, self.vectors
        self._ids, self._vecs = [], []
        self._stacked = None
        return ids, vecs


@dataclasses.dataclass(frozen=True)
class SealedSegment:
    """An immutable hashed mini-state awaiting compaction.

    ``codes`` are the rows re-hashed with the owning group's original
    family seeds at the group's padded table width (``seal_segment``), so
    compaction is a pure splice — no hashing happens on the compaction
    path itself.
    """

    ids: np.ndarray  # (m,) int64 global point ids
    vectors: np.ndarray  # (m, d) float32
    codes: np.ndarray  # (m, beta_padded) int32

    def __len__(self) -> int:
        """Number of rows in the sealed segment."""
        return len(self.ids)


def exact_weighted_lp(
    queries: np.ndarray,
    vectors: np.ndarray,
    q_weights: np.ndarray,
    p: float,
) -> np.ndarray:
    """(Q, m) exact per-query weighted l_p distances, float32.

    Coordinate-difference form — the same epilogue the sharded engine
    re-ranks its top-k survivors with (and the elementwise form of the
    ``kernels/weighted_lp`` Pallas kernel), *not* the norms+matmul
    expansion whose f32 cancellation error swamps small distances.  Delta
    hits therefore rank against indexed hits on equal footing.
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    q_weights = np.atleast_2d(np.asarray(q_weights, np.float32))
    vectors = np.atleast_2d(np.asarray(vectors, np.float32))
    diff = np.abs(
        (queries[:, None, :] - vectors[None, :, :]) * q_weights[:, None, :]
    ).astype(np.float32)
    if abs(p - 2.0) < 1e-9:
        return np.sqrt(np.sum(diff * diff, axis=-1, dtype=np.float32))
    if abs(p - 1.0) < 1e-9:
        return np.sum(diff, axis=-1, dtype=np.float32)
    return (
        np.sum(diff**np.float32(p), axis=-1, dtype=np.float32)
        ** np.float32(1.0 / p)
    )


def scan_topk(
    queries: np.ndarray,
    q_weights: np.ndarray,
    ids: np.ndarray,
    vectors: np.ndarray,
    p: float,
    k: int,
) -> tuple[np.ndarray, np.ndarray]:
    """Exact top-k of the delta rows per query: ``(ids, dists)`` (Q, k).

    Missing slots (fewer than ``k`` delta rows) hold id -1 / distance
    +inf, the same conventions the engine uses, so the batching layer's
    merge treats delta hits and indexed hits uniformly.  Ties sort by
    insertion order.

    Selection runs in O(m) per query via ``np.argpartition`` on a
    composite ``(distance bits, row index)`` key — bit-identical to a
    full stable argsort of the distance matrix (the distances are
    non-negative float32, so their bit patterns order like the values,
    and the packed row index breaks ties by insertion order exactly as
    a stable sort would), without the O(m log m) sort over rows that
    can never reach the top-k.
    """
    queries = np.atleast_2d(np.asarray(queries, np.float32))
    nq = len(queries)
    out_ids = np.full((nq, k), -1, np.int64)
    out_d = np.full((nq, k), np.inf, np.float32)
    m = len(ids)
    if m == 0:
        return out_ids, out_d
    dists = exact_weighted_lp(queries, vectors, q_weights, p)
    take = min(k, m)
    # + 0.0 normalizes any -0.0 so the uint32 bit pattern is monotone
    keys = (dists + np.float32(0.0)).view(np.uint32).astype(np.int64)
    keys = (keys << np.int64(32)) | np.arange(m, dtype=np.int64)[None, :]
    if take < m:
        part = np.argpartition(keys, take - 1, axis=1)[:, :take]
        sel = np.take_along_axis(keys, part, axis=1)
        order = np.take_along_axis(part, np.argsort(sel, axis=1), axis=1)
    else:
        order = np.argsort(keys, axis=1)
    out_ids[:, :take] = np.asarray(ids, np.int64)[order]
    out_d[:, :take] = np.take_along_axis(dists, order, axis=1)
    return out_ids, out_d
