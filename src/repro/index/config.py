"""Configuration for the distributed WLSH index engine."""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Shapes + plan parameters for one table group served on a mesh.

    Production-scale defaults correspond to the paper's regime scaled to a
    TPU pod: ~1B points, SIFT-like d, beta from Eq. 11 at n=2^30.
    """

    n: int = 1 << 30  # points (global)
    d: int = 128  # dimensions
    beta: int = 128  # hash tables in the group (post-relaxation size)
    q_batch: int = 64  # global query batch
    k: int = 10
    c: int = 2
    n_levels: int = 24  # virtual-rehashing levels (0..n_levels)
    p: float = 2.0
    block_n: int = 1 << 15  # points per scan block (per shard); the per-
    # block scoring working set is ~(q_batch x block_n x beta) x 4 bytes
    # (the XLA-fallback eq-count materializes it) — 1 GB at the production
    # config, next to the 2 GB/chip code shard
    budget: int = 4096 + 10  # k + gamma*n (gamma=100/n paper default -> ~k+100;
    # kept configurable because at 1B points a larger false-positive budget
    # is the practical choice)
    vec_dtype: str = "bfloat16"  # stored vectors (verification re-ranks in f32)
    use_pallas: bool | None = None  # None = auto (TPU only)
    analysis_unroll: bool = False  # unroll block/level loops so the dry-run
    # cost analysis counts true work (XLA counts loop bodies once); used by
    # launch/dryrun.py shallow analysis lowerings only

    @property
    def width_placeholder(self) -> float:
        return 1.0
