"""Configuration for the distributed WLSH index engine.

An ``IndexConfig`` fixes every compile-relevant shape of one table group's
query step.  Two groups whose configs compare equal lower to the *same*
compiled step — ``shape_signature()`` is the jit-cache key the group-aware
engine uses (see ``engine.QueryStepCache``).  ``pad_beta`` / ``pad_levels``
quantize per-group sizes onto a small set of buckets so a many-group plan
compiles only a handful of distinct steps; per-query ``beta_q`` and
``levels_q`` inputs mask the padding at run time, keeping results exact.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import numpy as np

__all__ = ["IndexConfig", "pad_beta", "pad_levels"]

# Default table-count buckets: multiples of 32 (the relaxed Eq. 11 betas
# land in the tens-to-hundreds, Table 6) capped by powers of two above 512.
_BETA_STEP = 32
_LEVEL_STEP = 4


def _dtype_itemsize(name: str) -> int:
    """Bytes per element of a dtype name, including the ml_dtypes extras."""
    try:
        return np.dtype(name).itemsize
    except TypeError:
        import ml_dtypes  # noqa: F401  (registers bfloat16 etc. with numpy)

        return np.dtype(name).itemsize


def pad_beta(beta: int, buckets: Sequence[int] | None = None) -> int:
    """Smallest admissible table count >= beta (bounds compile count)."""
    if buckets is not None:
        for b in sorted(buckets):
            if b >= beta:
                return int(b)
        raise ValueError(f"beta={beta} exceeds the largest bucket {max(buckets)}")
    if beta <= 512:
        return _BETA_STEP * math.ceil(beta / _BETA_STEP)
    return 1 << math.ceil(math.log2(beta))


def pad_levels(n_levels: int, step: int = _LEVEL_STEP) -> int:
    """Round the compiled level-loop bound up to a multiple of ``step``."""
    return step * math.ceil(max(n_levels, 1) / step)


@dataclasses.dataclass(frozen=True)
class IndexConfig:
    """Shapes + plan parameters for one table group served on a mesh.

    Production-scale defaults correspond to the paper's regime scaled to a
    TPU pod: ~1B points, SIFT-like d, beta from Eq. 11 at n=2^30.
    """

    n: int = 1 << 30  # row capacity (global); streaming builds may reserve
    # capacity above the live row count — state.n_valid masks the tail
    d: int = 128  # dimensions
    beta: int = 128  # hash tables in the group (post-relaxation size)
    q_batch: int = 64  # global query batch
    k: int = 10
    c: int = 2
    n_levels: int = 24  # virtual-rehashing levels (0..n_levels)
    p: float = 2.0
    block_n: int = 1 << 15  # points per scan block (per shard); the per-
    # block scoring working set is ~(q_batch x block_n x beta) x 4 bytes
    # (the XLA-fallback eq-count materializes it) — 1 GB at the production
    # config, next to the 2 GB/chip code shard
    gamma_n: float = 100.0  # gamma * n (paper default gamma = 100/n), so the
    # candidate budget k + ceil(gamma * n) stays aligned with the host
    # planner's PlanConfig regardless of n
    budget_override: int | None = None  # explicit budget; None = derive.
    # At 1B points a larger false-positive budget than the paper's ~k+100
    # is the practical choice — set it here instead of re-deriving gamma.
    vec_dtype: str = "bfloat16"  # stored vectors (verification re-ranks in f32)
    use_pallas: bool | str | None = None  # kernel path (kernels.platform):
    # None = auto (fused; compiled Pallas where the backend supports it,
    # bit-exact fused XLA composite elsewhere), True = fused Pallas
    # (interpret off-TPU), "interpret" = fused Pallas interpret mode,
    # False = the seed-era unfused stage-by-stage oracle
    delta_seal_rows: int = 1024  # streaming: an open delta memtable seals
    # into a hashed segment at this row count; not compile-relevant (absent
    # from shape_signature), but part of dataclass equality, so a Batcher
    # threads one uniform value through every group config
    analysis_unroll: bool = False  # unroll block/level loops so the dry-run
    # cost analysis counts true work (XLA counts loop bodies once); used by
    # launch/dryrun.py shallow analysis lowerings only
    n_shards: int = 1  # devices the row capacity is sharded across (the
    # serving mesh size; distributed.group_sharding).  Compile-relevant:
    # the per-shard row slice n/n_shards is the lowered scan extent, so
    # two groups served at different shard counts must not share a step
    shard_axis: str = "data"  # mesh axis name carrying the shards (the
    # trailing "model" axis stays size 1 in serving meshes)

    @property
    def gamma(self) -> float:
        return self.gamma_n / self.n

    @property
    def budget(self) -> int:
        """Candidate budget k + ceil(gamma * n) (paper stop condition 2).

        Computed as ``k + ceil(gamma_n)`` directly: ``gamma * n`` is
        ``gamma_n`` by definition, and the direct form keeps the budget
        exact (and independent of row-capacity padding) where the float
        round-trip ``gamma_n / n * n`` could land on either side of the
        integer.  The host planner computes the same quantity.
        """
        if self.budget_override is not None:
            return self.budget_override
        return self.k + int(math.ceil(self.gamma_n))

    @property
    def state_nbytes(self) -> int:
        """Device bytes of one group's resident ``QueryState`` at this config.

        Accounts every array of the padded state — codes ``(n, beta)`` i32,
        vectors ``(n, d)`` in ``vec_dtype``, the folded family
        (``proj (d, beta)`` f32, ``b_int``/``b_frac (beta,)``, ``width ()``)
        plus the ``n_valid ()`` row-count scalar — so the serving
        ``StateCache`` can budget residency before a group is ever built.
        Uses the *padded* beta/n_levels/row-capacity shapes (what is
        actually materialized), not the group's raw table or row count.

        With ``n_shards > 1`` this prices the **per-device slice**: row
        arrays shard over the mesh (``n / n_shards`` rows per device,
        strict — never replicated) while the family stays replicated, so
        paging budgets describe what one device actually holds.
        """
        vec_itemsize = _dtype_itemsize(self.vec_dtype)
        per_point = self.beta * 4 + self.d * vec_itemsize
        family = self.d * self.beta * 4 + self.beta * (4 + 4) + 4
        rows_per_shard = -(-self.n // max(self.n_shards, 1))
        return rows_per_shard * per_point + family + 4  # + n_valid scalar

    def shape_signature(self) -> tuple:
        """Everything that determines the compiled query step.

        Frozen + eq dataclass: the config itself is hashable, but the
        explicit tuple documents (and tests pin) what sharing depends on.
        """
        return (
            self.n, self.d, self.beta, self.q_batch, self.k, self.c,
            self.n_levels, self.p, self.block_n, self.budget,
            self.vec_dtype, self.use_pallas, self.analysis_unroll,
            self.n_shards, self.shard_axis,
        )

    @property
    def width_placeholder(self) -> float:
        return 1.0
