"""Sharded WLSH query engine (the paper's Search, TPU-pod-native).

Decomposition (DESIGN.md Sec. 4): point rows -- codes (n, beta) and vectors
(n, d) -- are sharded over *every* mesh axis ("pod" x "data" x "model"), and
the query batch is replicated.  Each chip scores all Q queries against its
n/chips rows, so the (Q, n) work splits perfectly by rows while per-chip
state stays 1/chips of the index.  At the 1B-point production config the
codes alone are 2 TB: the first-cut layout (rows over ("pod","data") only,
queries over "model") left the model axis holding replicas -- 128 GB/chip,
8x over HBM.  Row-sharding over all axes was perf iteration #1, see
EXPERIMENTS.md Sec. Perf.  The only communication is

  * a psum of per-query level histograms, (Q, L+2) ints -- bytes, and
  * an all-gather of per-shard top-k rows, (Q, k) -- bytes,

both over all axes.  Per shard the engine streams its code/vector slabs
through VMEM-sized blocks in two passes (lax.scan):

  pass 1  codes -> freq_level -> per-level frequent/good histograms
          -> psum -> the paper's stop conditions (k found / budget) -> j*
  pass 2  codes + vectors -> masked distances (L_freq <= j*) -> running
          local top-k -> all-gather -> global top-k

Each scan step of both passes dispatches through ``ops.fused_query_block``
— one launch per block computing level, distance and histogram/mask
together, so the (q_loc, block) intermediates never round-trip through HBM
between stages (Pallas kernel on TPU, a bit-exact fused XLA composite
elsewhere; ``kernels.platform.resolve`` maps ``cfg.use_pallas`` onto the
path).  ``use_pallas=False`` keeps the seed-era stage-by-stage scan as the
parity oracle.

Pass 2 recomputes L_freq instead of materializing the (Q, n_loc) int8
matrix -- at beta/d ~ 4 this costs ~1.3x compute for ~0 bytes of HBM
footprint; the single-pass per-level-candidate variant is evaluated in the
perf log (EXPERIMENTS.md Sec. Perf).

Every query carries its own weight vector, collision threshold mu, radius
base r_min, table count beta_q and level cap levels_q (the WLSH multi-weight
semantics -- queries under *different* weighted distance functions batch
together as long as they hit the same table group).  Query bucket codes are
an *input*: the retrieval service encodes on the host (float64, bit-exact
against the planner's codes) while standalone callers use
``encode_queries``.  Per-query beta_q/levels_q also make shape padding
exact, so groups whose (beta, n_levels) round to the same buckets share one
compiled step via ``QueryStepCache``.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..distributed import group_sharding
from ..distributed.sharding import shard_map_nocheck
from ..kernels import ops, ref
from ..kernels import platform as kplatform
from .config import IndexConfig

__all__ = [
    "QueryState",
    "QueryStepCache",
    "encode_queries",
    "make_query_step",
    "query_input_specs",
    "shardings",
]


@dataclasses.dataclass(frozen=True)
class QueryState:
    """Device-resident table-group state (a pytree).

    ``codes``/``points`` are materialized at the config's row *capacity*
    (``IndexConfig.n``); ``n_valid`` counts the live rows.  Rows at or
    beyond ``n_valid`` are dead weight the query step masks out of both
    histogram passes, which is what lets streaming compaction append rows
    into reserved capacity without changing any compiled shape.  A static
    (non-streaming) build simply has ``n_valid == capacity``.
    """

    codes: jax.Array  # (n, beta) int32, sharded (("pod","data"), None)
    points: jax.Array  # (n, d) vec_dtype, sharded likewise
    proj: jax.Array  # (d, beta) f32, replicated
    b_int: jax.Array  # (beta,) int32, replicated
    b_frac: jax.Array  # (beta,) f32, replicated
    width: jax.Array  # () f32
    n_valid: jax.Array  # () int32, replicated — live rows in [0, n]


jax.tree_util.register_dataclass(
    QueryState,
    data_fields=["codes", "points", "proj", "b_int", "b_frac", "width",
                 "n_valid"],
    meta_fields=[],
)


def _point_axes(mesh: Mesh):
    """Point rows shard over every mesh axis (see module docstring)."""
    return tuple(mesh.axis_names)


def shardings(mesh: Mesh):
    pa = _point_axes(mesh)
    return {
        "state": QueryState(
            codes=NamedSharding(mesh, P(pa, None)),
            points=NamedSharding(mesh, P(pa, None)),
            proj=NamedSharding(mesh, P(None, None)),
            b_int=NamedSharding(mesh, P(None)),
            b_frac=NamedSharding(mesh, P(None)),
            width=NamedSharding(mesh, P()),
            n_valid=NamedSharding(mesh, P()),
        ),
        "queries": NamedSharding(mesh, P(None, None)),
        "q_meta": NamedSharding(mesh, P(None)),
        "out": NamedSharding(mesh, P(None, None)),
    }


# The per-query distance helpers live in kernels.ref so the unfused scan
# below and the fused XLA composite (ops.fused_query_block's reference
# route) trace the *same* functions on the same block shapes — which is
# what makes the two paths bit-exact (f32 gemms are shape-sensitive).
_log_c = ref.log_c
_per_query_l2 = ref.per_query_l2
_per_query_lp = ref.per_query_lp


def _query_shard(
    state: QueryState,
    queries,  # (q_loc, d)
    codes_q,  # (q_loc, beta) int32 precomputed query bucket codes
    q_weight,  # (q_loc, d)
    mu,  # (q_loc,) int32
    r_min,  # (q_loc,) f32
    beta_q,  # (q_loc,) int32 per-member beta_{W_i}
    levels_q,  # (q_loc,) int32 per-member level cap (<= cfg.n_levels)
    cfg: IndexConfig,
    mesh_axes: tuple[str, ...],
    axis_sizes: tuple[int, ...],
):
    c, L, k = cfg.c, cfg.n_levels, cfg.k
    n_loc = state.codes.shape[0]
    block = min(cfg.block_n, n_loc)
    n_blocks = n_loc // block
    q_loc = queries.shape[0]
    qf32 = queries.astype(jnp.float32)
    wf32 = q_weight.astype(jnp.float32)

    codes_blocks = state.codes.reshape(n_blocks, block, cfg.beta)
    point_blocks = state.points.reshape(n_blocks, block, cfg.d)
    # use_pallas resolves to a concrete kernel path per backend (see
    # kernels.platform): fused single-launch block steps by default, the
    # seed-era unfused stage-by-stage scan as the use_pallas=False oracle.
    path = kplatform.resolve(cfg.use_pallas)

    # Global row offsets per block: streaming states reserve row capacity
    # above the live count, and rows >= n_valid must vanish from both
    # passes (their first-frequent level is forced past every stop level).
    shard_off = group_sharding.shard_row_offset(mesh_axes, axis_sizes, n_loc)
    boffs = shard_off + jnp.arange(n_blocks, dtype=jnp.int32) * block
    n_valid = state.n_valid.astype(jnp.int32)

    def _masked_freq_level(cb, boff):
        """(q_loc, block) first-frequent level, dead rows forced to L+1."""
        lf = ops.freq_level(
            cb, codes_q, mu, c=c, n_levels=L, beta_q=beta_q,
            use_pallas=cfg.use_pallas, unroll=cfg.analysis_unroll,
        )
        row_ok = (boff + jnp.arange(block, dtype=jnp.int32)) < n_valid
        return jnp.where(row_ok[None, :], lf, jnp.int32(L + 1))

    # ---- pass 1: level histograms -> stop level ---------------------------
    # Fused and unfused paths bin dead rows differently (excluded vs parked
    # at L+1), but the stop logic below only reads bins 0..L, so stop /
    # n_checked — and therefore ids/dists — are bit-identical either way.
    def pass1(carry, blk):
        hist_f, hist_g = carry
        cb, pb, boff = blk
        if path.fused:
            hf, hg = ops.fused_query_block(
                cb, pb, codes_q, qf32, wf32, mu, r_min, beta_q,
                boff=boff, n_valid=n_valid, c=c, n_levels=L, p=cfg.p,
                use_pallas=path.pallas, interpret=path.interpret,
                unroll=cfg.analysis_unroll,
            )
            return (hist_f + hf, hist_g + hg), None
        lf = _masked_freq_level(cb, boff)  # (q_loc, block)
        if abs(cfg.p - 2.0) < 1e-9:
            dist = _per_query_l2(qf32, wf32, pb.astype(jnp.float32))
        else:
            dist = _per_query_lp(qf32, wf32, pb.astype(jnp.float32), cfg.p)
        jg = jnp.ceil(
            jnp.maximum(_log_c(jnp.maximum(dist, 1e-30), c)
                        - _log_c(c * r_min, c)[:, None], 0.0)
        ).astype(jnp.int32)
        good_lvl = jnp.maximum(lf, jg)
        levels = jnp.arange(L + 2, dtype=jnp.int32)
        hist_f = hist_f + jnp.sum(
            (lf[:, :, None] == levels[None, None, :]).astype(jnp.int32), axis=1
        )
        hist_g = hist_g + jnp.sum(
            (good_lvl[:, :, None] == levels[None, None, :]).astype(jnp.int32),
            axis=1,
        )
        return (hist_f, hist_g), None

    hist0 = jnp.zeros((q_loc, L + 2), jnp.int32)
    (hist_f, hist_g), _ = jax.lax.scan(
        pass1, (hist0, hist0), (codes_blocks, point_blocks, boffs),
        unroll=n_blocks if cfg.analysis_unroll else 1,
    )
    hist_f, hist_g = group_sharding.merge_histograms(hist_f, hist_g,
                                                     mesh_axes)
    nf_cum = jnp.cumsum(hist_f[:, : L + 1], axis=1)
    ng_cum = jnp.cumsum(hist_g[:, : L + 1], axis=1)
    # Stop conditions evaluated only up to each query's own level cap: the
    # compiled bound L may be padded above the member's n_levels (bucketed
    # shape sharing), and a query that exhausts its levels stops *at* them
    # exactly like the host loop.
    levels = jnp.arange(L + 1, dtype=jnp.int32)
    cond = ((ng_cum >= k) | (nf_cum >= cfg.budget)) & (
        levels[None, :] <= levels_q[:, None]
    )
    stop = jnp.where(
        jnp.any(cond, axis=1), jnp.argmax(cond, axis=1), levels_q
    ).astype(jnp.int32)  # (q_loc,)

    # ---- pass 2: masked distances -> running local top-k ------------------
    def pass2(carry, blk):
        vals, idx = carry
        cb, pb, boff = blk
        if path.fused:
            scores = ops.fused_query_block(
                cb, pb, codes_q, qf32, wf32, mu, r_min, beta_q,
                boff=boff, n_valid=n_valid, c=c, n_levels=L, p=cfg.p,
                stop=stop, use_pallas=path.pallas, interpret=path.interpret,
                unroll=cfg.analysis_unroll,
            )
        else:
            lf = _masked_freq_level(cb, boff)
            if abs(cfg.p - 2.0) < 1e-9:
                dist = _per_query_l2(qf32, wf32, pb.astype(jnp.float32))
            else:
                dist = _per_query_lp(qf32, wf32, pb.astype(jnp.float32),
                                     cfg.p)
            scores = jnp.where(lf <= stop[:, None], dist, jnp.inf)
        bvals, bidx = jax.lax.top_k(-scores, k)
        bidx = bidx + boff
        vals = jnp.concatenate([vals, -bvals], axis=1)
        idx = jnp.concatenate([idx, bidx], axis=1)
        mvals, mpos = jax.lax.top_k(-vals, k)
        return (-mvals, jnp.take_along_axis(idx, mpos, axis=1)), None

    init = (
        jnp.full((q_loc, k), jnp.inf, jnp.float32),
        jnp.full((q_loc, k), -1, jnp.int32),
    )
    (vals, idx), _ = jax.lax.scan(
        pass2, init, (codes_blocks, point_blocks, boffs),
        unroll=n_blocks if cfg.analysis_unroll else 1,
    )

    # ---- exact re-rank of the k local winners ------------------------------
    # The p=2 scan scores with the norms+matmul expansion (MXU); its f32
    # cancellation error is ~|x||ulp| — swamping genuinely small distances.
    # Recompute the survivors' distances from the coordinate differences
    # ((q_loc, k, d) work, exact in f32) and re-sort.
    local_rows = jnp.clip(idx - shard_off, 0, n_loc - 1)
    cand = state.points[local_rows].astype(jnp.float32)  # (q_loc, k, d)
    diff = jnp.abs((qf32[:, None, :] - cand) * wf32[:, None, :])
    if abs(cfg.p - 2.0) < 1e-9:
        exact = jnp.sqrt(jnp.sum(diff * diff, axis=-1))
    elif abs(cfg.p - 1.0) < 1e-9:
        exact = jnp.sum(diff, axis=-1)
    else:
        exact = jnp.sum(diff**cfg.p, axis=-1) ** (1.0 / cfg.p)
    vals = jnp.where(jnp.isfinite(vals), exact, vals)
    rvals, rpos = jax.lax.top_k(-vals, k)
    vals = -rvals
    idx = jnp.take_along_axis(idx, rpos, axis=1)

    # ---- global top-k merge ------------------------------------------------
    fvals, fidx = group_sharding.merge_shard_topk(vals, idx, mesh_axes, k)
    n_checked = jnp.minimum(
        jnp.take_along_axis(nf_cum, stop[:, None], axis=1)[:, 0],
        jnp.int32(cfg.budget),
    )
    return fvals, fidx, stop, n_checked


def encode_queries(state: QueryState, queries) -> jax.Array:
    """(Q, beta) int32 query bucket codes via the device (f32) path.

    state.proj is the *folded* projection (center weight and bucket width
    folded in at build time), so queries hash with unit weight/width.  The
    retrieval service instead host-encodes in float64 for bit-exactness
    against the planner; this is the standalone/engine-only path.
    """
    return ops.hash_encode(
        jnp.asarray(queries, jnp.float32),
        jnp.ones((state.proj.shape[0],), jnp.float32),
        state.proj,
        state.b_int,
        state.b_frac,
        1.0,
        use_pallas=False,
    )


def make_query_step(mesh: Mesh, cfg: IndexConfig):
    """jit'd sharded query step:
    (state, queries, q_codes, q_weight, mu, r_min, beta_q, levels_q) ->
    (dists (Q,k), ids (Q,k), stop (Q,), n_checked (Q,))."""
    pa = _point_axes(mesh)
    sh = shardings(mesh)
    # Strict row placement (distributed.group_sharding): a capacity that
    # does not divide the mesh raises here instead of silently replicating
    # the state onto every device.
    state_sh = group_sharding.state_shardings(mesh, cfg)

    fn = functools.partial(
        _query_shard, cfg=cfg, mesh_axes=pa,
        axis_sizes=tuple(mesh.shape[a] for a in pa),
    )
    smapped = shard_map_nocheck(
        fn,
        mesh=mesh,
        in_specs=(
            QueryState(
                codes=P(pa, None),
                points=P(pa, None),
                proj=P(None, None),
                b_int=P(None),
                b_frac=P(None),
                width=P(),
                n_valid=P(),
            ),
            P(None, None),
            P(None, None),
            P(None, None),
            P(None),
            P(None),
            P(None),
            P(None),
        ),
        out_specs=(P(None, None), P(None, None), P(None), P(None)),
    )
    return jax.jit(
        smapped,
        in_shardings=(
            state_sh,
            sh["queries"],
            sh["queries"],
            sh["queries"],
            sh["q_meta"],
            sh["q_meta"],
            sh["q_meta"],
            sh["q_meta"],
        ),
        out_shardings=(sh["out"], sh["out"], sh["q_meta"], sh["q_meta"]),
    )


class QueryStepCache:
    """Compiled-step reuse across table groups.

    Keyed by (mesh, cfg): IndexConfig is a frozen eq dataclass, so two
    groups whose shapes quantize to the same buckets (config.pad_beta /
    pad_levels) produce equal configs and share one lowered+compiled step.
    ``n_compiled`` counts actual make_query_step calls — the serving tests
    pin it to the number of distinct shape signatures.  ``on_compile``
    (optional, set by the observability layer) is called with the config
    on every cache miss, attributing compiles to shape signatures.
    """

    def __init__(self):
        self._steps: dict = {}
        self.n_compiled = 0
        self.on_compile = None  # hook: on_compile(cfg) per actual compile

    def get(self, mesh: Mesh, cfg: IndexConfig):
        key = (mesh, cfg)
        step = self._steps.get(key)
        if step is None:
            step = make_query_step(mesh, cfg)
            self._steps[key] = step
            self.n_compiled += 1
            if self.on_compile is not None:
                self.on_compile(cfg)
        return step

    def __len__(self) -> int:
        return len(self._steps)


def query_input_specs(cfg: IndexConfig):
    """ShapeDtypeStructs for the dry-run (no allocation)."""
    vec = jnp.dtype(cfg.vec_dtype)
    state = QueryState(
        codes=jax.ShapeDtypeStruct((cfg.n, cfg.beta), jnp.int32),
        points=jax.ShapeDtypeStruct((cfg.n, cfg.d), vec),
        proj=jax.ShapeDtypeStruct((cfg.d, cfg.beta), jnp.float32),
        b_int=jax.ShapeDtypeStruct((cfg.beta,), jnp.int32),
        b_frac=jax.ShapeDtypeStruct((cfg.beta,), jnp.float32),
        width=jax.ShapeDtypeStruct((), jnp.float32),
        n_valid=jax.ShapeDtypeStruct((), jnp.int32),
    )
    return dict(
        state=state,
        queries=jax.ShapeDtypeStruct((cfg.q_batch, cfg.d), jnp.float32),
        q_codes=jax.ShapeDtypeStruct((cfg.q_batch, cfg.beta), jnp.int32),
        q_weight=jax.ShapeDtypeStruct((cfg.q_batch, cfg.d), jnp.float32),
        mu=jax.ShapeDtypeStruct((cfg.q_batch,), jnp.int32),
        r_min=jax.ShapeDtypeStruct((cfg.q_batch,), jnp.float32),
        beta_q=jax.ShapeDtypeStruct((cfg.q_batch,), jnp.int32),
        levels_q=jax.ShapeDtypeStruct((cfg.q_batch,), jnp.int32),
    )
