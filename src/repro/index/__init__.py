"""Distributed WLSH index runtime: sharded build + group-aware query engine."""

from .builder import (
    build_group_state,
    build_state,
    fold_center_weight,
    make_build_step,
)
from .config import IndexConfig, pad_beta, pad_levels
from .engine import (
    QueryState,
    QueryStepCache,
    encode_queries,
    make_query_step,
    query_input_specs,
)

__all__ = [
    "IndexConfig",
    "QueryState",
    "QueryStepCache",
    "build_group_state",
    "build_state",
    "encode_queries",
    "fold_center_weight",
    "make_build_step",
    "make_query_step",
    "pad_beta",
    "pad_levels",
    "query_input_specs",
]
