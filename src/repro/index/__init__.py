"""Distributed WLSH index runtime: sharded build + query engine."""

from .builder import build_state, fold_center_weight, make_build_step
from .config import IndexConfig
from .engine import QueryState, make_query_step, query_input_specs

__all__ = [
    "IndexConfig",
    "QueryState",
    "build_state",
    "fold_center_weight",
    "make_build_step",
    "make_query_step",
    "query_input_specs",
]
