"""Distributed WLSH index runtime: sharded build + group-aware query engine,
plus the streaming delta-segment primitives (append/seal/compact)."""

from .builder import (
    append_to_state,
    build_group_state,
    build_state,
    fold_center_weight,
    make_build_step,
    seal_segment,
)
from .config import IndexConfig, pad_beta, pad_levels
from .engine import (
    QueryState,
    QueryStepCache,
    encode_queries,
    make_query_step,
    query_input_specs,
)
from .streaming import DeltaSegment, SealedSegment, exact_weighted_lp, scan_topk

__all__ = [
    "DeltaSegment",
    "IndexConfig",
    "QueryState",
    "QueryStepCache",
    "SealedSegment",
    "append_to_state",
    "build_group_state",
    "build_state",
    "encode_queries",
    "exact_weighted_lp",
    "fold_center_weight",
    "make_build_step",
    "make_query_step",
    "pad_beta",
    "pad_levels",
    "query_input_specs",
    "scan_topk",
    "seal_segment",
]
