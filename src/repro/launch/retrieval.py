"""Retrieval-service launcher: plan -> build -> serve -> report.

End-to-end driver for the multi-group serving stack on synthetic data
(paper Sec. 5.1 generators):

    PYTHONPATH=src python -m repro.launch.retrieval \
        --n 4096 --d 24 --n-weights 24 --n-queries 96 --k 5 --check

Steps:
  1. plan   — WLSHIndex partitions the weight set into table groups
              (Algorithm 1) and exports a serializable ServingPlan
  2. build  — RetrievalService materializes per-group device state; groups
              whose padded shapes coincide share one compiled query step.
              ``--max-resident-groups`` / ``--device-budget`` page the
              states through a budgeted LRU cache (host offload/restore)
              instead of keeping every group resident;
              ``--shards`` shards every state's rows across that many
              devices (per-shard scan passes + exact collective merge,
              bit-identical answers at any shard count)
  3. serve  — sync (default): the mixed (query, weight_id) stream arrives
              in one call and is routed, coalesced, padded and answered in
              submission order (Algorithm 2).
              ``--async``: the same stream is replayed open-loop — each
              request submitted alone at a Poisson arrival time
              (``--arrival-rate`` q/s of virtual traffic) into the
              deadline-aware AsyncRetrievalService, which launches a batch
              when it fills or when the oldest request has waited
              ``--max-delay-ms``.  Both frontends are bit-exact on
              identical traffic.
              ``--driver`` steps the replay through the real-time
              ServiceDriver (deadline-miss accounting, cost-aware
              eviction, idle-tick background compaction); ``--prefetch``
              additionally issues predictive state prefetches from the
              pending-deadline schedule, so restores overlap launches
              instead of blocking them.  Answers stay bit-exact either
              way.
              ``--insert-rate`` turns either mode into a mixed read/write
              replay: that fraction of the op stream becomes streaming
              inserts (delta memtable -> sealed segments at
              ``--delta-seal-rows`` -> compaction into reserved state
              capacity), with recall on fresh inserts checked pre- and
              post-compaction.
  4. report — per-group occupancy / stop-level / n_checked stats, compile
              sharing, throughput (plus queue-wait percentiles and launch
              causes in async mode, delta/compaction counters in mixed
              mode); ``--check`` cross-validates every answer against the
              host oracle WLSHIndex.search_dense.
              ``--trace-out`` / ``--metrics-out`` / ``--profile-dir``
              switch the observability layer on (bit-exact either way):
              per-query trace spans to JSONL, the unified metrics
              registry as Prometheus text or JSON, and per-signature
              compile/dispatch attribution (plus a jax.profiler capture
              when available).
              ``--recall-sample-rate`` shadow-samples live queries for
              exact-oracle recall estimation; ``--health`` prints the
              per-rung observed-recall and alert report and
              ``--alerts-out`` exports the SLO burn-rate alert events
              fired on driver ticks

``--plan-out`` persists the ServingPlan npz so a separate serving job can
start without re-planning.
"""

from __future__ import annotations

import argparse
import re
import time

import numpy as np

from ..core.datagen import make_dataset, make_weight_set
from ..core.params import PlanConfig
from ..core.wlsh import WLSHIndex
from ..kernels import platform as kernel_platform
from ..obs import HealthMonitor, default_rules
from ..serving.async_service import (
    AsyncRetrievalService,
    ManualClock,
    replay_open_loop,
)
from ..serving.qos import DegradeStep, QosClass, QosScheduler
from ..serving.retrieval import RetrievalService, ServiceConfig
from ..serving.scheduler import (
    DeadlinePrefetch,
    ServiceDriver,
    replay_with_driver,
)

__all__ = ["parse_bytes", "parse_ladder", "parse_tenants", "run", "main"]

_UNITS = {"": 1, "B": 1, "KB": 1 << 10, "MB": 1 << 20, "GB": 1 << 30,
          "TB": 1 << 40,
          # IEC suffixes are the same binary multiples this parser always
          # meant ("512MiB" == "512MB" == 512 * 2**20)
          "KIB": 1 << 10, "MIB": 1 << 20, "GIB": 1 << 30, "TIB": 1 << 40}


def parse_bytes(text: str) -> int:
    """Parse a byte budget like ``"512MB"``, ``"2GiB"`` or a plain int.

    Suffixes are case-insensitive (``512mb``, ``2gb``) and both the
    conventional (KB/MB/GB/TB) and IEC (KiB/MiB/GiB/TiB) spellings name
    the binary multiples.  Zero or negative budgets are rejected with an
    explicit message (a budget under one byte cannot hold any state).
    """
    m = re.fullmatch(r"\s*(-?\d+(?:\.\d+)?)\s*([A-Za-z]*)\s*", text)
    if m is None:
        raise argparse.ArgumentTypeError(
            f"can't parse byte size {text!r} (use e.g. 1073741824, 512MB, "
            f"512MiB, 2gb)"
        )
    unit = m.group(2).upper()
    if unit not in _UNITS:
        raise argparse.ArgumentTypeError(
            f"unknown byte-size unit {m.group(2)!r} in {text!r} (use "
            f"B, KB/MB/GB/TB or KiB/MiB/GiB/TiB, any case)"
        )
    value = float(m.group(1))
    if value <= 0:
        raise argparse.ArgumentTypeError(
            f"byte budget must be positive, got {text!r}"
        )
    if unit == "" and "." in m.group(1):  # "1.5" meaning 1.5GB, probably
        raise argparse.ArgumentTypeError(
            f"fractional byte size {text!r} has no unit — missing a "
            f"KB/MB/GB suffix?"
        )
    nbytes = int(value * _UNITS[unit])
    if nbytes < 1:  # "0.0001KB", ...
        raise argparse.ArgumentTypeError(
            f"byte size {text!r} is under 1 byte"
        )
    return nbytes


def parse_tenants(text: str) -> list[QosClass]:
    """Parse a ``--tenants`` spec into ``QosClass``es.

    Spec: ``;``-separated tenants, each ``name:key=val,key=val,...``
    with keys ``weight``, ``rate``, ``burst``, ``slo_ms`` (floats) and
    ``degradable`` (bare flag or ``=true``/``=false``), e.g.::

        gold:weight=4,slo_ms=20;bronze:slo_ms=100,degradable
    """
    classes: list[QosClass] = []
    for part in filter(None, (s.strip() for s in text.split(";"))):
        name, _, body = part.partition(":")
        kwargs: dict = {}
        for item in filter(None, (s.strip() for s in body.split(","))):
            key, eq, val = item.partition("=")
            key = key.strip()
            if key == "degradable":
                kwargs[key] = (not eq) or val.strip().lower() in (
                    "1", "true", "yes"
                )
            elif key in ("weight", "rate", "burst", "slo_ms"):
                kwargs[key] = float(val)
            else:
                raise argparse.ArgumentTypeError(
                    f"unknown tenant key {key!r} in {part!r} (use weight, "
                    f"rate, burst, slo_ms, degradable)"
                )
        classes.append(QosClass(name.strip(), **kwargs))
    if not classes:
        raise argparse.ArgumentTypeError(f"empty --tenants spec {text!r}")
    return classes


def parse_ladder(text: str) -> tuple[DegradeStep, ...]:
    """Parse a ``--degrade-ladder`` spec into ``DegradeStep``s.

    Spec: ``,``-separated rungs, each ``c:k`` or ``c:k:cost``, strictest
    first, e.g. ``4:3:0.5,5:2:0.25``.
    """
    steps = []
    for part in filter(None, (s.strip() for s in text.split(","))):
        bits = part.split(":")
        if len(bits) not in (2, 3):
            raise argparse.ArgumentTypeError(
                f"can't parse ladder rung {part!r} (use c:k or c:k:cost)"
            )
        steps.append(DegradeStep(
            c=int(bits[0]), k=int(bits[1]),
            cost=float(bits[2]) if len(bits) == 3 else 1.0,
        ))
    if not steps:
        raise argparse.ArgumentTypeError(f"empty --degrade-ladder {text!r}")
    return tuple(steps)


def _make_qos(args, ladder) -> QosScheduler:
    """A QosScheduler over the CLI tenant classes and ladder."""
    return QosScheduler(
        classes=args.tenants,
        ladder=ladder,
        capacity_per_tick=args.qos_capacity,
    )


def _print_qos_report(qos: QosScheduler) -> None:
    """Per-tenant QoS report: admission, SLO misses, degradation."""
    s = qos.summary()
    print(f"qos: {s['n_degrade_steps']} degrade / "
          f"{s['n_restore_steps']} restore ladder steps")
    for name, t in sorted(s["tenants"].items()):
        miss = (f"{t['slo_miss_rate']:.2f}" if t["n_resolved"] else "n/a")
        print(f"  tenant {name}: {t['n_admitted']} admitted "
              f"({t['n_rate_limited']} rate-limited), slo-miss {miss}, "
              f"mean wait {1e3 * t['mean_wait_s']:.2f} ms, "
              f"{t['n_degraded']} degraded answers (rung {t['rung']})")


def _make_driver(args, asvc) -> ServiceDriver | None:
    """A ServiceDriver over ``asvc`` per the CLI flags (None = undriven).

    ``--alerts-out`` / ``--health`` attach a ``HealthMonitor`` with the
    stock SLO rule set; the driver evaluates it once per tick.
    """
    if not args.driver:
        return None
    health = None
    if args.alerts_out or args.health:
        health = HealthMonitor(asvc.batcher.metrics, default_rules())
    return ServiceDriver(
        asvc,
        prefetch=DeadlinePrefetch() if args.prefetch else None,
        health=health,
    )


def _print_driver_report(driver: ServiceDriver) -> None:
    """One-line scheduler report: ticks, launches, misses, prefetches."""
    d = driver.stats
    miss = (f"{d.deadline_miss_rate:.2f}"
            if d.n_deadlines_due else "n/a")
    print(f"driver: {d.n_ticks} ticks -> {d.n_launches} launches, "
          f"deadline-miss rate {miss} "
          f"({d.n_deadline_misses}/{d.n_deadlines_due}), "
          f"{d.n_prefetches_issued} prefetches issued, "
          f"{d.n_idle_compactions} idle compactions")
    # the registry-diff heartbeat a live deployment would log per tick
    print(driver.tick_summary())


def _finish_obs(args, svc) -> dict | None:
    """Stop profiling and export the observability artifacts.

    Runs after the serve phase: stops any in-flight ``jax.profiler``
    trace, exports trace spans (``--trace-out``, JSONL), the metrics
    registry (``--metrics-out``: ``.json`` = JSON snapshot, anything
    else = Prometheus text exposition) and prints the per-signature
    compile/dispatch attribution.  Returns the obs report dict (None
    with observability off).
    """
    if not svc.cfg.obs:
        return None
    b = svc.batcher
    out: dict = {}
    if b.profiler is not None:
        b.profiler.stop_trace()
    if b.tracer is not None:
        out["n_spans_started"] = b.tracer.n_started
        out["n_spans_finished"] = b.tracer.n_finished
        if args.trace_out:
            n = b.tracer.export_jsonl(args.trace_out)
            print(f"obs: {n} trace spans -> {args.trace_out} "
                  f"({b.tracer.n_started} started / "
                  f"{b.tracer.n_finished} finished)")
    if args.metrics_out:
        text = (b.metrics.to_json()
                if args.metrics_out.endswith(".json")
                else b.metrics.to_text())
        with open(args.metrics_out, "w") as fh:
            fh.write(text)
        print(f"obs: metrics -> {args.metrics_out}")
    if b.profiler is not None:
        prof = b.profiler.summary()
        out["profile"] = prof
        print(f"obs: {prof['n_compiles']} step compiles attributed; "
              f"dispatch by shape signature:")
        for sig, row in prof["dispatch"].items():
            print(f"  {sig}: {row['count']} launches, "
                  f"mean {1e3 * row['mean_s']:.2f} ms")
    return out


def _finish_health(args, svc, driver=None) -> dict | None:
    """Drain the shadow queue and report quality telemetry + alerts.

    Runs after the serve phase: finishes any queued shadow-exact recall
    jobs (off-path work a driver drains on idle ticks; the remainder is
    executed here), prints the ``--health`` report, exports the alert
    event log (``--alerts-out``, JSONL) and returns the health report
    dict (None when neither recall sampling nor alerting is on).
    """
    est = svc.batcher.recall
    health = driver.health if driver is not None else None
    if est is None and health is None:
        return None
    out: dict = {}
    if est is not None:
        est.drain()
        s = est.summary()
        out["recall"] = s
        if args.health:
            print(f"health: recall sample rate {s['sample_rate']:.2f} "
                  f"-> {s['n_sampled']} sampled, {s['n_executed']} "
                  f"shadow-checked, {s['n_dropped']} dropped")
            for rung in sorted(s["observed"], key=int):
                obs_r = s["observed"][rung]
                bound = s["bound"][rung]
                print(f"  rung {rung}: observed recall {obs_r:.3f} "
                      f"(bound {bound:.3f}, "
                      f"margin {obs_r - bound:+.3f})")
    if health is not None:
        hs = health.summary()
        out["alerts"] = hs
        if args.health:
            n_fired = sum(r["fired"] for r in hs["rules"].values())
            n_cleared = sum(r["cleared"] for r in hs["rules"].values())
            firing = ",".join(hs["firing"]) or "none"
            print(f"health: alerts over {hs['tick']} ticks: {n_fired} "
                  f"fired / {n_cleared} cleared; firing now: {firing}")
        if args.alerts_out:
            n = health.export_jsonl(args.alerts_out)
            print(f"obs: {n} alert events -> {args.alerts_out}")
    return out


def _print_cache_report(cache: dict) -> None:
    """State-cache report: residency, utilization, paging + prefetch work."""
    util = (f", budget {cache['budget_utilization']:.0%} used"
            if cache["device_budget_bytes"] else "")
    print(f"state cache: {cache['n_resident']}/{cache['n_groups']} "
          f"resident ({cache['resident_bytes'] / 2**20:.1f} MiB{util}), "
          f"hit rate {cache['hit_rate']:.2f}, "
          f"{cache['n_evictions']} evictions, "
          f"{cache['n_restores']} restores, "
          f"{cache['n_builds']} rebuilds, "
          f"{cache['n_prefetches']} prefetches "
          f"({cache['n_restore_overlapped']} overlapped restores, "
          f"{cache['n_prefetch_wasted']} wasted)")


def run(args) -> dict:
    rng = np.random.default_rng(args.seed)

    # ---- plan ---------------------------------------------------------------
    t0 = time.time()
    data = make_dataset(n=args.n, d=args.d, value_range=args.value_range,
                        seed=args.seed)
    weights = make_weight_set(size=args.n_weights, d=args.d,
                              n_subset=args.n_subset,
                              n_subrange=args.n_subrange, seed=args.seed + 1)
    pcfg = PlanConfig(p=args.p, c=args.c, n=args.n, gamma_n=args.gamma_n)
    host = WLSHIndex(data, weights, pcfg, tau=args.tau, v=args.v,
                     v_prime=args.v, value_range=args.value_range,
                     seed=args.seed + 2)
    plan = host.export_serving_plan()
    t_plan = time.time() - t0
    print(f"plan: |S|={args.n_weights} -> {plan.n_groups} groups, "
          f"{plan.beta_total} tables "
          f"(betas {[g.beta_group for g in plan.groups]}) in {t_plan:.1f}s")
    if args.plan_out:
        plan.save_npz(args.plan_out)
        print(f"plan saved to {args.plan_out}")

    # ---- build --------------------------------------------------------------
    t0 = time.time()
    reserve = args.delta_reserve_rows
    if reserve is None:  # headroom for every op turning out to be an insert
        reserve = args.n_queries if args.insert_rate > 0 else 0
    ladder = args.degrade_ladder if args.qos else ()
    obs = bool(args.trace_out or args.metrics_out or args.profile_dir
               or args.recall_sample_rate > 0 or args.health
               or args.alerts_out)
    scfg = ServiceConfig(k=args.k, q_batch=args.q_batch,
                         max_delay_ms=args.max_delay_ms,
                         max_resident_groups=args.max_resident_groups,
                         device_budget_bytes=args.device_budget,
                         delta_seal_rows=args.delta_seal_rows,
                         delta_reserve_rows=reserve,
                         use_pallas=args.use_pallas,
                         n_shards=args.shards,
                         degrade_ladder=ladder,
                         obs=obs,
                         recall_sample_rate=args.recall_sample_rate)
    svc = RetrievalService(plan, data, cfg=scfg)
    if obs and args.profile_dir:
        svc.batcher.profiler.profile_dir = args.profile_dir
        svc.batcher.profiler.start_trace()
    svc.warmup()
    t_build = time.time() - t0
    cache0 = svc.cache_summary()
    print(f"build: {plan.n_groups} group states "
          f"({cache0['n_resident']} resident, "
          f"{cache0['resident_bytes'] / 2**20:.1f} MiB on device), "
          f"{svc.step_cache.n_compiled} compiled steps "
          f"(shape sharing {plan.n_groups}/{svc.step_cache.n_compiled}) "
          f"in {t_build:.1f}s")
    if args.shards > 1:
        n_loc = svc.batcher.row_capacity() // svc.mesh.size
        print(f"sharding: {svc.mesh.size} shards over mesh "
              f"{dict(svc.mesh.shape)} ({n_loc} rows/shard, "
              f"collective-merged top-k)")
    print(f"kernels: {kernel_platform.describe(scfg.use_pallas)} "
          f"(--use-pallas {args.use_pallas})")
    svc.reset_stats()  # serve-phase cache counters exclude warmup churn

    # ---- serve --------------------------------------------------------------
    wids = rng.integers(0, args.n_weights, size=args.n_queries)
    qpts = data[rng.choice(args.n, args.n_queries, replace=False)].astype(
        np.float32
    )
    qpts = qpts + rng.normal(0, args.q_noise, qpts.shape).astype(np.float32)
    async_report = None
    driver = None
    if args.insert_rate > 0:
        return _serve_mixed(args, svc, plan, rng, qpts, wids,
                            t_plan=t_plan, t_build=t_build)
    if args.use_async:
        arrivals = np.cumsum(
            rng.exponential(1.0 / args.arrival_rate, args.n_queries)
        )
        qos = _make_qos(args, ladder) if args.qos else None
        tenants = None
        if qos is not None:
            names = [c.name for c in args.tenants]
            tenants = [str(t) for t in rng.choice(names, args.n_queries)]
        asvc = AsyncRetrievalService(svc, clock=ManualClock(), qos=qos)
        driver = _make_driver(args, asvc)
        t0 = time.time()
        if driver is not None:
            res, waits = replay_with_driver(driver, qpts, wids, arrivals,
                                            tenants=tenants)
        else:
            res, waits = replay_open_loop(asvc, qpts, wids, arrivals,
                                          tenants=tenants)
        t_serve = time.time() - t0
        wait_ms = 1e3 * waits if len(waits) else np.array([np.nan])
        async_report = {
            "arrival_rate": args.arrival_rate,
            "max_delay_ms": args.max_delay_ms,
            "mean_wait_ms": float(wait_ms.mean()),
            "p95_wait_ms": float(np.percentile(wait_ms, 95)),
            "n_launched_full": asvc.n_launched_full,
            "n_launched_deadline": asvc.n_launched_deadline,
            "driver": driver.stats.summary() if driver is not None else None,
            "qos": qos.summary() if qos is not None else None,
        }
        print(f"serve[async]: {args.n_queries} queries at "
              f"{args.arrival_rate:.0f} q/s open-loop, deadline "
              f"{args.max_delay_ms} ms -> {len(np.unique(res.group_ids))} "
              f"active groups, {asvc.n_launched_full} full / "
              f"{asvc.n_launched_deadline} deadline launches, wait "
              f"mean {wait_ms.mean():.2f} ms / p95 "
              f"{np.percentile(wait_ms, 95):.2f} ms "
              f"({args.n_queries / t_serve:.1f} q/s compute)")
        if driver is not None:
            _print_driver_report(driver)
        if qos is not None:
            _print_qos_report(qos)
    else:
        t0 = time.time()
        res = svc.query(qpts, wids)
        t_serve = time.time() - t0
        print(f"serve: {args.n_queries} queries over "
              f"{len(np.unique(res.group_ids))} active groups in "
              f"{t_serve:.2f}s ({args.n_queries / t_serve:.1f} q/s)")

    # ---- report -------------------------------------------------------------
    print("per-group serving stats:")
    for gi, s in sorted(svc.stats_summary().items()):
        print(f"  group {gi}: {s['n_queries']} queries / {s['n_batches']} "
              f"batches, occupancy {s['occupancy']:.2f}, "
              f"mean stop level {s['mean_stop_level']:.1f}, "
              f"mean checked {s['mean_n_checked']:.0f}")
    cache = svc.cache_summary()
    if (args.max_resident_groups is not None
            or args.device_budget is not None or args.driver):
        _print_cache_report(cache)
    obs_report = _finish_obs(args, svc)
    health_report = _finish_health(args, svc, driver)

    n_bad = 0
    if args.check:
        for qi in range(args.n_queries):
            want = host.search_dense(qpts[qi], weight_id=int(wids[qi]),
                                     k=args.k)
            ok = np.array_equal(res.ids[qi], want.ids.astype(np.int32))
            ok &= int(res.stop_levels[qi]) == want.stats.stop_level
            n_bad += not ok
        print(f"check vs search_dense: {args.n_queries - n_bad}"
              f"/{args.n_queries} exact")
        assert n_bad == 0, f"{n_bad} queries disagree with the host oracle"

    return {
        "n_groups": plan.n_groups,
        "beta_total": plan.beta_total,
        "n_compiled_steps": svc.step_cache.n_compiled,
        "t_plan": t_plan,
        "t_build": t_build,
        "t_serve": t_serve,
        "qps": args.n_queries / t_serve,
        "stats": svc.stats_summary(),
        "cache": cache,
        "n_check_failures": n_bad,
        "async": async_report,
        "obs": obs_report,
        "health": health_report,
    }


def _serve_mixed(args, svc, plan, rng, qpts, wids, t_plan, t_build):
    """Mixed read/write replay: a fraction of the op stream is inserts.

    Each op is an insert with probability ``--insert-rate``; inserted
    vectors are fresh (offset past the corpus range) so recall on them is
    checkable.  Sync mode serves op by op; ``--async`` replays the same
    schedule open-loop at ``--arrival-rate`` with writes applied at their
    arrival instants.  ``--check`` verifies pre-compaction recall (every
    insert answers its own self-query via the exact delta scan), then
    compacts and verifies the compiled path returns the same ids — with
    the compiled-step count pinned across the whole run.
    """
    n_ops = args.n_queries
    is_insert = rng.random(n_ops) < args.insert_rate
    ins_vecs = qpts + (
        args.value_range + 7.0 * np.arange(n_ops)[:, None]
    ).astype(np.float32)
    inserted = []  # (pid, vector, weight_id)
    n_compiled0 = svc.step_cache.n_compiled
    driver = None
    t0 = time.time()
    if args.use_async:
        asvc = AsyncRetrievalService(svc, clock=ManualClock())
        driver = _make_driver(args, asvc)
        tick = asvc.poll if driver is None else driver.step
        arrivals = np.cumsum(
            rng.exponential(1.0 / args.arrival_rate, n_ops)
        )
        for i in range(n_ops):
            while True:  # fire deadlines expiring before this arrival
                nd = asvc.next_deadline()
                if nd is None or nd > arrivals[i]:
                    break
                asvc.clock.advance_to(nd)
                tick()
            asvc.clock.advance_to(arrivals[i])
            if driver is not None:
                # arrival tick: gives prefetch its lead time (never
                # launches — due deadlines were fired above), exactly
                # like replay_with_driver
                driver.step()
            if is_insert[i]:
                pid = asvc.insert(ins_vecs[i], int(wids[i]))
                inserted.append((pid, ins_vecs[i], int(wids[i])))
            else:
                asvc.submit(qpts[i], wids[i])
        while asvc.pending_count:
            asvc.clock.advance_to(asvc.next_deadline())
            tick()
        if driver is not None:
            _print_driver_report(driver)
    else:
        for i in range(n_ops):
            if is_insert[i]:
                pid = svc.insert(ins_vecs[i], int(wids[i]))
                inserted.append((pid, ins_vecs[i], int(wids[i])))
            else:
                svc.query(qpts[i : i + 1], wids[i : i + 1])
    t_serve = time.time() - t0
    n_writes = len(inserted)
    # a low rate can sample zero inserts: no write ever happened, so the
    # delta index was never created and the summary is empty
    delta = svc.delta_summary() or dict(
        n_seals=0, n_compactions=0, n_pending=0
    )
    print(f"serve[mixed{'/async' if args.use_async else ''}]: "
          f"{n_ops - n_writes} queries + {n_writes} inserts "
          f"(write mix {args.insert_rate:.0%}) in {t_serve:.2f}s "
          f"({n_ops / t_serve:.1f} ops/s); delta: {delta['n_seals']} seals, "
          f"{delta['n_compactions']} compactions, {delta['n_pending']} "
          f"rows pending")

    n_bad = 0
    if args.check and inserted:
        for pid, v, w in inserted:  # pre-compaction: exact delta scan
            n_bad += pid not in svc.query(v[None], [w]).ids[0]
        absorbed = svc.compact()
        for pid, v, w in inserted:  # post-compaction: compiled index path
            n_bad += pid not in svc.query(v[None], [w]).ids[0]
        recompiled = svc.step_cache.n_compiled - n_compiled0
        n_bad += recompiled  # streaming must never compile a new step
        print(f"check[streaming]: {2 * len(inserted) - n_bad}"
              f"/{2 * len(inserted)} insert self-queries exact "
              f"(pre + post compaction of {absorbed} rows), "
              f"{recompiled} recompiles")
        assert n_bad == 0, f"{n_bad} streaming checks failed"
    obs_report = _finish_obs(args, svc)
    health_report = _finish_health(args, svc, driver)
    return {
        "n_groups": plan.n_groups,
        "beta_total": plan.beta_total,
        "n_compiled_steps": svc.step_cache.n_compiled,
        "t_plan": t_plan,
        "t_build": t_build,
        "t_serve": t_serve,
        "qps": n_ops / t_serve,
        "n_inserts": n_writes,
        "stats": svc.stats_summary(),
        "cache": svc.cache_summary(),
        "delta": svc.delta_summary(),
        "n_check_failures": n_bad,
        "async": None,
        "obs": obs_report,
        "health": health_report,
        "driver": driver.stats.summary() if driver is not None else None,
    }


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n", type=int, default=4_096)
    ap.add_argument("--d", type=int, default=24)
    ap.add_argument("--n-weights", type=int, default=24)
    ap.add_argument("--n-subset", type=int, default=6)
    ap.add_argument("--n-subrange", type=int, default=10)
    ap.add_argument("--n-queries", type=int, default=96)
    ap.add_argument("--k", type=int, default=5)
    ap.add_argument("--q-batch", type=int, default=8)
    ap.add_argument("--c", type=int, default=3)
    ap.add_argument("--p", type=float, default=2.0)
    ap.add_argument("--tau", type=float, default=500.0)
    ap.add_argument("--v", type=int, default=6)
    ap.add_argument("--gamma-n", type=float, default=100.0)
    ap.add_argument("--value-range", type=float, default=10_000.0)
    ap.add_argument("--q-noise", type=float, default=3.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-out", default=None,
                    help="save the exported ServingPlan npz here")
    ap.add_argument("--check", action="store_true",
                    help="cross-validate every answer against search_dense")
    ap.add_argument("--async", dest="use_async", action="store_true",
                    help="serve through the deadline-aware async frontend: "
                         "requests are replayed open-loop at --arrival-rate "
                         "and a batch launches when it fills or its oldest "
                         "request has waited --max-delay-ms")
    ap.add_argument("--driver", action="store_true",
                    help="step the --async replay through the real-time "
                         "ServiceDriver (deadline-miss accounting, "
                         "cost-aware eviction, idle-tick compaction)")
    ap.add_argument("--prefetch", action="store_true",
                    help="with --driver: predictively prefetch group "
                         "states from the pending-deadline schedule so "
                         "restores overlap launches")
    ap.add_argument("--qos", action="store_true",
                    help="multi-tenant QoS for the --async replay: each "
                         "request is tagged with a --tenants class, "
                         "admission-controlled, dequeued weighted-fair, "
                         "and degradable tenants step down the "
                         "--degrade-ladder under sustained overload")
    ap.add_argument("--tenants", type=parse_tenants,
                    default="gold:weight=4,slo_ms=20;"
                            "bronze:slo_ms=100,degradable",
                    help="tenant classes for --qos: ';'-separated "
                         "name:key=val,... specs (keys: weight, rate, "
                         "burst, slo_ms, degradable)")
    ap.add_argument("--degrade-ladder", type=parse_ladder,
                    default="4:3:0.5",
                    help="with --qos: pre-planned (c, k) relaxation "
                         "rungs, strictest first, as c:k[:cost] entries "
                         "joined by ','")
    ap.add_argument("--qos-capacity", type=float, default=1.0,
                    help="with --qos: launch-cost budget per scheduler "
                         "tick for the weighted-fair dequeue")
    ap.add_argument("--max-delay-ms", type=float, default=2.0,
                    help="async deadline budget: a partial batch launches "
                         "once its oldest request has waited this long")
    ap.add_argument("--arrival-rate", type=float, default=2_000.0,
                    help="open-loop Poisson arrival rate (queries/s of "
                         "virtual traffic) for --async replay")
    ap.add_argument("--insert-rate", type=float, default=0.0,
                    help="mixed read/write replay: fraction of the op "
                         "stream that are streaming inserts (0..1); with "
                         "--check, verifies insert recall pre and post "
                         "compaction")
    ap.add_argument("--delta-seal-rows", type=int, default=32,
                    help="streaming: seal a group's open delta memtable "
                         "into a hashed segment at this row count")
    ap.add_argument("--delta-reserve-rows", type=int, default=None,
                    help="row capacity reserved per group state for "
                         "compacted inserts (default: --n-queries when "
                         "--insert-rate > 0, else 0)")
    ap.add_argument("--shards", type=int, default=1,
                    help="shard every group state's rows across this many "
                         "devices (per-shard scan passes, exact collective "
                         "merge — answers are bit-identical at any shard "
                         "count); on CPU force a multi-device platform "
                         "with XLA_FLAGS="
                         "--xla_force_host_platform_device_count=N")
    ap.add_argument("--max-resident-groups", type=int, default=None,
                    help="page group states: keep at most this many device-"
                         "resident (LRU eviction + host offload/restore)")
    ap.add_argument("--device-budget", type=parse_bytes, default=None,
                    metavar="BYTES",
                    help="page group states under this device byte budget "
                         "(accepts 512MB / 2GB / plain bytes)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="observability: export one JSONL trace span per "
                         "served query to PATH (stage timestamps on the "
                         "service clock + WLSH cost counters); implies "
                         "the obs layer on")
    ap.add_argument("--metrics-out", default=None, metavar="PATH",
                    help="observability: write the unified metrics "
                         "registry to PATH after serving (.json = JSON "
                         "snapshot, anything else = Prometheus text "
                         "exposition); implies the obs layer on")
    ap.add_argument("--profile-dir", default=None, metavar="DIR",
                    help="observability: per-shape-signature compile and "
                         "dispatch-time attribution, plus a jax.profiler "
                         "trace captured into DIR when the profiler is "
                         "available; implies the obs layer on")
    ap.add_argument("--recall-sample-rate", type=float, default=0.0,
                    metavar="RATE",
                    help="quality telemetry: shadow-sample this fraction "
                         "of live queries (deterministic hash of the "
                         "query id) and re-rank their served answers "
                         "against the exact host oracle off the serving "
                         "path; answers stay bit-exact; implies the obs "
                         "layer on")
    ap.add_argument("--alerts-out", default=None, metavar="PATH",
                    help="with --driver: attach the stock SLO burn-rate "
                         "alert rules (deadline misses, tenant SLO, "
                         "prefetch waste, recall-below-bound) to the "
                         "driver ticks and export the alert events to "
                         "PATH as JSONL")
    ap.add_argument("--health", action="store_true",
                    help="print the quality-telemetry report after "
                         "serving: per-rung observed recall vs its "
                         "ladder bound, shadow-queue accounting, and "
                         "(with --driver) the alert-rule summary")
    ap.add_argument("--use-pallas", choices=["auto", "on", "off",
                                             "interpret"], default=None,
                    help="query kernel path: auto = per-backend fused "
                         "default (compiled Pallas where supported, fused "
                         "XLA composite elsewhere), on = fused Pallas "
                         "(interpret off-TPU), off = unfused reference "
                         "stages, interpret = fused Pallas in interpret "
                         "mode (kernel body, any backend)")
    ap.add_argument("--no-pallas", action="store_true",
                    help="shorthand for --use-pallas off")
    args = ap.parse_args(argv)
    if args.no_pallas:
        if args.use_pallas not in (None, "off"):
            ap.error("--no-pallas contradicts --use-pallas "
                     f"{args.use_pallas}")
        args.use_pallas = "off"
    if args.use_pallas is None:
        args.use_pallas = "auto"
    if not 0.0 <= args.insert_rate <= 1.0:
        ap.error(f"--insert-rate must be in [0, 1], got {args.insert_rate}")
    if not 0.0 <= args.recall_sample_rate <= 1.0:
        ap.error(f"--recall-sample-rate must be in [0, 1], got "
                 f"{args.recall_sample_rate}")
    if args.alerts_out and not args.driver:
        ap.error("--alerts-out needs the tick-driven alert evaluation; "
                 "add --driver (and --async)")
    if args.driver and not args.use_async:
        ap.error("--driver drives the async frontend; add --async")
    if args.prefetch and not args.driver:
        ap.error("--prefetch is a ServiceDriver feature; add --driver")
    if args.qos and not args.use_async:
        ap.error("--qos shapes the async frontend's traffic; add --async")
    if args.qos and args.insert_rate > 0:
        ap.error("--qos is not wired into the mixed read/write replay; "
                 "drop --insert-rate")
    if args.qos and args.check:
        ap.error("--check validates strict answers; a degraded QoS tenant "
                 "may legitimately differ — drop one of the two")
    return args


def main(argv=None):
    return run(parse_args(argv))


if __name__ == "__main__":
    main()
