"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set
XLA_FLAGS=--xla_force_host_platform_device_count=512 before first init.
"""

from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips with a leading "pod"
    axis (DCN) for the multi-pod dry-run."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int | None = None, model: int = 1):
    """Small mesh over whatever devices exist (tests/examples on CPU)."""
    n = len(jax.devices())
    if data is None:
        data = n // model
    return jax.make_mesh((data, model), ("data", "model"))
