"""Serving launcher: batched autoregressive generation behind the decode
step, CPU-runnable on reduced configs and mesh-lowerable for pods.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-780m \
        --reduced --batch 4 --prompt-len 16 --max-new 32
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from ..configs.base import get_config, reduced as reduce_cfg
from ..models import build_model, init_params
from ..serving.decode import SamplerConfig, generate

__all__ = ["serve", "main"]


def serve(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = rng.integers(0, cfg.vocab, (args.batch, args.prompt_len),
                           dtype=np.int32)
    t0 = time.time()
    out = generate(
        model, params, prompts,
        max_new_tokens=args.max_new,
        cache_len=args.prompt_len + args.max_new,
        sampler=SamplerConfig(temperature=args.temperature, top_k=args.top_k,
                              seed=args.seed),
    )
    dt = time.time() - t0
    toks = args.batch * args.max_new
    print(f"{cfg.name}: generated {toks} tokens in {dt:.1f}s "
          f"({toks / dt:.1f} tok/s incl. compile)")
    for b in range(min(args.batch, 2)):
        print(f"  seq {b}: {out[b][:16].tolist()} ...")
    return {"tokens": out, "tok_per_s": toks / dt}


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=1.0)
    ap.add_argument("--top-k", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    return ap.parse_args(argv)


def main(argv=None):
    return serve(parse_args(argv))


if __name__ == "__main__":
    main()
