import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# ^ MUST precede every other import (jax locks the device count on first
# init).  512 placeholder host devices back both production meshes:
# (16,16) single-pod and (2,16,16) multi-pod.

"""Multi-pod dry-run: lower + compile every (arch x input-shape x mesh)
cell on the production meshes, prove memory fits, and extract the roofline
terms (launch/roofline.py) from the compiled artifact.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-405b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both \
      --out experiments/dryrun

Results are cached to JSON (one file per cell); --force re-runs.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from ..configs.base import ARCHS, SHAPES, ModelConfig, ShapeConfig, get_config
from ..distributed.sharding import named_sharding, with_rules
from ..models import build_model, default_flags, input_specs
from ..models.params import ParamDef, abstract_params, param_specs
from ..training.optimizer import AdamWConfig
from ..training.train_loop import (batch_shardings, make_train_step,
                                   train_state_defs)
from .estimate import model_flops
from .mesh import make_production_mesh
from .roofline import HW, analyze

HBM_PER_CHIP = 16 * 1024**3  # v5e

# per-arch optimizer memory policy (see EXPERIMENTS.md Sec Dry-run):
# llama3-405b only fits a single 256-chip pod with bf16-SR master + int8
# moments; everything else keeps full-precision state.
_OPT_POLICY: dict[str, AdamWConfig] = {
    "llama3_405b": AdamWConfig(master_dtype="bfloat16", moment_dtype="int8",
                               acc_dtype="bfloat16", update_chunk=2),
    "chameleon_34b": AdamWConfig(moment_dtype="int8", update_chunk=4),
}

# per-arch microbatch policy for train_4k: gradient accumulation bounds the
# live-activation footprint (the standard fix once remat boundaries alone
# exceed HBM — see EXPERIMENTS.md Sec Perf iterations).
_MICRO_POLICY: dict[str, int] = {
    "llama3_405b": 8,
    "chameleon_34b": 4,
    "moonshot_v1_16b_a3b": 2,
    "minicpm_2b": 2,  # 122k-vocab head: 17.7 GB/chip at micro=1
}


def _opt_cfg(arch: str) -> AdamWConfig:
    return _OPT_POLICY.get(arch, AdamWConfig())


def _microbatches(arch: str) -> int:
    return _MICRO_POLICY.get(arch, 1)


def skip_reason(arch: str, cfg: ModelConfig, shape: ShapeConfig) -> str | None:
    if cfg.family == "index":
        if shape.kind == "decode":
            return "index has no decode semantics (build/query only)"
        return None
    if shape.name == "long_500k" and cfg.full_attention:
        return ("pure full-attention arch: 500k-token decode needs a "
                "sub-quadratic cache (DESIGN.md Sec 5)")
    return None


def _bf16_defs(defs):
    """Serving params: all f32 leaves in bf16."""
    is_def = lambda x: isinstance(x, ParamDef)  # noqa: E731
    return jax.tree.map(
        lambda d: dataclasses.replace(d, dtype="bfloat16")
        if d.dtype == "float32" else d,
        defs,
        is_leaf=is_def,
    )


def _cache_specs(model, mesh, cache_shapes):
    names_by_key = {
        "k": ("layers", "batch", "kv_seq", "kv_heads", None),
        "v": ("layers", "batch", "kv_seq", "kv_heads", None),
        "ssm": ("layers", "batch", "heads", None, None),
        "conv": ("layers", "batch", None, "model"),
    }
    return {
        k: named_sharding(mesh, names_by_key[k], tuple(s.shape))
        for k, s in cache_shapes.items()
    }


def lower_cell(arch: str, shape_name: str, mesh_name: str,
               cfg_override: ModelConfig | None = None,
               flags=None, index_overrides: dict | None = None):
    """Returns (lowered, compiled, chips, extras) for one cell.

    ``cfg_override``/``flags``/``index_overrides`` serve the shallow
    unrolled analysis lowerings (see analysis_terms)."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = mesh.size

    if cfg.family == "index":
        return _lower_wlsh(cfg, shape, mesh, mesh_name,
                           overrides=index_overrides)

    model = build_model(cfg, mesh=mesh, flags=flags or default_flags(cfg))
    defs = model.defs()
    analysis = flags is not None and flags.analysis_unroll

    if shape.kind == "train":
        ocfg = _opt_cfg(arch)
        micro = _microbatches(arch)
        sdefs = train_state_defs(defs, ocfg)
        state_abs = abstract_params(sdefs)
        state_sh = jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp),
            param_specs(sdefs, mesh),
        )
        batch_abs = input_specs(cfg, shape)
        batch_sh = batch_shardings(mesh, batch_abs)
        step = make_train_step(model, ocfg, microbatches=micro,
                               unroll=analysis)
        jitted = jax.jit(
            step, in_shardings=(state_sh, batch_sh), donate_argnums=(0,)
        )
        lowered = jitted.lower(state_abs, batch_abs)
    elif shape.kind == "prefill":
        pdefs = _bf16_defs(defs)
        params_abs = abstract_params(pdefs)
        params_sh = jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp),
            param_specs(pdefs, mesh),
        )
        batch_abs = input_specs(cfg, shape)
        batch_sh = batch_shardings(mesh, batch_abs)
        jitted = jax.jit(model.prefill, in_shardings=(params_sh, batch_sh))
        lowered = jitted.lower(params_abs, batch_abs)
    else:  # decode
        data_size = chips // mesh.shape["model"]
        rules = {}
        kv_axes = []
        if shape.global_batch % data_size != 0:
            # batch can't take the data axes -> cache sequence does
            kv_axes += ["pod", "data"] if multi else ["data"]
        eff_kv = cfg.n_kv_heads * model.kv_rep if cfg.n_kv_heads else 0
        if eff_kv and eff_kv % mesh.shape["model"] != 0:
            # MHA (G == 1, no kv replication possible): the head dim can't
            # shard over "model" -> the cache sequence does instead
            kv_axes.append("model")
        if kv_axes:
            rules["kv_seq"] = tuple(kv_axes)
        ctx = with_rules(**rules) if rules else None
        if ctx:
            ctx.__enter__()
        try:
            pdefs = _bf16_defs(defs)
            params_abs = abstract_params(pdefs)
            params_sh = jax.tree.map(
                lambda sp: jax.sharding.NamedSharding(mesh, sp),
                param_specs(pdefs, mesh),
            )
            cache_shapes = model.cache_shapes(shape.global_batch,
                                              shape.seq_len)
            cache_sh = _cache_specs(model, mesh, cache_shapes)
            tok_abs = input_specs(cfg, shape)
            tok_sh = {
                "tokens": named_sharding(
                    mesh, ("batch",), (shape.global_batch,)
                ),
                "position": jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec()
                ),
            }

            def serve_step(params, cache, tokens, position):
                return model.decode_step(params, cache, tokens, position)

            jitted = jax.jit(
                serve_step,
                in_shardings=(params_sh, cache_sh, tok_sh["tokens"],
                              tok_sh["position"]),
                donate_argnums=(1,),
            )
            lowered = jitted.lower(
                params_abs, cache_shapes, tok_abs["tokens"],
                tok_abs["position"]
            )
        finally:
            if ctx:
                ctx.__exit__(None, None, None)

    compiled = lowered.compile()
    return lowered, compiled, chips, {}


def _lower_wlsh(cfg, shape, mesh, mesh_name, overrides: dict | None = None):
    from ..index import IndexConfig, make_query_step, query_input_specs
    from ..index.builder import build_input_specs, make_build_step
    from ..index.engine import shardings as index_shardings

    kw = dict(n=cfg.vocab, d=cfg.d_model, beta=cfg.d_ff)
    kw.update(overrides or {})
    icfg = IndexConfig(**kw)
    chips = mesh.size
    if shape.kind == "train":  # build step
        step = make_build_step(mesh, icfg)
        specs = build_input_specs(icfg)
        lowered = step.lower(
            specs["points"], specs["proj"], specs["b_int"], specs["b_frac"]
        )
    else:  # query step
        step = make_query_step(mesh, icfg)
        specs = query_input_specs(icfg)
        lowered = step.lower(
            specs["state"], specs["queries"], specs["q_codes"],
            specs["q_weight"], specs["mu"], specs["r_min"],
            specs["beta_q"], specs["levels_q"],
        )
    compiled = lowered.compile()
    return lowered, compiled, chips, {"index_cfg": dataclasses.asdict(icfg)}


def _extract_terms(lowered, compiled) -> dict:
    """Per-chip (flops, bytes, coll_bytes) from one compiled module."""
    from .roofline import collective_bytes

    cost = compiled.cost_analysis()
    if isinstance(cost, list):
        cost = cost[0]
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": float(coll["total"]),
        "coll_detail": coll,
    }


def _analysis_depths(cfg: ModelConfig) -> tuple[int, int]:
    if cfg.family == "hybrid":
        e = max(cfg.shared_block_every, 1)
        return e, 2 * e
    return 2, 4


def analysis_terms(arch: str, shape_name: str, mesh_name: str) -> dict:
    """Corrected per-chip roofline inputs.

    XLA's cost_analysis counts while-loop bodies ONCE regardless of trip
    count (verified: scan of k matmuls reports k-independent FLOPs), so the
    full scanned lowering undercounts every per-layer term by ~n_layers.
    Correction: lower the model FULLY UNROLLED (python-loop layers, unrolled
    kv-block/CE-chunk/microbatch scans — RunFlags.analysis_unroll) at two
    shallow depths L1 < L2, fit terms linear in depth, extrapolate to the
    real depth.  Nested-remat grouping is disabled in the analysis lowering
    (its extra recompute is a ~1x-per-group-boundary forward, noted in
    EXPERIMENTS.md).  Memory analysis still comes from the full scanned
    lowering in run_cell — loop buffers are reused, so that number is the
    true peak.
    """
    from ..models.transformer import RunFlags

    cfg = get_config(arch)
    if cfg.family == "index":
        return _analysis_terms_wlsh(cfg, shape_name, mesh_name)
    L1, L2 = _analysis_depths(cfg)
    full_scan = cfg.n_layers - cfg.first_dense_layers
    flags = RunFlags(remat="full", layer_groups=1, analysis_unroll=True)
    pts = []
    for Lk in (L1, L2):
        cfg_k = dataclasses.replace(
            cfg, n_layers=Lk + cfg.first_dense_layers
        )
        lowered, compiled, _, _ = lower_cell(
            arch, shape_name, mesh_name, cfg_override=cfg_k, flags=flags
        )
        pts.append(_extract_terms(lowered, compiled))
    out = {}
    for key in ("flops", "bytes", "coll"):
        slope = (pts[1][key] - pts[0][key]) / (L2 - L1)
        out[key] = pts[0][key] + slope * (full_scan - L1)
    out["coll_detail"] = {
        "per_layer_bytes": (pts[1]["coll"] - pts[0]["coll"]) / (L2 - L1),
        "base_bytes": pts[0]["coll_detail"]["bytes"],
        "counts_at_L1": pts[0]["coll_detail"]["counts"],
    }
    out["method"] = (
        f"unrolled two-point extrapolation L1={L1}, L2={L2} -> {full_scan}"
    )
    return out


def _analysis_terms_wlsh(cfg, shape_name: str, mesh_name: str) -> dict:
    """Index cells: extrapolate over scan *blocks* instead of layers."""
    from ..index import IndexConfig

    shape = SHAPES[shape_name]
    if shape.kind == "train":
        # build step: one sharded matmul, no loops — direct counting
        lowered, compiled, _, _ = lower_cell(cfg.name.replace("-", "_"),
                                             shape_name, mesh_name)
        out = _extract_terms(lowered, compiled)
        out["method"] = "direct (loop-free build step)"
        return out
    base = IndexConfig(n=cfg.vocab, d=cfg.d_model, beta=cfg.d_ff)
    chips = 512 if mesh_name == "multi" else 256
    blocks_full = base.n // chips // base.block_n
    pts = []
    for nb in (1, 2):
        n_k = chips * base.block_n * nb
        lowered, compiled, _, _ = lower_cell(
            cfg.name.replace("-", "_"), shape_name, mesh_name,
            index_overrides={"n": n_k, "analysis_unroll": True},
        )
        pts.append(_extract_terms(lowered, compiled))
    out = {}
    for key in ("flops", "bytes", "coll"):
        slope = pts[1][key] - pts[0][key]
        out[key] = pts[0][key] + slope * (blocks_full - 1)
    out["coll_detail"] = {"per_block_bytes": pts[1]["coll"] - pts[0]["coll"],
                          "base": pts[0]["coll_detail"]["bytes"]}
    out["method"] = (
        f"unrolled two-point extrapolation blocks 1,2 -> {blocks_full}"
    )
    return out


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             force: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(arch, cfg, shape)
    t0 = time.time()
    if reason:
        result = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                  "status": "skipped", "reason": reason}
    else:
        try:
            lowered, compiled, chips, extras = lower_cell(
                arch, shape_name, mesh_name
            )
            terms = analysis_terms(arch, shape_name, mesh_name)
            rr = analyze(
                arch, shape_name, mesh_name, chips, compiled,
                model_flops(cfg, shape), terms=terms,
            )
            mem_total = rr.memory.get("total_bytes", 0)
            result = {
                "status": "ok",
                "compile_s": round(time.time() - t0, 1),
                "fits_hbm": bool(mem_total <= HBM_PER_CHIP),
                "hbm_gb": round(mem_total / 1024**3, 2),
                "analysis_method": terms.get("method", "direct"),
                **rr.to_dict(),
                **extras,
            }
        except Exception as e:  # noqa: BLE001 — per-cell isolation
            result = {
                "arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "error", "error": f"{type(e).__name__}: {e}",
                "traceback": traceback.format_exc()[-4000:],
                "compile_s": round(time.time() - t0, 1),
            }
    with open(path, "w") as f:
        json.dump(result, f, indent=1, default=str)
    return result


def _fmt(result: dict) -> str:
    if result["status"] == "skipped":
        return (f"{result['arch']:22s} {result['shape']:12s} "
                f"{result['mesh']:6s} SKIP   {result['reason'][:60]}")
    if result["status"] == "error":
        return (f"{result['arch']:22s} {result['shape']:12s} "
                f"{result['mesh']:6s} ERROR  {result['error'][:80]}")
    return (
        f"{result['arch']:22s} {result['shape']:12s} {result['mesh']:6s} "
        f"ok {result['hbm_gb']:7.2f}GB/chip "
        f"c={result['compute_s']:.2e}s m={result['memory_s']:.2e}s "
        f"x={result['collective_s']:.2e}s -> {result['bottleneck']:10s} "
        f"useful={result['useful_fraction']:.2f} "
        f"[{result['compile_s']:.0f}s compile]"
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args(argv)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    archs = ARCHS if args.all or not args.arch else [args.arch]
    shapes = list(SHAPES) if args.all or not args.shape else [args.shape]

    failures = 0
    for arch in archs:
        arch = arch.replace("-", "_").replace("1.2b", "1p2b")
        for shape_name in shapes:
            for mesh_name in meshes:
                result = run_cell(arch, shape_name, mesh_name, args.out,
                                  force=args.force)
                print(_fmt(result), flush=True)
                if result["status"] == "error":
                    failures += 1
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
