"""Roofline-term extraction from compiled dry-run artifacts.

Per (arch x shape x mesh):

    compute term    = HLO_FLOPs / (chips x 197 TFLOP/s bf16)
    memory term     = HLO_bytes / (chips x 819 GB/s HBM)
    collective term = collective_wire_bytes / (chips x 50 GB/s link)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
parsed from the post-SPMD ``compiled.as_text()`` (per-device shapes): for
every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction we take its result bytes, x2 for all-reduce
(ring RS+AG), and treat the sum as per-chip wire traffic.  Instructions
whose replica_groups only cross the "pod" axis are additionally reported as
DCN bytes.

``cost_analysis()`` on a partitioned module reports per-device numbers;
MODEL_FLOPS / HLO_FLOPs (x chips) is the useful-compute fraction — it
catches remat recompute and padding waste.
"""

from __future__ import annotations

import dataclasses
import json
import re

__all__ = ["HW", "collective_bytes", "analyze", "RooflineResult"]


@dataclasses.dataclass(frozen=True)
class HW:
    peak_flops: float = 197e12  # bf16 / chip (TPU v5e-like)
    hbm_bw: float = 819e9  # bytes/s / chip
    link_bw: float = 50e9  # bytes/s / link (ICI)
    dcn_bw: float = 6.25e9  # bytes/s / chip (inter-pod, ~50 Gbit)


_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s+(?:\(([^)]*)\)|(\S+))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(([^)]*)\)",
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+) = ")


def _shape_bytes(shape_str: str, f32_as_bf16: bool = False) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        nbytes = _DTYPE_BYTES[dt]
        if f32_as_bf16 and dt == "f32":
            nbytes = 2
        total += n * nbytes
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective kind (see module docstring).

    CPU-backend bf16 correction: the CPU has no native bf16 dot, so XLA
    wraps every bf16 matmul operand in a convert-to-f32 — and SPMD then
    places activation collectives on the *converted f32* values, doubling
    their apparent wire bytes.  On TPU (the target) the MXU consumes bf16
    and those collectives stay bf16.  When a collective's operands are
    produced by convert(-fusion) ops we therefore count f32 payloads at
    2 bytes/element; the uncorrected sum is reported alongside
    (``total_raw``).
    """
    out = {"all-reduce": 0, "all-gather": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0}
    out_raw = dict(out)
    counts = dict.fromkeys(out, 0)
    lines = hlo_text.splitlines()
    producer: dict[str, str] = {}
    for line in lines:
        m = _DEF_RE.match(line)
        if m:
            producer[m.group(1)] = line
    for line in lines:
        m = _COLL_RE.search(line)
        if not m:
            continue
        # skip the "-done" halves of async pairs (same bytes as -start)
        if "-done(" in line:
            continue
        shape_str = m.group(1) or m.group(2)
        kind = m.group(3)
        operands = [o.strip().lstrip("%").split(" ")[-1].lstrip("%")
                    for o in m.group(4).split(",") if o.strip()]
        from_convert = bool(operands) and all(
            "convert" in o or "convert" in producer.get(o, "")[:160]
            for o in operands
        )
        factor = 2 if kind == "all-reduce" else 1
        out[kind] += _shape_bytes(shape_str, f32_as_bf16=from_convert) * factor
        out_raw[kind] += _shape_bytes(shape_str) * factor
        counts[kind] += 1
    return {"bytes": out, "counts": counts,
            "total": int(sum(out.values())),
            "total_raw": int(sum(out_raw.values()))}


@dataclasses.dataclass
class RooflineResult:
    arch: str
    shape: str
    mesh: str
    chips: int
    hlo_flops_per_chip: float
    hlo_bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_detail: dict
    model_flops: float  # global useful FLOPs (6*N*D style estimate)
    memory: dict  # memory_analysis numbers (per device)
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0

    def finalize(self, hw: HW = HW()):
        self.compute_s = self.hlo_flops_per_chip / hw.peak_flops
        self.memory_s = self.hlo_bytes_per_chip / hw.hbm_bw
        self.collective_s = self.coll_bytes_per_chip / hw.link_bw
        return self

    @property
    def bottleneck(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def useful_fraction(self) -> float:
        total_hlo = self.hlo_flops_per_chip * self.chips
        return self.model_flops / total_hlo if total_hlo else 0.0

    @property
    def step_time_s(self) -> float:
        """Roofline-optimistic step time = max of the three terms."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def roofline_fraction(self) -> float:
        """useful FLOPs/s at roofline step time vs peak (the MFU bound)."""
        t = self.step_time_s
        if t <= 0:
            return 0.0
        return (self.model_flops / t) / (self.chips * HW().peak_flops)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.update(
            bottleneck=self.bottleneck,
            useful_fraction=self.useful_fraction,
            step_time_s=self.step_time_s,
            roofline_fraction=self.roofline_fraction,
        )
        return d


def analyze(
    arch: str,
    shape: str,
    mesh_name: str,
    chips: int,
    compiled,
    model_flops: float,
    hw: HW = HW(),
    terms: dict | None = None,
) -> RooflineResult:
    """``terms`` overrides the raw cost_analysis numbers with the unrolled
    two-point extrapolation from dryrun.analysis_terms — required for any
    module containing loops (cost_analysis counts loop bodies once)."""
    if terms is not None:
        flops, byts = terms["flops"], terms["bytes"]
        coll = {"total": terms["coll"],
                "bytes": terms.get("coll_detail", {}),
                "counts": {}}
    else:
        cost = compiled.cost_analysis()
        if isinstance(cost, list):  # older jax returns [dict]
            cost = cost[0]
        flops = float(cost.get("flops", 0.0))
        byts = float(cost.get("bytes accessed", 0.0))
        coll = collective_bytes(compiled.as_text())
    try:
        ma = compiled.memory_analysis()
        memory = {
            "argument_bytes": int(getattr(ma, "argument_size_in_bytes", 0)),
            "output_bytes": int(getattr(ma, "output_size_in_bytes", 0)),
            "temp_bytes": int(getattr(ma, "temp_size_in_bytes", 0)),
            "code_bytes": int(getattr(ma, "generated_code_size_in_bytes", 0)),
        }
        memory["total_bytes"] = (
            memory["argument_bytes"] + memory["temp_bytes"]
        )
    except Exception as e:  # pragma: no cover
        memory = {"error": str(e)}
    return RooflineResult(
        arch=arch,
        shape=shape,
        mesh=mesh_name,
        chips=chips,
        hlo_flops_per_chip=flops,
        hlo_bytes_per_chip=byts,
        coll_bytes_per_chip=float(coll["total"]),
        coll_detail=coll,
        model_flops=model_flops,
        memory=memory,
    ).finalize(hw)
