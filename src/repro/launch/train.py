"""Training launcher: config -> mesh -> sharded train loop with the full
fault-tolerance stack (checkpoint/restart, preemption handling, straggler
monitoring, bounded auto-restart supervision).

    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --reduced \
        --steps 200 --global-batch 8 --seq-len 128 --ckpt-dir /tmp/ckpt

On a real pod the same entry point runs under one process per host with
jax.distributed.initialize(); on CPU it drives the reduced configs for the
examples and tests.  The mesh is (data, model) from --mesh; sharded state
via the logical-axis rules (FSDP x TP x EP); the data pipeline is
deterministic and shardable, so restart-resume is exactly-once.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs.base import get_config, reduced as reduce_cfg
from ..distributed.fault import (PreemptionHandler, RestartSupervisor,
                                 StragglerMonitor)
from ..models import build_model, init_params
from ..training.checkpoint import CheckpointManager
from ..training.data import DataConfig, SyntheticStream
from ..training.optimizer import AdamWConfig
from ..training.train_loop import (batch_shardings, init_train_state,
                                   make_train_step, train_state_shardings)

__all__ = ["train", "main"]


def _mesh_or_none(spec: str):
    if not spec or spec == "1":
        return None
    shape = tuple(int(x) for x in spec.split(","))
    names = ("data", "model")[: len(shape)]
    return jax.make_mesh(shape, names)


def train(args) -> dict:
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduce_cfg(cfg)
    mesh = _mesh_or_none(args.mesh)
    model = build_model(cfg, mesh=mesh)
    ocfg = AdamWConfig(
        lr=args.lr,
        warmup_steps=max(10, args.steps // 20),
        total_steps=args.steps,
        schedule="wsd" if cfg.name.startswith("minicpm") else "cosine",
    )
    stream = SyntheticStream(DataConfig(
        vocab=cfg.vocab, seq_len=args.seq_len, global_batch=args.global_batch,
        seed=args.seed, mode="markov",
    ))

    step_fn = make_train_step(model, ocfg, microbatches=args.microbatches)
    if mesh is not None:
        sh = train_state_shardings(model.defs(), ocfg, mesh)
        bsh = batch_shardings(mesh, stream.global_batch(0))
        step_fn = jax.jit(step_fn, in_shardings=(sh, bsh),
                          donate_argnums=(0,))
    else:
        sh = None
        step_fn = jax.jit(step_fn, donate_argnums=(0,))

    mgr = CheckpointManager(args.ckpt_dir, every=args.ckpt_every,
                            keep=3) if args.ckpt_dir else None
    preempt = PreemptionHandler()
    straggler = StragglerMonitor(window=50, threshold=args.straggler_ratio)
    supervisor = RestartSupervisor(max_restarts=args.max_restarts)
    history: list[float] = []

    def resume_step() -> int:
        if mgr is None:
            return 0
        got = mgr.restore_or_none(_template())
        return got[2].get("data_step", 0) if got else 0

    def _template():
        params = init_params(model.defs(), jax.random.PRNGKey(args.seed))
        return init_train_state(model.defs(), params, ocfg)

    def body(start_step: int):
        state = _template()
        if mgr is not None and start_step > 0:
            _, state, _ = mgr.restore_or_none(state) or (0, state, {})
        if mesh is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, sh,
                is_leaf=lambda x: hasattr(x, "shape"),
            )
        loss = float("nan")
        for s in range(start_step, args.steps):
            straggler.start()
            batch = {k: jnp.asarray(v)
                     for k, v in stream.global_batch(s).items()}
            state, metrics = step_fn(state, batch)
            loss = float(metrics["loss"])
            history.append(loss)
            rep = straggler.stop()
            if rep is not None:
                print(f"[straggler] step {s}: {rep.duration:.2f}s = "
                      f"{rep.ratio:.1f}x median", flush=True)
            if args.fail_at is not None and s == args.fail_at:
                args.fail_at = None  # fail exactly once
                raise RuntimeError("injected failure (--fail-at)")
            if s % args.log_every == 0:
                print(f"step {s:5d}  loss {loss:.4f}  "
                      f"lr {float(metrics['lr']):.2e}  "
                      f"gnorm {float(metrics['grad_norm']):.2f}", flush=True)
            if mgr is not None:
                mgr.maybe_save(s + 1, state, extra={"data_step": s + 1})
            if preempt.should_stop:
                print("[preempt] SIGTERM received: checkpoint + exit",
                      flush=True)
                if mgr is not None:
                    mgr.maybe_save(s + 1, state,
                                   extra={"data_step": s + 1}, force=True)
                    mgr.wait()
                break
        if mgr is not None:
            mgr.maybe_save(args.steps, state,
                           extra={"data_step": args.steps}, force=True)
            mgr.wait()
        return {"final_loss": loss, "steps_run": len(history),
                "restarts": supervisor.restarts,
                "stragglers": len(straggler.flagged)}

    t0 = time.time()
    out = supervisor.run(body, resume_step)
    out["wall_s"] = round(time.time() - t0, 1)
    out["loss_first"] = history[0] if history else float("nan")
    out["loss_last_avg"] = float(np.mean(history[-10:])) if history else None
    print(f"done: {out}", flush=True)
    return out


def parse_args(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--reduced", action="store_true",
                    help="CPU-sized variant of the arch (smoke scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--mesh", default="",
                    help="mesh shape, e.g. '4,2' (needs >= 8 devices)")
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--straggler-ratio", type=float, default=3.0)
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject one failure at this step (restart demo)")
    return ap.parse_args(argv)


def main(argv=None):
    return train(parse_args(argv))


if __name__ == "__main__":
    main()
