"""MODEL_FLOPS estimates (the roofline numerator).

Convention (per the roofline spec): 6*N*D for training (2 fwd + 4 bwd per
param-token), 2*N*D for inference, with N = *active* non-embedding params
(MoE: router + top_k/n_experts of routed experts + shared experts) plus the
LM-head matmul term.  Attention's quadratic term is deliberately excluded —
a low useful-fraction on long-sequence cells then correctly exposes
attention/remat overhead rather than hiding it.
"""

from __future__ import annotations

import jax

from ..configs.base import ModelConfig, ShapeConfig
from ..models import build_model
from ..models.params import ParamDef, count_params

__all__ = ["active_params", "model_flops"]


def _count(tree) -> int:
    return count_params(tree)


def active_params(cfg: ModelConfig) -> tuple[int, int]:
    """(N_active, N_total), excluding embed/unembed."""
    model = build_model(cfg, mesh=None)
    defs = model.defs()
    total = 0
    active = 0
    for key, sub in defs.items():
        if key == "embed":
            continue
        n = _count(sub)
        total += n
        if key == "blocks" and cfg.n_experts:
            is_def = lambda x: isinstance(x, ParamDef)  # noqa: E731
            moe = sub.get("moe", {})
            n_moe_experts = sum(
                _count(moe[k]) for k in ("wg", "wu", "wd") if k in moe
            )
            frac = cfg.top_k / cfg.n_experts
            n_active = n - n_moe_experts + int(n_moe_experts * frac)
            active += n_active
        else:
            active += n
    return active, total


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Global useful FLOPs for one step of this (arch, shape) cell."""
    if cfg.family == "index":
        # wlsh: build = hash-encode matmul; query = two scoring passes of
        # per-query distance matmuls (pass 2 recomputes, engine docstring).
        # The freq-level compare work is integer ops, not FLOPs — it shows
        # up in the HLO byte/compute terms instead.
        n, d, beta = cfg.vocab, cfg.d_model, cfg.d_ff
        if shape.kind == "train":
            return 2.0 * n * d * beta
        q = 64  # IndexConfig.q_batch
        return 2.0 * 2.0 * q * n * d
    n_act, _ = active_params(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        factor = 6.0
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        factor = 2.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        factor = 2.0
    head = factor * tokens * cfg.d_model * cfg.vocab
    return factor * n_act * tokens + head
