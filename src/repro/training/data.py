"""Deterministic, shardable, resumable synthetic token pipeline.

Every batch is a pure function of (seed, step, shard), so:
  * resume-from-checkpoint needs only the step counter (exactly-once
    delivery across restarts — verified by tests/test_checkpoint.py);
  * each host materializes only its shard (per-host data loading at pod
    scale);
  * "markov" mode draws tokens from a fixed random Markov chain so small
    models have real structure to learn in examples/train_lm.py
    ("uniform" is i.i.d. noise for pure-throughput runs).
"""

from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["DataConfig", "SyntheticStream"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    mode: str = "markov"  # markov | uniform
    branching: int = 4  # markov: candidate successors per token


class SyntheticStream:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        if cfg.mode == "markov":
            rng = np.random.default_rng(cfg.seed)
            self._succ = rng.integers(
                0, cfg.vocab, size=(cfg.vocab, cfg.branching)
            ).astype(np.int32)

    def _rng(self, step: int, row: int):
        return np.random.default_rng(
            np.random.SeedSequence([self.cfg.seed, step, row])
        )

    def _row(self, step: int, row: int) -> np.ndarray:
        cfg = self.cfg
        rng = self._rng(step, row)
        if cfg.mode == "uniform":
            return rng.integers(0, cfg.vocab, size=cfg.seq_len + 1).astype(
                np.int32
            )
        toks = np.empty(cfg.seq_len + 1, np.int32)
        toks[0] = rng.integers(0, cfg.vocab)
        picks = rng.integers(0, cfg.branching, size=cfg.seq_len)
        for i in range(cfg.seq_len):
            toks[i + 1] = self._succ[toks[i], picks[i]]
        return toks

    def global_batch(self, step: int) -> dict[str, np.ndarray]:
        rows = np.stack(
            [self._row(step, r) for r in range(self.cfg.global_batch)]
        )
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}

    def host_shard(self, step: int, host_id: int, n_hosts: int):
        """Rows this host owns (contiguous block of the global batch)."""
        per = self.cfg.global_batch // n_hosts
        rows = np.stack(
            [self._row(step, host_id * per + r) for r in range(per)]
        )
        return {"tokens": rows[:, :-1], "labels": rows[:, 1:]}
