"""Training substrate: optimizer, train step, data pipeline, checkpointing."""

from .checkpoint import (CheckpointManager, latest_step, load_checkpoint,
                         save_checkpoint)
from .data import DataConfig, SyntheticStream
from .optimizer import AdamWConfig, adamw_init, adamw_update, lr_schedule
from .train_loop import (batch_shardings, init_train_state, make_train_step,
                         train_state_defs, train_state_shardings)

__all__ = [
    "AdamWConfig", "CheckpointManager", "DataConfig", "SyntheticStream",
    "adamw_init", "adamw_update", "batch_shardings", "init_train_state",
    "latest_step", "load_checkpoint", "lr_schedule", "make_train_step",
    "save_checkpoint", "train_state_defs", "train_state_shardings",
]
