"""Train step assembly: mixed precision, microbatch accumulation, sharded
state, metrics.

Flow per step (bf16-compute / f32-or-bf16SR-master):
  compute = cast(master, bf16)            # FSDP all-gathers happen in bf16
  grads   = grad(loss)(compute, batch)    # reduce-scatter in bf16 (wire
                                          # compression)
  opt     = adamw_update(grads, opt)      # f32 math, quantized storage
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.params import ParamDef, param_specs, pdef
from .optimizer import AdamWConfig, adamw_init, adamw_update, moment_defs

__all__ = [
    "make_train_step",
    "train_state_defs",
    "init_train_state",
    "train_state_shardings",
    "batch_shardings",
]


def _cast_compute(master):
    return jax.tree.map(
        lambda p: p.astype(jnp.bfloat16)
        if jnp.issubdtype(p.dtype, jnp.floating)
        else p,
        master,
    )


def make_train_step(model, ocfg: AdamWConfig, microbatches: int = 1,
                    unroll: bool = False):
    """(state, batch) -> (state, metrics).  state = adamw opt_state + rng.

    ``unroll`` unrolls the microbatch-accumulation scan (analysis lowerings
    only — cost_analysis counts loop bodies once)."""

    def loss_fn(compute, mb):
        return model.loss(compute, mb)

    def step_fn(state, batch):
        compute = _cast_compute(state["opt"]["master"])
        if microbatches == 1:
            loss, grads = jax.value_and_grad(loss_fn)(compute, batch)
        else:
            mb = jax.tree.map(
                lambda x: x.reshape(
                    microbatches, x.shape[0] // microbatches, *x.shape[1:]
                )
                if x.ndim >= 1
                else x,
                batch,
            )

            acc_dt = jnp.dtype(ocfg.acc_dtype)

            def acc(carry, mb_i):
                loss_a, g_a = carry
                loss_i, g_i = jax.value_and_grad(loss_fn)(compute, mb_i)
                g_a = jax.tree.map(
                    lambda a, g: a + g.astype(acc_dt), g_a, g_i
                )
                return (loss_a + loss_i, g_a), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), compute
            )
            (loss, grads), _ = jax.lax.scan(
                acc, (jnp.float32(0.0), g0), mb,
                unroll=microbatches if unroll else 1,
            )
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)

        opt, _, metrics = adamw_update(grads, state["opt"], ocfg,
                                       rng=state["rng"])
        new_state = {"opt": opt, "rng": state["rng"]}
        metrics = dict(metrics, loss=loss, step=opt["step"])
        return new_state, metrics

    return step_fn


# ---------------------------------------------------------------------------
# state defs / init / shardings
# ---------------------------------------------------------------------------


def train_state_defs(model_defs, ocfg: AdamWConfig):
    is_def = lambda x: isinstance(x, ParamDef)  # noqa: E731
    master = jax.tree.map(
        lambda d: dataclasses.replace(d, dtype=ocfg.master_dtype),
        model_defs,
        is_leaf=is_def,
    )
    moments = jax.tree.map(
        lambda d: {
            "m": moment_defs(d, ocfg.moment_dtype),
            "v": moment_defs(d, ocfg.moment_dtype),
        },
        model_defs,
        is_leaf=is_def,
    )
    return {
        "opt": {
            "step": pdef((), (), init="zeros", dtype="int32"),
            "master": master,
            "moments": moments,
        },
        "rng": pdef((2,), (None,), init="zeros", dtype="uint32"),
    }


def init_train_state(model_defs, params, ocfg: AdamWConfig, seed: int = 0):
    return {
        "opt": adamw_init(params, ocfg),
        "rng": jax.random.key_data(jax.random.PRNGKey(seed)).astype(
            jnp.uint32
        ),
    }


def train_state_shardings(model_defs, ocfg: AdamWConfig, mesh: Mesh):
    defs = train_state_defs(model_defs, ocfg)
    specs = param_specs(defs, mesh)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs)


def batch_shardings(mesh: Mesh, batch_tree):
    def one(x):
        if hasattr(x, "ndim") and x.ndim >= 1:
            return NamedSharding(
                mesh,
                P(("pod", "data") if "pod" in mesh.axis_names else "data"),
            )
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_tree)
