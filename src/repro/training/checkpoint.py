"""Sharded, mesh-independent, atomic checkpointing.

Layout (one directory per step):

    <root>/step_000123/
        manifest.json      {step, keys, shapes, dtypes, extra}
        000000.npy ...     one file per pytree leaf (global array values)

Properties required at pod scale:
  * atomic: written to ``<root>/.tmp_<step>`` then os.replace()d — a crash
    mid-save never corrupts the latest checkpoint;
  * mesh-independent (elastic): leaves store *global* arrays; restore
    device_puts them under any target sharding/mesh (tests restore a
    (4,)-mesh save onto (2,2));
  * keep-last-k pruning + find-latest for automatic restart;
  * async: the array->host fetch is synchronous (cheap device->host copy),
    the file writes happen on a background thread.

Production note: per-host distributed writes would replace np.save with a
sharded writer (each host persists its addressable shards); the manifest
format and atomicity protocol stay the same.
"""

from __future__ import annotations

import dataclasses
import json
import os
import re
import shutil
import threading

import jax
import numpy as np

__all__ = ["save_checkpoint", "load_checkpoint", "latest_step", "CheckpointManager"]

_STEP_RE = re.compile(r"^step_(\d{9})$")


def _leaf_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    keys = [jax.tree_util.keystr(p) for p, _ in flat]
    vals = [v for _, v in flat]
    return keys, vals, treedef


def save_checkpoint(root: str, step: int, tree, keep: int = 3,
                    extra: dict | None = None, async_write: bool = False):
    os.makedirs(root, exist_ok=True)
    keys, vals, _ = _leaf_paths(tree)
    host_vals = [np.asarray(v) for v in vals]  # device->host before async
    tmp = os.path.join(root, f".tmp_{step:09d}")
    final = os.path.join(root, f"step_{step:09d}")

    def write():
        shutil.rmtree(tmp, ignore_errors=True)
        os.makedirs(tmp)
        manifest = {
            "step": int(step),
            "keys": keys,
            "shapes": [list(v.shape) for v in host_vals],
            "dtypes": [str(v.dtype) for v in host_vals],
            "extra": extra or {},
        }
        for i, v in enumerate(host_vals):
            np.save(os.path.join(tmp, f"{i:06d}.npy"), v)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        shutil.rmtree(final, ignore_errors=True)
        os.replace(tmp, final)
        _prune(root, keep)

    if async_write:
        t = threading.Thread(target=write, daemon=True)
        t.start()
        return t
    write()
    return None


def _prune(root: str, keep: int):
    steps = sorted(
        int(m.group(1))
        for d in os.listdir(root)
        if (m := _STEP_RE.match(d))
    )
    for s in steps[:-keep] if keep else []:
        shutil.rmtree(os.path.join(root, f"step_{s:09d}"), ignore_errors=True)


def latest_step(root: str) -> int | None:
    if not os.path.isdir(root):
        return None
    steps = [
        int(m.group(1))
        for d in os.listdir(root)
        if (m := _STEP_RE.match(d))
    ]
    return max(steps) if steps else None


def load_checkpoint(root: str, template, step: int | None = None,
                    shardings=None):
    """Restore into the structure of ``template`` (values ignored).

    ``shardings``: optional matching pytree of NamedSharding — the elastic
    path; the checkpoint may have been written under any mesh.
    Returns (step, tree, extra).
    """
    if step is None:
        step = latest_step(root)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {root}")
    d = os.path.join(root, f"step_{step:09d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    keys, _, treedef = _leaf_paths(template)
    if keys != manifest["keys"]:
        raise ValueError(
            "checkpoint structure mismatch: "
            f"{set(keys) ^ set(manifest['keys'])}"
        )
    vals = [
        np.load(os.path.join(d, f"{i:06d}.npy")) for i in range(len(keys))
    ]
    tree = jax.tree_util.tree_unflatten(treedef, vals)
    if shardings is not None:
        tree = jax.tree.map(
            lambda v, s: jax.device_put(v, s), tree, shardings
        )
    return step, tree, manifest.get("extra", {})


@dataclasses.dataclass
class CheckpointManager:
    """save-every-N + auto-resume + preemption flush."""

    root: str
    every: int = 100
    keep: int = 3
    async_write: bool = True
    _pending: threading.Thread | None = None

    def maybe_save(self, step: int, tree, extra=None, force: bool = False):
        if not force and (self.every <= 0 or step % self.every != 0):
            return False
        self.wait()
        self._pending = save_checkpoint(
            self.root, step, tree, keep=self.keep, extra=extra,
            async_write=self.async_write,
        )
        return True

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def restore_or_none(self, template, shardings=None):
        try:
            return load_checkpoint(self.root, template, shardings=shardings)
        except FileNotFoundError:
            return None
