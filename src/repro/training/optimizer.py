"""AdamW with memory-footprint controls for pod-scale training.

Distributed-optimization tricks (all configurable, DESIGN.md Sec 4):
  * moment quantization — m/v stored bf16 or *blockwise int8* (256-wide
    blocks, per-block f32 scales): 8 -> 2 bytes/param of optimizer state;
  * bf16 master params with *stochastic rounding* (unbiased), halving the
    master copy (llama3-405b on a single 256-chip pod only fits with int8
    moments + bf16-SR master — see EXPERIMENTS.md);
  * decoupled weight decay, global-norm clipping;
  * WSD (warmup-stable-decay, MiniCPM) and cosine schedules.

The optimizer state is a pytree mirroring the params, so it shards exactly
like them (FSDP over ("pod","data") x TP over "model").
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "lr_schedule"]

_QBLOCK = 256


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    master_dtype: str = "float32"  # float32 | bfloat16 (stochastic rounding)
    moment_dtype: str = "float32"  # float32 | bfloat16 | int8
    acc_dtype: str = "float32"  # microbatch grad-accumulator dtype; bf16
    # halves the scan carry (llama3-405b: the f32 carry alone is
    # 2 x 6.3 GB/chip; relative error ~ sqrt(K) * 2^-8 at K microbatches)
    update_chunk: int = 0  # >0: apply the update lax.scan-chunked over the
    # leading (stacked-layers) axis of big leaves — bounds the f32
    # dequantize/update transients to one slice instead of one whole leaf
    schedule: str = "cosine"  # cosine | wsd | constant
    warmup_steps: int = 100
    total_steps: int = 10_000
    decay_frac: float = 0.1  # WSD: last fraction of steps decays
    min_lr_frac: float = 0.1


def lr_schedule(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    if cfg.schedule == "constant":
        frac = jnp.float32(1.0)
    elif cfg.schedule == "wsd":
        decay_start = cfg.total_steps * (1.0 - cfg.decay_frac)
        t = jnp.clip(
            (step - decay_start) / max(cfg.total_steps - decay_start, 1.0),
            0.0,
            1.0,
        )
        frac = 1.0 - (1.0 - cfg.min_lr_frac) * t
    else:  # cosine
        t = jnp.clip(step / max(cfg.total_steps, 1), 0.0, 1.0)
        frac = cfg.min_lr_frac + (1.0 - cfg.min_lr_frac) * 0.5 * (
            1.0 + jnp.cos(math.pi * t)
        )
    return cfg.lr * warm * frac


# ---------------------------------------------------------------------------
# blockwise int8 quantization
# ---------------------------------------------------------------------------


def _quantize(x, ceil: bool = False):
    """f32 -> (int8 codes, f32 per-block scales), blockwise on the LAST dim.

    Leading dims are untouched so the codes inherit the parameter's
    sharding (a flattened layout would force resharding collectives on
    every optimizer step).  The last dim is padded to a 256 multiple.
    ``ceil`` rounds magnitudes up (used for the second moment so quantized
    Adam denominators are conservative, never spuriously zero).
    """
    shape = x.shape
    last = shape[-1]
    pad = (-last) % _QBLOCK
    if pad:
        x = jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, pad)])
    nb = x.shape[-1] // _QBLOCK
    blocks = x.reshape(*shape[:-1], nb, _QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0  # (..., nb)
    ratio = blocks / jnp.maximum(scale[..., None], 1e-30)
    if ceil:
        q = jnp.sign(ratio) * jnp.ceil(jnp.abs(ratio))
    else:
        q = jnp.round(ratio)
    codes = jnp.clip(q, -127, 127).astype(jnp.int8)
    return codes.reshape(*shape[:-1], nb * _QBLOCK), scale, shape


def _dequantize(codes, scale, shape):
    nb = scale.shape[-1]
    blocks = codes.reshape(*shape[:-1], nb, _QBLOCK).astype(jnp.float32)
    out = (blocks * scale[..., None]).reshape(*shape[:-1], nb * _QBLOCK)
    return out[..., : shape[-1]]


def _moment_store(x, dtype: str, kind: str = "m"):
    """kind "m": linear int8.  kind "v": sqrt-domain + ceil rounding —
    direct int8 of v zeroes ~15% of entries (measured), exploding
    m/sqrt(v); sqrt-domain storage has ~1.6% median error and the ceil
    keeps denominators conservative."""
    if dtype == "int8":
        y = jnp.sqrt(jnp.maximum(x, 0.0)) if kind == "v" else x
        codes, scale, _ = _quantize(y, ceil=(kind == "v"))
        return {"q": codes, "s": scale}
    return x.astype(jnp.dtype(dtype))


def moment_defs(param_def, dtype: str):
    """ParamDef-level mirror of _moment_store for spec/abstract derivation."""
    from ..models.params import ParamDef

    if dtype != "int8":
        return dataclasses.replace(param_def, dtype=dtype, init="zeros")
    shape = param_def.shape
    last = shape[-1]
    padded = last + ((-last) % _QBLOCK)
    q = ParamDef((*shape[:-1], padded), param_def.names, "zeros", dtype="int8")
    s = ParamDef(
        (*shape[:-1], padded // _QBLOCK),
        (*param_def.names[:-1], None),
        "zeros",
        dtype="float32",
    )
    return {"q": q, "s": s}


def _moment_load(stored, shape, dtype: str, kind: str = "m"):
    if dtype == "int8":
        y = _dequantize(stored["q"], stored["s"], shape)
        return y * y if kind == "v" else y
    return stored.astype(jnp.float32)


def _sr_cast_bf16(x, key):
    """Stochastic-rounding cast f32 -> bf16 (unbiased)."""
    bits = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    rnd = jax.random.bits(key, x.shape, jnp.uint32) & jnp.uint32(0xFFFF)
    return jax.lax.bitcast_convert_type(
        (bits + rnd) & jnp.uint32(0xFFFF0000), jnp.float32
    ).astype(jnp.bfloat16)


# ---------------------------------------------------------------------------
# init / update
# ---------------------------------------------------------------------------


def adamw_init(params, cfg: AdamWConfig):
    def one(p):
        # distinct buffers for m and v: donation rejects aliased arguments
        return {
            "m": _moment_store(jnp.zeros(p.shape, jnp.float32),
                               cfg.moment_dtype),
            "v": _moment_store(jnp.zeros(p.shape, jnp.float32),
                               cfg.moment_dtype),
        }

    master = jax.tree.map(
        lambda p: p.astype(jnp.dtype(cfg.master_dtype)), params
    )
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": master,
        "moments": jax.tree.map(one, params),
    }


def _global_norm(grads):
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads))
    )


def adamw_update(grads, opt_state, cfg: AdamWConfig, rng=None):
    """One AdamW step.  Returns (new_opt_state, compute_params, metrics).

    ``compute_params`` are the bf16 copies the next forward should use
    (casting here keeps gradient all-reduce in bf16 = wire compression).
    """
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1**t
    bc2 = 1.0 - cfg.b2**t

    gnorm = _global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    leaves_g, treedef = jax.tree.flatten(grads)
    leaves_m = treedef.flatten_up_to(opt_state["master"])
    leaves_s = treedef.flatten_up_to(opt_state["moments"])
    if rng is None:
        rng = jax.random.PRNGKey(0)
    keys = jax.random.split(jax.random.fold_in(rng, step), len(leaves_g))

    def _leaf_update(g, p, st, key, scale, lr):
        g = g.astype(jnp.float32) * scale
        m = _moment_load(st["m"], g.shape, cfg.moment_dtype, "m")
        v = _moment_load(st["v"], g.shape, cfg.moment_dtype, "v")
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        upd = (m / bc1) / (jnp.sqrt(v / bc2) + cfg.eps)
        pf = p.astype(jnp.float32)
        pf = pf - lr * (upd + cfg.weight_decay * pf)
        if cfg.master_dtype == "bfloat16":
            p_new = _sr_cast_bf16(pf, key)
        else:
            p_new = pf.astype(jnp.dtype(cfg.master_dtype))
        moments = {"m": _moment_store(m, cfg.moment_dtype, "m"),
                   "v": _moment_store(v, cfg.moment_dtype, "v")}
        return p_new, moments, pf.astype(jnp.bfloat16)

    new_master, new_moments, new_compute = [], [], []
    for g, p, st, key in zip(leaves_g, leaves_m, leaves_s, keys):
        chunk = cfg.update_chunk
        lead = g.shape[0] if g.ndim else 0
        if chunk and g.ndim >= 2 and lead > chunk and lead % chunk == 0:
            # stacked-layers leaf: scan the update over leading slices so
            # the f32 dequantize/update transients stay one-slice-sized
            def body(_, sl):
                g_i, p_i, st_i, key_i = sl
                return None, _leaf_update(g_i, p_i, st_i, key_i, scale, lr)

            keys_l = jax.random.split(key, lead // chunk)
            resh = lambda x: x.reshape(lead // chunk, chunk, *x.shape[1:])  # noqa: E731,E501
            _, (p_new, moments, comp) = jax.lax.scan(
                body, None,
                (jax.tree.map(resh, g), jax.tree.map(resh, p),
                 jax.tree.map(resh, st), keys_l),
            )
            unresh = lambda x: x.reshape(lead, *x.shape[2:])  # noqa: E731
            p_new = jax.tree.map(unresh, p_new)
            moments = jax.tree.map(unresh, moments)
            comp = jax.tree.map(unresh, comp)
        else:
            p_new, moments, comp = _leaf_update(g, p, st, key, scale, lr)
        new_master.append(p_new)
        new_moments.append(moments)
        new_compute.append(comp)

    out = {
        "step": step,
        "master": jax.tree.unflatten(treedef, new_master),
        "moments": jax.tree.unflatten(treedef, new_moments),
    }
    metrics = {"grad_norm": gnorm, "lr": lr}
    return out, jax.tree.unflatten(treedef, new_compute), metrics
