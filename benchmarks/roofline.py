"""Roofline summary: aggregates experiments/dryrun/*.json into the
EXPERIMENTS.md Sec Roofline table (one row per arch x shape x mesh)."""

from __future__ import annotations

import glob
import json
import os

from .common import print_table, save

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)


def load_cells(mesh: str | None = None) -> list[dict]:
    cells = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if mesh and r.get("mesh") != mesh:
            continue
        cells.append(r)
    return cells


def run(full: bool = False, mesh: str | None = None):
    del full
    cells = load_cells(mesh)
    rows, n_ok, n_skip, n_err = [], 0, 0, 0
    for r in cells:
        tag = f"{r.get('arch','?')}/{r.get('shape','?')}/{r.get('mesh','?')}"
        if r["status"] == "skipped":
            n_skip += 1
            rows.append([tag, "SKIP", "-", "-", "-", "-", "-", "-",
                         r["reason"][:40]])
            continue
        if r["status"] == "error":
            n_err += 1
            rows.append([tag, "ERR", "-", "-", "-", "-", "-", "-",
                         r["error"][:40]])
            continue
        n_ok += 1
        rows.append([
            tag, "ok", r["hbm_gb"],
            f"{r['compute_s']:.2e}", f"{r['memory_s']:.2e}",
            f"{r['collective_s']:.2e}", r["bottleneck"],
            round(r["useful_fraction"], 3),
            f"roofline_frac={r['roofline_fraction']:.3f}",
        ])
    print_table(
        "Roofline terms per (arch x shape x mesh)",
        ["cell", "st", "GB/chip", "compute_s", "memory_s", "collective_s",
         "bound", "useful", "note"],
        rows,
    )
    fits = [r for r in cells if r["status"] == "ok"]
    bad_fit = [f"{r['arch']}/{r['shape']}/{r['mesh']}" for r in fits
               if not r.get("fits_hbm", False)]
    print(f"\ncells: {n_ok} ok, {n_skip} skipped, {n_err} error; "
          f"{len(bad_fit)} over HBM: {bad_fit}")
    out = {"rows": rows, "ok": n_ok, "skipped": n_skip, "errors": n_err,
           "over_hbm": bad_fit}
    save("roofline_summary", out)
    return out


if __name__ == "__main__":
    run()
