"""Paper Table 8 / Figs. 8-9: average overall ratio of WLSH vs SL-ALSH vs
S2-ALSH at *matched I/O* (l2, uniformly random weight vectors).

Protocol (Sec. 5.3.2): run WLSH, record its per-query candidate count, then
give each ALSH variant the same candidate budget and compare ratios.  The
paper uses c=8-ish budgets so all three have moderate space; we keep c=3
and simply hand ALSH the measured budget.  ALSH m is swept and the best
ratio kept (Table 12 protocol).
"""

from __future__ import annotations

import numpy as np

from repro.core.alsh import ALSHIndex
from repro.core.datagen import make_dataset, make_query_set, make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex

from .common import DEFAULT, TAU, Timer, print_table, save

_ALSH_M = (8, 16, 24)


def _ratio(data, ids, q, w, p=2.0):
    got = ids[ids >= 0]
    if got.size == 0:
        return np.inf
    exact = np.sort(weighted_lp_np(data, q, w, p))[: got.size]
    mine = np.sort(weighted_lp_np(data[got], q, w, p))
    return float(np.mean(mine / np.maximum(exact, 1e-12)))


def run(full: bool = False, k_values=(5, 20), datasets=("uniform", "clustered")):
    del full
    rows = []
    d, n, S = DEFAULT["d"], DEFAULT["n"], DEFAULT["S"]
    for ds in datasets:
        if ds == "uniform":
            data = make_dataset(n=n, d=d, seed=51)
        else:
            rng = np.random.default_rng(52)
            centers = rng.uniform(0, 10_000, (40, d))
            data = (
                centers[rng.integers(0, 40, n)] + rng.normal(0, 300, (n, d))
            ).clip(0, 10_000).astype(np.float32)
        # uniformly random weight vector set (paper: #Subset=|S|, #Subrange=1)
        weights = make_weight_set(size=S, d=d, n_subset=S, n_subrange=1,
                                  seed=53)
        # paper protocol: query points removed from the data set first
        qs = make_query_set(data, weights, n_query_points=6,
                            n_query_weights=3, seed=54)
        data = qs.data
        cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
        wlsh = WLSHIndex(data, weights, cfg, tau=TAU[2.0], v=max(1, d // 4),
                         v_prime=max(1, d // 4), seed=7)
        for k in k_values:
            wl_ratios, budgets = [], []
            for q in qs.points:
                for wid in qs.weight_ids:
                    res = wlsh.search(q, weight_id=int(wid), k=k)
                    wl_ratios.append(
                        _ratio(wlsh.data, res.ids, q, wlsh.weights[int(wid)])
                    )
                    budgets.append(max(res.stats.n_checked, k))
            row = {"dataset": ds, "k": k,
                   "wlsh": float(np.mean(wl_ratios)),
                   "beta_S": wlsh.beta_total}
            for variant in ("sl", "s2"):
                best = np.inf
                for m in _ALSH_M:
                    idx = ALSHIndex(data, cfg, variant=variant, m=m, L=16,
                                    seed=8)
                    ratios = []
                    b_iter = iter(budgets)
                    for q in qs.points:
                        for wid in qs.weight_ids:
                            ids, _, _ = idx.query(
                                q, weights[int(wid)], k=k,
                                budget=int(next(b_iter)),
                            )
                            ratios.append(
                                _ratio(data, ids, q, weights[int(wid)])
                            )
                    best = min(best, float(np.mean(ratios)))
                row[variant] = best
            rows.append([row["dataset"], row["k"], round(row["wlsh"], 4),
                         round(row["sl"], 4), round(row["s2"], 4),
                         row["beta_S"]])
    print_table(
        "Table 8 — avg overall ratio at matched I/O (l2)",
        ["dataset", "k", "WLSH", "SL-ALSH", "S2-ALSH", "beta_S"],
        rows,
    )
    wins = sum(1 for r in rows if r[2] <= r[3]) + sum(
        1 for r in rows if r[2] <= r[4]
    )
    checks = [
        ("WLSH ratio < c everywhere", all(r[2] < 3.0 for r in rows)),
        (f"WLSH wins majority of comparisons ({wins}/{2 * len(rows)})",
         wins >= len(rows)),
    ]
    out = {"rows": rows,
           "validation": [{"check": n, "ok": bool(ok)} for n, ok in checks]}
    print("\nvalidation:")
    for c in out["validation"]:
        print(f"  [{'ok' if c['ok'] else 'FAIL'}] {c['check']}")
    save("table8_ratio", out)
    return out


if __name__ == "__main__":
    run()
