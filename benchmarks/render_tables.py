"""Render the EXPERIMENTS.md §Roofline table from experiments/dryrun/*.json.

    PYTHONPATH=src python -m benchmarks.render_tables   # prints markdown
"""

from __future__ import annotations

import glob
import json
import os

DRYRUN_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "dryrun",
)

_SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def rows(mesh: str):
    out = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json"))):
        with open(path) as f:
            r = json.load(f)
        if r.get("mesh") != mesh:
            continue
        out.append(r)
    out.sort(key=lambda r: (r.get("arch", ""),
                            _SHAPE_ORDER.index(r.get("shape", "train_4k"))
                            if r.get("shape") in _SHAPE_ORDER else 9))
    return out


def markdown(mesh: str = "single") -> str:
    lines = [
        f"**{'Single pod (16,16)=256 chips' if mesh == 'single' else 'Multi-pod (2,16,16)=512 chips'}** — terms in seconds/step; bound = argmax term; useful = MODEL_FLOPS/HLO_FLOPS.",  # noqa: E501
        "",
        "| arch | shape | GB/chip | fits | compute_s | memory_s | collective_s | bound | useful | roofline_frac |",  # noqa: E501
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows(mesh):
        arch, shape = r.get("arch", "?"), r.get("shape", "?")
        if r["status"] == "skipped":
            lines.append(f"| {arch} | {shape} | — | skip | — | — | — | — | — "
                         f"| {r['reason'][:48]} |")
            continue
        if r["status"] == "error":
            lines.append(f"| {arch} | {shape} | — | ERR | — | — | — | — | — "
                         f"| {r['error'][:48]} |")
            continue
        lines.append(
            f"| {arch} | {shape} | {r['hbm_gb']:.1f} "
            f"| {'yes' if r['fits_hbm'] else 'NO*'} "
            f"| {r['compute_s']:.2e} | {r['memory_s']:.2e} "
            f"| {r['collective_s']:.2e} | {r['bottleneck']} "
            f"| {r['useful_fraction']:.2f} | {r['roofline_fraction']:.3f} |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(markdown("single"))
    print()
    print(markdown("multi"))
