"""Paper Fig. 1 (and Figs. 2-7): WLSH query efficiency (I/O cost) and
accuracy (average overall ratio) as each parameter varies, l1 + l2,
k in {10, 100 -> scaled 5, 20}, with collision-threshold reduction on/off.

Runs the faithful host search (the I/O-metered path) on CPU-scaled data.
Validation targets (Sec. 5.3.1): I/O up with n, down with c, ~flat in the
weight-set params; ratio well below c everywhere; reduction cuts I/O.
"""

from __future__ import annotations

import numpy as np

from repro.core.datagen import make_dataset, make_query_set, make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex

from .common import (DEFAULT, GRID, TAU, VALUE_RANGE, Timer, print_table,
                     save)

_K = (5, 20)  # paper: (10, 100), scaled with n


def _one_setting(p, d, n, c, n_subrange, n_subset, S, k, reduction,
                 n_qp=6, n_qw=3, seed=0):
    raw = make_dataset(n=n, d=d, seed=seed + 1)
    weights = make_weight_set(size=S, d=d, n_subset=n_subset,
                              n_subrange=n_subrange, seed=seed + 2)
    # paper protocol: query points are removed from the data set, THEN the
    # index is built (otherwise exact-NN distance is 0 for the point itself)
    qs = make_query_set(raw, weights, n_query_points=n_qp,
                        n_query_weights=n_qw, seed=seed + 3)
    data = qs.data
    cfg = PlanConfig(p=p, c=c, n=len(data), gamma_n=100.0)
    idx = WLSHIndex(data, weights, cfg, tau=TAU[p], v=max(1, d // 4),
                    v_prime=max(1, d // 4), use_reduction=reduction,
                    seed=seed)
    ios, ratios = [], []
    for q in qs.points:
        for wid in qs.weight_ids:
            res = idx.search(q, weight_id=int(wid), k=k)
            ios.append(res.stats.io_blocks)
            got = res.ids[res.ids >= 0]
            if got.size:
                w = idx.weights[int(wid)]
                exact = np.sort(weighted_lp_np(idx.data, q, w, p))[: got.size]
                mine = np.sort(weighted_lp_np(idx.data[got], q, w, p))
                ratios.append(
                    float(np.mean(mine / np.maximum(exact, 1e-12)))
                )
    return float(np.mean(ios)), float(np.mean(ratios)) if ratios else np.inf


def run(full: bool = False, p_values=(1.0, 2.0), reduction: bool = True,
        params=("n", "c", "d", "S")) -> dict:
    del full  # data-pass benchmark: always CPU-scaled
    out: dict = {"reduction": reduction, "results": {}}
    for p in p_values:
        rows = []
        for param in params:
            for val in GRID[param]:
                kw = dict(DEFAULT)
                kw[param] = val
                for k in _K:
                    with Timer() as t:
                        io, ratio = _one_setting(
                            p, kw["d"], kw["n"], kw["c"], kw["n_subrange"],
                            kw["n_subset"], kw["S"], k, reduction,
                        )
                    rows.append([param, val, k, round(io, 1),
                                 round(ratio, 4), round(t.seconds, 1)])
        out["results"][f"l{int(p)}"] = rows
        print_table(
            f"Fig 1 — WLSH query I/O + ratio, l_{int(p)}"
            f" (reduction={reduction})",
            ["param", "value", "k", "io_blocks", "avg_ratio", "sec"],
            rows,
        )
    _validate(out)
    save(f"fig1_query_red{int(reduction)}", out)
    return out


def _validate(out):
    checks = []
    for key, rows in out["results"].items():
        c_val = int(key[1])  # noqa: F841
        byp = lambda param, k: [  # noqa: E731
            (r[1], r[3], r[4]) for r in rows if r[0] == param and r[2] == k
        ]
        for k in _K:
            n_io = [x[1] for x in byp("n", k)]
            checks.append((f"{key} k={k} io up with n",
                           n_io[-1] > n_io[0]))
            c_io = [x[1] for x in byp("c", k)]
            checks.append((f"{key} k={k} io down with c",
                           c_io[-1] < c_io[0] * 1.1))
            ratios = [r[4] for r in rows if r[2] == k and np.isfinite(r[4])]
            # ratio << c=3 at defaults; allow some slack at c=6 cells
            checks.append((f"{key} k={k} mean ratio < 2",
                           float(np.mean(ratios)) < 2.0))
    out["validation"] = [
        {"check": n, "ok": bool(ok)} for n, ok in checks
    ]
    print("\nvalidation:")
    for c in out["validation"]:
        print(f"  [{'ok' if c['ok'] else 'FAIL'}] {c['check']}")


if __name__ == "__main__":
    run()
