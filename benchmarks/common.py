"""Shared benchmark plumbing: CPU-scaled parameter grids mirroring the
paper's Tables 3/5, timing helpers, result persistence, table printing.

The paper's grids (d up to 1.6k, n up to 1.6m, |S| up to 9k) are scaled by
SCALE (default 1/100) so the full suite runs in minutes on one CPU core;
``--full`` restores paper-scale for the planning-only benchmarks (space
tables need no data pass, so they run at paper scale regardless).
"""

from __future__ import annotations

import json
import os
import time

import numpy as np

RESULT_DIR = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "experiments", "bench",
)

# paper defaults (Sec. 5.1): eps=0.01, gamma=100/n, c=3, tau=1000 (l1)/500 (l2)
TAU = {1.0: 1_000.0, 2.0: 500.0}
VALUE_RANGE = 10_000.0

# CPU-scaled grids (underlined defaults of Tables 3/5 marked by position 2)
GRID = {
    "d": [16, 24, 32, 48, 64],
    "n": [1_000, 2_000, 4_000, 8_000, 16_000],
    "c": [2, 3, 4, 5, 6],
    "n_subrange": [5, 10, 20, 50, 100],
    "n_subset": [2, 4, 6, 10, 16],
    "S": [8, 16, 24, 32, 48],
}
DEFAULT = {"d": 24, "n": 4_000, "c": 3, "n_subrange": 20, "n_subset": 6,
           "S": 24}

# paper-scale grids for planning-only tables (no data pass involved)
GRID_FULL = {
    "d": [100, 200, 400, 800, 1_600],
    "n": [100_000, 200_000, 400_000, 800_000, 1_600_000],
    "c": [2, 3, 4, 5, 6],
    "n_subrange": [5, 10, 20, 50, 100],
    "n_subset": [50, 100, 200, 500, 1_000],
    "S": [1_000, 3_000, 5_000, 7_000, 9_000],
}
DEFAULT_FULL = {"d": 400, "n": 400_000, "c": 3, "n_subrange": 20,
                "n_subset": 200, "S": 5_000}


def save(name: str, payload: dict) -> str:
    os.makedirs(RESULT_DIR, exist_ok=True)
    path = os.path.join(RESULT_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1, default=_np_default)
    return path


def _np_default(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    return str(o)


class Timer:
    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *a):
        self.seconds = time.perf_counter() - self.t0


def print_table(title: str, header: list[str], rows: list[list]):
    print(f"\n== {title} ==")
    widths = [
        max(len(str(h)), max((len(_fmt(r[i])) for r in rows), default=0))
        for i, h in enumerate(header)
    ]
    print("  ".join(str(h).ljust(w) for h, w in zip(header, widths)))
    for r in rows:
        print("  ".join(_fmt(v).ljust(w) for v, w in zip(r, widths)))


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0 or (1e-3 < abs(v) < 1e6):
            return f"{v:,.3f}".rstrip("0").rstrip(".")
        return f"{v:.3e}"
    if isinstance(v, (int, np.integer)):
        return f"{v:,}"
    return str(v)
