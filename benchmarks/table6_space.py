"""Paper Table 6: WLSH space consumption (total hash tables beta_S) as each
of {d, n, c, #Subrange, #Subset, |S|} varies, with and without bound
relaxation.  Planning-only — no data pass — so ``--full`` reproduces the
paper's exact parameter grid.

Validation targets (paper Sec. 5.2.1): beta_S grows with n, |S|, #Subset;
shrinks with c, #Subrange; bound relaxation cuts it by ~an order of
magnitude; l1 needs more tables than l2.
"""

from __future__ import annotations

import numpy as np

from repro.core.datagen import make_weight_set
from repro.core.params import PlanConfig
from repro.core.partition import partition

from .common import (DEFAULT, DEFAULT_FULL, GRID, GRID_FULL, TAU,
                     VALUE_RANGE, Timer, print_table, save)


def beta_total(p, d, n, c, n_subrange, n_subset, S, relaxed, seed=0):
    weights = make_weight_set(size=S, d=d, n_subset=n_subset,
                              n_subrange=n_subrange, seed=seed)
    cfg = PlanConfig(p=p, c=c, n=n, gamma_n=100.0)
    v = max(1, d // 4) if relaxed else 1  # paper: v = v' = d/4
    res = partition(weights, cfg, VALUE_RANGE, tau=TAU[p], v=v, v_prime=v)
    return res.beta_total, len(res.groups)


def run(full: bool = False, p_values=(1.0, 2.0)) -> dict:
    grid = GRID_FULL if full else GRID
    base = DEFAULT_FULL if full else DEFAULT
    out: dict = {"full": full, "results": {}}
    for p in p_values:
        rows = []
        for param, values in grid.items():
            for val in values:
                kw = dict(base)
                kw[param] = val
                for relaxed in (False, True):
                    with Timer() as t:
                        bt, ng = beta_total(
                            p, kw["d"], kw["n"], kw["c"], kw["n_subrange"],
                            kw["n_subset"], kw["S"], relaxed,
                        )
                    rows.append([param, val, relaxed, bt, ng,
                                 round(t.seconds, 2)])
        out["results"][f"l{int(p)}"] = rows
        print_table(
            f"Table 6 — WLSH space, l_{int(p)} distance",
            ["param", "value", "relaxed", "beta_S", "groups", "sec"],
            rows,
        )
    _validate(out)
    save("table6_space", out)
    return out


def _validate(out: dict):
    """Assert the paper's monotone trends hold on our reproduction."""
    checks = []
    for key, rows in out["results"].items():
        get = lambda param, relaxed: {  # noqa: E731
            r[1]: r[3] for r in rows if r[0] == param and r[2] == relaxed
        }
        for relaxed in (False, True):
            n_curve = get("n", relaxed)
            checks.append((f"{key} beta up with n (rel={relaxed})",
                           _mostly_increasing(list(n_curve.values()))))
            c_curve = get("c", relaxed)
            checks.append((f"{key} beta down with c (rel={relaxed})",
                           _mostly_increasing(list(c_curve.values())[::-1])))
            s_curve = get("S", relaxed)
            checks.append((f"{key} beta up with |S| (rel={relaxed})",
                           _mostly_increasing(list(s_curve.values()))))
        # relaxation wins by a wide margin at defaults
        strict = {(r[0], r[1]): r[3] for r in rows if not r[2]}
        relax = {(r[0], r[1]): r[3] for r in rows if r[2]}
        shared = set(strict) & set(relax)
        gains = [strict[k] / max(relax[k], 1) for k in shared]
        checks.append((f"{key} relaxation median gain > 1.5x",
                       float(np.median(gains)) > 1.5))
    out["validation"] = [
        {"check": name, "ok": bool(ok)} for name, ok in checks
    ]
    print("\nvalidation:")
    for c in out["validation"]:
        print(f"  [{'ok' if c['ok'] else 'FAIL'}] {c['check']}")


def _mostly_increasing(xs) -> bool:
    xs = list(xs)
    ups = sum(1 for a, b in zip(xs, xs[1:]) if b >= a * 0.98)
    return ups >= len(xs) - 2


if __name__ == "__main__":
    import sys

    run(full="--full" in sys.argv)
