"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run           # CPU-scaled suite
    PYTHONPATH=src python -m benchmarks.run --full    # paper-scale planning
    PYTHONPATH=src python -m benchmarks.run --only table6_space

Each module prints its table, asserts the paper's qualitative claims as
validation checks, and persists JSON to experiments/bench/.  The roofline
module aggregates the dry-run artifacts (run launch/dryrun.py first for a
complete table).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (fig1_query, kernel_bench, roofline, serve_bench, table6_space,
               table7_alsh_space, table8_ratio, table11_relax)

MODULES = {
    "table6_space": table6_space,
    "table7_alsh_space": table7_alsh_space,
    "table8_ratio": table8_ratio,
    "fig1_query": fig1_query,
    "table11_relax": table11_relax,
    "kernel_bench": kernel_bench,
    "serve_bench": serve_bench,
    "roofline": roofline,
}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale grids for planning-only benchmarks")
    ap.add_argument("--only", default=None, choices=list(MODULES))
    args = ap.parse_args(argv)

    names = [args.only] if args.only else list(MODULES)
    failures, validation_failures = [], []
    for name in names:
        print(f"\n{'=' * 72}\n# {name}\n{'=' * 72}", flush=True)
        t0 = time.time()
        try:
            out = MODULES[name].run(full=args.full)
            bad = [c["check"] for c in (out or {}).get("validation", [])
                   if not c["ok"]]
            validation_failures += [f"{name}: {b}" for b in bad]
        except Exception:  # noqa: BLE001 — per-benchmark isolation
            traceback.print_exc()
            failures.append(name)
        print(f"[{name} done in {time.time() - t0:.1f}s]", flush=True)

    print(f"\n{'=' * 72}\nSUMMARY")
    print(f"  benchmarks run: {len(names)}, crashed: {failures or 'none'}")
    if validation_failures:
        print("  validation failures (paper-claim checks):")
        for v in validation_failures:
            print(f"    - {v}")
    else:
        print("  all paper-claim validation checks passed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
