"""Paper Table 11 / Appendix F.1: necessity of bound relaxation.

beta_S vs beta_S^br over c in {5,7,9,11,13} with uniformly random weight
vectors: without relaxation the table count decays slowly in c and stays
huge; with relaxation it collapses once c >= 7.  Planning-only.
"""

from __future__ import annotations

import numpy as np

from repro.core.datagen import make_weight_set
from repro.core.params import PlanConfig
from repro.core.partition import partition

from .common import DEFAULT, TAU, VALUE_RANGE, print_table, save

_C = (5, 7, 9, 11, 13)


def run(full: bool = False, p_values=(1.0, 2.0)):
    d, S = DEFAULT["d"], DEFAULT["S"]
    n = 400_000  # planning-only: paper-scale n
    weights = make_weight_set(size=S, d=d, n_subset=S, n_subrange=1, seed=61)
    rows = []
    for p in p_values:
        for c in _C:
            cfg = PlanConfig(p=p, c=c, n=n, gamma_n=100.0)
            strict = partition(weights, cfg, VALUE_RANGE, tau=float("inf"),
                               v=1, v_prime=1)
            relaxed = partition(weights, cfg, VALUE_RANGE, tau=float("inf"),
                                v=max(1, d // 4), v_prime=max(1, d // 4))
            rows.append([f"l{int(p)}", c, strict.beta_total,
                         relaxed.beta_total])
    print_table("Table 11 — bound relaxation necessity",
                ["dist", "c", "beta_S", "beta_S^br"], rows)

    by_p = {}
    for dist, c, b, br in rows:
        by_p.setdefault(dist, []).append((c, b, br))
    checks = []
    for dist, series in by_p.items():
        b_last = series[-1][1]
        br_at7 = [br for c, _, br in series if c >= 7]
        checks.append((f"{dist}: strict beta still large at c=13",
                       b_last > 10 * max(br_at7[0], 1)))
        checks.append((f"{dist}: relaxed beta acceptable for c >= 7",
                       all(br <= series[0][2] for br in br_at7)))
        checks.append((f"{dist}: relaxed <= strict everywhere",
                       all(br <= b for _, b, br in series)))
    out = {"rows": rows,
           "validation": [{"check": n_, "ok": bool(ok)} for n_, ok in checks]}
    print("\nvalidation:")
    for c in out["validation"]:
        print(f"  [{'ok' if c['ok'] else 'FAIL'}] {c['check']}")
    save("table11_relax", out)
    return out


if __name__ == "__main__":
    run()
