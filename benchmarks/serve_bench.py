"""Multi-group serving throughput: queries/s vs active groups & occupancy,
plus the deadline-batching occupancy lift under open-loop traffic.

The paper's experiments measure per-query table-group work; what dominates a
real deployment is the *serving path* — routing a mixed stream across many
weight groups, batch coalescing, and compiled-step reuse.  This benchmark
pins a baseline for that path:

  sweep 1  active groups: the same total query count routed to weights
           drawn from 1, 2, ... all table groups (more groups = more
           device dispatches at fixed work per query)
  sweep 2  batch occupancy: fixed mixed traffic served at submission chunk
           sizes that leave the compiled q_batch increasingly underfilled
           (padding waste on ragged tails)
  sweep 3  deadline batching: the same open-loop Poisson arrival trace
           (each request submitted alone, the worst case of sweep 2)
           served by the async deadline-aware frontend over arrival rate x
           max_delay_ms, vs the sync single-submission baseline — batch
           occupancy bought with bounded queue wait
  sweep 4  group-state paging: the same mixed trace served with the
           StateCache capped at a shrinking resident fraction of the
           plan's groups (1.0 -> 0.25) — throughput and state hit-rate
           vs device-memory budget, answers bit-exact throughout
  sweep 5  streaming writes: the same traffic with a growing fraction of
           ops replaced by streaming inserts (write mix 0 -> 50%) at a
           fixed paging budget — query throughput and p50 latency vs
           insert rate, fresh-insert recall via the exact delta scan,
           then a full compaction absorbs the backlog with zero
           query-step recompiles
  sweep 6  predictive prefetch: the same open-loop trace stepped through
           the real-time ServiceDriver under a tight paging budget (0.5x
           resident fraction), prefetch off vs on — the pending buffers
           are a schedule, so the driver pages states in *ahead* of
           their deadline launches: state hit rate rises, deadline-miss
           rate (deadline expired while the state was off-device) falls,
           answers bit-exact throughout
  sweep 7  sharded group states: the same workload served at n_shards in
           {1, 2, 4, 8} on a forced 8-device CPU mesh (each shard count
           runs in a child process so XLA_FLAGS lands before jax
           initialises).  Row capacity pads to a common block multiple,
           so every shard count runs identical per-block gemms and the
           answers are bit-exact across shard counts — on one
           oversubscribed CPU the throughput column prices the
           collective overhead, not a speedup
  sweep 8  multi-tenant QoS under overload: a 2x-capacity open-loop
           trace split across a strict high-priority tenant (gold,
           weight 4, tight SLO) and a degradable low-priority tenant
           (bronze), stepped on a fixed virtual tick grid with the
           fair queue capped at capacity_per_tick launches — weighted
           fairness must keep gold's SLO-miss rate at ~0 and its
           answers bit-exact strict, while sustained overload steps
           bronze down the pre-compiled (c, k) relaxation ladder
           (degradation on vs off), holding bronze recall above the
           rung's planned bound with zero new compiles
  sweep 9  observability overhead: the sweep-6 driver workload (open-loop
           trace, 0.5x paging budget, prefetch on) served with the obs
           layer off vs fully on (trace spans + profiler over the
           always-on metrics registry) — answers must stay bit-exact and
           the p50 per-launch driver-step time may pay < 5% overhead
  sweep 10 online recall telemetry: the sweep-8 degradation-on overload
           trace replayed with shadow-exact recall sampling off vs on at
           rate 1.0 — every served answer is re-ranked off-path against
           the exact host oracle (driver idle ticks drain the shadow
           queue).  Sampling must not move a single served bit, the
           online micro-averaged estimate must equal an offline oracle
           recomputation on the same sample bit-for-bit, and the
           per-rung observed recall must hold at or above the rung's
           planned bound (strict rung: the configured recall floor)

Validation checks assert the structural claims future PRs must not regress:
compiled steps stay below group count (shape-bucket sharing), full batches
beat 1-query submissions on throughput, the async frontend answers the
trace bit-exactly, deadline batching lifts mean occupancy over
single-submission on every swept configuration, paging stays bit-exact
with live eviction/restore traffic below full residency, prefetch
strictly improves the hit rate and miss rate at the same budget, sharded
serving answers bit-identically at every shard count, turning the
observability layer on neither changes an answer nor costs more than 5%
of the p50 per-launch step time, and shadow-exact recall sampling is
bit-invisible while its online estimate matches the offline oracle
exactly and clears every rung's planned recall bound.

    PYTHONPATH=src python -m benchmarks.run --only serve_bench
"""

from __future__ import annotations

import dataclasses
import json
import os
import subprocess
import sys
import textwrap
import time

import numpy as np

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.serving.async_service import (
    AsyncRetrievalService,
    ManualClock,
    replay_open_loop,
)
from repro.serving.qos import DegradeStep, QosClass, QosScheduler
from repro.serving.retrieval import RetrievalService, ServiceConfig
from repro.serving.scheduler import (
    DeadlinePrefetch,
    ServiceDriver,
    replay_with_driver,
)

from .common import TAU, Timer, print_table, save

K = 5
Q_BATCH = 8


def _build_service(n, d, n_weights, n_subset, seed=0):
    data = make_dataset(n=n, d=d, seed=seed)
    weights = make_weight_set(size=n_weights, d=d, n_subset=n_subset,
                              n_subrange=10, seed=seed + 1)
    cfg = PlanConfig(p=2.0, c=3, n=n, gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=TAU[2.0], v=d // 4,
                     v_prime=d // 4, seed=seed + 2)
    plan = host.export_serving_plan()
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=Q_BATCH, use_pallas=False),
    )
    svc.warmup()
    return data, weights, plan, svc


def _traffic(data, weight_ids_pool, n_queries, rng):
    wids = rng.choice(weight_ids_pool, size=n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def _metrics_condensed(service) -> dict:
    """One-number-per-metric view of a service's registry snapshot.

    Counters and gauges collapse to the sum over their label series;
    histograms report total count and sum.  Small enough to pin a
    per-sweep snapshot into the benchmark payload without drowning it.
    """
    out = {}
    for name, entry in service.batcher.metrics.snapshot().items():
        if entry["type"] == "histogram":
            out[name] = {
                "count": int(sum(s["count"]
                                 for s in entry["series"].values())),
                "sum": float(sum(s["sum"]
                                 for s in entry["series"].values())),
            }
        else:
            total = float(sum(entry["series"].values()))
            out[name] = int(total) if total == int(total) else total
    return out


_SHARD_DEVICES = 8

# Child body for sweep 7.  Each shard count needs its own process:
# XLA_FLAGS must be set before jax initialises, and the parent keeps the
# single real CPU device.  2011 live rows pad (5 reserve rows) to
# 2016 = 32 * 63, so shards in {1, 2, 4, 8} all run (q, 63, d) block
# gemms — the structural precondition for bit-exact answers across
# shard counts (f32 matmuls are shape-sensitive).
_SHARD_CHILD = """
    import json, time
    import numpy as np
    from repro.core.datagen import make_dataset, make_weight_set
    from repro.core.params import PlanConfig
    from repro.core.wlsh import WLSHIndex
    from repro.serving.retrieval import RetrievalService, ServiceConfig

    SHARDS = %(shards)d
    data = make_dataset(n=2011, d=24, seed=7)
    weights = make_weight_set(size=16, d=24, n_subset=8, n_subrange=10,
                              seed=8)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=500.0, v=6, v_prime=6,
                     seed=9)
    plan = host.export_serving_plan()
    svc = RetrievalService(plan, data, cfg=ServiceConfig(
        k=%(k)d, q_batch=%(q_batch)d, block_n=63, delta_reserve_rows=5,
        n_shards=SHARDS, use_pallas=False))
    assert svc.mesh.size == SHARDS
    svc.warmup()
    rng = np.random.default_rng(11)
    NQ = %(nq)d
    wids = rng.integers(0, len(weights), NQ)
    qpts = data[rng.choice(len(data), NQ, replace=False)].astype(
        np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    svc.query(qpts[:%(q_batch)d], wids[:%(q_batch)d])  # warm dispatch
    svc.reset_stats()
    t0 = time.perf_counter()
    res = svc.query(qpts, wids)
    dt = time.perf_counter() - t0
    print(json.dumps({
        "shards": SHARDS,
        "qps": NQ / dt,
        "rows_per_shard": svc.batcher.row_capacity() // svc.mesh.size,
        "occupancy": float(svc.mean_occupancy()),
        "compiled_steps": svc.step_cache.n_compiled,
        "ids": res.ids.tolist(),
        "n_checked": res.n_checked.tolist(),
    }))
"""


def _shard_child(shards: int, k: int, q_batch: int, nq: int) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src"
    )
    env["XLA_FLAGS"] = (
        f"--xla_force_host_platform_device_count={_SHARD_DEVICES}"
    )
    env.setdefault("JAX_PLATFORMS", "cpu")
    code = textwrap.dedent(_SHARD_CHILD) % {
        "shards": shards, "k": k, "q_batch": q_batch, "nq": nq,
    }
    r = subprocess.run(
        [sys.executable, "-c", code],
        capture_output=True, text=True, timeout=900, env=env,
    )
    if r.returncode != 0:
        raise RuntimeError(
            f"shard child (shards={shards}) failed:\n"
            f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
        )
    return json.loads(r.stdout.strip().splitlines()[-1])


def run(full: bool = False) -> dict:
    n, d = (16_000, 32) if full else (4_096, 24)
    n_weights, n_subset = (48, 12) if full else (16, 8)
    n_queries = 192 if full else 96
    data, weights, plan, svc = _build_service(n, d, n_weights, n_subset)
    rng = np.random.default_rng(3)
    # condensed registry snapshot per sweep, from the service that ran it
    # (sweep 7 runs in child processes and has no registry to read here)
    metrics_by_sweep = {}

    # ---- sweep 1: throughput vs number of active groups ---------------------
    rows_groups = []
    group_members = [g.member_ids for g in plan.groups]
    for n_active in range(1, plan.n_groups + 1):
        pool = np.concatenate(group_members[:n_active])
        qpts, wids = _traffic(data, pool, n_queries, rng)
        svc.query(qpts[:Q_BATCH], wids[:Q_BATCH])  # warm dispatch path
        svc.reset_stats()
        with Timer() as t:
            svc.query(qpts, wids)
        occ = svc.mean_occupancy()
        rows_groups.append([
            n_active, n_queries, n_queries / t.seconds, float(occ),
            svc.step_cache.n_compiled,
        ])
    print_table(
        "serving throughput vs active groups",
        ["groups", "queries", "q/s", "occupancy", "compiled steps"],
        rows_groups,
    )
    metrics_by_sweep["1_active_groups"] = _metrics_condensed(svc)

    # ---- sweep 2: throughput vs batch occupancy -----------------------------
    rows_occ = []
    pool = np.arange(n_weights)
    qpts, wids = _traffic(data, pool, n_queries, rng)
    for chunk in (1, 2, 4, Q_BATCH, n_queries):
        svc.reset_stats()
        with Timer() as t:
            for lo in range(0, n_queries, chunk):
                svc.query(qpts[lo : lo + chunk], wids[lo : lo + chunk])
        occ = svc.mean_occupancy()
        rows_occ.append(
            [chunk, n_queries, n_queries / t.seconds, float(occ)]
        )
    print_table(
        "serving throughput vs submission chunk (batch occupancy)",
        ["chunk", "queries", "q/s", "occupancy"],
        rows_occ,
    )
    metrics_by_sweep["2_occupancy"] = _metrics_condensed(svc)

    # ---- sweep 3: deadline batching vs sync single-submission ---------------
    # one fixed open-loop trace per arrival rate; the sync baseline submits
    # each request alone as it arrives (occupancy 1/q_batch by construction)
    qpts, wids = _traffic(data, pool, n_queries, rng)
    sync_res = svc.query(qpts, wids)
    svc.reset_stats()
    with Timer() as t:
        for qi in range(n_queries):
            svc.query(qpts[qi : qi + 1], wids[qi : qi + 1])
    occ_sync = svc.mean_occupancy()
    qps_sync_single = n_queries / t.seconds
    rows_async = []
    async_exact = True
    for rate in (500.0, 2_000.0, 8_000.0):
        trng = np.random.default_rng(int(rate))
        arrivals = np.cumsum(trng.exponential(1.0 / rate, n_queries))
        for delay_ms in (0.5, 2.0, 10.0):
            asvc = AsyncRetrievalService(svc, max_delay_ms=delay_ms,
                                         clock=ManualClock())
            svc.reset_stats()
            with Timer() as t:
                res, waits = replay_open_loop(asvc, qpts, wids, arrivals)
            async_exact &= bool(
                np.array_equal(res.ids, sync_res.ids)
                and np.array_equal(res.stop_levels, sync_res.stop_levels)
                and np.array_equal(res.n_checked, sync_res.n_checked)
            )
            occ = svc.mean_occupancy()
            rows_async.append([
                rate, delay_ms, occ, occ_sync,
                float(1e3 * waits.mean()),
                float(1e3 * np.percentile(waits, 95)),
                asvc.n_launched_full, asvc.n_launched_deadline,
                n_queries / t.seconds,
            ])
    print_table(
        "async deadline batching vs single-submission "
        f"(sync baseline occupancy {occ_sync:.3f} at {qps_sync_single:.1f} "
        "q/s)",
        ["rate q/s", "deadline ms", "occupancy", "occ sync", "wait ms",
         "p95 wait ms", "full", "deadline", "q/s"],
        rows_async,
    )
    metrics_by_sweep["3_deadline_batching"] = _metrics_condensed(svc)

    # ---- sweep 4: group-state paging under a device-memory budget -----------
    # same mixed trace, submitted in q_batch chunks so group launches
    # interleave (the access pattern that actually exercises LRU paging),
    # with the StateCache capped at a shrinking fraction of the groups
    qpts, wids = _traffic(data, pool, n_queries, rng)
    ref_res = svc.query(qpts, wids)
    rows_paging = []
    paging_exact = True
    for frac in (1.0, 0.75, 0.5, 0.25):
        cap = max(1, int(np.ceil(frac * plan.n_groups)))
        psvc = RetrievalService(
            plan, data,
            cfg=ServiceConfig(k=K, q_batch=Q_BATCH, use_pallas=False,
                              max_resident_groups=cap),
        )
        psvc.warmup()  # builds every state once; excess groups host-offload
        psvc.reset_stats()
        outs = []
        with Timer() as t:
            for lo in range(0, n_queries, Q_BATCH):
                outs.append(
                    psvc.query(qpts[lo : lo + Q_BATCH],
                               wids[lo : lo + Q_BATCH]).ids
                )
        cs = psvc.state_cache.stats
        paging_exact &= bool(
            np.array_equal(np.concatenate(outs), ref_res.ids)
        )
        rows_paging.append([
            frac, cap, plan.n_groups, n_queries / t.seconds,
            float(cs.hit_rate), cs.n_evictions, cs.n_restores, cs.n_builds,
            psvc.state_cache.resident_bytes,
        ])
    print_table(
        "group-state paging vs resident fraction "
        f"({'bit-exact' if paging_exact else 'MISMATCH'} vs full residency)",
        ["resident frac", "cap", "groups", "q/s", "hit rate", "evictions",
         "restores", "rebuilds", "resident bytes"],
        rows_paging,
    )
    metrics_by_sweep["4_paging"] = _metrics_condensed(psvc)

    # ---- sweep 5: streaming — query throughput / p50 latency vs write mix ---
    # mixed op stream at a fixed paging budget (cap = half the groups);
    # queries go out in stream-order chunks of up to Q_BATCH, inserts land
    # in the delta memtables (seals allowed, compaction deferred so the
    # mid-stream read path is delta-scan + merge); after the stream a full
    # compaction absorbs the backlog and the insert recall is re-checked
    # through the compiled index path
    rows_stream = []
    stream_exact = True
    stream_recall = True
    stream_no_recompile = True
    cap5 = max(1, plan.n_groups // 2)
    qpts, wids = _traffic(data, pool, n_queries, rng)
    base_ref = svc.query(qpts, wids)  # static reference answers
    for mix in (0.0, 0.1, 0.25, 0.5):
        srng = np.random.default_rng(int(mix * 100) + 17)
        ssvc = RetrievalService(
            plan, data,
            cfg=ServiceConfig(k=K, q_batch=Q_BATCH, use_pallas=False,
                              max_resident_groups=cap5,
                              delta_seal_rows=16,
                              delta_reserve_rows=n_queries),
        )
        ssvc.warmup()
        ssvc.reset_stats()
        n_compiled0 = ssvc.step_cache.n_compiled
        is_ins = srng.random(n_queries) < mix
        ins_vecs = qpts + np.float32(60_000.0) + np.float32(7.0) * (
            np.arange(n_queries, dtype=np.float32)[:, None]
        )
        inserted = []
        got_ids = {}
        lat_s = []
        with Timer() as t:
            i = 0
            while i < n_queries:
                if is_ins[i]:
                    pid = ssvc.insert(ins_vecs[i], int(wids[i]))
                    inserted.append((pid, i))
                    i += 1
                    continue
                lo = i  # stream-order chunk of consecutive reads
                while (i < n_queries and not is_ins[i]
                       and i - lo < Q_BATCH):
                    i += 1
                with Timer() as tq:
                    r = ssvc.query(qpts[lo:i], wids[lo:i])
                lat_s.extend([tq.seconds / (i - lo)] * (i - lo))
                for row, qi in enumerate(range(lo, i)):
                    got_ids[qi] = r.ids[row]
        n_reads = len(lat_s)
        # mid-stream reads bit-exact vs the static reference (inserts are
        # far offsets, so base top-k answers must be untouched)
        for qi, ids in got_ids.items():
            stream_exact &= bool(np.array_equal(ids, base_ref.ids[qi]))
        # fresh-insert recall through the exact delta scan
        for pid, qi in inserted:
            r = ssvc.query(ins_vecs[qi][None], [int(wids[qi])])
            stream_recall &= int(r.ids[0][0]) == pid
        absorbed = ssvc.compact()
        # ... and through the compiled index path after compaction
        for pid, qi in inserted:
            r = ssvc.query(ins_vecs[qi][None], [int(wids[qi])])
            stream_recall &= int(r.ids[0][0]) == pid
        stream_no_recompile &= (
            ssvc.step_cache.n_compiled == n_compiled0
        )
        d = ssvc.delta_summary() or dict(n_seals=0, n_compactions=0)
        rows_stream.append([
            mix, n_reads, len(inserted),
            (n_reads / t.seconds) if n_reads else 0.0,
            1e3 * float(np.percentile(lat_s, 50)) if lat_s else 0.0,
            d["n_seals"], d["n_compactions"], absorbed,
        ])
    print_table(
        "streaming writes: query throughput / p50 latency vs write mix "
        f"(paging cap {cap5}/{plan.n_groups} groups)",
        ["write mix", "reads", "inserts", "read q/s", "p50 read ms",
         "seals", "compactions", "rows compacted"],
        rows_stream,
    )
    metrics_by_sweep["5_streaming"] = _metrics_condensed(ssvc)

    # ---- sweep 6: predictive prefetch under a tight paging budget -----------
    # the same open-loop trace stepped through the real-time ServiceDriver
    # at a 0.5x resident-fraction budget, prefetch off vs on; the driver
    # counts a deadline miss whenever a group's oldest deadline expires
    # while its state is off-device (the restore would serialize into the
    # launch's critical path) — prefetch exists to drive that to zero
    cap6 = max(1, int(np.ceil(0.5 * plan.n_groups)))
    qpts, wids = _traffic(data, pool, n_queries, rng)
    sched_ref = svc.query(qpts, wids)
    srng = np.random.default_rng(29)
    arrivals6 = np.cumsum(srng.exponential(1.0 / 2_000.0, n_queries))
    rows_sched = []
    sched_exact = True
    sched_stats = {}
    for label, policy in (("off", None), ("on", DeadlinePrefetch())):
        dsvc = RetrievalService(
            plan, data,
            cfg=ServiceConfig(k=K, q_batch=Q_BATCH, use_pallas=False,
                              max_resident_groups=cap6),
        )
        dsvc.warmup()
        dsvc.reset_stats()
        asvc = AsyncRetrievalService(dsvc, max_delay_ms=2.0,
                                     clock=ManualClock())
        driver = ServiceDriver(asvc, prefetch=policy)
        with Timer() as t:
            res, _ = replay_with_driver(driver, qpts, wids, arrivals6)
        sched_exact &= bool(
            np.array_equal(res.ids, sched_ref.ids)
            and np.array_equal(res.stop_levels, sched_ref.stop_levels)
            and np.array_equal(res.n_checked, sched_ref.n_checked)
        )
        cs = dsvc.state_cache.stats
        ds = driver.stats
        sched_stats[label] = (float(cs.hit_rate),
                              float(ds.deadline_miss_rate))
        rows_sched.append([
            label, cap6, plan.n_groups, float(cs.hit_rate),
            float(ds.deadline_miss_rate), ds.n_deadlines_due,
            cs.n_prefetches, cs.n_restore_overlapped, cs.n_prefetch_wasted,
            cs.n_evictions, cs.n_restores, n_queries / t.seconds,
        ])
    print_table(
        "predictive prefetch under a tight paging budget "
        f"(cap {cap6}/{plan.n_groups} groups, "
        f"{'bit-exact' if sched_exact else 'MISMATCH'} vs sync reference)",
        ["prefetch", "cap", "groups", "hit rate", "miss rate", "deadlines",
         "prefetches", "overlapped", "wasted", "evictions", "restores",
         "q/s"],
        rows_sched,
    )
    metrics_by_sweep["6_prefetch"] = _metrics_condensed(dsvc)

    # ---- sweep 7: sharded group states on a forced 8-device CPU mesh --------
    # fixed-size workload regardless of --full: each shard count pays a
    # fresh child-process jax init, and the claim being pinned is
    # bit-exactness + the collective-overhead trend, not absolute q/s
    rows_shard = []
    shard_exact = True
    shard_base = None
    for shards in (1, 2, 4, 8):
        out = _shard_child(shards, k=K, q_batch=Q_BATCH, nq=n_queries)
        if shard_base is None:
            shard_base = out
        shard_exact &= bool(
            out["ids"] == shard_base["ids"]
            and out["n_checked"] == shard_base["n_checked"]
        )
        rows_shard.append([
            shards, out["rows_per_shard"], out["qps"],
            out["occupancy"], out["compiled_steps"],
        ])
    print_table(
        "sharded serving vs shard count "
        f"({_SHARD_DEVICES}-device forced CPU mesh, "
        f"{'bit-exact' if shard_exact else 'MISMATCH'} across counts)",
        ["shards", "rows/shard", "q/s", "occupancy", "compiled steps"],
        rows_shard,
    )

    # ---- sweep 8: multi-tenant QoS under 2x-capacity overload ---------------
    # fixed virtual tick grid (a wall-clock driver's cadence): the fair
    # queue may spend capacity_per_tick launch-cost units per tick, so
    # the service ceiling is q_batch * capacity / tick_s queries/s and
    # the trace arrives at 2x that.  Gold (weight 4, strict) is sized
    # within its fair share; bronze (weight 1, degradable) supplies the
    # overload.  Every launch flows through the weighted-fair queue
    # (submit defers full buffers to the tick under QoS), so deferral
    # pressure is sustained and the hysteresis steps bronze down the
    # pre-compiled ladder.
    ladder8 = (DegradeStep(c=4, k=3, cost=0.5, recall_bound=0.3),)
    tick8, cap8 = 0.005, 2.0
    rate8 = 2.0 * Q_BATCH * cap8 / tick8  # 2x the tick-capacity ceiling
    n8 = 4 * n_queries
    qrng = np.random.default_rng(37)
    qpts8, wids8 = _traffic(data, pool, n8, qrng)
    ref8 = svc.query(qpts8, wids8)  # strict oracle answers
    arr8 = np.cumsum(qrng.exponential(1.0 / rate8, n8))
    ten8 = [str(t) for t in
            qrng.choice(["gold", "bronze"], n8, p=[0.25, 0.75])]
    rows_qos = []
    qos_results = {}
    for label, degradable in (("off", False), ("on", True)):
        qsvc = RetrievalService(plan, data, cfg=ServiceConfig(
            k=K, q_batch=Q_BATCH, use_pallas=False,
            degrade_ladder=ladder8))
        qsvc.warmup()  # compiles every rung's step ahead of traffic
        qsvc.reset_stats()
        n_compiled8 = qsvc.step_cache.n_compiled
        qos = QosScheduler(
            classes=[QosClass("gold", weight=4.0, slo_ms=25.0),
                     QosClass("bronze", weight=1.0, slo_ms=60.0,
                              degradable=degradable)],
            ladder=ladder8, capacity_per_tick=cap8,
            degrade_after=3, restore_after=3,
        )
        asvc = AsyncRetrievalService(qsvc, clock=ManualClock(), qos=qos)
        driver = ServiceDriver(asvc, prefetch=None)
        futs = [None] * n8
        i8, t8 = 0, 0.0
        with Timer() as t:
            while i8 < n8 or asvc.pending_count:
                while i8 < n8 and arr8[i8] <= t8:
                    asvc.clock.advance_to(arr8[i8])
                    futs[i8] = asvc.submit(qpts8[i8], wids8[i8],
                                           tenant=ten8[i8])
                    i8 += 1
                asvc.clock.advance_to(t8)
                driver.step()
                # next tick: the grid cadence, pulled earlier when a
                # pending deadline falls inside the interval — a punctual
                # launch then fires exactly at its deadline (as the
                # event-driven replays do) instead of being counted
                # missed by up to one tick of grid quantization.  Under
                # backlog, deferred deadlines are already past, so
                # draining still happens at the capacity-per-grid-tick
                # rate.
                nxt = t8 + tick8
                nd = asvc.next_deadline()
                if nd is not None and t8 < nd < nxt:
                    nxt = nd
                t8 = nxt
                assert driver.stats.n_ticks < 100_000, "sweep 8 stalled"
        recall8 = {"gold": [], "bronze": []}
        exact8 = {"gold": True, "bronze": True}
        for qi in range(n8):
            ids = futs[qi].result().ids
            want = ref8.ids[qi]
            valid = set(int(x) for x in want if x >= 0)
            got = set(int(x) for x in ids if x >= 0)
            recall8[ten8[qi]].append(
                len(got & valid) / max(1, len(valid))
            )
            exact8[ten8[qi]] &= bool(np.array_equal(ids, want))
        s8 = qos.summary()
        qos_results[label] = dict(
            summary=s8, exact=exact8,
            recall={k: float(np.mean(v)) for k, v in recall8.items()},
            new_compiles=qsvc.step_cache.n_compiled - n_compiled8,
        )
        rows_qos.append([
            label,
            s8["tenants"]["gold"]["slo_miss_rate"],
            s8["tenants"]["bronze"]["slo_miss_rate"],
            qos_results[label]["recall"]["gold"],
            qos_results[label]["recall"]["bronze"],
            s8["tenants"]["bronze"]["n_degraded"],
            s8["n_degrade_steps"],
            1e3 * s8["tenants"]["gold"]["mean_wait_s"],
            1e3 * s8["tenants"]["bronze"]["mean_wait_s"],
            qos_results[label]["new_compiles"],
        ])
    print_table(
        "multi-tenant QoS at 2x-capacity overload, degradation off vs on "
        f"(gold strict weight 4, bronze degradable weight 1; ladder "
        f"c={ladder8[0].c} k={ladder8[0].k} cost={ladder8[0].cost})",
        ["degrade", "gold miss", "bronze miss", "gold recall",
         "bronze recall", "n degraded", "ladder steps", "gold wait ms",
         "bronze wait ms", "new compiles"],
        rows_qos,
    )
    metrics_by_sweep["8_qos"] = _metrics_condensed(qsvc)

    # ---- sweep 9: observability overhead at the sweep-6 settings ------------
    # the sweep-6 driver workload (same trace, same 0.5x paging budget,
    # prefetch on) with the obs layer off vs fully on: per-query trace
    # spans + per-signature profiler attribution over the always-on
    # metrics registry.  Each driver.step() is wall-timed; the p50 is
    # taken over the steps that launched a batch (arrival-only steps do
    # no compiled work) and the reported step time is the median over
    # OBS_REPS fresh-service runs per setting.  Spans mark stages on the
    # virtual ManualClock but the *recording* cost lands on the wall
    # steps being timed, which is exactly the overhead being priced.
    OBS_REPS = 3

    def _obs_run(obs_on: bool) -> dict:
        osvc = RetrievalService(
            plan, data,
            cfg=ServiceConfig(k=K, q_batch=Q_BATCH, use_pallas=False,
                              max_resident_groups=cap6, obs=obs_on),
        )
        osvc.warmup()
        osvc.reset_stats()
        oasvc = AsyncRetrievalService(osvc, max_delay_ms=2.0,
                                      clock=ManualClock())
        odriver = ServiceDriver(oasvc, prefetch=DeadlinePrefetch())
        launch_times = []
        seen = [0]
        real_step = odriver.step

        def timed_step():
            t0 = time.perf_counter()
            out = real_step()
            dt = time.perf_counter() - t0
            n = odriver.stats.n_launches
            if n > seen[0]:
                launch_times.append(dt)
                seen[0] = n
            return out

        odriver.step = timed_step
        res, _ = replay_with_driver(odriver, qpts, wids, arrivals6)
        tr = osvc.batcher.tracer
        return {
            "res": res,
            "p50_step_s": float(np.percentile(launch_times, 50)),
            "n_launches": odriver.stats.n_launches,
            "spans": (None if tr is None
                      else (tr.n_started, tr.n_finished)),
            "svc": osvc,
        }

    obs_runs = {"off": [], "on": []}
    for _rep in range(OBS_REPS):
        for label in ("off", "on"):
            obs_runs[label].append(_obs_run(label == "on"))
    obs_exact = all(
        bool(np.array_equal(r_on["res"].ids, r_off["res"].ids)
             and np.array_equal(r_on["res"].stop_levels,
                                r_off["res"].stop_levels)
             and np.array_equal(r_on["res"].n_checked,
                                r_off["res"].n_checked))
        for r_off, r_on in zip(obs_runs["off"], obs_runs["on"])
    ) and bool(
        np.array_equal(obs_runs["off"][0]["res"].ids, sched_ref.ids)
    )
    obs_spans_exact = all(
        r["spans"] == (n_queries, n_queries) for r in obs_runs["on"]
    )
    obs_p50 = {
        label: float(np.median([r["p50_step_s"] for r in runs]))
        for label, runs in obs_runs.items()
    }
    obs_overhead = obs_p50["on"] / obs_p50["off"] - 1.0
    rows_obs = [
        [label, cap6, obs_runs[label][0]["n_launches"],
         1e3 * obs_p50[label],
         (0.0 if label == "off" else obs_overhead)]
        for label in ("off", "on")
    ]
    print_table(
        "observability overhead at the sweep-6 settings "
        f"({'bit-exact' if obs_exact else 'MISMATCH'}, p50 per-launch "
        f"step time over median of {OBS_REPS} runs)",
        ["obs", "cap", "launches", "p50 step ms", "overhead"],
        rows_obs,
    )
    metrics_by_sweep["9_obs_overhead"] = _metrics_condensed(
        obs_runs["on"][-1]["svc"]
    )

    # ---- sweep 10: online recall telemetry on the sweep-8 overload trace ----
    # the degradation-on QoS replay rerun with shadow-exact recall
    # sampling off vs on at rate 1.0: a deterministic hash of the query
    # id picks the sample (here: everything), served answers are queued
    # as host-copy shadow jobs, and the driver's idle ticks re-rank them
    # against the exact oracle off the serving path.  recall_floor pins
    # the strict rung's bound; the degraded rung carries the ladder's
    # planned recall_bound.
    RECALL_FLOOR = 0.5

    def _recall_replay(sample_rate: float):
        rsvc = RetrievalService(plan, data, cfg=ServiceConfig(
            k=K, q_batch=Q_BATCH, use_pallas=False,
            degrade_ladder=ladder8,
            recall_sample_rate=sample_rate,
            recall_floor=RECALL_FLOOR))
        rsvc.warmup()
        rsvc.reset_stats()
        qos = QosScheduler(
            classes=[QosClass("gold", weight=4.0, slo_ms=25.0),
                     QosClass("bronze", weight=1.0, slo_ms=60.0,
                              degradable=True)],
            ladder=ladder8, capacity_per_tick=cap8,
            degrade_after=3, restore_after=3,
        )
        asvc = AsyncRetrievalService(rsvc, clock=ManualClock(), qos=qos)
        driver = ServiceDriver(asvc, prefetch=None)
        futs = [None] * n8
        i10, t10 = 0, 0.0
        while i10 < n8 or asvc.pending_count:
            while i10 < n8 and arr8[i10] <= t10:
                asvc.clock.advance_to(arr8[i10])
                futs[i10] = asvc.submit(qpts8[i10], wids8[i10],
                                        tenant=ten8[i10])
                i10 += 1
            asvc.clock.advance_to(t10)
            driver.step()
            nxt = t10 + tick8
            nd = asvc.next_deadline()
            if nd is not None and t10 < nd < nxt:
                nxt = nd
            t10 = nxt
            assert driver.stats.n_ticks < 100_000, "sweep 10 stalled"
        return rsvc, futs

    ref_svc, ref_futs = _recall_replay(0.0)
    rec_svc, rec_futs = _recall_replay(1.0)
    est = rec_svc.batcher.recall
    n_drained_idle = est.summary()["n_executed"]  # driver idle ticks
    est.drain()
    recall_exact = all(
        bool(np.array_equal(rec_futs[qi].result().ids,
                            ref_futs[qi].result().ids)
             and rec_futs[qi].result().n_checked
             == ref_futs[qi].result().n_checked)
        for qi in range(n8)
    )
    # offline oracle recomputation on the same sample: the estimator's
    # own exact scan per query, folded with the same integer counts
    off_hits = off_rel = 0
    for qi in range(n8):
        r = rec_futs[qi].result()
        exact = est.oracle_topk(qpts8[qi], int(wids8[qi]),
                                int(r.group_id))
        exact_set = {int(i) for i in exact if i >= 0}
        served_set = {int(i) for i in np.asarray(r.ids).reshape(-1)
                      if i >= 0}
        off_hits += len(served_set & exact_set)
        off_rel += len(exact_set)
    online_est = est.estimate()
    offline_est = off_hits / off_rel if off_rel else float("nan")
    rsum = est.summary()
    rows_recall = [
        [rung, rsum["observed"][rung], rsum["bound"][rung],
         rsum["observed"][rung] - rsum["bound"][rung]]
        for rung in sorted(rsum["observed"], key=int)
    ]
    print_table(
        "online recall telemetry on the sweep-8 overload trace "
        f"({'bit-exact' if recall_exact else 'MISMATCH'} vs sampling "
        f"off; {rsum['n_executed']} shadow checks, {n_drained_idle} "
        f"drained on idle ticks; online {online_est:.4f} vs offline "
        f"{offline_est:.4f})",
        ["rung", "observed recall", "planned bound", "margin"],
        rows_recall,
    )
    metrics_by_sweep["10_recall"] = _metrics_condensed(rec_svc)

    qps_full = rows_occ[-1][2]
    qps_single = rows_occ[0][2]
    occ_async_min = min(r[2] for r in rows_async)
    occ_async_max = max(r[2] for r in rows_async)
    validation = [
        {
            "check": "compiled steps < table groups (shape-bucket sharing)",
            "ok": bool(svc.step_cache.n_compiled < plan.n_groups),
        },
        {
            "check": "full-batch submission beats 1-query submission",
            "ok": bool(qps_full > qps_single),
        },
        {
            "check": "mean occupancy > 0.45 when traffic arrives in one batch",
            "ok": bool(rows_occ[-1][3] > 0.45),
        },
        {
            "check": "async frontend bit-exact with sync on the same trace",
            "ok": async_exact,
        },
        {
            "check": "deadline batching lifts occupancy over "
                     "single-submission on every (rate, deadline)",
            "ok": bool(occ_async_min > occ_sync),
        },
        {
            "check": "occupancy at the largest rate x deadline >= 2x "
                     "single-submission",
            "ok": bool(occ_async_max >= 2 * occ_sync),
        },
        {
            "check": "paging bit-exact vs full residency at every "
                     "resident fraction",
            "ok": paging_exact,
        },
        {
            "check": "full residency serves with hit rate 1.0 after warmup",
            "ok": bool(rows_paging[0][4] == 1.0),
        },
        {
            "check": "capped residency pages live (evictions and restores "
                     "> 0 at the smallest fraction)",
            "ok": bool(rows_paging[-1][5] > 0 and rows_paging[-1][6] > 0),
        },
        {
            "check": "state hit rate decreases as the resident fraction "
                     "shrinks",
            "ok": bool(rows_paging[-1][4] < rows_paging[0][4]),
        },
        {
            "check": "mixed-stream reads bit-exact with the static "
                     "reference at every write mix",
            "ok": stream_exact,
        },
        {
            "check": "fresh inserts recalled exactly, pre- and "
                     "post-compaction, at every write mix",
            "ok": stream_recall,
        },
        {
            "check": "streaming (seal + compact) never recompiles a "
                     "query step",
            "ok": stream_no_recompile,
        },
        {
            "check": "the 50% write mix seals and compacts a real backlog",
            "ok": bool(rows_stream[-1][5] > 0 and rows_stream[-1][7] > 0),
        },
        {
            "check": "driver-stepped replay bit-exact with the sync "
                     "reference, prefetch on and off, at the 0.5x budget",
            "ok": sched_exact,
        },
        {
            "check": "prefetch strictly lifts the state hit rate at the "
                     "same paging budget",
            "ok": bool(sched_stats["on"][0] > sched_stats["off"][0]),
        },
        {
            "check": "prefetch strictly lowers the deadline-miss rate "
                     "(and prefetch-off actually misses)",
            "ok": bool(sched_stats["off"][1] > sched_stats["on"][1]),
        },
        {
            "check": "prefetch-on serves every deadline with its state "
                     "already on device (miss rate 0)",
            "ok": bool(sched_stats["on"][1] == 0.0),
        },
        {
            "check": "sharded answers (ids, n_checked) bit-exact across "
                     "shard counts {1, 2, 4, 8} on the forced 8-device "
                     "mesh",
            "ok": shard_exact,
        },
        {
            "check": "each shard holds exactly capacity / n_shards rows "
                     "(strict placement, no replication)",
            "ok": bool(all(
                r[0] * r[1] == rows_shard[0][1] for r in rows_shard
            )),
        },
        {
            "check": "qos: weighted fairness keeps the strict gold "
                     "tenant's SLO-miss rate ~0 under 2x-capacity "
                     "overload (degradation on)",
            "ok": bool(
                qos_results["on"]["summary"]["tenants"]["gold"]
                ["slo_miss_rate"] <= 0.02
            ),
        },
        {
            "check": "qos: gold answers stay bit-exact strict under "
                     "overload (a degraded step never touches a "
                     "non-degradable tenant)",
            "ok": bool(qos_results["on"]["exact"]["gold"]),
        },
        {
            "check": "qos: sustained overload steps bronze down the "
                     "ladder (degrade transitions and degraded answers "
                     "> 0)",
            "ok": bool(
                qos_results["on"]["summary"]["n_degrade_steps"] > 0
                and qos_results["on"]["summary"]["tenants"]["bronze"]
                ["n_degraded"] > 0
            ),
        },
        {
            "check": "qos: degraded bronze recall stays above the "
                     "rung's planned relaxation bound",
            "ok": bool(
                qos_results["on"]["recall"]["bronze"]
                >= ladder8[0].recall_bound
            ),
        },
        {
            "check": "qos: degradation relieves bronze (mean wait "
                     "strictly below the degradation-off run)",
            "ok": bool(
                qos_results["on"]["summary"]["tenants"]["bronze"]
                ["mean_wait_s"]
                < qos_results["off"]["summary"]["tenants"]["bronze"]
                ["mean_wait_s"]
            ),
        },
        {
            "check": "qos: degraded steps compile nothing new (rungs "
                     "pre-compiled at warmup), on and off",
            "ok": bool(
                qos_results["on"]["new_compiles"] == 0
                and qos_results["off"]["new_compiles"] == 0
            ),
        },
        {
            "check": "obs: tracing + profiling on is bit-exact (ids, "
                     "stop levels, n_checked) vs obs off on the sweep-6 "
                     "workload",
            "ok": obs_exact,
        },
        {
            "check": "obs: every submitted query yields exactly one "
                     "finished trace span on every obs-on run",
            "ok": obs_spans_exact,
        },
        {
            "check": "obs: p50 per-launch step-time overhead below 5% "
                     "with the full obs layer on",
            "ok": bool(obs_overhead < 0.05),
        },
        {
            "check": "recall: shadow sampling at rate 1.0 is bit-exact "
                     "(ids, n_checked) vs sampling off on the overload "
                     "trace",
            "ok": recall_exact,
        },
        {
            "check": "recall: the online micro-averaged estimate equals "
                     "the offline oracle recomputation bit-for-bit",
            "ok": bool(online_est == offline_est),
        },
        {
            "check": "recall: every sampled query was shadow-checked "
                     "(no drops, full coverage at rate 1.0)",
            "ok": bool(rsum["n_executed"] == n8
                       and rsum["n_dropped"] == 0),
        },
        {
            "check": "recall: the driver's idle ticks drained shadow "
                     "work off-path during the replay",
            "ok": bool(n_drained_idle > 0),
        },
        {
            "check": "recall: per-rung observed recall holds at or "
                     "above the rung's planned bound",
            "ok": bool(all(r[1] >= r[2] for r in rows_recall)),
        },
    ]
    for v in validation:
        print(("PASS " if v["ok"] else "FAIL ") + v["check"])

    payload = {
        "n": n, "d": d, "n_weights": n_weights,
        "n_groups": plan.n_groups,
        "n_compiled_steps": svc.step_cache.n_compiled,
        "groups_sweep": rows_groups,
        "occupancy_sweep": rows_occ,
        "async_sweep": rows_async,
        "async_sweep_columns": [
            "arrival_rate_qps", "max_delay_ms", "occupancy",
            "occupancy_sync_single", "mean_wait_ms", "p95_wait_ms",
            "n_launched_full", "n_launched_deadline", "qps_compute",
        ],
        "occupancy_sync_single": occ_sync,
        "qps_sync_single": qps_sync_single,
        "paging_sweep": rows_paging,
        "paging_sweep_columns": [
            "resident_fraction", "max_resident_groups", "n_groups",
            "qps", "state_hit_rate", "n_evictions", "n_restores",
            "n_rebuilds", "resident_bytes",
        ],
        "streaming_sweep": rows_stream,
        "streaming_sweep_columns": [
            "write_mix", "n_reads", "n_inserts", "read_qps",
            "p50_read_latency_ms", "n_seals", "n_compactions",
            "n_rows_compacted",
        ],
        "streaming_paging_cap": cap5,
        "scheduler_sweep": rows_sched,
        "scheduler_sweep_columns": [
            "prefetch", "max_resident_groups", "n_groups",
            "state_hit_rate", "deadline_miss_rate", "n_deadlines_due",
            "n_prefetches", "n_restore_overlapped", "n_prefetch_wasted",
            "n_evictions", "n_restores", "qps",
        ],
        "scheduler_paging_cap": cap6,
        "sharding_sweep": rows_shard,
        "sharding_sweep_columns": [
            "n_shards", "rows_per_shard", "qps", "occupancy",
            "n_compiled_steps",
        ],
        "sharding_forced_devices": _SHARD_DEVICES,
        "qos_sweep": rows_qos,
        "qos_sweep_columns": [
            "degradation", "gold_slo_miss_rate", "bronze_slo_miss_rate",
            "gold_recall", "bronze_recall", "bronze_n_degraded",
            "n_degrade_steps", "gold_mean_wait_ms", "bronze_mean_wait_ms",
            "n_new_compiles",
        ],
        "qos_ladder": [dataclasses.asdict(s) for s in ladder8],
        "qos_capacity_per_tick": cap8,
        "qos_tick_s": tick8,
        "qos_overload_rate_qps": rate8,
        "obs_sweep": rows_obs,
        "obs_sweep_columns": [
            "obs", "max_resident_groups", "n_launches",
            "p50_launch_step_ms", "p50_overhead_fraction",
        ],
        "obs_overhead_fraction": float(obs_overhead),
        "obs_reps": OBS_REPS,
        "recall_sweep": rows_recall,
        "recall_sweep_columns": [
            "rung", "observed_recall", "planned_bound", "margin",
        ],
        "recall_online_estimate": float(online_est),
        "recall_offline_estimate": float(offline_est),
        "recall_n_shadow_checks": int(rsum["n_executed"]),
        "recall_n_drained_idle": int(n_drained_idle),
        "recall_floor": RECALL_FLOOR,
        "metrics_by_sweep": metrics_by_sweep,
        "validation": validation,
    }
    save("serve_bench", payload)
    return payload


if __name__ == "__main__":
    run()
