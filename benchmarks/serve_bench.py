"""Multi-group serving throughput: queries/s vs active groups & occupancy.

The paper's experiments measure per-query table-group work; what dominates a
real deployment is the *serving path* — routing a mixed stream across many
weight groups, batch coalescing, and compiled-step reuse.  This benchmark
pins a baseline for that path:

  sweep 1  active groups: the same total query count routed to weights
           drawn from 1, 2, ... all table groups (more groups = more
           device dispatches at fixed work per query)
  sweep 2  batch occupancy: fixed mixed traffic served at submission chunk
           sizes that leave the compiled q_batch increasingly underfilled
           (padding waste on ragged tails)

Validation checks assert the structural claims future PRs must not regress:
compiled steps stay below group count (shape-bucket sharing), and full
batches beat 1-query submissions on throughput.

    PYTHONPATH=src python -m benchmarks.run --only serve_bench
"""

from __future__ import annotations

import numpy as np

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.serving.retrieval import RetrievalService, ServiceConfig

from .common import TAU, Timer, print_table, save

K = 5
Q_BATCH = 8


def _build_service(n, d, n_weights, n_subset, seed=0):
    data = make_dataset(n=n, d=d, seed=seed)
    weights = make_weight_set(size=n_weights, d=d, n_subset=n_subset,
                              n_subrange=10, seed=seed + 1)
    cfg = PlanConfig(p=2.0, c=3, n=n, gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=TAU[2.0], v=d // 4,
                     v_prime=d // 4, seed=seed + 2)
    plan = host.export_serving_plan()
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=Q_BATCH, use_pallas=False),
    )
    svc.warmup()
    return data, weights, plan, svc


def _traffic(data, weight_ids_pool, n_queries, rng):
    wids = rng.choice(weight_ids_pool, size=n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def run(full: bool = False) -> dict:
    n, d = (16_000, 32) if full else (4_096, 24)
    n_weights, n_subset = (48, 12) if full else (16, 8)
    n_queries = 192 if full else 96
    data, weights, plan, svc = _build_service(n, d, n_weights, n_subset)
    rng = np.random.default_rng(3)

    # ---- sweep 1: throughput vs number of active groups ---------------------
    rows_groups = []
    group_members = [g.member_ids for g in plan.groups]
    for n_active in range(1, plan.n_groups + 1):
        pool = np.concatenate(group_members[:n_active])
        qpts, wids = _traffic(data, pool, n_queries, rng)
        svc.query(qpts[:Q_BATCH], wids[:Q_BATCH])  # warm dispatch path
        svc.reset_stats()
        with Timer() as t:
            svc.query(qpts, wids)
        occ = np.mean(
            [s["occupancy"] for s in svc.stats_summary().values()]
        )
        rows_groups.append([
            n_active, n_queries, n_queries / t.seconds, float(occ),
            svc.step_cache.n_compiled,
        ])
    print_table(
        "serving throughput vs active groups",
        ["groups", "queries", "q/s", "occupancy", "compiled steps"],
        rows_groups,
    )

    # ---- sweep 2: throughput vs batch occupancy -----------------------------
    rows_occ = []
    pool = np.arange(n_weights)
    qpts, wids = _traffic(data, pool, n_queries, rng)
    for chunk in (1, 2, 4, Q_BATCH, n_queries):
        svc.reset_stats()
        with Timer() as t:
            for lo in range(0, n_queries, chunk):
                svc.query(qpts[lo : lo + chunk], wids[lo : lo + chunk])
        occ = np.mean(
            [s["occupancy"] for s in svc.stats_summary().values()]
        )
        rows_occ.append(
            [chunk, n_queries, n_queries / t.seconds, float(occ)]
        )
    print_table(
        "serving throughput vs submission chunk (batch occupancy)",
        ["chunk", "queries", "q/s", "occupancy"],
        rows_occ,
    )

    qps_full = rows_occ[-1][2]
    qps_single = rows_occ[0][2]
    validation = [
        {
            "check": "compiled steps < table groups (shape-bucket sharing)",
            "ok": bool(svc.step_cache.n_compiled < plan.n_groups),
        },
        {
            "check": "full-batch submission beats 1-query submission",
            "ok": bool(qps_full > qps_single),
        },
        {
            "check": "mean occupancy > 0.45 when traffic arrives in one batch",
            "ok": bool(rows_occ[-1][3] > 0.45),
        },
    ]
    for v in validation:
        print(("PASS " if v["ok"] else "FAIL ") + v["check"])

    payload = {
        "n": n, "d": d, "n_weights": n_weights,
        "n_groups": plan.n_groups,
        "n_compiled_steps": svc.step_cache.n_compiled,
        "groups_sweep": rows_groups,
        "occupancy_sweep": rows_occ,
        "validation": validation,
    }
    save("serve_bench", payload)
    return payload


if __name__ == "__main__":
    run()
