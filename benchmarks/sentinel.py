"""Bench-regression sentinel: fresh serving metrics vs a pinned baseline.

A CI gate, not a table benchmark: ``collect()`` runs one small
driver-stepped serving workload (open-loop trace, tight paging budget,
predictive prefetch, shadow-exact recall sampling at rate 1.0) and
condenses it to a flat metric dict; ``compare()`` checks every metric
against the committed baseline under a per-metric tolerance band; the
CLI exits nonzero on any regression so a lane can require it.

Band semantics: each metric declares the direction that counts as a
regression (``lower`` = bigger is worse, ``higher`` = smaller is worse)
and a tolerance — wall-clock metrics (step latency, q/s) get wide
relative bands because CI machines vary, deterministic metrics
(compiled-step count, shadow drops, observed recall) get tight or zero
bands because the workload is fully seeded.  Improvements never fail.
``obs_overhead_frac`` (the obs-on / obs-off p50 step ratio) rides along
in the artifact as an informational metric but is not gated: a ratio of
two noisy p50s on a smoke-sized workload pages on hardware weather, and
the serve bench's sweep 9 already pins the < 5% claim statistically.

Every run writes a machine-readable ``BENCH_serve.json`` at the repo
root (the artifact a CI job uploads); ``--write-baseline`` pins the
current metrics as ``experiments/bench/BASELINE.json``.

    PYTHONPATH=src python -m benchmarks.sentinel                # gate
    PYTHONPATH=src python -m benchmarks.sentinel --write-baseline
    PYTHONPATH=src python -m benchmarks.sentinel --from-json m.json
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.serving.async_service import AsyncRetrievalService, ManualClock
from repro.serving.qos import DegradeStep
from repro.serving.retrieval import RetrievalService, ServiceConfig
from repro.serving.scheduler import (
    DeadlinePrefetch,
    ServiceDriver,
    replay_with_driver,
)

from .common import TAU, print_table

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_BASELINE = os.path.join(_ROOT, "experiments", "bench",
                                "BASELINE.json")
DEFAULT_OUT = os.path.join(_ROOT, "BENCH_serve.json")

# one band per gated metric: the direction that is a regression, plus a
# relative and/or absolute tolerance applied to the baseline value.
# wall metrics are wide (CI hardware noise); seeded metrics are tight.
BANDS: dict[str, dict] = {
    "p50_step_ms":        {"direction": "lower", "rel_tol": 1.0},
    "p95_step_ms":        {"direction": "lower", "rel_tol": 1.5},
    "qps":                {"direction": "higher", "rel_tol": 0.6},
    "state_hit_rate":     {"direction": "higher", "abs_tol": 0.05},
    "deadline_miss_rate": {"direction": "lower", "abs_tol": 0.05},
    "mean_occupancy":     {"direction": "higher", "abs_tol": 0.05},
    "observed_recall":    {"direction": "higher", "abs_tol": 0.02},
    "recall_margin_min":  {"direction": "higher", "abs_tol": 0.02},
    "n_compiled_steps":   {"direction": "lower", "abs_tol": 0.0},
    "n_shadow_dropped":   {"direction": "lower", "abs_tol": 0.0},
}

# sentinel workload: small enough for a CI smoke lane, big enough to
# exercise paging, prefetch, deadlines and the shadow-recall path
_WL = dict(n=2_048, d=16, n_weights=8, n_subset=4, n_queries=96,
           arrival_rate=2_000.0, seed=5)


def _timed_replay(svc, qpts, wids, arrivals):
    """Drive one replay; returns (per-launch step seconds, wall seconds)."""
    asvc = AsyncRetrievalService(svc, max_delay_ms=2.0,
                                 clock=ManualClock())
    driver = ServiceDriver(asvc, prefetch=DeadlinePrefetch())
    launch_times = []
    seen = [0]
    real_step = driver.step

    def timed_step():
        t0 = time.perf_counter()
        out = real_step()
        dt = time.perf_counter() - t0
        if driver.stats.n_launches > seen[0]:
            launch_times.append(dt)
            seen[0] = driver.stats.n_launches
        return out

    driver.step = timed_step
    t0 = time.perf_counter()
    replay_with_driver(driver, qpts, wids, arrivals)
    wall = time.perf_counter() - t0
    return launch_times, wall, driver


def collect() -> dict:
    """Run the sentinel workload; returns the flat gated-metric dict.

    Fully seeded: the same code produces the same deterministic metrics
    (compiled steps, recall, drops) on every run; only the wall-clock
    numbers move with the hardware.
    """
    w = _WL
    data = make_dataset(n=w["n"], d=w["d"], seed=w["seed"])
    weights = make_weight_set(size=w["n_weights"], d=w["d"],
                              n_subset=w["n_subset"], n_subrange=10,
                              seed=w["seed"] + 1)
    pcfg = PlanConfig(p=2.0, c=3, n=w["n"], gamma_n=100.0)
    host = WLSHIndex(data, weights, pcfg, tau=TAU[2.0], v=4, v_prime=4,
                     seed=w["seed"] + 2)
    plan = host.export_serving_plan()
    cap = max(1, int(np.ceil(0.5 * plan.n_groups)))
    ladder = (DegradeStep(c=4, k=3, cost=0.5, recall_bound=0.3),)

    rng = np.random.default_rng(w["seed"] + 3)
    wids = rng.integers(0, w["n_weights"], w["n_queries"])
    qpts = data[rng.choice(w["n"], w["n_queries"], replace=False)].astype(
        np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    arrivals = np.cumsum(
        rng.exponential(1.0 / w["arrival_rate"], w["n_queries"]))

    def _service(obs_on: bool):
        svc = RetrievalService(plan, data, cfg=ServiceConfig(
            k=5, q_batch=8, use_pallas=False,
            max_resident_groups=cap, degrade_ladder=ladder,
            recall_sample_rate=1.0 if obs_on else 0.0,
            recall_floor=0.25, obs=obs_on))
        svc.warmup()
        svc.reset_stats()
        return svc

    # obs-off pass first: prices the bare step so the on-pass overhead
    # fraction is measurable on the same machine in the same process
    off_svc = _service(False)
    off_times, _, _ = _timed_replay(off_svc, qpts, wids, arrivals)

    svc = _service(True)
    times, wall, driver = _timed_replay(svc, qpts, wids, arrivals)
    est = svc.batcher.recall
    est.drain()
    rsum = est.summary()
    margin = svc.batcher.metrics.gauge(
        "wlsh_recall_bound_margin",
        "observed recall minus the rung's planned recall bound")
    margins = list(margin.series().values())
    cs = svc.state_cache.stats
    p50_on = float(np.percentile(times, 50))
    p50_off = float(np.percentile(off_times, 50))
    return {
        "p50_step_ms": 1e3 * p50_on,
        "p95_step_ms": 1e3 * float(np.percentile(times, 95)),
        "qps": w["n_queries"] / wall,
        "obs_overhead_frac": p50_on / p50_off - 1.0,
        "state_hit_rate": float(cs.hit_rate),
        "deadline_miss_rate": float(driver.stats.deadline_miss_rate),
        "mean_occupancy": float(svc.mean_occupancy()),
        "observed_recall": float(est.estimate()),
        "recall_margin_min": float(min(margins)),
        "n_compiled_steps": int(svc.step_cache.n_compiled),
        "n_shadow_dropped": int(rsum["n_dropped"]),
    }


def compare(current: dict, baseline: dict,
            bands: dict | None = None) -> list[dict]:
    """Judge ``current`` against ``baseline`` under the tolerance bands.

    Returns one row per banded baseline metric: the values, the
    computed pass limit, and ``ok``.  A metric present in the baseline
    but missing from the current run is a regression (it disappeared);
    a metric new in the current run is ignored (no baseline to judge
    against — pin a fresh baseline to start gating it).
    """
    bands = BANDS if bands is None else bands
    rows = []
    for name, band in bands.items():
        if name not in baseline:
            continue
        base = float(baseline[name])
        tol = (band.get("abs_tol", 0.0)
               + band.get("rel_tol", 0.0) * abs(base))
        if band["direction"] == "lower":  # bigger is worse
            limit = base + tol
            cur = current.get(name)
            ok = cur is not None and float(cur) <= limit
        else:  # smaller is worse
            limit = base - tol
            cur = current.get(name)
            ok = cur is not None and float(cur) >= limit
        rows.append({
            "metric": name,
            "current": None if cur is None else float(cur),
            "baseline": base,
            "limit": limit,
            "direction": band["direction"],
            "ok": bool(ok),
        })
    return rows


def _load_metrics(path: str) -> dict:
    """Read a metric dict from JSON (bare, or under a ``metrics`` key)."""
    with open(path) as fh:
        payload = json.load(fh)
    return payload.get("metrics", payload)


def main(argv=None) -> int:
    """CLI gate: 0 = within bands, 1 = regression, 2 = no baseline."""
    ap = argparse.ArgumentParser(
        description="serving bench-regression sentinel")
    ap.add_argument("--baseline", default=DEFAULT_BASELINE,
                    metavar="PATH",
                    help="pinned baseline metrics (JSON) to gate against")
    ap.add_argument("--out", default=DEFAULT_OUT, metavar="PATH",
                    help="write the machine-readable run artifact here")
    ap.add_argument("--from-json", default=None, metavar="PATH",
                    help="judge pre-collected metrics from PATH instead "
                         "of running the sentinel workload")
    ap.add_argument("--write-baseline", action="store_true",
                    help="pin the current metrics as the new baseline "
                         "and exit 0 (no gating)")
    args = ap.parse_args(argv)

    current = (_load_metrics(args.from_json) if args.from_json
               else collect())
    artifact = {
        "metrics": current,
        "workload": _WL,
        "bands": BANDS,
        "baseline_path": os.path.relpath(args.baseline, _ROOT),
        "t_collected": time.time(),
    }
    with open(args.out, "w") as fh:
        json.dump(artifact, fh, indent=1)
    print(f"sentinel: metrics -> {args.out}")

    if args.write_baseline:
        os.makedirs(os.path.dirname(args.baseline), exist_ok=True)
        with open(args.baseline, "w") as fh:
            json.dump({"metrics": current,
                       "workload": _WL,
                       "t_pinned": time.time()}, fh, indent=1)
        print(f"sentinel: baseline pinned -> {args.baseline}")
        return 0

    if not os.path.exists(args.baseline):
        print(f"sentinel: no baseline at {args.baseline} — run with "
              f"--write-baseline to pin one")
        return 2
    rows = compare(current, _load_metrics(args.baseline))
    print_table(
        "bench-regression sentinel vs "
        f"{os.path.relpath(args.baseline, _ROOT)}",
        ["metric", "current", "baseline", "limit", "worse when", "ok"],
        [[r["metric"],
          "MISSING" if r["current"] is None else r["current"],
          r["baseline"], r["limit"], r["direction"],
          "PASS" if r["ok"] else "FAIL"] for r in rows],
    )
    bad = [r for r in rows if not r["ok"]]
    if bad:
        print(f"sentinel: {len(bad)} regression(s): "
              + ", ".join(r["metric"] for r in bad))
        return 1
    print(f"sentinel: {len(rows)} metrics within bands")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
