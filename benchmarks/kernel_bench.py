"""Kernel microbenchmarks + the fused-vs-unfused query-step sweep.

Three sections, all pinned into ``experiments/bench/kernel_bench.json``:

  1. micro — XLA-reference wall time for the individual kernels (the
     stage-by-stage throughput the unfused path is built from);
  2. sweep — the fused ``ops.fused_query_block`` pass-1 step against the
     seed-era unfused pipeline (separate freq_level / distance / histogram
     dispatches with the (Q, block) intermediates round-tripping between
     them), per backend over block_n x beta x p in {2, 1, 0.5};
  3. agreement — every Pallas kernel body (hash_encode, freq_level,
     weighted_lp, fused hist + scores) executed in interpret mode against
     its ref.py oracle, at benchmark scale.  The assertions at the bottom
     make this the CI kernels-lane gate: a kernel-body regression fails
     here before any serving lane runs.

On-CPU wall times are NOT the perf deliverable (that's the roofline table,
derived from the compiled TPU-mesh dry-run); the sweep's job is to show the
fused dispatch at least matching the unfused one on the XLA backend it runs
on, and to be re-runnable on a TPU host where the compiled Pallas column is
the one that matters.
"""

from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops, platform, ref

from .common import print_table, save


def _time(fn, *args, iters=5, **kw):
    """Min-of-iters wall time (robust to scheduler noise) + last output."""
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


# ----------------------------------------------- unfused pass-1 baseline
# The seed pipeline as separate compiled dispatches: level matrix, distance
# matrix and histogram each cross the dispatch boundary (this is the HBM
# round-trip the fused kernel removes).


@functools.partial(jax.jit, static_argnames=("p",))
def _dist_stage(qs, w, pts, p: float):
    return ref.per_query_dist(qs, w, pts, p)


@functools.partial(jax.jit, static_argnames=("c", "n_levels"))
def _hist_stage(lf, dist, r_min, row_ok, c: int, n_levels: int):
    L = n_levels
    lf = jnp.where(row_ok[None, :], lf, jnp.int32(L + 1))
    jg = jnp.ceil(
        jnp.maximum(ref.log_c(jnp.maximum(dist, 1e-30), c)
                    - ref.log_c(c * r_min, c)[:, None], 0.0)
    ).astype(jnp.int32)
    good = jnp.maximum(lf, jg)
    levels = jnp.arange(L + 2, dtype=jnp.int32)
    hf = jnp.sum((lf[:, :, None] == levels[None, None, :]).astype(jnp.int32),
                 axis=1)
    hg = jnp.sum((good[:, :, None] == levels[None, None, :]).astype(jnp.int32),
                 axis=1)
    return hf, hg


def _unfused_pass1(cb, pts_b, cq, qs, w, mu, r_min, bq, row_ok, c, L, p):
    lf = ops.freq_level(cb, cq, mu, c=c, n_levels=L, beta_q=bq,
                        use_pallas=False)
    dist = _dist_stage(qs, w, pts_b, p)
    return _hist_stage(lf, dist, r_min, row_ok, c=c, n_levels=L)


def _sweep(full: bool):
    """Fused vs unfused pass-1 block step over block_n x beta x p."""
    rng = np.random.default_rng(1)
    Q, d, c, L = (16, 64, 2, 12) if full else (8, 32, 2, 10)
    blocks = [1024, 4096] if not full else [4096, 16384]
    betas = [64, 128]
    rows, entries = [], []
    for block_n in blocks:
        for beta in betas:
            cp = jnp.asarray(
                rng.integers(0, 2**20, (block_n, beta)).astype(np.int32))
            cq = jnp.asarray(
                rng.integers(0, 2**20, (Q, beta)).astype(np.int32))
            pts = jnp.asarray(
                rng.uniform(0, 1000, (block_n, d)).astype(np.float32))
            qs = jnp.asarray(rng.uniform(0, 1000, (Q, d)).astype(np.float32))
            w = jnp.asarray(rng.uniform(1, 10, (Q, d)).astype(np.float32))
            mu = jnp.asarray(rng.integers(2, beta // 4, Q).astype(np.int32))
            bq = jnp.asarray(rng.integers(beta // 2, beta + 1, Q)
                             .astype(np.int32))
            r_min = jnp.asarray(
                rng.uniform(10.0, 100.0, Q).astype(np.float32))
            row_ok = jnp.arange(block_n, dtype=jnp.int32) < (block_n - 7)
            for p in (2.0, 1.0, 0.5):
                t_un, (hf0, hg0) = _time(
                    _unfused_pass1, cp, pts, cq, qs, w, mu, r_min, bq,
                    row_ok, c, L, p,
                )
                # the engine invokes the fused step from inside its jitted
                # scan body — time it the same way, as ONE compiled dispatch
                fused_step = jax.jit(functools.partial(
                    ops.fused_query_block, boff=0, n_valid=block_n - 7,
                    c=c, n_levels=L, p=p,
                ))
                t_fu, (hf1, hg1) = _time(
                    fused_step, cp, pts, cq, qs, w, mu, r_min, bq,
                )
                # bins 0..L must agree exactly (the stop logic reads only
                # those; L+1 differs by the dead-row parking convention)
                agree = bool(
                    np.array_equal(np.array(hf0)[:, : L + 1],
                                   np.array(hf1)[:, : L + 1])
                    and np.array_equal(np.array(hg0)[:, : L + 1],
                                       np.array(hg1)[:, : L + 1])
                )
                entry = {
                    "backend": platform.backend(),
                    "path": platform.resolve(None).label,
                    "block_n": block_n, "beta": beta, "p": p, "q": Q,
                    "d": d, "unfused_ms": round(t_un * 1e3, 3),
                    "fused_ms": round(t_fu * 1e3, 3),
                    "speedup": round(t_un / t_fu, 2),
                    "hist_agrees": agree,
                }
                entries.append(entry)
                rows.append([block_n, beta, p, entry["unfused_ms"],
                             entry["fused_ms"], entry["speedup"],
                             "OK" if agree else "MISMATCH"])
    print_table(
        f"Fused vs unfused pass-1 block step "
        f"({platform.backend()}, path={platform.resolve(None).label})",
        ["block_n", "beta", "p", "unfused ms", "fused ms", "speedup",
         "hist"], rows,
    )
    return entries


def _boundary_ok(diff, u):
    """hash_encode mismatches: |1| only, and only at ~integer boundaries."""
    if not diff.any():
        return True
    if np.abs(diff[diff != 0]).max() > 1:
        return False
    frac = np.abs(u - np.round(u))
    return bool(np.all(frac[diff != 0] < 1e-2))


def _agreement(codes_p, codes_q, pts, qs, w, proj, b_int, b_frac):
    """Interpret-mode kernel bodies vs the ref.py oracles, benchmark data.

    Returns {check_name: bool}; every entry must be True for the bench to
    pass (the CI kernels lane asserts on this dict).
    """
    rng = np.random.default_rng(2)
    ns, nq = 512, 8
    cp, cq = np.array(codes_p[:ns]), np.array(codes_q[:nq])
    ptss, qss = np.array(pts[:ns]), np.array(qs[:nq])
    checks = {}

    # hash_encode: exact up to floor-boundary jitter between summation orders
    he_ref = np.array(ops.hash_encode(ptss, w, proj, b_int, b_frac, 25.0,
                                      use_pallas=False))
    he_pal = np.array(ops.hash_encode(ptss, w, proj, b_int, b_frac, 25.0,
                                      use_pallas=True, interpret=True,
                                      bn=128, bb=64, bd=64))
    u = (ptss * np.array(w)) @ np.array(proj) / 25.0 + np.array(b_frac)
    checks["hash_encode"] = bool(
        _boundary_ok(he_pal - he_ref, u)
        and np.mean(he_pal != he_ref) < 1e-3
    )

    # freq_level: exact integer match
    fl_ref = np.array(ops.freq_level(cp, cq, 4, c=2, n_levels=8,
                                     use_pallas=False))
    fl_pal = np.array(ops.freq_level(cp, cq, 4, c=2, n_levels=8,
                                     use_pallas=True, interpret=True,
                                     bn=128))
    checks["freq_level"] = bool(np.array_equal(fl_ref, fl_pal))

    # weighted_lp (p != 2; p == 2 routes to the MXU expansion, no kernel)
    for p in (1.0, 0.5):
        wl_ref = np.array(ops.weighted_lp_dist(qss, ptss, w, p,
                                               use_pallas=False))
        wl_pal = np.array(ops.weighted_lp_dist(qss, ptss, w, p,
                                               use_pallas=True,
                                               interpret=True, bn=128,
                                               bd=64))
        checks[f"weighted_lp_p{p}"] = bool(
            np.allclose(wl_ref, wl_pal, rtol=2e-4, atol=2e-2)
        )

    # fused query block: hist bit-exact; scores bit-exact for p != 2 and
    # allclose (same inf mask) for the p = 2 MXU expansion
    qw = rng.uniform(1, 10, (nq, ptss.shape[1])).astype(np.float32)
    mu = rng.integers(2, 8, nq).astype(np.int32)
    bqv = rng.integers(cp.shape[1] // 2, cp.shape[1] + 1, nq).astype(np.int32)
    rmin = rng.uniform(10.0, 100.0, nq).astype(np.float32)
    stop = rng.integers(0, 9, nq).astype(np.int32)
    kw = dict(boff=100, n_valid=ns - 40, c=2, n_levels=8)
    for p in (2.0, 1.0, 0.5):
        hf0, hg0 = ops.fused_query_block(cp, ptss, cq, qss, qw, mu, rmin,
                                         bqv, p=p, use_pallas=False, **kw)
        hf1, hg1 = ops.fused_query_block(cp, ptss, cq, qss, qw, mu, rmin,
                                         bqv, p=p, use_pallas=True,
                                         interpret=True, bn=128, **kw)
        checks[f"fused_hist_p{p}"] = bool(
            np.array_equal(np.array(hf0), np.array(hf1))
            and np.array_equal(np.array(hg0), np.array(hg1))
        )
        s0 = np.array(ops.fused_query_block(cp, ptss, cq, qss, qw, mu, rmin,
                                            bqv, p=p, stop=stop,
                                            use_pallas=False, **kw))
        s1 = np.array(ops.fused_query_block(cp, ptss, cq, qss, qw, mu, rmin,
                                            bqv, p=p, stop=stop,
                                            use_pallas=True, interpret=True,
                                            bn=128, **kw))
        fin = np.isfinite(s0)
        mask_eq = bool(np.array_equal(fin, np.isfinite(s1)))
        if abs(p - 2.0) < 1e-9:
            ok = mask_eq and bool(
                np.allclose(s0[fin], s1[fin], rtol=2e-4, atol=2e-2)
            )
        else:
            ok = mask_eq and bool(np.array_equal(s0[fin], s1[fin]))
        checks[f"fused_scores_p{p}"] = ok
    return checks


def run(full: bool = False):
    n, d, beta, Q = (65_536, 128, 256, 64) if full else (16_384, 128, 128, 32)
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1000, (n, d)).astype(np.float32))
    qs = jnp.asarray(rng.uniform(0, 1000, (Q, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1, 10, d).astype(np.float32))
    proj = jnp.asarray(rng.normal(0, 1, (d, beta)).astype(np.float32))
    b = rng.uniform(0, 729, beta)
    b_int = jnp.asarray(np.floor(b).astype(np.int32))
    b_frac = jnp.asarray((b - np.floor(b)).astype(np.float32))

    rows = []

    t, codes_p = _time(ops.hash_encode, pts, w, proj, b_int, b_frac, 25.0,
                       use_pallas=False)
    gflops = 2 * n * d * beta / t / 1e9
    rows.append(["hash_encode", f"({n},{d})x({d},{beta})",
                 round(t * 1e3, 2), round(gflops, 1)])

    codes_q = ops.hash_encode(qs, w, proj, b_int, b_frac, 25.0,
                              use_pallas=False)
    t, _ = _time(ops.freq_level, codes_p, codes_q, 8, c=2, n_levels=12,
                 use_pallas=False)
    gcomp = Q * n * beta * 13 / t / 1e9  # compare-ops, not FLOPs
    rows.append(["freq_level", f"Q={Q} n={n} beta={beta} L=12",
                 round(t * 1e3, 2), round(gcomp, 1)])

    t, _ = _time(ops.weighted_lp_dist, qs, pts, w, 2.0, use_pallas=False)
    gflops = 3 * Q * n * d / t / 1e9
    rows.append(["weighted_lp(p=2)", f"Q={Q} n={n} d={d}",
                 round(t * 1e3, 2), round(gflops, 1)])

    t, _ = _time(ops.weighted_lp_dist, qs, pts, w, 1.0, use_pallas=False)
    rows.append(["weighted_lp(p=1)", f"Q={Q} n={n} d={d}",
                 round(t * 1e3, 2), round(3 * Q * n * d / t / 1e9, 1)])

    print_table("Kernel microbench (XLA reference path)",
                ["kernel", "shape", "ms/call", "G(fl)ops/s"], rows)

    sweep = _sweep(full)
    checks = _agreement(codes_p, codes_q, pts, qs, w, proj, b_int, b_frac)
    agree = all(checks.values())
    print("\ninterpret-vs-ref agreement:",
          "all OK" if agree else
          f"MISMATCH in {[k for k, v in checks.items() if not v]}")

    out = {
        "backend": platform.backend(),
        "auto_path": platform.resolve(None).label,
        "rows": rows,
        "sweep": sweep,
        "agreement": checks,
        "pallas_interpret_agrees": agree,
        "note": ("sweep entries are per backend; re-run on a TPU host to "
                 "populate the compiled fused-pallas column"),
    }
    save("kernel_bench", out)
    assert agree, f"kernel agreement gate failed: {checks}"
    assert all(e["hist_agrees"] for e in sweep), "fused sweep hist mismatch"
    return out


if __name__ == "__main__":
    run()
