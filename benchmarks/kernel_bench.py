"""Kernel microbenchmarks: XLA-reference wall time on CPU + interpret-mode
oracle agreement for the three Pallas kernels.

On-CPU wall times are NOT the perf deliverable (that's the roofline table,
derived from the compiled TPU-mesh dry-run) — this benchmark (a) proves the
kernel semantics at benchmark scale, and (b) gives the XLA-path throughput
that the sharded engine falls back to off-TPU.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops

from .common import print_table, save


def _time(fn, *args, iters=5, **kw):
    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args, **kw)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters, out


def run(full: bool = False):
    n, d, beta, Q = (65_536, 128, 256, 64) if full else (16_384, 128, 128, 32)
    rng = np.random.default_rng(0)
    pts = jnp.asarray(rng.uniform(0, 1000, (n, d)).astype(np.float32))
    qs = jnp.asarray(rng.uniform(0, 1000, (Q, d)).astype(np.float32))
    w = jnp.asarray(rng.uniform(1, 10, d).astype(np.float32))
    proj = jnp.asarray(rng.normal(0, 1, (d, beta)).astype(np.float32))
    b = rng.uniform(0, 729, beta)
    b_int = jnp.asarray(np.floor(b).astype(np.int32))
    b_frac = jnp.asarray((b - np.floor(b)).astype(np.float32))

    rows = []

    t, codes_p = _time(ops.hash_encode, pts, w, proj, b_int, b_frac, 25.0,
                       use_pallas=False)
    gflops = 2 * n * d * beta / t / 1e9
    rows.append(["hash_encode", f"({n},{d})x({d},{beta})",
                 round(t * 1e3, 2), round(gflops, 1)])

    codes_q = ops.hash_encode(qs, w, proj, b_int, b_frac, 25.0,
                              use_pallas=False)
    t, _ = _time(ops.freq_level, codes_p, codes_q, 8, c=2, n_levels=12,
                 use_pallas=False)
    gcomp = Q * n * beta * 13 / t / 1e9  # compare-ops, not FLOPs
    rows.append(["freq_level", f"Q={Q} n={n} beta={beta} L=12",
                 round(t * 1e3, 2), round(gcomp, 1)])

    t, _ = _time(ops.weighted_lp_dist, qs, pts, w, 2.0, use_pallas=False)
    gflops = 3 * Q * n * d / t / 1e9
    rows.append(["weighted_lp(p=2)", f"Q={Q} n={n} d={d}",
                 round(t * 1e3, 2), round(gflops, 1)])

    t, _ = _time(ops.weighted_lp_dist, qs, pts, w, 1.0, use_pallas=False)
    rows.append(["weighted_lp(p=1)", f"Q={Q} n={n} d={d}",
                 round(t * 1e3, 2), round(3 * Q * n * d / t / 1e9, 1)])

    print_table("Kernel microbench (XLA reference path, CPU)",
                ["kernel", "shape", "ms/call", "G(fl)ops/s"], rows)

    # interpret-mode oracle agreement at a reduced size (kernel body runs
    # per grid cell in Python — keep it small)
    ns, qs_n = 512, 8
    cp = codes_p[:ns]
    cq = codes_q[:qs_n]
    a = np.array(ops.freq_level(cp, cq, 4, c=2, n_levels=8, use_pallas=False))
    bq = np.array(ops.freq_level(cp, cq, 4, c=2, n_levels=8, use_pallas=True,
                                 interpret=True, bn=128))
    agree = bool((a == bq).all())
    rows.append(["freq_level pallas-interpret == ref", f"n={ns}", "-",
                 "OK" if agree else "MISMATCH"])
    out = {"rows": rows, "pallas_interpret_agrees": agree}
    save("kernel_bench", out)
    assert agree
    return out


if __name__ == "__main__":
    run()
