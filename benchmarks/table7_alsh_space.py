"""Paper Table 7: SL-ALSH / S2-ALSH space (L = n^rho tables at R = 1000).

Planning-only (Eqs. 17-18 numeric minimization); runs at paper scale.
Validation: L grows polynomially with n, shrinks with c, and is much less
sensitive to the weight-set parameters than WLSH's beta_S — the paper's
"ALSH space is data-sensitive, WLSH space is weight-set-sensitive" claim.
"""

from __future__ import annotations

import numpy as np

from repro.core.alsh import alsh_tables, rho_s2, rho_sl
from repro.core.datagen import make_weight_set

from .common import DEFAULT_FULL, GRID_FULL, print_table, save

_R = 1_000.0


def run(full: bool = True) -> dict:
    # Table 7 is pure planning math -> always paper scale; weight-set d is
    # capped so the |S| x d generation stays light.
    grid = dict(GRID_FULL)
    base = dict(DEFAULT_FULL)
    base["S"] = 1_000
    rows = []
    for param, values in grid.items():
        if param == "c":
            for c in values:
                W = make_weight_set(base["S"], base["d"],
                                    base["n_subset"], base["n_subrange"])
                rows.append([param, c,
                             alsh_tables(base["n"], rho_sl(W, _R, c)),
                             alsh_tables(base["n"], rho_s2(W, _R, c))])
        elif param == "n":
            W = make_weight_set(base["S"], base["d"], base["n_subset"],
                                base["n_subrange"])
            r_sl, r_s2 = rho_sl(W, _R, base["c"]), rho_s2(W, _R, base["c"])
            for n in values:
                rows.append([param, n, alsh_tables(n, r_sl),
                             alsh_tables(n, r_s2)])
        else:
            for val in values:
                kw = dict(base)
                kw[param] = val
                W = make_weight_set(kw["S"], kw["d"], kw["n_subset"],
                                    kw["n_subrange"])
                rows.append([param, val,
                             alsh_tables(kw["n"], rho_sl(W, _R, kw["c"])),
                             alsh_tables(kw["n"], rho_s2(W, _R, kw["c"]))])
    print_table("Table 7 — SL/S2-ALSH space (R=1000)",
                ["param", "value", "L_SL", "L_S2"], rows)

    # validation
    n_curve = [r[2] for r in rows if r[0] == "n"]
    c_curve = [r[2] for r in rows if r[0] == "c"]
    s_vals = [r[2] for r in rows if r[0] == "S"]
    checks = [
        ("L grows with n", all(b > a for a, b in zip(n_curve, n_curve[1:]))),
        ("L shrinks with c", all(b <= a for a, b in zip(c_curve, c_curve[1:]))),
        ("L insensitive to |S| (<15% spread)",
         (max(s_vals) - min(s_vals)) / max(s_vals) < 0.15),
        ("polynomial n-growth (L(16x n) / L(n) >> 16^0.5)",
         n_curve[-1] / n_curve[0] > 4.0),
    ]
    out = {"rows": rows,
           "validation": [{"check": n, "ok": bool(ok)} for n, ok in checks]}
    print("\nvalidation:")
    for c in out["validation"]:
        print(f"  [{'ok' if c['ok'] else 'FAIL'}] {c['check']}")
    save("table7_alsh_space", out)
    return out


if __name__ == "__main__":
    run()
