"""p-stable sampling + density evaluation."""

from __future__ import annotations

import jax
import numpy as np
import pytest

from repro.core.pstable import (
    pstable_pdf,
    pstable_pdf_abs,
    sample_pstable,
    sample_pstable_np,
)


def test_p2_is_standard_normal():
    rng = np.random.default_rng(0)
    x = sample_pstable_np(rng, 2.0, (200_000,))
    assert abs(np.mean(x)) < 0.02
    assert abs(np.std(x) - 1.0) < 0.02


def test_p1_is_cauchy():
    rng = np.random.default_rng(0)
    x = sample_pstable_np(rng, 1.0, (200_000,))
    # Cauchy has no mean; check the IQR instead (exactly 2 for standard).
    q1, q3 = np.percentile(x, [25, 75])
    assert abs((q3 - q1) - 2.0) < 0.05


@pytest.mark.parametrize("p", [0.5, 1.2, 1.8])
def test_general_p_stability_property(p):
    """Defining property: (X1 + X2) / 2^(1/p) is distributed like X."""
    rng = np.random.default_rng(1)
    n = 150_000
    x1 = sample_pstable_np(rng, p, (n,))
    x2 = sample_pstable_np(rng, p, (n,))
    s = (x1 + x2) / 2.0 ** (1.0 / p)
    # compare central quantiles (tails of stable laws are heavy/noisy)
    qs = np.linspace(0.2, 0.8, 13)
    a = np.quantile(x1, qs)
    b = np.quantile(s, qs)
    np.testing.assert_allclose(a, b, atol=0.05, rtol=0.05)


def test_jax_matches_numpy_distribution():
    key = jax.random.PRNGKey(0)
    xj = np.asarray(sample_pstable(key, 1.5, (100_000,)))
    rng = np.random.default_rng(2)
    xn = sample_pstable_np(rng, 1.5, (100_000,))
    qs = np.linspace(0.1, 0.9, 17)
    np.testing.assert_allclose(
        np.quantile(xj, qs), np.quantile(xn, qs), atol=0.05, rtol=0.05
    )


@pytest.mark.parametrize("p", [0.5, 1.0, 1.5, 2.0])
def test_pdf_integrates_to_one(p):
    x = np.linspace(-150.0, 150.0, 300_001)
    f = pstable_pdf(x, p)
    mass = np.trapezoid(f, x)
    # heavy tails for small p make the finite integral < 1
    assert 0.93 <= mass <= 1.005


@pytest.mark.parametrize("p", [0.7, 1.3])
def test_pdf_matches_histogram(p):
    rng = np.random.default_rng(3)
    x = sample_pstable_np(rng, p, (400_000,))
    hist, edges = np.histogram(x[np.abs(x) < 5], bins=60, density=False)
    centers = 0.5 * (edges[1:] + edges[:-1])
    frac_in = np.mean(np.abs(x) < 5)
    emp = hist / len(x) / np.diff(edges) * 1.0
    ref = pstable_pdf(centers, p)
    np.testing.assert_allclose(emp, ref, atol=0.012)
    assert frac_in > 0.5


def test_pdf_abs_zero_below_zero():
    f = pstable_pdf_abs(np.array([-1.0, 0.5]), 1.5)
    assert f[0] == 0.0 and f[1] > 0.0
