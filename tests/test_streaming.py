"""Streaming inserts: delta segments, compaction parity, delete support.

The mutable subsystem must never change what the static stack pinned:

* pre-compaction recall — a query whose true nearest neighbor is an
  unsealed insert always returns it (the delta memtable is scanned
  exactly), property-tested; deleted ids never appear in any result;
* post-compaction parity — a group state reached by insert -> seal ->
  compact answers bit-exactly (ids/stop/n_checked) like
  ``WLSHIndex.search_dense`` on an index freshly built from the union
  corpus with the same family seeds, for p in {2, 1, 0.5}, on both
  frontends, paged and unpaged;
* compaction touches one group's cached state (versioned invalidation)
  and never a compiled step; discard-mode cold rebuilds include the
  compacted rows;
* the ``merge_topk`` helper preserves the no-drop/no-dup/no-tombstone
  merge invariants (property-tested on synthetic candidate lists).
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import build_parity_service
from repro.core.serving_plan import ServingPlan
from repro.core.wlsh import WLSHIndex
from repro.serving import (
    AsyncRetrievalService,
    ManualClock,
    RetrievalService,
    ServiceConfig,
    merge_topk,
    replay_open_loop,
)

K = 5


def _streaming_service(plan, data, *, cap=None, reserve=64, seal_rows=8,
                       q_batch=4, auto=None, offload=True):
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(
            k=K, q_batch=q_batch, max_resident_groups=cap,
            delta_seal_rows=seal_rows, delta_reserve_rows=reserve,
            auto_compact_segments=auto, offload_evicted=offload,
        ),
    )
    svc.warmup()
    svc.reset_stats()
    return svc


@pytest.fixture(scope="module")
def setup():
    # p=2 instance of the session parity build; streaming tests construct
    # their own services over it (never mutate the shared one)
    return build_parity_service(2.0)[1:]


def _far_vector(data, i, tag):
    """A fresh insert guaranteed distinct from (and far from) the corpus."""
    return (data[i % len(data)] + 50_000.0 + 13.0 * tag).astype(np.float32)


# ------------------------------------------------------- pre-compaction reads


def test_insert_visible_immediately_and_tenant_scoped(setup):
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    v = _far_vector(data, 3, tag=1)
    pid = svc.insert(v, w_in)
    assert pid == plan.n  # ids continue from the corpus epoch
    res = svc.query(v[None], [w_in])
    assert res.ids[0][0] == pid and res.dists[0][0] == 0.0
    # inserts are tenant-scoped: a weight routed to a *different* group
    # does not see the row
    other = int(np.where(plan.group_of != gi)[0][0])
    res_other = svc.query(v[None], [other])
    assert pid not in res_other.ids[0]
    # and the indexed hits behind the delta hit are unperturbed
    base = svc.query(data[5][None].astype(np.float32), [w_in])
    assert pid not in base.ids[0][:1] or base.dists[0][0] == 0.0


def test_deleted_ids_never_appear(setup):
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data)
    q = data[11].astype(np.float32)
    wid = 0
    before = svc.query(q[None], [wid])
    victim = int(before.ids[0][0])
    svc.delete(victim)
    after = svc.query(q[None], [wid])
    assert victim not in after.ids[0]
    # backfill keeps the remaining candidates sorted with no duplicates
    valid = after.ids[0][after.ids[0] >= 0]
    assert len(set(valid.tolist())) == len(valid)
    d = after.dists[0]
    assert np.all(np.diff(d[np.isfinite(d)]) >= 0)
    # deleting an unknown id is rejected
    with pytest.raises(ValueError):
        svc.delete(10**9)


@st.composite
def _insert_case(draw):
    base = draw(st.integers(0, 1_023))
    tag = draw(st.integers(0, 500))
    wid = draw(st.integers(0, 7))
    deleted = draw(st.booleans())
    return base, tag, wid, deleted


@given(_insert_case())
@settings(max_examples=30, deadline=None)
def test_unsealed_insert_is_always_recalled_property(case):
    """Queries whose true nearest neighbor is an unsealed insert always
    return it (exact delta scan); once deleted it never appears.  State
    accumulates across examples — recall must survive a growing memtable
    and tombstone set."""
    base, tag, wid, deleted = case
    data, weights, host, plan, _ = build_parity_service(2.0)[1:]
    svc = _property_service(plan, data)
    # repeated (base, tag) draws must not produce duplicate vectors: a
    # distance-0 tie would resolve to the *earlier* example's id (stable
    # scan order), which is correct recall but not what this asserts —
    # an all-dims serial offset keeps every insert unique under any
    # member weight
    _property_cache["serial"] = _property_cache.get("serial", 0) + 1
    v = _far_vector(data, base, tag) + np.float32(
        997.0 * _property_cache["serial"]
    )
    pid = svc.insert(v, wid)
    if deleted:
        svc.delete(pid)
    res = svc.query(v[None], [wid])
    if deleted:
        assert pid not in res.ids[0]
    else:
        assert res.ids[0][0] == pid and res.dists[0][0] == 0.0


_property_cache: dict = {}


def _property_service(plan, data):
    # one shared service across hypothesis examples: large seal threshold
    # keeps every insert in the open memtable (the "unsealed" regime)
    if "svc" not in _property_cache:
        _property_cache["svc"] = _streaming_service(
            plan, data, seal_rows=10_000, reserve=0
        )
    return _property_cache["svc"]


# ------------------------------------------------------- seal / compact flow


def test_seal_and_auto_compact_lifecycle(setup):
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=4, auto=1)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    pids = [svc.insert(_far_vector(data, j, 7), w_in) for j in range(4)]
    d = svc.delta_summary()
    assert d["n_seals"] == 1 and d["n_compactions"] == 1
    assert d["n_rows_compacted"] == 4 and d["n_pending"] == 0
    assert d["plan_version"] == 1
    assert d["corpus_epoch"] == plan.n + 4
    # versioned invalidation: exactly the compacted group, nobody else
    assert svc.state_cache.version_of(gi) == 1
    assert all(
        svc.state_cache.version_of(g) == 0
        for g in range(plan.n_groups) if g != gi
    )
    assert svc.cache_summary()["n_invalidations"] == 1
    assert svc.stats[gi].n_state_invalidations == 1
    # compacted rows now served by the compiled index path
    for j, pid in enumerate(pids):
        res = svc.query(_far_vector(data, j, 7)[None], [w_in])
        assert res.ids[0][0] == pid and res.dists[0][0] == 0.0


def test_compaction_never_recompiles(setup):
    """Acceptance: QueryStepCache counters pinned across seal/compact."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=4)
    signatures = {
        svc.group_config(gi).shape_signature()
        for gi in range(plan.n_groups)
    }
    assert svc.step_cache.n_compiled == len(signatures)
    w_in = int(plan.groups[0].member_ids[0])
    for j in range(9):  # 2 seals + a partial memtable
        svc.insert(_far_vector(data, j, 3), w_in)
    assert svc.delta_summary()["n_seals"] == 2
    assert svc.step_cache.n_compiled == len(signatures)
    assert svc.compact() == 9
    assert svc.step_cache.n_compiled == len(signatures)
    rng = np.random.default_rng(3)
    wids = rng.integers(0, len(weights), 8)
    qpts = data[rng.choice(len(data), 8, replace=False)].astype(np.float32)
    svc.query(qpts, wids)  # post-compaction traffic over every group
    assert svc.step_cache.n_compiled == len(signatures)


def test_capacity_exhaustion_is_explicit(setup):
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, reserve=4, seal_rows=2)
    w_in = int(plan.groups[0].member_ids[0])
    pids = [svc.insert(_far_vector(data, j, 9), w_in) for j in range(6)]
    # the background (non-strict) path skips the over-capacity group...
    assert svc.batcher.delta.compact_sealed() == 0
    # ...while the explicit path names the fix
    with pytest.raises(ValueError, match="delta_reserve_rows"):
        svc.compact()
    # rows keep serving from the exact scan regardless
    res = svc.query(_far_vector(data, 2, 9)[None], [w_in])
    assert res.ids[0][0] == pids[2]


def test_cold_rebuild_includes_compacted_rows(setup):
    """Discard-mode paging must rebuild a compacted group from its union
    corpus — eviction can never silently drop streamed rows."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, cap=1, offload=False,
                             seal_rows=4, reserve=64)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    pids = [svc.insert(_far_vector(data, j, 5), w_in) for j in range(4)]
    assert svc.compact() == 4  # flush the sealed 4-row segment
    assert svc.delta_summary()["n_rows_compacted"] == 4
    # evict the compacted group by touching every other group
    for other in range(plan.n_groups):
        if other != gi:
            wo = int(plan.groups[other].member_ids[0])
            svc.query(data[1][None].astype(np.float32), [wo])
    assert not svc.state_cache.is_resident(gi)
    res = svc.query(_far_vector(data, 1, 5)[None], [w_in])
    assert res.ids[0][0] == pids[1] and res.dists[0][0] == 0.0


# -------------------------------------------------- post-compaction parity


def _union_host(host: WLSHIndex, union: np.ndarray,
                weights: np.ndarray) -> WLSHIndex:
    """Fresh host index over the union corpus with the same family seeds.

    Eq. 11/12 betas drift with ``z(gamma=gamma_n/n)`` as n grows, so the
    freshly partitioned plan would differ from the served one by a table
    or two; the comparison the streaming stack guarantees is *same plan,
    same family seeds, union corpus* — so the original partition is
    pinned onto the fresh index (families re-sample identically from the
    shared seed) and only the hash tables are rebuilt over the union.
    """
    cfg2 = dataclasses.replace(host.cfg, n=len(union))
    host2 = WLSHIndex(union, weights, cfg2, tau=host.tau,
                      value_range=host.value_range, v=host.v,
                      v_prime=host.v_prime, seed=host.seed)
    host2.part = host.part
    host2._built = {}
    return host2


@pytest.mark.slow_parity
def test_post_compaction_parity_vs_fresh_union_build(parity_setup):
    """Acceptance: insert -> seal -> compact is bit-exact
    (ids/stop/n_checked) with search_dense on a fresh union-corpus index,
    per p in {2, 1, 0.5}, sync + async, paged + unpaged."""
    p, data, weights, host, plan, _ = parity_setup
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    members = plan.groups[gi].member_ids
    m = 24
    rng = np.random.default_rng(71)
    extra = (
        data[rng.choice(len(data), m, replace=False)]
        + rng.normal(0, 3.0, (m, plan.d))
    ).astype(np.float32)
    ins_wids = members[rng.integers(0, len(members), m)]

    svc = _streaming_service(plan, data, reserve=64, seal_rows=8)
    pids = [
        svc.insert(extra[j], int(ins_wids[j])) for j in range(m)
    ]
    assert pids == list(range(plan.n, plan.n + m))
    assert svc.compact() == m
    assert svc.delta_summary()["n_pending"] == 0

    union = np.concatenate([data, extra])
    host2 = _union_host(host, union, weights)

    # mixed queries under the compacted group's member weights: near base
    # points and near the streamed inserts
    nq = 24
    wids = members[rng.integers(0, len(members), nq)]
    qpts = union[rng.choice(len(union), nq, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)

    res = svc.query(qpts, wids)
    for qi in range(nq):
        want = host2.search_dense(qpts[qi], weight_id=int(wids[qi]), k=K)
        np.testing.assert_array_equal(
            res.ids[qi], want.ids.astype(np.int32),
            err_msg=f"post-compaction ids mismatch at query {qi} (p={p})",
        )
        assert int(res.stop_levels[qi]) == want.stats.stop_level
        assert int(res.n_checked[qi]) == want.stats.n_checked

    # a service freshly built over the union plan answers identically
    plan2 = host2.export_serving_plan()
    svc_fresh = RetrievalService(
        plan2, union, cfg=ServiceConfig(k=K, q_batch=4)
    )
    res_f = svc_fresh.query(qpts, wids)
    np.testing.assert_array_equal(res.ids, res_f.ids)
    np.testing.assert_array_equal(res.dists, res_f.dists)
    np.testing.assert_array_equal(res.stop_levels, res_f.stop_levels)
    np.testing.assert_array_equal(res.n_checked, res_f.n_checked)

    # paged (cap=1) streaming service, sync chunks + async replay
    paged = _streaming_service(plan, data, cap=1, reserve=64, seal_rows=8)
    for j in range(m):
        paged.insert(extra[j], int(ins_wids[j]))
    paged.compact()
    ids_chunks, stop_chunks, chk_chunks = [], [], []
    for lo in range(0, nq, 4):
        r = paged.query(qpts[lo:lo + 4], wids[lo:lo + 4])
        ids_chunks.append(r.ids)
        stop_chunks.append(r.stop_levels)
        chk_chunks.append(r.n_checked)
    np.testing.assert_array_equal(np.concatenate(ids_chunks), res.ids)
    np.testing.assert_array_equal(np.concatenate(stop_chunks),
                                  res.stop_levels)
    np.testing.assert_array_equal(np.concatenate(chk_chunks),
                                  res.n_checked)

    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, nq))
    asvc = AsyncRetrievalService(paged.batcher, max_delay_ms=2.0,
                                 clock=ManualClock())
    res_a, _ = replay_open_loop(asvc, qpts, wids, arrivals)
    np.testing.assert_array_equal(res_a.ids, res.ids)
    np.testing.assert_array_equal(res_a.stop_levels, res.stop_levels)
    np.testing.assert_array_equal(res_a.n_checked, res.n_checked)


@pytest.mark.slow_parity
def test_compacted_state_bit_equals_fresh_union_state(parity_setup):
    """The compacted device state itself (codes, vectors, n_valid) equals
    a fresh ``build_group_state`` over the union corpus at the same
    capacity — the strongest form of the parity claim."""
    p, data, weights, host, plan, _ = parity_setup
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    m = 12
    rng = np.random.default_rng(5)
    extra = (
        data[rng.choice(len(data), m, replace=False)]
        + rng.normal(0, 3.0, (m, plan.d))
    ).astype(np.float32)
    svc = _streaming_service(plan, data, reserve=32, seal_rows=4)
    for j in range(m):
        svc.insert(extra[j], w_in)
    svc.compact()

    from repro.index.builder import build_group_state, seal_segment

    cfg = svc.group_config(gi)
    sealed_codes = seal_segment(cfg, plan.groups[gi], extra)
    fresh = build_group_state(
        svc.mesh, cfg, data, plan.groups[gi],
        extra_points=extra, extra_codes=sealed_codes,
    )
    got = svc.state_cache.acquire(gi)
    try:
        assert int(got.n_valid) == int(fresh.n_valid) == plan.n + m
        np.testing.assert_array_equal(
            np.asarray(got.codes), np.asarray(fresh.codes)
        )
        np.testing.assert_array_equal(
            np.asarray(got.points, np.float32),
            np.asarray(fresh.points, np.float32),
        )
    finally:
        svc.state_cache.release(gi)


# ------------------------------------------------------------ tombstone purge


def test_purge_drops_tombstones_and_reclaims_capacity(setup):
    """compact(purge=True): tombstoned rows (base and inserted, compacted
    and pending) leave the states, their n_valid capacity is reclaimed,
    the tombstone set is cleared, and no query step recompiles."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=4, reserve=64)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    pids = [svc.insert(_far_vector(data, j, 21), w_in) for j in range(8)]
    svc.compact()  # absorb them, then tombstone a few
    q = data[11].astype(np.float32)
    victim_base = int(svc.query(q[None], [0]).ids[0][0])
    svc.delete(victim_base)
    svc.delete(pids[2])
    extra = [svc.insert(_far_vector(data, j, 23), w_in) for j in range(3)]
    svc.delete(extra[1])  # a still-pending insert, tombstoned
    n_compiled0 = svc.step_cache.n_compiled
    with svc.state_cache.lease(gi) as st:
        nv_before = int(st.n_valid)

    absorbed = svc.compact(purge=True)
    assert absorbed == 2  # the two surviving pending inserts

    d = svc.delta_summary()
    assert d["n_tombstones"] == 0  # the set is cleared...
    assert d["n_purges"] == 1 and d["n_rows_purged"] >= 3
    assert d["n_base_live"] == plan.n - 1
    assert d["n_pending"] == 0
    assert svc.step_cache.n_compiled == n_compiled0
    with svc.state_cache.lease(gi) as st:
        # 8 compacted - 1 purged + 2 surviving pending - 1 purged base
        assert int(st.n_valid) == nv_before - 1 - 1 + 2
    # ...and deleted rows are *gone*, not filtered: every group rebuilt
    assert svc.cache_summary()["n_invalidations"] >= plan.n_groups
    r = svc.query(q[None], [0])
    assert victim_base not in r.ids[0]
    for j, pid in enumerate(pids):
        r = svc.query(_far_vector(data, j, 21)[None], [w_in])
        if j == 2:
            assert pid not in r.ids[0]
        else:
            assert r.ids[0][0] == pid and r.dists[0][0] == 0.0
    assert svc.query(
        _far_vector(data, 0, 23)[None], [w_in]
    ).ids[0][0] == extra[0]
    assert extra[1] not in svc.query(
        _far_vector(data, 1, 23)[None], [w_in]
    ).ids[0]
    # plan lineage: the purge bumps the version, and the epoch covers
    # every minted id — including the tombstoned pending insert that was
    # dropped instead of absorbed — so a resumed service never reuses one
    assert svc.plan.version >= 2 and svc.plan.corpus_epoch == plan.n + 11
    # a per-group purge is rejected (tombstones are global)
    with pytest.raises(ValueError, match="purge"):
        svc.compact(group=gi, purge=True)


def test_purge_survives_eviction_and_continues_streaming(setup):
    """Post-purge cold rebuilds (discard-mode paging) must reproduce the
    purged corpus — never resurrect dropped rows — and later inserts /
    compactions keep working against the purged base."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, cap=1, offload=False,
                             seal_rows=4, reserve=64)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    pid = svc.insert(_far_vector(data, 0, 27), w_in)
    q = data[11].astype(np.float32)
    victim_base = int(svc.query(q[None], [0]).ids[0][0])
    svc.delete(victim_base)
    svc.compact(purge=True)
    # page the purged group out by touching every other group
    for other in range(plan.n_groups):
        if other != gi:
            wo = int(plan.groups[other].member_ids[0])
            svc.query(data[1][None].astype(np.float32), [wo])
    assert not svc.state_cache.is_resident(gi)
    r = svc.query(_far_vector(data, 0, 27)[None], [w_in])
    assert r.ids[0][0] == pid and r.dists[0][0] == 0.0
    assert victim_base not in svc.query(q[None], [0]).ids[0]
    # streaming continues on the purged base: insert -> compact -> exact
    pid2 = svc.insert(_far_vector(data, 1, 29), w_in)
    assert svc.compact() == 1
    r = svc.query(_far_vector(data, 1, 29)[None], [w_in])
    assert r.ids[0][0] == pid2 and r.dists[0][0] == 0.0


def test_failed_purge_commits_nothing(setup):
    """The purge is transactional: a capacity overflow raises the same
    explicit delta_reserve_rows error as ordinary compaction *before*
    any state is replaced — tombstones, logs and answers are unchanged."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=2, reserve=4)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    pids = [svc.insert(_far_vector(data, j, 31), w_in) for j in range(6)]
    svc.delete(0)  # a base tombstone so the purge can't degrade to compact
    with pytest.raises(ValueError, match="delta_reserve_rows"):
        svc.compact(purge=True)
    d = svc.delta_summary()
    assert d["n_purges"] == 0 and d["n_tombstones"] == 1
    assert d["n_base_live"] == plan.n
    assert svc.cache_summary()["n_invalidations"] == 0  # nothing committed
    r = svc.query(_far_vector(data, 2, 31)[None], [w_in])
    assert r.ids[0][0] == pids[2]  # rows keep serving from the exact scan


def test_purge_without_tombstones_degrades_to_compact(setup):
    """With nothing to drop, purge=True must not rebuild every group —
    it delegates to the ordinary append-based full compact."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=2, reserve=16)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    svc.insert(_far_vector(data, 0, 33), w_in)
    svc.insert(_far_vector(data, 1, 33), w_in)
    assert svc.compact(purge=True) == 2
    d = svc.delta_summary()
    assert d["n_purges"] == 0  # no sweep happened...
    assert d["n_compactions"] == 1  # ...just the ordinary compaction
    assert svc.cache_summary()["n_invalidations"] == 1  # one group touched


def test_identity_purge_rebuilds_only_affected_groups(setup):
    """With the base corpus untouched, a purge rebuilds only groups that
    actually drop a row; everyone else keeps their cached state (sealed
    backlogs take the ordinary append path)."""
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=4, reserve=64)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    other = int(np.argmin(
        [g.n_members if g2 != gi else 10**9
         for g2, g in enumerate(plan.groups)]
    ))
    w_other = int(plan.groups[other].member_ids[0])
    pids = [svc.insert(_far_vector(data, j, 41), w_in) for j in range(4)]
    svc.compact(gi)
    pid_other = svc.insert(_far_vector(data, 0, 43), w_other)
    svc.delete(pids[1])  # only group gi drops a row
    inval0 = {g: svc.stats[g].n_state_invalidations
              for g in range(plan.n_groups)}
    svc.compact(purge=True)
    # gi rebuilt (one replace); `other` only absorbed its sealed row
    # (ordinary append compaction); every untouched group: zero churn
    for g in range(plan.n_groups):
        delta = svc.stats[g].n_state_invalidations - inval0[g]
        assert delta == (1 if g in (gi, other) else 0), (g, delta)
    assert svc.delta_summary()["n_tombstones"] == 0
    assert pids[1] not in svc.query(
        _far_vector(data, 1, 41)[None], [w_in]
    ).ids[0]
    assert svc.query(
        _far_vector(data, 0, 43)[None], [w_other]
    ).ids[0][0] == pid_other
    # ...and the optimization survives an earlier base-dropping purge:
    # the next purge compares against the *current* surviving base, so a
    # single-group insert tombstone again touches only that group
    victim_base = int(svc.query(
        data[11][None].astype(np.float32), [0]
    ).ids[0][0])
    svc.delete(victim_base)
    svc.compact(purge=True)  # drops a base row: every group rebuilds
    pid3 = svc.insert(_far_vector(data, 5, 47), w_in)
    svc.compact(gi)
    svc.delete(pid3)
    inval1 = {g: svc.stats[g].n_state_invalidations
              for g in range(plan.n_groups)}
    svc.compact(purge=True)
    for g in range(plan.n_groups):
        delta = svc.stats[g].n_state_invalidations - inval1[g]
        assert delta == (1 if g == gi else 0), (g, delta)


@pytest.mark.slow_parity
def test_purged_state_bit_equals_fresh_surviving_build(parity_setup):
    """Acceptance: the purged state (codes, vectors, n_valid) is bit-exact
    with a fresh ``build_group_state`` over the surviving corpus (live
    base rows + surviving inserts), per p in {2, 1, 0.5}."""
    p, data, weights, host, plan, _ = parity_setup
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    m = 12
    rng = np.random.default_rng(13)
    extra = (
        data[rng.choice(len(data), m, replace=False)]
        + rng.normal(0, 3.0, (m, plan.d))
    ).astype(np.float32)
    svc = _streaming_service(plan, data, reserve=32, seal_rows=4)
    pids = [svc.insert(extra[j], w_in) for j in range(m)]
    svc.compact()
    drop_base = [3, 77]
    drop_ins = [1, 6]
    for b in drop_base:
        svc.delete(b)
    for j in drop_ins:
        svc.delete(pids[j])
    svc.compact(purge=True)

    from repro.index.builder import build_group_state, seal_segment

    cfg = svc.group_config(gi)
    surv_base = np.setdiff1d(
        np.arange(plan.n, dtype=np.int64), drop_base
    )
    keep = [j for j in range(m) if j not in drop_ins]
    surv_vecs = extra[keep]
    sealed_codes = seal_segment(cfg, plan.groups[gi], surv_vecs)
    fresh = build_group_state(
        svc.mesh, cfg, data, plan.groups[gi],
        extra_points=surv_vecs, extra_codes=sealed_codes,
        base_rows=surv_base,
    )
    got = svc.state_cache.acquire(gi)
    try:
        assert int(got.n_valid) == int(fresh.n_valid)
        assert int(got.n_valid) == plan.n - len(drop_base) + len(keep)
        np.testing.assert_array_equal(
            np.asarray(got.codes), np.asarray(fresh.codes)
        )
        np.testing.assert_array_equal(
            np.asarray(got.points, np.float32),
            np.asarray(fresh.points, np.float32),
        )
    finally:
        svc.state_cache.release(gi)
    # surviving rows answer bit-exactly through the compiled path
    for j in keep:
        r = svc.query(extra[j][None], [w_in])
        assert r.ids[0][0] == pids[j] and r.dists[0][0] == 0.0
    for j in drop_ins:
        assert pids[j] not in svc.query(extra[j][None], [w_in]).ids[0]


# --------------------------------------------------------- plan versioning


def test_plan_version_round_trips_npz(tmp_path, setup):
    data, weights, host, plan, _ = setup
    assert plan.version == 0 and plan.corpus_epoch == plan.n
    bumped = plan.bumped(40)
    assert bumped.version == 1 and bumped.corpus_epoch == plan.n + 40
    path = str(tmp_path / "plan_v.npz")
    bumped.save_npz(path)
    loaded = ServingPlan.load_npz(path)
    assert loaded.version == 1
    assert loaded.corpus_epoch == plan.n + 40


def test_compaction_advances_the_served_plan(setup):
    data, weights, host, plan, _ = setup
    svc = _streaming_service(plan, data, seal_rows=4, auto=1)
    w_in = int(plan.groups[0].member_ids[0])
    for j in range(8):
        svc.insert(_far_vector(data, j, 11), w_in)
    assert svc.plan.version == 2  # two auto-compactions
    assert svc.plan.corpus_epoch == plan.n + 8
    # a service resumed from the advanced plan continues the id space
    svc2 = _streaming_service(svc.plan, data)
    pid = svc2.insert(_far_vector(data, 0, 12), w_in)
    assert pid == plan.n + 8


# ------------------------------------------------- hot-path micro-structure


def test_memtable_vectors_cached_no_recopy():
    """The stacked delta matrix is built once per write epoch: repeated
    reads return the *same* array object (no O(m*d) re-stack per scan),
    writes invalidate, and the shared array is read-only."""
    from repro.index.streaming import DeltaSegment

    seg = DeltaSegment(4)
    empty = seg.vectors
    assert empty.shape == (0, 4) and seg.vectors is empty
    seg.append(10, np.arange(4, dtype=np.float32))
    seg.append(11, np.arange(4, dtype=np.float32) + 1)
    v1 = seg.vectors
    assert v1 is seg.vectors  # identity: no copy on the read path
    assert not v1.flags.writeable  # shared across reads, so frozen
    np.testing.assert_array_equal(v1[1], np.arange(4, dtype=np.float32) + 1)
    seg.append(12, np.arange(4, dtype=np.float32) + 2)
    v2 = seg.vectors
    assert v2 is not v1 and v2.shape == (3, 4)  # append invalidates
    ids, vecs = seg.drain()
    assert vecs is v2 and ids.tolist() == [10, 11, 12]
    assert seg.vectors is not v2 and seg.vectors.shape == (0, 4)


def _scan_topk_reference(queries, q_weights, ids, vectors, p, k):
    """The pre-optimization scan_topk: full (Q, m) stable argsort."""
    from repro.index.streaming import exact_weighted_lp

    queries = np.atleast_2d(np.asarray(queries, np.float32))
    nq = len(queries)
    out_ids = np.full((nq, k), -1, np.int64)
    out_d = np.full((nq, k), np.inf, np.float32)
    m = len(ids)
    if m == 0:
        return out_ids, out_d
    dists = exact_weighted_lp(queries, vectors, q_weights, p)
    take = min(k, m)
    order = np.argsort(dists, axis=1, kind="stable")[:, :take]
    out_ids[:, :take] = np.asarray(ids, np.int64)[order]
    out_d[:, :take] = np.take_along_axis(dists, order, axis=1)
    return out_ids, out_d


@pytest.mark.parametrize("p", [2.0, 1.0, 0.5])
@pytest.mark.parametrize("m,k", [(0, 5), (3, 5), (64, 5), (64, 64), (7, 7)])
def test_scan_topk_bit_identical_to_stable_argsort(p, m, k):
    """The argpartition fast path returns bit-identical ids *and* dists
    to the full stable argsort it replaced — including insertion-order
    tie-breaks from duplicated rows (equal distances under every query)."""
    from repro.index.streaming import scan_topk

    rng = np.random.default_rng(97)
    d = 6
    vecs = rng.normal(0, 5, (max(m, 1), d)).astype(np.float32)[:m]
    if m >= 8:
        vecs[5] = vecs[1]  # exact duplicates: distance ties every query
        vecs[7] = vecs[1]
        vecs[6] = vecs[2]
    ids = rng.permutation(10 * max(m, 1))[:m].astype(np.int64)
    q = rng.normal(0, 5, (4, d)).astype(np.float32)
    q[2] = vecs[0] if m else 0.0  # a zero-distance hit
    w = rng.uniform(0.25, 2.0, (4, d)).astype(np.float32)
    got_i, got_d = scan_topk(q, w, ids, vecs, p, k)
    want_i, want_d = _scan_topk_reference(q, w, ids, vecs, p, k)
    np.testing.assert_array_equal(got_i, want_i)
    np.testing.assert_array_equal(
        got_d.view(np.uint32), want_d.view(np.uint32)
    )


# ------------------------------------------------------- merge_topk helper


@st.composite
def _merge_case(draw):
    k = draw(st.integers(1, 6))
    na = draw(st.integers(0, 8))
    nb = draw(st.integers(0, 6))
    a_d = sorted(draw(st.lists(
        st.floats(0, 100, allow_nan=False, width=32),
        min_size=na, max_size=na,
    )))
    b_d = sorted(draw(st.lists(
        st.floats(0, 100, allow_nan=False, width=32),
        min_size=nb, max_size=nb,
    )))
    n_drop = draw(st.integers(0, 4))
    return k, a_d, b_d, n_drop


@given(_merge_case())
@settings(max_examples=100, deadline=None)
def test_merge_topk_invariants_property(case):
    """Sorted output, no dropped/duplicated/invented candidate, tombstones
    filtered with backfill, missing slots -1/inf at the tail."""
    k, a_d, b_d, n_drop = case
    ka = max(len(a_d), 1)
    ids_a = np.full((1, ka), -1, np.int64)
    d_a = np.full((1, ka), np.inf, np.float32)
    ids_a[0, :len(a_d)] = np.arange(len(a_d))  # indexed ids 0..
    d_a[0, :len(a_d)] = a_d
    kb = max(len(b_d), 1)
    ids_b = np.full((1, kb), -1, np.int64)
    d_b = np.full((1, kb), np.inf, np.float32)
    ids_b[0, :len(b_d)] = 1_000 + np.arange(len(b_d))  # disjoint delta ids
    d_b[0, :len(b_d)] = b_d
    drop = set(range(0, n_drop)) | {1_000}  # tombstone some of each
    out_ids, out_d = merge_topk(ids_a, d_a, ids_b, d_b, k, drop=drop)
    assert out_ids.shape == (1, k) and out_d.shape == (1, k)
    finite = out_d[0][np.isfinite(out_d[0])]
    assert np.all(np.diff(finite) >= 0)  # sorted ascending
    valid = out_ids[0][out_ids[0] >= 0]
    assert len(set(valid.tolist())) == len(valid)  # no duplicates
    assert not (set(valid.tolist()) & drop)  # tombstones never surface
    # every surfaced id existed in an input with its own distance
    pool = {int(i): float(d) for i, d in zip(ids_a[0], d_a[0]) if i >= 0}
    pool.update(
        {int(i): float(d) for i, d in zip(ids_b[0], d_b[0]) if i >= 0}
    )
    for i, d in zip(out_ids[0], out_d[0]):
        if i >= 0:
            assert pool[int(i)] == pytest.approx(float(d))
    # survivors are exactly the k best non-dropped candidates
    best = sorted(
        (d for i, d in pool.items() if i not in drop)
    )[:k]
    assert list(np.sort(finite)) == pytest.approx(best)


def test_merge_topk_passthrough_is_bit_exact():
    ids = np.array([[4, 9, -1]], np.int32)
    d = np.array([[1.5, 2.5, np.inf]], np.float32)
    empty_i = np.full((1, 0), -1, np.int64)
    empty_d = np.full((1, 0), np.inf, np.float32)
    out_ids, out_d = merge_topk(ids, d, empty_i, empty_d, 3)
    np.testing.assert_array_equal(out_ids, ids)
    np.testing.assert_array_equal(out_d, d)
    # distance ties prefer the indexed operand
    tie_i = np.array([[77]], np.int64)
    tie_d = np.array([[1.5]], np.float32)
    out_ids, _ = merge_topk(ids, d, tie_i, tie_d, 3)
    assert out_ids[0].tolist() == [4, 77, 9]
