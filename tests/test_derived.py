"""Theorem 1 bounds for derived weighted LSH families (the paper's core).

The central property test: for any x, y, W, W' with D_{W'}(x,y) <= R it must
hold that D_W(x,y) <= R^up, and for D_{W'}(x,y) >= cR it must hold that
D_W(x,y) >= (cR)^down.  This is exactly the correctness condition the WLSH
parameter planning relies on.
"""

from __future__ import annotations

import numpy as np
import pytest
from _hyp import given, st

from repro.core.derived import angular_bounds, derived_sensitivity, ratio_bounds
from repro.core.distances import (
    weighted_angular_np,
    weighted_lp_np,
)

_dim = st.integers(2, 16)


@st.composite
def _pair_weights_points(draw):
    d = draw(_dim)
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    W = rng.uniform(1.0, 10.0, d)
    Wp = rng.uniform(1.0, 10.0, d)
    x = rng.uniform(0, 1000.0, d)
    y = rng.uniform(0, 1000.0, d)
    return W, Wp, x, y


@given(_pair_weights_points(), st.sampled_from([0.5, 1.0, 1.5, 2.0]))
def test_theorem1_lp_bounds(pack, p):
    W, Wp, x, y = pack
    hi, lo = ratio_bounds(W, Wp[None, :])
    hi, lo = float(hi[0]), float(lo[0])
    d_wp = float(weighted_lp_np(x, y, Wp, p))
    d_w = float(weighted_lp_np(x, y, W, p))
    # R^up with R = D_{W'}(x,y):  D_W <= D_{W'} * max_i(w_i/w'_i)
    assert d_w <= d_wp * hi * (1 + 1e-9)
    # (cR)^down with cR = D_{W'}(x,y):  D_W >= D_{W'} * min_i(w_i/w'_i)
    assert d_w >= d_wp * lo * (1 - 1e-9)


def test_ratio_bounds_vectorized_matches_loop():
    rng = np.random.default_rng(0)
    W = rng.uniform(1, 10, 12)
    T = rng.uniform(1, 10, (40, 12))
    hi, lo = ratio_bounds(W, T)
    ref = W[None, :] / T
    np.testing.assert_allclose(hi, ref.max(axis=1), rtol=1e-6)
    np.testing.assert_allclose(lo, ref.min(axis=1), rtol=1e-6)


@pytest.mark.parametrize("v", [1, 2, 4])
def test_bound_relaxation_order_statistics(v):
    rng = np.random.default_rng(1)
    W = rng.uniform(1, 10, 16)
    T = rng.uniform(1, 10, (10, 16))
    hi, lo = ratio_bounds(W, T, v=v, v_prime=v)
    ratios = np.sort(W[None, :] / T, axis=1)
    np.testing.assert_allclose(hi, ratios[:, -v], rtol=1e-6)
    np.testing.assert_allclose(lo, ratios[:, v - 1], rtol=1e-6)


def test_relaxation_tightens_with_v():
    """v > 1 gives hi' <= hi and lo' >= lo -> smaller beta (Eq. 11)."""
    rng = np.random.default_rng(2)
    W = rng.uniform(1, 10, 32)
    T = rng.uniform(1, 10, (20, 32))
    hi1, lo1 = ratio_bounds(W, T, v=1, v_prime=1)
    hi4, lo4 = ratio_bounds(W, T, v=4, v_prime=4)
    assert np.all(hi4 <= hi1 + 1e-12)
    assert np.all(lo4 >= lo1 - 1e-12)


def test_derived_sensitivity_usefulness():
    # identical weights: x_up = x < y_down = y -> useful
    x_up, y_down, useful = derived_sensitivity(
        np.array([1.0]), np.array([3.0]), np.array([1.0]), np.array([1.0])
    )
    assert useful[0] and x_up[0] == 1.0 and y_down[0] == 3.0
    # wildly different weights: hi/lo spread kills usefulness
    _, _, useless = derived_sensitivity(
        np.array([1.0]), np.array([3.0]), np.array([10.0]), np.array([0.1])
    )
    assert not useless[0]


def test_self_derivation_is_exact():
    """H_{W->W} must recover the underlying family: hi == lo == 1."""
    rng = np.random.default_rng(3)
    W = rng.uniform(1, 10, 24)
    hi, lo = ratio_bounds(W, W[None, :])
    np.testing.assert_allclose(hi, 1.0, rtol=1e-9)
    np.testing.assert_allclose(lo, 1.0, rtol=1e-9)


@given(_pair_weights_points())
def test_theorem1_angular_bounds(pack):
    W, Wp, x, y = pack
    R = float(weighted_angular_np(x, y, Wp))
    if R < 1e-6 or R > np.pi - 1e-6:
        return
    d_w = float(weighted_angular_np(x, y, W))
    r_up, _ = angular_bounds(W, Wp, R, c=2.0)
    assert d_w <= r_up + 1e-7
    # lower bound at cR: use cR = the actual distance (R' := R/c)
    _, cr_down = angular_bounds(W, Wp, R / 2.0, c=2.0)
    assert d_w >= cr_down - 1e-7
