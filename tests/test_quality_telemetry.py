"""Online quality telemetry: shadow-exact recall, alerting, sentinel.

Pinned claims:

* the deterministic sampler is a pure function of the query id: rate 0
  samples nothing, rate 1 everything, and the sampled set is monotone
  in the rate (every id sampled at r stays sampled at every r' >= r) —
  as hypothesis properties;
* the same corpus + seed + rate yields the *identical* sampled
  query-id set across the sync, async and driver-stepped frontends,
  and the online micro-averaged recall estimate equals an offline
  exact-oracle recomputation on that sample bit-for-bit;
* turning recall sampling on changes no served answer — ids, dists,
  stop levels and n_checked are bit-exact vs the sampling-off service;
* a full shadow queue drops (and counts) sampled jobs instead of
  growing unbounded, and offers always equal executions + drops;
* the HealthMonitor implements multi-window burn-rate semantics: a
  sustained bad ratio must exceed the threshold over BOTH the fast and
  slow windows to fire, recovery clears the alert promptly, gauge
  rules respect their consecutive-tick streak, and alert events are
  edge-triggered, ring-retained and JSONL-exportable;
* the bench-regression sentinel passes metrics equal to their
  baseline, fails direction-aware on a worsened metric beyond its
  band, tolerates improvements, and flags a disappeared metric.

No wall-clock sleeps anywhere: replays run on ManualClock and the
monitor's windows are counted in ticks.
"""

from __future__ import annotations

import json

import numpy as np
import pytest

from _hyp import given, settings, st
from benchmarks import sentinel
from repro.obs import (
    AlertRule,
    HealthMonitor,
    MetricsRegistry,
    default_rules,
    sample_hash,
    should_sample,
)
from repro.serving import (
    AsyncRetrievalService,
    ManualClock,
    RetrievalService,
    ServiceConfig,
    ServiceDriver,
    replay_open_loop,
)
from conftest import build_parity_service
from repro.serving.scheduler import replay_with_driver

K = 5
Q_BATCH = 4
RATE = 0.5


def _traffic(data, weights, n_queries, seed=61):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def _sampling_service(plan, data, **cfg_kw):
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=Q_BATCH,
                          recall_sample_rate=RATE, **cfg_kw),
    )
    svc.warmup()
    return svc


def _offline_recall(est, qpts, wids, results_by_qid) -> float:
    """Micro-averaged oracle recall over the estimator's executed ids."""
    hits = rel = 0
    for qid in est.executed_ids():
        ids, gid = results_by_qid[qid]
        exact = est.oracle_topk(qpts[qid], int(wids[qid]), gid)
        exact_set = {int(i) for i in exact if i >= 0}
        served = {int(i) for i in np.asarray(ids).reshape(-1) if i >= 0}
        hits += len(served & exact_set)
        rel += len(exact_set)
    return hits / rel if rel else float("nan")


# ------------------------------------------------------- deterministic sampler


def test_sampler_rate_edges():
    ids = range(1_000)
    assert not any(should_sample(i, 0.0) for i in ids)
    assert not any(should_sample(i, -0.5) for i in ids)
    assert all(should_sample(i, 1.0) for i in ids)
    assert all(should_sample(i, 2.0) for i in ids)


@settings(max_examples=50)
@given(qid=st.integers(min_value=0, max_value=2**62),
       rate=st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
def test_sampler_is_pure_function_of_id(qid, rate):
    assert should_sample(qid, rate) == should_sample(qid, rate)
    assert sample_hash(qid) == sample_hash(qid)


@settings(max_examples=50)
@given(lo=st.floats(min_value=0.0, max_value=1.0, allow_nan=False),
       hi=st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
def test_sampled_set_monotone_in_rate(lo, hi):
    lo, hi = min(lo, hi), max(lo, hi)
    ids = range(512)
    at_lo = {i for i in ids if should_sample(i, lo)}
    at_hi = {i for i in ids if should_sample(i, hi)}
    assert at_lo <= at_hi


def test_sampler_hits_the_configured_fraction():
    # splitmix64 is uniform enough that the realized fraction over a
    # contiguous id range tracks the rate closely
    n = 4_096
    for rate in (0.1, 0.3, 0.5, 0.9):
        got = sum(should_sample(i, rate) for i in range(n)) / n
        assert abs(got - rate) < 0.05


# ------------------------------------- frontends: determinism and bit-exactness


@pytest.mark.parametrize("p", [2.0], ids=["p2.0"])
def test_sampling_on_is_bit_exact_and_matches_offline_oracle(p):
    _, data, weights, host, plan, base_svc = build_parity_service(p)
    qpts, wids = _traffic(data, weights, 28)
    ref = base_svc.query(qpts, wids)  # sampling off

    svc = _sampling_service(plan, data)
    res = svc.query(qpts, wids)
    assert np.array_equal(res.ids, ref.ids)
    assert np.array_equal(res.dists, ref.dists)
    assert np.array_equal(res.stop_levels, ref.stop_levels)
    assert np.array_equal(res.n_checked, ref.n_checked)

    est = svc.batcher.recall
    assert est.backlog > 0  # serving only enqueued; nothing executed
    est.drain()
    sampled = sorted(est.executed_ids())
    # the sampled set is exactly the hash-selected subset of query ids
    # (the sync tracer assigns ids 0..n-1 in submission order)
    assert sampled == [i for i in range(len(qpts))
                       if should_sample(i, RATE)]
    results = {qi: (ref.ids[qi], int(ref.group_ids[qi]))
               for qi in range(len(qpts))}
    assert est.estimate() == _offline_recall(est, qpts, wids, results)
    s = est.summary()
    assert s["n_sampled"] == s["n_executed"] == len(sampled)
    assert s["n_dropped"] == 0 and s["backlog"] == 0


def test_sync_async_driver_sample_identical_sets():
    _, data, weights, host, plan, _ = build_parity_service(2.0)
    qpts, wids = _traffic(data, weights, 24)
    arrivals = np.cumsum(
        np.random.default_rng(7).exponential(1 / 2_000.0, len(qpts)))

    sync_svc = _sampling_service(plan, data)
    sync_res = sync_svc.query(qpts, wids)
    sync_svc.batcher.recall.drain()
    sync_ids = sorted(sync_svc.batcher.recall.executed_ids())
    sync_est = sync_svc.batcher.recall.estimate()

    async_svc = _sampling_service(plan, data)
    asvc = AsyncRetrievalService(async_svc, clock=ManualClock())
    replay_open_loop(asvc, qpts, wids, arrivals)
    async_svc.batcher.recall.drain()
    assert sorted(async_svc.batcher.recall.executed_ids()) == sync_ids
    assert async_svc.batcher.recall.estimate() == sync_est

    drv_svc = _sampling_service(plan, data)
    dsvc = AsyncRetrievalService(drv_svc, clock=ManualClock())
    driver = ServiceDriver(dsvc)
    res, _ = replay_with_driver(driver, qpts, wids, arrivals)
    est = drv_svc.batcher.recall
    n_idle_drained = len(est.executed_ids())
    est.drain()
    assert sorted(est.executed_ids()) == sync_ids
    assert est.estimate() == sync_est
    # the driver's idle ticks executed shadow work during the replay
    assert n_idle_drained > 0
    # and the driven answers are the sync answers bit-for-bit
    assert np.array_equal(res.ids, sync_res.ids)
    assert np.array_equal(res.n_checked, sync_res.n_checked)


def test_sampled_spans_carry_their_shadow_recall():
    _, data, weights, host, plan, _ = build_parity_service(2.0)
    qpts, wids = _traffic(data, weights, 16)
    svc = _sampling_service(plan, data)
    svc.query(qpts, wids)
    est = svc.batcher.recall
    est.drain()
    sampled = set(est.executed_ids())
    for span in svc.batcher.tracer.spans():
        if span.query_id in sampled:
            assert 0.0 <= span.recall <= 1.0
        else:
            assert span.recall == -1.0  # not sampled


def test_full_shadow_queue_drops_and_counts():
    _, data, weights, host, plan, _ = build_parity_service(2.0)
    qpts, wids = _traffic(data, weights, 24)
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=Q_BATCH,
                          recall_sample_rate=1.0, recall_shadow_max=4),
    )
    svc.warmup()
    svc.query(qpts, wids)
    est = svc.batcher.recall
    assert est.backlog == 4  # capped, never above shadow_max
    est.drain()
    s = est.summary()
    assert s["n_sampled"] == len(qpts)  # every query hashed in
    assert s["n_executed"] == 4
    assert s["n_dropped"] == len(qpts) - 4
    assert s["n_sampled"] == s["n_executed"] + s["n_dropped"]


def test_recall_sample_rate_implies_obs_and_validates():
    cfg = ServiceConfig(recall_sample_rate=0.25)
    assert cfg.obs  # sampling keys on tracer query ids
    with pytest.raises(ValueError, match="recall_sample_rate"):
        ServiceConfig(recall_sample_rate=1.5)
    with pytest.raises(ValueError, match="recall_shadow_max"):
        ServiceConfig(recall_shadow_max=0)
    with pytest.raises(ValueError, match="recall_floor"):
        ServiceConfig(recall_floor=-0.1)


# ------------------------------------------------------------- health monitor


def _burn_monitor(threshold=0.25, fast=4, slow=10, min_events=1):
    reg = MetricsRegistry()
    bad = reg.counter("wlsh_bad_total")
    due = reg.counter("wlsh_due_total")
    mon = HealthMonitor(reg, [AlertRule(
        name="burn", kind="burn_ratio", threshold=threshold,
        numerator="wlsh_bad_total", denominator="wlsh_due_total",
        fast_window=fast, slow_window=slow, min_events=min_events)])
    return reg, bad, due, mon


def test_burn_rule_needs_both_windows_hot():
    # seed healthy history first: with an empty window even a short
    # spike reads as ratio 1.0 over both windows (correctly — there is
    # no good history to dilute it), which would mask the multi-window
    # distinction this test pins
    _, bad, due, mon = _burn_monitor()
    t = 0.0
    for _ in range(10):  # healthy: deadlines due, none missed
        due.inc()
        mon.observe(t := t + 1.0)
    assert mon.firing() == []
    # 2 hot ticks: fast ratio 2/4 > 0.25, slow ratio 2/12 < 0.25
    for _ in range(2):
        bad.inc()
        due.inc()
        mon.observe(t := t + 1.0)
    assert mon.firing() == []  # slow window still healthy: no page
    # sustain the burn until the slow window crosses too
    fired = []
    for _ in range(6):
        bad.inc()
        due.inc()
        fired += mon.observe(t := t + 1.0)
    assert [a.rule for a in mon.firing()] == ["burn"]
    assert len(fired) == 1  # edge-triggered: one event, not per-tick
    assert fired[0].value_fast > 0.25 and fired[0].value > 0.25
    # recovery: the fast window clears the alert promptly
    for _ in range(5):
        due.inc()
        mon.observe(t := t + 1.0)
    assert mon.firing() == []
    reg = mon.metrics
    assert reg.counter("wlsh_alerts_fired_total").total() == 1
    assert reg.counter("wlsh_alerts_cleared_total").total() == 1


def test_burn_rule_min_events_gate():
    _, bad, due, mon = _burn_monitor(min_events=4)
    t = 0.0
    bad.inc()
    due.inc()  # ratio 1.0 but only 1 event: unjudgeable
    mon.observe(t := t + 1.0)
    assert mon.firing() == []
    for _ in range(3):
        bad.inc()
        due.inc()
        mon.observe(t := t + 1.0)
    assert [a.rule for a in mon.firing()] == ["burn"]


def test_gauge_rules_streak_and_edges():
    reg = MetricsRegistry()
    g = reg.gauge("wlsh_margin")
    mon = HealthMonitor(reg, [AlertRule(
        name="below", kind="gauge_below", threshold=0.0,
        gauge="wlsh_margin", for_ticks=2)])
    t = 0.0
    g.set(0.5, rung="0")
    mon.observe(t := t + 1.0)
    assert mon.firing() == []
    g.set(-0.1, rung="1")  # the worst series decides (min over series)
    mon.observe(t := t + 1.0)
    assert mon.firing() == []  # streak 1 < for_ticks 2
    mon.observe(t := t + 1.0)
    assert [a.rule for a in mon.firing()] == ["below"]
    g.set(0.2, rung="1")  # one good tick resets the streak
    mon.observe(t := t + 1.0)
    assert mon.firing() == []


def test_gauge_above_rule_and_export(tmp_path):
    reg = MetricsRegistry()
    depth = reg.gauge("wlsh_depth")
    mon = HealthMonitor(reg, [AlertRule(
        name="sat", kind="gauge_above", threshold=10.0,
        gauge="wlsh_depth", for_ticks=1, severity="warn")])
    depth.set(11.0)
    fired = mon.observe(3.5)
    assert [a.rule for a in fired] == ["sat"]
    assert fired[0].severity == "warn" and fired[0].t_fired == 3.5
    path = tmp_path / "alerts.jsonl"
    assert mon.export_jsonl(path) == 1
    lines = [json.loads(x) for x in path.read_text().splitlines()]
    assert lines[0]["rule"] == "sat" and lines[0]["value"] == 11.0
    s = mon.summary()
    assert s["rules"]["sat"]["fired"] == 1
    assert s["rules"]["sat"]["firing"] is True


def test_rule_validation_and_unique_names():
    with pytest.raises(ValueError, match="kind"):
        AlertRule(name="x", kind="weird", threshold=0.1)
    with pytest.raises(ValueError, match="numerator"):
        AlertRule(name="x", kind="burn_ratio", threshold=0.1)
    with pytest.raises(ValueError, match="fast_window"):
        AlertRule(name="x", kind="burn_ratio", threshold=0.1,
                  numerator="n", fast_window=9, slow_window=3)
    with pytest.raises(ValueError, match="gauge"):
        AlertRule(name="x", kind="gauge_below", threshold=0.1)
    reg = MetricsRegistry()
    rule = AlertRule(name="dup", kind="gauge_below", threshold=0.0,
                     gauge="g")
    with pytest.raises(ValueError, match="unique"):
        HealthMonitor(reg, [rule, rule])


def test_default_rules_shape():
    rules = default_rules()
    names = {r.name for r in rules}
    assert {"deadline_miss_burn", "tenant_slo_burn",
            "prefetch_waste_burn", "recall_below_bound"} <= names
    assert "queue_saturation" not in names  # needs a saturation point
    with_cap = default_rules(max_pending=100)
    sat = next(r for r in with_cap if r.name == "queue_saturation")
    assert sat.threshold == pytest.approx(90.0)
    # the stock set attaches to a registry without error
    HealthMonitor(MetricsRegistry(), with_cap)


def test_driver_surfaces_firing_alerts_in_tick_summary():
    _, data, weights, host, plan, _ = build_parity_service(2.0)
    qpts, wids = _traffic(data, weights, 12)
    svc = _sampling_service(plan, data)
    asvc = AsyncRetrievalService(svc, clock=ManualClock())
    # a rule that fires immediately: queue depth above -1 is always true
    mon = HealthMonitor(svc.batcher.metrics, [AlertRule(
        name="always", kind="gauge_above", threshold=-1.0,
        gauge="wlsh_pending_queue_depth", for_ticks=1)])
    driver = ServiceDriver(asvc, health=mon)
    arrivals = np.cumsum(
        np.random.default_rng(3).exponential(1 / 2_000.0, len(qpts)))
    replay_with_driver(driver, qpts, wids, arrivals)
    assert [a.rule for a in mon.firing()] == ["always"]
    assert "ALERTS: always" in driver.tick_summary()


# ---------------------------------------------------- bench-regression sentinel


_BASE = {
    "p50_step_ms": 10.0, "qps": 100.0, "state_hit_rate": 0.8,
    "deadline_miss_rate": 0.0, "observed_recall": 0.9,
    "n_compiled_steps": 4, "n_shadow_dropped": 0,
}


def test_sentinel_compare_equal_passes():
    rows = sentinel.compare(dict(_BASE), dict(_BASE))
    assert rows and all(r["ok"] for r in rows)


def test_sentinel_compare_direction_aware():
    # worsening beyond the band fails in the metric's bad direction
    cur = dict(_BASE, observed_recall=0.8)  # higher-better, -0.1
    assert any(not r["ok"] and r["metric"] == "observed_recall"
               for r in sentinel.compare(cur, _BASE))
    cur = dict(_BASE, p50_step_ms=30.0)  # lower-better, 3x baseline
    assert any(not r["ok"] and r["metric"] == "p50_step_ms"
               for r in sentinel.compare(cur, _BASE))
    # improvements never fail, however large
    cur = dict(_BASE, p50_step_ms=0.1, observed_recall=1.0, qps=9_999.0)
    assert all(r["ok"] for r in sentinel.compare(cur, _BASE))
    # small wall-clock noise stays inside the wide band
    cur = dict(_BASE, p50_step_ms=14.0, qps=80.0)
    assert all(r["ok"] for r in sentinel.compare(cur, _BASE))


def test_sentinel_compare_missing_metric_is_regression():
    cur = dict(_BASE)
    del cur["observed_recall"]
    rows = sentinel.compare(cur, _BASE)
    row = next(r for r in rows if r["metric"] == "observed_recall")
    assert not row["ok"] and row["current"] is None


def test_sentinel_cli_exit_codes(tmp_path):
    base = tmp_path / "BASELINE.json"
    out = tmp_path / "BENCH.json"
    cur = tmp_path / "current.json"
    cur.write_text(json.dumps({"metrics": _BASE}))
    # no baseline yet: exit 2
    assert sentinel.main(["--from-json", str(cur),
                          "--baseline", str(base),
                          "--out", str(out)]) == 2
    # pin a baseline: exit 0, both artifacts written
    assert sentinel.main(["--from-json", str(cur),
                          "--baseline", str(base),
                          "--out", str(out),
                          "--write-baseline"]) == 0
    assert json.loads(base.read_text())["metrics"] == _BASE
    assert json.loads(out.read_text())["metrics"] == _BASE
    # clean gate: exit 0
    assert sentinel.main(["--from-json", str(cur),
                          "--baseline", str(base),
                          "--out", str(out)]) == 0
    # injected regression: exit 1
    bad = dict(_BASE, n_compiled_steps=5)  # zero-tolerance metric
    worse = tmp_path / "worse.json"
    worse.write_text(json.dumps(bad))  # bare dict form also accepted
    assert sentinel.main(["--from-json", str(worse),
                          "--baseline", str(base),
                          "--out", str(out)]) == 1
