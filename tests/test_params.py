"""beta/mu planning math (Eqs. 4-5 and 11-12) + threshold reduction."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.params import (
    PlanConfig,
    beta_mu,
    threshold_reduction_factor,
    z_value,
)


def _cfg(**kw):
    base = dict(p=2.0, c=3.0, eps=0.01, gamma_n=100.0, n=400_000)
    base.update(kw)
    return PlanConfig(**base)


def test_z_value_paper_defaults():
    cfg = _cfg()
    z = z_value(cfg.eps, cfg.gamma)
    assert z == pytest.approx(
        np.sqrt(np.log(2.0 / cfg.gamma) / np.log(1.0 / cfg.eps))
    )
    assert z > 1.0  # paper regime


def test_beta_mu_c2lsh_case():
    """x_up = x, y_down = cx (no derivation) recovers C2LSH Eqs. 4-5."""
    cfg = _cfg()
    x = 1.0
    beta, mu, p1, p2 = beta_mu(x, cfg.c * x, width=1.0, cfg=cfg)
    assert np.isfinite(beta[0]) and beta[0] >= 1
    assert 0 < p2[0] < p1[0] < 1
    # mu must sit strictly between beta*P2 and beta*P1 (separation works)
    assert beta[0] * p2[0] < mu[0] < beta[0] * p1[0]


def test_beta_increases_with_n():
    b_small, *_ = beta_mu(1.0, 3.0, 1.0, _cfg(n=100_000))
    b_big, *_ = beta_mu(1.0, 3.0, 1.0, _cfg(n=1_600_000))
    assert b_big[0] >= b_small[0]


def test_beta_decreases_with_c():
    cfg2 = _cfg(c=2.0)
    cfg6 = _cfg(c=6.0)
    b2, *_ = beta_mu(1.0, 2.0, 1.0, cfg2)
    b6, *_ = beta_mu(1.0, 6.0, 1.0, cfg6)
    assert b6[0] <= b2[0]


def test_beta_grows_as_bounds_shrink():
    """Worse derived bounds (x_up closer to y_down) -> more tables."""
    cfg = _cfg()
    gaps = [(1.0, 3.0), (1.5, 2.5), (1.8, 2.2)]
    betas = [beta_mu(x, y, 1.0, cfg)[0][0] for x, y in gaps]
    assert betas[0] <= betas[1] <= betas[2]


def test_beta_infinite_when_useless():
    cfg = _cfg()
    beta, mu, _, _ = beta_mu(3.0, 1.0, 1.0, cfg)  # x_up > y_down
    assert np.isinf(beta[0]) and np.isinf(mu[0])


def test_beta_cap():
    cfg = _cfg()
    # a nearly-degenerate gap forces beta beyond any small cap
    beta, *_ = beta_mu(2.9, 3.0, 1.0, cfg, beta_cap=100)
    assert np.isinf(beta[0])


@pytest.mark.parametrize("p", [1.0, 2.0])
def test_threshold_reduction_below_one(p):
    x = threshold_reduction_factor(np.array([1.0, 2.0, 5.0]), 3.0, 1.0, p)
    assert np.all(x < 1.0) and np.all(x > 0.0)


def test_beta_log_n_scaling():
    """Paper Table 1: tables grow ~log n at fixed gamma*n."""
    ns = [10**5, 10**6, 10**7]
    betas = [float(beta_mu(1.0, 3.0, 1.0, _cfg(n=n))[0][0]) for n in ns]
    # ratios of (beta / ln n) stay within a modest constant band
    ratios = [b / np.log(n) for b, n in zip(betas, ns)]
    assert max(ratios) / min(ratios) < 2.0
