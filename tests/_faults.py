"""Fault-injection harness for the paging / scheduler / QoS test layer.

``FaultyExecutor`` wraps the suite's fake build/offload/restore executor
pattern (``StateCache`` over host-side tuples, no device) with
*injectable* faults:

  * ``fail_builds`` / ``fail_restores`` — the next N calls of that
    executor raise a typed ``InjectedFault`` (set ``math.inf`` for a
    persistent fault; the counters are plain mutable attributes, so a
    test heals the executor mid-run by zeroing them);
  * ``build_delay_s`` / ``restore_delay_s`` — modeled latency spikes,
    *recorded* through the ``sleeper`` hook instead of wall-slept (the
    default appends to ``slept``), so property tests stay instant;
  * every call is logged to ``calls`` as ``(kind, group_id)`` for
    exact-sequence assertions.

``record_backoffs`` additionally intercepts a ``StateCache``'s retry
backoff sleeps, so bounded-retry tests can assert the doubling schedule
without ever sleeping.
"""

from __future__ import annotations

from repro.serving import StateCache


class InjectedFault(RuntimeError):
    """The typed failure every injected fault raises (match="injected")."""


class FaultyExecutor:
    """Fake state executors with injectable failures and recorded delays.

    States are host-side tuples — ``build`` returns ``("dev", gi)``,
    ``offload`` wraps to ``("host", state)``, ``restore`` unwraps — so a
    restored state is trivially bit-identical to the evicted one and no
    device is involved anywhere.
    """

    def __init__(
        self,
        *,
        fail_builds: float = 0,
        fail_restores: float = 0,
        build_delay_s: float = 0.0,
        restore_delay_s: float = 0.0,
        sleeper=None,
    ):
        self.fail_builds = fail_builds
        self.fail_restores = fail_restores
        self.build_delay_s = float(build_delay_s)
        self.restore_delay_s = float(restore_delay_s)
        self.calls: list[tuple[str, int]] = []
        self.slept: list[float] = []
        self._sleep = sleeper if sleeper is not None else self.slept.append

    def build(self, gi: int):
        """Cold-build executor: fails while ``fail_builds`` > 0."""
        self.calls.append(("build", int(gi)))
        if self.build_delay_s:
            self._sleep(self.build_delay_s)
        if self.fail_builds > 0:
            self.fail_builds -= 1
            raise InjectedFault(f"injected build fault (group {gi})")
        return ("dev", int(gi))

    def offload(self, state):
        """Device-to-host offload executor (never fails: copies are cheap)."""
        self.calls.append(("offload", state[-1]))
        return ("host", state)

    def restore(self, gi: int, host):
        """Host-to-device restore executor: fails while ``fail_restores``
        > 0."""
        self.calls.append(("restore", int(gi)))
        if self.restore_delay_s:
            self._sleep(self.restore_delay_s)
        if self.fail_restores > 0:
            self.fail_restores -= 1
            raise InjectedFault(f"injected restore fault (group {gi})")
        return host[1]

    def n_calls(self, kind: str) -> int:
        """How many times executor ``kind`` ran (failed calls included)."""
        return sum(1 for k, _ in self.calls if k == kind)

    def make_cache(self, *, nbytes=lambda gi: 10, offload=True,
                   **kw) -> StateCache:
        """A ``StateCache`` wired to this executor's fault hooks."""
        if offload:
            kw.setdefault("offload", self.offload)
            kw.setdefault("restore", self.restore)
        return StateCache(build=self.build, nbytes_of=nbytes, **kw)


def record_backoffs(cache: StateCache) -> list[float]:
    """Divert ``cache``'s retry backoff sleeps into the returned list.

    The cache's ``retry_backoff_s`` schedule (doubling per attempt) is
    then assertable without any wall-clock sleep actually happening.
    """
    recorded: list[float] = []
    cache._sleep = recorded.append
    return recorded
