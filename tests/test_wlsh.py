"""End-to-end WLSH index behaviour: accuracy guarantees, faithful vs dense
path agreement, C2LSH degeneration, I/O accounting."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.c2lsh import C2LSH
from repro.core.datagen import make_dataset, make_query_set, make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex


def _overall_ratio(idx, qs, k, use_dense=False):
    """Average overall ratio (paper Eq. 16) over a query set."""
    ratios = []
    for q in qs.points:
        for wid in qs.weight_ids:
            fn = idx.search_dense if use_dense else idx.search
            res = fn(q, weight_id=int(wid), k=k)
            got = res.ids[res.ids >= 0]
            if got.size == 0:
                ratios.append(np.inf)
                continue
            w = idx.weights[int(wid)]
            exact = np.sort(weighted_lp_np(idx.data, q, w, idx.cfg.p))[: got.size]
            mine = np.sort(
                weighted_lp_np(idx.data[got], q, w, idx.cfg.p)
            )
            ratios.append(float(np.mean(mine / np.maximum(exact, 1e-12))))
    return float(np.mean(ratios))


@pytest.fixture(scope="module", params=[1.0, 2.0], ids=["l1", "l2"])
def built(request):
    p = request.param
    data = make_dataset(n=3_000, d=24, seed=11)
    weights = make_weight_set(size=10, d=24, n_subset=2, n_subrange=10, seed=12)
    cfg = PlanConfig(p=p, c=3, n=len(data), gamma_n=100.0)
    idx = WLSHIndex(
        data, weights, cfg, tau=1_000.0 if p == 1.0 else 500.0,
        v=6, v_prime=6, seed=3,
    )
    qs = make_query_set(data, weights, n_query_points=8, n_query_weights=3,
                        seed=13)
    return idx, qs


def test_accuracy_guarantee(built):
    """Average overall ratio must be well under the approximation ratio c."""
    idx, qs = built
    ratio = _overall_ratio(idx, qs, k=5)
    assert ratio < idx.cfg.c, f"avg overall ratio {ratio} >= c={idx.cfg.c}"


def test_dense_path_matches_guarantee(built):
    idx, qs = built
    ratio = _overall_ratio(idx, qs, k=5, use_dense=True)
    assert ratio < idx.cfg.c


def test_faithful_vs_dense_same_stop_semantics(built):
    """Both paths implement identical stop conditions -> same stop level and
    the same frequent-candidate *sets* (order may differ)."""
    idx, qs = built
    for q in qs.points[:4]:
        for wid in qs.weight_ids[:2]:
            r1 = idx.search(q, weight_id=int(wid), k=3)
            r2 = idx.search_dense(q, weight_id=int(wid), k=3)
            assert r1.stats.stop_level == r2.stats.stop_level
            # top-1 distances agree (best candidate is identical)
            if r1.ids[0] >= 0 and r2.ids[0] >= 0:
                np.testing.assert_allclose(
                    r1.dists[0], r2.dists[0], rtol=1e-6
                )


def test_self_query_finds_itself(built):
    """A query that IS a data point must return it at distance ~0."""
    idx, _ = built
    for pid in (0, 100, 999):
        res = idx.search(idx.data[pid], weight_id=0, k=1)
        assert res.ids[0] == pid
        assert res.dists[0] < 1e-6


def test_io_accounting(built):
    idx, qs = built
    res = idx.search(qs.points[0], weight_id=int(qs.weight_ids[0]), k=5)
    st = res.stats
    assert st.io_blocks > 0
    assert st.n_checked <= 5 + int(np.ceil(idx.cfg.gamma * idx.n)) + 5
    assert st.n_collisions >= st.n_checked  # identify >= check


def test_c2lsh_degeneration():
    """WLSH with |S| = 1 is exactly C2LSH (shared plumbing, Eqs. 4-5)."""
    data = make_dataset(n=1_500, d=16, seed=21)
    w = np.ones(16)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    c2 = C2LSH(data, cfg, weight=w, seed=5)
    wl = WLSHIndex(data, w[None, :], cfg, tau=float("inf"), seed=5)
    assert len(wl.part.groups) == 1
    # identical plans: same beta, mu
    assert c2.part.groups[0].beta_group == wl.part.groups[0].beta_group
    np.testing.assert_allclose(
        c2.part.groups[0].mus, wl.part.groups[0].mus
    )
    q = data[7].astype(np.float32) + 1.5
    r1 = c2.query(q, k=3)
    r2 = wl.search(q, weight_id=0, k=3)
    np.testing.assert_array_equal(r1.ids, r2.ids)


def test_collision_threshold_reduction_cuts_io():
    """Sec 4.2.1: reduced mu identifies candidates earlier -> fewer blocks."""
    data = make_dataset(n=2_000, d=16, seed=31)
    weights = make_weight_set(size=6, d=16, n_subset=2, n_subrange=10, seed=32)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    io = {}
    for red in (True, False):
        idx = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4,
                        use_reduction=red, seed=7)
        qs = make_query_set(data, weights, n_query_points=6,
                            n_query_weights=2, seed=33)
        costs = [
            idx.search(q, weight_id=int(w), k=3).stats.io_blocks
            for q in qs.points for w in qs.weight_ids
        ]
        io[red] = float(np.mean(costs))
    assert io[True] <= io[False] * 1.25  # reduction must not blow up I/O


def test_non_integer_c_rejected():
    data = make_dataset(n=100, d=8, seed=0)
    with pytest.raises(ValueError):
        WLSHIndex(data, np.ones((1, 8)), PlanConfig(p=2.0, c=2.5, n=100),
                  tau=1e9)


def test_weight_set_generator_properties():
    W = make_weight_set(size=20, d=12, n_subset=4, n_subrange=5, seed=1)
    assert W.shape == (20, 12)
    assert np.all(W >= 1.0) and np.all(W <= 10.0)
    # subsets of 5 share a subrange per dim: within-subset spread is bounded
    for s in range(4):
        sub = W[s * 5 : (s + 1) * 5]
        assert np.all(sub.max(axis=0) - sub.min(axis=0) <= 9.0 / 5 + 1e-9)
