"""Sharded WLSH query engine vs the host oracle (WLSHIndex.search_dense).

Single-device mesh here; the multi-device SPMD semantics are covered by
tests/test_multidevice.py (subprocess with forced host device count) and by
the production dry-run.
"""

from __future__ import annotations

import dataclasses

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.index import (
    IndexConfig,
    build_state,
    encode_queries,
    make_query_step,
    pad_beta,
    pad_levels,
)


@pytest.fixture(scope="module")
def setup():
    data = make_dataset(n=1_024, d=16, seed=41)
    weights = make_weight_set(size=6, d=16, n_subset=2, n_subrange=10, seed=42)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4, seed=9)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return data, weights, cfg, host, mesh


def _engine_for_group(host: WLSHIndex, mesh, gi: int, data, k: int):
    built = host._group(gi)
    plan = built.plan
    n_levels = int(np.max(plan.n_levels))
    icfg = IndexConfig(
        n=len(data),
        d=data.shape[1],
        beta=built.fam.beta,
        q_batch=4,
        k=k,
        c=int(round(host.cfg.c)),
        n_levels=n_levels,
        p=host.cfg.p,
        block_n=256,
        gamma_n=host.cfg.gamma_n,
        vec_dtype="float32",
        use_pallas=False,
    )
    state = build_state(mesh, icfg, data, built.fam)
    step = make_query_step(mesh, icfg)
    return icfg, state, step, built


def test_engine_matches_host_oracle(setup):
    data, weights, cfg, host, mesh = setup
    k = 5
    gi = int(host.part.group_of[0])
    icfg, state, step, built = _engine_for_group(host, mesh, gi, data, k)

    # queries under every weight vector served by this group
    wids = [int(w) for w in built.plan.member_ids[:4]]
    nq = len(wids)
    rng = np.random.default_rng(43)
    qpts = data[rng.choice(len(data), nq, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)

    q_weight = np.stack([host.weights[w] for w in wids]).astype(np.float32)
    mus, r_mins, betas, levels = [], [], [], []
    for w in wids:
        _, slot, beta_i, mu_i = host._member_params(w)
        mus.append(mu_i)
        r_mins.append(built.plan.r_min_members[slot])
        betas.append(beta_i)
        levels.append(int(built.plan.n_levels[slot]))

    dists, ids, stop, n_checked = step(
        state,
        jnp.asarray(qpts),
        encode_queries(state, qpts),
        jnp.asarray(q_weight),
        jnp.asarray(mus, jnp.int32),
        jnp.asarray(r_mins, jnp.float32),
        jnp.asarray(betas, jnp.int32),
        jnp.asarray(levels, jnp.int32),
    )
    dists, ids, stop = np.asarray(dists), np.asarray(ids), np.asarray(stop)

    for qi, wid in enumerate(wids):
        want = host.search_dense(qpts[qi], weight_id=wid, k=k)
        assert stop[qi] == want.stats.stop_level, (
            f"stop level mismatch q{qi}: {stop[qi]} vs {want.stats.stop_level}"
        )
        got_ids = ids[qi][ids[qi] >= 0]
        want_ids = want.ids[want.ids >= 0]
        # The engine hashes queries in f32, the host oracle in f64; near-
        # boundary code jitter can flip individual candidates near the mu
        # threshold.  Demand strong agreement, not identity:
        overlap = len(set(got_ids) & set(want_ids))
        assert overlap >= max(1, (min(len(got_ids), len(want_ids)) + 1) // 2)
        # ... and guarantee-level agreement on the best distance
        assert dists[qi][0] <= host.cfg.c * max(want.dists[0], 1e-9) + 1e-6


def test_engine_self_query(setup):
    data, weights, cfg, host, mesh = setup
    gi = int(host.part.group_of[0])
    icfg, state, step, built = _engine_for_group(host, mesh, gi, data, k=1)
    wid = int(built.plan.member_ids[0])
    _, slot, beta_i, mu_i = host._member_params(wid)
    pids = [0, 17, 1023, 512]
    qpts = jnp.asarray(data[pids], jnp.float32)
    dists, ids, *_ = step(
        state,
        qpts,
        encode_queries(state, qpts),
        jnp.asarray(np.stack([host.weights[wid]] * 4), jnp.float32),
        jnp.asarray([mu_i] * 4, jnp.int32),
        jnp.asarray([built.plan.r_min_members[slot]] * 4, jnp.float32),
        jnp.asarray([beta_i] * 4, jnp.int32),
        jnp.asarray([int(built.plan.n_levels[slot])] * 4, jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(ids)[:, 0], pids)
    assert np.all(np.asarray(dists)[:, 0] < 1e-3)


@pytest.mark.parametrize("mode", [None, "interpret"], ids=["auto", "interpret"])
def test_engine_fused_paths_bit_exact(setup, mode):
    """Fused query step (auto/XLA composite and Pallas interpret) must be
    bit-exact with the unfused oracle: same ids, dists, stop levels and
    n_checked.  The exact re-rank plus identical candidate sets absorb any
    kernel-internal float jitter, so equality is exact, not approximate."""
    data, weights, cfg, host, mesh = setup
    k = 5
    gi = int(host.part.group_of[0])
    icfg, state, step, built = _engine_for_group(host, mesh, gi, data, k)

    wids = [int(w) for w in built.plan.member_ids[:4]]
    nq = len(wids)
    rng = np.random.default_rng(47)
    qpts = data[rng.choice(len(data), nq, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    q_weight = np.stack([host.weights[w] for w in wids]).astype(np.float32)
    mus, r_mins, betas, levels = [], [], [], []
    for w in wids:
        _, slot, beta_i, mu_i = host._member_params(w)
        mus.append(mu_i)
        r_mins.append(built.plan.r_min_members[slot])
        betas.append(beta_i)
        levels.append(int(built.plan.n_levels[slot]))
    args = (
        jnp.asarray(qpts),
        encode_queries(state, qpts),
        jnp.asarray(q_weight),
        jnp.asarray(mus, jnp.int32),
        jnp.asarray(r_mins, jnp.float32),
        jnp.asarray(betas, jnp.int32),
        jnp.asarray(levels, jnp.int32),
    )
    want = step(state, *args)  # the unfused oracle (use_pallas=False)

    fcfg = dataclasses.replace(icfg, use_pallas=mode)
    fstate = build_state(mesh, fcfg, data, built.fam)
    fstep = make_query_step(mesh, fcfg)
    got = fstep(fstate, *args)

    for name, a, b in zip(("dists", "ids", "stop", "n_checked"), want, got):
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(b),
            err_msg=f"fused path ({mode}) diverged from unfused on {name}",
        )


def test_budget_derived_from_gamma():
    # paper default: budget = k + ceil(gamma * n) with gamma = gamma_n / n
    cfg = IndexConfig(n=2_000, k=7, gamma_n=100.0)
    assert cfg.gamma == 100.0 / 2_000
    assert cfg.budget == 7 + 100
    cfg = IndexConfig(n=1 << 30, k=10, gamma_n=100.0)
    assert cfg.budget == 110
    # explicit override wins (the practical choice at 1B points)
    cfg = IndexConfig(n=1 << 30, k=10, budget_override=4096)
    assert cfg.budget == 4096
    # engine and host planner agree by construction
    from repro.core.params import PlanConfig

    pcfg = PlanConfig(n=4_000, gamma_n=100.0)
    icfg = IndexConfig(n=4_000, k=5, gamma_n=pcfg.gamma_n)
    assert icfg.budget == 5 + int(np.ceil(pcfg.gamma * pcfg.n))


def test_shape_padding_buckets():
    assert pad_beta(1) == 32
    assert pad_beta(135) == 160
    assert pad_beta(160) == 160
    assert pad_beta(161) == 192
    assert pad_beta(513) == 1024
    assert pad_beta(150, buckets=(128, 256)) == 256
    with pytest.raises(ValueError):
        pad_beta(300, buckets=(128, 256))
    assert pad_levels(13) == 16
    assert pad_levels(16) == 16
    assert pad_levels(5, step=8) == 8
    # configs built from shapes that quantize to the same buckets are equal
    # (and therefore share one compiled step through QueryStepCache)
    a = IndexConfig(n=1_024, beta=pad_beta(135), n_levels=pad_levels(13))
    b = IndexConfig(n=1_024, beta=pad_beta(137), n_levels=pad_levels(14))
    assert a == b and a.shape_signature() == b.shape_signature()


def test_build_is_deterministic(setup):
    data, weights, cfg, host, mesh = setup
    gi = int(host.part.group_of[0])
    built = host._group(gi)
    icfg = IndexConfig(n=len(data), d=data.shape[1], beta=built.fam.beta,
                       vec_dtype="float32", use_pallas=False)
    s1 = build_state(mesh, icfg, data, built.fam)
    s2 = build_state(mesh, icfg, data, built.fam)
    np.testing.assert_array_equal(np.asarray(s1.codes), np.asarray(s2.codes))
    # codes agree with the host planner's (float64) oracle except at rare
    # f32-vs-f64 floor boundaries (projection magnitudes reach ~r_max/w, so
    # f32 ulp jitter near bucket edges flips ~0.5% of codes by exactly one —
    # noise on top of the random hash, bounded and harmless)
    host_codes = built.codes
    mismatch = np.mean(np.asarray(s1.codes) != host_codes)
    assert mismatch < 2e-2
    assert np.max(np.abs(np.asarray(s1.codes) - host_codes)) <= 1
