"""Sharded WLSH query engine vs the host oracle (WLSHIndex.search_dense).

Single-device mesh here; the multi-device SPMD semantics are covered by
tests/test_multidevice.py (subprocess with forced host device count) and by
the production dry-run.
"""

from __future__ import annotations

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.index import IndexConfig, build_state, make_query_step


@pytest.fixture(scope="module")
def setup():
    data = make_dataset(n=1_024, d=16, seed=41)
    weights = make_weight_set(size=6, d=16, n_subset=2, n_subrange=10, seed=42)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4, seed=9)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    return data, weights, cfg, host, mesh


def _engine_for_group(host: WLSHIndex, mesh, gi: int, data, k: int):
    built = host._group(gi)
    plan = built.plan
    n_levels = int(np.max(plan.n_levels))
    icfg = IndexConfig(
        n=len(data),
        d=data.shape[1],
        beta=built.fam.beta,
        q_batch=4,
        k=k,
        c=int(round(host.cfg.c)),
        n_levels=n_levels,
        p=host.cfg.p,
        block_n=256,
        budget=k + int(np.ceil(host.cfg.gamma * len(data))),
        vec_dtype="float32",
        use_pallas=False,
    )
    state = build_state(mesh, icfg, data, built.fam)
    step = make_query_step(mesh, icfg)
    return icfg, state, step, built


def test_engine_matches_host_oracle(setup):
    data, weights, cfg, host, mesh = setup
    k = 5
    gi = int(host.part.group_of[0])
    icfg, state, step, built = _engine_for_group(host, mesh, gi, data, k)

    # queries under every weight vector served by this group
    wids = [int(w) for w in built.plan.member_ids[:4]]
    nq = len(wids)
    rng = np.random.default_rng(43)
    qpts = data[rng.choice(len(data), nq, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)

    q_weight = np.stack([host.weights[w] for w in wids]).astype(np.float32)
    mus, r_mins, betas = [], [], []
    for w in wids:
        _, slot, beta_i, mu_i = host._member_params(w)
        mus.append(mu_i)
        r_mins.append(built.plan.r_min_members[slot])
        betas.append(beta_i)

    dists, ids, stop, n_checked = step(
        state,
        jnp.asarray(qpts),
        jnp.asarray(q_weight),
        jnp.asarray(mus, jnp.int32),
        jnp.asarray(r_mins, jnp.float32),
        jnp.asarray(betas, jnp.int32),
    )
    dists, ids, stop = np.asarray(dists), np.asarray(ids), np.asarray(stop)

    for qi, wid in enumerate(wids):
        want = host.search_dense(qpts[qi], weight_id=wid, k=k)
        assert stop[qi] == want.stats.stop_level, (
            f"stop level mismatch q{qi}: {stop[qi]} vs {want.stats.stop_level}"
        )
        got_ids = ids[qi][ids[qi] >= 0]
        want_ids = want.ids[want.ids >= 0]
        # The engine hashes queries in f32, the host oracle in f64; near-
        # boundary code jitter can flip individual candidates near the mu
        # threshold.  Demand strong agreement, not identity:
        overlap = len(set(got_ids) & set(want_ids))
        assert overlap >= max(1, (min(len(got_ids), len(want_ids)) + 1) // 2)
        # ... and guarantee-level agreement on the best distance
        assert dists[qi][0] <= host.cfg.c * max(want.dists[0], 1e-9) + 1e-6


def test_engine_self_query(setup):
    data, weights, cfg, host, mesh = setup
    gi = int(host.part.group_of[0])
    icfg, state, step, built = _engine_for_group(host, mesh, gi, data, k=1)
    wid = int(built.plan.member_ids[0])
    _, slot, beta_i, mu_i = host._member_params(wid)
    pids = [0, 17, 1023, 512]
    dists, ids, *_ = step(
        state,
        jnp.asarray(data[pids], jnp.float32),
        jnp.asarray(np.stack([host.weights[wid]] * 4), jnp.float32),
        jnp.asarray([mu_i] * 4, jnp.int32),
        jnp.asarray([built.plan.r_min_members[slot]] * 4, jnp.float32),
        jnp.asarray([beta_i] * 4, jnp.int32),
    )
    np.testing.assert_array_equal(np.asarray(ids)[:, 0], pids)
    assert np.all(np.asarray(dists)[:, 0] < 1e-3)


def test_build_is_deterministic(setup):
    data, weights, cfg, host, mesh = setup
    gi = int(host.part.group_of[0])
    built = host._group(gi)
    icfg = IndexConfig(n=len(data), d=data.shape[1], beta=built.fam.beta,
                       vec_dtype="float32", use_pallas=False)
    s1 = build_state(mesh, icfg, data, built.fam)
    s2 = build_state(mesh, icfg, data, built.fam)
    np.testing.assert_array_equal(np.asarray(s1.codes), np.asarray(s2.codes))
    # codes agree with the host planner's (float64) oracle except at rare
    # f32-vs-f64 floor boundaries (projection magnitudes reach ~r_max/w, so
    # f32 ulp jitter near bucket edges flips ~0.5% of codes by exactly one —
    # noise on top of the random hash, bounded and harmless)
    host_codes = built.codes
    mismatch = np.mean(np.asarray(s1.codes) != host_codes)
    assert mismatch < 2e-2
    assert np.max(np.abs(np.asarray(s1.codes) - host_codes)) <= 1
