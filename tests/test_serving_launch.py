"""Serving loop + launchers: generation determinism, train launcher with
injected failure -> restart, analysis-extrapolation validation."""

from __future__ import annotations

import dataclasses

import jax
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models import build_model, init_params
from repro.serving.decode import SamplerConfig, generate


@pytest.fixture(scope="module")
def tiny():
    cfg = reduced(get_config("olmo_1b"))
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(0))
    return cfg, model, params


def test_generate_shapes_and_determinism(tiny):
    cfg, model, params = tiny
    prompts = np.array([[1, 2, 3, 4], [5, 6, 7, 8]], np.int32)
    a = generate(model, params, prompts, max_new_tokens=6, cache_len=16,
                 sampler=SamplerConfig(temperature=0.0))
    b = generate(model, params, prompts, max_new_tokens=6, cache_len=16,
                 sampler=SamplerConfig(temperature=0.0))
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(a, b)  # greedy = deterministic
    assert np.all((a >= 0) & (a < cfg.vocab))


def test_generate_sampled_differs_by_seed(tiny):
    cfg, model, params = tiny
    prompts = np.array([[1, 2, 3, 4]], np.int32)
    a = generate(model, params, prompts, 8, 16,
                 SamplerConfig(temperature=1.0, seed=0))
    b = generate(model, params, prompts, 8, 16,
                 SamplerConfig(temperature=1.0, seed=1))
    assert not np.array_equal(a, b)


def test_serve_launcher_runs():
    from repro.launch.serve import main

    out = main(["--arch", "olmo-1b", "--reduced", "--batch", "2",
                "--prompt-len", "4", "--max-new", "4"])
    assert out["tokens"].shape == (2, 4)


def test_retrieval_launcher_runs(tmp_path):
    """plan -> build -> serve -> report, with the search_dense cross-check
    and ServingPlan persistence."""
    from repro.core.serving_plan import ServingPlan
    from repro.launch.retrieval import main

    plan_path = str(tmp_path / "plan.npz")
    out = main([
        "--n", "512", "--d", "16", "--n-weights", "4", "--n-subset", "2",
        "--n-queries", "8", "--k", "3", "--v", "4", "--q-batch", "4",
        "--check", "--plan-out", plan_path,
    ])
    assert out["n_check_failures"] == 0
    assert out["n_groups"] >= 1
    assert out["n_compiled_steps"] <= out["n_groups"]
    assert sum(s["n_queries"] for s in out["stats"].values()) == 8
    assert ServingPlan.load_npz(plan_path).n_groups == out["n_groups"]


def test_train_launcher_restart_resume(tmp_path):
    """Injected failure at step 6 -> supervisor restarts from checkpoint,
    run completes, loss history continuous."""
    from repro.launch.train import parse_args, train

    args = parse_args([
        "--arch", "olmo-1b", "--reduced", "--steps", "12",
        "--global-batch", "4", "--seq-len", "16",
        "--ckpt-dir", str(tmp_path / "ckpt"), "--ckpt-every", "3",
        "--log-every", "100", "--fail-at", "6",
    ])
    out = train(args)
    assert out["restarts"] == 1
    assert np.isfinite(out["final_loss"])


def test_analysis_extrapolation_matches_direct():
    """The two-point unrolled extrapolation (dryrun.analysis_terms) must
    reproduce direct full-unroll flops counting on a model small enough to
    unroll completely (<2% error; exactly linear stacks)."""
    import jax.numpy as jnp

    from repro.models.transformer import RunFlags
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import make_train_step, train_state_defs
    from repro.models.params import abstract_params
    from repro.models import input_specs
    from repro.configs.base import ShapeConfig

    cfg0 = reduced(get_config("olmo_1b"))
    shape = ShapeConfig("s", 64, 4, "train")
    flags = RunFlags(remat="full", layer_groups=1, analysis_unroll=True)
    ocfg = AdamWConfig()

    def flops_at(n_layers):
        cfg = dataclasses.replace(cfg0, n_layers=n_layers)
        model = build_model(cfg, mesh=None, flags=flags)
        sdefs = train_state_defs(model.defs(), ocfg)
        step = make_train_step(model, ocfg, unroll=True)
        lowered = jax.jit(step).lower(
            abstract_params(sdefs), input_specs(cfg, shape)
        )
        ca = lowered.compile().cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        return float(ca.get("flops", 0.0))

    f2, f4, f8 = flops_at(2), flops_at(4), flops_at(8)
    extrapolated = f2 + (f4 - f2) / 2 * (8 - 2)
    # per-layer cost is slightly depth-dependent at toy scale (boundary
    # layers + constant-folding); ~5% here, smaller for real models where
    # the per-layer term dominates the base.  Methodology error budget is
    # documented in EXPERIMENTS.md Sec Roofline.
    assert abs(extrapolated - f8) / f8 < 0.06, (f2, f4, f8, extrapolated)
