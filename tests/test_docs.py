"""The documentation layer must not rot.

Three guards, all CI-enforceable without a human reading the docs:

  * the README quickstart commands are parsed out of the fenced code
    blocks and *executed* (shrunk onto a tiny synthetic corpus — same
    flags, smaller sizes), so a CLI change that breaks the documented
    invocation fails the docs lane;
  * the docs cross-link web (README <-> ARCHITECTURE <-> ROADMAP, the
    tier-1 verify command) is checked for presence;
  * every public module/class/function/method of the serving API keeps
    a docstring — an AST-level equivalent of the ruff D1xx rules that
    runs even where ruff isn't installed (the docs CI lane additionally
    runs the full ruff D-rule set).
"""

from __future__ import annotations

import ast
import pathlib
import re
import shlex

import pytest

REPO = pathlib.Path(__file__).resolve().parent.parent
README = REPO / "README.md"
ARCHITECTURE = REPO / "docs" / "ARCHITECTURE.md"
ROADMAP = REPO / "ROADMAP.md"

# the modules whose public surface the docstring lint covers (kept in
# sync with the ruff invocation in .github/workflows/ci.yml)
DOCSTRING_SCOPE = [
    "src/repro/serving/__init__.py",
    "src/repro/serving/batching.py",
    "src/repro/serving/retrieval.py",
    "src/repro/serving/async_service.py",
    "src/repro/serving/state_cache.py",
    "src/repro/serving/scheduler.py",
    "src/repro/serving/qos.py",
    "src/repro/serving/delta.py",
    "src/repro/serving/decode.py",
    "src/repro/core/serving_plan.py",
    "src/repro/index/streaming.py",
    "src/repro/distributed/group_sharding.py",
    "src/repro/obs/__init__.py",
    "src/repro/obs/metrics.py",
    "src/repro/obs/trace.py",
    "src/repro/obs/profile.py",
    "src/repro/obs/recall.py",
    "src/repro/obs/health.py",
]

# quickstart smoke: same flags as documented, shrunk to a tiny corpus
TINY_OVERRIDES = {
    "--n": "512",
    "--d": "16",
    "--n-weights": "6",
    "--n-subset": "3",
    "--n-queries": "12",
    "--k": "3",
    "--v": "4",
    "--q-batch": "4",
    # the documented sharded invocation forces an 8-device mesh via
    # XLA_FLAGS; the in-process smoke keeps the single real device
    "--shards": "1",
}
_STORE_TRUE = {"--check", "--async", "--no-pallas", "--driver",
               "--prefetch", "--qos", "--health"}


def _fenced_blocks(text: str) -> list[str]:
    return re.findall(r"```(?:\w*)\n(.*?)```", text, flags=re.S)


def _extract_cli_commands(text: str) -> list[list[str]]:
    """Documented `repro.launch.retrieval` invocations -> argv lists."""
    cmds = []
    for block in _fenced_blocks(text):
        joined = block.replace("\\\n", " ")
        for line in joined.splitlines():
            if "repro.launch.retrieval" not in line:
                continue
            toks = shlex.split(line)
            argv = toks[toks.index("repro.launch.retrieval") + 1:]
            cmds.append(argv)
    return cmds


def _shrink(argv: list[str]) -> list[str]:
    """Re-emit a documented argv with tiny-corpus size overrides."""
    out, seen, i = [], set(), 0
    while i < len(argv):
        tok = argv[i]
        if tok in _STORE_TRUE:
            out.append(tok)
            i += 1
            continue
        val = argv[i + 1]
        seen.add(tok)
        out.extend([tok, TINY_OVERRIDES.get(tok, val)])
        i += 2
    for flag, val in TINY_OVERRIDES.items():
        if flag not in seen:
            out.extend([flag, val])
    return out


def test_readme_quickstart_commands_run():
    """Every documented launcher invocation must execute end to end (on a
    tiny synthetic corpus) and, when it documents --check, agree with the
    host oracle on every answer."""
    from repro.launch.retrieval import main

    cmds = _extract_cli_commands(README.read_text())
    assert len(cmds) >= 2, "README must document sync and async quickstarts"
    assert any("--async" in c for c in cmds)
    assert any("--async" not in c for c in cmds)
    for argv in cmds:
        out = main(_shrink(argv))
        assert out["n_check_failures"] == 0, f"quickstart failed: {argv}"


def test_readme_paging_flags_documented_and_valid():
    """The paging flags named in the README must parse in the launcher."""
    from repro.launch.retrieval import parse_args, parse_bytes

    text = README.read_text()
    assert "--max-resident-groups" in text
    assert "--device-budget" in text
    args = parse_args(["--max-resident-groups", "2",
                       "--device-budget", "512MB"])
    assert args.max_resident_groups == 2
    assert args.device_budget == 512 * 2**20
    assert parse_bytes("2GB") == 2 << 30
    with pytest.raises(Exception):
        parse_bytes("twelve parsecs")
    with pytest.raises(Exception):
        parse_bytes("0")  # floors to 0 bytes
    with pytest.raises(Exception):
        parse_bytes("0.5")  # fractional without unit: missing suffix
    with pytest.raises(Exception):
        parse_bytes("1.5")  # ditto — would silently mean 1 byte
    assert parse_bytes("1.5GB") == int(1.5 * (1 << 30))
    # case-insensitive + IEC suffixes, clear rejection of negatives
    assert parse_bytes("512mb") == 512 * 2**20
    assert parse_bytes("512MiB") == 512 * 2**20
    assert parse_bytes("2gib") == 2 << 30
    assert parse_bytes("1KiB") == 1024
    with pytest.raises(Exception, match="positive"):
        parse_bytes("-512MB")
    with pytest.raises(Exception, match="positive"):
        parse_bytes("0")
    with pytest.raises(Exception, match="unit"):
        parse_bytes("512XB")


def test_readme_documents_install_and_tier1_verify():
    text = README.read_text()
    assert "pip install -e .[test]" in text
    # the exact tier-1 command from ROADMAP.md, verbatim
    assert "PYTHONPATH=src python -m pytest -x -q" in text


def test_docs_cross_links():
    """README <-> ARCHITECTURE <-> ROADMAP must stay linked, and the
    architecture guide must keep covering the five layers + paging."""
    assert ARCHITECTURE.exists()
    readme = README.read_text()
    assert "docs/ARCHITECTURE.md" in readme
    roadmap = ROADMAP.read_text()
    assert "docs/ARCHITECTURE.md" in roadmap
    arch = ARCHITECTURE.read_text()
    assert "```mermaid" in arch
    for anchor in ("serving_plan.py", "QueryStepCache", "StateCache",
                   "batching.py", "RetrievalService",
                   "AsyncRetrievalService", "launch/retrieval.py",
                   "state_nbytes", "max_resident_groups",
                   "DeltaIndex", "delta_seal_rows", "append_to_state",
                   "n_valid", "ServiceDriver", "DeadlinePrefetch",
                   "CostAwareEviction", "scheduler.py", "prefetch",
                   "purge=True", "group_sharding.py", "serving_mesh",
                   "state_shardings", "strict=True",
                   "build_group_state_per_host",
                   "offload_state_sharded", "n_shards",
                   "qos.py", "QosScheduler", "QosClass",
                   "DeficitRoundRobin", "TokenBucket", "DegradeStep",
                   "degrade_ladder", "RateLimited", "capacity_per_tick",
                   "degrade_after",
                   "obs/metrics.py", "obs/trace.py", "obs/profile.py",
                   "MetricsRegistry", "TraceSpan", "Tracer", "Profiler",
                   "--trace-out", "--metrics-out", "--profile-dir",
                   "wlsh_group_queries_total", "wlsh_query_wait_seconds",
                   "tick_summary",
                   "obs/recall.py", "obs/health.py",
                   "RecallEstimator", "HealthMonitor", "AlertRule",
                   "sample_hash", "ShadowJob",
                   "--recall-sample-rate", "--alerts-out", "--health",
                   "wlsh_recall_observed", "wlsh_recall_bound_margin",
                   "benchmarks/sentinel.py", "BASELINE.json",
                   "BENCH_serve.json", "--write-baseline"):
        assert anchor in arch, f"ARCHITECTURE.md lost its {anchor} coverage"


def _missing_docstrings(path: pathlib.Path) -> list[str]:
    """AST D1xx sweep: public defs in ``path`` lacking a docstring."""
    tree = ast.parse(path.read_text())
    missing = []
    if ast.get_docstring(tree) is None:
        missing.append(f"{path.name}: module")

    def walk(node, prefix):
        for child in ast.iter_child_nodes(node):
            if isinstance(
                child,
                (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef),
            ):
                name = child.name
                public = not name.startswith("_")
                if public and ast.get_docstring(child) is None:
                    missing.append(f"{path.name}: {prefix}{name}")
                if isinstance(child, ast.ClassDef) and public:
                    walk(child, f"{prefix}{name}.")

    walk(tree, "")
    return missing


@pytest.mark.parametrize("relpath", DOCSTRING_SCOPE)
def test_public_serving_api_has_docstrings(relpath):
    """Local equivalent of the docs-lane ruff D1xx rules: every public
    module/class/function/method in the serving API is documented."""
    missing = _missing_docstrings(REPO / relpath)
    assert not missing, "missing docstrings:\n  " + "\n  ".join(missing)
