"""Optimizer + train-loop substrate: AdamW semantics, schedules, moment
quantization, stochastic rounding, microbatch-accumulation equivalence."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.optimizer import (
    AdamWConfig,
    _dequantize,
    _quantize,
    _sr_cast_bf16,
    adamw_init,
    adamw_update,
    lr_schedule,
)


def test_adamw_descends_quadratic():
    """Minimize ||x - t||^2; AdamW must reduce the loss monotonically-ish."""
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      total_steps=100, schedule="constant")
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"x": jnp.zeros(3)}
    opt = adamw_init(params, cfg)

    def loss_fn(p):
        return jnp.sum((p["x"] - target) ** 2)

    losses = []
    for _ in range(60):
        g = jax.grad(loss_fn)({"x": opt["master"]["x"]})
        opt, _, _ = adamw_update(g, opt, cfg)
        losses.append(float(loss_fn({"x": opt["master"]["x"]})))
    assert losses[-1] < 0.05 * losses[0]


@pytest.mark.parametrize("sched", ["cosine", "wsd", "constant"])
def test_lr_schedule_shapes(sched):
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      schedule=sched, decay_frac=0.2, min_lr_frac=0.1)
    lr = np.array([float(lr_schedule(cfg, s)) for s in range(101)])
    # warmup: monotone ramp to ~peak
    assert np.all(np.diff(lr[:10]) > 0)
    assert lr[0] == 0.0
    if sched == "constant":
        np.testing.assert_allclose(lr[10:], 1.0)
    if sched == "wsd":
        # stable plateau until decay_start = 80
        np.testing.assert_allclose(lr[10:80], 1.0)
        assert lr[100] == pytest.approx(0.1, rel=1e-5)
        assert np.all(np.diff(lr[80:]) <= 1e-7)
    if sched == "cosine":
        assert lr[100] == pytest.approx(0.1, rel=1e-2)
        assert np.all(np.diff(lr[11:]) <= 1e-7)


def test_int8_quantization_roundtrip_error():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(0, 3.0, (8, 700)).astype(np.float32))
    codes, scale, shape = _quantize(x)
    y = _dequantize(codes, scale, x.shape)
    err = np.abs(np.asarray(y) - np.asarray(x))
    blk_max = np.asarray(jnp.max(jnp.abs(x)))
    # blockwise int8: error bounded by scale/2 = blockmax/254
    assert float(err.max()) <= blk_max / 127.0
    rel = float(np.linalg.norm(err) / np.linalg.norm(np.asarray(x)))
    assert rel < 0.01


def test_sr_cast_unbiased():
    x = jnp.full((200_000,), 1.0 + 2.0 ** -10, jnp.float32)  # between bf16 grid
    key = jax.random.PRNGKey(1)
    y = _sr_cast_bf16(x, key).astype(jnp.float32)
    # stochastic rounding: mean preserved within noise, values on grid
    assert abs(float(jnp.mean(y)) - float(x[0])) < 1e-4
    assert set(np.unique(np.asarray(y))).issubset(
        {np.float32(1.0), np.float32(1.0078125)}
    )


@pytest.mark.parametrize("moment_dtype", ["float32", "bfloat16", "int8"])
def test_moment_dtypes_still_converge(moment_dtype):
    cfg = AdamWConfig(lr=0.05, weight_decay=0.0, warmup_steps=0,
                      schedule="constant", moment_dtype=moment_dtype)
    target = jnp.array([0.5, -1.5, 2.5, 0.1] * 64)  # 256-wide (one block)
    params = {"x": jnp.zeros(256)}
    opt = adamw_init(params, cfg)

    def loss_fn(p):
        return jnp.sum((p["x"] - target) ** 2)

    for _ in range(80):
        g = jax.grad(loss_fn)({"x": opt["master"]["x"]})
        opt, _, _ = adamw_update(g, opt, cfg)
    final = float(loss_fn({"x": opt["master"]["x"]}))
    assert final < 5.0  # int8 moments converge slower but must converge


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(lr=1.0, clip_norm=1e-3, weight_decay=0.0,
                      warmup_steps=0, schedule="constant")
    params = {"x": jnp.zeros(4)}
    opt = adamw_init(params, cfg)
    g = {"x": jnp.full(4, 1e6)}
    opt, _, metrics = adamw_update(g, opt, cfg)
    assert float(metrics["grad_norm"]) == pytest.approx(2e6, rel=1e-3)
    # clipped: effective g tiny; but adam normalizes by sqrt(v) so update ~ lr
    assert np.all(np.isfinite(np.asarray(opt["master"]["x"])))


def test_microbatch_accumulation_equivalence():
    """grad accumulation over 4 microbatches == single big batch."""
    from repro.configs.base import ShapeConfig, get_config, reduced
    from repro.models import build_model, init_params, make_batch
    from repro.training.train_loop import init_train_state, make_train_step

    cfg = reduced(get_config("olmo_1b"))
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(0))
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    batch = make_batch(cfg, ShapeConfig("s", 16, 8, "train"), seed=3)

    s1 = init_train_state(model.defs(), params, ocfg)
    s4 = jax.tree.map(jnp.copy, s1)
    step1 = make_train_step(model, ocfg, microbatches=1)
    step4 = make_train_step(model, ocfg, microbatches=4)
    s1, m1 = jax.jit(step1)(s1, batch)
    s4, m4 = jax.jit(step4)(s4, batch)
    assert float(m1["loss"]) == pytest.approx(float(m4["loss"]), rel=2e-2)
    # parameters land close (not identical: accumulation reorders bf16 sums)
    a = np.concatenate([np.ravel(x) for x in jax.tree.leaves(s1["opt"]["master"])])
    b = np.concatenate([np.ravel(x) for x in jax.tree.leaves(s4["opt"]["master"])])
    assert np.corrcoef(a, b)[0, 1] > 0.999


def test_loss_decreases_on_markov_data():
    """Tiny model must learn a markov stream in a few dozen steps."""
    from repro.configs.base import get_config, reduced
    from repro.models import build_model, init_params
    from repro.training.data import DataConfig, SyntheticStream
    from repro.training.train_loop import init_train_state, make_train_step

    cfg = reduced(get_config("olmo_1b"))
    model = build_model(cfg, mesh=None)
    params = init_params(model.defs(), jax.random.PRNGKey(1))
    ocfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=80,
                       schedule="constant")
    stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=32,
                                        global_batch=8, mode="markov"))
    step = jax.jit(make_train_step(model, ocfg))
    state = init_train_state(model.defs(), params, ocfg)
    losses = []
    for s in range(60):
        b = stream.global_batch(s)
        state, m = step(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    # markov chain with branching 4: optimal loss ~= ln 4 << ln 256 = 5.55
    assert np.mean(losses[-5:]) < np.mean(losses[:3]) - 0.5
