"""Checkpointing: atomic save/restore, keep-k, elastic mesh independence,
exactly-once data resume."""

from __future__ import annotations

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.training.checkpoint import (
    CheckpointManager,
    latest_step,
    load_checkpoint,
    save_checkpoint,
)


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.normal(size=(8, 16)).astype(np.float32)),
        "nest": {"b": jnp.arange(10, dtype=jnp.int32),
                 "c": jnp.asarray(rng.normal(size=(3,)))},
    }


def test_roundtrip(tmp_path):
    root = str(tmp_path / "ckpt")
    tree = _tree(1)
    save_checkpoint(root, 7, tree)
    step, restored, extra = load_checkpoint(root, tree)
    assert step == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_and_keep_k(tmp_path):
    root = str(tmp_path / "ckpt")
    tree = _tree(2)
    for s in (1, 2, 3, 4, 5):
        save_checkpoint(root, s, tree, keep=3)
    assert latest_step(root) == 5
    kept = sorted(os.listdir(root))
    assert kept == ["step_000000003", "step_000000004", "step_000000005"]


def test_extra_metadata(tmp_path):
    root = str(tmp_path / "ckpt")
    save_checkpoint(root, 1, _tree(), extra={"data_step": 41})
    _, _, extra = load_checkpoint(root, _tree())
    assert extra["data_step"] == 41


def test_structure_mismatch_rejected(tmp_path):
    root = str(tmp_path / "ckpt")
    save_checkpoint(root, 1, _tree())
    with pytest.raises(ValueError):
        load_checkpoint(root, {"different": jnp.zeros(3)})


def test_no_partial_checkpoint_on_crash(tmp_path):
    """Simulated crash mid-write must leave the old checkpoint intact."""
    root = str(tmp_path / "ckpt")
    tree = _tree(3)
    save_checkpoint(root, 1, tree)
    # simulate a crashed writer: stale tmp dir left behind
    os.makedirs(os.path.join(root, ".tmp_000000002"))
    with open(os.path.join(root, ".tmp_000000002", "garbage"), "w") as f:
        f.write("partial")
    assert latest_step(root) == 1
    step, restored, _ = load_checkpoint(root, tree)
    assert step == 1
    # a new save with the same step id must clobber the stale tmp
    save_checkpoint(root, 2, tree)
    assert latest_step(root) == 2


def test_manager_every_and_force(tmp_path):
    root = str(tmp_path / "ckpt")
    mgr = CheckpointManager(root, every=10, keep=2, async_write=True)
    tree = _tree(4)
    assert not mgr.maybe_save(5, tree)
    assert mgr.maybe_save(10, tree)
    assert mgr.maybe_save(11, tree, force=True)
    mgr.wait()
    assert latest_step(root) == 11
    assert mgr.restore_or_none(tree) is not None
    assert CheckpointManager(str(tmp_path / "none")).restore_or_none(tree) is None


def test_elastic_restore_under_new_sharding(tmp_path):
    """Save replicated, restore under an explicit (1,1) mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    root = str(tmp_path / "ckpt")
    tree = _tree(5)
    save_checkpoint(root, 3, tree)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    step, restored, _ = load_checkpoint(root, tree, shardings=sh)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert b.sharding.mesh.shape == mesh.shape


def test_train_resume_exactly_once(tmp_path):
    """Kill-and-resume mid-run reproduces the uninterrupted run exactly
    (deterministic data stream + checkpointed step counter)."""
    from repro.configs.base import get_config, reduced
    from repro.models import build_model, init_params
    from repro.training.data import DataConfig, SyntheticStream
    from repro.training.optimizer import AdamWConfig
    from repro.training.train_loop import init_train_state, make_train_step

    cfg = reduced(get_config("olmo_1b"))
    model = build_model(cfg, mesh=None)
    ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    stream = SyntheticStream(DataConfig(vocab=cfg.vocab, seq_len=16,
                                        global_batch=4))
    step_fn = jax.jit(make_train_step(model, ocfg))

    def fresh():
        params = init_params(model.defs(), jax.random.PRNGKey(7))
        return init_train_state(model.defs(), params, ocfg)

    # uninterrupted: 6 steps
    state = fresh()
    for s in range(6):
        b = stream.global_batch(s)
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
    want = np.asarray(state["opt"]["master"]["embed"]["tok"])

    # interrupted at step 3 + resume from checkpoint
    root = str(tmp_path / "ckpt")
    state = fresh()
    for s in range(3):
        b = stream.global_batch(s)
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
    save_checkpoint(root, 3, state, extra={"data_step": 3})
    del state
    step, state, extra = load_checkpoint(root, fresh())
    for s in range(extra["data_step"], 6):
        b = stream.global_batch(s)
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
    got = np.asarray(state["opt"]["master"]["embed"]["tok"])
    np.testing.assert_allclose(want, got, atol=1e-6)
