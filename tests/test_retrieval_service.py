"""Multi-group retrieval service vs the host oracle.

The service must route every query to its weight's table group, answer a
mixed batch spanning >= 3 groups *identically* to `WLSHIndex.search_dense`
(the plan ships host codes and the service host-encodes queries in f64, so
candidate sets match bit-exactly; distances compare in f32), coalesce and
pad batches without changing per-query answers, and compile at most one
query step per distinct padded shape signature.
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.serving_plan import ServingPlan
from repro.core.wlsh import WLSHIndex
from repro.serving import RetrievalService, ServiceConfig

K = 5


@pytest.fixture(scope="module")
def setup():
    data = make_dataset(n=1_024, d=16, seed=41)
    # 4 subsets of 2 users -> the partition yields 4 groups with distinct
    # per-member beta/mu (betas 135/135/137/161 at these seeds)
    weights = make_weight_set(size=8, d=16, n_subset=4, n_subrange=10,
                              seed=42)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4, seed=9)
    plan = host.export_serving_plan()
    assert plan.n_groups >= 3, "fixture must span >= 3 table groups"
    svc = RetrievalService(plan, data, cfg=ServiceConfig(k=K, q_batch=4))
    return data, weights, host, plan, svc


def _mixed_queries(data, weights, n_queries, seed=43):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def test_routing_follows_partition(setup):
    data, weights, host, plan, svc = setup
    qpts, wids = _mixed_queries(data, weights, 16)
    res = svc.query(qpts, wids)
    np.testing.assert_array_equal(
        res.group_ids, host.part.group_of[wids].astype(np.int32)
    )
    # distinct member parameters across the served groups
    betas = {int(g.beta_group) for g in plan.groups}
    mus = {tuple(g.mu_members.tolist()) for g in plan.groups}
    assert len(betas) >= 2 and len(mus) >= 3


def test_mixed_batch_matches_search_dense(setup):
    data, weights, host, plan, svc = setup
    qpts, wids = _mixed_queries(data, weights, 24)
    res = svc.query(qpts, wids)
    assert len(np.unique(res.group_ids)) >= 3
    for qi in range(len(qpts)):
        want = host.search_dense(qpts[qi], weight_id=int(wids[qi]), k=K)
        np.testing.assert_array_equal(
            res.ids[qi], want.ids.astype(np.int32),
            err_msg=f"ids mismatch at query {qi} (weight {wids[qi]})",
        )
        assert int(res.stop_levels[qi]) == want.stats.stop_level
        assert int(res.n_checked[qi]) == want.stats.n_checked
        m = res.ids[qi] >= 0
        np.testing.assert_allclose(
            res.dists[qi][m], want.dists[m], rtol=1e-4, atol=1e-2
        )


def test_one_compiled_step_per_shape_signature(setup):
    data, weights, host, plan, svc = setup
    svc.warmup()  # every group built + compiled
    signatures = {
        svc.group_config(gi).shape_signature()
        for gi in range(plan.n_groups)
    }
    assert svc.step_cache.n_compiled == len(signatures)
    # bucketed padding makes sharing actually happen on this plan
    assert svc.step_cache.n_compiled < plan.n_groups
    # repeated traffic compiles nothing new
    qpts, wids = _mixed_queries(data, weights, 8, seed=5)
    before = svc.step_cache.n_compiled
    svc.query(qpts, wids)
    assert svc.step_cache.n_compiled == before


def test_coalesced_batch_equals_one_at_a_time(setup):
    data, weights, host, plan, svc = setup
    # all queries under weights of one group -> coalesced into shared batches
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    members = plan.groups[gi].member_ids
    rng = np.random.default_rng(7)
    wids = members[rng.integers(0, len(members), 6)]
    qpts = data[rng.choice(len(data), 6, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)

    batched = svc.query(qpts, wids)
    assert np.all(batched.group_ids == gi)
    for qi in range(len(qpts)):
        single = svc.query(qpts[qi : qi + 1], wids[qi : qi + 1])
        np.testing.assert_array_equal(single.ids[0], batched.ids[qi])
        np.testing.assert_array_equal(single.dists[0], batched.dists[qi])
        assert single.stop_levels[0] == batched.stop_levels[qi]
        assert single.n_checked[0] == batched.n_checked[qi]


def test_ragged_batches_match_aligned(setup):
    data, weights, host, plan, svc = setup
    # 13 mixed queries with q_batch=4 -> every group serves a padded tail
    qpts, wids = _mixed_queries(data, weights, 13, seed=11)
    ragged = svc.query(qpts, wids)
    # same queries submitted one by one (maximal padding, 1/4 occupancy)
    for qi in range(len(qpts)):
        single = svc.query(qpts[qi : qi + 1], wids[qi : qi + 1])
        np.testing.assert_array_equal(single.ids[0], ragged.ids[qi])
        np.testing.assert_array_equal(single.dists[0], ragged.dists[qi])


def test_serving_stats_accounting(setup):
    data, weights, host, plan, svc = setup
    svc.reset_stats()
    qpts, wids = _mixed_queries(data, weights, 13, seed=11)
    res = svc.query(qpts, wids)
    summary = svc.stats_summary()
    assert sum(s["n_queries"] for s in summary.values()) == 13
    for gi, s in summary.items():
        served = int(np.sum(res.group_ids == gi))
        assert s["n_queries"] == served
        assert 0.0 < s["occupancy"] <= 1.0
        assert s["n_batches"] == -(-served // svc.cfg.q_batch)


def test_plan_npz_roundtrip(tmp_path, setup):
    data, weights, host, plan, svc = setup
    path = str(tmp_path / "plan.npz")
    plan.save_npz(path)
    plan2 = ServingPlan.load_npz(path)
    assert plan2.n_groups == plan.n_groups
    assert (plan2.n, plan2.d, plan2.c, plan2.p) == (
        plan.n, plan.d, plan.c, plan.p
    )
    np.testing.assert_array_equal(plan2.group_of, plan.group_of)
    np.testing.assert_array_equal(plan2.weights, plan.weights)
    for a, b in zip(plan.groups, plan2.groups):
        np.testing.assert_array_equal(a.proj, b.proj)
        np.testing.assert_array_equal(a.codes, b.codes)
        np.testing.assert_array_equal(a.mu_members, b.mu_members)
        np.testing.assert_array_equal(a.r_min_members, b.r_min_members)
        assert a.width == b.width and a.levels_cap == b.levels_cap
    # a service over the reloaded plan answers identically
    svc2 = RetrievalService(plan2, data, cfg=ServiceConfig(k=K, q_batch=4))
    qpts, wids = _mixed_queries(data, weights, 6, seed=3)
    r1, r2 = svc.query(qpts, wids), svc2.query(qpts, wids)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    np.testing.assert_array_equal(r1.dists, r2.dists)


def test_plan_without_codes_serves_via_device_encoding(setup):
    """include_codes=False: data codes are built on device (f32), so query
    codes must come from the same encoding — the service falls back from
    host_encode automatically and self-queries still find themselves."""
    data, weights, host, plan, svc = setup
    plan2 = host.export_serving_plan(include_codes=False)
    assert all(g.codes is None for g in plan2.groups)
    svc2 = RetrievalService(plan2, data, cfg=ServiceConfig(k=K, q_batch=4))
    rng = np.random.default_rng(13)
    wids = rng.integers(0, len(weights), 4)
    res = svc2.query(data[:4].astype(np.float32), wids)
    np.testing.assert_array_equal(res.ids[:, 0], np.arange(4))
    assert np.all(res.dists[:, 0] < 1e-3)


def test_weight_id_validation(setup):
    data, weights, host, plan, svc = setup
    q = data[:1].astype(np.float32)
    with pytest.raises(ValueError):
        svc.query(q, [len(weights)])
    with pytest.raises(ValueError):
        svc.query(q, [-1])
    with pytest.raises(ValueError):
        svc.query(data[:2].astype(np.float32), [0])
