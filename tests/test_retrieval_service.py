"""Multi-group retrieval service vs the host oracle, and the batching core.

The service must route every query to its weight's table group, answer a
mixed batch spanning >= 3 groups *identically* to `WLSHIndex.search_dense`
for every supported exponent p in {2, 1, 0.5} (the plan ships host codes
and the service host-encodes queries in f64, so candidate sets match
bit-exactly; distances compare in f32), coalesce and pad batches without
changing per-query answers, and compile at most one query step per
distinct padded shape signature.

The shared batching core (`serving.batching`) is additionally pinned by
hypothesis property tests against a fake executor: arbitrary interleavings
of group ids and ragged tails always merge back in submission order with
no dropped or duplicated query, and padded rows never leak into results.
"""

from __future__ import annotations

import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import build_parity_service
from repro.core.serving_plan import ServingPlan
from repro.serving import RetrievalService, ServiceConfig
from repro.serving.batching import coalesce, pad_take, run_plans

K = 5


@pytest.fixture(scope="module")
def setup():
    # the p=2 instance of the session parity build (betas 135/135/137/161
    # at these seeds); structure tests share it with the parity suite
    return build_parity_service(2.0)[1:]


def _mixed_queries(data, weights, n_queries, seed=43):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def test_routing_follows_partition(setup):
    data, weights, host, plan, svc = setup
    qpts, wids = _mixed_queries(data, weights, 16)
    res = svc.query(qpts, wids)
    np.testing.assert_array_equal(
        res.group_ids, host.part.group_of[wids].astype(np.int32)
    )
    # distinct member parameters across the served groups
    betas = {int(g.beta_group) for g in plan.groups}
    mus = {tuple(g.mu_members.tolist()) for g in plan.groups}
    assert len(betas) >= 2 and len(mus) >= 3


def test_mixed_batch_matches_search_dense(parity_setup):
    """Bit-exact ids/stop/n_checked vs the host oracle, per p in {2, 1, 0.5}."""
    p, data, weights, host, plan, svc = parity_setup
    qpts, wids = _mixed_queries(data, weights, 24)
    res = svc.query(qpts, wids)
    assert len(np.unique(res.group_ids)) >= 3
    for qi in range(len(qpts)):
        want = host.search_dense(qpts[qi], weight_id=int(wids[qi]), k=K)
        np.testing.assert_array_equal(
            res.ids[qi], want.ids.astype(np.int32),
            err_msg=f"ids mismatch at query {qi} (weight {wids[qi]}, p={p})",
        )
        assert int(res.stop_levels[qi]) == want.stats.stop_level
        assert int(res.n_checked[qi]) == want.stats.n_checked
        m = res.ids[qi] >= 0
        np.testing.assert_allclose(
            res.dists[qi][m], want.dists[m], rtol=1e-4, atol=1e-2
        )


def test_one_compiled_step_per_shape_signature(setup):
    data, weights, host, plan, svc = setup
    svc.warmup()  # every group built + compiled
    signatures = {
        svc.group_config(gi).shape_signature()
        for gi in range(plan.n_groups)
    }
    assert svc.step_cache.n_compiled == len(signatures)
    # bucketed padding makes sharing actually happen on this plan
    assert svc.step_cache.n_compiled < plan.n_groups
    # repeated traffic compiles nothing new
    qpts, wids = _mixed_queries(data, weights, 8, seed=5)
    before = svc.step_cache.n_compiled
    svc.query(qpts, wids)
    assert svc.step_cache.n_compiled == before


def test_coalesced_batch_equals_one_at_a_time(setup):
    data, weights, host, plan, svc = setup
    # all queries under weights of one group -> coalesced into shared batches
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    members = plan.groups[gi].member_ids
    rng = np.random.default_rng(7)
    wids = members[rng.integers(0, len(members), 6)]
    qpts = data[rng.choice(len(data), 6, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)

    batched = svc.query(qpts, wids)
    assert np.all(batched.group_ids == gi)
    for qi in range(len(qpts)):
        single = svc.query(qpts[qi : qi + 1], wids[qi : qi + 1])
        np.testing.assert_array_equal(single.ids[0], batched.ids[qi])
        np.testing.assert_array_equal(single.dists[0], batched.dists[qi])
        assert single.stop_levels[0] == batched.stop_levels[qi]
        assert single.n_checked[0] == batched.n_checked[qi]


def test_ragged_batches_match_aligned(setup):
    data, weights, host, plan, svc = setup
    # 13 mixed queries with q_batch=4 -> every group serves a padded tail
    qpts, wids = _mixed_queries(data, weights, 13, seed=11)
    ragged = svc.query(qpts, wids)
    # same queries submitted one by one (maximal padding, 1/4 occupancy)
    for qi in range(len(qpts)):
        single = svc.query(qpts[qi : qi + 1], wids[qi : qi + 1])
        np.testing.assert_array_equal(single.ids[0], ragged.ids[qi])
        np.testing.assert_array_equal(single.dists[0], ragged.dists[qi])


def test_serving_stats_accounting(setup):
    data, weights, host, plan, svc = setup
    svc.reset_stats()
    qpts, wids = _mixed_queries(data, weights, 13, seed=11)
    res = svc.query(qpts, wids)
    summary = svc.stats_summary()
    assert sum(s["n_queries"] for s in summary.values()) == 13
    for gi, s in summary.items():
        served = int(np.sum(res.group_ids == gi))
        assert s["n_queries"] == served
        assert 0.0 < s["occupancy"] <= 1.0
        assert s["n_batches"] == -(-served // svc.cfg.q_batch)


def test_plan_npz_roundtrip(tmp_path, setup):
    data, weights, host, plan, svc = setup
    path = str(tmp_path / "plan.npz")
    plan.save_npz(path)
    plan2 = ServingPlan.load_npz(path)
    assert plan2.n_groups == plan.n_groups
    assert (plan2.n, plan2.d, plan2.c, plan2.p) == (
        plan.n, plan.d, plan.c, plan.p
    )
    np.testing.assert_array_equal(plan2.group_of, plan.group_of)
    np.testing.assert_array_equal(plan2.weights, plan.weights)
    for a, b in zip(plan.groups, plan2.groups):
        np.testing.assert_array_equal(a.proj, b.proj)
        np.testing.assert_array_equal(a.codes, b.codes)
        np.testing.assert_array_equal(a.mu_members, b.mu_members)
        np.testing.assert_array_equal(a.r_min_members, b.r_min_members)
        assert a.width == b.width and a.levels_cap == b.levels_cap
    # a service over the reloaded plan answers identically
    svc2 = RetrievalService(plan2, data, cfg=ServiceConfig(k=K, q_batch=4))
    qpts, wids = _mixed_queries(data, weights, 6, seed=3)
    r1, r2 = svc.query(qpts, wids), svc2.query(qpts, wids)
    np.testing.assert_array_equal(r1.ids, r2.ids)
    np.testing.assert_array_equal(r1.dists, r2.dists)


def test_plan_npz_roundtrip_preserves_dtypes_exactly(tmp_path, setup):
    """Regression guard for the offload/restore path: every array of a
    saved-and-reloaded ServingPlan must keep its exact dtype and bytes
    (r_min stays f64, codes stay i32, ...), scalars their python types,
    and optional host codes must round-trip both present and absent."""
    import dataclasses

    data, weights, host, plan, svc = setup
    path = str(tmp_path / "plan_dtypes.npz")
    plan.save_npz(path)
    plan2 = ServingPlan.load_npz(path)
    for f in ("weights", "group_of", "member_slot"):
        a, b = getattr(plan, f), getattr(plan2, f)
        assert a.dtype == b.dtype, f"plan.{f} dtype drifted"
        np.testing.assert_array_equal(a, b)
    for f in ("n", "d", "c"):
        assert isinstance(getattr(plan2, f), int)
    for f in ("p", "gamma_n", "tau"):
        assert isinstance(getattr(plan2, f), float)
        assert getattr(plan2, f) == getattr(plan, f)
    for g, g2 in zip(plan.groups, plan2.groups):
        for fld in dataclasses.fields(g):
            a, b = getattr(g, fld.name), getattr(g2, fld.name)
            if isinstance(a, np.ndarray):
                assert a.dtype == b.dtype, (
                    f"group.{fld.name} dtype drifted: {a.dtype} -> {b.dtype}"
                )
                np.testing.assert_array_equal(
                    a, b, err_msg=f"group.{fld.name} values drifted"
                )
            else:
                assert type(a) is type(b) and a == b, f"group.{fld.name}"
    # optional host codes absent: stays absent through the round-trip
    plan_nc = host.export_serving_plan(include_codes=False)
    path_nc = str(tmp_path / "plan_nocodes.npz")
    plan_nc.save_npz(path_nc)
    plan_nc2 = ServingPlan.load_npz(path_nc)
    assert all(g.codes is None for g in plan_nc2.groups)


def test_plan_without_codes_serves_via_device_encoding(setup):
    """include_codes=False: data codes are built on device (f32), so query
    codes must come from the same encoding — the service falls back from
    host_encode automatically and self-queries still find themselves."""
    data, weights, host, plan, svc = setup
    plan2 = host.export_serving_plan(include_codes=False)
    assert all(g.codes is None for g in plan2.groups)
    svc2 = RetrievalService(plan2, data, cfg=ServiceConfig(k=K, q_batch=4))
    rng = np.random.default_rng(13)
    wids = rng.integers(0, len(weights), 4)
    res = svc2.query(data[:4].astype(np.float32), wids)
    np.testing.assert_array_equal(res.ids[:, 0], np.arange(4))
    assert np.all(res.dists[:, 0] < 1e-3)


def test_weight_id_validation(setup):
    data, weights, host, plan, svc = setup
    q = data[:1].astype(np.float32)
    with pytest.raises(ValueError):
        svc.query(q, [len(weights)])
    with pytest.raises(ValueError):
        svc.query(q, [-1])
    with pytest.raises(ValueError):
        svc.query(data[:2].astype(np.float32), [0])


# ------------------------------------------------------- config validation


@pytest.mark.parametrize("kwargs", [
    dict(q_batch=0),
    dict(q_batch=-3),
    dict(k=0),
    dict(block_n=0),
    dict(level_step=0),
    dict(budget_override=0),
    dict(max_delay_ms=-1.0),
    dict(max_delay_ms=float("nan")),
    dict(beta_buckets=()),
    dict(beta_buckets=(0, 32)),
    dict(vec_dtype="not-a-dtype"),
])
def test_service_config_rejects_invalid(kwargs):
    with pytest.raises(ValueError):
        ServiceConfig(**kwargs)


def test_service_config_accepts_defaults_and_edges():
    ServiceConfig()  # defaults must validate
    ServiceConfig(q_batch=1, k=1, level_step=1, max_delay_ms=0.0,
                  block_n=1, budget_override=1, beta_buckets=(32, 512),
                  vec_dtype="bfloat16")


# ------------------------------- batching core properties (fake executor)


@st.composite
def _traffic_shape(draw):
    """Arbitrary interleaving of group ids plus a compiled batch size."""
    n_groups = draw(st.integers(1, 5))
    gids = draw(st.lists(st.integers(0, n_groups - 1), min_size=1,
                         max_size=48))
    q_batch = draw(st.integers(1, 9))
    return np.asarray(gids), q_batch


@given(_traffic_shape())
@settings(max_examples=100, deadline=None)
def test_coalesce_partitions_every_submission_once(traffic):
    gids, qb = traffic
    plans = coalesce(gids, qb)
    rows = np.concatenate([bp.rows for bp in plans])
    assert sorted(rows.tolist()) == list(range(len(gids)))  # no drop/dup
    for bp in plans:
        assert 1 <= len(bp.rows) <= qb
        assert np.all(gids[bp.rows] == bp.group_id)
        assert np.all(np.diff(bp.rows) > 0)  # submission order within batch
    for gi in np.unique(gids):
        served = int(np.sum(gids == gi))
        n_batches = sum(bp.group_id == gi for bp in plans)
        assert n_batches == -(-served // qb)  # minimal batch count


@given(st.integers(1, 9))
@settings(max_examples=50, deadline=None)
def test_pad_take_cycles_real_rows(qb):
    for real in range(1, qb + 1):
        take = pad_take(real, qb)
        assert take.shape == (qb,)
        np.testing.assert_array_equal(take[:real], np.arange(real))
        np.testing.assert_array_equal(take, np.arange(qb) % real)
    with pytest.raises(ValueError):
        pad_take(0, qb)
    with pytest.raises(ValueError):
        pad_take(qb + 1, qb)


@given(_traffic_shape())
@settings(max_examples=100, deadline=None)
def test_run_plans_merges_in_submission_order_without_pad_leak(traffic):
    """A fake executor tags each padded row; merged results must hold every
    submission's own tag exactly once and never a pad poison value."""
    gids, qb = traffic
    nq, k = len(gids), 3
    queries = np.arange(nq, dtype=np.float32).reshape(nq, 1)  # row tag
    wids = np.arange(nq)  # weight_ids double as submission indices
    pad_poison = -7
    reals = []

    def fake_run_batch(gi, qsub, wsub):
        real = len(qsub)
        assert 1 <= real <= qb
        assert np.all(gids[wsub] == gi)  # only rows routed to this group
        np.testing.assert_array_equal(qsub[:, 0].astype(np.int64), wsub)
        take = pad_take(real, qb)
        padded_rows = wsub[take]  # what the compiled step would see
        ids = np.repeat(padded_rows[:, None], k, 1).astype(np.int32)
        stop = padded_rows.astype(np.int32)
        ids[real:] = pad_poison  # poison pad outputs: must never merge
        stop[real:] = pad_poison
        reals.append(real)
        return (ids[:real], ids[:real].astype(np.float32),
                stop[:real], stop[:real])

    out_ids, out_d, out_stop, out_chk = run_plans(
        coalesce(gids, qb), queries, wids, fake_run_batch, k
    )
    want = np.repeat(np.arange(nq, dtype=np.int32)[:, None], k, 1)
    np.testing.assert_array_equal(out_ids, want)  # submission order kept
    np.testing.assert_array_equal(out_stop, np.arange(nq))
    np.testing.assert_array_equal(out_chk, np.arange(nq))
    assert not np.any(out_ids == pad_poison)
    assert not np.any(out_stop == pad_poison)
    assert sum(reals) == nq  # every query executed exactly once
