"""Data pipeline determinism/shardability + fault-tolerance substrate."""

from __future__ import annotations

import os
import signal

import numpy as np
import pytest

from repro.distributed.fault import (
    PreemptionHandler,
    RestartSupervisor,
    StragglerMonitor,
)
from repro.training.data import DataConfig, SyntheticStream


# ----------------------------------------------------------------- data


def _cfg(**kw):
    base = dict(vocab=64, seq_len=12, global_batch=8, seed=3)
    base.update(kw)
    return DataConfig(**base)


def test_stream_deterministic():
    a = SyntheticStream(_cfg()).global_batch(5)
    b = SyntheticStream(_cfg()).global_batch(5)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])
    np.testing.assert_array_equal(a["labels"], b["labels"])


def test_stream_steps_differ():
    s = SyntheticStream(_cfg())
    assert not np.array_equal(s.global_batch(0)["tokens"],
                              s.global_batch(1)["tokens"])


def test_labels_are_shifted_tokens():
    b = SyntheticStream(_cfg()).global_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_host_shards_partition_global_batch():
    s = SyntheticStream(_cfg())
    full = s.global_batch(2)
    parts = [s.host_shard(2, h, 4) for h in range(4)]
    glued = np.concatenate([p["tokens"] for p in parts])
    np.testing.assert_array_equal(full["tokens"], glued)


def test_markov_structure_learnable():
    """Markov mode: successor entropy is ~log(branching) << log(vocab)."""
    s = SyntheticStream(_cfg(mode="markov", branching=4, global_batch=64))
    b = s.global_batch(0)
    toks = b["tokens"]
    succ: dict[int, set] = {}
    for row in toks:
        for i in range(len(row) - 1):
            succ.setdefault(int(row[i]), set()).add(int(row[i + 1]))
    n_succ = [len(v) for v in succ.values() if v]
    assert np.mean(n_succ) <= 4.5  # bounded branching (vs 64 for uniform)


# ----------------------------------------------------------------- fault


def test_preemption_handler_sets_flag():
    h = PreemptionHandler(signals=(signal.SIGUSR1,))
    assert not h.should_stop
    os.kill(os.getpid(), signal.SIGUSR1)
    assert h.should_stop
    h.restore()


def test_straggler_monitor_flags_outliers():
    m = StragglerMonitor(window=20, threshold=2.0)
    for _ in range(15):
        assert m.record(1.0) is None
    rep = m.record(3.5)
    assert rep is not None and rep.ratio == pytest.approx(3.5)
    assert m.flagged and m.flagged[0].duration == 3.5
    # normal steps after the spike are not flagged
    assert m.record(1.1) is None


def test_straggler_monitor_warmup_silent():
    m = StragglerMonitor(window=50)
    for _ in range(3):
        assert m.record(100.0) is None  # no baseline yet -> no flags


def test_restart_supervisor_recovers():
    calls = {"n": 0, "resume": []}

    def resume_step():
        return calls["n"]

    def body(resume):
        calls["resume"].append(resume)
        calls["n"] += 1
        if calls["n"] < 3:
            raise RuntimeError(f"simulated failure {calls['n']}")
        return "done"

    sup = RestartSupervisor(max_restarts=5)
    assert sup.run(body, resume_step) == "done"
    assert sup.restarts == 2
    assert calls["resume"] == [0, 1, 2]  # resumed from the advancing step


def test_restart_supervisor_gives_up():
    sup = RestartSupervisor(max_restarts=2)

    def body(_):
        raise RuntimeError("always fails")

    with pytest.raises(RuntimeError):
        sup.run(body, lambda: 0)
    assert sup.restarts == 3
    assert len(sup.failures) == 3
