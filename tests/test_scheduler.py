"""Real-time scheduler: driver ticks, predictive prefetch, cost-aware evict.

Everything here is deterministic — drivers are stepped on a ``ManualClock``
and the cache-level behaviour is pinned against fake build/offload/restore
executors (no device, no wall-clock sleeps):

* prefetch brings a state on device ahead of its acquire (the consuming
  acquire is a hit and counts the overlapped restore), never evicts a
  pinned or protected (about-to-launch) state, and unconsumed prefetches
  are counted as wasted;
* the cost-aware eviction policy orders victims by staleness per restore
  byte (hypothesis property test against the argmax model), degrading to
  LRU at equal sizes;
* a ``ServiceDriver``-stepped replay is bit-exact with the undriven
  ``poll()`` replay of the same trace, per p in {2, 1, 0.5};
* no deadline fires late when capacity allows: every future resolves at
  its deadline tick, never after;
* idle-time background compaction is the driver's once one is attached.
"""

from __future__ import annotations

import numpy as np
import pytest

from _hyp import given, settings, st
from conftest import build_parity_service
from repro.serving import (
    AsyncRetrievalService,
    CostAwareEviction,
    DeadlinePrefetch,
    EvictionCandidate,
    LRUEviction,
    ManualClock,
    RetrievalService,
    ServiceConfig,
    ServiceDriver,
    StateCache,
    replay_open_loop,
    replay_with_driver,
)

K = 5


# ------------------------------------------------- fake-executor unit tests


def _fake_cache(cap=None, budget=None, nbytes=lambda gi: 10, log=None,
                policy=None):
    """StateCache over fake build/offload/restore executors (no device)."""
    return StateCache(
        build=lambda gi: ("dev", gi),
        nbytes_of=nbytes,
        max_resident_groups=cap,
        device_budget_bytes=budget,
        offload=lambda state: ("host", state),
        restore=lambda gi, host: host[1],
        on_event=(lambda gi, kind: log.append((gi, kind)))
        if log is not None else None,
        eviction_policy=policy,
    )


def test_prefetch_restore_then_acquire_counts_overlap():
    cache = _fake_cache(cap=1)
    with cache.lease(0):
        pass
    with cache.lease(1):  # 0 offloaded
        pass
    assert cache.prefetch(0) is True  # evicts 1, restores 0 ahead of time
    assert cache.is_resident(0) and not cache.is_resident(1)
    assert cache.stats.n_prefetches == 1
    assert cache.pin_count(0) == 0  # prefetched, not pinned
    with cache.lease(0):  # the consuming acquire: a hit, overlapped
        pass
    s = cache.stats
    assert s.n_hits == 1
    assert s.n_restore_overlapped == 1
    assert s.n_prefetch_wasted == 0
    # consuming twice must not double-count the overlap
    with cache.lease(0):
        pass
    assert cache.stats.n_restore_overlapped == 1


def test_prefetch_of_resident_state_is_noop():
    cache = _fake_cache(cap=2)
    with cache.lease(0):
        pass
    assert cache.prefetch(0) is False
    assert cache.stats.n_prefetches == 0


def test_unconsumed_prefetch_counts_wasted():
    log = []
    cache = _fake_cache(cap=1, log=log)
    assert cache.prefetch(0) is True  # cold prefetch = build
    with cache.lease(1):  # evicts 0 before anything consumed it
        pass
    s = cache.stats
    assert s.n_prefetch_wasted == 1
    assert s.n_restore_overlapped == 0
    assert (0, "prefetch_wasted") in log


def test_prefetch_never_evicts_pinned_or_protected_state():
    """The satellite invariant: a prefetch must not evict a pinned state
    or one protected as about-to-launch — the budget goes soft instead."""
    cache = _fake_cache(cap=1)
    cache.acquire(0)  # pinned (launch in flight)
    cache.protect([1])
    with cache.lease(1):
        pass
    assert cache.is_resident(0) and cache.is_resident(1)
    cache.prefetch(2)  # over budget, but 0 pinned and 1 protected
    assert cache.is_resident(0) and cache.is_resident(1)
    assert cache.is_resident(2)
    assert cache.n_resident == 3  # soft budget, nothing thrashed
    cache.release(0)
    cache.protect(())  # next enforcement point reclaims the excess
    with cache.lease(2):
        pass
    assert cache.n_resident == 1


def test_protection_is_replaced_not_accumulated():
    cache = _fake_cache(cap=1)
    cache.protect([0, 1])
    assert cache.protected_group_ids() == frozenset({0, 1})
    cache.protect([2])
    assert cache.protected_group_ids() == frozenset({2})


def test_cost_aware_eviction_spares_expensive_restores():
    """With distinct sizes the cost-aware policy deviates from LRU: the
    small (cheap-to-restore) state goes first even though the large one
    is staler."""
    sizes = {0: 100, 1: 10, 2: 10}
    cache = _fake_cache(budget=115, nbytes=lambda gi: sizes[gi],
                        policy=CostAwareEviction())
    with cache.lease(0):  # large, older
        pass
    with cache.lease(1):  # small, newer
        pass
    with cache.lease(2):  # 120 > 115: must evict 1 although 0 is staler
        pass
    assert cache.is_resident(0) and cache.is_resident(2)
    assert not cache.is_resident(1)
    assert cache.resident_bytes == 110


def test_lru_policy_matches_default_choice():
    log_a, log_b = [], []
    a = _fake_cache(cap=2, log=log_a)  # built-in LRU
    b = _fake_cache(cap=2, log=log_b, policy=LRUEviction())
    for cache in (a, b):
        for gi in (0, 1, 2, 0, 3):
            with cache.lease(gi):
                pass
    assert [e for e in log_a if e[1] == "evict"] == (
        [e for e in log_b if e[1] == "evict"]
    )
    assert a.resident_group_ids() == b.resident_group_ids()


def test_eviction_policy_returning_non_candidate_raises():
    cache = _fake_cache(cap=1, policy=lambda cands: 999)
    with cache.lease(0):
        pass
    with pytest.raises(ValueError, match="policy"):
        cache.acquire(1)


@st.composite
def _candidate_set(draw):
    """Distinct-group candidates with arbitrary recency ticks and sizes."""
    n = draw(st.integers(1, 8))
    last_uses = draw(st.lists(st.integers(0, 100), min_size=n, max_size=n))
    nbytes = draw(st.lists(st.integers(1, 1 << 20), min_size=n, max_size=n))
    flags = draw(st.lists(st.booleans(), min_size=n, max_size=n))
    return tuple(
        EvictionCandidate(group_id=gi, last_use=last_uses[gi],
                          nbytes=nbytes[gi], prefetched=flags[gi])
        for gi in range(n)
    )


@given(_candidate_set())
@settings(max_examples=200, deadline=None)
def test_cost_aware_ordering_property(candidates):
    """The satellite property: CostAwareEviction picks exactly the argmax
    of staleness-per-restore-byte (ties: staler first, then smaller
    group id), always from the offered candidates; with equal sizes it
    is exactly LRU."""
    policy = CostAwareEviction()
    victim = policy(candidates)
    ids = {c.group_id for c in candidates}
    assert victim in ids
    now = max(c.last_use for c in candidates) + 1

    def key(c):
        return ((now - c.last_use) / c.nbytes, -c.last_use, -c.group_id)

    best = max(candidates, key=key)
    assert victim == best.group_id
    # equal sizes: degrades to the LRU choice exactly
    flat = tuple(
        EvictionCandidate(c.group_id, c.last_use, 64, c.prefetched)
        for c in candidates
    )
    lru_victims = [
        c.group_id for c in flat
        if c.last_use == min(x.last_use for x in flat)
    ]
    assert policy(flat) == min(lru_victims)
    assert LRUEviction()(flat) == min(lru_victims)


# --------------------------------------------------- driver-stepped serving


def _paged_async(plan, data, cap=1, q_batch=4, **svc_kw):
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=K, q_batch=q_batch,
                          max_resident_groups=cap, **svc_kw),
    )
    svc.warmup()
    svc.reset_stats()
    return AsyncRetrievalService(svc.batcher, max_delay_ms=2.0,
                                 clock=ManualClock())


def _mixed_queries(data, weights, n_queries, seed=43):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n_queries)
    qpts = data[rng.choice(len(data), n_queries, replace=False)].astype(
        np.float32
    )
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return qpts, wids


def test_driver_stepped_replay_bit_exact_vs_poll_loop(parity_setup):
    """Acceptance: the driver-stepped replay (prefetch + cost-aware
    eviction on) answers bit-exactly like the undriven poll() replay and
    the sync frontend, per p in {2, 1, 0.5}, under a paging budget."""
    p, data, weights, host, plan, svc = parity_setup
    qpts, wids = _mixed_queries(data, weights, 24, seed=31)
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, len(qpts)))
    sync = svc.query(qpts, wids)  # unpaged sync reference

    undriven = _paged_async(plan, data)
    res_u, _ = replay_open_loop(undriven, qpts, wids, arrivals)

    driven = _paged_async(plan, data)
    driver = ServiceDriver(driven)
    res_d, _ = replay_with_driver(driver, qpts, wids, arrivals)

    for res in (res_u, res_d):
        np.testing.assert_array_equal(res.ids, sync.ids)
        np.testing.assert_array_equal(res.dists, sync.dists)
        np.testing.assert_array_equal(res.stop_levels, sync.stop_levels)
        np.testing.assert_array_equal(res.n_checked, sync.n_checked)
    # the driver actually scheduled: prefetches were issued and consumed
    cs = driven.batcher.state_cache.stats
    assert driver.stats.n_prefetches_issued > 0
    assert cs.n_restore_overlapped > 0
    assert driver.stats.n_launches == driven.n_launched_deadline


def test_no_deadline_fires_late_when_capacity_allows(parity_setup):
    """Stepping the driver at each deadline resolves every future exactly
    at its deadline — never after, and never before its batch is due."""
    p, data, weights, host, plan, _ = parity_setup
    asvc = _paged_async(plan, data)
    driver = ServiceDriver(asvc)
    clock = asvc.clock
    qpts, wids = _mixed_queries(data, weights, 8, seed=3)
    futs = []
    for i in range(len(qpts)):
        target = 0.0005 * (i + 1)
        while True:  # fire every deadline expiring before this arrival
            nd = asvc.next_deadline()
            if nd is None or nd > target:
                break
            clock.advance_to(nd)
            driver.step()
        clock.advance_to(target)
        futs.append(driver.submit(qpts[i], wids[i]))
    while asvc.pending_count:
        nd = asvc.next_deadline()
        clock.advance_to(nd)
        driver.step()
    for fut, _ in zip(futs, qpts):
        assert fut.done()
    deadline_budget = asvc.max_delay_ms / 1e3
    for i, fut in enumerate(futs):
        # submitted at (i+1)*0.5ms; resolved by its own deadline at the
        # latest (full-batch launches resolve earlier)
        submit_t = 0.0005 * (i + 1)
        assert fut.t_resolved <= submit_t + deadline_budget + 1e-9
    assert driver.stats.n_deadline_misses <= driver.stats.n_deadlines_due


def test_driver_owns_idle_background_compaction(parity_setup):
    """Idle-work handoff: with a driver attached, an undriven poll() no
    longer compacts — the driver's idle ticks do."""
    p, data, weights, host, plan, _ = parity_setup
    asvc = _paged_async(plan, data, cap=None, delta_seal_rows=2,
                        delta_reserve_rows=16)
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    w_in = int(plan.groups[gi].member_ids[0])
    v = (data[3] + 50_000.0).astype(np.float32)
    asvc.insert(v, w_in)
    asvc.insert(v + 1.0, w_in)  # seals at 2 rows
    assert asvc.batcher.delta.summary()["n_sealed_segments"] == 1
    driver = ServiceDriver(asvc)
    asvc.poll()  # idle poll, but the driver owns idle work now
    assert asvc.batcher.delta.summary()["n_compactions"] == 0
    driver.step()  # idle driver tick compacts the sealed backlog
    assert asvc.batcher.delta.summary()["n_compactions"] == 1
    assert driver.stats.n_idle_compactions == 1
    driver.detach()  # handoff reverses: undriven polls compact again
    asvc.insert(v + 2.0, w_in)
    asvc.insert(v + 3.0, w_in)
    asvc.poll()
    assert asvc.batcher.delta.summary()["n_compactions"] == 2


def test_driver_attach_detach_contract(parity_setup):
    p, data, weights, host, plan, _ = parity_setup
    asvc = _paged_async(plan, data)
    cache = asvc.batcher.state_cache
    assert cache.eviction_policy is None
    driver = ServiceDriver(asvc)
    assert asvc.driver is driver
    assert isinstance(cache.eviction_policy, CostAwareEviction)
    with pytest.raises(ValueError, match="already has a driver"):
        ServiceDriver(asvc)
    with pytest.raises(TypeError, match="ManualClock"):
        driver.start()  # thread mode refuses a manual clock
    driver.detach()
    assert asvc.driver is None
    assert cache.eviction_policy is None
    assert cache.protected_group_ids() == frozenset()


def test_driver_never_makes_over_budget_residency_steady(parity_setup):
    """The scheduler's imminent set is clamped to the cache budget: with
    a wide prefetch horizon and a cap of 1 group, protection + prefetch
    must not hold extra states resident in steady state — peak residency
    stays within cap + the one launch-transient group."""
    p, data, weights, host, plan, _ = parity_setup
    asvc = _paged_async(plan, data, cap=1)
    cache = asvc.batcher.state_cache
    peaks = []
    orig = cache._on_event
    cache._on_event = lambda gi, kind: (
        peaks.append(cache.n_resident), orig(gi, kind)
    )
    driver = ServiceDriver(asvc)  # default horizon >> 2 ms deadlines
    qpts, wids = _mixed_queries(data, weights, 24, seed=31)
    rng = np.random.default_rng(7)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, len(qpts)))
    replay_with_driver(driver, qpts, wids, arrivals)
    assert max(peaks) <= 2  # cap (protected/prefetched) + launch transient
    assert cache.n_resident <= 1


def test_prefetch_policy_reads_depth_and_deadline():
    policy = DeadlinePrefetch(horizon_s=0.010, depth_fraction=0.5)
    pending = {
        3: (1, 1.005),  # deadline within the 10 ms horizon
        5: (1, 9.000),  # far future, shallow: not imminent
        7: (4, 9.000),  # far future but buffer >= half of q_batch=8
        2: (1, 1.001),  # most imminent deadline
    }
    order, shield = policy.plan(pending, q_batch=8, now=1.0)
    assert order == [2, 3, 7]  # soonest deadline first
    assert shield == {2, 3, 7}


def test_driver_thread_start_stop_resolves_futures(parity_setup):
    """Thread-mode smoke on the real clock: start/submit/stop(drain) must
    resolve every future (stop drains, so this holds even on a machine
    too slow for the thread to tick) — no sleeps, no timing asserts."""
    p, data, weights, host, plan, _ = parity_setup
    svc = RetrievalService(
        plan, data, cfg=ServiceConfig(k=K, q_batch=4,
                                      max_resident_groups=1),
    )
    svc.warmup()
    asvc = AsyncRetrievalService(svc.batcher, max_delay_ms=0.5)
    driver = ServiceDriver(asvc, tick_s=0.001)
    driver.start()
    assert driver.running
    qpts, wids = _mixed_queries(data, weights, 6, seed=23)
    futs = [driver.submit(qpts[i], wids[i]) for i in range(len(qpts))]
    driver.stop(drain=True)
    assert not driver.running
    assert all(f.done() for f in futs)
    sync = svc.query(qpts, wids)  # thread-mode answers are still bit-exact
    got = np.stack([f.result().ids for f in futs])
    np.testing.assert_array_equal(got, sync.ids)
    driver.stop()  # idempotent
