"""Baselines the paper compares against: E2LSH, SL-ALSH, S2-ALSH."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.alsh import ALSHIndex, alsh_tables, rho_s2, rho_sl
from repro.core.datagen import make_dataset, make_weight_set
from repro.core.distances import weighted_lp_np
from repro.core.e2lsh import E2LSH, e2lsh_params
from repro.core.params import PlanConfig


@pytest.fixture(scope="module")
def weights():
    return make_weight_set(size=16, d=16, n_subset=4, n_subrange=5, seed=1)


# ------------------------------------------------------------------ E2LSH


def test_e2lsh_params_regime():
    m, L, rho, p1, p2 = e2lsh_params(n=400_000, w=4.0, c=3.0, p=2.0)
    assert 0.0 < rho < 1.0
    assert 0 < p2 < p1 < 1
    assert m >= 1 and L >= 1
    # sublinearity: L = n^rho << n
    assert L < 400_000


def test_e2lsh_recovers_neighbors():
    data = make_dataset(n=1_200, d=16, seed=3)
    w = np.ones(16)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    idx = E2LSH(data, w, cfg, seed=4, max_tables=24)
    hits = 0
    rng = np.random.default_rng(5)
    for pid in rng.choice(len(data), 10, replace=False):
        ids, dists, _ = idx.query(data[pid], k=1)
        exact = weighted_lp_np(data, data[pid], w, 2.0)
        if ids[0] >= 0 and dists[0] <= cfg.c * np.partition(exact, 1)[0] + 1e-6:
            hits += 1
    assert hits >= 8  # c-NN guarantee holds with constant probability


# ------------------------------------------------------------- SL/S2-ALSH


def test_rho_values_in_unit_interval(weights):
    for R in (500.0, 1000.0):
        r_sl = rho_sl(weights, R=R, c=3.0)
        r_s2 = rho_s2(weights, R=R, c=3.0)
        assert 0.0 < r_sl < 1.0
        assert 0.0 < r_s2 < 1.0


def test_rho_decreases_with_c(weights):
    """Paper Table 7: required tables decrease with c."""
    rs = [rho_sl(weights, R=1000.0, c=c) for c in (2.0, 4.0, 6.0)]
    assert rs[0] >= rs[1] >= rs[2]
    rs2 = [rho_s2(weights, R=1000.0, c=c) for c in (2.0, 4.0, 6.0)]
    assert rs2[0] >= rs2[1] >= rs2[2]


def test_alsh_table_count_grows_polynomially(weights):
    rho = rho_sl(weights, R=1000.0, c=3.0)
    l1 = alsh_tables(100_000, rho)
    l2 = alsh_tables(1_600_000, rho)
    assert l2 > l1
    # polynomial growth: l2/l1 ~ 16^rho (way faster than log)
    assert l2 / l1 > np.log(1_600_000) / np.log(100_000)


def test_alsh_query_finds_close_points(weights):
    """The asymmetric MIPS reduction must rank near neighbors first.

    Clustered (SIFT-like) data: on uniform data these methods degrade to
    near-random for adversarial weight vectors (rho ~ 0.98, the paper's
    motivation), so the meaningful check is that they find structure where
    structure exists.  Bimodal per-weight behaviour (perfect hit or cluster-
    level miss) is expected and matches the paper's 120/160 win-rate framing.
    """
    rng0 = np.random.default_rng(100)
    centers = rng0.uniform(0, 10_000, (30, 16))
    data = (
        centers[rng0.integers(0, 30, 1_500)]
        + rng0.normal(0, 300, (1_500, 16))
    ).clip(0, 10_000).astype(np.float32)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    rng = np.random.default_rng(7)
    for variant in ("sl", "s2"):
        idx = ALSHIndex(data, cfg, variant=variant, m=16, L=16, seed=8)
        ratios = []
        for _ in range(8):
            pid = rng.integers(0, len(data))
            w = weights[rng.integers(0, len(weights))]
            q = data[pid].astype(np.float64) + rng.normal(0, 50.0, 16)
            ids, dists, n_checked = idx.query(q, w, k=5, budget=300)
            assert n_checked <= 300
            got = ids[ids >= 0]
            exact = np.sort(weighted_lp_np(data, q, w, 2.0))[: got.size]
            mine = np.sort(weighted_lp_np(data[got], q, w, 2.0))
            ratios.append(float(np.mean(mine / np.maximum(exact, 1e-9))))
        ratios = np.asarray(ratios)
        assert np.median(ratios) <= 8.0, f"{variant}: {ratios}"
        assert np.sum(ratios < 2.0) >= 3, f"{variant}: {ratios}"


def test_wlsh_beats_alsh_space_at_paper_scale(weights):
    """Table 1 headline: WLSH tables O(log n) vs ALSH n^rho (l2, c=3)."""
    from repro.core.partition import partition

    cfg = PlanConfig(p=2.0, c=3, n=400_000, gamma_n=100.0)
    res = partition(weights, cfg, 10_000.0, tau=500.0, v=4, v_prime=4)
    rho = rho_sl(weights, R=1000.0, c=3.0)
    l_sl = alsh_tables(400_000, rho)
    assert res.beta_total < l_sl
