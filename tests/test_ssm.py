"""Mamba2 SSD: chunked scan == step-by-step recurrence; decode state flow."""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import get_config, reduced
from repro.models.params import init_params
from repro.models.ssm import (
    mamba2_block,
    mamba2_decode_step,
    ssm_defs,
    ssm_state_shape,
)


@pytest.fixture(scope="module")
def setup():
    cfg = reduced(get_config("mamba2_780m"))
    cfg = dataclasses.replace(cfg, ssm_chunk=8)
    params = init_params(ssm_defs(cfg), jax.random.PRNGKey(0))
    return cfg, params


def test_chunked_equals_stepwise(setup):
    """The SSD chunked path must equal running the recurrence token by
    token — the state-space duality the architecture is named for."""
    cfg, params = setup
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.5

    y_chunked, s_last = mamba2_block(params, x, cfg, mesh=None)

    state = {
        "ssm": jnp.zeros(ssm_state_shape(cfg, B)["ssm"], jnp.float32),
        "conv": jnp.zeros(ssm_state_shape(cfg, B)["conv"], jnp.float32),
    }
    ys = []
    for t in range(S):
        y_t, state = mamba2_decode_step(params, x[:, t], cfg, None, state)
        ys.append(y_t)
    y_step = jnp.stack(ys, axis=1)

    np.testing.assert_allclose(
        np.asarray(y_chunked, np.float32), np.asarray(y_step, np.float32),
        rtol=2e-2, atol=2e-3,
    )
    np.testing.assert_allclose(
        np.asarray(s_last), np.asarray(state["ssm"]), rtol=2e-2, atol=2e-3,
    )


def test_initial_state_continuation(setup):
    """Processing [first half] then [second half from carried state] must
    equal one full pass — the prefill-to-decode handoff invariant."""
    cfg, params = setup
    B, S = 2, 32
    x = jax.random.normal(jax.random.PRNGKey(2), (B, S, cfg.d_model),
                          jnp.float32) * 0.5
    y_full, s_full = mamba2_block(params, x, cfg, mesh=None)
    y_a, s_a = mamba2_block(params, x[:, : S // 2], cfg, mesh=None)
    # NOTE: conv carry across the split is not part of mamba2_block's API
    # (prefill always starts at position 0); feed the overlap explicitly.
    # We check the *state* recurrence instead: second half step-by-step
    # from s_a with the conv tail.
    state = {
        "ssm": s_a,
        "conv": x_conv_tail(cfg, params, x[:, : S // 2]),
    }
    ys = []
    for t in range(S // 2, S):
        y_t, state = mamba2_decode_step(params, x[:, t], cfg, None, state)
        ys.append(y_t)
    y_b = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(
        np.asarray(y_full[:, S // 2 :], np.float32),
        np.asarray(y_b, np.float32),
        rtol=2e-2, atol=2e-3,
    )


def x_conv_tail(cfg, params, x_prefix):
    """Conv carry after a prefix: last K-1 pre-conv xBC rows."""
    dt_ = x_prefix.dtype
    proj = x_prefix @ params["in_proj"].astype(dt_)
    di = cfg.d_inner
    gn = cfg.ssm_state
    xBC = proj[..., di : 2 * di + 2 * gn]
    return xBC[:, -(cfg.conv_kernel - 1):, :]


def test_state_shape_contract(setup):
    cfg, params = setup
    shapes = ssm_state_shape(cfg, batch=3)
    assert shapes["ssm"] == (3, cfg.ssm_heads, cfg.ssm_state,
                             cfg.ssm_head_dim)
    assert shapes["conv"] == (3, cfg.conv_kernel - 1,
                              cfg.d_inner + 2 * cfg.ssm_state)


def test_decay_clamp_no_nan(setup):
    """Long sequences with large dt must not overflow the decay kernel."""
    cfg, params = setup
    big = jax.random.normal(jax.random.PRNGKey(3), (1, 64, cfg.d_model),
                            jnp.float32) * 20.0
    y, s = mamba2_block(params, big, cfg, mesh=None)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert bool(jnp.all(jnp.isfinite(s)))
