"""Weighted distance functions (Definition 4) + radius bounds."""

from __future__ import annotations

import numpy as np
import pytest
from _hyp import given, st

from repro.core.distances import (
    radius_bounds,
    weighted_angular_np,
    weighted_hamming_np,
    weighted_lp,
    weighted_lp_np,
)


@st.composite
def _xyw(draw):
    d = draw(st.integers(2, 12))
    rng = np.random.default_rng(draw(st.integers(0, 2**31 - 1)))
    return (
        rng.uniform(-100, 100, d),
        rng.uniform(-100, 100, d),
        rng.uniform(0.5, 10, d),
    )


@given(_xyw(), st.sampled_from([0.5, 1.0, 1.5, 2.0]))
def test_weighted_lp_is_rescaled_lp(pack, p):
    """D_W(x, y) == D(W o x, W o y) — the identity WLSH is built on."""
    x, y, w = pack
    direct = weighted_lp_np(x, y, w, p)
    scaled = weighted_lp_np(x * w, y * w, np.ones_like(w), p)
    np.testing.assert_allclose(direct, scaled, rtol=1e-9)


@given(_xyw())
def test_metric_axioms_p_ge_1(pack):
    x, y, w = pack
    for p in (1.0, 2.0):
        assert weighted_lp_np(x, x, w, p) == pytest.approx(0.0, abs=1e-9)
        np.testing.assert_allclose(
            weighted_lp_np(x, y, w, p), weighted_lp_np(y, x, w, p)
        )
        z = (x + y) / 2
        lhs = weighted_lp_np(x, y, w, p)
        rhs = weighted_lp_np(x, z, w, p) + weighted_lp_np(z, y, w, p)
        assert lhs <= rhs + 1e-9


def test_jax_matches_numpy():
    rng = np.random.default_rng(0)
    x = rng.uniform(-10, 10, (5, 8)).astype(np.float32)
    y = rng.uniform(-10, 10, (5, 8)).astype(np.float32)
    w = rng.uniform(1, 10, 8).astype(np.float32)
    for p in (0.5, 1.0, 2.0):
        a = np.asarray(weighted_lp(x, y, w, p))
        b = weighted_lp_np(x, y, w.astype(np.float64), p)
        np.testing.assert_allclose(a, b, rtol=2e-4)


def test_weighted_hamming():
    x = np.array([0, 1, 1, 0])
    y = np.array([0, 0, 1, 1])
    w = np.array([5.0, 2.0, 3.0, 7.0])
    assert weighted_hamming_np(x, y, w) == pytest.approx(9.0)


def test_weighted_angular_range():
    rng = np.random.default_rng(1)
    x = rng.normal(size=16)
    w = rng.uniform(1, 10, 16)
    assert weighted_angular_np(x, x, w) == pytest.approx(0.0, abs=1e-6)
    assert weighted_angular_np(x, -x, w) == pytest.approx(np.pi, abs=1e-6)


@pytest.mark.parametrize("p", [1.0, 2.0])
def test_radius_bounds_achievable(p):
    """r_min/r_max must bound all achievable integer-grid distances."""
    rng = np.random.default_rng(2)
    d, vr = 6, 100.0
    w = rng.uniform(1, 10, d)
    r_min, r_max = radius_bounds(w, vr, p)
    pts = rng.integers(0, int(vr) + 1, (200, d)).astype(float)
    qts = rng.integers(0, int(vr) + 1, (200, d)).astype(float)
    dist = weighted_lp_np(pts, qts, w, p)
    nz = dist[dist > 0]
    assert np.all(nz >= r_min - 1e-9)
    assert np.all(dist <= r_max + 1e-9)
    # extremes are achievable
    lo = np.zeros(d)
    hi = np.full(d, vr)
    assert weighted_lp_np(lo, hi, w, p) == pytest.approx(r_max)
    e = np.zeros(d)
    e[np.argmin(w)] = 1.0
    assert weighted_lp_np(lo, e, w, p) == pytest.approx(r_min)
