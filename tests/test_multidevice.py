"""Multi-device SPMD semantics via subprocesses (8 forced host devices).

The main test process must keep the single real CPU device (smoke tests),
so anything needing a populated mesh runs in a child process with
XLA_FLAGS=--xla_force_host_platform_device_count=8.  These are the CI-scale
versions of the production dry-run meshes.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

import pytest

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(code: str, timeout=600):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(_ROOT, "src")
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env,
    )
    assert r.returncode == 0, f"STDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    return r.stdout


def test_sharded_engine_matches_host_oracle_on_8_devices():
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.core.datagen import make_dataset, make_weight_set
        from repro.core.params import PlanConfig
        from repro.core.wlsh import WLSHIndex
        from repro.index import IndexConfig, build_state, encode_queries, \
            make_query_step

        assert jax.device_count() == 8
        data = make_dataset(n=1024, d=16, seed=41)
        weights = make_weight_set(size=6, d=16, n_subset=2, n_subrange=10,
                                  seed=42)
        cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
        host = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4,
                         seed=9)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        gi = int(host.part.group_of[0])
        built = host._group(gi)
        icfg = IndexConfig(
            n=len(data), d=16, beta=built.fam.beta, q_batch=4, k=3,
            c=3, n_levels=int(np.max(built.plan.n_levels)), p=2.0,
            block_n=128, gamma_n=cfg.gamma_n,
            vec_dtype="float32", use_pallas=False,
        )
        state = build_state(mesh, icfg, data, built.fam)
        step = make_query_step(mesh, icfg)
        wid = int(built.plan.member_ids[0])
        _, slot, beta_i, mu_i = host._member_params(wid)
        pids = [3, 400, 777, 1000]
        qpts = jnp.asarray(data[pids], jnp.float32)
        dists, ids, stop, _ = step(
            state,
            qpts,
            encode_queries(state, qpts),
            jnp.asarray(np.stack([host.weights[wid]] * 4), jnp.float32),
            jnp.asarray([mu_i] * 4, jnp.int32),
            jnp.asarray([built.plan.r_min_members[slot]] * 4, jnp.float32),
            jnp.asarray([beta_i] * 4, jnp.int32),
            jnp.asarray([int(built.plan.n_levels[slot])] * 4, jnp.int32),
        )
        ids = np.asarray(ids)
        assert list(ids[:, 0]) == pids, ids[:, 0]
        assert np.all(np.asarray(dists)[:, 0] < 1e-3)
        # per-query oracle agreement on stop level
        for qi, pid in enumerate(pids):
            want = host.search_dense(data[pid], weight_id=wid, k=3)
            assert int(np.asarray(stop)[qi]) == want.stats.stop_level
        print("OK")
    """)
    assert "OK" in out


def test_retrieval_service_on_8_devices_matches_host_oracle():
    """Multi-group serving on a real (4,2) mesh: routed, coalesced queries
    match search_dense per query, with compiled-step sharing intact."""
    out = _run("""
        import numpy as np, jax
        from repro.core.datagen import make_dataset, make_weight_set
        from repro.core.params import PlanConfig
        from repro.core.wlsh import WLSHIndex
        from repro.serving import RetrievalService, ServiceConfig

        assert jax.device_count() == 8
        data = make_dataset(n=1024, d=16, seed=41)
        weights = make_weight_set(size=8, d=16, n_subset=4, n_subrange=10,
                                  seed=42)
        cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
        host = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4,
                         seed=9)
        plan = host.export_serving_plan()
        assert plan.n_groups >= 3
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        svc = RetrievalService(plan, data, mesh=mesh,
                               cfg=ServiceConfig(k=3, q_batch=4))
        rng = np.random.default_rng(43)
        wids = rng.integers(0, len(weights), 10)
        qpts = data[rng.choice(len(data), 10, replace=False)].astype(
            np.float32)
        qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
        res = svc.query(qpts, wids)
        assert len(np.unique(res.group_ids)) >= 3
        for qi in range(10):
            want = host.search_dense(qpts[qi], weight_id=int(wids[qi]), k=3)
            np.testing.assert_array_equal(res.ids[qi],
                                          want.ids.astype(np.int32))
            assert int(res.stop_levels[qi]) == want.stats.stop_level
        assert svc.step_cache.n_compiled < plan.n_groups
        print("OK")
    """)
    assert "OK" in out


def test_train_step_spmd_matches_single_device():
    """Same tiny model, same batch: (4,2)-mesh loss == 1-device loss."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.base import ShapeConfig, get_config, reduced
        from repro.models import build_model, init_params, make_batch
        from repro.models.params import param_specs
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import (batch_shardings,
            init_train_state, make_train_step, train_state_shardings)

        cfg = reduced(get_config("olmo_1b"))
        shape = ShapeConfig("s", 16, 8, "train")
        ocfg = AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
        batch = make_batch(cfg, shape, seed=3)

        # single device
        m0 = build_model(cfg, mesh=None)
        p0 = init_params(m0.defs(), jax.random.PRNGKey(0))
        s0 = init_train_state(m0.defs(), p0, ocfg)
        _, met0 = jax.jit(make_train_step(m0, ocfg))(s0, batch)

        # 4x2 mesh
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        m1 = build_model(cfg, mesh=mesh)
        p1 = init_params(m1.defs(), jax.random.PRNGKey(0))
        s1 = init_train_state(m1.defs(), p1, ocfg)
        sh = train_state_shardings(m1.defs(), ocfg, mesh)
        s1 = jax.tree.map(
            lambda x, s: jax.device_put(x, s), s1, sh,
            is_leaf=lambda x: hasattr(x, "shape"),
        )
        bsh = batch_shardings(mesh, batch)
        batch1 = jax.tree.map(jax.device_put, batch, bsh)
        step = jax.jit(make_train_step(m1, ocfg),
                       in_shardings=(sh, bsh), donate_argnums=(0,))
        _, met1 = step(s1, batch1)
        l0, l1 = float(met0["loss"]), float(met1["loss"])
        assert abs(l0 - l1) / abs(l0) < 0.05, (l0, l1)
        print("OK", l0, l1)
    """)
    assert "OK" in out


def test_dryrun_cell_on_8_device_mesh():
    """A miniature dry-run: lower+compile a reduced arch on a real 8-device
    mesh through the launcher path (sharding rules, input specs)."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp
        from repro.configs.base import ShapeConfig, get_config, reduced
        from repro.models import build_model, input_specs
        from repro.models.params import abstract_params, param_specs
        from repro.training.optimizer import AdamWConfig
        from repro.training.train_loop import (batch_shardings,
            make_train_step, train_state_defs)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        cfg = reduced(get_config("olmoe_1b_7b"))
        shape = ShapeConfig("s", 64, 8, "train")
        model = build_model(cfg, mesh=mesh)
        ocfg = AdamWConfig()
        sdefs = train_state_defs(model.defs(), ocfg)
        state_abs = abstract_params(sdefs)
        state_sh = jax.tree.map(
            lambda sp: jax.sharding.NamedSharding(mesh, sp),
            param_specs(sdefs, mesh))
        batch_abs = input_specs(cfg, shape)
        step = make_train_step(model, ocfg)
        lowered = jax.jit(step, in_shardings=(state_sh,
            batch_shardings(mesh, batch_abs)), donate_argnums=(0,)
        ).lower(state_abs, batch_abs)
        compiled = lowered.compile()
        ca = compiled.cost_analysis()
        if isinstance(ca, list): ca = ca[0]
        assert ca.get("flops", 0) > 0
        print("OK flops=", ca.get("flops"))
    """)
    assert "OK" in out


def test_elastic_checkpoint_across_meshes():
    """Save under a (2,4) mesh, restore under (4,2) — elastic restart."""
    out = _run("""
        import numpy as np, jax, jax.numpy as jnp, tempfile
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.training.checkpoint import load_checkpoint, save_checkpoint

        tree = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8)}
        mesh_a = jax.make_mesh((2, 4), ("data", "model"))
        sh_a = {"w": NamedSharding(mesh_a, P("data", "model"))}
        tree_a = jax.tree.map(jax.device_put, tree, sh_a)
        with tempfile.TemporaryDirectory() as d:
            save_checkpoint(d, 1, tree_a)
            mesh_b = jax.make_mesh((4, 2), ("data", "model"))
            sh_b = {"w": NamedSharding(mesh_b, P("model", "data"))}
            _, restored, _ = load_checkpoint(d, tree, shardings=sh_b)
            np.testing.assert_array_equal(
                np.asarray(restored["w"]), np.asarray(tree["w"]))
        print("OK")
    """)
    assert "OK" in out
