"""Partition (Function Partition + greedy WSC): optimization invariants."""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.datagen import make_weight_set
from repro.core.params import PlanConfig
from repro.core.partition import pairwise_beta, partition, tau_min

_VR = 10_000.0


def _cfg(**kw):
    base = dict(p=2.0, c=3.0, gamma_n=100.0, n=400_000)
    base.update(kw)
    return PlanConfig(**base)


@pytest.fixture(scope="module")
def weights():
    return make_weight_set(size=24, d=16, n_subset=4, n_subrange=10, seed=7)


def test_partition_is_disjoint_cover(weights):
    cfg = _cfg()
    res = partition(weights, cfg, _VR, tau=500.0, v=4, v_prime=4)
    m = len(weights)
    assert res.group_of.shape == (m,)
    assert np.all(res.group_of >= 0)
    seen = set()
    for gi, g in enumerate(res.groups):
        ids = set(int(i) for i in g.member_ids)
        assert not (ids & seen), "groups must be disjoint"
        seen |= ids
        assert np.all(res.group_of[g.member_ids] == gi)
    assert seen == set(range(m)), "groups must cover S"


def test_per_group_tables_below_tau(weights):
    tau = 500.0
    res = partition(weights, _cfg(), _VR, tau=tau, v=4, v_prime=4)
    for g in res.groups:
        assert g.beta_group <= tau
        assert np.all(np.isfinite(g.betas))
        assert g.beta_group == int(np.max(g.betas))
    assert res.beta_total == sum(g.beta_group for g in res.groups)


def test_beta_total_not_worse_than_naive(weights):
    """The partition must never need more tables than one-group-per-W."""
    cfg = _cfg()
    B, _, _, _ = pairwise_beta(weights, cfg, _VR, v=4, v_prime=4)
    naive = float(np.sum(np.diag(B)))
    res = partition(weights, cfg, _VR, tau=max(tau_min(B), 500.0), v=4, v_prime=4)
    assert res.beta_total <= naive + 1e-9


def test_tau_below_tau_min_raises(weights):
    cfg = _cfg()
    B, _, _, _ = pairwise_beta(weights, cfg, _VR, v=4, v_prime=4)
    with pytest.raises(ValueError):
        partition(weights, cfg, _VR, tau=0.5 * tau_min(B), v=4, v_prime=4)


def test_identical_weights_share_one_group():
    w = np.full((8, 16), 3.0)
    res = partition(w, _cfg(), _VR, tau=10_000.0)
    assert len(res.groups) == 1
    g = res.groups[0]
    # all members identical -> identical beta; group beta == member beta
    assert np.allclose(g.betas, g.betas[0])
    assert g.beta_group == g.betas[0]


def test_bound_relaxation_reduces_tables(weights):
    """Paper Sec. 5.2.1 / Table 6: beta^br << beta (strict Theorem 1)."""
    cfg = _cfg()
    strict = partition(weights, cfg, _VR, tau=1e9, v=1, v_prime=1)
    relaxed = partition(weights, cfg, _VR, tau=1e9, v=4, v_prime=4)
    assert relaxed.beta_total <= strict.beta_total


def test_group_parameters_sane(weights):
    res = partition(weights, _cfg(), _VR, tau=500.0, v=4, v_prime=4)
    for g in res.groups:
        assert np.all(g.mus <= g.betas + 1e-9)
        assert np.all(g.mus_reduced <= g.mus + 1e-9)
        assert np.all(g.mus_reduced >= 1.0)
        assert g.width > 0
        assert g.ratio_cap >= 1.0
        assert np.all(g.n_levels >= 1)
        # member slots index correctly
        for slot, wid in enumerate(g.member_ids):
            assert res.member_slot[wid] == slot


def test_mu_reduced_matches_c2lsh_extension(weights):
    """mu_hat = X * mu with X = P((c^2 r)^up) / P((r)^up) < 1."""
    res = partition(weights, _cfg(), _VR, tau=500.0, v=4, v_prime=4)
    for g in res.groups:
        ratio = g.mus_reduced / np.maximum(g.mus, 1e-12)
        assert np.all(ratio <= 1.0 + 1e-9)
