"""Async deadline-aware frontend vs the sync retrieval service.

The async frontend must launch a compiled step immediately when a group's
pending buffer fills, launch a *partial* (padded) batch once the oldest
request's deadline budget expires, share group states / serving stats /
the compiled-step cache with the sync frontend (compile counter pinned),
and answer identical traffic bit-exactly vs `RetrievalService.query` for
every supported exponent p in {2, 1, 0.5}.  Deadline behaviour is tested
on a deterministic ManualClock via the same code path real-time callers
use (submit / poll / drain).
"""

from __future__ import annotations

import numpy as np
import pytest

from repro.core.datagen import make_dataset, make_weight_set
from repro.core.params import PlanConfig
from repro.core.wlsh import WLSHIndex
from repro.serving import (
    AsyncRetrievalService,
    ManualClock,
    RetrievalService,
    ServiceConfig,
    replay_open_loop,
)

QB = 4
MAX_DELAY_MS = 5.0


@pytest.fixture(scope="module")
def tiny():
    data = make_dataset(n=512, d=16, seed=21)
    weights = make_weight_set(size=6, d=16, n_subset=3, n_subrange=10,
                              seed=22)
    cfg = PlanConfig(p=2.0, c=3, n=len(data), gamma_n=100.0)
    host = WLSHIndex(data, weights, cfg, tau=500.0, v=4, v_prime=4, seed=23)
    plan = host.export_serving_plan()
    svc = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=3, q_batch=QB, max_delay_ms=MAX_DELAY_MS),
    )
    svc.warmup()
    return data, weights, plan, svc


def _one_group_traffic(data, plan, n, seed=31):
    """n queries all under member weights of the largest group."""
    gi = int(np.argmax([g.n_members for g in plan.groups]))
    members = plan.groups[gi].member_ids
    rng = np.random.default_rng(seed)
    wids = members[rng.integers(0, len(members), n)]
    qpts = data[rng.choice(len(data), n, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    return gi, qpts, wids


def test_full_batch_launches_immediately(tiny):
    data, weights, plan, svc = tiny
    gi, qpts, wids = _one_group_traffic(data, plan, QB)
    svc.reset_stats()
    clock = ManualClock()
    asvc = AsyncRetrievalService(svc, clock=clock)
    futs = [asvc.submit(qpts[i], wids[i]) for i in range(QB - 1)]
    assert not any(f.done() for f in futs)  # buffer below q_batch: no launch
    assert asvc.pending_count == QB - 1
    futs.append(asvc.submit(qpts[QB - 1], wids[QB - 1]))
    # the fill-triggering submit launched without any clock advance or poll
    assert all(f.done() for f in futs)
    assert asvc.pending_count == 0
    assert asvc.n_launched_full == 1 and asvc.n_launched_deadline == 0
    st = svc.stats[gi]
    assert st.n_batches == 1 and st.n_queries == QB and st.n_padded == 0
    assert st.occupancy == 1.0


def test_deadline_expiry_launches_partial_batch(tiny):
    data, weights, plan, svc = tiny
    gi, qpts, wids = _one_group_traffic(data, plan, 2)
    svc.reset_stats()
    clock = ManualClock()
    asvc = AsyncRetrievalService(svc, clock=clock)
    futs = [asvc.submit(qpts[i], wids[i]) for i in range(2)]
    assert asvc.next_deadline() == pytest.approx(MAX_DELAY_MS / 1e3)
    assert asvc.poll() == 0  # deadline not reached: nothing launches
    clock.advance(0.8 * MAX_DELAY_MS / 1e3)
    assert asvc.poll() == 0
    assert not any(f.done() for f in futs)
    clock.advance(0.4 * MAX_DELAY_MS / 1e3)  # past the oldest deadline
    assert asvc.poll() == 1
    assert all(f.done() for f in futs)
    assert asvc.n_launched_deadline == 1 and asvc.n_launched_full == 0
    st = svc.stats[gi]
    assert st.n_batches == 1 and st.n_queries == 2
    assert st.n_padded == QB - 2  # partial batch padded to the compiled shape


def test_per_request_deadline_overrides_budget(tiny):
    data, weights, plan, svc = tiny
    gi, qpts, wids = _one_group_traffic(data, plan, 1)
    clock = ManualClock(10.0)
    asvc = AsyncRetrievalService(svc, clock=clock)
    fut = asvc.submit(qpts[0], wids[0], deadline=10.0 + 1e-4)
    assert asvc.next_deadline() == pytest.approx(10.0 + 1e-4)
    clock.advance(2e-4)  # well under max_delay_ms, past the explicit deadline
    assert asvc.poll() == 1
    assert fut.done()


def test_result_pending_raises_until_drain(tiny):
    data, weights, plan, svc = tiny
    gi, qpts, wids = _one_group_traffic(data, plan, 1)
    asvc = AsyncRetrievalService(svc, clock=ManualClock())
    fut = asvc.submit(qpts[0], wids[0])
    with pytest.raises(RuntimeError):
        fut.result()
    assert asvc.drain() == 1
    assert asvc.n_launched_drain == 1
    ans = fut.result()
    assert ans.group_id == gi and ans.ids.shape == (svc.cfg.k,)
    assert asvc.pending_count == 0 and asvc.next_deadline() is None


def test_submit_validation(tiny):
    data, weights, plan, svc = tiny
    asvc = AsyncRetrievalService(svc, clock=ManualClock())
    with pytest.raises(ValueError):
        asvc.submit(data[0], len(weights))  # weight_id out of range
    with pytest.raises(ValueError):
        asvc.submit(data[0][:4], 0)  # wrong query dimensionality
    with pytest.raises(ValueError):
        asvc.submit(data[0], 0, deadline=float("nan"))  # would never expire
    with pytest.raises(ValueError):
        asvc.submit(data[0], 0, deadline=float("inf"))
    assert asvc.pending_count == 0  # rejected submissions left nothing queued
    with pytest.raises(ValueError):
        AsyncRetrievalService(svc, max_delay_ms=-1.0)


def test_failed_launch_restores_pending_buffer(tiny):
    """A device error inside a launch must be atomic: the batch returns to
    its buffer in order, no future is stranded, and a retry succeeds."""
    data, weights, plan, svc = tiny
    gi, qpts, wids = _one_group_traffic(data, plan, 2)
    clock = ManualClock()
    asvc = AsyncRetrievalService(svc, clock=clock)
    futs = [asvc.submit(qpts[i], wids[i]) for i in range(2)]
    real_run_batch = asvc.batcher.run_batch
    asvc.batcher.run_batch = lambda *a, **kw: (_ for _ in ()).throw(
        RuntimeError("injected device failure")
    )
    try:
        clock.advance(1.0)
        with pytest.raises(RuntimeError, match="injected"):
            asvc.poll()
    finally:
        asvc.batcher.run_batch = real_run_batch
    assert asvc.pending_count == 2  # nothing dropped
    assert not any(f.done() for f in futs)
    assert asvc.poll() == 1  # retry after the transient failure succeeds
    assert all(f.done() for f in futs)
    # submission order survived the round trip through the failed launch
    np.testing.assert_array_equal(
        np.stack([f.result().ids for f in futs]),
        svc.query(qpts, wids).ids,
    )


def test_failed_fill_launch_in_submit_withdraws_only_the_new_request(tiny):
    """When the fill-triggering submit itself fails, the caller holds no
    future — their request must be withdrawn (a retry re-submits it) while
    the earlier requests stay queued with live futures."""
    data, weights, plan, svc = tiny
    gi, qpts, wids = _one_group_traffic(data, plan, QB)
    asvc = AsyncRetrievalService(svc, clock=ManualClock())
    futs = [asvc.submit(qpts[i], wids[i]) for i in range(QB - 1)]
    real_run_batch = asvc.batcher.run_batch
    asvc.batcher.run_batch = lambda *a, **kw: (_ for _ in ()).throw(
        RuntimeError("injected device failure")
    )
    try:
        with pytest.raises(RuntimeError, match="injected"):
            asvc.submit(qpts[QB - 1], wids[QB - 1])
    finally:
        asvc.batcher.run_batch = real_run_batch
    assert asvc.pending_count == QB - 1  # only the failed submit withdrawn
    assert not any(f.done() for f in futs)
    fut = asvc.submit(qpts[QB - 1], wids[QB - 1])  # retry fills the batch
    assert fut.done() and all(f.done() for f in futs)
    np.testing.assert_array_equal(
        np.stack([f.result().ids for f in futs + [fut]]),
        svc.query(qpts, wids).ids,
    )


def test_replay_requires_manual_clock(tiny):
    data, weights, plan, svc = tiny
    asvc = AsyncRetrievalService(svc)  # default time.monotonic clock
    with pytest.raises(TypeError):
        replay_open_loop(asvc, data[:2], [0, 0], [0.0, 1.0])


def _mixed_traffic(data, weights, n, seed):
    rng = np.random.default_rng(seed)
    wids = rng.integers(0, len(weights), n)
    qpts = data[rng.choice(len(data), n, replace=False)].astype(np.float32)
    qpts += rng.normal(0, 3.0, qpts.shape).astype(np.float32)
    arrivals = np.cumsum(rng.exponential(1 / 2_000.0, n))
    return qpts, wids, arrivals


def test_async_matches_sync_bitexact(parity_setup):
    """Identical traffic through both frontends: bit-exact ids / stop /
    n_checked per p in {2, 1, 0.5}, with every wait bounded by the deadline
    budget."""
    p, data, weights, host, plan, svc = parity_setup
    qpts, wids, arrivals = _mixed_traffic(data, weights, 32, seed=37)
    sync = svc.query(qpts, wids)
    asvc = AsyncRetrievalService(svc, max_delay_ms=2.0, clock=ManualClock())
    res, waits = replay_open_loop(asvc, qpts, wids, arrivals)
    np.testing.assert_array_equal(res.ids, sync.ids)
    np.testing.assert_array_equal(res.dists, sync.dists)
    np.testing.assert_array_equal(res.group_ids, sync.group_ids)
    np.testing.assert_array_equal(res.stop_levels, sync.stop_levels)
    np.testing.assert_array_equal(res.n_checked, sync.n_checked)
    assert np.all(waits >= 0) and np.all(waits <= 2.0 / 1e3 + 1e-9)
    assert asvc.n_launched_full + asvc.n_launched_deadline > 0
    assert asvc.n_launched_drain == 0  # replay runs the tail out by deadline


def test_compile_counter_pinned_across_frontends(parity_setup):
    """Layering the async frontend over a warmed sync service must compile
    nothing new: both frontends share one QueryStepCache."""
    p, data, weights, host, plan, svc = parity_setup
    svc.warmup()
    qpts, wids, arrivals = _mixed_traffic(data, weights, 16, seed=39)
    before = svc.step_cache.n_compiled
    svc.query(qpts, wids)
    asvc = AsyncRetrievalService(svc, max_delay_ms=1.0, clock=ManualClock())
    replay_open_loop(asvc, qpts, wids, arrivals)
    assert svc.step_cache.n_compiled == before


def test_open_loop_occupancy_beats_single_submission(tiny):
    """The deadline batcher must lift occupancy over the sync frontend fed
    one request at a time (the serve_bench sweep-2 penalty) on the same
    arrival trace."""
    data, weights, plan, svc = tiny
    qpts, wids, arrivals = _mixed_traffic(data, weights, 48, seed=41)
    svc.reset_stats()
    for qi in range(len(qpts)):  # open-loop sync: one launch per request
        svc.query(qpts[qi : qi + 1], wids[qi : qi + 1])
    occ_sync = svc.mean_occupancy()
    svc.reset_stats()
    asvc = AsyncRetrievalService(svc, max_delay_ms=5.0, clock=ManualClock())
    replay_open_loop(asvc, qpts, wids, arrivals)
    occ_async = svc.mean_occupancy()
    assert occ_sync == pytest.approx(1.0 / QB)  # every sync launch pads QB-1
    assert occ_async > occ_sync


def test_async_launcher_runs():
    """--async end-to-end: open-loop Poisson replay + host-oracle check."""
    from repro.launch.retrieval import main

    out = main([
        "--n", "512", "--d", "16", "--n-weights", "4", "--n-subset", "2",
        "--n-queries", "12", "--k", "3", "--v", "4", "--q-batch", "4",
        "--check", "--async", "--max-delay-ms", "2", "--arrival-rate",
        "1500",
    ])
    assert out["n_check_failures"] == 0
    rep = out["async"]
    assert rep["n_launched_full"] + rep["n_launched_deadline"] >= 1
    # every wait is bounded by the deadline budget
    assert rep["p95_wait_ms"] <= rep["max_delay_ms"] + 1e-6


# --------------------------------------------------------- backpressure


def test_submit_raises_overloaded_at_max_pending(tiny):
    """ServiceConfig.max_pending bounds per-group pending buffers: the
    overflowing submit raises a typed Overloaded (with the observed
    depth) *before* enqueueing, and capacity freed by poll()/drain()
    accepts new submissions again."""
    from repro.serving import Overloaded

    data, weights, plan, svc = tiny
    bounded = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=3, q_batch=8, max_delay_ms=MAX_DELAY_MS,
                          max_pending=2),
    )
    clock = ManualClock()
    asvc = AsyncRetrievalService(bounded, clock=clock)
    gi, qpts, wids = _one_group_traffic(data, plan, 4)
    futs = [asvc.submit(qpts[i], wids[i]) for i in range(2)]
    with pytest.raises(Overloaded) as err:
        asvc.submit(qpts[2], wids[2])
    assert err.value.group_id == gi
    assert err.value.depth == 2 and err.value.max_pending == 2
    # the rejected request was never enqueued and no future was resolved
    assert asvc.pending_count == 2
    assert not any(f.done() for f in futs)
    # deadline expiry drains the buffer; the retry is accepted
    clock.advance(MAX_DELAY_MS / 1e3 + 1e-4)
    assert asvc.poll() == 1
    assert all(f.done() for f in futs)
    fut = asvc.submit(qpts[2], wids[2])
    assert asvc.pending_count == 1
    asvc.drain()
    assert fut.done()


def test_max_pending_transparent_for_fill_launched_traffic(tiny):
    """A cap at q_batch never fires on well-batched traffic: fill
    launches drain the buffer before it can overflow, and answers stay
    bit-exact with the unbounded frontend."""
    data, weights, plan, svc = tiny
    bounded = RetrievalService(
        plan, data,
        cfg=ServiceConfig(k=3, q_batch=QB, max_delay_ms=MAX_DELAY_MS,
                          max_pending=QB),
    )
    qpts, wids, arrivals = _mixed_traffic(data, weights, 24, seed=77)
    ref, _ = replay_open_loop(
        AsyncRetrievalService(svc, clock=ManualClock()),
        qpts, wids, arrivals,
    )
    got, _ = replay_open_loop(
        AsyncRetrievalService(bounded, clock=ManualClock()),
        qpts, wids, arrivals,
    )
    np.testing.assert_array_equal(got.ids, ref.ids)
    np.testing.assert_array_equal(got.stop_levels, ref.stop_levels)
