"""Shared fixtures for the WLSH framework test suite.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
must see the single real CPU device (the 512-device override is strictly
dryrun.py's, per the multi-pod dry-run spec).  Multi-device engine tests
spawn subprocesses that set the flag themselves.
"""

from __future__ import annotations

import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, HealthCheck, settings

if HAVE_HYPOTHESIS:
    settings.register_profile(
        "ci",
        max_examples=25,
        deadline=None,
        suppress_health_check=[
            HealthCheck.too_slow,
            HealthCheck.data_too_large,
        ],
    )
    settings.load_profile("ci")


@pytest.fixture(scope="session")
def small_data():
    from repro.core.datagen import make_dataset

    return make_dataset(n=2_000, d=24, value_range=10_000.0, seed=1)


@pytest.fixture(scope="session")
def small_weights():
    from repro.core.datagen import make_weight_set

    return make_weight_set(size=12, d=24, n_subset=3, n_subrange=10, seed=2)


@pytest.fixture(scope="session")
def plan_cfg():
    from repro.core.params import PlanConfig

    return PlanConfig(p=2.0, c=3, n=2_000, gamma_n=100.0)


@pytest.fixture(scope="session")
def built_index(small_data, small_weights, plan_cfg):
    from repro.core.wlsh import WLSHIndex

    return WLSHIndex(
        small_data,
        small_weights,
        plan_cfg,
        tau=500.0,
        v=6,
        v_prime=6,
        use_reduction=True,
        seed=0,
    )
