"""Shared fixtures for the WLSH framework test suite.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches
must see the single real CPU device (the 512-device override is strictly
dryrun.py's, per the multi-pod dry-run spec).  Multi-device engine tests
spawn subprocesses that set the flag themselves.
"""

from __future__ import annotations

import numpy as np
import pytest

from _hyp import HAVE_HYPOTHESIS, HealthCheck, settings

if HAVE_HYPOTHESIS:
    settings.register_profile(
        "ci",
        max_examples=25,
        deadline=None,
        suppress_health_check=[
            HealthCheck.too_slow,
            HealthCheck.data_too_large,
        ],
    )
    settings.load_profile("ci")


_parity_cache: dict = {}


def build_parity_service(p: float):
    """Session-cached (p, data, weights, host, plan, svc) per exponent.

    One serving build per p in {2, 1, 0.5} (paper tau defaults for l2/l1,
    scaled up for the heavier-tailed p=0.5 family), shared by the service
    parity suite, the async frontend suite, and the p=2 structure tests so
    the expensive partition/plan/build step runs once per exponent.
    """
    if p not in _parity_cache:
        from repro.core.datagen import make_dataset, make_weight_set
        from repro.core.params import PlanConfig
        from repro.core.wlsh import WLSHIndex
        from repro.serving import RetrievalService, ServiceConfig

        tau = {2.0: 500.0, 1.0: 1_000.0, 0.5: 2_000.0}[p]
        data = make_dataset(n=1_024, d=16, seed=41)
        # 4 subsets of 2 users -> the partition yields >= 3 groups with
        # distinct per-member beta/mu at every supported exponent
        weights = make_weight_set(size=8, d=16, n_subset=4, n_subrange=10,
                                  seed=42)
        cfg = PlanConfig(p=p, c=3, n=len(data), gamma_n=100.0)
        host = WLSHIndex(data, weights, cfg, tau=tau, v=4, v_prime=4,
                         seed=9)
        plan = host.export_serving_plan()
        assert plan.n_groups >= 3, "fixture must span >= 3 table groups"
        svc = RetrievalService(plan, data,
                               cfg=ServiceConfig(k=5, q_batch=4))
        _parity_cache[p] = (p, data, weights, host, plan, svc)
    return _parity_cache[p]


@pytest.fixture(scope="session", params=[2.0, 1.0, 0.5],
                ids=lambda p: f"p{p}")
def parity_setup(request):
    """(p, data, weights, host, plan, svc) per distance exponent."""
    return build_parity_service(request.param)


@pytest.fixture(scope="session")
def small_data():
    from repro.core.datagen import make_dataset

    return make_dataset(n=2_000, d=24, value_range=10_000.0, seed=1)


@pytest.fixture(scope="session")
def small_weights():
    from repro.core.datagen import make_weight_set

    return make_weight_set(size=12, d=24, n_subset=3, n_subrange=10, seed=2)


@pytest.fixture(scope="session")
def plan_cfg():
    from repro.core.params import PlanConfig

    return PlanConfig(p=2.0, c=3, n=2_000, gamma_n=100.0)


@pytest.fixture(scope="session")
def built_index(small_data, small_weights, plan_cfg):
    from repro.core.wlsh import WLSHIndex

    return WLSHIndex(
        small_data,
        small_weights,
        plan_cfg,
        tau=500.0,
        v=6,
        v_prime=6,
        use_reduction=True,
        seed=0,
    )
