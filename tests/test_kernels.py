"""Pallas kernel sweeps vs the pure-jnp ref.py oracles.

Per the kernel contract:
  * freq_level: exact integer match (no float path after the codes);
  * hash_encode: exact match except at floor boundaries, where independent
    f32 summation orders may legitimately differ by one bucket (|diff| <= 1
    and only where the pre-floor value is within eps of an integer);
  * weighted_lp: allclose in f32;
  * fused_query_block: histograms exact-int; scores carry an identical
    +inf stop-mask and are bit-exact for p != 2 when d is already a lane
    multiple (no padding), else ulp-tight allclose — padding d changes
    the f32 reduction tree, and the p = 2 in-body MXU expansion may
    differ from the XLA gemm in the last ulp.  (Serving bit-exactness
    does not rest on this: off-TPU the fused path is the XLA composite
    in ref.py, which shares the unfused engine's helpers exactly.)

All Pallas calls run with interpret=True on CPU (the kernel body itself is
executed), matching how the kernels are validated off-TPU.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.kernels import ops, ref

# Pallas-interpret runs grid cells in Python -> keep shapes moderate.
_SHAPES = [
    (64, 16, 24, 4),  # (n, d, beta, Q)
    (300, 40, 70, 9),
    (257, 33, 128, 3),  # non-multiples exercise wrapper padding
    (512, 128, 64, 8),
]


def _mk(n, d, beta, Q, seed=0, int_vals=False):
    rng = np.random.default_rng(seed)
    if int_vals:
        pts = rng.integers(0, 1000, (n, d)).astype(np.float32)
        qs = rng.integers(0, 1000, (Q, d)).astype(np.float32)
    else:
        pts = rng.uniform(0, 1000, (n, d)).astype(np.float32)
        qs = rng.uniform(0, 1000, (Q, d)).astype(np.float32)
    w = rng.uniform(1, 10, d).astype(np.float32)
    proj = rng.normal(0, 1, (d, beta)).astype(np.float32)
    b = rng.uniform(0, 729.0, beta)
    b_int = np.floor(b).astype(np.int32)
    b_frac = (b - b_int).astype(np.float32)
    return pts, qs, w, proj, b_int, b_frac


def _boundary_ok(diff, u):
    """Mismatches must be |1| and only where u is ~at an integer boundary."""
    if not diff.any():
        return True
    if np.abs(diff[diff != 0]).max() > 1:
        return False
    frac = np.abs(u - np.round(u))
    return bool(np.all(frac[diff != 0] < 1e-2))


@pytest.mark.parametrize("shape", _SHAPES, ids=str)
def test_hash_encode_sweep(shape):
    n, d, beta, Q = shape
    pts, _, w, proj, b_int, b_frac = _mk(n, d, beta, Q)
    width = 37.5
    got_ref = np.array(
        ops.hash_encode(pts, w, proj, b_int, b_frac, width, use_pallas=False)
    )
    got_pal = np.array(
        ops.hash_encode(pts, w, proj, b_int, b_frac, width, use_pallas=True,
                        interpret=True, bn=128, bb=64, bd=64)
    )
    u = (pts * w) @ proj / width + b_frac
    assert _boundary_ok(got_pal - got_ref, u)
    mismatch = np.mean(got_pal != got_ref)
    assert mismatch < 1e-3  # boundary jitter must stay rare


@pytest.mark.parametrize("shape", _SHAPES, ids=str)
@pytest.mark.parametrize("c,n_levels", [(2, 10), (3, 7)])
def test_freq_level_sweep(shape, c, n_levels):
    n, d, beta, Q = shape
    pts, qs, w, proj, b_int, b_frac = _mk(n, d, beta, Q, seed=1)
    cp = np.array(ops.hash_encode(pts, w, proj, b_int, b_frac, 10.0,
                                  use_pallas=False))
    cq = np.array(ops.hash_encode(qs, w, proj, b_int, b_frac, 10.0,
                                  use_pallas=False))
    rng = np.random.default_rng(2)
    mu = rng.integers(1, max(2, beta // 3), Q).astype(np.int32)
    beta_q = rng.integers(1, beta + 1, Q).astype(np.int32)
    got_ref = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=n_levels,
                                      beta_q=beta_q, use_pallas=False))
    got_pal = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=n_levels,
                                      beta_q=beta_q, use_pallas=True,
                                      interpret=True, bn=128))
    np.testing.assert_array_equal(got_ref, got_pal)


def test_freq_level_semantics_bruteforce():
    """ref.freq_level == brute-force per-level collision counting."""
    rng = np.random.default_rng(3)
    n, beta, Q, c, L = 80, 12, 5, 3, 6
    cp = rng.integers(-(c**L), c**L, (n, beta)).astype(np.int32)
    cq = rng.integers(-(c**L), c**L, (Q, beta)).astype(np.int32)
    mu = rng.integers(1, 6, Q).astype(np.int32)
    got = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L,
                                  use_pallas=False))
    for qi in range(Q):
        for pi in range(n):
            first = L + 1
            for j in range(L + 1):
                cnt = np.sum(
                    (cp[pi] // (c**j)) == (cq[qi] // (c**j))
                )
                if cnt >= mu[qi]:
                    first = j
                    break
            assert got[qi, pi] == first


def test_freq_level_monotone_in_mu():
    """Larger mu can only delay the first frequent level."""
    rng = np.random.default_rng(4)
    cp = rng.integers(0, 729, (64, 16)).astype(np.int32)
    cq = rng.integers(0, 729, (4, 16)).astype(np.int32)
    prev = None
    for mu in (1, 3, 6, 12):
        cur = np.array(
            ops.freq_level(cp, cq, mu, c=3, n_levels=6, use_pallas=False)
        )
        if prev is not None:
            assert np.all(cur >= prev)
        prev = cur


def test_count_level_matches_numpy():
    rng = np.random.default_rng(5)
    cp = rng.integers(0, 500, (100, 20)).astype(np.int32)
    cq = rng.integers(0, 500, (6, 20)).astype(np.int32)
    for lvl in (0, 1, 3):
        got = np.array(ref.count_level_ref(cp, cq, c=3, level=lvl))
        want = (
            (cq[:, None, :] // 3**lvl) == (cp[None, :, :] // 3**lvl)
        ).sum(-1)
        np.testing.assert_array_equal(got, want)


@pytest.mark.parametrize("shape", _SHAPES[:3], ids=str)
@pytest.mark.parametrize("p", [0.5, 1.0, 1.5])
def test_weighted_lp_sweep(shape, p):
    n, d, beta, Q = shape
    pts, qs, w, *_ = _mk(n, d, beta, Q, seed=6)
    got_ref = np.array(ops.weighted_lp_dist(qs, pts, w, p, use_pallas=False))
    got_pal = np.array(ops.weighted_lp_dist(qs, pts, w, p, use_pallas=True,
                                            interpret=True, bn=128, bd=64))
    np.testing.assert_allclose(got_ref, got_pal, rtol=2e-4, atol=2e-2)


@pytest.mark.parametrize("p", [1.0, 2.0])
def test_weighted_lp_vs_host_oracle(p):
    from repro.core.distances import weighted_lp_np

    pts, qs, w, *_ = _mk(150, 32, 8, 7, seed=7)
    got = np.array(ops.weighted_lp_dist(qs, pts, w, p))
    want = np.stack([weighted_lp_np(pts, q, w.astype(np.float64), p)
                     for q in qs])
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-2)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_weighted_lp_dtypes(dtype):
    pts, qs, w, *_ = _mk(64, 16, 4, 3, seed=8)
    got = np.array(
        ops.weighted_lp_dist(
            jnp.asarray(qs, dtype), jnp.asarray(pts, dtype),
            jnp.asarray(w, jnp.float32), 2.0, use_pallas=False,
        )
    )
    ref32 = np.array(ops.weighted_lp_dist(qs, pts, w, 2.0, use_pallas=False))
    tol = 1e-5 if dtype == jnp.float32 else 0.05
    np.testing.assert_allclose(got, ref32, rtol=tol, atol=tol * 1e3)


@settings(max_examples=15)
@given(
    n=st.integers(8, 96),
    beta=st.integers(2, 24),
    q=st.integers(1, 6),
    c=st.sampled_from([2, 3]),
    seed=st.integers(0, 10_000),
)
def test_property_freq_level_pallas_equals_ref(n, beta, q, c, seed):
    rng = np.random.default_rng(seed)
    L = 5
    cp = rng.integers(-(c**L) * 2, (c**L) * 2, (n, beta)).astype(np.int32)
    cq = rng.integers(-(c**L) * 2, (c**L) * 2, (q, beta)).astype(np.int32)
    mu = rng.integers(1, beta + 1, q).astype(np.int32)
    a = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L,
                                use_pallas=False))
    b = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L, use_pallas=True,
                                interpret=True, bn=64))
    np.testing.assert_array_equal(a, b)


# ------------------------------------------------- fused query block step

# Smaller than _SHAPES: interpret mode runs the grid in Python, and the
# fused kernel re-runs per p.  (257, 33, ...) keeps wrapper padding (row
# and d non-multiples of bn=128) in the sweep.
_FUSED_SHAPES = [
    (64, 16, 24, 4),  # (n, d, beta, Q)
    (257, 33, 70, 3),
    (96, 128, 24, 3),  # d a lane multiple: the bit-exact p != 2 case
]
_PS = [2.0, 1.0, 0.5]


def _mk_fused(n, d, beta, Q, seed=0):
    rng = np.random.default_rng(seed)
    cp = rng.integers(-(2**16), 2**16, (n, beta)).astype(np.int32)
    cq = rng.integers(-(2**16), 2**16, (Q, beta)).astype(np.int32)
    pts = rng.uniform(0, 1000, (n, d)).astype(np.float32)
    qs = rng.uniform(0, 1000, (Q, d)).astype(np.float32)
    qw = rng.uniform(1, 10, (Q, d)).astype(np.float32)
    mu = rng.integers(1, max(2, beta // 3), Q).astype(np.int32)
    beta_q = rng.integers(max(1, beta // 2), beta + 1, Q).astype(np.int32)
    r_min = rng.uniform(10.0, 200.0, Q).astype(np.float32)
    stop = rng.integers(0, 9, Q).astype(np.int32)
    return cp, cq, pts, qs, qw, mu, beta_q, r_min, stop


def _fused_both(shape, p, *, boff, n_valid, stop=None, seed=0, bn=128):
    """(ref-route result, pallas-interpret result) for one config."""
    n, d, beta, Q = shape
    cp, cq, pts, qs, qw, mu, beta_q, r_min, st_ = _mk_fused(
        n, d, beta, Q, seed=seed)
    if stop is not None:
        stop = st_
    kw = dict(boff=boff, n_valid=n_valid, c=2, n_levels=8, p=p, stop=stop)
    got_ref = ops.fused_query_block(cp, pts, cq, qs, qw, mu, r_min, beta_q,
                                    use_pallas=False, **kw)
    got_pal = ops.fused_query_block(cp, pts, cq, qs, qw, mu, r_min, beta_q,
                                    use_pallas=True, interpret=True, bn=bn,
                                    **kw)
    return got_ref, got_pal


@pytest.mark.parametrize("shape", _FUSED_SHAPES, ids=str)
@pytest.mark.parametrize("p", _PS)
def test_fused_hist_pallas_equals_ref(shape, p):
    n = shape[0]
    (hf0, hg0), (hf1, hg1) = _fused_both(shape, p, boff=0, n_valid=n)
    np.testing.assert_array_equal(np.array(hf0), np.array(hf1))
    np.testing.assert_array_equal(np.array(hg0), np.array(hg1))
    # every live row lands in exactly one frequent bin; good rows are a
    # prefix-dominated subset (good = max(lf, jg) >= lf; rows whose good
    # level overflows the kept bins drop out of hist_g entirely)
    assert np.all(np.array(hf0).sum(axis=1) == n)
    assert np.all(np.array(hg0).sum(axis=1) <= n)
    assert np.all(np.cumsum(hg0, axis=1) <= np.cumsum(hf0, axis=1))


@pytest.mark.parametrize("shape", _FUSED_SHAPES, ids=str)
@pytest.mark.parametrize("p", _PS)
def test_fused_scores_pallas_equals_ref(shape, p):
    n = shape[0]
    s0, s1 = _fused_both(shape, p, boff=0, n_valid=n, stop=True)
    s0, s1 = np.array(s0), np.array(s1)
    fin = np.isfinite(s0)
    np.testing.assert_array_equal(fin, np.isfinite(s1))  # same stop mask
    if abs(p - 2.0) < 1e-9:
        np.testing.assert_allclose(s0[fin], s1[fin], rtol=2e-4, atol=2e-2)
    elif shape[1] % 128 == 0:
        np.testing.assert_array_equal(s0[fin], s1[fin])  # bit-exact, no pad
    else:  # d-padding changes the f32 reduction tree: ulp-tight only
        np.testing.assert_allclose(s0[fin], s1[fin], rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("p", _PS)
def test_fused_streaming_watermark(p):
    """Rows at/after n_valid vanish from hists and score +inf, both paths.

    boff puts the block mid-stream so the watermark cuts it at row 21 of
    64: a streaming state serving with n_valid below capacity.
    """
    shape = (64, 16, 24, 4)
    boff, n_valid = 1000, 1021  # rows 21.. of this block are dead
    live = n_valid - boff
    (hf0, hg0), (hf1, hg1) = _fused_both(shape, p, boff=boff,
                                         n_valid=n_valid)
    np.testing.assert_array_equal(np.array(hf0), np.array(hf1))
    np.testing.assert_array_equal(np.array(hg0), np.array(hg1))
    assert np.all(np.array(hf0).sum(axis=1) == live)
    assert np.all(np.array(hg0).sum(axis=1) <= live)
    s0, s1 = _fused_both(shape, p, boff=boff, n_valid=n_valid, stop=True)
    s0, s1 = np.array(s0), np.array(s1)
    assert np.all(np.isinf(s0[:, live:])) and np.all(np.isinf(s1[:, live:]))
    np.testing.assert_array_equal(np.isfinite(s0), np.isfinite(s1))


def test_fused_ref_matches_unfused_stages():
    """The fused XLA composite vs the seed-era separate stages.

    Pins the bit-exact-by-construction property the engine relies on:
    same distance helpers, same shapes -> identical bins 0..L and
    identical stop-masked scores (dead-row parking differs only in bins
    the stop logic never reads: unfused L+1 vs fused's sliced-off L+2).
    """
    n, d, beta, Q = 300, 40, 70, 9
    c, L = 2, 8
    cp, cq, pts, qs, qw, mu, beta_q, r_min, stop = _mk_fused(
        n, d, beta, Q, seed=11)
    n_valid = n - 17
    row_ok = np.arange(n) < n_valid
    for p in _PS:
        hf, hg = ops.fused_query_block(
            cp, pts, cq, qs, qw, mu, r_min, beta_q, boff=0, n_valid=n_valid,
            c=c, n_levels=L, p=p, use_pallas=False)
        lf = np.array(ops.freq_level(cp, cq, mu, c=c, n_levels=L,
                                     beta_q=beta_q, use_pallas=False))
        dist = np.array(ref.per_query_dist(jnp.asarray(qs), jnp.asarray(qw),
                                           jnp.asarray(pts), p))
        jg = np.ceil(np.maximum(
            np.log(np.maximum(dist, 1e-30)) / np.log(c)
            - np.log(c * r_min)[:, None] / np.log(c), 0.0)).astype(np.int64)
        good = np.maximum(lf, jg)
        for bins, fused in ((lf, np.array(hf)), (good, np.array(hg))):
            for j in range(L + 1):  # bins the stop logic reads
                want = ((bins == j) & row_ok[None, :]).sum(axis=1)
                np.testing.assert_array_equal(fused[:, j], want)
        scores = np.array(ops.fused_query_block(
            cp, pts, cq, qs, qw, mu, r_min, beta_q, boff=0, n_valid=n_valid,
            c=c, n_levels=L, p=p, stop=stop, use_pallas=False))
        want = np.where((lf <= stop[:, None]) & row_ok[None, :], dist, np.inf)
        np.testing.assert_array_equal(scores, want)  # bit-exact, shared HLO


def test_fused_scalar_broadcast_and_default_beta():
    """Scalar mu/r_min/stop and beta_q=None broadcast like arrays."""
    n, d, beta, Q = 64, 16, 24, 4
    cp, cq, pts, qs, qw, *_ = _mk_fused(n, d, beta, Q, seed=12)
    kw = dict(boff=0, n_valid=n, c=2, n_levels=8, p=1.0)
    a = ops.fused_query_block(cp, pts, cq, qs, qw, 3, 50.0, None,
                              use_pallas=False, **kw)
    b = ops.fused_query_block(cp, pts, cq, qs, qw,
                              np.full(Q, 3, np.int32),
                              np.full(Q, 50.0, np.float32),
                              np.full(Q, beta, np.int32),
                              use_pallas=False, **kw)
    np.testing.assert_array_equal(np.array(a[0]), np.array(b[0]))
    np.testing.assert_array_equal(np.array(a[1]), np.array(b[1]))


def test_hash_encode_matches_host_family():
    """Kernel path must agree with core.families.hash_codes_np (the planner's
    oracle) — the int split of b* is exactness-critical."""
    from repro.core.families import hash_codes_np, sample_lp_family

    rng = np.random.default_rng(9)
    pts = rng.integers(0, 10_000, (128, 24)).astype(np.float32)
    wc = rng.uniform(1, 10, 24)
    fam = sample_lp_family(d=24, beta=16, p=2.0, width=50.0,
                           center_weight=wc, ratio_cap=1e5, c=3, seed=2)
    want = hash_codes_np(pts, fam)
    got = np.array(
        ops.hash_encode(
            pts, fam.center_weight, fam.proj, fam.b_int, fam.b_frac,
            fam.width, use_pallas=False,
        )
    )
    diff = got - want
    u = (pts * fam.center_weight) @ fam.proj / fam.width + fam.b_frac
    assert _boundary_ok(diff, u)
    assert np.mean(diff != 0) < 1e-3
